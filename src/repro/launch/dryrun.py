import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell we build abstract inputs (ShapeDtypeStruct only — zero
allocation), jit the step function with explicit in/out shardings over the
production mesh, ``.lower().compile()``, and extract:

  * ``compiled.cost_analysis()``   -> HLO FLOPs / bytes accessed,
  * ``compiled.memory_analysis()`` -> per-device buffer sizes (proves fit),
  * the partitioned HLO text       -> per-collective operand bytes
    (all-gather / all-reduce / reduce-scatter / all-to-all /
    collective-permute), which cost_analysis does not report,

and derive the three roofline terms (docs/EXPERIMENTS.md §Roofline) against
TPU v5e constants. One JSON artifact per cell; ``--sweep`` runs every cell in
a subprocess (resumable — existing artifacts are skipped).

Usage:
  python -m repro.launch.dryrun --arch tinyllama-1.1b --shape train_4k
  python -m repro.launch.dryrun --arch qwen3-moe-30b-a3b --shape train_4k --multi-pod
  python -m repro.launch.dryrun --sweep --out-dir artifacts/dryrun
"""
import argparse
import json
import re
import sys
import time
from pathlib import Path

# v5e per-chip hardware constants (roofline denominators)
PEAK_FLOPS = 197e12      # bf16 FLOP/s
HBM_BW = 819e9           # B/s
LINK_BW = 50e9           # B/s per ICI link

from repro.launch import hloparse


def ring_link_bytes(collectives: dict) -> float:
    """Per-device bytes crossing the busiest link, ring-algorithm model:
    all-gather / reduce-scatter move (g-1)/g of the full buffer; all-reduce
    2x that; permute moves the operand once."""
    total = 0.0
    for op, rec in collectives.items():
        gs = rec.get("group_sizes") or {}
        n = sum(gs.values())
        g = (sum(int(k) * v for k, v in gs.items()) / n) if n else 2.0
        frac = (g - 1.0) / g if g > 1 else 0.0
        if op == "all-gather":
            total += rec["result_bytes"] * frac
        elif op == "reduce-scatter":
            total += rec["operand_bytes"] * frac
        elif op == "all-reduce":
            total += 2.0 * rec["operand_bytes"] * frac
        elif op in ("all-to-all", "ragged-all-to-all"):
            total += rec["operand_bytes"] * frac
        elif op == "collective-permute":
            total += rec["operand_bytes"]
    return total


# ---------------------------------------------------------------------------
# Cell construction
# ---------------------------------------------------------------------------

def build_cell(arch: str, shape_name: str, multi_pod: bool, *,
               rules_mode=None, q_chunk=512, remat=True, rwkv_chunk=32,
               use_flash=True):
    """Returns (jitted_fn, abstract_args, meta). Imports jax lazily so the
    XLA_FLAGS line above always wins."""
    import jax
    import jax.numpy as jnp
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.configs.base import SHAPE_BY_NAME, shape_applicable
    from repro.configs.registry import get_config
    from repro.launch import sharding as SH
    from repro.launch.mesh import make_production_mesh
    from repro.models import transformer as T
    from repro.optim import adamw
    from repro.train import step as S

    cfg = get_config(arch)
    shape = SHAPE_BY_NAME[shape_name]
    ok, why = shape_applicable(cfg, shape)
    if not ok:
        return None, None, {"skipped": why}

    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = mesh.devices.size
    mode = "train" if shape.kind == "train" else "serve"
    rules = SH.ShardingRules(mode=rules_mode or mode)
    shd = SH.make_sharder(mesh, rules)
    make_ctx = lambda: T.Ctx(mode="train", shd=shd, q_chunk=q_chunk,
                             remat=remat, rwkv_chunk=rwkv_chunk,
                             flash=use_flash)

    from repro.models.params import abstract_params

    specs = T.param_specs(cfg)
    aparams = abstract_params(specs)
    psh = SH.tree_param_shardings(specs, mesh, rules)
    repl = SH.replicated(mesh)

    B, Sq = shape.global_batch, shape.seq_len
    meta = {
        "arch": arch, "config": cfg.name, "shape": shape_name,
        "mesh": list(mesh.devices.shape), "axes": list(mesh.axis_names),
        "chips": chips, "kind": shape.kind,
        "n_params": cfg.n_params, "n_active_params": cfg.n_active_params,
    }

    if shape.kind == "train":
        opt_cfg = adamw.AdamWConfig()
        aopt = adamw.abstract_state(aparams, opt_cfg)
        ospecs = _opt_specs(specs, opt_cfg)
        osh = {"m": SH.tree_param_shardings(ospecs["m"], mesh, rules),
               "v": SH.tree_param_shardings(ospecs["v"], mesh, rules),
               "step": repl}
        abatch = S.abstract_batch(cfg, B, Sq)
        bsh = SH.batch_shardings(abatch, mesh, rules)
        fn = S.make_train_step(cfg, opt_cfg, make_ctx)
        msh = {k: repl for k in ("loss", "ce", "moe_aux", "grad_norm")}
        jf = jax.jit(fn, in_shardings=(psh, osh, bsh),
                     out_shardings=(psh, osh, msh), donate_argnums=(0, 1))
        args = (aparams, aopt, abatch)
    elif shape.kind == "prefill":
        acache = T.abstract_cache(cfg, B, Sq)
        csh = SH.tree_param_shardings(T.cache_specs(cfg, B, Sq), mesh, rules)
        abatch = S.abstract_batch(cfg, B, Sq)
        bsh = SH.batch_shardings(abatch, mesh, rules)
        fn = S.make_prefill_step(cfg, lambda: T.Ctx(
            mode="prefill", shd=shd, q_chunk=q_chunk, remat=remat,
            rwkv_chunk=rwkv_chunk, flash=use_flash))
        lsh = NamedSharding(mesh, SH.resolve((B, 1, cfg.vocab),
                                             ("batch", None, "vocab"),
                                             mesh, rules, "act"))
        jf = jax.jit(fn, in_shardings=(psh, bsh, csh),
                     out_shardings=(lsh, csh), donate_argnums=(2,))
        args = (aparams, abatch, acache)
    else:  # decode
        acache = T.abstract_cache(cfg, B, Sq)
        csh = SH.tree_param_shardings(T.cache_specs(cfg, B, Sq), mesh, rules)
        atok = jax.ShapeDtypeStruct((B,), jnp.int32)
        apos = jax.ShapeDtypeStruct((), jnp.int32)
        fn = S.make_decode_step(cfg, lambda: T.Ctx(mode="decode", shd=shd,
                                                   q_chunk=q_chunk, remat=False))
        toksh = NamedSharding(mesh, SH.resolve((B,), ("batch",), mesh, rules, "act"))
        lsh = NamedSharding(mesh, SH.resolve((B, 1, cfg.vocab),
                                             ("batch", None, "vocab"),
                                             mesh, rules, "act"))
        jf = jax.jit(fn, in_shardings=(psh, toksh, csh, repl),
                     out_shardings=(lsh, csh), donate_argnums=(2,))
        args = (aparams, atok, acache, apos)
    return jf, args, meta


def _opt_specs(specs, opt_cfg):
    """ParamSpec tree for optimizer moments (fp32 mirror of params)."""
    import dataclasses as dc

    import jax

    from repro.models.params import is_spec

    def mom(s):
        return dc.replace(s, dtype=opt_cfg.moment_dtype, init="zeros")

    m = jax.tree_util.tree_map(mom, specs, is_leaf=is_spec)
    return {"m": m, "v": m}


# ---------------------------------------------------------------------------
# Roofline terms
# ---------------------------------------------------------------------------

def model_flops(meta, shape_kind: str, tokens: int) -> float:
    n = meta["n_active_params"]
    if shape_kind == "train":
        return 6.0 * n * tokens
    return 2.0 * n * tokens


def roofline(meta, parsed: "hloparse.Costs", chips: int, tokens: int) -> dict:
    """Three-term roofline from the trip-count-scaled per-device HLO costs."""
    flops_dev = parsed.flops
    bytes_dev = parsed.hbm_bytes
    coll_operand_dev = float(sum(v["operand_bytes"]
                                 for v in parsed.collectives.values()))
    link_dev = ring_link_bytes(parsed.collectives)
    terms = {
        "compute_s": flops_dev / PEAK_FLOPS,
        "memory_s": bytes_dev / HBM_BW,
        "collective_s": link_dev / LINK_BW,            # ring model (used)
        "collective_s_spec": coll_operand_dev / LINK_BW,  # literal spec formula
        "hlo_flops_per_dev": flops_dev,
        "hlo_bytes_per_dev": bytes_dev,
        "collective_link_bytes_per_dev": link_dev,
        "collective_operand_bytes_per_dev": coll_operand_dev,
    }
    dom = max(("compute_s", "memory_s", "collective_s"), key=lambda k: terms[k])
    terms["bottleneck"] = dom
    mf = model_flops(meta, meta["kind"], tokens)
    terms["model_flops"] = mf
    hlo_global = flops_dev * chips
    terms["useful_flop_ratio"] = (mf / hlo_global) if hlo_global else 0.0
    terms["roofline_fraction"] = (
        (mf / chips / PEAK_FLOPS) / max(terms[dom], 1e-30))
    return terms


# ---------------------------------------------------------------------------
# Single-cell run
# ---------------------------------------------------------------------------

def run_cell(arch: str, shape_name: str, multi_pod: bool, out_path=None,
             save_hlo=False, **build_kw) -> dict:
    from repro.configs.base import SHAPE_BY_NAME
    t0 = time.time()
    jf, args, meta = build_cell(arch, shape_name, multi_pod, **build_kw)
    rec = dict(meta)
    rec["multi_pod"] = multi_pod
    if jf is None:
        rec["status"] = "skipped"
        _write(rec, out_path)
        return rec
    shape = SHAPE_BY_NAME[shape_name]
    tokens = shape.tokens if shape.kind != "decode" else shape.global_batch
    try:
        lowered = jf.lower(*args)
        rec["lower_s"] = round(time.time() - t0, 2)
        t1 = time.time()
        compiled = lowered.compile()
        rec["compile_s"] = round(time.time() - t1, 2)
        cost = compiled.cost_analysis() or {}
        if isinstance(cost, (list, tuple)):     # older jax: one dict per device
            cost = cost[0] if cost else {}
        try:
            mem = compiled.memory_analysis()
            rec["memory_analysis"] = {
                k: int(getattr(mem, k))
                for k in ("argument_size_in_bytes", "output_size_in_bytes",
                          "temp_size_in_bytes", "generated_code_size_in_bytes",
                          "alias_size_in_bytes")
                if hasattr(mem, k)
            }
        except Exception as e:                       # pragma: no cover
            rec["memory_analysis_error"] = str(e)
        hlo = compiled.as_text()
        parsed = hloparse.analyze(hlo)
        rec["collectives"] = parsed.collectives
        rec["cost_analysis_raw"] = {           # note: counts loop bodies once
            k: v for k, v in cost.items()
            if k in ("flops", "bytes accessed", "transcendentals")}
        rec["roofline"] = roofline(meta, parsed, meta["chips"], tokens)
        rec["tokens"] = tokens
        rec["status"] = "ok"
        if save_hlo and out_path:
            Path(str(out_path).replace(".json", ".hlo.txt")).write_text(hlo)
    except Exception as e:
        rec["status"] = "error"
        rec["error"] = f"{type(e).__name__}: {e}"[:2000]
    rec["total_s"] = round(time.time() - t0, 2)
    _write(rec, out_path)
    return rec


def _write(rec, out_path):
    if out_path:
        Path(out_path).parent.mkdir(parents=True, exist_ok=True)
        Path(out_path).write_text(json.dumps(rec, indent=1, default=str))


def list_cells():
    from repro.configs.base import SHAPES, shape_applicable
    from repro.configs.registry import ARCH_IDS, get_config
    cells = []
    for a in ARCH_IDS:
        cfg = get_config(a)
        for s in SHAPES:
            ok, why = shape_applicable(cfg, s)
            cells.append((a, s.name, ok, why))
    return cells


def sweep(out_dir: str, multi_pod_also=True, timeout=2400):
    import subprocess
    out = Path(out_dir)
    out.mkdir(parents=True, exist_ok=True)
    jobs = []
    for mp in ([False, True] if multi_pod_also else [False]):
        for a, sname, ok, why in list_cells():
            tag = f"{a}__{sname}__{'mp' if mp else 'sp'}"
            jobs.append((a, sname, mp, out / f"{tag}.json"))
    for a, sname, mp, path in jobs:
        if path.exists():
            st = json.loads(path.read_text()).get("status")
            if st in ("ok", "skipped"):
                continue
        cmd = [sys.executable, "-m", "repro.launch.dryrun", "--arch", a,
               "--shape", sname, "--out", str(path)]
        if mp:
            cmd.append("--multi-pod")
        print(f"[sweep] {path.stem}", flush=True)
        try:
            subprocess.run(cmd, timeout=timeout, check=False)
        except subprocess.TimeoutExpired:
            _write({"arch": a, "shape": sname, "multi_pod": mp,
                    "status": "timeout", "timeout_s": timeout}, path)
    print("[sweep] done", flush=True)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--out")
    ap.add_argument("--save-hlo", action="store_true")
    ap.add_argument("--sweep", action="store_true")
    ap.add_argument("--single-pod-only", action="store_true")
    ap.add_argument("--out-dir", default="artifacts/dryrun")
    ap.add_argument("--list", action="store_true")
    args = ap.parse_args()
    if args.list:
        for a, s, ok, why in list_cells():
            print(f"{a:26s} {s:12s} {'run' if ok else 'SKIP: ' + why}")
        return
    if args.sweep:
        sweep(args.out_dir, multi_pod_also=not args.single_pod_only)
        return
    rec = run_cell(args.arch, args.shape, args.multi_pod, args.out,
                   save_hlo=args.save_hlo)
    print(json.dumps(rec, indent=1, default=str))


if __name__ == "__main__":
    main()
