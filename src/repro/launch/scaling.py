"""Multi-APU scaling driver: one decomposed cavity replay per node size.

Runs the `fig_scaling` measurement for ONE simulated node size: capture a
SIMPLE time-step, replay it on a single device and domain-decomposed
across ``--apus`` simulated APUs (``repro.core.shard_program``), assert
numerical parity (docs/DESIGN.md §2 tolerance), and report the node-level
compute / staging / inter-APU-exchange / overlap split from the
aggregated per-device ledgers.

The exchange schedule is selectable (docs/SCALING.md): ``--schedule
overlap`` (default) hides halo exchanges behind interior compute,
``sequential`` is the exposed PR-3 baseline, ``split`` runs the causal
interior/boundary sub-region split.  ``--halo-multiplier k`` exchanges
``k``-wide ghosts every ``k``-th stencil application, and ``--mesh 2x2``
decomposes over a 2-D mesh to cut surface-to-volume.  Grid extents that
don't divide over the mesh are padded up to the next multiple
(remainder-row padding — both replays run the padded grid, so parity
stays meaningful).

Each invocation must own its process: the APU count is baked into
``XLA_FLAGS=--xla_force_host_platform_device_count=N`` *before* the first
jax import (the ``launch.dryrun`` trick), so the benchmark harness
(``benchmarks/run.py fig_scaling``) runs this module once per node size in
a subprocess:

  PYTHONPATH=src python -m repro.launch.scaling --apus 4 --mesh 2x2 \\
      --steps 2 --grid 16,16,16 --policy unified \\
      --out artifacts/scaling/apu4.json
"""
from __future__ import annotations

import argparse
import json
import os
import sys
from pathlib import Path


def parse_args(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--apus", type=int, default=2,
                    help="simulated APUs (forced host-platform devices)")
    ap.add_argument("--mesh", default="",
                    help="mesh shape over the APUs, e.g. '4' (1-D) or "
                         "'2x2' (2-D, cuts surface-to-volume); default: "
                         "1-D over --apus")
    ap.add_argument("--steps", type=int, default=2,
                    help="replayed time-steps per measurement")
    ap.add_argument("--grid", default="8,8,8",
                    help="cavity grid; extents that don't divide over the "
                         "mesh are padded up to the next multiple")
    ap.add_argument("--policy", default="unified",
                    choices=("unified", "discrete", "host", "adaptive",
                             "auto"),
                    help="'auto' loads the tuned cfd_sharded profile "
                         "entry for this grid (repro.tune) and, where "
                         "--mesh/--schedule/--halo-multiplier are left "
                         "at their defaults, adopts the winner's values")
    ap.add_argument("--variant", default="ref",
                    help="implementation variant both replays run under "
                         "(StaticSelector; regions without it fall back "
                         "to ref — docs/VARIANTS.md)")
    ap.add_argument("--schedule", default="overlap",
                    choices=("overlap", "sequential", "split"),
                    help="halo-exchange schedule (docs/SCALING.md)")
    ap.add_argument("--halo-multiplier", type=int, default=1,
                    help="wide-halo ghost depth: exchange k-wide ghosts "
                         "every k-th stencil application")
    ap.add_argument("--inner-max", type=int, default=6)
    ap.add_argument("--out", default="", help="also write the JSON here")
    return ap.parse_args(argv)


def pad_grid(grid, mesh_shape, shard_dims=None):
    """Remainder-row padding: grow each decomposed grid extent to the next
    multiple of its mesh-axis size so every APU holds an equal chunk
    (odd-sized production grids must not silently replicate).  Mesh axes
    map to the trailing grid dimensions (the ShardExecutor default)."""
    dims = shard_dims or range(-len(mesh_shape), 0)
    grid = list(grid)
    for dim, n in zip(dims, mesh_shape):
        e = grid[dim]
        grid[dim] = -(-e // n) * n
    return tuple(grid)


def main(argv=None) -> dict:
    args = parse_args(argv)
    if "jax" not in sys.modules:
        # mesh.apu_flags spells the same flag, but importing repro.launch
        # .mesh would itself import jax — too late to set flags after that.
        # Ours goes LAST: with repeated absl flags the last occurrence
        # wins, so an inherited device-count pin cannot override the run.
        flag = f"--xla_force_host_platform_device_count={args.apus}"
        os.environ["XLA_FLAGS"] = " ".join(
            [os.environ.get("XLA_FLAGS", ""), flag]).strip()
    import jax
    import numpy as np

    if jax.device_count() < args.apus:
        raise SystemExit(
            f"jax sees {jax.device_count()} device(s) but --apus="
            f"{args.apus}; run this module in a fresh process (it sets "
            "XLA_FLAGS itself) or export XLA_FLAGS first")

    from repro.cfd.grid import Grid
    from repro.cfd.simple import SimpleConfig, SimpleFoam, init_state
    from repro.core.regions import Executor, StaticSelector, make_policy
    from repro.core.shard_program import shard_program
    from repro.launch.mesh import make_apu_mesh, parse_mesh_shape

    tuned_cell = None
    if args.policy == "auto":
        # tuned warm-start: nearest cfd_sharded profile cell for this
        # grid; CLI knobs left at their defaults adopt the winner's
        # values, explicit non-default flags win (imported after the jax
        # flag dance above — repro.tune's harness imports model code)
        from repro.launch.policy import auto_policy
        from repro.tune.space import cfd_size
        grid_req = tuple(int(g) for g in args.grid.split(","))
        pol = auto_policy("cfd_sharded", cfd_size(grid_req))
        tuned = getattr(pol, "tuned_entry", None)
        args.policy = (tuned.candidate.placement if tuned is not None
                       else "unified")
        if tuned is not None:
            tuned_cell = tuned.key
            c = tuned.candidate
            if not args.mesh and c.mesh and len(c.mesh) > 1:
                prod = 1
                for m in c.mesh:
                    prod *= m
                if prod == args.apus:
                    args.mesh = "x".join(str(m) for m in c.mesh)
            if args.schedule == "overlap":
                args.schedule = c.schedule
            if args.halo_multiplier == 1:
                args.halo_multiplier = c.halo_multiplier

    mesh_shape = parse_mesh_shape(args.mesh) if args.mesh else (args.apus,)
    n_mesh = 1
    for s in mesh_shape:
        n_mesh *= s
    if n_mesh != args.apus:
        raise SystemExit(f"mesh {mesh_shape} needs {n_mesh} APUs but "
                         f"--apus={args.apus}")
    grid_requested = tuple(int(g) for g in args.grid.split(","))
    grid = pad_grid(grid_requested, mesh_shape)
    cfg = SimpleConfig(grid=Grid(grid), nu=0.1, inner_max=args.inner_max)
    app = SimpleFoam(cfg)
    st = init_state(cfg)
    st, _, _ = app.run_steps(st, 1)          # develop flow + warm caches
    prog = app.capture_step(st)

    # BOTH replays run the same variant selection, so sharded-vs-single
    # parity stays within the §2 bound whichever implementation runs
    selector = StaticSelector(args.variant)

    # single-device reference replay of the same trace
    ref_policy = make_policy(args.policy)
    ref_policy.selector = selector
    ref = Executor(ref_policy)
    app.replay_steps(prog, st, 1, ref)       # warm per-sharding compiles
    ref.ledger.reset_timings()
    s_ref, fom_ref = app.replay_steps(prog, st, args.steps, ref)

    # decomposed replay across the simulated node
    mesh = make_apu_mesh(mesh_shape)
    sh_policy = make_policy(args.policy)
    sh_policy.selector = selector
    sp = shard_program(prog, mesh, sh_policy,
                       halo_multiplier=args.halo_multiplier,
                       overlap=args.schedule != "sequential",
                       split_stencil=args.schedule == "split")
    app.replay_steps(prog, st, 1, sp)        # warm sharded compiles
    sp.reset_timings()
    s_sh, fom_sh = app.replay_steps(prog, st, args.steps, sp)

    fields = zip((s_ref.u, s_ref.v, s_ref.w, s_ref.p),
                 (s_sh.u, s_sh.v, s_sh.w, s_sh.p))
    max_err = max(float(np.max(np.abs(np.asarray(a) - np.asarray(b))))
                  for a, b in fields)
    scale = max(float(np.max(np.abs(np.asarray(f))))
                for f in (s_ref.u, s_ref.v, s_ref.w, s_ref.p))
    # docs/DESIGN.md §2: float32 replay parity tolerance
    tol = 1e-5 * max(scale, 1.0)
    rep = sp.coverage_report()
    rec = {
        "apus": args.apus,
        "mesh_shape": list(mesh_shape),
        "grid": list(grid),
        "grid_requested": list(grid_requested),
        "grid_padded": grid != grid_requested,
        "steps": args.steps,
        "policy": args.policy,
        "tuned_cell": tuned_cell,
        "variant": args.variant,
        "schedule": args.schedule,
        "halo_multiplier": args.halo_multiplier,
        "impl_counts": rep["impl_counts"],
        "ops": len(prog),
        "fom_single_s": fom_ref,
        "fom_sharded_s": fom_sh,
        "exchange_fraction": rep["exchange_fraction"],
        "exchange_s": rep["exchange_s"],
        "overlap_s": rep["overlap_s"],
        "parity_max_abs_err": max_err,
        "parity_tol": tol,
        "parity_ok": bool(max_err <= tol),
        "halo_rows": sorted(n for n in sp.ledgers[0].regions
                            if n.startswith("halo(")),
        "report": rep,
    }
    if not rec["parity_ok"]:
        rec["status"] = "parity_failure"
    else:
        rec["status"] = "ok"
    if args.out:
        out = Path(args.out)
        out.parent.mkdir(parents=True, exist_ok=True)
        out.write_text(json.dumps(rec, indent=1, default=str))
    print(json.dumps({k: v for k, v in rec.items() if k != "report"},
                     indent=1, default=str))
    if not rec["parity_ok"]:
        raise SystemExit(2)
    return rec


if __name__ == "__main__":
    main()
