"""Production mesh construction.

A function (not a module-level constant) so importing this module never
touches jax device state. Processes that need many devices set
``XLA_FLAGS=--xla_force_host_platform_device_count=N`` before any jax
import — 512 for the dry-run sweep, the APU count for the multi-APU
scaling driver (``repro.launch.scaling``, see docs/SCALING.md); smoke
tests and in-process benchmarks see the real single device.

Mesh topology (TPU v5e pods):
  single-pod : (16, 16)      axes ("data", "model")   = 256 chips
  multi-pod  : (2, 16, 16)   axes ("pod", "data", "model") = 512 chips
The "pod" axis carries the slowest links (DCN/optical); FSDP/DP gradient
reduction over ("pod","data") is therefore hierarchical by construction.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = 1
    for s in shape:
        n *= s
    devices = jax.devices()
    if len(devices) < n:
        raise RuntimeError(
            f"need {n} devices for mesh {shape}, have {len(devices)}; "
            "the dry-run sets XLA_FLAGS=--xla_force_host_platform_device_count=512")
    return jax.make_mesh(shape, axes, devices=devices[:n])


def make_smoke_mesh(shape=(1, 1), axes=("data", "model")):
    """Small mesh over the first prod(shape) devices (default 1x1 over the
    single real device — sharding unit tests).  ``serve --mesh N`` builds
    an (N, 1) smoke mesh over the simulated APUs so the model's internal
    sharding constraints share a device assignment with the APU mesh."""
    n = 1
    for s in shape:
        n *= s
    devices = jax.devices()
    if len(devices) < n:
        raise RuntimeError(
            f"need {n} devices for smoke mesh {shape}, have {len(devices)}; "
            f"set XLA_FLAGS={apu_flags(n)} before importing jax")
    return jax.make_mesh(shape, axes, devices=devices[:n])


def apu_flags(n_apus: int) -> str:
    """The XLA flag that simulates an ``n_apus``-APU node on a CPU host.
    Must be in ``XLA_FLAGS`` *before* the first jax import (subprocess
    drivers like ``repro.launch.scaling`` set it; shells export it)."""
    return f"--xla_force_host_platform_device_count={n_apus}"


def make_apu_mesh(n_apus: int = 1, axis: str = "apu"):
    """1-D mesh of ``n_apus`` simulated APUs — the node topology of the
    multi-APU replay (``repro.core.shard_program``).  Each "APU" is one
    forced host-platform device; the Infinity Fabric between them is the
    inter-device transfer path XLA partitions collectives onto."""
    devices = jax.devices()
    if len(devices) < n_apus:
        raise RuntimeError(
            f"need {n_apus} devices for a {n_apus}-APU mesh, have "
            f"{len(devices)}; set XLA_FLAGS={apu_flags(n_apus)} before "
            "importing jax (see docs/SCALING.md)")
    return jax.make_mesh((n_apus,), (axis,), devices=devices[:n_apus])
