"""Production mesh construction.

A function (not a module-level constant) so importing this module never
touches jax device state. The dry-run process sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before any jax
import; smoke tests and benchmarks see the real single device.

Mesh topology (TPU v5e pods):
  single-pod : (16, 16)      axes ("data", "model")   = 256 chips
  multi-pod  : (2, 16, 16)   axes ("pod", "data", "model") = 512 chips
The "pod" axis carries the slowest links (DCN/optical); FSDP/DP gradient
reduction over ("pod","data") is therefore hierarchical by construction.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = 1
    for s in shape:
        n *= s
    devices = jax.devices()
    if len(devices) < n:
        raise RuntimeError(
            f"need {n} devices for mesh {shape}, have {len(devices)}; "
            "the dry-run sets XLA_FLAGS=--xla_force_host_platform_device_count=512")
    return jax.make_mesh(shape, axes, devices=devices[:n])


def make_smoke_mesh(shape=(1, 1), axes=("data", "model")):
    """1x1 mesh over the single real device — used by sharding unit tests."""
    return jax.make_mesh(shape, axes, devices=jax.devices()[:1])
