"""Production mesh construction.

A function (not a module-level constant) so importing this module never
touches jax device state. Processes that need many devices set
``XLA_FLAGS=--xla_force_host_platform_device_count=N`` before any jax
import — 512 for the dry-run sweep, the APU count for the multi-APU
scaling driver (``repro.launch.scaling``, see docs/SCALING.md); smoke
tests and in-process benchmarks see the real single device.

Mesh topology (TPU v5e pods):
  single-pod : (16, 16)      axes ("data", "model")   = 256 chips
  multi-pod  : (2, 16, 16)   axes ("pod", "data", "model") = 512 chips
The "pod" axis carries the slowest links (DCN/optical); FSDP/DP gradient
reduction over ("pod","data") is therefore hierarchical by construction.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = 1
    for s in shape:
        n *= s
    devices = jax.devices()
    if len(devices) < n:
        raise RuntimeError(
            f"need {n} devices for mesh {shape}, have {len(devices)}; "
            "the dry-run sets XLA_FLAGS=--xla_force_host_platform_device_count=512")
    return jax.make_mesh(shape, axes, devices=devices[:n])


def make_smoke_mesh(shape=(1, 1), axes=("data", "model")):
    """Small mesh over the first prod(shape) devices (default 1x1 over the
    single real device — sharding unit tests).  ``serve --mesh N`` builds
    an (N, 1) smoke mesh over the simulated APUs so the model's internal
    sharding constraints share a device assignment with the APU mesh."""
    n = 1
    for s in shape:
        n *= s
    devices = jax.devices()
    if len(devices) < n:
        raise RuntimeError(
            f"need {n} devices for smoke mesh {shape}, have {len(devices)}; "
            f"set XLA_FLAGS={apu_flags(n)} before importing jax")
    return jax.make_mesh(shape, axes, devices=devices[:n])


def apu_flags(n_apus: int) -> str:
    """The XLA flag that simulates an ``n_apus``-APU node on a CPU host.
    Must be in ``XLA_FLAGS`` *before* the first jax import (subprocess
    drivers like ``repro.launch.scaling`` set it; shells export it)."""
    return f"--xla_force_host_platform_device_count={n_apus}"


def near_square_mesh_shape(n: int) -> tuple:
    """Near-square 2-D factorization of an APU count: largest divisor
    ``d <= sqrt(n)`` gives ``(d, n // d)`` — 4 -> (2, 2), 8 -> (2, 4),
    6 -> (2, 3) — which cuts halo surface-to-volume versus a 1-D slab
    decomposition (docs/SCALING.md).  Primes (and 1) stay 1-D: ``(n,)``.
    Shared by ``fig_scaling`` and the policy autotuner's mesh-shape axis
    (``repro.tune``, docs/AUTOTUNE.md)."""
    n = int(n)
    if n < 1:
        raise ValueError(f"APU count must be >= 1, got {n}")
    best = 1
    for d in range(2, int(n ** 0.5) + 1):
        if n % d == 0:
            best = d
    return (best, n // best) if best > 1 else (n,)


def parse_mesh_shape(spec) -> tuple:
    """Parse a mesh-shape spec: ``4`` / ``"4"`` -> ``(4,)`` (1-D),
    ``"2x2"`` -> ``(2, 2)``, ``"2x2x2"`` -> ``(2, 2, 2)``.  The CLI
    surface of the 2-D/3-D domain decomposition (``launch.scaling
    --mesh``, ``FIG_SCALING_MESH``)."""
    if isinstance(spec, int):
        return (spec,)
    if isinstance(spec, (tuple, list)):
        return tuple(int(s) for s in spec)
    shape = tuple(int(s) for s in str(spec).lower().split("x") if s)
    if not shape or any(s < 1 for s in shape):
        raise ValueError(f"bad mesh shape {spec!r}: want e.g. '4' or '2x2'")
    return shape


def make_apu_mesh(n_apus=1, axis: str = "apu"):
    """Mesh of simulated APUs — the node topology of the multi-APU replay
    (``repro.core.shard_program``).  Each "APU" is one forced
    host-platform device; the Infinity Fabric between them is the
    inter-device transfer path XLA partitions collectives onto.

    ``n_apus`` is an APU count (1-D mesh, axis ``"apu"`` — the PR-3
    surface) or a mesh shape (``(2, 2)`` / ``"2x2"``): an N-D
    decomposition with axes ``("apu0", "apu1", ...)`` that cuts
    surface-to-volume (docs/SCALING.md)."""
    shape = parse_mesh_shape(n_apus)
    axes = (axis,) if len(shape) == 1 else tuple(
        f"{axis}{i}" for i in range(len(shape)))
    n = 1
    for s in shape:
        n *= s
    devices = jax.devices()
    if len(devices) < n:
        raise RuntimeError(
            f"need {n} devices for a {shape} APU mesh, have "
            f"{len(devices)}; set XLA_FLAGS={apu_flags(n)} before "
            "importing jax (see docs/SCALING.md)")
    return jax.make_mesh(shape, axes, devices=devices[:n])
