"""ExecutionPolicy construction for the LM drivers (train / serve).

One place where a CLI ``--policy`` choice plus the config's declarative
:class:`~repro.configs.base.MemoryPolicy` become a concrete
:class:`~repro.core.regions.ExecutionPolicy`:

* ``adaptive`` threads ``MemoryPolicy.target_cutoff`` into the
  :class:`~repro.core.regions.SizeRouter` — the paper's ``TARGET_CUT_OFF``
  as a config value, not a magic number in driver code;
* every mode gets a ``min_bytes``-gated Placer so placement hints (the
  optimizer-offload hint on ``ADAMW_UPDATE``, serve's role-keyed KV
  placer) never bounce scalars across memory spaces;
* callers may swap in a custom ``placer`` (serve's ``--offload-kv``) or
  ``selector`` (variant dispatch) — the two axes the drivers expose;
* ``auto`` (:func:`auto_policy`) loads the nearest-bucket winner from
  the tuned warm-start profile (``repro.tune``, docs/AUTOTUNE.md) and
  falls back to the hand-assembled ``lm_policy`` when no profile
  matches.
"""
from __future__ import annotations

import os
from typing import Optional

from repro.configs.base import MemoryPolicy
from repro.core.regions import ComposedPolicy, Placer, make_policy

#: placement hints skip leaves below this (moving a scalar across spaces
#: costs more than it saves — paper C4's threshold idea applied to C1)
PLACER_MIN_BYTES = 4096

#: the CLI surface the drivers expose ("auto" = tuned-profile lookup)
POLICY_CHOICES = ("unified", "discrete", "host", "adaptive", "auto")


def lm_policy(mode: str, memory: Optional[MemoryPolicy] = None, *,
              placer: Optional[Placer] = None,
              selector=None) -> ComposedPolicy:
    """Build the ExecutionPolicy one LM driver run executes under."""
    kw = {"placer": placer or Placer(min_bytes=PLACER_MIN_BYTES)}
    if selector is not None:
        kw["selector"] = selector
    if mode == "adaptive" and memory is not None:
        kw["cutoff"] = memory.target_cutoff
    return make_policy(mode, **kw)


def auto_policy(workload: str, size: int,
                memory: Optional[MemoryPolicy] = None, *,
                profile_path: Optional[str] = None,
                placer: Optional[Placer] = None,
                selector=None, fallback: str = "unified",
                quiet: bool = False) -> ComposedPolicy:
    """``--policy auto``: the tuned profile's nearest-bucket winner for
    ``(workload, size)`` as a runnable ExecutionPolicy.

    ``workload`` names a tuned cell family (``serve_decode`` /
    ``train_step`` / ``cfd_step`` / ``cfd_sharded`` — docs/AUTOTUNE.md)
    and ``size`` is that workload's shape measure
    (``repro.tune.space.serve_size`` etc.), bucketed with the shared
    power-of-2 scheme.  The profile path resolves ``profile_path`` ->
    ``$REPRO_TUNE_PROFILE`` -> ``artifacts/tune/policy_profile.json``.
    No profile, or no entry for the workload -> ``lm_policy(fallback)``,
    so ``auto`` is always safe to pass.  The returned policy carries
    ``tuned_entry`` (the ProfileEntry, or None on fallback) so drivers
    can report what they loaded."""
    from repro.tune.profile import DEFAULT_PROFILE_PATH, PolicyProfile
    path = profile_path or os.environ.get("REPRO_TUNE_PROFILE",
                                          DEFAULT_PROFILE_PATH)
    prof = PolicyProfile.load_if_exists(path)
    entry = prof.lookup(workload, size) if prof is not None else None
    if entry is None:
        pol = lm_policy(fallback, memory, placer=placer, selector=selector)
        pol.tuned_entry = None
        if not quiet:
            print(f"[auto] no tuned entry for {workload!r} in {path}; "
                  f"falling back to lm_policy({fallback!r})")
        return pol
    pol = entry.candidate.build_policy(
        memory, winners=entry.variant_winners,
        placer=placer or Placer(min_bytes=PLACER_MIN_BYTES))
    if selector is not None:                  # explicit driver axis wins
        pol.selector = selector
    pol.tuned_entry = entry
    if not quiet:
        print(f"[auto] {workload}: loaded {entry.candidate.label} "
              f"(cell {entry.key}, profile {path})")
    return pol
