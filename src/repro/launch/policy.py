"""ExecutionPolicy construction for the LM drivers (train / serve).

One place where a CLI ``--policy`` choice plus the config's declarative
:class:`~repro.configs.base.MemoryPolicy` become a concrete
:class:`~repro.core.regions.ExecutionPolicy`:

* ``adaptive`` threads ``MemoryPolicy.target_cutoff`` into the
  :class:`~repro.core.regions.SizeRouter` — the paper's ``TARGET_CUT_OFF``
  as a config value, not a magic number in driver code;
* every mode gets a ``min_bytes``-gated Placer so placement hints (the
  optimizer-offload hint on ``ADAMW_UPDATE``, serve's role-keyed KV
  placer) never bounce scalars across memory spaces;
* callers may swap in a custom ``placer`` (serve's ``--offload-kv``) or
  ``selector`` (variant dispatch) — the two axes the drivers expose.
"""
from __future__ import annotations

from typing import Optional

from repro.configs.base import MemoryPolicy
from repro.core.regions import ComposedPolicy, Placer, make_policy

#: placement hints skip leaves below this (moving a scalar across spaces
#: costs more than it saves — paper C4's threshold idea applied to C1)
PLACER_MIN_BYTES = 4096

#: the CLI surface both drivers expose
POLICY_CHOICES = ("unified", "discrete", "host", "adaptive")


def lm_policy(mode: str, memory: Optional[MemoryPolicy] = None, *,
              placer: Optional[Placer] = None,
              selector=None) -> ComposedPolicy:
    """Build the ExecutionPolicy one LM driver run executes under."""
    kw = {"placer": placer or Placer(min_bytes=PLACER_MIN_BYTES)}
    if selector is not None:
        kw["selector"] = selector
    if mode == "adaptive" and memory is not None:
        kw["cutoff"] = memory.target_cutoff
    return make_policy(mode, **kw)
