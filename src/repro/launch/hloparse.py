"""Structural HLO cost extraction with while-loop trip-count scaling.

XLA's ``compiled.cost_analysis()`` counts while-loop (lax.scan) bodies ONCE
— useless for scan-over-layers models. This module parses the partitioned
post-optimization HLO text and computes, with each computation weighted by
the product of enclosing loop trip counts:

  * flops            — exact 2*M*N*K for every ``dot`` (from result shape x
                       lhs contracting dims), + 1 flop/element for other
                       arithmetic ops (elementwise tail),
  * hbm_bytes        — sum of operand+result buffer sizes of *executed*
                       top-level instructions (fusion bodies excluded:
                       internal values never hit HBM; control-flow bodies
                       included with their multiplier),
  * collectives      — operand/result bytes and instruction counts per
                       collective opcode, with replica-group sizes (to tell
                       'model'-axis ICI traffic from 'pod'-axis DCN).

Validated against unrolled-vs-scanned reference programs in
``tests/test_hloparse.py``.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
    "f64": 8, "c64": 8, "c128": 16, "token": 0,
    "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3b11fnuz": 1,
}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")

COLLECTIVE_OPS = (
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute", "ragged-all-to-all",
)

# ops that don't move/compute data (excluded from byte accounting)
_NOBYTE_OPS = {
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
    "after-all", "partition-id", "replica-id", "iota",
}
# ops executed via their called computations, not directly
_CONTROL_OPS = {"while", "conditional", "call"}

# arithmetic opcodes that count ~1 flop per output element
_ARITH_OPS = {
    "add", "subtract", "multiply", "divide", "maximum", "minimum", "power",
    "exponential", "log", "tanh", "rsqrt", "sqrt", "negate", "abs",
    "exponential-minus-one", "logistic", "cosine", "sine", "select",
    "compare", "and", "or", "xor", "clamp", "floor", "ceil",
    "round-nearest-afz", "remainder", "sign",
}


def shape_elems_bytes(type_str: str) -> Tuple[int, int]:
    elems_total, bytes_total = 0, 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        b = _DTYPE_BYTES.get(dt)
        if b is None:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                if d:
                    n *= int(d)
        elems_total += n
        bytes_total += n * b
    return elems_total, bytes_total


@dataclasses.dataclass
class Instr:
    name: str
    type_str: str
    opcode: str
    operands: List[str]
    attrs: str
    is_root: bool = False


_COMP_START = re.compile(r"^(?:ENTRY\s+)?%([\w.\-]+)\s*\(.*\)\s*->\s*.*\{$")
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*((?:\([^()]*\)|[a-z0-9]+\[[^\]]*\])"
    r"(?:\{[^}]*\})?)\s+([a-z][\w\-]*)\((.*)$")


def _split_operands(argstr: str) -> List[str]:
    """Names referenced before the closing paren of the operand list."""
    depth = 1
    bracket = 0     # [] / {} nesting: operand type annotations carry shapes
    out = []        # and layouts ("f32[256,256]{1,0} %x") whose commas must
    cur = []        # not split the operand list
    for ch in argstr:
        if ch == "(":
            depth += 1
        elif ch == ")":
            depth -= 1
            if depth == 0:
                break
        elif ch in "[{":
            bracket += 1
        elif ch in "]}":
            bracket -= 1
        if ch == "," and depth == 1 and bracket == 0:
            out.append("".join(cur))
            cur = []
        else:
            cur.append(ch)
    if cur:
        out.append("".join(cur))
    names = []
    for tok in out:
        m = re.search(r"%?([\w.\-]+)\s*$", tok.strip())
        if m:
            names.append(m.group(1))
    return names


def parse_module(hlo: str) -> Dict[str, List[Instr]]:
    comps: Dict[str, List[Instr]] = {}
    cur: Optional[str] = None
    for line in hlo.splitlines():
        stripped = line.strip()
        if cur is None:
            m = _COMP_START.match(stripped)
            if m and stripped.endswith("{"):
                cur = m.group(1)
                comps[cur] = []
            continue
        if stripped.startswith("}"):
            cur = None
            continue
        m = _INSTR_RE.match(line)
        if m:
            name, ty, opcode, rest = m.groups()
            attrs = rest[rest.find(")") + 1:] if ")" in rest else ""
            comps[cur].append(Instr(name, ty, opcode, _split_operands(rest),
                                    attrs, "ROOT " in line[:len(line) - len(line.lstrip()) + 8]))
    return comps


def _entry_name(hlo: str, comps) -> str:
    m = re.search(r"^ENTRY\s+%?([\w.\-]+)", hlo, re.M)
    if m and m.group(1) in comps:
        return m.group(1)
    # fallback: computation not called by anyone
    called = set()
    for instrs in comps.values():
        for i in instrs:
            called.update(re.findall(r"(?:calls|body|condition|to_apply)=%?([\w.\-]+)", i.attrs))
    for name in comps:
        if name not in called:
            return name
    return next(iter(comps))


def _trip_count(cond_instrs: List[Instr], body_instrs: List[Instr]) -> int:
    """lax.scan lowers to a while whose cond is compare(iv, constant, LT)."""
    consts = {}
    for i in cond_instrs:
        if i.opcode == "constant" and i.operands and \
                re.fullmatch(r"-?\d+", i.operands[0] or ""):
            consts[i.name] = int(i.operands[0])
    for i in cond_instrs:
        if i.opcode == "compare" and "direction=LT" in i.attrs:
            for op in i.operands:
                if op in consts and consts[op] > 0:
                    return consts[op]
    return 1


@dataclasses.dataclass
class Costs:
    flops: float = 0.0
    hbm_bytes: float = 0.0
    collectives: dict = dataclasses.field(default_factory=dict)

    def coll(self, op):
        return self.collectives.setdefault(
            op, {"count": 0, "operand_bytes": 0.0, "result_bytes": 0.0,
                 "group_sizes": {}})


def _dot_flops(instr: Instr, types: Dict[str, str]) -> float:
    out_elems, _ = shape_elems_bytes(instr.type_str)
    lhs_ty = types.get(instr.operands[0], "") if instr.operands else ""
    m = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", instr.attrs)
    if not m or not lhs_ty:
        return 2.0 * out_elems
    dims_m = _SHAPE_RE.search(lhs_ty)
    if not dims_m:
        return 2.0 * out_elems
    lhs_dims = [int(d) for d in dims_m.group(2).split(",") if d]
    k = 1
    for ci in m.group(1).split(","):
        if ci and int(ci) < len(lhs_dims):
            k *= lhs_dims[int(ci)]
    return 2.0 * out_elems * k


def _fusion_hbm_bytes(instr: Instr, types: Dict[str, str],
                      comps: Dict[str, List[Instr]]) -> float:
    """HBM traffic of one fusion: operands + result, with two corrections:
    (i) an operand consumed ONLY via dynamic-slice inside the fusion is
    charged the slice size, not the full buffer (scan-over-stacked-weights);
    (ii) a root dynamic-update-slice is charged the update size (in-place
    aliasing), not the full carry buffer."""
    callee = None
    m = re.search(r"calls=%?([\w.\-]+)", instr.attrs)
    if m and m.group(1) in comps:
        callee = comps[m.group(1)]
    total = 0.0
    if callee is None:
        ob = sum(shape_elems_bytes(types.get(o, ""))[1]
                 for o in instr.operands if o in types)
        return ob + shape_elems_bytes(instr.type_str)[1]
    inner_types = {i.name: i.type_str for i in callee}
    param_of_idx = {}
    users: Dict[str, List[Instr]] = {}
    root = None
    for i in callee:
        if i.is_root:
            root = i
        if i.opcode == "parameter" and i.operands and \
                re.fullmatch(r"\d+", i.operands[0] or ""):
            param_of_idx[int(i.operands[0])] = i.name
        for o in i.operands:
            users.setdefault(o, []).append(i)
    if root is None and callee:
        root = callee[-1]
    # --- reads: per fused param, charge what is actually touched ---
    for idx, oname in enumerate(instr.operands):
        if oname not in types:
            continue
        full = shape_elems_bytes(types[oname])[1]
        pname = param_of_idx.get(idx)
        u = users.get(pname, []) if pname else []
        if u:
            charge, fallback = 0, False
            for x in u:
                if x.opcode in ("dynamic-slice", "slice"):
                    charge += shape_elems_bytes(x.type_str)[1]
                elif x.opcode == "dynamic-update-slice" and x.operands and \
                        x.operands[0] == pname:
                    pass          # in-place target: no read of untouched rest
                else:
                    fallback = True
                    break
            full = full if fallback else charge
        total += full
    # --- writes: root DUS (or tuple of DUSes) writes only the update slice ---
    def write_bytes(r: Optional[Instr]) -> float:
        if r is None:
            return shape_elems_bytes(instr.type_str)[1]
        if r.opcode == "dynamic-update-slice" and len(r.operands) >= 2 and \
                r.operands[1] in inner_types:
            return shape_elems_bytes(inner_types[r.operands[1]])[1]
        if r.opcode == "tuple":
            by_name = {i.name: i for i in callee}
            s = 0.0
            for o in r.operands:
                ri = by_name.get(o)
                if ri is not None and ri.opcode == "dynamic-update-slice" and \
                        len(ri.operands) >= 2 and ri.operands[1] in inner_types:
                    s += shape_elems_bytes(inner_types[ri.operands[1]])[1]
                elif o in inner_types:
                    s += shape_elems_bytes(inner_types[o])[1]
            return s
        return shape_elems_bytes(instr.type_str)[1]

    total += write_bytes(root)
    return total


def analyze(hlo: str) -> Costs:
    comps = parse_module(hlo)
    entry = _entry_name(hlo, comps)
    costs = Costs()
    visited_stack = []

    def walk(comp: str, mult: float, in_fusion: bool):
        if comp in visited_stack or comp not in comps:
            return
        visited_stack.append(comp)
        instrs = comps[comp]
        types = {i.name: i.type_str for i in instrs}
        for i in instrs:
            op = i.opcode
            base = op.replace("-start", "").replace("-done", "")
            # --- flops (counted inside fusions too) ---
            if op in ("dot", "dot-general"):
                costs.flops += mult * _dot_flops(i, types)
            elif op in _ARITH_OPS:
                e, _ = shape_elems_bytes(i.type_str)
                costs.flops += mult * e
            # --- collectives ---
            if base in COLLECTIVE_OPS and not op.endswith("-done"):
                rec = costs.coll(base)
                rec["count"] += mult
                _, rb = shape_elems_bytes(i.type_str)
                ob = sum(shape_elems_bytes(types.get(o, ""))[1]
                         for o in i.operands if o in types)
                rec["result_bytes"] += mult * rb
                rec["operand_bytes"] += mult * (ob if ob else rb)
                g = re.search(r"replica_groups=\{\{([0-9, ]*)\}", i.attrs)
                if not g:
                    g = re.search(r"replica_groups=\[(\d+),(\d+)\]", i.attrs)
                    size = int(g.group(2)) if g else 0
                else:
                    size = len(g.group(1).split(","))
                rec["group_sizes"][str(size)] = rec["group_sizes"].get(str(size), 0) + mult
            # --- bytes: executed instructions only, not inside fusions ---
            if not in_fusion and op not in _NOBYTE_OPS and op not in _CONTROL_OPS:
                if op == "fusion":
                    costs.hbm_bytes += mult * _fusion_hbm_bytes(i, types, comps)
                elif op in ("dynamic-slice", "slice", "gather"):
                    costs.hbm_bytes += mult * 2 * shape_elems_bytes(i.type_str)[1]
                elif op == "dynamic-update-slice" and len(i.operands) >= 2 \
                        and i.operands[1] in types:
                    costs.hbm_bytes += mult * 2 * shape_elems_bytes(
                        types[i.operands[1]])[1]
                else:
                    _, rb = shape_elems_bytes(i.type_str)
                    ob = sum(shape_elems_bytes(types.get(o, ""))[1]
                             for o in i.operands if o in types)
                    costs.hbm_bytes += mult * (rb + ob)
            # --- recursion ---
            if op == "while":
                body = re.search(r"body=%?([\w.\-]+)", i.attrs)
                cond = re.search(r"condition=%?([\w.\-]+)", i.attrs)
                # preferred: XLA records the static trip count directly
                tc = re.search(r'known_trip_count.{0,4}"n":"(\d+)"', i.attrs)
                if tc:
                    trips = int(tc.group(1))
                elif cond and cond.group(1) in comps:
                    cond_instrs = list(comps[cond.group(1)])
                    for ci in comps[cond.group(1)]:
                        for c in re.findall(r"calls=%?([\w.\-]+)", ci.attrs):
                            cond_instrs.extend(comps.get(c, []))
                    trips = _trip_count(cond_instrs, [])
                else:
                    trips = 1
                if body:
                    walk(body.group(1), mult * trips, in_fusion)
            elif op == "fusion":
                for c in re.findall(r"calls=%?([\w.\-]+)", i.attrs):
                    walk(c, mult, True)
            elif op in ("call", "custom-call", "conditional", "reduce", "sort",
                        "scatter", "select-and-scatter", "map"):
                for c in re.findall(r"(?:calls|to_apply)=%?([\w.\-]+)", i.attrs):
                    walk(c, mult, True)   # applied per-element; treat as fused
                if op == "conditional":
                    for c in re.findall(r"branch_computations=\{([^}]*)\}", i.attrs):
                        for b in re.findall(r"%?([\w.\-]+)", c):
                            walk(b, mult, in_fusion)
        visited_stack.pop()

    walk(entry, 1.0, False)
    return costs
