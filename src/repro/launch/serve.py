"""Batched serving driver — prefill + decode on the region-program spine.

Serving is where the unified-memory policy earns its keep (paper C1/C4).
The request path is three directive-sized regions — ``PREFILL``,
``DECODE_STEP``, ``KV_APPEND`` — captured as two RegionPrograms (one
prefill call; one greedy decode loop, one ``DECODE_STEP`` + ``KV_APPEND``
pair per generated token) and replayed through an ``Executor`` under any
``--policy``; ``--replay-batch N`` pushes N independent request groups
through the decode program as ONE vmapped composite
(``RegionProgram.replay_batch``, the heavy-traffic path).

``--offload-kv`` is *just a policy choice*: :func:`offload_kv_cache`
builds a role-keyed :class:`KVCachePlacer` — only the actual ``k``/``v``
cache pages (megabytes at serving scale) above ``min_bytes`` move to host
DRAM; slot/position bookkeeping is decode-hot and stays deviceside no
matter how large.  The decode math never changes, only the placement axis.

The pre-capture jit path (:func:`build_server` + :func:`decode_stream`)
remains as the streaming reference: the decode loop syncs once per
``--sync-every`` tokens (0 = end of stream) instead of per token — a
per-token ``block_until_ready`` serializes the stream, and ``fig_serve``
(benchmarks/run.py) records the reclaimed latency.  Under
``UnifiedPolicy`` the captured-program tokens are asserted bit-identical
to this jit path on every run.

  PYTHONPATH=src python -m repro.launch.serve --arch gemma3-1b --reduced \
      --batch 4 --prompt-len 32 --gen 32 --policy unified --report
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import time
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.reduced import reduced as make_reduced
from repro.configs.registry import get_config
from repro.core.ledger import Ledger
from repro.core.program import AsyncExecutor, capture
from repro.core.regions import Executor, Placer, UnifiedPolicy, region
from repro.core.umem import MemSpace, preferred_host_space, tree_place
from repro.launch import sharding as SH
from repro.launch.mesh import make_smoke_mesh
from repro.launch.policy import PLACER_MIN_BYTES, POLICY_CHOICES, lm_policy
from repro.models import transformer as T
from repro.train import step as S


# placement is keyed on tensor ROLE, not just size: only the actual k/v
# pages (batch*heads*len*head_dim — megabytes at serving scale) go to host
# DRAM; slot/position bookkeeping is decode-hot and stays deviceside no
# matter how large. min_bytes additionally keeps smoke-scale k/v pages,
# where the crossing costs more than it saves, where they are.
KV_PLACE_KEYS = ("k", "v")
KV_PLACE_MIN_BYTES = 32768


def place_kv_leaves(tree, space: MemSpace, min_bytes=KV_PLACE_MIN_BYTES):
    """Role-keyed placement: move only ``k``/``v``-named leaves above
    ``min_bytes`` to ``space``; every other leaf stays put."""
    def per_leaf(path, x):
        keys = {getattr(p, "key", None) for p in path}
        if keys & set(KV_PLACE_KEYS):
            return tree_place(x, space, min_bytes=min_bytes)
        return x
    return jax.tree_util.tree_map_with_path(per_leaf, tree)


@dataclasses.dataclass
class KVCachePlacer(Placer):
    """KV-cache offload as a *placement axis* (:class:`Placer` subclass).

    On top of the base hint behavior, every region's arguments and results
    get the role-keyed treatment of :func:`place_kv_leaves`: ``k``/``v``
    cache pages above ``kv_min_bytes`` are re-homed to ``kv_space`` each
    time they cross a region boundary — the ``KV_APPEND`` commit point in
    the decode program re-places the token's freshly appended pages.  With
    ``kv_space=None`` this is exactly the base :class:`Placer`.
    """
    kv_space: Optional[MemSpace] = None
    kv_min_bytes: int = KV_PLACE_MIN_BYTES

    def place_args(self, target_region, args, kwargs):
        args, kwargs = super().place_args(target_region, args, kwargs)
        if self.kv_space is None:
            return args, kwargs
        return place_kv_leaves((args, kwargs), self.kv_space,
                               self.kv_min_bytes)

    def place_result(self, target_region, out):
        out = super().place_result(target_region, out)
        if self.kv_space is None:
            return out
        return place_kv_leaves(out, self.kv_space, self.kv_min_bytes)


def offload_kv_cache(space: Optional[MemSpace] = None,
                     min_bytes: int = KV_PLACE_MIN_BYTES) -> KVCachePlacer:
    """The ``--offload-kv`` Placer: role-keyed KV offload to host DRAM
    (``preferred_host_space()`` unless ``space`` names one explicitly)."""
    return KVCachePlacer(min_bytes=PLACER_MIN_BYTES,
                         kv_space=space or preferred_host_space(),
                         kv_min_bytes=min_bytes)


# ---------------------------------------------------------------------------
# Serving regions + captured programs
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class ServeRegions:
    """The request path as directive-sized regions (params closed over)."""
    prefill: Any        # (batch, cache)    -> (tok, cache)
    decode_step: Any    # (tok, cache, pos) -> (tok, cache)
    kv_append: Any      # (cache,)          -> cache


def make_serve_regions(cfg, mesh, params, *, ledger: Optional[Ledger] = None,
                       q_chunk: int = 256) -> ServeRegions:
    """``PREFILL`` / ``DECODE_STEP`` / ``KV_APPEND`` on one ledger.

    ``params`` are closed over (constants), which is exactly what
    ``replay_batch`` wants: under ``vmap`` they broadcast across the N
    stacked requests while tokens and caches batch.  ``KV_APPEND`` is the
    cache *commit* directive: the model's fused insert runs inside
    ``DECODE_STEP`` (attention appends as it attends), and this
    math-identity region is where the policy's placement axis re-homes the
    appended pages (role-keyed ``--offload-kv``) and the ledger accounts
    the per-token cache commit.  ``offloaded=False``: commitment is
    bookkeeping, not a staged offload — no policy stages the whole cache
    twice per token.
    """
    rules = SH.ShardingRules("serve")
    shd = SH.make_sharder(mesh, rules)
    raw_prefill = S.make_prefill_step(
        cfg, lambda: T.Ctx(mode="prefill", shd=shd, q_chunk=q_chunk,
                           remat=False))
    raw_decode = S.make_decode_step(
        cfg, lambda: T.Ctx(mode="decode", shd=shd, remat=False))

    @region("PREFILL", ledger=ledger)
    def prefill_region(batch, cache):
        logits, cache = raw_prefill(params, batch, cache)
        return jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32), cache

    @region("DECODE_STEP", ledger=ledger)
    def decode_region(tok, cache, pos):
        logits, cache = raw_decode(params, tok, cache, pos)
        return jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32), cache

    # donate_args: the cache fed to KV_APPEND is always the PREVIOUS
    # region's output (never a program input), and this commit is its last
    # consumer — XLA aliases the buffers through, so the pass-through costs
    # O(1), not an O(cache-bytes) copy per token
    @region("KV_APPEND", ledger=ledger, offloaded=False, donate_args=(0,))
    def kv_append(cache):
        return cache

    return ServeRegions(prefill=prefill_region, decode_step=decode_region,
                        kv_append=kv_append)


def capture_prefill_program(regions: ServeRegions, example_batch,
                            example_cache, name: str = "prefill_program"):
    """Prefill as a RegionProgram: one ``PREFILL`` call, then the
    ``KV_APPEND`` commit of the prompt's cache pages."""
    def prefill_fn(run, batch, cache):
        tok, cache = run(regions.prefill, batch, cache)
        cache = run(regions.kv_append, cache)
        return tok, cache

    return capture(prefill_fn, example_batch, example_cache, name=name)


def capture_decode_program(regions: ServeRegions, prompt_len: int, gen: int,
                           example_tok, example_cache,
                           name: str = "decode_program"):
    """The greedy decode loop as one RegionProgram.

    Each generated token is one ``DECODE_STEP`` (decode + argmax) whose KV
    cache flows into a ``KV_APPEND`` commit and on to the next token, so
    the captured trace carries the full request dataflow; positions are
    frozen constants (CUDA-graph style).
    """
    def gen_loop(run, tok, cache):
        toks = [tok]
        for i in range(gen - 1):
            tok, cache = run(regions.decode_step, tok, cache,
                             jnp.int32(prompt_len + i))
            cache = run(regions.kv_append, cache)
            toks.append(tok)
        return tuple(toks)      # tuple of refs (stacking outside a region
        #                         would freeze the result as a constant)

    return capture(gen_loop, example_tok, example_cache, name=name)


# ---------------------------------------------------------------------------
# Pre-capture jit path (streaming reference)
# ---------------------------------------------------------------------------

def build_server(cfg, mesh, batch: int, max_len: int, q_chunk=256,
                 offload_kv=False):
    rules = SH.ShardingRules("serve")
    shd = SH.make_sharder(mesh, rules)
    prefill = jax.jit(S.make_prefill_step(
        cfg, lambda: T.Ctx(mode="prefill", shd=shd, q_chunk=q_chunk,
                           remat=False)))
    decode = jax.jit(S.make_decode_step(
        cfg, lambda: T.Ctx(mode="decode", shd=shd, remat=False)),
        donate_argnums=(2,))

    # KV placement is a MemSpace hint, not a hand-rolled sharding: pages big
    # enough to matter go to host DRAM, small tensors stay put (paper C1/C4)
    kv_space = preferred_host_space() if offload_kv else None

    def make_cache():
        cache = T.init_cache(cfg, batch, max_len)
        if kv_space is not None:
            cache = place_kv_leaves(cache, kv_space)
        return cache

    return prefill, decode, make_cache


def decode_stream(decode, params, tok, cache, prompt_len: int, gen: int,
                  sync_every: int = 0):
    """Greedy decode on the raw jit path with interval syncing.

    ``sync_every <= 0`` means *never* sync mid-stream: the whole stream
    dispatches asynchronously and blocks exactly once on the final token —
    the maximally-overlapped default.  (Before this was pinned down, a
    negative value fell through the modulo and silently behaved like the
    per-token sync.)  ``sync_every = 1`` is that retired per-token
    ``jax.block_until_ready`` — dispatch of token *i+1* cannot start until
    *i* has fully materialized; larger intervals reclaim the latency one
    report interval at a time (measured by ``fig_serve``)."""
    toks = [tok]
    for i in range(gen - 1):
        logits, cache = decode(params, tok, cache, jnp.int32(prompt_len + i))
        tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
        toks.append(tok)
        if sync_every > 0 and (i + 1) % sync_every == 0:
            jax.block_until_ready(tok)
    jax.block_until_ready(toks[-1])
    return toks, cache


# ---------------------------------------------------------------------------
# Heavy traffic: replay_batch over N request groups
# ---------------------------------------------------------------------------

def replay_batch_demo(cfg, ex, decode_prog, prefill, make_cache,
                      params, args, n_requests: int, apu_mesh_size: int = 0):
    """The "heavy traffic" path: push N independent request groups through
    the captured decode program as ONE vmapped composite
    (``RegionProgram.replay_batch``).

    ``apu_mesh_size`` > 0 additionally scatters the stacked request groups
    across a 1-D mesh of simulated APUs (``repro.core.shard_program``):
    each APU decodes its slice of the requests through the same compiled
    composite, with per-device ledgers aggregated in the printed report.
    Needs ``XLA_FLAGS=--xla_force_host_platform_device_count=N`` exported
    before launch (see docs/SCALING.md)."""
    key0 = jax.random.PRNGKey(args.seed)
    toks, caches = [], []
    for r in range(n_requests):
        key = jax.random.fold_in(key0, r)
        prompts = jax.random.randint(key, (args.batch, args.prompt_len), 0,
                                     cfg.vocab, jnp.int32)
        batch = _prefill_inputs(cfg, args, prompts)
        logits, cache = prefill(params, batch, make_cache())
        toks.append(jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32))
        caches.append(cache)

    stacked_tok = jnp.stack(toks)
    stacked_cache = jax.tree.map(lambda *xs: jnp.stack(xs), *caches)
    sharded = None
    if apu_mesh_size:
        from repro.core.shard_program import shard_program
        from repro.launch.mesh import make_apu_mesh
        if n_requests % apu_mesh_size:
            raise SystemExit(f"--replay-batch {n_requests} does not divide "
                             f"over --mesh {apu_mesh_size} APUs")
        sharded = shard_program(decode_prog, make_apu_mesh(apu_mesh_size),
                                UnifiedPolicy(), shard_dim=0)
    t0 = time.time()
    if sharded is not None:
        out = sharded.replay_batch(stacked_tok, stacked_cache)
    else:
        out = decode_prog.replay_batch(stacked_tok, stacked_cache,
                                       executor=ex)
    dt = time.time() - t0
    seqs = np.asarray(jnp.stack(out, axis=-1))        # (N, B, gen)
    assert np.isfinite(seqs).all()
    # request 0 replayed alone through the same program (vmap-free):
    # agreement can drop below 1.0 only via argmax ties under batched matmul
    solo = np.asarray(jnp.stack(decode_prog.replay(ex, toks[0], caches[0]),
                                axis=-1))
    agree = float((seqs[0] == solo).mean())
    total = n_requests * args.batch * args.gen
    shard_note = ""
    if sharded is not None:
        rep = sharded.coverage_report()
        # NB: no exchange figure here — the batched path scatters whole
        # independent requests, so there is no halo traffic to model
        shard_note = (f"; sharded over {rep['devices']} APUs "
                      f"({n_requests // rep['devices']} request groups "
                      f"each)")
    print(f"[serve] replay_batch: {n_requests} request groups x "
          f"{args.batch}x{args.gen} tokens = {total} tokens in "
          f"{dt*1e3:.1f} ms ({total/max(dt,1e-9):.0f} tok/s); "
          f"solo-replay agreement {agree:.3f}{shard_note}")
    return seqs


def _prefill_inputs(cfg, args, prompts):
    batch = {"tokens": prompts}
    if cfg.mrope_sections is not None:
        pos = jnp.arange(args.prompt_len, dtype=jnp.int32)[None, :, None]
        batch["positions3"] = jnp.broadcast_to(
            pos, (args.batch, args.prompt_len, 3))
    if cfg.n_enc_layers:
        batch["enc_embeds"] = jnp.zeros(
            (args.batch, cfg.enc_len, cfg.d_model), cfg.compute_dtype)
    return batch


def _verify_programs(ex, *progs):
    """``--verify``: statically lint freshly captured programs under the
    serving executor's policy (repro.analysis) before any replay; findings
    print, error severity aborts startup (docs/ANALYSIS.md)."""
    for prog in progs:
        rep = prog.verify(ex.policy, ledger=ex.ledger)
        print(f"[verify] {rep.summary()}")
        for d in rep.findings:
            print(f"    {d}")
        if rep.errors:
            raise SystemExit(f"[verify] {prog.name!r} has error-severity "
                             "findings; refusing to serve")


def _engine_demo(cfg, mesh, params, ex, args, max_len):
    """Continuous-batching engine under the launcher flags: seeded Poisson
    traffic with ragged prompt/gen lengths through
    :class:`repro.serve.ServeEngine`, bit-parity asserted against solo jit
    decodes of the same prompts (docs/SERVING.md)."""
    # lazy import: repro.serve runs ON this module's regions and programs
    from repro.serve import (PagedKVCache, ServeEngine, make_traffic,
                             run_traffic, solo_reference)
    from repro.serve.traffic import assert_parity

    budget = None
    if args.kv_oversub_ratio > 0:
        # oversubscription mode: derive the logical device budget from the
        # measured footprint of one parked full-length entry x slots, so
        # --kv-oversub-ratio 2 means "the KV working set is 2x device
        # capacity" regardless of model size (see docs/EXPERIMENTS.md)
        from repro.core.oversub import MemoryBudget
        probe = PagedKVCache(page_tokens=args.page_tokens)
        probe.commit(0, T.init_cache(cfg, 1, max_len), true_len=max_len)
        footprint = probe.total_bytes * args.slots
        probe.free(0)
        budget = MemoryBudget.for_ratio(footprint, args.kv_oversub_ratio,
                                        name="kv")
    kv = PagedKVCache(page_tokens=args.page_tokens,
                      device_budget_bytes=args.kv_device_budget or None,
                      total_budget_bytes=args.kv_total_budget or None,
                      budget=budget)
    engine = ServeEngine(cfg, mesh, params, ex, max_len=max_len,
                         n_slots=args.slots, kv=kv)
    if args.verify:
        _verify_programs(ex, engine.tick_prog)
    lens = sorted({max(2, args.prompt_len // 2), args.prompt_len})
    gens = sorted({1, max(2, args.gen // 2), args.gen})
    reqs = make_traffic(args.seed, args.requests, cfg.vocab,
                        arrival_rate=args.rate, prompt_lens=lens,
                        gen_lens=gens)
    metrics = run_traffic(engine, reqs)
    oracle, solo_wall = solo_reference(cfg, mesh, params, reqs, max_len,
                                       offload_kv=args.offload_kv)
    assert_parity(reqs, oracle)        # the acceptance invariant
    solo_tps = metrics["tokens"] / max(solo_wall, 1e-9)
    st = kv.stats
    spill_note = (f"; {st.pages_spilled} pages spilled to host"
                  f" ({st.pages_fetched} fetched back)"
                  if st.pages_spilled else "")
    evict_note = f"; {st.evictions} evictions" if st.evictions else ""
    if budget is not None:
        evict_note += (f"; oversub x{args.kv_oversub_ratio:g} budget "
                       f"{budget.limit_bytes} B (high-water "
                       f"{budget.stats.high_water_bytes} B, "
                       f"{budget.stats.pressure_events} pressure events)")
    print(f"[serve] engine {args.arch}"
          f"{' (reduced)' if args.reduced else ''} [{ex.mode}]: "
          f"{metrics['requests']} requests / {metrics['tokens']} tokens in "
          f"{metrics['wall_s']*1e3:.1f} ms — {metrics['tokens_per_s']:.0f} "
          f"tok/s engine vs {solo_tps:.0f} tok/s sequential solo jit; "
          f"p50 {metrics.get('p50_token_ms', 0.0):.2f} / p99 "
          f"{metrics.get('p99_token_ms', 0.0):.2f} ms/token; KV page "
          f"high-water {st.device_high_water_bytes} B device"
          f"{spill_note}{evict_note}; parity OK vs solo jit")
    if args.report:
        print(json.dumps(ex.report(), indent=1, default=str))
    return metrics


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma3-1b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--offload-kv", action="store_true",
                    help="role-keyed KV offload to host DRAM — a Placer "
                         "swapped into the policy, nothing else changes")
    ap.add_argument("--policy", default="unified", choices=POLICY_CHOICES,
                    help="ExecutionPolicy the serving regions run under "
                         "(adaptive threads cfg.memory.target_cutoff)")
    ap.add_argument("--verify", action="store_true",
                    help="statically lint every captured program "
                         "(PREFILL/DECODE_STEP/KV_APPEND, or the engine "
                         "tick) under the serving policy at startup; "
                         "error-severity findings abort (repro.analysis, "
                         "docs/ANALYSIS.md)")
    ap.add_argument("--report", action="store_true",
                    help="print the run's coverage_report() as JSON")
    ap.add_argument("--sync-every", type=int, default=0, metavar="K",
                    help="jit streaming path: block_until_ready once per K "
                         "tokens; K <= 0 = never sync mid-stream, one "
                         "final sync at end of stream; 1 = the retired "
                         "per-token sync")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--engine", action="store_true",
                    help="continuous-batching engine instead of the static "
                         "batch: Poisson traffic through slot-scheduled "
                         "decode over a paged KV cache, bit-parity "
                         "asserted vs solo jit decodes (docs/SERVING.md); "
                         "composes with any --policy and --offload-kv")
    ap.add_argument("--slots", type=int, default=4, metavar="N",
                    help="engine decode slots (the vmapped tick width)")
    ap.add_argument("--requests", type=int, default=8, metavar="N",
                    help="engine traffic size (seeded by --seed)")
    ap.add_argument("--rate", type=float, default=1.0, metavar="R",
                    help="engine mean arrivals per tick (Poisson)")
    ap.add_argument("--page-tokens", type=int, default=8, metavar="T",
                    help="engine KV page size along the token axis")
    ap.add_argument("--kv-device-budget", type=int, default=0, metavar="B",
                    help="engine paged-KV device budget in bytes; exceeding "
                         "it spills LRU entries to host DRAM (0 = "
                         "unlimited)")
    ap.add_argument("--kv-total-budget", type=int, default=0, metavar="B",
                    help="engine paged-KV total budget in bytes; exceeding "
                         "it evicts+requeues LRU requests (0 = unlimited)")
    ap.add_argument("--kv-oversub-ratio", type=float, default=0.0,
                    metavar="R",
                    help="engine KV oversubscription ratio: set the logical "
                         "device budget (repro.core.oversub.MemoryBudget) "
                         "to 1/R of the measured slots-x-full-length KV "
                         "footprint, so R=2 runs a working set twice "
                         "device capacity — LRU spill keeps it inside "
                         "(0 = off)")
    ap.add_argument("--replay-batch", type=int, default=0, metavar="N",
                    help="also push N stacked request groups through the "
                         "captured decode program "
                         "(RegionProgram.replay_batch heavy-traffic path)")
    ap.add_argument("--mesh", type=int, default=0, metavar="N",
                    help="scatter the --replay-batch request groups over a "
                         "mesh of N simulated APUs (shard_program); export "
                         "XLA_FLAGS=--xla_force_host_platform_device_"
                         "count=N before launch, see docs/SCALING.md")
    args = ap.parse_args(argv)
    if args.mesh and not args.replay_batch:
        raise SystemExit("--mesh requires --replay-batch N (it shards the "
                         "batched decode program)")
    if args.engine and (args.replay_batch or args.mesh):
        raise SystemExit("--engine replaces the static batch paths; it "
                         "does not compose with --replay-batch/--mesh")

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = make_reduced(cfg)
    # with --mesh N the model mesh spans the same N simulated APUs as the
    # shard_program mesh — one jit cannot mix two device assignments
    mesh = make_smoke_mesh((args.mesh, 1)) if args.mesh else make_smoke_mesh()
    max_len = args.prompt_len + args.gen
    placer = offload_kv_cache() if args.offload_kv else None
    if args.policy == "auto":
        # tuned warm-start: the profile's serve_decode winner for this
        # request shape (lazy import — repro.tune pulls this driver back
        # in for its workload harness)
        from repro.launch.policy import auto_policy
        from repro.tune.space import serve_size
        pol = auto_policy("serve_decode",
                          serve_size(args.batch, max_len, cfg.d_model),
                          cfg.memory, placer=placer)
        entry = getattr(pol, "tuned_entry", None)
        if entry is not None and entry.candidate.staging == "async":
            ex = AsyncExecutor(pol, Ledger("serve"))
        else:
            ex = Executor(pol, Ledger("serve"))
    else:
        ex = Executor(lm_policy(args.policy, cfg.memory, placer=placer),
                      Ledger("serve"))
    key = jax.random.PRNGKey(args.seed)
    params = T.init(key, cfg)
    if args.engine:
        return _engine_demo(cfg, mesh, params, ex, args, max_len)
    regions = make_serve_regions(cfg, mesh, params, ledger=ex.ledger)

    prompts = jax.random.randint(key, (args.batch, args.prompt_len), 0,
                                 cfg.vocab, jnp.int32)
    batch = _prefill_inputs(cfg, args, prompts)

    # -- captured-program path (the accounted serving spine) -------------
    prefill_prog = capture_prefill_program(regions, batch,
                                           T.init_cache(cfg, args.batch,
                                                        max_len))
    if args.verify:
        _verify_programs(ex, prefill_prog)
    t0 = time.time()
    tok, cache = prefill_prog.replay(ex, batch,
                                     T.init_cache(cfg, args.batch, max_len))
    t_prefill = time.time() - t0
    decode_prog = capture_decode_program(regions, args.prompt_len, args.gen,
                                         tok, cache)
    if args.verify:
        _verify_programs(ex, decode_prog)
    t1 = time.time()
    toks = decode_prog.replay(ex, tok, cache)
    t_decode = time.time() - t1
    seq = np.asarray(jnp.stack(toks, axis=1))
    assert np.isfinite(seq).all()

    # -- pre-capture jit streaming path (interval sync) -------------------
    # built only when needed: under UnifiedPolicy it doubles as the parity
    # oracle (capture changes the schedule, never the tokens); other
    # policies change placement/staging, not math — re-running the jit
    # stream there would double the run for numbers the report carries
    stream_note = ""
    prefill = make_cache = None
    if args.policy == "unified" or args.replay_batch:
        prefill, decode, make_cache = build_server(
            cfg, mesh, args.batch, max_len, offload_kv=args.offload_kv)
    if args.policy == "unified":
        logits, cache_j = prefill(params, batch, make_cache())
        tok_j = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
        # warm the decode executable on a throwaway prefill output (a
        # fresh make_cache() has different sharding than the prefill
        # result and would compile a second executable) so the stream
        # timing measures the stream, not the compile
        _, cache_w = prefill(params, batch, make_cache())
        jax.block_until_ready(decode(params, tok_j, cache_w,
                                     jnp.int32(args.prompt_len)))
        t2 = time.time()
        toks_j, _ = decode_stream(decode, params, tok_j, cache_j,
                                  args.prompt_len, args.gen,
                                  sync_every=args.sync_every)
        t_stream = time.time() - t2
        seq_j = np.asarray(jnp.stack(toks_j, axis=1))
        # the acceptance invariant: program tokens == jit-path tokens
        np.testing.assert_array_equal(seq, seq_j)
        total_new = args.batch * args.gen
        stream_note = f", {total_new/max(t_stream,1e-9):.0f} tok/s stream"

    total_new = args.batch * args.gen
    print(f"[serve] {args.arch}{' (reduced)' if args.reduced else ''} "
          f"[{ex.mode}]: prefill {args.batch}x{args.prompt_len} in "
          f"{t_prefill*1e3:.1f} ms; decode {total_new} tokens in "
          f"{t_decode*1e3:.1f} ms ({total_new/max(t_decode,1e-9):.0f} tok/s "
          f"program{stream_note})"
          + (f" [KV in {preferred_host_space().kind}]"
             if args.offload_kv and preferred_host_space() else ""))
    if args.replay_batch:
        replay_batch_demo(cfg, ex, decode_prog, prefill, make_cache,
                          params, args, args.replay_batch,
                          apu_mesh_size=args.mesh)
    if args.report:
        print(json.dumps(ex.report(), indent=1, default=str))
    return seq


if __name__ == "__main__":
    main()
