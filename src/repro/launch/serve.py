"""Batched serving driver: prefill + decode with KV-cache management.

Serving is where the unified-memory policy earns its keep (paper C1/C4):
KV pages come from the ``DeviceBufferPool`` (no alloc churn between
requests), and with ``--offload-kv`` the cache is placed in ``pinned_host``
memory — the single-address-space model lets one config flag move hundreds
of GB of cache off HBM with zero changes to the decode math.

  PYTHONPATH=src python -m repro.launch.serve --arch gemma3-1b --reduced \
      --batch 4 --prompt-len 32 --gen 32
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.reduced import reduced as make_reduced
from repro.configs.registry import get_config
from repro.core.ledger import Ledger
from repro.core.pool import DeviceBufferPool
from repro.core.regions import Executor, UnifiedPolicy, region
from repro.core.umem import preferred_host_space, tree_place
from repro.launch import sharding as SH
from repro.launch.mesh import make_smoke_mesh
from repro.models import transformer as T
from repro.train import step as S


# placement is keyed on tensor ROLE, not just size: only the actual k/v
# pages (batch*heads*len*head_dim — megabytes at serving scale) go to host
# DRAM; slot/position bookkeeping is decode-hot and stays deviceside no
# matter how large. min_bytes additionally keeps smoke-scale k/v pages,
# where the crossing costs more than it saves, where they are.
KV_PLACE_KEYS = ("k", "v")
KV_PLACE_MIN_BYTES = 32768


def offload_kv_cache(cache, space, min_bytes=KV_PLACE_MIN_BYTES):
    def per_leaf(path, x):
        keys = {getattr(p, "key", None) for p in path}
        if keys & set(KV_PLACE_KEYS):
            return tree_place(x, space, min_bytes=min_bytes)
        return x
    return jax.tree_util.tree_map_with_path(per_leaf, cache)


def build_server(cfg, mesh, batch: int, max_len: int, q_chunk=256,
                 offload_kv=False):
    rules = SH.ShardingRules("serve")
    shd = SH.make_sharder(mesh, rules)
    prefill = jax.jit(S.make_prefill_step(
        cfg, lambda: T.Ctx(mode="prefill", shd=shd, q_chunk=q_chunk,
                           remat=False)))
    decode = jax.jit(S.make_decode_step(
        cfg, lambda: T.Ctx(mode="decode", shd=shd, remat=False)),
        donate_argnums=(2,))

    # KV placement is a MemSpace hint, not a hand-rolled sharding: pages big
    # enough to matter go to host DRAM, small tensors stay put (paper C1/C4)
    kv_space = preferred_host_space() if offload_kv else None

    def make_cache():
        cache = T.init_cache(cfg, batch, max_len)
        if kv_space is not None:
            cache = offload_kv_cache(cache, kv_space)
        return cache

    return prefill, decode, make_cache


def capture_decode_program(cfg, mesh, params, prompt_len: int, gen: int,
                           example_tok, example_cache, ledger=None):
    """The greedy decode loop as one :class:`RegionProgram`.

    Each generated token is one ``decode+argmax`` region call whose KV cache
    flows region-to-region, so the captured trace carries the full request
    dataflow.  ``params`` are closed over (constants), which is exactly what
    ``replay_batch`` wants: under ``vmap`` they broadcast across the N
    stacked requests while tokens and caches batch.
    """
    from repro.core.program import capture

    rules = SH.ShardingRules("serve")
    shd = SH.make_sharder(mesh, rules)
    raw_decode = S.make_decode_step(
        cfg, lambda: T.Ctx(mode="decode", shd=shd, remat=False))

    @region("decode+argmax", ledger=ledger or Ledger("decode_program"))
    def decode_region(tok, cache, pos):
        logits, cache = raw_decode(params, tok, cache, pos)
        return jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32), cache

    def gen_loop(run, tok, cache):
        toks = [tok]
        for i in range(gen - 1):
            tok, cache = run(decode_region, tok, cache,
                             jnp.int32(prompt_len + i))
            toks.append(tok)
        return tuple(toks)      # tuple of refs (stacking outside a region
        #                         would freeze the result as a constant)

    return capture(gen_loop, example_tok, example_cache,
                   name="decode_program")


def replay_batch_demo(cfg, mesh, prefill, make_cache, params, args,
                      n_requests: int, apu_mesh_size: int = 0):
    """The "heavy traffic" path: capture one request group's decode loop,
    then push N independent request groups through it as ONE vmapped
    program (``RegionProgram.replay_batch``).

    ``apu_mesh_size`` > 0 additionally scatters the stacked request groups
    across a 1-D mesh of simulated APUs (``repro.core.shard_program``):
    each APU decodes its slice of the requests through the same compiled
    composite, with per-device ledgers aggregated in the printed report.
    Needs ``XLA_FLAGS=--xla_force_host_platform_device_count=N`` exported
    before launch (see docs/SCALING.md)."""
    key0 = jax.random.PRNGKey(args.seed)
    toks, caches = [], []
    for r in range(n_requests):
        key = jax.random.fold_in(key0, r)
        prompts = jax.random.randint(key, (args.batch, args.prompt_len), 0,
                                     cfg.vocab, jnp.int32)
        batch = _prefill_inputs(cfg, args, prompts)
        logits, cache = prefill(params, batch, make_cache())
        toks.append(jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32))
        caches.append(cache)

    ex = Executor(UnifiedPolicy(), Ledger("serve_batch"))
    prog = capture_decode_program(cfg, mesh, params, args.prompt_len,
                                  args.gen, toks[0], caches[0],
                                  ledger=ex.ledger)
    stacked_tok = jnp.stack(toks)
    stacked_cache = jax.tree.map(lambda *xs: jnp.stack(xs), *caches)
    sharded = None
    if apu_mesh_size:
        from repro.core.shard_program import shard_program
        from repro.launch.mesh import make_apu_mesh
        if n_requests % apu_mesh_size:
            raise SystemExit(f"--replay-batch {n_requests} does not divide "
                             f"over --mesh {apu_mesh_size} APUs")
        sharded = shard_program(prog, make_apu_mesh(apu_mesh_size),
                                UnifiedPolicy(), shard_dim=0)
    t0 = time.time()
    if sharded is not None:
        out = sharded.replay_batch(stacked_tok, stacked_cache)
    else:
        out = prog.replay_batch(stacked_tok, stacked_cache, executor=ex)
    dt = time.time() - t0
    seqs = np.asarray(jnp.stack(out, axis=-1))        # (N, B, gen)
    assert np.isfinite(seqs).all()
    # request 0 replayed alone through the same program (vmap-free):
    # agreement can drop below 1.0 only via argmax ties under batched matmul
    solo = np.asarray(jnp.stack(prog.replay(ex, toks[0], caches[0]),
                                axis=-1))
    agree = float((seqs[0] == solo).mean())
    total = n_requests * args.batch * args.gen
    shard_note = ""
    if sharded is not None:
        rep = sharded.coverage_report()
        # NB: no exchange figure here — the batched path scatters whole
        # independent requests, so there is no halo traffic to model
        shard_note = (f"; sharded over {rep['devices']} APUs "
                      f"({n_requests // rep['devices']} request groups "
                      f"each)")
    print(f"[serve] replay_batch: {n_requests} request groups x "
          f"{args.batch}x{args.gen} tokens = {total} tokens in "
          f"{dt*1e3:.1f} ms ({total/max(dt,1e-9):.0f} tok/s); "
          f"solo-replay agreement {agree:.3f}{shard_note}")
    return seqs


def _prefill_inputs(cfg, args, prompts):
    batch = {"tokens": prompts}
    if cfg.mrope_sections is not None:
        pos = jnp.arange(args.prompt_len, dtype=jnp.int32)[None, :, None]
        batch["positions3"] = jnp.broadcast_to(
            pos, (args.batch, args.prompt_len, 3))
    if cfg.n_enc_layers:
        batch["enc_embeds"] = jnp.zeros(
            (args.batch, cfg.enc_len, cfg.d_model), cfg.compute_dtype)
    return batch


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma3-1b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--offload-kv", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--replay-batch", type=int, default=0, metavar="N",
                    help="also capture the decode loop as a RegionProgram "
                         "and replay it over N stacked request groups "
                         "(repro.core.program heavy-traffic path)")
    ap.add_argument("--mesh", type=int, default=0, metavar="N",
                    help="scatter the --replay-batch request groups over a "
                         "mesh of N simulated APUs (shard_program); export "
                         "XLA_FLAGS=--xla_force_host_platform_device_"
                         "count=N before launch, see docs/SCALING.md")
    args = ap.parse_args(argv)
    if args.mesh and not args.replay_batch:
        raise SystemExit("--mesh requires --replay-batch N (it shards the "
                         "batched decode program)")

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = make_reduced(cfg)
    # with --mesh N the model mesh spans the same N simulated APUs as the
    # shard_program mesh — one jit cannot mix two device assignments
    mesh = make_smoke_mesh((args.mesh, 1)) if args.mesh else make_smoke_mesh()
    max_len = args.prompt_len + args.gen
    prefill, decode, make_cache = build_server(
        cfg, mesh, args.batch, max_len, offload_kv=args.offload_kv)
    key = jax.random.PRNGKey(args.seed)
    params = T.init(key, cfg)

    prompts = jax.random.randint(key, (args.batch, args.prompt_len), 0,
                                 cfg.vocab, jnp.int32)
    cache = make_cache()

    t0 = time.time()
    batch = _prefill_inputs(cfg, args, prompts)
    logits, cache = prefill(params, batch, cache)
    jax.block_until_ready(logits)
    t_prefill = time.time() - t0

    tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
    toks = [tok]
    t1 = time.time()
    for i in range(args.gen - 1):
        logits, cache = decode(params, tok, cache, jnp.int32(args.prompt_len + i))
        tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
        toks.append(tok)
    jax.block_until_ready(tok)
    t_decode = time.time() - t1
    total_new = args.batch * args.gen
    print(f"[serve] {args.arch}{' (reduced)' if args.reduced else ''}: "
          f"prefill {args.batch}x{args.prompt_len} in {t_prefill*1e3:.1f} ms; "
          f"decode {total_new} tokens in {t_decode*1e3:.1f} ms "
          f"({total_new/max(t_decode,1e-9):.0f} tok/s)"
          + (f" [KV in {preferred_host_space().kind}]"
             if args.offload_kv and preferred_host_space() else ""))
    seq = np.asarray(jnp.stack(toks, axis=1))
    assert np.isfinite(seq).all()
    if args.replay_batch:
        replay_batch_demo(cfg, mesh, prefill, make_cache, params, args,
                          args.replay_batch, apu_mesh_size=args.mesh)
    return seq


if __name__ == "__main__":
    main()
