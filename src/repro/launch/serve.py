"""Batched serving driver: prefill + decode with KV-cache management.

Serving is where the unified-memory policy earns its keep (paper C1/C4):
KV pages come from the ``DeviceBufferPool`` (no alloc churn between
requests), and with ``--offload-kv`` the cache is placed in ``pinned_host``
memory — the single-address-space model lets one config flag move hundreds
of GB of cache off HBM with zero changes to the decode math.

  PYTHONPATH=src python -m repro.launch.serve --arch gemma3-1b --reduced \
      --batch 4 --prompt-len 32 --gen 32
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.reduced import reduced as make_reduced
from repro.configs.registry import get_config
from repro.core.pool import DeviceBufferPool
from repro.core.umem import preferred_host_space, tree_place
from repro.launch import sharding as SH
from repro.launch.mesh import make_smoke_mesh
from repro.models import transformer as T
from repro.train import step as S


# placement is keyed on tensor ROLE, not just size: only the actual k/v
# pages (batch*heads*len*head_dim — megabytes at serving scale) go to host
# DRAM; slot/position bookkeeping is decode-hot and stays deviceside no
# matter how large. min_bytes additionally keeps smoke-scale k/v pages,
# where the crossing costs more than it saves, where they are.
KV_PLACE_KEYS = ("k", "v")
KV_PLACE_MIN_BYTES = 32768


def offload_kv_cache(cache, space, min_bytes=KV_PLACE_MIN_BYTES):
    def per_leaf(path, x):
        keys = {getattr(p, "key", None) for p in path}
        if keys & set(KV_PLACE_KEYS):
            return tree_place(x, space, min_bytes=min_bytes)
        return x
    return jax.tree_util.tree_map_with_path(per_leaf, cache)


def build_server(cfg, mesh, batch: int, max_len: int, q_chunk=256,
                 offload_kv=False):
    rules = SH.ShardingRules("serve")
    shd = SH.make_sharder(mesh, rules)
    prefill = jax.jit(S.make_prefill_step(
        cfg, lambda: T.Ctx(mode="prefill", shd=shd, q_chunk=q_chunk,
                           remat=False)))
    decode = jax.jit(S.make_decode_step(
        cfg, lambda: T.Ctx(mode="decode", shd=shd, remat=False)),
        donate_argnums=(2,))

    # KV placement is a MemSpace hint, not a hand-rolled sharding: pages big
    # enough to matter go to host DRAM, small tensors stay put (paper C1/C4)
    kv_space = preferred_host_space() if offload_kv else None

    def make_cache():
        cache = T.init_cache(cfg, batch, max_len)
        if kv_space is not None:
            cache = offload_kv_cache(cache, kv_space)
        return cache

    return prefill, decode, make_cache


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma3-1b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--offload-kv", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = make_reduced(cfg)
    mesh = make_smoke_mesh()
    max_len = args.prompt_len + args.gen
    prefill, decode, make_cache = build_server(
        cfg, mesh, args.batch, max_len, offload_kv=args.offload_kv)
    key = jax.random.PRNGKey(args.seed)
    params = T.init(key, cfg)

    prompts = jax.random.randint(key, (args.batch, args.prompt_len), 0,
                                 cfg.vocab, jnp.int32)
    cache = make_cache()

    t0 = time.time()
    batch = {"tokens": prompts}
    if cfg.mrope_sections is not None:
        pos = jnp.arange(args.prompt_len, dtype=jnp.int32)[None, :, None]
        batch["positions3"] = jnp.broadcast_to(
            pos, (args.batch, args.prompt_len, 3))
    if cfg.n_enc_layers:
        batch["enc_embeds"] = jnp.zeros(
            (args.batch, cfg.enc_len, cfg.d_model), cfg.compute_dtype)
    logits, cache = prefill(params, batch, cache)
    jax.block_until_ready(logits)
    t_prefill = time.time() - t0

    tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
    toks = [tok]
    t1 = time.time()
    for i in range(args.gen - 1):
        logits, cache = decode(params, tok, cache, jnp.int32(args.prompt_len + i))
        tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
        toks.append(tok)
    jax.block_until_ready(tok)
    t_decode = time.time() - t1
    total_new = args.batch * args.gen
    print(f"[serve] {args.arch}{' (reduced)' if args.reduced else ''}: "
          f"prefill {args.batch}x{args.prompt_len} in {t_prefill*1e3:.1f} ms; "
          f"decode {total_new} tokens in {t_decode*1e3:.1f} ms "
          f"({total_new/max(t_decode,1e-9):.0f} tok/s)"
          + (f" [KV in {preferred_host_space().kind}]"
             if args.offload_kv and preferred_host_space() else ""))
    seq = np.asarray(jnp.stack(toks, axis=1))
    assert np.isfinite(seq).all()
    return seq


if __name__ == "__main__":
    main()
