"""End-to-end training driver.

Integrates the full stack: config registry (--arch, full or --reduced),
mesh + logical-axis sharding (FSDP/TP), the unified-memory policy
(--offload-optimizer puts AdamW moments in pinned_host — paper C1), pooled
host staging, async atomic checkpointing, the fault-tolerant supervisor,
and the deterministic data pipeline.

Examples:
  PYTHONPATH=src python -m repro.launch.train --arch tinyllama-1.1b \
      --reduced --steps 50 --batch 8 --seq 64
  PYTHONPATH=src python -m repro.launch.train --arch qwen3-moe-30b-a3b \
      --reduced --steps 20 --batch 4 --seq 32 --offload-optimizer
"""
from __future__ import annotations

import argparse
import time
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.ckpt import Checkpointer
from repro.configs.base import ModelConfig
from repro.configs.reduced import reduced as make_reduced
from repro.configs.registry import get_config
from repro.core.umem import place_like, preferred_host_space
from repro.data.pipeline import ShardInfo, make_source
from repro.launch import sharding as SH
from repro.launch.mesh import make_smoke_mesh
from repro.models import transformer as T
from repro.models.params import abstract_params
from repro.optim import adamw
from repro.runtime.fault import FaultInjector, StragglerMonitor, TrainSupervisor
from repro.train import step as S


def build_trainer(cfg: ModelConfig, mesh, *, lr=3e-4, offload_optimizer=False,
                  q_chunk=512, seed=0):
    """Returns (init_fn() -> state, step_fn(state, tokens) -> (state, metrics))."""
    rules = SH.ShardingRules("train")
    shd = SH.make_sharder(mesh, rules)
    opt_cfg = adamw.AdamWConfig(lr=lr)
    specs = T.param_specs(cfg)
    psh = SH.tree_param_shardings(specs, mesh, rules)
    mom_kind = None
    if offload_optimizer:
        host_space = preferred_host_space()
        mom_kind = host_space.kind if host_space is not None else None
    msh_m = SH.tree_param_shardings(specs, mesh, rules, memory_kind=mom_kind)
    repl = SH.replicated(mesh)
    osh = {"m": msh_m, "v": msh_m, "step": repl}

    make_ctx = lambda: T.Ctx(mode="train", shd=shd, q_chunk=q_chunk)
    raw_step = S.make_train_step(cfg, opt_cfg, make_ctx)

    def step2(state, batch):
        params, opt = state
        params, opt, metrics = raw_step(params, opt, batch)
        return (params, opt), metrics

    metr = {k: repl for k in ("loss", "ce", "moe_aux", "grad_norm")}
    jstep = jax.jit(step2,
                    in_shardings=((psh, osh), None),
                    out_shardings=((psh, osh), metr),
                    donate_argnums=(0,))

    def init_fn():
        key = jax.random.PRNGKey(seed)
        params = jax.jit(lambda k: T.init(k, cfg), out_shardings=psh)(key)
        opt = adamw.init_state(params, opt_cfg)
        if mom_kind:
            opt = {"m": place_like(opt["m"], osh["m"]),
                   "v": place_like(opt["v"], osh["v"]),
                   "step": opt["step"]}
        return (params, opt)

    return init_fn, jstep


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tinyllama-1.1b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--offload-optimizer", action="store_true")
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=25)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--data", default="synthetic")
    ap.add_argument("--data-path", default="")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--fail-at", default="", help="fault injection steps, csv")
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = make_reduced(cfg)
    mesh = make_smoke_mesh()
    init_fn, jstep = build_trainer(cfg, mesh, lr=args.lr,
                                   offload_optimizer=args.offload_optimizer,
                                   q_chunk=min(512, args.seq), seed=args.seed)
    src = make_source(args.data, cfg.vocab, path=args.data_path,
                      seed=args.seed)

    def batch_fn(step):
        tok = jnp.asarray(src.batch_at(step, args.batch, args.seq))
        b = {"tokens": tok}
        if cfg.mrope_sections is not None:
            pos = jnp.arange(args.seq, dtype=jnp.int32)[None, :, None]
            b["positions3"] = jnp.broadcast_to(pos, (args.batch, args.seq, 3))
        if cfg.n_enc_layers:
            key = jax.random.PRNGKey(step)
            b["enc_embeds"] = jax.random.normal(
                key, (args.batch, cfg.enc_len, cfg.d_model),
                jnp.float32).astype(cfg.compute_dtype)
        return b

    state = init_fn()
    start = 0
    ckpt = None
    if args.ckpt_dir:
        ckpt = Checkpointer(args.ckpt_dir, keep=3)
        if args.resume and ckpt.latest_step() is not None:
            state, man = ckpt.restore(state)
            start = man["extra"]["step"]
            print(f"[train] resumed at step {start}")

    t0 = time.time()
    if ckpt is not None:
        fault = FaultInjector({int(s) for s in args.fail_at.split(",") if s})
        sup = TrainSupervisor(jstep, batch_fn, ckpt,
                              ckpt_every=args.ckpt_every, fault=fault)
        state, rep = sup.run(state, start, args.steps)
        print(f"[train] done: {rep}")
        losses = [rep.metrics_last.get("loss", float("nan"))]
    else:
        losses = []
        for step in range(start, start + args.steps):
            state, metrics = jstep(state, batch_fn(step))
            losses.append(float(metrics["loss"]))
            if step % 10 == 0 or step == start + args.steps - 1:
                print(f"[train] step {step} loss {losses[-1]:.4f} "
                      f"gnorm {float(metrics['grad_norm']):.3f}")
    dt = time.time() - t0
    toks = args.steps * args.batch * args.seq
    print(f"[train] {args.arch}{' (reduced)' if args.reduced else ''}: "
          f"{toks/dt:.0f} tok/s, first loss {losses[0]:.4f}, "
          f"last loss {losses[-1]:.4f}")
    return losses


if __name__ == "__main__":
    main()
