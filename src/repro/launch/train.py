"""End-to-end training driver — on the region-program spine.

Integrates the full stack: config registry (--arch, full or --reduced),
mesh + logical-axis sharding (FSDP/TP), the region-decomposed train step
(``FWD_BWD`` + ``ADAMW_UPDATE`` Regions captured as one RegionProgram and
replayed through an Executor under ``--policy``), the unified-memory
placement axis (--offload-optimizer attaches a host-space hint to the
AdamW moments — paper C1, no hand-rolled placement calls), pooled host
staging, async atomic checkpointing (each checkpoint carries a
``coverage_report()`` snapshot beside the weights), the fault-tolerant
supervisor (restarts re-capture the program against restored state while
keeping the same Ledger), and the deterministic data pipeline.
``--report`` prints the canonical ``coverage_report()`` as JSON.

Examples:
  PYTHONPATH=src python -m repro.launch.train --arch tinyllama-1.1b \
      --reduced --steps 50 --batch 8 --seq 64
  PYTHONPATH=src python -m repro.launch.train --arch qwen3-moe-30b-a3b \
      --reduced --steps 20 --batch 4 --seq 32 --offload-optimizer --report
"""
from __future__ import annotations

import argparse
import json
import time
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.ckpt import Checkpointer
from repro.configs.base import ModelConfig
from repro.configs.reduced import reduced as make_reduced
from repro.configs.registry import get_config
from repro.core.ledger import Ledger
from repro.core.regions import Executor
from repro.core.umem import place_like
from repro.data.pipeline import ShardInfo, make_source
from repro.launch import sharding as SH
from repro.launch.mesh import make_smoke_mesh
from repro.launch.policy import POLICY_CHOICES, lm_policy
from repro.models import transformer as T
from repro.models.params import abstract_params
from repro.optim import adamw
from repro.runtime.fault import FaultInjector, StragglerMonitor, TrainSupervisor
from repro.train import step as S


def build_trainer(cfg: ModelConfig, mesh, *, lr=3e-4, offload_optimizer=False,
                  q_chunk=512, seed=0, policy: str = "unified",
                  executor: Optional[Executor] = None,
                  verify: bool = False, tuned_size: Optional[int] = None):
    """Returns ``(init_fn, capture_fn, ex)``.

    ``init_fn() -> state`` builds sharded params + optimizer state.
    ``capture_fn(state, batch) -> step_fn`` captures one train step as a
    RegionProgram over the trainer's ``FWD_BWD``/``ADAMW_UPDATE`` regions
    and returns ``step_fn(state, batch) -> (state, metrics)`` replaying it
    through ``ex`` — call it again after a restore to re-capture (the
    regions, and therefore the Ledger rows, are reused).
    ``ex`` is the Executor every step runs under; ``ex.report()`` is the
    canonical coverage report for the run.

    Memory note: the pre-regions trainer jitted the whole step with
    ``donate_argnums=(0,)``, updating params/moments in place.  Region
    executables do not donate (a replayed region may be staged, and the
    discrete stager recycles staged-in buffers after the call — donation
    would hand consumed storage back to the pool), so peak state memory
    is roughly 2x the old path at the ADAMW_UPDATE boundary.  A
    stage-aware donation axis is the natural follow-up; at the smoke
    scales this container runs, the 2x is noise.
    """
    rules = SH.ShardingRules("train")
    shd = SH.make_sharder(mesh, rules)
    opt_cfg = adamw.AdamWConfig(lr=lr)
    specs = T.param_specs(cfg)
    psh = SH.tree_param_shardings(specs, mesh, rules)

    if executor is not None:
        ex = executor
    elif policy == "auto":
        # tuned warm-start: profile's train_step winner at this workload
        # size (``repro.tune.space.train_size``); with no ``tuned_size``
        # the nearest calibrated bucket still resolves (lazy import —
        # repro.tune's workload harness imports this driver back)
        from repro.core.program import AsyncExecutor
        from repro.launch.policy import auto_policy
        pol = auto_policy("train_step", tuned_size or 0, cfg.memory)
        entry = getattr(pol, "tuned_entry", None)
        led = Ledger("train")
        ex = (AsyncExecutor(pol, led)
              if entry is not None and entry.candidate.staging == "async"
              else Executor(pol, led))
    else:
        ex = Executor(lm_policy(policy, cfg.memory), Ledger("train"))
    make_ctx = lambda: T.Ctx(mode="train", shd=shd, q_chunk=q_chunk)
    regions = S.make_train_regions(cfg, opt_cfg, make_ctx, ledger=ex.ledger,
                                   offload_optimizer=offload_optimizer)

    def init_fn():
        key = jax.random.PRNGKey(seed)
        params = jax.jit(lambda k: T.init(k, cfg), out_shardings=psh)(key)
        # moments mirror their params' FSDP/TP partitioning (a moment tree
        # left unsharded would clash with mesh-committed params inside the
        # ADAMW_UPDATE jit on any real mesh); which memory SPACE they live
        # in stays a policy-axis decision — the ADAMW_UPDATE placement
        # hints move them to host space when --offload-optimizer is set
        opt = adamw.init_state(params, opt_cfg)
        opt = {"m": place_like(opt["m"], psh),
               "v": place_like(opt["v"], psh),
               "step": opt["step"]}
        return (params, opt)

    def capture_fn(state, batch):
        prog = S.capture_train_program(regions, state, batch)
        if verify:
            # --verify: lint the fresh FWD_BWD + ADAMW_UPDATE trace under
            # the training policy before the first replay (repro.analysis;
            # supervisor re-captures re-verify the same way)
            rep = prog.verify(ex.policy, ledger=ex.ledger)
            print(f"[verify] {rep.summary()}")
            for d in rep.findings:
                print(f"    {d}")
            if rep.errors:
                raise SystemExit(f"[verify] {prog.name!r} has "
                                 "error-severity findings; refusing to "
                                 "train")

        def step_fn(state, batch):
            return prog.replay(ex, state, batch)

        return step_fn

    return init_fn, capture_fn, ex


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tinyllama-1.1b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--offload-optimizer", action="store_true")
    ap.add_argument("--policy", default="unified", choices=POLICY_CHOICES,
                    help="ExecutionPolicy the train-step regions run under "
                         "(adaptive threads cfg.memory.target_cutoff)")
    ap.add_argument("--verify", action="store_true",
                    help="statically lint the captured train-step program "
                         "(FWD_BWD + ADAMW_UPDATE) under the training "
                         "policy at capture; error-severity findings "
                         "abort (repro.analysis, docs/ANALYSIS.md)")
    ap.add_argument("--report", action="store_true",
                    help="print the run's coverage_report() as JSON")
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=25)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--data", default="synthetic")
    ap.add_argument("--data-path", default="")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--fail-at", default="", help="fault injection steps, csv")
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = make_reduced(cfg)
    mesh = make_smoke_mesh()
    tuned_size = None
    if args.policy == "auto":
        from repro.tune.space import train_size
        tuned_size = train_size(args.batch, args.seq, cfg.d_model)
    init_fn, capture_fn, ex = build_trainer(
        cfg, mesh, lr=args.lr, offload_optimizer=args.offload_optimizer,
        q_chunk=min(512, args.seq), seed=args.seed, policy=args.policy,
        verify=args.verify, tuned_size=tuned_size)
    src = make_source(args.data, cfg.vocab, path=args.data_path,
                      seed=args.seed)

    def batch_fn(step):
        tok = jnp.asarray(src.batch_at(step, args.batch, args.seq))
        b = {"tokens": tok}
        if cfg.mrope_sections is not None:
            pos = jnp.arange(args.seq, dtype=jnp.int32)[None, :, None]
            b["positions3"] = jnp.broadcast_to(pos, (args.batch, args.seq, 3))
        if cfg.n_enc_layers:
            key = jax.random.PRNGKey(step)
            b["enc_embeds"] = jax.random.normal(
                key, (args.batch, cfg.enc_len, cfg.d_model),
                jnp.float32).astype(cfg.compute_dtype)
        return b

    state = init_fn()
    start = 0
    ckpt = None
    if args.ckpt_dir:
        ckpt = Checkpointer(args.ckpt_dir, keep=3)
        if args.resume and ckpt.latest_step() is not None:
            state, man = ckpt.restore(state)
            start = man["extra"]["step"]
            print(f"[train] resumed at step {start}")

    step_fn = capture_fn(state, batch_fn(start))
    t0 = time.time()
    if ckpt is not None:
        fault = FaultInjector({int(s) for s in args.fail_at.split(",") if s})
        sup = TrainSupervisor(
            step_fn, batch_fn, ckpt, ckpt_every=args.ckpt_every, fault=fault,
            rebuild_step=lambda st, step: capture_fn(st, batch_fn(step)),
            report_fn=ex.report)
        state, rep = sup.run(state, start, args.steps)
        print(f"[train] done: {rep}")
        losses = [rep.metrics_last.get("loss", float("nan"))]
    else:
        losses = []
        for step in range(start, start + args.steps):
            state, metrics = step_fn(state, batch_fn(step))
            losses.append(float(metrics["loss"]))
            if step % 10 == 0 or step == start + args.steps - 1:
                print(f"[train] step {step} loss {losses[-1]:.4f} "
                      f"gnorm {float(metrics['grad_norm']):.3f}")
    dt = time.time() - t0
    toks = args.steps * args.batch * args.seq
    print(f"[train] {args.arch}{' (reduced)' if args.reduced else ''}: "
          f"{toks/dt:.0f} tok/s, first loss {losses[0]:.4f}, "
          f"last loss {losses[-1]:.4f}")
    if args.report:
        print(json.dumps(ex.report(), indent=1, default=str))
    return losses


if __name__ == "__main__":
    main()
