"""Logical-axis -> mesh-axis resolution (FSDP / TP / SP / EP).

Every parameter and activation carries *logical* axis names (see
``ParamSpec`` and the ``shd(x, *axes)`` calls inside the model). This module
resolves them against a concrete mesh:

  pass 1 (TP/EP)   : model-type axes (experts, vocab, heads, ff, rnn) ->
                     the ``model`` mesh axis, when the dim divides.
  pass 2 (DP/FSDP) : ``batch`` -> ("pod", "data") (longest divisible prefix).
  pass 3 (flex)    : leftover mesh axes soaked up greedily by flexible axes —
                     ``kv_seq`` for activations/caches (sequence parallelism
                     for long-context serving), ``embed``/``moe_ff``/... for
                     parameters (FSDP).

Divisibility is checked per tensor, so e.g. ``kv_heads=1`` simply resolves to
replicated instead of erroring — the resolver is total over all 40 assigned
(arch x shape) cells.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models.params import ParamSpec, is_spec

# logical axes that map to the tensor-parallel 'model' axis, in priority order
MODEL_AXES = ("experts", "vocab", "q_heads", "kv_heads", "ff", "rnn", "heads")
BATCH_AXES = ("batch", "expert_group")
ACT_FLEX = ("kv_seq",)
PARAM_FLEX = ("embed", "moe_ff", "vocab", "ff", "rnn", "embed2", "rnn2")


@dataclasses.dataclass(frozen=True)
class ShardingRules:
    """mode: 'train' (FSDP on) or 'serve' (params replicated over data,
    except MoE expert ff which stays FSDP-sharded for memory)."""
    mode: str = "train"

    @property
    def param_flex(self) -> Tuple[str, ...]:
        return PARAM_FLEX if self.mode == "train" else ("moe_ff",)


def _axis_sizes(mesh: Mesh) -> dict:
    return dict(zip(mesh.axis_names, mesh.devices.shape))


def resolve(shape: Sequence[int], axes: Sequence[Optional[str]], mesh: Mesh,
            rules: ShardingRules, kind: str) -> P:
    """kind: 'param' | 'act'."""
    sizes = _axis_sizes(mesh)
    model_sz = sizes.get("model", 1)
    dp_axes = tuple(a for a in ("pod", "data") if a in sizes)
    assign: list = [None] * len(shape)
    used = set()

    # pass 1: tensor/expert parallelism
    order = sorted(
        [i for i, a in enumerate(axes) if a in MODEL_AXES],
        key=lambda i: MODEL_AXES.index(axes[i]))
    for i in order:
        if "model" in used or "model" not in sizes:
            break
        if shape[i] % model_sz == 0 and model_sz > 1:
            assign[i] = "model"
            used.add("model")

    # pass 2: batch over (pod, data)
    for i, a in enumerate(axes):
        if a in BATCH_AXES:
            got = []
            for ax in dp_axes:
                if ax in used:
                    continue
                prod = int(np.prod([sizes[g] for g in got + [ax]]))
                if shape[i] % prod == 0:
                    got.append(ax)
            if got:
                assign[i] = tuple(got) if len(got) > 1 else got[0]
                used.update(got)

    # pass 3: flexible axes soak up leftover mesh axes
    flex = rules.param_flex if kind == "param" else ACT_FLEX
    remaining = [ax for ax in ("pod", "data", "model") if ax in sizes and ax not in used]
    flex_dims = sorted(
        [i for i, a in enumerate(axes) if a in flex and assign[i] is None],
        key=lambda i: flex.index(axes[i]))
    for i in flex_dims:
        got = []
        for ax in list(remaining):
            prod = int(np.prod([sizes[g] for g in got + [ax]]))
            if shape[i] % prod == 0 and sizes[ax] > 1:
                got.append(ax)
                remaining.remove(ax)
        if got:
            assign[i] = tuple(got) if len(got) > 1 else got[0]
            used.update(got)

    return P(*assign)


def param_sharding(spec: ParamSpec, mesh: Mesh, rules: ShardingRules,
                   memory_kind: Optional[str] = None) -> NamedSharding:
    ps = resolve(spec.shape, spec.axes, mesh, rules, "param")
    if memory_kind:
        return NamedSharding(mesh, ps, memory_kind=memory_kind)
    return NamedSharding(mesh, ps)


def tree_param_shardings(specs, mesh: Mesh, rules: ShardingRules,
                         memory_kind: Optional[str] = None):
    return jax.tree_util.tree_map(
        lambda s: param_sharding(s, mesh, rules, memory_kind), specs,
        is_leaf=is_spec)


def make_sharder(mesh: Mesh, rules: ShardingRules):
    """The ``shd(x, *logical_axes)`` callable threaded through the model."""

    def shd(x, *axes):
        if len(axes) != x.ndim:
            raise ValueError(f"sharder: {len(axes)} axes for rank-{x.ndim}")
        ps = resolve(x.shape, axes, mesh, rules, "act")
        return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, ps))

    return shd


def batch_shardings(abstract_batch, mesh: Mesh, rules: ShardingRules):
    """Token batches shard on ('pod','data') over dim 0."""

    def one(sds):
        ps = resolve(sds.shape, ("batch",) + (None,) * (len(sds.shape) - 1),
                     mesh, rules, "act")
        return NamedSharding(mesh, ps)

    return jax.tree_util.tree_map(one, abstract_batch)


def replicated(mesh: Mesh) -> NamedSharding:
    return NamedSharding(mesh, P())
