"""Seeded synthetic serving traffic + the solo-jit parity oracle.

Traffic is generated in *tick units*: the engine has no wall-clock of its
own (one :meth:`~repro.serve.scheduler.ServeEngine.step` is one tick), so
Poisson arrivals are exponential inter-arrival gaps measured in ticks and
a request joins the engine when the driver loop reaches its arrival tick.
Prompt and generation lengths are drawn from small discrete mixes — the
ragged-length regime continuous batching exists for (each distinct prompt
length maps to one captured prefill program: length-bucketed admission).

:func:`solo_reference` is the parity oracle AND latency reference: every
request decoded alone, batch-1, on the pre-capture jit path
(:func:`~repro.launch.serve.build_server` + ``decode_stream``) — the
engine's per-request token sequences must match it bit-for-bit under
every policy.
"""
from __future__ import annotations

import time
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.launch.serve import build_server, decode_stream
from repro.serve.scheduler import Request, ServeEngine, batch_for_prompt


def make_traffic(seed: int, n_requests: int, vocab: int, *,
                 arrival_rate: float = 1.0,
                 prompt_lens: Sequence[int] = (6, 10),
                 gen_lens: Sequence[int] = (5, 9)) -> List[Request]:
    """Poisson arrival stream with mixed prompt/gen lengths, fully seeded.

    ``arrival_rate`` is the expected arrivals per engine tick; the request
    list is sorted by ``arrival_tick`` with ids in arrival order."""
    if arrival_rate <= 0:
        raise ValueError("arrival_rate must be > 0")
    rng = np.random.default_rng(seed)
    t = 0.0
    out = []
    for rid in range(n_requests):
        t += rng.exponential(1.0 / arrival_rate)
        L = int(rng.choice(prompt_lens))
        G = int(rng.choice(gen_lens))
        prompt = rng.integers(0, vocab, size=L).astype(np.int32)
        out.append(Request(req_id=rid, prompt=prompt, gen=G,
                           arrival_tick=int(t)))
    return out


def _warm_engine(engine: ServeEngine, requests: Sequence[Request]) -> None:
    """Compile off the clock, like the oracle's warm-up: one throwaway
    request per distinct prompt length (each length owns a captured
    prefill program) with a decode tick each, then reset the ledger's
    serve counters so the measured run starts clean."""
    rng = np.random.default_rng(0)
    for k, L in enumerate(sorted({r.prompt_len for r in requests})):
        prompt = rng.integers(0, engine.cfg.vocab, size=L).astype(np.int32)
        engine.submit(Request(req_id=-1 - k, prompt=prompt, gen=2))
    engine.drain()
    engine.ledger.reset_timings()


def run_traffic(engine: ServeEngine, requests: Sequence[Request],
                max_ticks: int = 100_000, warmup: bool = True) -> dict:
    """Drive the engine through an arrival stream and measure it.

    Tokens/s counts every emitted token (prefill's first token plus decode
    tokens) over the wall time from first submission to drain.  Per-token
    latency is the gap between consecutive token emissions of one request
    (decode cadence); first-token latency is submission -> first token."""
    if warmup:
        _warm_engine(engine, requests)
    pending = sorted(requests, key=lambda r: (r.arrival_tick, r.req_id))
    i = 0
    t0 = time.perf_counter()
    for tick in range(max_ticks):
        while i < len(pending) and pending[i].arrival_tick <= tick:
            engine.submit(pending[i])
            i += 1
        did = engine.step()
        if not did and i >= len(pending):
            break
    else:
        raise RuntimeError(f"traffic did not drain in {max_ticks} ticks")
    wall_s = time.perf_counter() - t0

    gaps_ms: List[float] = []
    first_ms: List[float] = []
    tokens = 0
    for r in requests:
        assert r.done, f"request {r.req_id} not done: {r.state}"
        tokens += len(r.tokens)
        if r.token_times:
            first_ms.append((r.token_times[0] - r.submit_time) * 1e3)
            gaps_ms.extend(np.diff(r.token_times) * 1e3)
    lat = {}
    if gaps_ms:
        lat = {"p50_token_ms": float(np.percentile(gaps_ms, 50)),
               "p99_token_ms": float(np.percentile(gaps_ms, 99))}
    return {
        "wall_s": wall_s,
        "tokens": tokens,
        "tokens_per_s": tokens / max(wall_s, 1e-9),
        "requests": len(requests),
        "evictions": sum(r.evictions for r in requests),
        "first_token_p50_ms": float(np.percentile(first_ms, 50))
        if first_ms else 0.0,
        **lat,
    }


def solo_reference(cfg, mesh, params, requests: Sequence[Request],
                   max_len: int, *, offload_kv: bool = False,
                   q_chunk: int = 256) -> Tuple[Dict[int, List[int]], float]:
    """Sequential solo decodes on the pre-capture jit path: each request
    prefilled and greedily decoded alone at batch 1.  Returns the
    per-request token sequences (the bit-parity oracle) and the timed
    sequential wall seconds (compiles excluded via warm-up)."""
    prefill, decode, make_cache = build_server(
        cfg, mesh, 1, max_len, q_chunk=q_chunk, offload_kv=offload_kv)

    def one(req: Request) -> List[int]:
        batch = batch_for_prompt(cfg, req.prompt)
        logits, cache = prefill(params, batch, make_cache())
        tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
        if req.gen <= 1:
            return [int(np.asarray(tok)[0])]
        toks, _ = decode_stream(decode, params, tok, cache,
                                req.prompt_len, req.gen)
        return [int(np.asarray(t)[0]) for t in toks]

    # warm every (prompt-length, gen) executable pair off the clock: one
    # pass per distinct shape compiles prefill (per length) and decode
    # (once, on a prefill-output cache — a fresh init cache has different
    # sharding and would compile a second executable)
    seen = set()
    for req in requests:
        key = (req.prompt_len, req.gen > 1)
        if key not in seen:
            seen.add(key)
            one(req)

    t0 = time.perf_counter()
    out = {req.req_id: one(req) for req in requests}
    wall_s = time.perf_counter() - t0
    return out, wall_s


def assert_parity(requests: Sequence[Request],
                  oracle: Dict[int, List[int]]) -> None:
    """The bit-parity contract: every engine token sequence equals the
    solo jit decode of the same prompt, token for token."""
    for r in requests:
        np.testing.assert_array_equal(
            np.asarray(r.tokens), np.asarray(oracle[r.req_id]),
            err_msg=f"request {r.req_id} diverged from solo jit decode")
