"""Continuous-batching serving engine on the region-program spine.

Three layers (docs/SERVING.md):

* :mod:`repro.serve.paged_kv` — fixed-size KV pages drawn from a
  :class:`~repro.core.pool.DeviceBufferPool`, LRU host spill / eviction
  through the placement axis (paper C1 + C4).
* :mod:`repro.serve.scheduler` — slot-based request scheduler driving the
  captured PREFILL / DECODE_STEP / KV_APPEND regions, accounting every
  decision on the shared :class:`~repro.core.ledger.Ledger`.
* :mod:`repro.serve.traffic` — seeded synthetic traffic (Poisson arrivals,
  ragged lengths) plus the solo-jit parity oracle the engine is measured
  against (``fig_traffic`` in benchmarks/run.py).
"""
from repro.serve.paged_kv import PagedKVCache, PagedKVStats
from repro.serve.scheduler import Request, ServeEngine
from repro.serve.traffic import make_traffic, run_traffic, solo_reference

__all__ = ["PagedKVCache", "PagedKVStats", "Request", "ServeEngine",
           "make_traffic", "run_traffic", "solo_reference"]
