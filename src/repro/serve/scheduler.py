"""Slot-based continuous-batching scheduler on the captured region programs.

The engine runs ON the PR-5 serving spine, not beside it:

* admission prefills ride the captured ``PREFILL`` + ``KV_APPEND`` program
  (:func:`repro.launch.serve.capture_prefill_program`), one program per
  prompt-length bucket (length-bucketed admission — capture freezes
  shapes, so each distinct prompt length owns one captured program that
  every request of that length replays);
* the decode tick is ONE captured program per engine: ``DECODE_SLOTS`` —
  the ``DECODE_STEP`` region body (``impl_fn("ref")``) vmapped over the
  slot axis with a *per-slot position vector as a program input* (the
  static decode program freezes positions as constants; ragged requests
  need them live) — followed by the same ``KV_APPEND`` commit, where the
  policy's placement axis re-homes the appended pages (``--offload-kv``);
* ``SLOT_ADMIT`` scatters an admitted request's gathered cache into its
  slot row of the stacked slot cache — a region, so admission traffic is
  accounted like everything else.

The active-mask over slots is split between program and host: inside
``DECODE_SLOTS`` inactive slots keep their previous token (``jnp.where``
on the mask — the emitted value is exactly the solo value for active
slots), and the host-side scheduler commits results only for active slots.
Inactive slots still compute (the program is frozen-shape; that waste is
the occupancy story ``fig_traffic`` reports) and garbage-write their own
slot row, which the next ``SLOT_ADMIT`` fully overwrites — rows never
leak across the vmapped slot axis.

Per-request state machine: QUEUED -> PREFILL (prefilled, KV parked in the
:class:`~repro.serve.paged_kv.PagedKVCache`) -> DECODE (in a slot) ->
DONE, with EVICTED on the budget path (pages dropped, request re-queued
for a fresh prefill).  Every decision lands on the shared
:class:`~repro.core.ledger.Ledger` (``serve`` section of
``coverage_report()``).

Parity contract (asserted by tests and ``fig_traffic``): each request's
token sequence is bit-identical to a solo jit decode of the same prompt —
vmap over the slot axis is bit-stable on this backend (the same invariant
``replay_batch`` already asserts), placement never changes values, and
active slots pass through ``jnp.where(True, new, old)`` unchanged.
"""
from __future__ import annotations

import collections
import dataclasses
import time
from typing import Any, Deque, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.program import capture
from repro.core.regions import region
from repro.launch.serve import capture_prefill_program, make_serve_regions
from repro.models import transformer as T
from repro.serve.paged_kv import PagedKVCache

QUEUED = "QUEUED"
PREFILL = "PREFILL"
DECODE = "DECODE"
DONE = "DONE"
EVICTED = "EVICTED"

#: legal transitions of the per-request state machine
_TRANSITIONS = {
    QUEUED: (PREFILL, DONE),            # gen==1 finishes at prefill
    PREFILL: (DECODE, EVICTED),
    DECODE: (DONE,),
    EVICTED: (QUEUED,),                 # re-queued for a fresh prefill
    DONE: (),
}


@dataclasses.dataclass
class Request:
    """One sequence moving through the engine."""
    req_id: int
    prompt: np.ndarray                  # [prompt_len] int32 token ids
    gen: int                            # tokens to generate (incl. prefill's)
    arrival_tick: int = 0
    state: str = QUEUED
    tokens: List[int] = dataclasses.field(default_factory=list)
    token_times: List[float] = dataclasses.field(default_factory=list)
    submit_time: float = 0.0
    slot: Optional[int] = None
    pos: int = 0                        # next decode position
    evictions: int = 0
    history: List[str] = dataclasses.field(default_factory=lambda: [QUEUED])

    @property
    def prompt_len(self) -> int:
        return int(self.prompt.shape[0])

    @property
    def done(self) -> bool:
        return self.state == DONE


def batch_for_prompt(cfg, prompt: np.ndarray) -> dict:
    """Batch-1 prefill inputs for one prompt (mirrors the driver's
    ``_prefill_inputs`` for arbitrary single prompts)."""
    prompt_len = int(prompt.shape[0])
    batch = {"tokens": jnp.asarray(prompt, jnp.int32)[None]}
    if cfg.mrope_sections is not None:
        pos = jnp.arange(prompt_len, dtype=jnp.int32)[None, :, None]
        batch["positions3"] = jnp.broadcast_to(pos, (1, prompt_len, 3))
    if cfg.n_enc_layers:
        batch["enc_embeds"] = jnp.zeros(
            (1, cfg.enc_len, cfg.d_model), cfg.compute_dtype)
    return batch


class ServeEngine:
    """Continuous-batching engine: N decode slots over one captured tick
    program, paged-KV parking between prefill and admission (module
    docstring)."""

    def __init__(self, cfg, mesh, params, executor, *, max_len: int,
                 n_slots: int = 4, kv: Optional[PagedKVCache] = None,
                 prefill_per_tick: int = 1, q_chunk: int = 256):
        if n_slots < 1:
            raise ValueError("need at least one decode slot")
        self.cfg = cfg
        self.executor = executor
        self.ledger = executor.ledger
        self.max_len = max_len
        self.n_slots = n_slots
        self.prefill_per_tick = prefill_per_tick
        self.kv = kv if kv is not None else PagedKVCache()  # len()==0 is falsy
        self.ledger.attach_pool("kv_pages", self.kv.pool)
        self.regions = make_serve_regions(cfg, mesh, params,
                                          ledger=self.ledger, q_chunk=q_chunk)

        raw_decode = self.regions.decode_step.impl_fn("ref")

        @region("DECODE_SLOTS", ledger=self.ledger)
        def decode_slots(tok, cache, pos, active):
            # the DECODE_STEP body per slot: batch-1 decode, per-slot pos —
            # identical math to the solo path, batched over the slot axis
            new_tok, new_cache = jax.vmap(raw_decode)(tok, cache, pos)
            new_tok = jnp.where(active[:, None], new_tok, tok)
            return new_tok, new_cache

        @region("SLOT_ADMIT", ledger=self.ledger, offloaded=False)
        def slot_admit(slot_cache, req_cache, slot_idx):
            def scatter(sc, rc):
                starts = (slot_idx,) + (0,) * rc.ndim
                return jax.lax.dynamic_update_slice(sc, rc[None], starts)
            return jax.tree.map(scatter, slot_cache, req_cache)

        self._decode_slots = decode_slots
        self._slot_admit = slot_admit

        # slot state: stacked batch-1 caches [n_slots, 1, ...] plus
        # host-side token/position/active vectors (program inputs per tick)
        base = T.init_cache(cfg, 1, max_len)
        self.slot_cache = jax.tree.map(
            lambda x: jnp.stack([x] * n_slots), base)
        self._tok = np.zeros(n_slots, np.int32)
        self._pos = np.zeros(n_slots, np.int32)
        self._active = np.zeros(n_slots, bool)
        self.slot_req: List[Optional[Request]] = [None] * n_slots

        # ONE captured tick program; pos and the active mask are program
        # INPUTS (live per replay), unlike the static decode program's
        # frozen positions.  Capture runs the tick eagerly once — that is
        # the engine's compile warm-up; all-empty slots are numerically
        # inert (finite-NEG_INF masking) and their rows are overwritten
        # wholesale at admission.
        self.tick_prog = capture(
            self._tick_fn, jnp.asarray(self._tok[:, None]), self.slot_cache,
            jnp.asarray(self._pos), jnp.asarray(self._active),
            name="engine_tick")

        self._prefill_progs: Dict[int, Any] = {}
        self.queued: Deque[Request] = collections.deque()
        self.waiting: Deque[Request] = collections.deque()
        self.requests: Dict[int, Request] = {}
        self.ticks = 0

    def _tick_fn(self, run, tok, cache, pos, active):
        tok, cache = run(self._decode_slots, tok, cache, pos, active)
        cache = run(self.regions.kv_append, cache)
        return tok, cache

    # -- request intake ------------------------------------------------
    def submit(self, req: Request) -> Request:
        if req.req_id in self.requests:
            raise ValueError(f"duplicate req_id {req.req_id}")
        if req.prompt_len + req.gen > self.max_len:
            raise ValueError(
                f"request {req.req_id}: prompt {req.prompt_len} + gen "
                f"{req.gen} exceeds engine max_len {self.max_len}")
        req.submit_time = time.perf_counter()
        self.requests[req.req_id] = req
        self.queued.append(req)
        self.ledger.serve_record("submitted")
        return req

    # -- state machine -------------------------------------------------
    def _set_state(self, req: Request, state: str) -> None:
        if state not in _TRANSITIONS[req.state]:
            raise RuntimeError(f"request {req.req_id}: illegal transition "
                               f"{req.state} -> {state}")
        req.state = state
        req.history.append(state)

    # -- prefill (length-bucketed) --------------------------------------
    def _prefill_program(self, prompt_len: int, example_batch, example_cache):
        prog = self._prefill_progs.get(prompt_len)
        if prog is None:
            prog = capture_prefill_program(
                self.regions, example_batch, example_cache,
                name=f"prefill_L{prompt_len}")
            self._prefill_progs[prompt_len] = prog
        return prog

    def _prefill(self, req: Request) -> None:
        batch = batch_for_prompt(self.cfg, req.prompt)
        cache0 = T.init_cache(self.cfg, 1, self.max_len)
        prog = self._prefill_program(req.prompt_len, batch, cache0)
        tok, cache = prog.replay(self.executor, batch, cache0)
        req.tokens = [int(np.asarray(tok)[0])]
        req.token_times = [time.perf_counter()]
        req.pos = req.prompt_len
        self.ledger.serve_record("prefills")
        if req.gen <= 1:                    # finished at prefill: no slot
            self._set_state(req, DONE)
            self.ledger.serve_record("retired")
            return
        evicted = self.kv.commit(req.req_id, cache, true_len=req.prompt_len)
        self._set_state(req, PREFILL)
        self.waiting.append(req)
        for rid in evicted:
            self._evict(self.requests[rid])

    def _evict(self, req: Request) -> None:
        """Total-budget eviction: the parked prefill is lost — drop its
        tokens and re-queue for a fresh prefill (pages already freed)."""
        self.waiting.remove(req)
        req.evictions += 1
        req.tokens = []
        req.token_times = []
        self._set_state(req, EVICTED)
        self._set_state(req, QUEUED)
        self.queued.appendleft(req)         # it arrived first: keep order
        self.ledger.serve_record("evicted")

    # -- admission ------------------------------------------------------
    def _admit(self, req: Request, slot: int) -> None:
        cache = self.kv.gather(req.req_id)
        self.slot_cache = self.executor.run(
            self._slot_admit, self.slot_cache, cache, jnp.int32(slot))
        self._tok[slot] = req.tokens[-1]
        self._pos[slot] = req.pos
        self._active[slot] = True
        self.slot_req[slot] = req
        req.slot = slot
        self._set_state(req, DECODE)
        self.ledger.serve_record("admitted")

    # -- decode tick ----------------------------------------------------
    def _decode_tick(self) -> None:
        n_active = int(self._active.sum())
        tok, cache = self.tick_prog.replay(
            self.executor, jnp.asarray(self._tok[:, None]), self.slot_cache,
            jnp.asarray(self._pos), jnp.asarray(self._active))
        self.slot_cache = cache
        tok_np = np.asarray(tok)
        now = time.perf_counter()
        for s in np.nonzero(self._active)[0]:
            req = self.slot_req[s]
            t = int(tok_np[s, 0])
            req.tokens.append(t)
            req.token_times.append(now)
            req.pos += 1
            self._tok[s] = t
            self._pos[s] = req.pos
            if len(req.tokens) >= req.gen:
                self._retire(req, int(s))
        self.ticks += 1
        self.ledger.serve_record("ticks")
        self.ledger.serve_record("decode_tokens", n_active)
        self.ledger.serve_record("active_slot_ticks", n_active)

    def _retire(self, req: Request, slot: int) -> None:
        self._active[slot] = False
        self.slot_req[slot] = None
        req.slot = None
        self._set_state(req, DONE)
        self.ledger.serve_record("retired")

    # -- the engine step ------------------------------------------------
    def step(self) -> bool:
        """One engine tick: prefill-interleave, admit, decode.  Returns
        whether any work was done (False = fully drained)."""
        did = False
        # prefill interleaving, throttled: parking more than a full slot
        # complement ahead just grows the paged store (and, under a total
        # budget, thrashes it)
        for _ in range(self.prefill_per_tick):
            if not self.queued or len(self.waiting) >= self.n_slots:
                break
            self._prefill(self.queued.popleft())
            did = True
        while self.waiting and not self._active.all():
            slot = int(np.nonzero(~self._active)[0][0])
            self._admit(self.waiting.popleft(), slot)
            did = True
        if self._active.any():
            self._decode_tick()
            did = True
        self._push_gauges()
        return did

    def drain(self, max_ticks: int = 100_000) -> None:
        """Step until every submitted request is DONE."""
        for _ in range(max_ticks):
            if not self.step():
                return
        raise RuntimeError(f"engine did not drain in {max_ticks} ticks")

    def _push_gauges(self) -> None:
        led = self.ledger
        counters = led.serve_counters
        if counters.get("ticks"):
            # peak running occupancy: active slot-ticks per slot capacity
            led.serve_gauge("slot_occupancy",
                            counters.get("active_slot_ticks", 0)
                            / (counters["ticks"] * self.n_slots))
        st = self.kv.stats
        led.serve_gauge("kv_device_page_high_water_bytes",
                        st.device_high_water_bytes)
        led.serve_gauge("kv_total_page_high_water_bytes",
                        st.total_high_water_bytes)
        led.serve_gauge("kv_slot_cache_bytes", sum(
            int(x.nbytes) for x in jax.tree.leaves(self.slot_cache)))
        budget = getattr(self.kv, "budget", None)
        if budget is not None:
            # oversubscription gauges: how hard the logical device budget
            # was pressed and how much the LRU spill path had to shed
            led.serve_gauge("kv_budget_limit_bytes",
                            budget.limit_bytes or 0)
            led.serve_gauge("kv_budget_high_water_bytes",
                            budget.stats.high_water_bytes)
            led.serve_gauge("kv_budget_pressure_events",
                            budget.stats.pressure_events)
