"""Paged KV cache — the serving engine's parking store (paper C1 + C4).

Between PREFILL and slot admission a request's KV cache is *paged*: the
``k``/``v``-keyed leaves (the :data:`~repro.launch.serve.KV_PLACE_KEYS`
role keying of :class:`~repro.launch.serve.KVCachePlacer`) are split along
the token axis into fixed-size pages copied into pooled buffers from a
:class:`~repro.core.pool.DeviceBufferPool`; everything else (slot
positions, recurrent state) rides along as a dense residual tree.  Pages
recycle through the pool's free-list (paper C4: Umpire-style reuse instead
of alloc/free churn), and two budgets bound the store:

* ``device_budget_bytes`` — when device-resident page bytes exceed it, the
  least-recently-used entry's pages *spill* to host DRAM through the
  placement axis (:func:`~repro.core.umem.place` into
  ``preferred_host_space()``), so the cache can exceed device memory —
  the paper's incremental-offload pattern applied to serving.  Spilled
  pages are fetched back through the same axis at admission; placement
  never changes values, so parity survives oversubscription.
* ``total_budget_bytes`` — when even host spill cannot hold the store,
  whole LRU entries are *evicted* (pages freed, the scheduler re-queues
  the request for a fresh prefill).

On the CPU container every space is ``unpinned_host`` (see docs/DESIGN.md
§2): ``place`` degrades to a no-op data move and residency is tracked
logically — the claim structure (budget-bounded device high-water, spill
counts, bit-parity across the spill) is what the tests and ``fig_traffic``
assert, exactly as the rest of the repo treats placement on CPU.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.pool import DeviceBufferPool
from repro.core.umem import MemSpace, place, preferred_host_space
from repro.launch.serve import KV_PLACE_KEYS

DEFAULT_PAGE_TOKENS = 8


@functools.partial(jax.jit, donate_argnums=(1,))
def _copy_into(src, dst):
    """Donating full overwrite: the result owns ``dst``'s (pooled) storage
    and carries ``src``'s values — how jax 'reuses' an immutable buffer."""
    return jnp.where(True, src, dst)


def _leaf_role(path) -> Optional[str]:
    """The KV role of a tree path (``"k"``/``"v"``) or None — the same
    role keying :func:`repro.launch.serve.place_kv_leaves` uses."""
    for p in path:
        key = getattr(p, "key", None)
        if key in KV_PLACE_KEYS:
            return key
    return None


def _token_axis(path) -> int:
    """Token axis of a k/v leaf: cache_specs stacks repeated cycle layers
    (leaves under a ``cycles`` key gain a leading layer axis, [L, B, S,
    ...]) while ``rest*`` layers stay per-layer ([B, S, ...])."""
    for p in path:
        if getattr(p, "key", None) == "cycles":
            return 2
    return 1


@dataclasses.dataclass
class PagedKVStats:
    pages_committed: int = 0
    pages_released: int = 0
    pages_spilled: int = 0          # device -> host placement-axis moves
    pages_fetched: int = 0          # host -> device, paid at admission
    evictions: int = 0              # whole entries dropped (total budget)
    device_bytes: int = 0           # page bytes logically device-resident
    host_bytes: int = 0             # page bytes logically host-resident
    device_high_water_bytes: int = 0
    total_high_water_bytes: int = 0
    role_pages: Dict[str, int] = dataclasses.field(default_factory=dict)

    def as_dict(self) -> dict:
        return dataclasses.asdict(self)


@dataclasses.dataclass
class _Entry:
    """One parked request: paged k/v leaves + dense residual leaves, in
    tree-flatten order so ``treedef.unflatten`` reconstructs the cache."""
    req_id: int
    treedef: object
    leaves: List[Tuple]             # ("page", pages, shape, valid, axis) | ("dense", leaf)
    page_bytes: int
    last_touch: int
    on_host: bool = False


class PagedKVCache:
    """Fixed-size KV pages over a :class:`DeviceBufferPool` free-list with
    LRU host spill and whole-entry eviction (module docstring)."""

    def __init__(self, page_tokens: int = DEFAULT_PAGE_TOKENS,
                 pool: Optional[DeviceBufferPool] = None,
                 device_budget_bytes: Optional[int] = None,
                 total_budget_bytes: Optional[int] = None,
                 host_space: Optional[MemSpace] = None,
                 budget=None):
        if page_tokens < 1:
            raise ValueError("page_tokens must be >= 1")
        self.page_tokens = page_tokens
        # min_elems=0: every page pools — smoke-scale pages are far below
        # the paper's 5K-element threshold, and the free-list IS the point
        self.pool = pool if pool is not None else DeviceBufferPool(min_elems=0)
        self.device_budget_bytes = device_budget_bytes
        self.total_budget_bytes = total_budget_bytes
        self.host_space = host_space or preferred_host_space()
        # a MemoryBudget (repro.core.oversub) is the oversubscription form
        # of device_budget_bytes: its limit caps device-resident page bytes
        # (tightest of the two wins) and the store mirrors its device-byte
        # deltas into it, so one budget instance can span the KV store and
        # other device consumers.  Don't ALSO hand the same budget to
        # self.pool — that would double-charge every page.
        self.budget = budget
        self.stats = PagedKVStats()
        self._entries: Dict[int, _Entry] = {}
        self._clock = 0

    def _device_limit(self) -> Optional[int]:
        lims = [b for b in (self.device_budget_bytes,
                            getattr(self.budget, "limit_bytes", None))
                if b is not None]
        return min(lims) if lims else None

    def _device_delta(self, nbytes: int) -> None:
        """Mirror a device-resident byte change into the attached budget
        (charge on +, release on −); pressure events mark the window
        between a commit landing over the limit and the LRU spill that
        sheds it."""
        if self.budget is None or nbytes == 0:
            return
        if nbytes > 0:
            self.budget.charge(nbytes)
        else:
            self.budget.release(-nbytes)

    # -- bookkeeping ---------------------------------------------------
    def __len__(self) -> int:
        return len(self._entries)

    def __contains__(self, req_id: int) -> bool:
        return req_id in self._entries

    @property
    def total_bytes(self) -> int:
        return self.stats.device_bytes + self.stats.host_bytes

    def touch(self, req_id: int) -> None:
        e = self._entries.get(req_id)
        if e is not None:
            self._clock += 1
            e.last_touch = self._clock

    def _lru(self, *, exclude: Optional[int] = None,
             on_host: Optional[bool] = None) -> Optional[_Entry]:
        best = None
        for e in self._entries.values():
            if e.req_id == exclude:
                continue
            if on_host is not None and e.on_host != on_host:
                continue
            if best is None or e.last_touch < best.last_touch:
                best = e
        return best

    def _water_marks(self) -> None:
        s = self.stats
        s.device_high_water_bytes = max(s.device_high_water_bytes,
                                        s.device_bytes)
        s.total_high_water_bytes = max(s.total_high_water_bytes,
                                       s.device_bytes + s.host_bytes)

    # -- commit: cache tree -> pages -----------------------------------
    def _page_leaf(self, leaf, true_len: int, axis: int):
        """Split one k/v leaf along its token axis into fixed-size pooled
        pages covering ``min(true_len, S)`` tokens (the ring-slot clamp: a
        local-attention cache has S = window slots); the untouched tail is
        zeros by construction (init_cache) and is re-padded exactly at
        gather."""
        S = leaf.shape[axis]
        valid = min(max(int(true_len), 1), S)
        pt = self.page_tokens
        n_pages = -(-valid // pt)
        page_shape = leaf.shape[:axis] + (pt,) + leaf.shape[axis + 1:]
        pages = []
        for p in range(n_pages):
            chunk = jax.lax.slice_in_dim(leaf, p * pt,
                                         min((p + 1) * pt, S), axis=axis)
            if chunk.shape[axis] < pt:
                pad = [(0, 0)] * leaf.ndim
                pad[axis] = (0, pt - chunk.shape[axis])
                chunk = jnp.pad(chunk, pad)
            buf = self.pool.acquire(page_shape, leaf.dtype)
            pages.append(_copy_into(chunk, buf))
        return pages, leaf.shape, valid

    def commit(self, req_id: int, cache, true_len: int) -> List[int]:
        """Park a prefilled cache: page the k/v leaves, keep the rest
        dense.  Returns the req_ids of any entries the total budget forced
        out (the scheduler re-queues them as EVICTED)."""
        if req_id in self._entries:
            raise ValueError(f"request {req_id} already committed")
        flat, treedef = jax.tree_util.tree_flatten_with_path(cache)
        leaves: List[Tuple] = []
        page_bytes = 0
        n_pages = 0
        for path, leaf in flat:
            role = _leaf_role(path)
            axis = _token_axis(path)
            if role is not None and getattr(leaf, "ndim", 0) > axis:
                pages, shape, valid = self._page_leaf(leaf, true_len, axis)
                leaves.append(("page", pages, shape, valid, axis))
                page_bytes += sum(int(p.nbytes) for p in pages)
                n_pages += len(pages)
                self.stats.role_pages[role] = \
                    self.stats.role_pages.get(role, 0) + len(pages)
            else:
                leaves.append(("dense", leaf))
        self._clock += 1
        self._entries[req_id] = _Entry(req_id=req_id, treedef=treedef,
                                       leaves=leaves, page_bytes=page_bytes,
                                       last_touch=self._clock)
        self.stats.pages_committed += n_pages
        self.stats.device_bytes += page_bytes
        self._device_delta(page_bytes)
        self._water_marks()
        self._spill_to_budget()
        return self._evict_to_budget(newest=req_id)

    # -- budgets: LRU spill, then LRU eviction -------------------------
    def _spill_entry(self, e: _Entry) -> None:
        if self.host_space is None or e.on_host:
            return
        n = 0
        for i, rec in enumerate(e.leaves):
            if rec[0] == "page":
                _, pages, shape, valid, axis = rec
                pages = [place(p, self.host_space) for p in pages]
                e.leaves[i] = ("page", pages, shape, valid, axis)
                n += len(pages)
        e.on_host = True
        self.stats.pages_spilled += n
        self.stats.device_bytes -= e.page_bytes
        self.stats.host_bytes += e.page_bytes
        self._device_delta(-e.page_bytes)
        self._water_marks()

    def _spill_to_budget(self) -> None:
        limit = self._device_limit()
        if limit is None or self.host_space is None:
            return
        while self.stats.device_bytes > limit:
            victim = self._lru(on_host=False)
            if victim is None:
                break
            self._spill_entry(victim)

    def _evict_to_budget(self, newest: int) -> List[int]:
        evicted: List[int] = []
        if self.total_budget_bytes is None:
            return evicted
        while self.total_bytes > self.total_budget_bytes \
                and len(self._entries) > 1:
            victim = self._lru(exclude=newest)
            if victim is None:
                break
            self.free(victim.req_id)
            self.stats.evictions += 1
            evicted.append(victim.req_id)
        return evicted

    # -- gather: pages -> cache tree (admission) -----------------------
    def gather(self, req_id: int):
        """Reassemble and remove a parked cache.  Spilled pages pay the
        host->device crossing here (placement axis); page buffers return
        to the pool free-list for the next commit."""
        e = self._entries.pop(req_id)
        if e.on_host:
            self.stats.host_bytes -= e.page_bytes
        else:
            self.stats.device_bytes -= e.page_bytes
            self._device_delta(-e.page_bytes)
        out = []
        for rec in e.leaves:
            if rec[0] == "dense":
                out.append(rec[1])
                continue
            _, pages, shape, valid, axis = rec
            if e.on_host:
                pages = [place(p, MemSpace.DEVICE) for p in pages]
                self.stats.pages_fetched += len(pages)
            full = jax.lax.slice_in_dim(jnp.concatenate(pages, axis=axis),
                                        0, valid, axis=axis)
            S = shape[axis]
            if valid < S:
                pad = [(0, 0)] * len(shape)
                pad[axis] = (0, S - valid)
                full = jnp.pad(full, pad)
            out.append(full)
            for p in pages:
                self.pool.release(p)
            self.stats.pages_released += len(pages)
        return jax.tree_util.tree_unflatten(e.treedef, out)

    def free(self, req_id: int) -> None:
        """Drop a parked cache without gathering (eviction, abort)."""
        e = self._entries.pop(req_id, None)
        if e is None:
            return
        if e.on_host:
            self.stats.host_bytes -= e.page_bytes
        else:
            self.stats.device_bytes -= e.page_bytes
            self._device_delta(-e.page_bytes)
        for rec in e.leaves:
            if rec[0] == "page":
                for p in rec[1]:
                    self.pool.release(p)
                self.stats.pages_released += len(rec[1])
