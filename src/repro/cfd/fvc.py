"""Explicit finite-volume operators (fvc::) — gradients and divergence."""
from __future__ import annotations

import jax.numpy as jnp

from repro.cfd.grid import Grid, NEIGHBORS, interior_mask, shift


def grad(grid: Grid, p):
    """Cell-centered gradient, central differences, one-sided at walls."""
    out = []
    for ax in range(3):
        h = grid.h[ax]
        m_lo = interior_mask(grid, ax, -1)
        m_hi = interior_mask(grid, ax, +1)
        lo = shift(p, ax, -1)
        hi = shift(p, ax, +1)
        both = (m_lo * m_hi) > 0
        # central where both neighbors exist; one-sided at boundaries
        g = jnp.where(both, (hi - lo) / (2 * h),
                      jnp.where(m_lo > 0, (p - lo) / h,
                                jnp.where(m_hi > 0, (hi - p) / h, 0.0)))
        out.append(g)
    return out


def div_flux(grid: Grid, phi_faces):
    """div of face fluxes (sum of signed fluxes / volume)."""
    return jnp.sum(phi_faces, axis=0) / grid.vol
