"""Structured 3-D finite-volume grid (the computational substrate of the
OpenFOAM case study).

OpenFOAM's HPC_motorbike mesh is unstructured; the paper's systems claims
(directive-per-loop offload, unified memory, pooling) are insensitive to
mesh topology — what costs is cells x iterations x solver structure. We use
a structured grid so the LDU operator re-lays into DIA form (7 shifted
diagonals), which is the TPU-native formulation (no gathers; pure VPU
shifted FMAs). See docs/DESIGN.md §2.
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class Grid:
    shape: Tuple[int, int, int]          # (nx, ny, nz) cells
    lengths: Tuple[float, float, float] = (1.0, 1.0, 1.0)

    @property
    def n(self) -> int:
        return int(np.prod(self.shape))

    @property
    def h(self) -> Tuple[float, float, float]:
        return tuple(L / s for L, s in zip(self.lengths, self.shape))

    @property
    def vol(self) -> float:
        hx, hy, hz = self.h
        return hx * hy * hz

    def zeros(self):
        return jnp.zeros(self.shape, jnp.float32)

    def field(self, fill: float = 0.0):
        return jnp.full(self.shape, fill, jnp.float32)

    def red_black_masks(self):
        """Two-coloring of the 7-point stencil (for the two-color DILU)."""
        nx, ny, nz = self.shape
        i, j, k = jnp.meshgrid(jnp.arange(nx), jnp.arange(ny), jnp.arange(nz),
                               indexing="ij")
        red = ((i + j + k) % 2 == 0)
        return red, ~red


# face-neighbor shift table: axis, direction
NEIGHBORS = (
    (0, -1), (0, +1),   # -x, +x
    (1, -1), (1, +1),   # -y, +y
    (2, -1), (2, +1),   # -z, +z
)


def shift(f, axis: int, direction: int):
    """Neighbor value with zero padding outside the domain.
    shift(f, 0, -1)[i] == f[i-1] (the -x neighbor)."""
    n = f.shape[axis]
    pad = [(0, 0)] * f.ndim
    if direction < 0:
        pad[axis] = (1, 0)
        sl = [slice(None)] * f.ndim
        sl[axis] = slice(0, n)
        return jnp.pad(f, pad)[tuple(sl)]
    pad[axis] = (0, 1)
    sl = [slice(None)] * f.ndim
    sl[axis] = slice(1, n + 1)
    return jnp.pad(f, pad)[tuple(sl)]


def interior_mask(grid: Grid, axis: int, direction: int):
    """1.0 where the neighbor in (axis, direction) exists."""
    nx, ny, nz = grid.shape
    m = np.ones(grid.shape, np.float32)
    sl = [slice(None)] * 3
    sl[axis] = 0 if direction < 0 else grid.shape[axis] - 1
    m[tuple(sl)] = 0.0
    return jnp.asarray(m)
