"""Implicit finite-volume operators (fvm::) — build DiaMatrix systems.

Mirrors the OpenFOAM operators used by simpleFoam (paper listing 3):
``fvm.laplacian(gamma, ...)`` (momentum diffusion, pressure Poisson) and
``fvm.div(phi, ...)`` (first-order upwind convection). Uniform grid,
per-unit-volume scaling; Dirichlet or zero-gradient (Neumann) boundaries.
"""
from __future__ import annotations

from typing import Sequence, Union

import jax.numpy as jnp

from repro.cfd.dia import DiaMatrix
from repro.cfd.grid import Grid, NEIGHBORS, interior_mask, shift

Scalar = Union[float, jnp.ndarray]


def laplacian(grid: Grid, gamma: Scalar, *, dirichlet: Sequence[bool] = None):
    """Matrix for  -gamma * laplace(x)  (positive-definite form).

    dirichlet[axis*2+dir]: True -> wall value enters the rhs via bc_rhs;
    False -> zero-gradient (no face flux).
    Returns (A, bc_coeff [6,...]) where bc_coeff[f] * wall_value adds to rhs.
    """
    h = grid.h
    diag = jnp.zeros(grid.shape, jnp.float32)
    offs = []
    bcs = []
    dirichlet = dirichlet if dirichlet is not None else [True] * 6
    for f, (ax, d) in enumerate(NEIGHBORS):
        coef = gamma / (h[ax] * h[ax])
        mask = interior_mask(grid, ax, d)
        off = -coef * mask
        diag = diag + coef * mask
        boundary = 1.0 - mask
        if dirichlet[f]:
            # ghost value = 2*wall - cell  =>  diag += 2c, rhs += 2c*wall
            diag = diag + 2.0 * coef * boundary
            bcs.append(2.0 * coef * boundary)
        else:
            bcs.append(jnp.zeros(grid.shape, jnp.float32))
        offs.append(off)
    return DiaMatrix(diag, jnp.stack(offs)), jnp.stack(bcs)


def div_upwind(grid: Grid, phi_faces):
    """Matrix for  div(phi, x)  with first-order upwind.

    phi_faces[f] = volumetric flux across face f (positive = outflow),
    shape [6, nx,ny,nz] per cell-face. Off-diagonal pulls from the upwind
    neighbor when flow enters the cell; diagonal collects outflow.
    """
    diag = jnp.zeros(grid.shape, jnp.float32)
    offs = []
    for f, (ax, d) in enumerate(NEIGHBORS):
        mask = interior_mask(grid, ax, d)
        out = jnp.maximum(phi_faces[f], 0.0)      # leaving through face f
        inn = jnp.minimum(phi_faces[f], 0.0)      # entering (neighbor upwind)
        diag = diag + out / grid.vol
        offs.append(inn * mask / grid.vol)
    return DiaMatrix(diag, jnp.stack(offs))


def face_fluxes(grid: Grid, u, v, w):
    """Volumetric face fluxes from cell-centered velocity (linear interp).
    Returns [6, nx,ny,nz]; sign convention: positive = out of the cell."""
    h = grid.h
    areas = (h[1] * h[2], h[1] * h[2], h[0] * h[2], h[0] * h[2],
             h[0] * h[1], h[0] * h[1])
    comps = (u, u, v, v, w, w)
    fluxes = []
    for f, (ax, d) in enumerate(NEIGHBORS):
        c = comps[f]
        mask = interior_mask(grid, ax, d)
        face_vel = 0.5 * (c + shift(c, ax, d)) * mask
        sign = -1.0 if d < 0 else 1.0
        fluxes.append(sign * face_vel * areas[f])
    return jnp.stack(fluxes)


def add_diag(A: DiaMatrix, s) -> DiaMatrix:
    return DiaMatrix(A.diag + s, A.off)


def relax(A: DiaMatrix, x, b, alpha: float):
    """OpenFOAM-style implicit under-relaxation: diag /= alpha and
    rhs += (1-alpha)/alpha * diag * x_old."""
    new_diag = A.diag / alpha
    new_b = b + (new_diag - A.diag) * x
    return DiaMatrix(new_diag, A.off), new_b
