"""simpleFoam — the SIMPLE pressure-velocity corrector (paper listing 3).

Steady, incompressible, laminar lid-driven cavity (the geometry stand-in
for HPC_motorbike — see docs/DESIGN.md §3). One time-step executes the stages of
listing 3, each built from region-decorated pieces so all three executors
can replay it:

  1. momentum predictor:  solve(UEqn == -grad(p))         (PBiCGStab+DILU)
  2. pressure corrector:  laplacian(rAU, p') == div(HbyA) (PBiCGStab+DILU)
  3. momentum corrector:  U = HbyA - rAU*grad(p')         (field macros)

The FOM is average seconds per time-step over the run, exactly the paper's
figure of merit.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Optional

import jax
import jax.numpy as jnp

from repro.cfd import fvc, fvm
from repro.cfd.dia import DiaMatrix, STENCIL_OFFSETS, amul_ref
from repro.cfd.fields import make_field_ops
from repro.cfd.grid import Grid
from repro.cfd.precond import rb_dilu_factor
from repro.cfd.solvers import (make_solver_regions, pbicgstab_fused,
                               pbicgstab_regions)
from repro.core.ledger import Ledger
from repro.core.regions import Executor, UnifiedPolicy, region


@dataclasses.dataclass
class SimpleConfig:
    grid: Grid
    nu: float = 0.01                  # kinematic viscosity (Re = U*L/nu)
    lid_velocity: float = 1.0
    alpha_u: float = 0.7              # momentum under-relaxation
    alpha_p: float = 0.3              # pressure under-relaxation
    tol_u: float = 1e-5
    tol_p: float = 1e-6
    inner_max: int = 50
    n_correctors: int = 1


@dataclasses.dataclass
class SimpleState:
    u: jax.Array
    v: jax.Array
    w: jax.Array
    p: jax.Array
    step: int = 0


def init_state(cfg: SimpleConfig) -> SimpleState:
    g = cfg.grid
    return SimpleState(g.zeros(), g.zeros(), g.zeros(), g.zeros())


class SimpleFoam:
    """Region-program version of the solver, replayable by any executor."""

    def __init__(self, cfg: SimpleConfig, executor: Optional[Executor] = None,
                 assemble_on_host: bool = False):
        """assemble_on_host=True reproduces the PETSc-interface mode of
        Fig 2: matrix assembly regions stay on the host; only solver kernels
        are offloaded."""
        self.cfg = cfg
        self.ledger = Ledger("simpleFoam")
        self.ex = executor or Executor(UnifiedPolicy(), self.ledger)
        self.ex.ledger = self.ledger
        self.ops = make_field_ops(self.ledger)
        self.solver_regions = make_solver_regions(self.ledger)
        self.red, self.black = cfg.grid.red_black_masks()
        asm = dict(ledger=self.ledger)

        # stencil/halo declarations drive the multi-APU replay
        # (repro.core.shard_program): face interpolation and gradients
        # reach one neighbor along each grid axis
        @region("assemble(momentum)", offloaded=not assemble_on_host,
                        stencil=STENCIL_OFFSETS,
                        halo_args=("u", "v", "w", "p"), **asm)
        def assemble_momentum(u, v, w, p):
            g = cfg.grid
            phi = fvm.face_fluxes(g, u, v, w)
            conv = fvm.div_upwind(g, phi)
            diff, bc = fvm.laplacian(g, cfg.nu, dirichlet=[True] * 6)
            A = DiaMatrix(conv.diag + diff.diag, conv.off + diff.off)
            gp = fvc.grad(g, p)
            # lid (+y face, f=3) drives u with wall value = lid_velocity
            rhs_u = -gp[0] + bc[3] * cfg.lid_velocity
            rhs_v = -gp[1]
            rhs_w = -gp[2]
            Au, ru = fvm.relax(A, u, rhs_u, cfg.alpha_u)
            Av, rv = fvm.relax(A, v, rhs_v, cfg.alpha_u)
            Aw, rw = fvm.relax(A, w, rhs_w, cfg.alpha_u)
            return (Au.diag, Au.off, ru, Av.diag, rv, Aw.diag, rw)

        @region("assemble(pressure)", offloaded=not assemble_on_host,
                        stencil=STENCIL_OFFSETS,
                        halo_args=("u_s", "v_s", "w_s"), **asm)
        def assemble_pressure(rAU, u_s, v_s, w_s):
            g = cfg.grid
            # laplacian(rAU, p) with zero-gradient walls (singular -> pinned)
            Ap, _ = fvm.laplacian(g, 1.0, dirichlet=[False] * 6)
            Ap = DiaMatrix(Ap.diag * rAU, Ap.off * rAU[None])
            phi_s = fvm.face_fluxes(g, u_s, v_s, w_s)
            div_hbya = fvc.div_flux(g, phi_s)
            # pin reference cell (pEqn.setReference)
            pin = jnp.zeros_like(rAU).at[0, 0, 0].set(1.0)
            diag = jnp.where(pin > 0, 1.0, Ap.diag)
            off = Ap.off * (1.0 - pin)[None]
            # Ap == -div(rAU grad .)  =>  Ap p' = -div(HbyA)
            rhs = jnp.where(pin > 0, 0.0, -div_hbya)
            return (diag, off, rhs)

        @region("DILU factor", stencil=STENCIL_OFFSETS,
                halo_args=("diag", "off"), **asm)
        def factor(diag, off):
            P = rb_dilu_factor(DiaMatrix(diag, off), self.red)
            return P.rdiag

        @region("momentum corrector", **asm)
        def correct_u(hb_u, hb_v, hb_w, rAU, gpx, gpy, gpz):
            # U = HbyA - rAU*grad(p)   (listing 3 line 32 == listing 4 macro)
            return (hb_u - rAU * gpx, hb_v - rAU * gpy, hb_w - rAU * gpz)

        @region("grad(p)", stencil=STENCIL_OFFSETS, halo_args=("p",), **asm)
        def grad_p(p):
            return tuple(fvc.grad(cfg.grid, p))

        @region("rAU=1/A", **asm)
        def recip_diag(diag):
            # region (not host glue) so program capture sees the dependency
            return 1.0 / diag

        @region("p relax", **asm)
        def relax_p(p, dp):
            # dp is the pressure CORRECTION from the Poisson solve
            return p + cfg.alpha_p * dp

        self.assemble_momentum = assemble_momentum
        self.assemble_pressure = assemble_pressure
        self.factor = factor
        self.recip_diag = recip_diag
        self.correct_u = correct_u
        self.grad_p = grad_p
        self.relax_p = relax_p

    # ------------------------------------------------------------------
    def time_step(self, st: SimpleState, executor=None) -> tuple:
        """One SIMPLE iteration.  ``executor`` overrides ``self.ex`` for this
        call only — program capture passes a recording executor here."""
        cfg, ex = self.cfg, executor if executor is not None else self.ex
        run = ex.run
        # --- momentum predictor -------------------------------------
        du, off, ru, dv, rv, dw, rw = run(self.assemble_momentum,
                                          st.u, st.v, st.w, st.p)
        rdiag_m = run(self.factor, du, off)
        from repro.cfd.precond import RBDilu
        Pm = RBDilu(rdiag_m, self.red)
        Au = DiaMatrix(du, off)
        res_u = pbicgstab_regions(ex, self.solver_regions, Au, ru, st.u, Pm,
                                  tol=cfg.tol_u, max_iter=cfg.inner_max)
        res_v = pbicgstab_regions(ex, self.solver_regions, DiaMatrix(dv, off),
                                  rv, st.v, Pm, tol=cfg.tol_u,
                                  max_iter=cfg.inner_max)
        res_w = pbicgstab_regions(ex, self.solver_regions, DiaMatrix(dw, off),
                                  rw, st.w, Pm, tol=cfg.tol_u,
                                  max_iter=cfg.inner_max)
        u_s, v_s, w_s = res_u.x, res_v.x, res_w.x
        rAU = run(self.recip_diag, du)
        # --- pressure corrector (solves for the correction p') -------
        p = st.p
        for _ in range(self.cfg.n_correctors):
            dp, offp, rp = run(self.assemble_pressure, rAU, u_s, v_s, w_s)
            rdiag_p = run(self.factor, dp, offp)
            Pp = RBDilu(rdiag_p, self.red)
            res_p = pbicgstab_regions(ex, self.solver_regions,
                                      DiaMatrix(dp, offp), rp,
                                      jnp.zeros_like(rp), Pp,
                                      tol=cfg.tol_p, max_iter=cfg.inner_max)
            p_corr = res_p.x
            # --- momentum corrector ----------------------------------
            gpx, gpy, gpz = run(self.grad_p, p_corr)
            u_s, v_s, w_s = run(self.correct_u, u_s, v_s, w_s, rAU,
                                gpx, gpy, gpz)
            p = run(self.relax_p, p, p_corr)
        new = SimpleState(u_s, v_s, w_s, p, st.step + 1)
        metrics = {
            "res_u": res_u.final_residual, "iters_u": res_u.iters,
            "res_p": res_p.final_residual, "iters_p": res_p.iters,
        }
        return new, metrics

    def run_steps(self, st: SimpleState, n: int) -> tuple:
        """Returns (state, fom_seconds_per_step, metrics_last)."""
        t0 = time.perf_counter()
        m = {}
        for _ in range(n):
            st, m = self.time_step(st)
        fom = (time.perf_counter() - t0) / n
        return st, fom, m

    # -- captured-program path (repro.core.program) --------------------
    def capture_step(self, st: SimpleState):
        """Record one SIMPLE time-step as a :class:`RegionProgram`.

        The step executes eagerly during capture (inner solver loops run to
        their real convergence on ``st``), and the resulting trace — with
        iteration counts and host-extracted residual scalars frozen,
        CUDA-graph style — can be replayed under any policy, overlapped by
        ``AsyncExecutor``, or vmapped over N cavities by ``replay_batch``.
        """
        from repro.core.program import capture

        class _Rec:                   # quacks like an Executor for time_step
            def __init__(self, run):
                self.run = run

        def step_fn(run, u, v, w, p):
            new, _ = self.time_step(SimpleState(u, v, w, p, st.step),
                                    executor=_Rec(run))
            return (new.u, new.v, new.w, new.p)

        return capture(step_fn, st.u, st.v, st.w, st.p, name="simple_step")

    def replay_steps(self, prog, st: SimpleState, n: int, executor,
                     mesh=None, **shard_opts) -> tuple:
        """Replay a captured step ``n`` times, chaining the state through.
        Returns (state, fom_seconds_per_step).

        ``mesh`` (an APU mesh from ``repro.launch.mesh.make_apu_mesh`` —
        1-D, or 2-D/3-D for lower surface-to-volume) domain-decomposes the
        replay across simulated APUs: ``executor``'s policy is rebound
        into a :class:`~repro.core.shard_program.ShardExecutor` and fields
        shard along the trailing grid ax(es) with halo exchange scheduled
        at every stencil region.  ``shard_opts`` forward to
        ``ShardExecutor`` (``halo_multiplier``, ``overlap``,
        ``split_stencil``, ... — docs/SCALING.md).  This convenience path
        builds (and discards) the shard executor internally — nothing
        lands on the passed executor's ledger; pass a pre-built
        ``ShardExecutor``/``ShardedProgram`` as ``executor`` instead when
        you need the per-device ledgers afterwards (that is what
        ``repro.launch.scaling`` does)."""
        if mesh is not None:
            from repro.core.shard_program import (ShardedProgram,
                                                  ShardExecutor)
            if not hasattr(executor, "replay_program"):
                executor = ShardExecutor(
                    getattr(executor, "policy", None), mesh, **shard_opts)
            elif not isinstance(executor, (ShardExecutor, ShardedProgram)):
                # an AsyncExecutor etc. would silently replay single-device
                raise ValueError(
                    f"mesh= cannot rebind {type(executor).__name__}; pass "
                    "a plain Executor (or a ShardExecutor built on the "
                    "mesh) instead")
        t0 = time.perf_counter()
        for _ in range(n):
            u, v, w, p = prog.replay(executor, st.u, st.v, st.w, st.p)
            st = SimpleState(u, v, w, p, st.step + 1)
        return st, (time.perf_counter() - t0) / n
