"""Preconditioners: Jacobi and two-color (red-black) DILU.

OpenFOAM's DILUPreconditioner (paper listing 6) does sequential forward /
backward substitution — fine on CPU, level-scheduled on GPU, hostile to the
TPU VPU. Under a red-black ordering of the 7-point stencil the triangular
solves decompose into two fully-parallel half-sweeps, each a shifted-stencil
FMA — this IS a DILU factorization, just for the two-color ordering (see
docs/DESIGN.md §2). With red cells ordered before black:

    D*_red   = diag(A)_red
    D*_black = diag(A)_black - sum_f  A_bf * A_fb / D*_red(neighbor)
    (L+D*) y = r :  y_r = r_r / D*_r ;  y_b = (r_b - sum L_br y_r) / D*_b
    (D*+U) z = D* y :  z_b = y_b ;      z_r = y_r - (sum U_rb z_b) / D*_r
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.cfd.dia import DiaMatrix
from repro.cfd.grid import Grid, NEIGHBORS, shift


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class RBDilu:
    rdiag: jax.Array          # 1 / D*  (reciprocal, fused into the sweeps)
    red: jax.Array            # red mask (bool)

    def tree_flatten(self):
        return (self.rdiag, self.red), None

    @classmethod
    def tree_unflatten(cls, aux, leaves):
        return cls(*leaves)


def _neighbor_sum(off, field, weight=None):
    """sum_f off[f] * field(neighbor_f) [* weight(neighbor_f)]."""
    acc = jnp.zeros_like(field)
    for f, (ax, d) in enumerate(NEIGHBORS):
        nb = shift(field if weight is None else field * weight, ax, d)
        acc = acc + off[f] * nb
    return acc


def rb_dilu_factor(A: DiaMatrix, red) -> RBDilu:
    """D* for the red-black ordering (black rows update off red D*)."""
    redf = red.astype(A.diag.dtype)
    dstar_red = A.diag
    # A_bf * A_fb: neighbor's opposite-face coefficient
    corr = jnp.zeros_like(A.diag)
    for f, (ax, d) in enumerate(NEIGHBORS):
        g = f + 1 if f % 2 == 0 else f - 1
        a_fb = shift(A.off[g], ax, d)              # neighbor -> me
        inv_dstar_nb = shift(redf / jnp.where(dstar_red == 0, 1.0, dstar_red),
                             ax, d)
        corr = corr + A.off[f] * a_fb * inv_dstar_nb
    dstar = jnp.where(red, A.diag, A.diag - corr)
    rdiag = 1.0 / jnp.where(dstar == 0, 1.0, dstar)
    return RBDilu(rdiag=rdiag, red=red)


def rb_dilu_apply(P: RBDilu, A: DiaMatrix, r, use_kernel: bool = False):
    """w = M^-1 r with M = (L+D*) D*^-1 (D*+U) in red-black ordering."""
    if use_kernel:
        from repro.kernels.stencil_spmv import ops as K
        return K.rb_dilu_apply(P.rdiag, P.red, A.off, r)
    red = P.red
    # forward: reds first (no lower neighbors), then blacks
    y_r = jnp.where(red, r * P.rdiag, 0.0)
    y_b = jnp.where(red, 0.0, (r - _neighbor_sum(A.off, y_r)) * P.rdiag)
    y = y_r + y_b
    # backward: blacks unchanged, reds corrected by upper (black) neighbors
    z_r = jnp.where(red, y_r - P.rdiag * _neighbor_sum(A.off, y_b), 0.0)
    return jnp.where(red, z_r, y_b)


def jacobi_apply(A: DiaMatrix, r):
    return r / jnp.where(A.diag == 0, 1.0, A.diag)


def dilu_seq_ref(A: DiaMatrix, r):
    """Sequential (natural-ordering) DILU oracle on the dense form —
    O(N^2); small-grid tests only."""
    import numpy as np
    from repro.cfd.dia import to_dense
    M = to_dense(A)
    N = M.shape[0]
    rr = np.asarray(r, np.float64).reshape(N)
    dstar = np.zeros(N)
    for i in range(N):
        s = M[i, i]
        for j in range(i):
            if M[i, j] != 0 and M[j, i] != 0:
                s -= M[i, j] * M[j, i] / dstar[j]
        dstar[i] = s
    y = np.zeros(N)
    for i in range(N):
        y[i] = (rr[i] - M[i, :i] @ y[:i]) / dstar[i]
    z = np.zeros(N)
    for i in reversed(range(N)):
        z[i] = y[i] - (M[i, i + 1:] @ z[i + 1:]) / dstar[i]
    return z.reshape(r.shape)
