"""Preconditioners: Jacobi and two-color (red-black) DILU.

OpenFOAM's DILUPreconditioner (paper listing 6) does sequential forward /
backward substitution — fine on CPU, level-scheduled on GPU, hostile to the
TPU VPU. Under a red-black ordering of the 7-point stencil the triangular
solves decompose into two fully-parallel half-sweeps, each a shifted-stencil
FMA — this IS a DILU factorization, just for the two-color ordering (see
docs/DESIGN.md §2). With red cells ordered before black:

    D*_red   = diag(A)_red
    D*_black = diag(A)_black - sum_f  A_bf * A_fb / D*_red(neighbor)
    (L+D*) y = r :  y_r = r_r / D*_r ;  y_b = (r_b - sum L_br y_r) / D*_b
    (D*+U) z = D* y :  z_b = y_b ;      z_r = y_r - (sum U_rb z_b) / D*_r
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.cfd.dia import DiaMatrix, STENCIL_OFFSETS, compose_offsets
from repro.cfd.grid import Grid, NEIGHBORS, shift
from repro.core.regions import region


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class RBDilu:
    rdiag: jax.Array          # 1 / D*  (reciprocal, fused into the sweeps)
    red: jax.Array            # red mask (bool)

    def tree_flatten(self):
        return (self.rdiag, self.red), None

    @classmethod
    def tree_unflatten(cls, aux, leaves):
        return cls(*leaves)


def _neighbor_sum(off, field, weight=None):
    """sum_f off[f] * field(neighbor_f) [* weight(neighbor_f)]."""
    acc = jnp.zeros_like(field)
    for f, (ax, d) in enumerate(NEIGHBORS):
        nb = shift(field if weight is None else field * weight, ax, d)
        acc = acc + off[f] * nb
    return acc


def rb_dilu_factor(A: DiaMatrix, red) -> RBDilu:
    """D* for the red-black ordering (black rows update off red D*)."""
    redf = red.astype(A.diag.dtype)
    dstar_red = A.diag
    # A_bf * A_fb: neighbor's opposite-face coefficient
    corr = jnp.zeros_like(A.diag)
    for f, (ax, d) in enumerate(NEIGHBORS):
        g = f + 1 if f % 2 == 0 else f - 1
        a_fb = shift(A.off[g], ax, d)              # neighbor -> me
        inv_dstar_nb = shift(redf / jnp.where(dstar_red == 0, 1.0, dstar_red),
                             ax, d)
        corr = corr + A.off[f] * a_fb * inv_dstar_nb
    dstar = jnp.where(red, A.diag, A.diag - corr)
    rdiag = 1.0 / jnp.where(dstar == 0, 1.0, dstar)
    return RBDilu(rdiag=rdiag, red=red)


def _rb_dilu_ref(rdiag, red, off, r):
    """w = M^-1 r with M = (L+D*) D*^-1 (D*+U) in red-black ordering
    (pure-jnp oracle; the ``ref`` variant of :data:`RB_DILU`)."""
    # forward: reds first (no lower neighbors), then blacks
    y_r = jnp.where(red, r * rdiag, 0.0)
    y_b = jnp.where(red, 0.0, (r - _neighbor_sum(off, y_r)) * rdiag)
    # backward: blacks unchanged, reds corrected by upper (black) neighbors
    z_r = jnp.where(red, y_r - rdiag * _neighbor_sum(off, y_b), 0.0)
    return jnp.where(red, z_r, y_b)


# the two half-sweeps chain (black reads updated red): composed reach 2
@region("rb_dilu(dia)",
        stencil=compose_offsets(STENCIL_OFFSETS, STENCIL_OFFSETS),
        halo_args=("r",))
def RB_DILU(rdiag, red, off, r):
    """The canonical red-black DILU apply region; the Pallas half-sweep
    kernels register below as its ``pallas`` variant."""
    return _rb_dilu_ref(rdiag, red, off, r)


@RB_DILU.variant("pallas")
def rb_dilu_pallas(rdiag, red, off, r):
    """The ONE lazy wrapper around the half-sweep kernel composition
    (defined in the kernel package) — per-app DILU regions register this
    same callable."""
    from repro.kernels.stencil_spmv import kernel as K
    return K.rb_dilu(rdiag, red, off, r)


def rb_dilu_apply(P: RBDilu, A: DiaMatrix, r, impl: str = "ref"):
    """Variant-dispatched preconditioner apply for direct callers; ``impl``
    names a registered variant of :data:`RB_DILU` (executor-driven code
    lets the policy's Selector decide instead)."""
    return RB_DILU.impl_fn(RB_DILU.resolve(impl))(P.rdiag, P.red, A.off, r)


def jacobi_apply(A: DiaMatrix, r):
    return r / jnp.where(A.diag == 0, 1.0, A.diag)


def dilu_seq_ref(A: DiaMatrix, r):
    """Sequential (natural-ordering) DILU oracle on the dense form —
    O(N^2); small-grid tests only."""
    import numpy as np
    from repro.cfd.dia import to_dense
    M = to_dense(A)
    N = M.shape[0]
    rr = np.asarray(r, np.float64).reshape(N)
    dstar = np.zeros(N)
    for i in range(N):
        s = M[i, i]
        for j in range(i):
            if M[i, j] != 0 and M[j, i] != 0:
                s -= M[i, j] * M[j, i] / dstar[j]
        dstar[i] = s
    y = np.zeros(N)
    for i in range(N):
        y[i] = (rr[i] - M[i, :i] @ y[:i]) / dstar[i]
    z = np.zeros(N)
    for i in reversed(range(N)):
        z[i] = y[i] - (M[i, i + 1:] @ z[i + 1:]) / dstar[i]
    return z.reshape(r.shape)
