"""Krylov solvers: PBiCGStab (paper listing 5) and PCG.

Two execution styles, same math:

* ``pbicgstab_regions`` — faithful to the paper's porting model: every
  region (Amul, preconditioner, each field macro, each reduction) is a
  separate offloaded region dispatched through an executor. On the
  ``discrete`` executor each region pays staging — the page-migration storm
  of Fig 6; on ``unified`` the alternation is free — the APU claim.
* ``pbicgstab_fused`` — the beyond-paper path: the whole solve is one jitted
  ``lax.while_loop`` (no host round-trips at all). This is what a TPU-native
  production deployment would run, and the delta vs. the region path is
  reported in the benchmarks.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Callable, Optional

import jax
import jax.numpy as jnp

from repro.cfd.dia import (DiaMatrix, STENCIL_OFFSETS, amul_pallas,
                           amul_ref, compose_offsets)
from repro.cfd.fields import fused_axpy_pallas, fused_axpbypz_pallas
from repro.cfd.precond import (RBDilu, jacobi_apply, rb_dilu_apply,
                               rb_dilu_factor, rb_dilu_pallas)
from repro.core.ledger import Ledger
from repro.core.regions import region

SMALL = 1e-20


@dataclasses.dataclass
class SolveResult:
    x: jax.Array
    iters: int
    initial_residual: float
    final_residual: float
    converged: bool


# ---------------------------------------------------------------------------
# Region-granular PBiCGStab (paper-faithful execution)
# ---------------------------------------------------------------------------

def make_solver_regions(ledger: Optional[Ledger] = None):
    # fresh Ledger when none given — repeated factory calls must not grow
    # the process-global ledger with uniquified duplicate rows
    kw = dict(ledger=ledger or Ledger("solver_regions"))

    # stencil declarations feed sharded replay (repro.core.shard_program):
    # halo width along the decomposed grid axis is inferred from the DIA
    # offsets; halo_args names the operands whose neighbors are read
    # pallas variants reuse the canonical lazy wrappers from dia / precond
    # / fields — one definition per kernel composition, many registrations
    @region("Amul", stencil=STENCIL_OFFSETS, halo_args=("x",), **kw)
    def amul_r(diag, off, x):
        return amul_ref(DiaMatrix(diag, off), x)

    amul_r.variant("pallas", amul_pallas)

    # the two half-sweeps chain (black reads updated red reads r): reach 2
    @region("precondition(DILU)",
            stencil=compose_offsets(STENCIL_OFFSETS, STENCIL_OFFSETS),
            halo_args=("r",), **kw)
    def precond_r(rdiag, red, off, r):
        return rb_dilu_apply(RBDilu(rdiag, red), DiaMatrix(rdiag * 0, off), r)

    precond_r.variant("pallas", rb_dilu_pallas)

    @region("sA=rA-alpha*AyA", **kw)
    def saxpy_r(a, x, y):
        return y - a * x

    @saxpy_r.variant("pallas")
    def _saxpy_k(a, x, y):
        # y - a*x is fused_axpy with the scale negated (exact)
        return fused_axpy_pallas(-a, x, y)

    @region("x+=a*yA+w*zA", **kw)
    def update_x_r(x, a, yA, w, zA):
        return x + a * yA + w * zA

    @update_x_r.variant("pallas")
    def _update_x_k(x, a, yA, w, zA):
        return fused_axpbypz_pallas(a, yA, w, zA, x)

    @region("p=r+beta*(p-w*v)", **kw)
    def update_p_r(r, beta, p, w, v):
        return r + beta * (p - w * v)

    @region("dot", **kw)
    def dot_r(x, y):
        return jnp.sum(x.astype(jnp.float64) * y.astype(jnp.float64))

    @region("sumMag", **kw)
    def summag_r(x):
        return jnp.sum(jnp.abs(x.astype(jnp.float64)))

    class R:
        amul, precond = amul_r, precond_r
        saxpy, update_x, update_p = saxpy_r, update_x_r, update_p_r
        dot, summag = dot_r, summag_r

    return R


def pbicgstab_regions(executor, regions, A: DiaMatrix, b, x0, P: RBDilu,
                      tol: float = 1e-6, rel_tol: float = 0.0,
                      max_iter: int = 500) -> SolveResult:
    """OpenFOAM PBiCGStab, one executor.run per offloaded region."""
    run = executor.run
    x = x0
    # r = b - 1.0*Ax through the saxpy region (identical math) so the whole
    # residual dataflow is region-visible — program capture
    # (repro.core.program) records real dependencies instead of freezing a
    # host-computed array as a constant
    r = run(regions.saxpy, 1.0, run(regions.amul, A.diag, A.off, x), b)
    rA0 = r
    norm = float(run(regions.summag, b)) + SMALL
    res0 = float(run(regions.summag, r)) / norm
    res = res0
    rho_old = alpha = omega = 1.0
    p = jnp.zeros_like(b)
    v = jnp.zeros_like(b)
    it = 0
    while res > tol and (rel_tol <= 0 or res / max(res0, SMALL) > rel_tol) \
            and it < max_iter:
        rho = float(run(regions.dot, rA0, r))
        if abs(rho) < SMALL:
            break
        beta = (rho / rho_old) * (alpha / max(omega, SMALL))
        p = run(regions.update_p, r, beta, p, omega, v)
        yA = run(regions.precond, P.rdiag, P.red, A.off, p)
        v = run(regions.amul, A.diag, A.off, yA)
        denom = float(run(regions.dot, rA0, v))
        alpha = rho / (denom if abs(denom) > SMALL else SMALL)
        s = run(regions.saxpy, alpha, v, r)
        zA = run(regions.precond, P.rdiag, P.red, A.off, s)
        t = run(regions.amul, A.diag, A.off, zA)
        tt = float(run(regions.dot, t, t))
        ts = float(run(regions.dot, t, s))
        omega = ts / (tt if abs(tt) > SMALL else SMALL)
        x = run(regions.update_x, x, alpha, yA, omega, zA)
        r = run(regions.saxpy, omega, t, s)
        rho_old = rho
        res = float(run(regions.summag, r)) / norm
        it += 1
    return SolveResult(x, it, res0, res, res <= tol)


# ---------------------------------------------------------------------------
# Fused PBiCGStab (single jitted while_loop)
# ---------------------------------------------------------------------------

@partial(jax.jit, static_argnames=("max_iter", "use_dilu"))
def pbicgstab_fused(A: DiaMatrix, b, x0, rdiag, red, tol: float = 1e-6,
                    max_iter: int = 500, use_dilu: bool = True):
    P = RBDilu(rdiag, red)

    def precond(r):
        return rb_dilu_apply(P, A, r) if use_dilu else jacobi_apply(A, r)

    def dot(a_, b_):
        return jnp.sum(a_.astype(jnp.float64) * b_.astype(jnp.float64))

    norm = jnp.sum(jnp.abs(b.astype(jnp.float64))) + SMALL
    r0 = b - amul_ref(A, x0)

    def res_of(r):
        return jnp.sum(jnp.abs(r.astype(jnp.float64))) / norm

    state = dict(x=x0, r=r0, rA0=r0, p=jnp.zeros_like(b), v=jnp.zeros_like(b),
                 rho=jnp.float64(1.0), alpha=jnp.float64(1.0),
                 omega=jnp.float64(1.0), it=jnp.int32(0), res=res_of(r0))

    def cond(st):
        return (st["res"] > tol) & (st["it"] < max_iter)

    def body(st):
        rho = dot(st["rA0"], st["r"])
        beta = (rho / jnp.where(jnp.abs(st["rho"]) < SMALL, SMALL, st["rho"])) \
            * (st["alpha"] / jnp.where(jnp.abs(st["omega"]) < SMALL, SMALL,
                                       st["omega"]))
        p = st["r"] + jnp.float32(beta) * (st["p"] - jnp.float32(st["omega"]) * st["v"])
        yA = precond(p)
        v = amul_ref(A, yA)
        denom = dot(st["rA0"], v)
        alpha = rho / jnp.where(jnp.abs(denom) < SMALL, SMALL, denom)
        s = st["r"] - jnp.float32(alpha) * v
        zA = precond(s)
        t = amul_ref(A, zA)
        tt = dot(t, t)
        omega = dot(t, s) / jnp.where(tt < SMALL, SMALL, tt)
        x = st["x"] + jnp.float32(alpha) * yA + jnp.float32(omega) * zA
        r = s - jnp.float32(omega) * t
        return dict(x=x, r=r, rA0=st["rA0"], p=p, v=v, rho=rho, alpha=alpha,
                    omega=omega, it=st["it"] + 1, res=res_of(r))

    out = jax.lax.while_loop(cond, body, state)
    return out["x"], out["it"], res_of(r0), out["res"]


def solve(A: DiaMatrix, b, x0, red, tol=1e-6, max_iter=500, use_dilu=True):
    """Convenience wrapper: factor + fused solve."""
    P = rb_dilu_factor(A, red)
    x, it, r0, res = pbicgstab_fused(A, b, x0, P.rdiag, P.red, tol=tol,
                                     max_iter=max_iter, use_dilu=use_dilu)
    return SolveResult(x, int(it), float(r0), float(res), float(res) <= tol)
