"""DIA (diagonal-offset) sparse matrix over a structured grid.

This is the TPU adaptation of OpenFOAM's lduMatrix (docs/DESIGN.md §2): the
face-list gather/scatter Amul becomes 7 shifted-vector FMAs. Coefficients
are stored per cell: ``diag [nx,ny,nz]`` and ``off [6, nx,ny,nz]`` where
``off[f]`` multiplies the neighbor in ``grid.NEIGHBORS[f]``; entries for
non-existent (boundary) neighbors are zero.

``amul_ref`` is the jnp oracle and the *ref* variant of the module-level
:data:`AMUL` region; ``repro.kernels.stencil_spmv`` registers as its
``pallas`` variant.  Which one runs is decided per call by the executing
policy's Selector (docs/VARIANTS.md) — nothing here hard-wires the kernel.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from repro.cfd.grid import Grid, NEIGHBORS, shift
from repro.core.regions import region

#: the DIA offset table in (grid_axis, offset) form — one entry per stored
#: band.  This is the canonical stencil declaration consumed by sharded
#: replay (``repro.core.shard_program.halo_width`` infers the halo width a
#: domain decomposition must exchange from exactly this tuple).
STENCIL_OFFSETS = NEIGHBORS


def compose_offsets(a, b):
    """Offset table of a stencil applied after another (Minkowski sum).

    A region that chains two 7-point operators (e.g. face interpolation
    followed by a divergence) reaches two cells along each axis; its
    declared stencil is ``compose_offsets(STENCIL_OFFSETS, STENCIL_OFFSETS)``
    so halo-width inference sees the composed reach, not the single-hop one.
    """
    out = {(ax, d) for ax, d in a} | {(ax, d) for ax, d in b}
    for ax1, d1 in a:
        for ax2, d2 in b:
            if ax1 == ax2 and d1 + d2 != 0:
                out.add((ax1, d1 + d2))
    return tuple(sorted(out))


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class DiaMatrix:
    diag: jax.Array              # [nx,ny,nz]
    off: jax.Array               # [6,nx,ny,nz]

    def tree_flatten(self):
        return (self.diag, self.off), None

    @classmethod
    def tree_unflatten(cls, aux, leaves):
        return cls(*leaves)

    @property
    def shape3(self):
        return self.diag.shape

    def transpose(self) -> "DiaMatrix":
        """A^T: off[f] becomes the opposite face's coefficient, shifted."""
        new_off = []
        for f, (ax, d) in enumerate(NEIGHBORS):
            g = f + 1 if f % 2 == 0 else f - 1        # opposite face index
            new_off.append(shift(self.off[g], ax, d))
        return DiaMatrix(self.diag, jnp.stack(new_off))


def amul_ref(A: DiaMatrix, x: jax.Array) -> jax.Array:
    """y = A x  — 7 shifted FMAs, no gathers (pure-jnp oracle)."""
    y = A.diag * x
    for f, (ax, d) in enumerate(NEIGHBORS):
        y = y + A.off[f] * shift(x, ax, d)
    return y


@region("Amul(dia)", stencil=STENCIL_OFFSETS, halo_args=("x",))
def AMUL(diag, off, x):
    """The canonical DIA SpMV region: ``ref`` is the 7-FMA oracle, the
    Pallas kernel registers below as ``pallas``.  Solver factories
    (``repro.cfd.solvers.make_solver_regions``) build their own per-app
    Amul regions with the same variant table."""
    return amul_ref(DiaMatrix(diag, off), x)


@AMUL.variant("pallas")
def amul_pallas(diag, off, x):
    """The ONE lazy wrapper around the stencil-SpMV kernel — per-app Amul
    regions (``solvers.make_solver_regions``) register this same callable.
    Imported at trace time, not module import: the kernel layer stays an
    optional dependency of the variant, not of the CFD core."""
    from repro.kernels.stencil_spmv import kernel as K
    return K.stencil_spmv(diag, off, x)


def amul(A: DiaMatrix, x: jax.Array, impl: str = "ref") -> jax.Array:
    """Variant-dispatched y = A x for direct (non-executor) callers.
    ``impl`` names a registered variant of :data:`AMUL`; executor-driven
    code should instead let the policy's Selector decide."""
    return AMUL.impl_fn(AMUL.resolve(impl))(A.diag, A.off, x)


def residual(A: DiaMatrix, x, b):
    return b - amul_ref(A, x)


def to_dense(A: DiaMatrix):
    """O(N^2) dense form for small-grid tests only."""
    import numpy as np
    nx, ny, nz = A.diag.shape
    N = nx * ny * nz
    M = np.zeros((N, N), np.float64)
    diag = np.asarray(A.diag, np.float64)
    off = np.asarray(A.off, np.float64)

    def idx(i, j, k):
        return (i * ny + j) * nz + k

    for i in range(nx):
        for j in range(ny):
            for k in range(nz):
                r = idx(i, j, k)
                M[r, r] = diag[i, j, k]
                for f, (ax, d) in enumerate(NEIGHBORS):
                    ni, nj, nk = i, j, k
                    if ax == 0:
                        ni += d
                    elif ax == 1:
                        nj += d
                    else:
                        nk += d
                    if 0 <= ni < nx and 0 <= nj < ny and 0 <= nk < nz:
                        M[r, idx(ni, nj, nk)] = off[f, i, j, k]
    return M
