"""Field algebra — the ``TFOR_ALL_F_OP_F_OP_F`` macro family (paper
listing 4).

OpenFOAM expands field expressions through macros into elementwise loops;
the paper offloads each with one directive, and those loops fire hundreds of
times per time-step (Fig 3). Here each macro is a region-decorated jitted
function (so the executors can stage/measure them), and the ternary fused
forms map onto the ``repro.kernels.fused_field`` Pallas kernel.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.ledger import Ledger
from repro.core.regions import region


def make_field_ops(ledger: Ledger = None, use_kernel: bool = False):
    """Region-decorated field macros (one ledger per app instance).

    A fresh Ledger per call when none is given: repeated factory calls
    against the process-global ledger would accumulate uniquified rows
    (dot#2, dot#3, ...) without bound."""
    kw = dict(ledger=ledger or Ledger("field_ops"))

    if use_kernel:
        from repro.kernels.fused_field import ops as K

    @region("F_OP_F_OP_F(axpy)", **kw)
    def axpy(a, x, y):
        """y + a*x — the daxpy of listing 2."""
        if use_kernel:
            return K.fused_axpy(a, x, y)
        return y + a * x

    @region("F_OP_F_OP_F(xpay)", **kw)
    def xpay(a, x, y):
        """x + a*y (PBiCGStab's p-update shape)."""
        if use_kernel:
            return K.fused_xpay(a, x, y)
        return x + a * y

    @region("F_OP_F_OP_F(axpbypz)", **kw)
    def axpbypz(a, x, b, y, z):
        """z + a*x + b*y (momentum corrector shape, listing 3 line 32)."""
        return z + a * x + b * y

    @region("F_MUL_F", **kw)
    def fmul(x, y):
        if use_kernel:
            return K.fused_mul(x, y)
        return x * y

    @region("dot", **kw)
    def dot(x, y):
        return jnp.sum(x.astype(jnp.float64) * y.astype(jnp.float64))

    @region("norm2", **kw)
    def norm2(x):
        return jnp.sqrt(jnp.sum(x.astype(jnp.float64) ** 2))

    @region("sumMag", **kw)
    def summag(x):
        return jnp.sum(jnp.abs(x.astype(jnp.float64)))

    class Ops:
        pass

    ops = Ops()
    ops.axpy, ops.xpay, ops.axpbypz = axpy, xpay, axpbypz
    ops.fmul, ops.dot, ops.norm2, ops.summag = fmul, dot, norm2, summag
    return ops
