"""Field algebra — the ``TFOR_ALL_F_OP_F_OP_F`` macro family (paper
listing 4).

OpenFOAM expands field expressions through macros into elementwise loops;
the paper offloads each with one directive, and those loops fire hundreds of
times per time-step (Fig 3). Here each macro is a region-decorated jitted
function (so the executors can stage/measure them); the fused forms from
``repro.kernels.fused_field`` register as each region's ``pallas`` variant,
selected per call by the executing policy (docs/VARIANTS.md) — no
hard-wired kernel flag.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.ledger import Ledger
from repro.core.regions import region


# -- the canonical lazy kernel wrappers: defined ONCE, registered on every
# -- factory's regions (and reused by solvers.make_solver_regions)

def fused_axpy_pallas(a, x, y):
    from repro.kernels.fused_field import kernel as K
    return K.fused_axpy(a, x, y)


def fused_xpay_pallas(a, x, y):
    from repro.kernels.fused_field import kernel as K
    return K.fused_xpay(a, x, y)


def fused_axpbypz_pallas(a, x, b, y, z):
    from repro.kernels.fused_field import kernel as K
    return K.fused_axpbypz(a, x, b, y, z)


def fused_mul_pallas(x, y):
    from repro.kernels.fused_field import kernel as K
    return K.fused_mul(x, y)


def make_field_ops(ledger: Ledger = None):
    """Region-decorated field macros (one ledger per app instance).

    A fresh Ledger per call when none is given: repeated factory calls
    against the process-global ledger would accumulate uniquified rows
    (dot#2, dot#3, ...) without bound."""
    kw = dict(ledger=ledger or Ledger("field_ops"))

    @region("F_OP_F_OP_F(axpy)", **kw)
    def axpy(a, x, y):
        """y + a*x — the daxpy of listing 2."""
        return y + a * x

    axpy.variant("pallas", fused_axpy_pallas)

    @region("F_OP_F_OP_F(xpay)", **kw)
    def xpay(a, x, y):
        """x + a*y (PBiCGStab's p-update shape)."""
        return x + a * y

    xpay.variant("pallas", fused_xpay_pallas)

    @region("F_OP_F_OP_F(axpbypz)", **kw)
    def axpbypz(a, x, b, y, z):
        """z + a*x + b*y (momentum corrector shape, listing 3 line 32)."""
        return z + a * x + b * y

    axpbypz.variant("pallas", fused_axpbypz_pallas)

    @region("F_MUL_F", **kw)
    def fmul(x, y):
        return x * y

    fmul.variant("pallas", fused_mul_pallas)

    @region("dot", **kw)
    def dot(x, y):
        return jnp.sum(x.astype(jnp.float64) * y.astype(jnp.float64))

    @region("norm2", **kw)
    def norm2(x):
        return jnp.sqrt(jnp.sum(x.astype(jnp.float64) ** 2))

    @region("sumMag", **kw)
    def summag(x):
        return jnp.sum(jnp.abs(x.astype(jnp.float64)))

    class Ops:
        pass

    ops = Ops()
    ops.axpy, ops.xpay, ops.axpbypz = axpy, xpay, axpbypz
    ops.fmul, ops.dot, ops.norm2, ops.summag = fmul, dot, norm2, summag
    return ops
