"""Sharded, mesh-agnostic, atomic checkpointing with async host staging.

Format: one directory per step —
  manifest.json   step, logical tree structure, leaf shapes/dtypes
  <i>.npy         one file per leaf (full logical array)
  coverage.json   optional coverage_report() snapshot (save(report=...)):
                  the region/offload accounting that produced the weights

Design points for the 1000+-node posture:
* **Mesh-agnostic**: leaves are saved as full logical arrays with their
  tree paths; restore re-shards onto ANY mesh via target shardings —
  elastic rescaling is a restore, not a migration (runtime/elastic.py).
* **Atomic**: writes land in ``step_k.tmp`` and are renamed; a crash never
  leaves a half-readable checkpoint. ``latest`` resolution scans committed
  dirs only.
* **Async with pooled staging** (paper C1+C4): device->host transfer goes
  through ``pinned_host`` placement, serialization runs on a worker thread
  over ``HostStagingPool`` buffers; the train loop blocks only on the
  previous save (bounded staleness of 1).
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from pathlib import Path
from typing import Any, Optional

import jax
import numpy as np

from repro.core.pool import GLOBAL_STAGING_POOL
from repro.core.umem import MemSpace, tree_place, supported_spaces


def _paths_and_leaves(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    paths = ["/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                      for k in path) for path, _ in flat]
    leaves = [l for _, l in flat]
    return paths, leaves, treedef


class Checkpointer:
    def __init__(self, directory: str, keep: int = 3, async_save: bool = True):
        self.dir = Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep = keep
        self.async_save = async_save
        self._worker: Optional[threading.Thread] = None

    # -- save -----------------------------------------------------------
    def save(self, step: int, tree: Any, extra: Optional[dict] = None,
             report: Optional[dict] = None) -> None:
        """``report`` (optional) is a ``coverage_report()``-style dict
        snapshotted to ``coverage.json`` inside the step directory — the
        offload/staging/variant accounting that produced these weights
        travels with them (paper C2: coverage is part of the artifact)."""
        self.wait()
        # stage to host memory space (zero-copy on unified memory; one DMA
        # per buffer otherwise), then serialize off-thread
        if "pinned_host" in supported_spaces():
            staged = tree_place(tree, MemSpace.HOST)
        else:                                   # pragma: no cover
            staged = tree
        jax.block_until_ready(staged)
        host_tree = jax.tree.map(lambda x: np.asarray(x), staged)

        def work():
            self._write(step, host_tree, extra or {}, report)

        if self.async_save:
            self._worker = threading.Thread(target=work, daemon=True)
            self._worker.start()
        else:
            work()

    def _write(self, step: int, host_tree, extra: dict,
               report: Optional[dict] = None) -> None:
        tmp = self.dir / f"step_{step:010d}.tmp"
        final = self.dir / f"step_{step:010d}"
        if tmp.exists():
            shutil.rmtree(tmp)
        tmp.mkdir(parents=True)
        paths, leaves, _ = _paths_and_leaves(host_tree)
        manifest = {"step": step, "extra": extra, "leaves": []}
        for i, (p, leaf) in enumerate(zip(paths, leaves)):
            arr = np.asarray(leaf)
            dtype_name = str(arr.dtype)
            if arr.dtype.kind == "V":          # ml_dtypes (bf16, fp8, ...)
                arr = arr.view(np.uint8 if arr.dtype.itemsize == 1
                               else np.uint16)
            buf = GLOBAL_STAGING_POOL.acquire(arr.shape, arr.dtype)
            np.copyto(buf, arr)
            np.save(tmp / f"{i}.npy", buf)
            GLOBAL_STAGING_POOL.release(buf)
            manifest["leaves"].append(
                {"path": p, "file": f"{i}.npy", "shape": list(arr.shape),
                 "dtype": dtype_name})
        (tmp / "manifest.json").write_text(json.dumps(manifest))
        if report is not None:
            (tmp / "coverage.json").write_text(json.dumps(report, indent=1))
        if final.exists():
            shutil.rmtree(final)
        os.rename(tmp, final)
        self._gc()

    def wait(self) -> None:
        if self._worker is not None:
            self._worker.join()
            self._worker = None

    def _gc(self) -> None:
        steps = self.all_steps()
        for s in steps[: -self.keep]:
            shutil.rmtree(self.dir / f"step_{s:010d}", ignore_errors=True)

    # -- restore ---------------------------------------------------------
    def all_steps(self):
        out = []
        for d in self.dir.iterdir():
            if d.is_dir() and d.name.startswith("step_") and \
                    not d.name.endswith(".tmp") and (d / "manifest.json").exists():
                out.append(int(d.name[5:]))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, like_tree: Any, step: Optional[int] = None,
                shardings: Any = None) -> tuple:
        """Restore into the structure of ``like_tree``; if ``shardings`` is
        given (a matching pytree of Shardings for the CURRENT mesh), leaves
        are placed directly — this is the elastic re-shard path."""
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.dir}")
        d = self.dir / f"step_{step:010d}"
        manifest = json.loads((d / "manifest.json").read_text())
        by_path = {l["path"]: l for l in manifest["leaves"]}
        paths, leaves, treedef = _paths_and_leaves(like_tree)
        shard_leaves = (jax.tree_util.tree_leaves(shardings)
                        if shardings is not None else [None] * len(leaves))
        out = []
        for p, like, sh in zip(paths, leaves, shard_leaves):
            rec = by_path[p]
            arr = np.load(d / rec["file"])
            want = np.dtype(jax.numpy.dtype(rec["dtype"]))
            if arr.dtype != want:              # ml_dtypes saved as uint view
                arr = arr.view(want)
            if sh is not None:
                out.append(jax.device_put(arr, sh))
            else:
                out.append(jax.device_put(arr))
        return treedef.unflatten(out), manifest
