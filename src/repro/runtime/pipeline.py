"""GPipe-style pipeline parallelism over a mesh axis (shard_map + ppermute).

Stages live on consecutive members of one mesh axis (typically ``pod`` —
PP across pods keeps the narrow DCN links to point-to-point activation
traffic instead of all-reduces). Microbatches stream with the classic
GPipe schedule: T = M + S - 1 ticks, stage s works on microbatch m = t - s,
activations hop one stage per tick via ``lax.ppermute``.

This is the schedule primitive: ``gpipe_apply`` runs any per-stage function
(e.g. a block of transformer layers) forward. It is differentiable (jax AD
through ppermute gives the reverse schedule automatically), so it composes
with the trainer for PP+DP runs.
"""
from __future__ import annotations

import functools
from typing import Callable

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


def gpipe_apply(stage_fn: Callable, stage_params, x_micro, mesh: Mesh,
                axis: str = "pod"):
    """Run ``x -> stage_{S-1}(...stage_0(x))`` with pipelining.

    stage_params: pytree whose leaves have leading dim S (one slice per
    stage; sharded over ``axis``). x_micro: [M, mb, ...] microbatches
    (replicated over ``axis``). Returns [M, mb, ...] outputs (replicated).
    """
    S = mesh.shape[axis]
    M = x_micro.shape[0]
    T = M + S - 1

    pspec_params = jax.tree.map(lambda _: P(axis), stage_params)
    x_spec = P(*([None] * x_micro.ndim))

    def member(params_local, xs):
        # params_local leaves: [1, ...] -> this stage's slice
        p_here = jax.tree.map(lambda a: a[0], params_local)
        s = jax.lax.axis_index(axis)
        act0 = jnp.zeros_like(xs[0])
        outs0 = jnp.zeros_like(xs)

        def tick(carry, t):
            act, outs = carry
            # stage 0 ingests microbatch t (if any); others use incoming act
            m_in = jnp.clip(t, 0, M - 1)
            inject = xs[m_in]
            cur = jnp.where(s == 0, inject, act)
            y = stage_fn(p_here, cur)
            m_done = t - (S - 1)                  # microbatch finishing now
            is_last = s == S - 1
            valid_out = is_last & (m_done >= 0) & (m_done < M)
            outs = jax.lax.dynamic_update_index_in_dim(
                outs, jnp.where(valid_out, y, outs[jnp.clip(m_done, 0, M - 1)]),
                jnp.clip(m_done, 0, M - 1), axis=0)
            # hop: stage s sends y to s+1 (last stage sends nowhere useful)
            perm = [(i, (i + 1) % S) for i in range(S)]
            act_next = jax.lax.ppermute(y, axis, perm)
            return (act_next, outs), None

        (act, outs), _ = jax.lax.scan(tick, (act0, outs0), jnp.arange(T))
        # broadcast finished outputs from the last stage to every member
        outs = jax.lax.psum(jnp.where(s == S - 1, outs, 0.0), axis)
        return outs

    fn = shard_map(member, mesh=mesh,
                   in_specs=(pspec_params, x_spec), out_specs=x_spec,
                   check_rep=False)
    return fn(stage_params, x_micro)


def split_microbatches(x, n_micro: int):
    """[B, ...] -> [M, B/M, ...]"""
    B = x.shape[0]
    assert B % n_micro == 0, (B, n_micro)
    return x.reshape(n_micro, B // n_micro, *x.shape[1:])


def merge_microbatches(y):
    return y.reshape(y.shape[0] * y.shape[1], *y.shape[2:])
