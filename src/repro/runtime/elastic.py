"""Elastic scaling: resume any checkpoint on any mesh shape.

Checkpoints are mesh-agnostic (full logical arrays + tree paths), and all
shardings derive from logical axis names (launch/sharding.py), so scaling
from N to M chips is: build the new mesh, re-derive shardings, restore.
No resharding tool, no migration step — the checkpoint IS the exchange
format. This is what bounds blast radius when a pod is lost: the job
restarts on the surviving pods with the same code path as a normal resume.
"""
from __future__ import annotations

from typing import Any, Optional

import jax

from repro.checkpoint.ckpt import Checkpointer
from repro.launch import sharding as SH
from repro.models.params import is_spec


def reshard_restore(ckpt: Checkpointer, specs: Any, mesh, rules=None,
                    step: Optional[int] = None, memory_kind=None):
    """Restore a param-spec-shaped checkpoint onto ``mesh``."""
    rules = rules or SH.ShardingRules("train")
    from repro.models.params import abstract_params
    like = abstract_params(specs)
    shardings = SH.tree_param_shardings(specs, mesh, rules,
                                        memory_kind=memory_kind)
    return ckpt.restore(like, step=step, shardings=shardings)


def mesh_transition_plan(old_shape, new_shape) -> dict:
    """Describe the transition (for logs/ops review): per-axis scale factor
    and whether each is a clean divisor change (zero-copy reshard)."""
    plan = {"old": list(old_shape), "new": list(new_shape), "axes": []}
    for i, (a, b) in enumerate(zip(old_shape, new_shape)):
        plan["axes"].append({
            "axis": i, "old": a, "new": b,
            "clean": (max(a, b) % min(a, b) == 0),
        })
    return plan
