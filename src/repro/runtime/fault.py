"""Fault tolerance: supervised training with checkpoint/restart and
straggler detection.

At 1000+ nodes the MTBF of the job is minutes-to-hours; the supervisor
treats the train step as an unreliable operation:

* periodic checkpoints (async, atomic — see checkpoint/ckpt.py), each one
  carrying a ``coverage_report()`` snapshot beside the weights when a
  ``report_fn`` is given (the ledger state that produced this checkpoint),
* on failure: restore latest checkpoint, rebuild the data stream at the
  restored step (the pipeline is step-deterministic), continue — restart
  equivalence is a tested invariant, not a hope.  When the step function
  is a captured :class:`~repro.core.program.RegionProgram` replay, pass
  ``rebuild_step`` so the restart RE-CAPTURES the program against the
  restored state — the regions (and therefore their Ledger rows) are
  reused, so accounting accumulates across restarts instead of forking
  ``FWD_BWD#2``-style duplicate rows,
* straggler detection: per-step wall-time EWMA + threshold; flagged steps
  are reported through the ledger (on a real fleet this feeds the
  reschedule/backup-worker policy; the policy hook is injectable).

``FaultInjector`` produces deterministic synthetic failures for tests.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Optional

import jax

from repro.checkpoint.ckpt import Checkpointer


class FaultInjector:
    """Raises RuntimeError at the given step numbers (once each)."""

    def __init__(self, fail_at=()):
        self.fail_at = set(fail_at)

    def check(self, step: int):
        if step in self.fail_at:
            self.fail_at.discard(step)
            raise RuntimeError(f"injected fault at step {step}")


@dataclasses.dataclass
class StragglerMonitor:
    alpha: float = 0.2
    threshold: float = 2.0
    ewma: Optional[float] = None
    flagged: int = 0
    events: list = dataclasses.field(default_factory=list)

    def observe(self, step: int, dt: float) -> bool:
        is_straggler = False
        if self.ewma is not None and dt > self.threshold * self.ewma:
            self.flagged += 1
            self.events.append((step, dt, self.ewma))
            is_straggler = True
            # don't poison the EWMA with the outlier
        else:
            self.ewma = dt if self.ewma is None else \
                (1 - self.alpha) * self.ewma + self.alpha * dt
        return is_straggler


@dataclasses.dataclass
class SupervisorReport:
    steps_run: int = 0
    restarts: int = 0
    checkpoints: int = 0
    stragglers: int = 0
    final_step: int = 0
    metrics_last: dict = dataclasses.field(default_factory=dict)


class TrainSupervisor:
    """Drives (state, batch) -> (state, metrics) with checkpoint/restart.

    ``state`` is any pytree (params/opt/...); ``batch_fn(step)`` must be
    deterministic; ``fault`` is an optional injector (tests).

    ``rebuild_step(state, step) -> step_fn`` (optional) is invoked after
    every restore: a region-program trainer re-captures its step program
    against the restored state, keeping the same Regions/Ledger (see
    ``repro.train.step.capture_train_program``).  ``report_fn() -> dict``
    (optional) is snapshotted into every checkpoint beside the weights
    (``coverage.json``).
    """

    def __init__(self, step_fn: Callable, batch_fn: Callable,
                 ckpt: Checkpointer, ckpt_every: int = 50,
                 fault: Optional[FaultInjector] = None,
                 straggler: Optional[StragglerMonitor] = None,
                 max_restarts: int = 10,
                 rebuild_step: Optional[Callable] = None,
                 report_fn: Optional[Callable] = None):
        self.step_fn = step_fn
        self.batch_fn = batch_fn
        self.ckpt = ckpt
        self.ckpt_every = ckpt_every
        self.fault = fault or FaultInjector()
        self.straggler = straggler or StragglerMonitor()
        self.max_restarts = max_restarts
        self.rebuild_step = rebuild_step
        self.report_fn = report_fn

    def _save(self, step: int, state: Any) -> None:
        report = self.report_fn() if self.report_fn is not None else None
        self.ckpt.save(step, state, extra={"step": step}, report=report)

    def run(self, state: Any, start_step: int, n_steps: int,
            shardings: Any = None) -> tuple:
        rep = SupervisorReport()
        step = start_step
        end = start_step + n_steps
        restarts = 0
        if self.ckpt.latest_step() is None:
            # anchor: a fault before the first periodic save must restart
            # from the true initial state, not a partially-advanced one
            self._save(start_step, state)
            rep.checkpoints += 1
        while step < end:
            try:
                t0 = time.perf_counter()
                self.fault.check(step)
                batch = self.batch_fn(step)
                state, metrics = self.step_fn(state, batch)
                jax.block_until_ready(jax.tree.leaves(state)[0])
                dt = time.perf_counter() - t0
                if self.straggler.observe(step, dt):
                    rep.stragglers += 1
                step += 1
                rep.steps_run += 1
                rep.metrics_last = {
                    k: float(v) for k, v in metrics.items()} if metrics else {}
                if step % self.ckpt_every == 0 or step == end:
                    self._save(step, state)
                    rep.checkpoints += 1
            except Exception:
                restarts += 1
                rep.restarts += 1
                if restarts > self.max_restarts:
                    raise
                latest = self.ckpt.latest_step()
                if latest is None:
                    # no checkpoint yet: restart from the initial state
                    step = start_step
                    continue
                state, manifest = self.ckpt.restore(state, step=latest,
                                                    shardings=shardings)
                step = manifest["extra"]["step"]
                if self.rebuild_step is not None:
                    # re-capture against the restored state; same regions,
                    # same Ledger — accounting survives the restart
                    self.step_fn = self.rebuild_step(state, step)
        rep.final_step = step
        self.ckpt.wait()
        return state, rep
