"""Error-feedback int8 gradient compression for the slow ('pod') axis.

At 2+ pods the DCN/optical links are ~an order of magnitude slower than
intra-pod ICI; compressing the cross-pod gradient reduction 4x (f32->int8,
per-tensor scale) with error feedback (residual carried to the next step)
keeps convergence while shrinking the pod-axis collective term of the
roofline. Pure-functional API: state is a pytree of residuals.
"""
from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp


def init_state(grads: Any) -> Any:
    return jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32), grads)


def _quant_one(g, r):
    gf = g.astype(jnp.float32) + r                 # error feedback
    scale = jnp.maximum(jnp.max(jnp.abs(gf)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(gf / scale), -127, 127).astype(jnp.int8)
    residual = gf - q.astype(jnp.float32) * scale
    return q, scale, residual


def compress(grads: Any, state: Any) -> Tuple[Any, Any, Any]:
    """Returns (q_tree int8, scale_tree, new_state)."""
    flat_g, tdef = jax.tree.flatten(grads)
    flat_r = tdef.flatten_up_to(state)
    qs, scales, res = [], [], []
    for g, r in zip(flat_g, flat_r):
        q, s, rr = _quant_one(g, r)
        qs.append(q)
        scales.append(s)
        res.append(rr)
    return tdef.unflatten(qs), tdef.unflatten(scales), tdef.unflatten(res)


def decompress(q_tree: Any, scale_tree: Any, dtype=jnp.float32) -> Any:
    return jax.tree.map(
        lambda q, s: (q.astype(jnp.float32) * s).astype(dtype),
        q_tree, scale_tree)


def compressed_psum(grads: Any, state: Any, axis_name: str) -> Tuple[Any, Any]:
    """Inside shard_map/pmap: quantize, psum int-sums in f32, dequantize.
    (The wire format is int8 + one f32 scale per tensor per member.)"""
    q, s, new_state = compress(grads, state)
    summed = jax.tree.map(
        lambda qq, ss: jax.lax.psum(qq.astype(jnp.float32) * ss, axis_name),
        q, s)
    return summed, new_state


def compression_error(grads: Any, state: Any) -> float:
    """Relative L2 error of one compress/decompress round (diagnostics)."""
    q, s, _ = compress(grads, state)
    deq = decompress(q, s)
    num = sum(float(jnp.sum((a.astype(jnp.float32) - b) ** 2))
              for a, b in zip(jax.tree.leaves(grads), jax.tree.leaves(deq)))
    den = sum(float(jnp.sum(a.astype(jnp.float32) ** 2))
              for a in jax.tree.leaves(grads)) + 1e-30
    return (num / den) ** 0.5
