"""Whisper large-v3 backbone — encoder-decoder; conv/mel frontend is a STUB
(``input_specs()`` provides precomputed frame embeddings) [arXiv:2212.04356].

The assignment lists 32L; Whisper large has 32 encoder + 32 decoder layers.
We implement both stacks (n_enc_layers=32, n_layers=32 decoder layers).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-large-v3", family="audio",
    n_layers=32, d_model=1280, n_heads=20, n_kv_heads=20, head_dim=64,
    d_ff=5120, vocab=51866,
    layer_cycle=("attn_xdec",),
    n_enc_layers=32, enc_len=1500,
    tie_embeddings=True,
    source="arXiv:2212.04356; hf:openai/whisper-large-v3",
)
