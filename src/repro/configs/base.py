"""Configuration schema for the repro framework.

Every assigned architecture is expressed as a :class:`ModelConfig` — a purely
declarative description (no jax imports at module scope) consumed by
``repro.models.transformer`` to build the layer program, by
``repro.launch.sharding`` to derive parameter/activation shardings, and by
``repro.launch.dryrun`` to build ``input_specs()``.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence, Tuple

# ---------------------------------------------------------------------------
# Layer kinds understood by the layer program interpreter.
#   attn        : global causal self-attention (RoPE or M-RoPE)
#   attn_local  : sliding-window causal self-attention
#   attn_enc    : bidirectional self-attention (encoder stacks)
#   attn_xdec   : decoder layer with causal self-attn + cross-attention
#   rglru       : RecurrentGemma recurrent block (conv1d + RG-LRU)
#   rwkv        : RWKV6 time-mix (data-dependent decay linear attention)
# Each layer is (mixer, mlp); mlp kind is per-config (dense swiglu / moe /
# rwkv channel-mix) unless overridden by ``moe_every``.
# ---------------------------------------------------------------------------

ATTN_KINDS = ("attn", "attn_local", "attn_enc", "attn_xdec")
RECURRENT_KINDS = ("rglru", "rwkv")


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_ff: int                     # per-expert hidden size
    shared_expert_ff: int = 0     # 0 = no shared expert
    capacity_factor: float = 1.25
    router_dtype: str = "float32"


@dataclasses.dataclass(frozen=True)
class MemoryPolicy:
    """The paper's unified-memory policy (C1/C4) applied to the LM stack.

    ``offload_optimizer``: place AdamW moments in ``pinned_host`` memory.
    ``offload_kv_spill``: serve-time KV pages beyond ``kv_hot_window`` may be
    placed in host memory (unified address space; compute follows data).
    ``pool_min_elems``: Umpire-style pooling threshold (paper: 5K elements).
    """
    offload_optimizer: bool = False
    offload_kv_spill: bool = False
    kv_hot_window: int = 8192
    pool_min_elems: int = 5120
    # the SizeRouter threshold — the paper's empirical TARGET_CUT_OFF as a
    # config value: under `--policy adaptive` the serve/train drivers build
    # AdaptivePolicy(cutoff=target_cutoff) (repro.launch.policy.lm_policy),
    # so calls whose largest operand exceeds it route to the device
    # executable and smaller ones stay on host (paper C3, listings 4-6)
    target_cutoff: int = 16384


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                   # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: Optional[int] = None        # default: d_model // n_heads
    # --- layer pattern -----------------------------------------------------
    # cycle of mixer kinds, tiled (and truncated) to n_layers.
    layer_cycle: Tuple[str, ...] = ("attn",)
    window: int = 0                       # sliding window for attn_local
    # --- MoE ----------------------------------------------------------------
    moe: Optional[MoEConfig] = None
    moe_every: int = 1                    # MoE mlp on layers where i % moe_every == moe_offset
    moe_offset: int = 0
    # --- embeddings / head --------------------------------------------------
    tie_embeddings: bool = True
    qkv_bias: bool = False
    rope_theta: float = 10000.0
    mrope_sections: Optional[Tuple[int, int, int]] = None   # qwen2-vl M-RoPE
    # --- enc-dec (whisper) --------------------------------------------------
    n_enc_layers: int = 0                 # >0 => encoder-decoder
    enc_len: int = 1500                   # stub frontend frame count
    # --- recurrent (rwkv / rglru) -------------------------------------------
    rnn_width: int = 0                    # RG-LRU recurrence width (0 = d_model)
    conv_width: int = 4                   # RG-LRU temporal conv
    # --- numerics ------------------------------------------------------------
    param_dtype: str = "bfloat16"
    compute_dtype: str = "bfloat16"
    # --- runtime policy -------------------------------------------------------
    memory: MemoryPolicy = dataclasses.field(default_factory=MemoryPolicy)
    # --- provenance -----------------------------------------------------------
    source: str = ""

    # ----- derived -----------------------------------------------------------
    @property
    def hd(self) -> int:
        return self.head_dim or (self.d_model // self.n_heads)

    @property
    def layer_kinds(self) -> Tuple[str, ...]:
        reps = -(-self.n_layers // len(self.layer_cycle))
        return tuple((self.layer_cycle * reps)[: self.n_layers])

    def is_moe_layer(self, i: int) -> bool:
        return self.moe is not None and (i % self.moe_every) == self.moe_offset

    @property
    def sub_quadratic(self) -> bool:
        """True if no layer needs an unbounded-length KV cache."""
        return all(k in RECURRENT_KINDS or k == "attn_local" for k in self.layer_kinds)

    @property
    def n_params(self) -> int:
        """Analytic parameter count (used for 6ND roofline term)."""
        d, v, hd = self.d_model, self.vocab, self.hd
        emb = v * d if self.tie_embeddings else 2 * v * d
        total = emb
        for i, kind in enumerate(self.layer_kinds):
            if kind in ATTN_KINDS:
                qk = d * self.n_heads * hd + d * self.n_kv_heads * hd * 2
                o = self.n_heads * hd * d
                total += qk + o
                if kind == "attn_xdec":      # cross-attention too
                    total += qk + o
            elif kind == "rglru":
                w = self.rnn_width or d
                total += 2 * d * w + w * d + w * self.conv_width + 3 * w
            elif kind == "rwkv":
                total += 4 * d * self.n_heads * self.hd + self.n_heads * self.hd * d
                total += 6 * 32 * d  # lora-style ddlerp adapters (approx)
            if self.is_moe_layer(i):
                m = self.moe
                total += d * m.n_experts                      # router
                total += m.n_experts * 3 * d * m.d_ff         # experts
                if m.shared_expert_ff:
                    total += 3 * d * m.shared_expert_ff
            elif kind == "rwkv":
                total += 2 * d * int(3.5 * d)                # channel-mix
            else:
                total += 3 * d * self.d_ff                   # swiglu
            total += 2 * d                                    # norms
        if self.n_enc_layers:
            per = 2 * (d * self.n_heads * hd + d * self.n_kv_heads * hd) + 3 * d * self.d_ff + 2 * d
            total += self.n_enc_layers * per
        return int(total)

    @property
    def n_active_params(self) -> int:
        """Active params per token (MoE: top_k experts only)."""
        if self.moe is None:
            return self.n_params
        m = self.moe
        full_moe = 0
        active_moe = 0
        for i in range(self.n_layers):
            if self.is_moe_layer(i):
                full_moe += m.n_experts * 3 * self.d_model * m.d_ff
                active_moe += m.top_k * 3 * self.d_model * m.d_ff
        return self.n_params - full_moe + active_moe


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    """One input-shape cell (assignment: 4 per arch)."""
    name: str
    seq_len: int
    global_batch: int
    kind: str                     # train | prefill | decode

    @property
    def tokens(self) -> int:
        return self.seq_len * self.global_batch


SHAPES = (
    ShapeSpec("train_4k", 4096, 256, "train"),
    ShapeSpec("prefill_32k", 32768, 32, "prefill"),
    ShapeSpec("decode_32k", 32768, 128, "decode"),
    ShapeSpec("long_500k", 524288, 1, "decode"),
)
SHAPE_BY_NAME = {s.name: s for s in SHAPES}


def shape_applicable(cfg: ModelConfig, shape: ShapeSpec) -> Tuple[bool, str]:
    """Assignment rules: long_500k only for sub-quadratic-capable archs."""
    if shape.name == "long_500k":
        ok = any(k in RECURRENT_KINDS or k == "attn_local" for k in cfg.layer_kinds)
        if not ok:
            return False, "long_500k skipped: pure full-attention arch (see docs/DESIGN.md §7)"
    return True, ""
