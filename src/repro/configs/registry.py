"""Registry of assigned architectures (+ the paper's own CFD case).

Each ``src/repro/configs/<id>.py`` exposes ``CONFIG``; this module collects
them. ``--arch <id>`` everywhere resolves through :func:`get_config`.
"""
from __future__ import annotations

import importlib
from typing import Dict

from repro.configs.base import ModelConfig

ARCH_IDS = (
    "rwkv6_7b",
    "qwen2_vl_72b",
    "recurrentgemma_9b",
    "llama3_2_3b",
    "tinyllama_1_1b",
    "gemma3_1b",
    "qwen2_5_32b",
    "llama4_scout_17b_a16e",
    "qwen3_moe_30b_a3b",
    "whisper_large_v3",
)

# assignment ids use dashes; module names use underscores
ALIASES = {i.replace("_", "-"): i for i in ARCH_IDS}
ALIASES.update({
    "rwkv6-7b": "rwkv6_7b",
    "qwen2-vl-72b": "qwen2_vl_72b",
    "recurrentgemma-9b": "recurrentgemma_9b",
    "llama3.2-3b": "llama3_2_3b",
    "tinyllama-1.1b": "tinyllama_1_1b",
    "gemma3-1b": "gemma3_1b",
    "qwen2.5-32b": "qwen2_5_32b",
    "llama4-scout-17b-a16e": "llama4_scout_17b_a16e",
    "qwen3-moe-30b-a3b": "qwen3_moe_30b_a3b",
    "whisper-large-v3": "whisper_large_v3",
})

_cache: Dict[str, ModelConfig] = {}


def get_config(arch: str) -> ModelConfig:
    key = ALIASES.get(arch, arch).replace("-", "_").replace(".", "_")
    if key not in _cache:
        if key not in ARCH_IDS:
            raise KeyError(f"unknown arch {arch!r}; known: {sorted(ALIASES)}")
        mod = importlib.import_module(f"repro.configs.{key}")
        _cache[key] = mod.CONFIG
    return _cache[key]


def all_configs() -> Dict[str, ModelConfig]:
    return {a: get_config(a) for a in ARCH_IDS}
