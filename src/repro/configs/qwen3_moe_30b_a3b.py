"""Qwen3 MoE 30B-A3B — 128 experts, top-8, fine-grained d_ff=768 [hf:Qwen/Qwen3-30B-A3B]."""
from repro.configs.base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="qwen3-moe-30b-a3b", family="moe",
    n_layers=48, d_model=2048, n_heads=32, n_kv_heads=4, head_dim=128,
    d_ff=768, vocab=151936,
    layer_cycle=("attn",), rope_theta=1e6,
    moe=MoEConfig(n_experts=128, top_k=8, d_ff=768),
    moe_every=1, tie_embeddings=False,
    source="hf:Qwen/Qwen3-30B-A3B",
)
