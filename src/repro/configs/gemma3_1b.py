"""Gemma 3 1B — 5:1 local:global attention, 262k vocab [hf:google/gemma-3-1b-pt]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="gemma3-1b", family="dense",
    n_layers=26, d_model=1152, n_heads=4, n_kv_heads=1, head_dim=256,
    d_ff=6912, vocab=262144,
    layer_cycle=("attn_local",) * 5 + ("attn",), window=512,
    rope_theta=1e6, tie_embeddings=True,
    source="hf:google/gemma-3-1b-pt",
)
