"""RWKV6 "Finch" 7B — attention-free, data-dependent decay [arXiv:2404.05892; hf]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="rwkv6-7b", family="ssm",
    n_layers=32, d_model=4096, n_heads=64, n_kv_heads=64, head_dim=64,
    d_ff=14336, vocab=65536,
    layer_cycle=("rwkv",),
    tie_embeddings=False,
    source="arXiv:2404.05892; hf:RWKV/rwkv-6-world-7b",
)
