"""RecurrentGemma 9B — RG-LRU + local attention, pattern (rec, rec, attn) [arXiv:2402.19427]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="recurrentgemma-9b", family="hybrid",
    n_layers=38, d_model=4096, n_heads=16, n_kv_heads=1, head_dim=256,
    d_ff=12288, vocab=256000,
    layer_cycle=("rglru", "rglru", "attn_local"), window=2048,
    rnn_width=4096, conv_width=4,
    tie_embeddings=True,
    source="arXiv:2402.19427 (Griffin); hf:google/recurrentgemma-9b",
)
