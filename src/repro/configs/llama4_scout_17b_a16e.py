"""Llama 4 Scout 17B-active / 16 experts — MoE top-1 + shared expert, early fusion
[hf:meta-llama/Llama-4-Scout-17B-16E]."""
from repro.configs.base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="llama4-scout-17b-a16e", family="moe",
    n_layers=48, d_model=5120, n_heads=40, n_kv_heads=8, head_dim=128,
    d_ff=8192, vocab=202048,
    layer_cycle=("attn",), rope_theta=500000.0,
    moe=MoEConfig(n_experts=16, top_k=1, d_ff=8192, shared_expert_ff=8192),
    moe_every=1, tie_embeddings=False,
    source="hf:meta-llama/Llama-4-Scout-17B-16E",
)
