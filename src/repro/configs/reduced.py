"""Reduced configs: same family/pattern/structure, smoke-test scale.

Per the assignment, each architecture's SMOKE test instantiates a reduced
config of the same family (few layers/width, few experts, tiny vocab) and
runs a real forward/train step on CPU. The reduction preserves: the layer
cycle pattern (incl. remainder handling), GQA ratio, MoE routing (top_k),
enc-dec structure, M-RoPE sections, tying, biases.
"""
from __future__ import annotations

import dataclasses

from repro.configs.base import ModelConfig, MoEConfig


def reduced(cfg: ModelConfig, *, d_model: int = 64, head_dim: int = 16,
            vocab: int = 512, d_ff: int = 128, max_cycles: int = 2) -> ModelConfig:
    cyc = len(cfg.layer_cycle)
    rem = cfg.n_layers % cyc
    n_layers = min(cfg.n_layers, max_cycles * cyc + rem)
    n_heads = max(2, min(4, cfg.n_heads))
    ratio = max(1, cfg.n_heads // max(cfg.n_kv_heads, 1))
    n_kv = max(1, n_heads // ratio)
    moe = None
    if cfg.moe is not None:
        moe = MoEConfig(
            n_experts=min(8, cfg.moe.n_experts),
            top_k=min(cfg.moe.top_k, min(8, cfg.moe.n_experts)),
            d_ff=min(64, cfg.moe.d_ff),
            shared_expert_ff=64 if cfg.moe.shared_expert_ff else 0,
            capacity_factor=2.0,                   # avoid drops at tiny scale
        )
    return dataclasses.replace(
        cfg,
        name=cfg.name + "-reduced",
        n_layers=n_layers,
        d_model=d_model,
        n_heads=n_heads,
        n_kv_heads=n_kv,
        head_dim=head_dim,
        d_ff=d_ff,
        vocab=vocab,
        window=min(cfg.window, 16) if cfg.window else 0,
        moe=moe,
        mrope_sections=(4, 2, 2) if cfg.mrope_sections else None,
        n_enc_layers=min(cfg.n_enc_layers, 2),
        enc_len=min(cfg.enc_len, 16) if cfg.n_enc_layers else cfg.enc_len,
        rnn_width=d_model if cfg.rnn_width else 0,
    )
