"""Qwen2-VL 72B backbone — M-RoPE, dynamic resolution [arXiv:2409.12191; hf].

The vision frontend is a STUB per the assignment: ``input_specs()`` provides
precomputed patch embeddings merged into the token stream, plus 3-component
M-RoPE position ids.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-vl-72b", family="vlm",
    n_layers=80, d_model=8192, n_heads=64, n_kv_heads=8, head_dim=128,
    d_ff=29568, vocab=152064,
    layer_cycle=("attn",),
    qkv_bias=True, tie_embeddings=False, rope_theta=1e6,
    mrope_sections=(16, 24, 24),
    source="arXiv:2409.12191; hf:Qwen/Qwen2-VL-72B",
)
