"""Train / serve step functions — the units the launcher jits and lowers.

The train step also ships region-decomposed (``make_train_regions``): two
directive-sized :class:`~repro.core.regions.Region`\\ s — ``FWD_BWD`` and
``ADAMW_UPDATE`` — so the LM stack rides the same Region x ExecutionPolicy
spine as the CFD case study.  Optimizer offload is a *placement-axis* hint
on ``ADAMW_UPDATE``'s ``opt_state`` argument (paper C1: the policy's
Placer decides, not hand-rolled ``place_like`` calls), and the update
registers a ``host`` implementation variant so ``TargetSelector`` /
``AutotuneSelector`` can pick the host-tuned path per call.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core.ledger import Ledger
from repro.core.regions import region
from repro.core.umem import preferred_host_space
from repro.models import transformer as T
from repro.models.layers import noshard
from repro.optim import adamw

MOE_AUX_WEIGHT = 0.01


def cross_entropy(logits, targets, shd=noshard):
    """Next-token CE in fp32; logits [B,S,V] (already shifted by caller)."""
    lf = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(lf, axis=-1)
    gold = jnp.take_along_axis(lf, targets[..., None], axis=-1)[..., 0]
    return jnp.mean(lse - gold)


def loss_fn(params, batch, cfg: ModelConfig, ctx: T.Ctx):
    logits, aux = T.forward_train(params, batch, cfg, ctx)
    ce = cross_entropy(logits[:, :-1], batch["tokens"][:, 1:], ctx.shd)
    return ce + MOE_AUX_WEIGHT * aux, (ce, aux)


def make_train_step(cfg: ModelConfig, opt_cfg: adamw.AdamWConfig,
                    make_ctx=None):
    """Returns train_step(params, opt_state, batch) -> (params, opt, metrics)."""
    make_ctx = make_ctx or (lambda: T.Ctx(mode="train"))

    def train_step(params, opt_state, batch):
        ctx = make_ctx()
        (loss, (ce, aux)), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(params, batch, cfg, ctx)
        params, opt_state, gnorm = adamw.apply_updates(
            params, grads, opt_state, opt_cfg)
        metrics = {"loss": loss, "ce": ce, "moe_aux": aux, "grad_norm": gnorm}
        return params, opt_state, metrics

    return train_step


# ---------------------------------------------------------------------------
# The train step on the region spine
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class TrainRegions:
    """The train step decomposed into directive-sized regions."""
    fwd_bwd: Any            # (params, batch)             -> (grads, metrics)
    adamw_update: Any       # (params, grads, opt_state)  -> (params, opt, gnorm)


def make_train_regions(cfg: ModelConfig, opt_cfg: adamw.AdamWConfig,
                       make_ctx=None, *, ledger: Optional[Ledger] = None,
                       offload_optimizer: bool = False) -> TrainRegions:
    """``FWD_BWD`` + ``ADAMW_UPDATE`` as Regions on one ledger.

    ``offload_optimizer`` attaches host-space :class:`MemSpace` hints to
    ``ADAMW_UPDATE``: on the ``opt_state`` argument AND on the
    ``opt_state`` element of the result (keyed ``result_space``), so the
    policy's Placer keeps the AdamW moments host-resident *between* steps
    — the freshly computed moments are re-homed each update instead of
    lingering in device memory until the next call (min_bytes-gated, so
    the scalar step counter stays put).  The math never changes; only the
    placement axis does.
    """
    make_ctx = make_ctx or (lambda: T.Ctx(mode="train"))

    @region("FWD_BWD", ledger=ledger)
    def fwd_bwd(params, batch):
        ctx = make_ctx()
        (loss, (ce, aux)), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(params, batch, cfg, ctx)
        return grads, {"loss": loss, "ce": ce, "moe_aux": aux}

    placement = result_hint = None
    if offload_optimizer:
        host_space = preferred_host_space()
        if host_space is not None:
            placement = {"opt_state": host_space}
            result_hint = {1: host_space}     # of (params, opt_state, gnorm)

    @region("ADAMW_UPDATE", ledger=ledger, placement=placement,
            result_space=result_hint)
    def adamw_update(params, grads, opt_state):
        return adamw.apply_updates(params, grads, opt_state, opt_cfg)

    @adamw_update.variant("host")
    def _adamw_update_host(params, grads, opt_state):
        return adamw.apply_updates_leafwise(params, grads, opt_state,
                                            opt_cfg)

    return TrainRegions(fwd_bwd=fwd_bwd, adamw_update=adamw_update)


def capture_train_program(regions: TrainRegions, example_state,
                          example_batch, name: str = "train_step"):
    """One train step captured as a :class:`RegionProgram`.

    ``state = (params, opt_state)`` and ``batch`` are program inputs;
    replaying under any executor re-issues ``FWD_BWD`` then
    ``ADAMW_UPDATE`` with the recorded dataflow, so a supervisor restart
    can re-capture against restored state while the regions — and their
    ledger rows — stay the same objects (accounting accumulates across
    restarts instead of forking new rows)."""
    from repro.core.program import capture

    def step(run, state, batch):
        params, opt_state = state
        grads, metrics = run(regions.fwd_bwd, params, batch)
        params, opt_state, gnorm = run(regions.adamw_update, params, grads,
                                       opt_state)
        return (params, opt_state), {**metrics, "grad_norm": gnorm}

    return capture(step, example_state, example_batch, name=name)


def make_prefill_step(cfg: ModelConfig, make_ctx=None):
    make_ctx = make_ctx or (lambda: T.Ctx(mode="prefill"))

    def prefill_step(params, batch, caches):
        ctx = make_ctx()
        logits, caches = T.prefill(params, batch, cfg, ctx, caches)
        return logits, caches

    return prefill_step


def make_decode_step(cfg: ModelConfig, make_ctx=None):
    make_ctx = make_ctx or (lambda: T.Ctx(mode="decode"))

    def decode_step(params, token, caches, pos):
        ctx = make_ctx()
        logits, caches = T.decode_step(params, token, caches, pos, cfg, ctx)
        return logits, caches

    return decode_step


# ---------------------------------------------------------------------------
# Abstract inputs for lowering (the dry-run path: ShapeDtypeStruct only)
# ---------------------------------------------------------------------------

def abstract_batch(cfg: ModelConfig, batch: int, seq: int) -> Dict[str, Any]:
    b: Dict[str, Any] = {
        "tokens": jax.ShapeDtypeStruct((batch, seq), jnp.int32),
    }
    if cfg.mrope_sections is not None:
        b["positions3"] = jax.ShapeDtypeStruct((batch, seq, 3), jnp.int32)
    if cfg.n_enc_layers:
        b["enc_embeds"] = jax.ShapeDtypeStruct(
            (batch, cfg.enc_len, cfg.d_model), jnp.dtype(cfg.compute_dtype))
    return b


def demo_batch(key, cfg: ModelConfig, batch: int, seq: int) -> Dict[str, Any]:
    """Synthetic concrete batch matching abstract_batch (smoke tests)."""
    ks = jax.random.split(key, 3)
    b: Dict[str, Any] = {
        "tokens": jax.random.randint(ks[0], (batch, seq), 0, cfg.vocab, jnp.int32),
    }
    if cfg.mrope_sections is not None:
        pos = jnp.arange(seq, dtype=jnp.int32)[None, :, None]
        b["positions3"] = jnp.broadcast_to(pos, (batch, seq, 3))
    if cfg.n_enc_layers:
        b["enc_embeds"] = jax.random.normal(
            ks[1], (batch, cfg.enc_len, cfg.d_model), jnp.float32
        ).astype(cfg.compute_dtype)
    return b
