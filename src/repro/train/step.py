"""Train / serve step functions — the units the launcher jits and lowers."""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import transformer as T
from repro.models.layers import noshard
from repro.optim import adamw

MOE_AUX_WEIGHT = 0.01


def cross_entropy(logits, targets, shd=noshard):
    """Next-token CE in fp32; logits [B,S,V] (already shifted by caller)."""
    lf = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(lf, axis=-1)
    gold = jnp.take_along_axis(lf, targets[..., None], axis=-1)[..., 0]
    return jnp.mean(lse - gold)


def loss_fn(params, batch, cfg: ModelConfig, ctx: T.Ctx):
    logits, aux = T.forward_train(params, batch, cfg, ctx)
    ce = cross_entropy(logits[:, :-1], batch["tokens"][:, 1:], ctx.shd)
    return ce + MOE_AUX_WEIGHT * aux, (ce, aux)


def make_train_step(cfg: ModelConfig, opt_cfg: adamw.AdamWConfig,
                    make_ctx=None):
    """Returns train_step(params, opt_state, batch) -> (params, opt, metrics)."""
    make_ctx = make_ctx or (lambda: T.Ctx(mode="train"))

    def train_step(params, opt_state, batch):
        ctx = make_ctx()
        (loss, (ce, aux)), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(params, batch, cfg, ctx)
        params, opt_state, gnorm = adamw.apply_updates(
            params, grads, opt_state, opt_cfg)
        metrics = {"loss": loss, "ce": ce, "moe_aux": aux, "grad_norm": gnorm}
        return params, opt_state, metrics

    return train_step


def make_prefill_step(cfg: ModelConfig, make_ctx=None):
    make_ctx = make_ctx or (lambda: T.Ctx(mode="prefill"))

    def prefill_step(params, batch, caches):
        ctx = make_ctx()
        logits, caches = T.prefill(params, batch, cfg, ctx, caches)
        return logits, caches

    return prefill_step


def make_decode_step(cfg: ModelConfig, make_ctx=None):
    make_ctx = make_ctx or (lambda: T.Ctx(mode="decode"))

    def decode_step(params, token, caches, pos):
        ctx = make_ctx()
        logits, caches = T.decode_step(params, token, caches, pos, cfg, ctx)
        return logits, caches

    return decode_step


# ---------------------------------------------------------------------------
# Abstract inputs for lowering (the dry-run path: ShapeDtypeStruct only)
# ---------------------------------------------------------------------------

def abstract_batch(cfg: ModelConfig, batch: int, seq: int) -> Dict[str, Any]:
    b: Dict[str, Any] = {
        "tokens": jax.ShapeDtypeStruct((batch, seq), jnp.int32),
    }
    if cfg.mrope_sections is not None:
        b["positions3"] = jax.ShapeDtypeStruct((batch, seq, 3), jnp.int32)
    if cfg.n_enc_layers:
        b["enc_embeds"] = jax.ShapeDtypeStruct(
            (batch, cfg.enc_len, cfg.d_model), jnp.dtype(cfg.compute_dtype))
    return b


def demo_batch(key, cfg: ModelConfig, batch: int, seq: int) -> Dict[str, Any]:
    """Synthetic concrete batch matching abstract_batch (smoke tests)."""
    ks = jax.random.split(key, 3)
    b: Dict[str, Any] = {
        "tokens": jax.random.randint(ks[0], (batch, seq), 0, cfg.vocab, jnp.int32),
    }
    if cfg.mrope_sections is not None:
        pos = jnp.arange(seq, dtype=jnp.int32)[None, :, None]
        b["positions3"] = jnp.broadcast_to(pos, (batch, seq, 3))
    if cfg.n_enc_layers:
        b["enc_embeds"] = jax.random.normal(
            ks[1], (batch, cfg.enc_len, cfg.d_model), jnp.float32
        ).astype(cfg.compute_dtype)
    return b
