"""Jitted public wrappers for the fused-field Pallas kernel."""
import jax

from repro.kernels.fused_field import kernel as _k

fused_axpy = jax.jit(_k.fused_axpy)
fused_xpay = jax.jit(_k.fused_xpay)
fused_mul = jax.jit(_k.fused_mul)
fused_axpbypz = jax.jit(_k.fused_axpbypz)
