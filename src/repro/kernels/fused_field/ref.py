"""Pure-jnp oracle for the fused field macros."""
import jax.numpy as jnp


def fused_axpy(a, x, y):
    return y + a * x


def fused_xpay(a, x, y):
    return x + a * y


def fused_mul(x, y):
    return x * y


def fused_axpbypz(a, x, b, y, z):
    return z + a * x + b * y
