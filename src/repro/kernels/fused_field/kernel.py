"""Pallas kernel: fused ternary field macros (TFOR_ALL_F_OP_F_OP_F).

The paper's hottest offloaded loops are elementwise field expressions fired
hundreds of times per time-step (listing 4, Fig 3). Unfused, each OP is a
separate pass over HBM; the fused kernel reads each operand once and writes
once — on TPU these loops are VPU/bandwidth-bound, so fusion is the entire
win. BlockSpec tiles the (flattened, lane-padded) field into
``(BLOCK_ROWS, 128)`` VMEM tiles.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

LANES = 128
BLOCK_ROWS = 256            # 256x128 f32 tile = 128 KiB VMEM per operand


def _axpy_kernel(a_ref, x_ref, y_ref, o_ref):
    # o = y + a*x
    a = a_ref[0, 0]
    o_ref[...] = y_ref[...] + a * x_ref[...]


def _xpay_kernel(a_ref, x_ref, y_ref, o_ref):
    # o = x + a*y
    a = a_ref[0, 0]
    o_ref[...] = x_ref[...] + a * y_ref[...]


def _mul_kernel(x_ref, y_ref, o_ref):
    o_ref[...] = x_ref[...] * y_ref[...]


def _axpbypz_kernel(a_ref, b_ref, x_ref, y_ref, z_ref, o_ref):
    # o = z + a*x + b*y   (momentum-corrector shape)
    a = a_ref[0, 0]
    b = b_ref[0, 0]
    o_ref[...] = z_ref[...] + a * x_ref[...] + b * y_ref[...]


def _pad_2d(x):
    """Flatten to (rows, 128) with zero padding; return (x2d, orig_size)."""
    n = x.size
    rows = -(-n // LANES)
    rows_pad = -(-rows // BLOCK_ROWS) * BLOCK_ROWS
    flat = jnp.pad(x.reshape(-1), (0, rows_pad * LANES - n))
    return flat.reshape(rows_pad, LANES), n


def _run_elementwise(kernel, scalars, arrays, out_dtype):
    """Common driver: tile arrays, broadcast scalars via SMEM-like (1,1)."""
    x0 = arrays[0]
    tiled, n = zip(*[_pad_2d(a) for a in arrays])
    rows = tiled[0].shape[0]
    grid = (rows // BLOCK_ROWS,)
    block = pl.BlockSpec((BLOCK_ROWS, LANES), lambda i: (i, 0))
    sblock = pl.BlockSpec((1, 1), lambda i: (0, 0))
    in_specs = []
    args = []
    for s in scalars:
        in_specs.append(sblock)
        args.append(jnp.asarray(s, out_dtype).reshape(1, 1))
    for t in tiled:
        in_specs.append(block)
        args.append(t)
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=block,
        out_shape=jax.ShapeDtypeStruct((rows, LANES), out_dtype),
        interpret=_INTERPRET,
    )(*args)
    return out.reshape(-1)[: n[0]].reshape(x0.shape)


_INTERPRET = True       # CPU container: interpret mode; flip on real TPU


def fused_axpy(a, x, y):
    return _run_elementwise(_axpy_kernel, [a], [x, y], x.dtype)


def fused_xpay(a, x, y):
    return _run_elementwise(_xpay_kernel, [a], [x, y], x.dtype)


def fused_mul(x, y):
    return _run_elementwise(_mul_kernel, [], [x, y], x.dtype)


def fused_axpbypz(a, x, b, y, z):
    return _run_elementwise(_axpbypz_kernel, [a, b], [x, y, z], x.dtype)
