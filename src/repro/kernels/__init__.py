"""Custom-kernel packages and their implementation-variant tables.

Each subpackage ``<name>/`` is one compute hot-spot with three files:

* ``kernel.py`` — the Pallas implementation (interpret mode on CPU
  containers; flip ``_INTERPRET`` on real hardware),
* ``ref.py``    — the pure-jnp oracle: the **ref** variant and the
  semantics anchor every other variant is tested against,
* ``ops.py``    — jitted public wrappers (used by the kernel's own tests).

The packages do NOT wire themselves into application code.  Application
regions declare them as named variants (``@some_region.variant("pallas")``,
``repro.core.regions``) and the executing policy's Selector axis picks one
per call — OpenMP 5.2's ``declare variant`` dispatch (docs/VARIANTS.md).
The live registrations are in ``repro.cfd.dia`` / ``precond`` / ``fields``
/ ``solvers`` and ``repro.models.rwkv6``.

Contract: every op of every package MUST carry a ``ref`` entry in
:func:`variant_tables` (CI runs :func:`check_ref_variants`), so the
declare-variant fallback — and the parity tests in tests/test_variants.py
— always have a base function to land on.
"""
from __future__ import annotations

from typing import Callable, Dict

#: the variant every kernel package must provide (the fallback target)
REQUIRED_VARIANT = "ref"

#: kernel subpackages participating in the variant contract
PACKAGES = ("stencil_spmv", "fused_field", "rwkv6_scan")


def variant_tables() -> Dict[str, Dict[str, Dict[str, Callable]]]:
    """``{package: {op: {variant: callable}}}`` for every kernel package.

    Imported lazily so merely importing ``repro.kernels`` never pulls the
    Pallas toolchain; callables are the *unjitted* implementations, ready
    for ``Region.variant`` registration or direct jitting."""
    from repro.kernels.fused_field import kernel as ffk, ref as ffr
    from repro.kernels.rwkv6_scan import kernel as rwk, ref as rwr
    from repro.kernels.stencil_spmv import kernel as ssk, ref as ssr

    return {
        "stencil_spmv": {
            "amul": {"ref": ssr.stencil_spmv, "pallas": ssk.stencil_spmv},
            "rb_dilu": {"ref": ssr.rb_dilu, "pallas": ssk.rb_dilu},
        },
        "fused_field": {
            "axpy": {"ref": ffr.fused_axpy, "pallas": ffk.fused_axpy},
            "xpay": {"ref": ffr.fused_xpay, "pallas": ffk.fused_xpay},
            "mul": {"ref": ffr.fused_mul, "pallas": ffk.fused_mul},
            "axpbypz": {"ref": ffr.fused_axpbypz,
                        "pallas": ffk.fused_axpbypz},
        },
        "rwkv6_scan": {
            "scan": {"ref": rwr.rwkv6_scan, "pallas": rwk.rwkv6_scan},
        },
    }


def _live_kernel_regions():
    """The Region objects that actually register kernel variants — the
    registrations the declare-variant fallback depends on at runtime."""
    from repro.cfd.dia import AMUL
    from repro.cfd.fields import make_field_ops
    from repro.cfd.precond import RB_DILU
    from repro.cfd.solvers import make_solver_regions
    from repro.models.rwkv6 import RWKV6_SCAN
    ops = make_field_ops()
    solver = make_solver_regions()
    return [AMUL, RB_DILU, RWKV6_SCAN,
            solver.amul, solver.precond, solver.saxpy, solver.update_x,
            ops.axpy, ops.xpay, ops.axpbypz, ops.fmul]


def check_ref_variants() -> Dict[str, int]:
    """Fail (SystemExit) unless every op of every kernel package ships a
    ``ref`` entry in :func:`variant_tables` AND every live kernel-backed
    Region registration carries both ``ref`` and a kernel variant; returns
    ``{package: op count}`` on success.  CI runs this as a dedicated job
    step.  Checking the real Region objects (not just the table literal)
    is what catches a package wired into application regions without a
    base-function fallback."""
    tables = variant_tables()
    missing = [pkg for pkg in PACKAGES if pkg not in tables]
    missing += [f"{pkg}.{op}" for pkg, ops in tables.items()
                for op, table in ops.items()
                if REQUIRED_VARIANT not in table]
    for r in _live_kernel_regions():
        if REQUIRED_VARIANT not in r.variants:
            missing.append(f"region:{r.name}")
        if len(r.variants) < 2:        # kernel-backed: ref alone is a lie
            missing.append(f"region:{r.name} (no kernel variant)")
    if missing:
        raise SystemExit(
            f"kernel packages/regions without a {REQUIRED_VARIANT!r} "
            f"variant: {missing}")
    return {pkg: len(ops) for pkg, ops in tables.items()}
