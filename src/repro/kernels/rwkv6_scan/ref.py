"""Oracles: sequential RWKV6 recurrence + the pure-JAX chunked form."""
import jax.numpy as jnp

from repro.models.rwkv6 import rwkv_chunk, rwkv_ref_scan


def rwkv6_scan(r, k, v, logw, u, chunk: int = 64):
    B, T, H, hd = r.shape
    S0 = jnp.zeros((B, H, hd, hd), jnp.float32)
    return rwkv_ref_scan(r, k, v, logw, u, S0)


def rwkv6_chunked(r, k, v, logw, u, chunk: int = 64):
    B, T, H, hd = r.shape
    S0 = jnp.zeros((B, H, hd, hd), jnp.float32)
    return rwkv_chunk(r, k, v, logw, u, S0, chunk)
