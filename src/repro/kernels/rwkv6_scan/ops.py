"""Jitted public wrapper for the RWKV6 chunked-scan kernel."""
from functools import partial

import jax

from repro.kernels.rwkv6_scan import kernel as _k

rwkv6_scan = jax.jit(_k.rwkv6_scan, static_argnames=("chunk",))
