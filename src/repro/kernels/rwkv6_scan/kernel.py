"""Pallas kernel: RWKV6 chunked linear-attention scan.

The perf-critical mixer of the rwkv6-7b assigned arch. Grid is
(batch*heads, T/C) with the chunk axis sequential ("arbitrary" semantics on
TPU): the [hd, hd] fp32 state lives in a VMEM scratch and is carried across
chunk steps — one HBM read of (r,k,v,logw) and one write of the output per
token, instead of the pure-JAX path's scan-carried HBM state round-trips.

Math is identical to ``repro.models.rwkv6.rwkv_chunk`` (the anchor
semantics; ``ref.py`` re-exports the sequential oracle): all decay exponents
are cumulative differences with t >= i, so everything stays <= 0 — no
overflow, no rescaling pass needed (the log-space-safety argument in
rwkv6.py applies unchanged inside the kernel).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

_INTERPRET = True
CHUNK = 64


def _kernel(C, hd, r_ref, k_ref, v_ref, lw_ref, u_ref, o_ref, s_out_ref,
            state_ref):
    ci = pl.program_id(1)
    nc = pl.num_programs(1)

    @pl.when(ci == 0)
    def _init():
        state_ref[...] = jnp.zeros((hd, hd), jnp.float32)

    r = r_ref[0].astype(jnp.float32)          # [C, hd]
    k = k_ref[0].astype(jnp.float32)
    v = v_ref[0].astype(jnp.float32)
    lw = lw_ref[0].astype(jnp.float32)
    u = u_ref[0].astype(jnp.float32)          # [1, hd] -> broadcast
    S = state_ref[...]

    la = jnp.cumsum(lw, axis=0)               # [C, hd]
    la_prev = la - lw
    rA = r * jnp.exp(la_prev)
    inter = rA @ S                             # [C, hd_v]

    # intra-chunk: att[t,i] = sum_d r[t,d] k[i,d] exp(la_prev[t,d]-la[i,d])
    D = la_prev[:, None, :] - la[None, :, :]   # [C, C, hd] (<= 0 for t > i)
    mask = (jax.lax.broadcasted_iota(jnp.int32, (C, C), 0) >
            jax.lax.broadcasted_iota(jnp.int32, (C, C), 1))
    D = jnp.where(mask[:, :, None], D, -jnp.inf)
    att = jnp.sum(r[:, None, :] * k[None, :, :] * jnp.exp(D), axis=-1)
    diag = jnp.sum(r * k * u, axis=-1)         # u-bonus for i == t
    att = att + jnp.where(
        jax.lax.broadcasted_iota(jnp.int32, (C, C), 0) ==
        jax.lax.broadcasted_iota(jnp.int32, (C, C), 1), diag[:, None], 0.0)
    intra = att @ v
    o_ref[0] = (inter + intra).astype(o_ref.dtype)

    la_C = la[-1]                              # [hd]
    kA = k * jnp.exp(la_C[None, :] - la)
    state_ref[...] = jnp.exp(la_C)[:, None] * S + kA.T @ v

    @pl.when(ci == nc - 1)
    def _flush():
        s_out_ref[0] = state_ref[...]


def rwkv6_scan(r, k, v, logw, u, chunk: int = CHUNK):
    """r,k,v,logw [B,T,H,hd]; u [H,hd]. Returns (out [B,T,H,hd] f32,
    S_final [B,H,hd,hd] f32). Zero initial state (prefill semantics)."""
    B, T, H, hd = r.shape
    C = min(chunk, T)
    assert T % C == 0, (T, C)
    nc = T // C

    def bh(x):     # [B,T,H,hd] -> [B*H, T, hd]
        return jnp.moveaxis(x, 2, 1).reshape(B * H, T, hd)

    rb, kb, vb, lwb = bh(r), bh(k), bh(v), bh(logw)
    ub = jnp.broadcast_to(u[None], (B, H, hd)).reshape(B * H, 1, hd)

    io_spec = pl.BlockSpec((1, C, hd), lambda b, c: (b, c, 0))
    u_spec = pl.BlockSpec((1, 1, hd), lambda b, c: (b, 0, 0))
    out, s_final = pl.pallas_call(
        functools.partial(_kernel, C, hd),
        grid=(B * H, nc),
        in_specs=[io_spec, io_spec, io_spec, io_spec, u_spec],
        out_specs=[io_spec,
                   pl.BlockSpec((1, hd, hd), lambda b, c: (b, 0, 0))],
        out_shape=[jax.ShapeDtypeStruct((B * H, T, hd), jnp.float32),
                   jax.ShapeDtypeStruct((B * H, hd, hd), jnp.float32)],
        scratch_shapes=[pltpu.VMEM((hd, hd), jnp.float32)],
        interpret=_INTERPRET,
    )(rb, kb, vb, lwb, ub)
    out = jnp.moveaxis(out.reshape(B, H, T, hd), 1, 2)
    return out, s_final.reshape(B, H, hd, hd)
