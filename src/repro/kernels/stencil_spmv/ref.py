"""Pure-jnp oracles (shifted-stencil forms from repro.cfd)."""
from repro.cfd.dia import DiaMatrix, amul_ref
from repro.cfd.precond import RBDilu, rb_dilu_apply


def stencil_spmv(diag, off, x):
    return amul_ref(DiaMatrix(diag, off), x)


def rb_dilu(rdiag, red, off, r):
    return rb_dilu_apply(RBDilu(rdiag, red), DiaMatrix(rdiag * 0, off), r)
