"""Pallas kernel: 7-point DIA stencil SpMV (OpenFOAM lduMatrix::Amul on TPU).

TPU adaptation (docs/DESIGN.md §2): the unstructured LDU face-list gather/scatter
becomes, on a structured grid, y[i] = d[i]*x[i] + sum_f off[f][i]*x[i+s_f]
with six constant strides s_f in the flattened index space. The kernel
processes the flat field in VMEM chunks; the input is pre-padded by the
largest stride H = ny*nz so every neighbor access is a static in-window
slice of one contiguous [C + 2H] window loaded per chunk (manual halo —
the TPU-native substitute for gathers). All 13 reads + 1 write per cell
happen in one HBM pass, where the unfused jnp form makes 7 passes.

Layout: flat vectors are viewed as (rows, 128) lanes; the window is loaded
from an ANY-space (HBM) ref with ``pl.ds`` and reshaped in VMEM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

_INTERPRET = True
CHUNK = 32768                      # cells per grid step (multiple of 128)


def _strides(shape3):
    nx, ny, nz = shape3
    return (-ny * nz, ny * nz, -nz, nz, -1, 1)   # (-x,+x,-y,+y,-z,+z)


def _kernel(strides, C, H, dflat_ref, offs_ref, xpad_ref, y_ref):
    i = pl.program_id(0)
    base = i * C
    win = xpad_ref[pl.ds(base, C + 2 * H)]        # halo window -> VMEM
    d = dflat_ref[pl.ds(base, C)]
    acc = d * win[H:H + C]
    for f, s in enumerate(strides):
        off = offs_ref[f, pl.ds(base, C)]
        acc = acc + off * win[H + s:H + s + C]
    y_ref[...] = acc


def stencil_spmv(diag, off, x):
    """diag [nx,ny,nz]; off [6,nx,ny,nz]; x [nx,ny,nz] -> y = A x."""
    shape3 = diag.shape
    n = diag.size
    H = shape3[1] * shape3[2]
    C = min(CHUNK, -(-n // 128) * 128)
    npad = -(-n // C) * C
    dflat = jnp.pad(diag.reshape(-1), (0, npad - n))
    offs = jnp.pad(off.reshape(6, -1), ((0, 0), (0, npad - n)))
    xpad = jnp.pad(x.reshape(-1), (H, npad - n + H))
    grid = (npad // C,)
    strides = _strides(shape3)
    out = pl.pallas_call(
        functools.partial(_kernel, strides, C, H),
        grid=grid,
        in_specs=[
            pl.BlockSpec(memory_space=pl.ANY),     # dflat (manual slices)
            pl.BlockSpec(memory_space=pl.ANY),     # offs
            pl.BlockSpec(memory_space=pl.ANY),     # xpad (halo window)
        ],
        out_specs=pl.BlockSpec((C,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((npad,), x.dtype),
        interpret=_INTERPRET,
    )(dflat, offs, xpad)
    return out[:n].reshape(shape3)


def _rb_kernel(strides, C, H, rdiag_ref, red_ref, offs_ref, rpad_ref, w_ref):
    """Fused two-color DILU apply on the flat layout (one pass per color
    pair instead of six shifted jnp passes)."""
    i = pl.program_id(0)
    base = i * C
    rwin = rpad_ref[pl.ds(base, C + 2 * H)]
    rd = rdiag_ref[pl.ds(base, C + 2 * H)]
    red = red_ref[pl.ds(base, C + 2 * H)]
    blk = 1.0 - red

    def nbsum(field):
        acc = jnp.zeros((C,), field.dtype)
        for f, s in enumerate(strides):
            off = offs_ref[f, pl.ds(base, C)]
            acc = acc + off * field[H + s:H + s + C]
        return acc

    # forward: y_r over the whole window (needed for black neighbor sums)
    y_r_win = red * rwin * rd
    y_b = blk[H:H + C] * (rwin[H:H + C] - nbsum(y_r_win)) * rd[H:H + C]
    w_ref[...] = y_r_win[H:H + C] + y_b


def _rb_back_kernel(strides, C, H, rdiag_ref, red_ref, offs_ref, ypad_ref,
                    w_ref):
    """Backward half-sweep: z_b = y_b ; z_r = y_r - rd * sum U_rb y_b."""
    i = pl.program_id(0)
    base = i * C
    ywin = ypad_ref[pl.ds(base, C + 2 * H)]
    rd = rdiag_ref[pl.ds(base, C + 2 * H)]
    red = red_ref[pl.ds(base, C + 2 * H)]
    yb_win = (1.0 - red) * ywin

    acc = jnp.zeros((C,), ywin.dtype)
    for f, s in enumerate(strides):
        off = offs_ref[f, pl.ds(base, C)]
        acc = acc + off * yb_win[H + s:H + s + C]
    yc = ywin[H:H + C]
    redc = red[H:H + C]
    w_ref[...] = redc * (yc - rd[H:H + C] * acc) + (1.0 - redc) * yc


def rb_dilu_forward(rdiag, red, off, r):
    """Forward half-sweep of the two-color DILU (see precond.py). The
    backward half reuses the same kernel on reversed colors."""
    shape3 = r.shape
    n = r.size
    H = shape3[1] * shape3[2]
    C = min(CHUNK, -(-n // 128) * 128)
    npad = -(-n // C) * C
    rdp = jnp.pad(rdiag.reshape(-1), (H, npad - n + H))
    redp = jnp.pad(red.astype(r.dtype).reshape(-1), (H, npad - n + H))
    offs = jnp.pad(off.reshape(6, -1), ((0, 0), (0, npad - n)))
    rp = jnp.pad(r.reshape(-1), (H, npad - n + H))
    strides = _strides(shape3)
    out = pl.pallas_call(
        functools.partial(_rb_kernel, strides, C, H),
        grid=(npad // C,),
        in_specs=[pl.BlockSpec(memory_space=pl.ANY)] * 4,
        out_specs=pl.BlockSpec((C,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((npad,), r.dtype),
        interpret=_INTERPRET,
    )(rdp, redp, offs, rp)
    return out[:n].reshape(shape3)


def rb_dilu_backward(rdiag, red, off, y):
    shape3 = y.shape
    n = y.size
    H = shape3[1] * shape3[2]
    C = min(CHUNK, -(-n // 128) * 128)
    npad = -(-n // C) * C
    rdp = jnp.pad(rdiag.reshape(-1), (H, npad - n + H))
    redp = jnp.pad(red.astype(y.dtype).reshape(-1), (H, npad - n + H))
    offs = jnp.pad(off.reshape(6, -1), ((0, 0), (0, npad - n)))
    yp = jnp.pad(y.reshape(-1), (H, npad - n + H))
    strides = _strides(shape3)
    out = pl.pallas_call(
        functools.partial(_rb_back_kernel, strides, C, H),
        grid=(npad // C,),
        in_specs=[pl.BlockSpec(memory_space=pl.ANY)] * 4,
        out_specs=pl.BlockSpec((C,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((npad,), y.dtype),
        interpret=_INTERPRET,
    )(rdp, redp, offs, yp)
    return out[:n].reshape(shape3)


def rb_dilu(rdiag, red, off, r):
    """Full preconditioner apply: the forward->backward half-sweep
    composition, defined ONCE here — ops.py jits it and the application
    regions (precond/solvers) register it as their pallas variant."""
    return rb_dilu_backward(rdiag, red, off,
                            rb_dilu_forward(rdiag, red, off, r))
