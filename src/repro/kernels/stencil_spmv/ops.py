"""Jitted public wrappers for the DIA stencil kernels."""
import jax

from repro.kernels.stencil_spmv import kernel as _k

stencil_spmv = jax.jit(_k.stencil_spmv)


@jax.jit
def rb_dilu_apply(rdiag, red, off, r):
    y = _k.rb_dilu_forward(rdiag, red, off, r)
    return _k.rb_dilu_backward(rdiag, red, off, y)
