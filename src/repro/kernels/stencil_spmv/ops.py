"""Jitted public wrappers for the DIA stencil kernels."""
import jax

from repro.kernels.stencil_spmv import kernel as _k

stencil_spmv = jax.jit(_k.stencil_spmv)

rb_dilu_apply = jax.jit(_k.rb_dilu)
