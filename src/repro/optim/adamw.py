"""AdamW with unified-memory-policy-aware state placement.

The optimizer state is a plain pytree mirroring params (moments in fp32).
Under ``MemoryPolicy.offload_optimizer`` the ``ADAMW_UPDATE`` region
(``repro.train.step.make_train_regions``) carries a host-space placement
hint on ``opt_state`` (the paper's C1: one logical space, placement by
policy) — the update math here is identical either way; XLA streams the
moments through HBM for the fused update.

Two implementations of the same update ship as region variants:
:func:`apply_updates` (the fused flatten — ``ref``) and
:func:`apply_updates_leafwise` (per-leaf ``jax.tree.map`` form — the
``host`` variant: smaller per-leaf programs that a host backend schedules
leaf-at-a-time instead of one monolithic fusion).  Both walk leaves in
treedef order with identical per-leaf math, so results are bit-identical
and any Selector may swap them per call.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    moment_dtype: str = "float32"


def init_state(params, cfg: AdamWConfig):
    zeros = lambda p: jnp.zeros(p.shape, cfg.moment_dtype)
    return {
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def abstract_state(abstract_params, cfg: AdamWConfig):
    sds = lambda p: jax.ShapeDtypeStruct(p.shape, jnp.dtype(cfg.moment_dtype))
    return {
        "m": jax.tree.map(sds, abstract_params),
        "v": jax.tree.map(sds, abstract_params),
        "step": jax.ShapeDtypeStruct((), jnp.int32),
    }


def global_norm(tree) -> jax.Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32))) for l in leaves))


def apply_updates(params, grads, state, cfg: AdamWConfig, lr_scale=1.0):
    """Fused AdamW. Returns (new_params, new_state, grad_norm)."""
    gnorm = global_norm(grads)
    clip = jnp.minimum(1.0, cfg.grad_clip / (gnorm + 1e-9))
    step = state["step"] + 1
    t = step.astype(jnp.float32)
    bc1 = 1.0 - cfg.b1 ** t
    bc2 = 1.0 - cfg.b2 ** t
    lr = cfg.lr * lr_scale

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * clip
        m = cfg.b1 * m.astype(jnp.float32) + (1 - cfg.b1) * g
        v = cfg.b2 * v.astype(jnp.float32) + (1 - cfg.b2) * g * g
        u = (m / bc1) / (jnp.sqrt(v / bc2) + cfg.eps)
        pf = p.astype(jnp.float32)
        pf = pf - lr * (u + cfg.weight_decay * pf)
        return pf.astype(p.dtype), m.astype(state_dt), v.astype(state_dt)

    state_dt = jnp.dtype(cfg.moment_dtype)
    flat_p, tdef = jax.tree.flatten(params)
    flat_g = tdef.flatten_up_to(grads)
    flat_m = tdef.flatten_up_to(state["m"])
    flat_v = tdef.flatten_up_to(state["v"])
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = tdef.unflatten([o[0] for o in out])
    new_m = tdef.unflatten([o[1] for o in out])
    new_v = tdef.unflatten([o[2] for o in out])
    return new_p, {"m": new_m, "v": new_v, "step": step}, gnorm


def apply_updates_leafwise(params, grads, state, cfg: AdamWConfig,
                           lr_scale=1.0):
    """The ``host`` implementation variant of :func:`apply_updates`.

    Same per-leaf math and leaf order (bit-identical results); expressed as
    three ``jax.tree.map`` passes so the lowered program stays one small
    kernel per leaf — the shape host backends schedule well — instead of
    the fused flatten the device path prefers.
    """
    gnorm = global_norm(grads)
    clip = jnp.minimum(1.0, cfg.grad_clip / (gnorm + 1e-9))
    step = state["step"] + 1
    t = step.astype(jnp.float32)
    bc1 = 1.0 - cfg.b1 ** t
    bc2 = 1.0 - cfg.b2 ** t
    lr = cfg.lr * lr_scale
    state_dt = jnp.dtype(cfg.moment_dtype)

    gclip = jax.tree.map(lambda g: g.astype(jnp.float32) * clip, grads)
    new_m = jax.tree.map(
        lambda g, m: cfg.b1 * m.astype(jnp.float32) + (1 - cfg.b1) * g,
        gclip, state["m"])
    new_v = jax.tree.map(
        lambda g, v: cfg.b2 * v.astype(jnp.float32) + (1 - cfg.b2) * g * g,
        gclip, state["v"])

    def upd(p, m, v):
        u = (m / bc1) / (jnp.sqrt(v / bc2) + cfg.eps)
        pf = p.astype(jnp.float32)
        return (pf - lr * (u + cfg.weight_decay * pf)).astype(p.dtype)

    new_p = jax.tree.map(upd, params, new_m, new_v)
    cast = lambda t: jax.tree.map(lambda x: x.astype(state_dt), t)
    return new_p, {"m": cast(new_m), "v": cast(new_v), "step": step}, gnorm
