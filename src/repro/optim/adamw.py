"""AdamW with unified-memory-policy-aware state placement.

The optimizer state is a plain pytree mirroring params (moments in fp32).
Under ``MemoryPolicy.offload_optimizer`` the launcher places the moments in
``pinned_host`` memory (the paper's C1: one logical space, placement by
policy) — the update math here is identical either way; XLA streams the
moments through HBM for the fused update.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    moment_dtype: str = "float32"


def init_state(params, cfg: AdamWConfig):
    zeros = lambda p: jnp.zeros(p.shape, cfg.moment_dtype)
    return {
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def abstract_state(abstract_params, cfg: AdamWConfig):
    sds = lambda p: jax.ShapeDtypeStruct(p.shape, jnp.dtype(cfg.moment_dtype))
    return {
        "m": jax.tree.map(sds, abstract_params),
        "v": jax.tree.map(sds, abstract_params),
        "step": jax.ShapeDtypeStruct((), jnp.int32),
    }


def global_norm(tree) -> jax.Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32))) for l in leaves))


def apply_updates(params, grads, state, cfg: AdamWConfig, lr_scale=1.0):
    """Fused AdamW. Returns (new_params, new_state, grad_norm)."""
    gnorm = global_norm(grads)
    clip = jnp.minimum(1.0, cfg.grad_clip / (gnorm + 1e-9))
    step = state["step"] + 1
    t = step.astype(jnp.float32)
    bc1 = 1.0 - cfg.b1 ** t
    bc2 = 1.0 - cfg.b2 ** t
    lr = cfg.lr * lr_scale

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * clip
        m = cfg.b1 * m.astype(jnp.float32) + (1 - cfg.b1) * g
        v = cfg.b2 * v.astype(jnp.float32) + (1 - cfg.b2) * g * g
        u = (m / bc1) / (jnp.sqrt(v / bc2) + cfg.eps)
        pf = p.astype(jnp.float32)
        pf = pf - lr * (u + cfg.weight_decay * pf)
        return pf.astype(p.dtype), m.astype(state_dt), v.astype(state_dt)

    state_dt = jnp.dtype(cfg.moment_dtype)
    flat_p, tdef = jax.tree.flatten(params)
    flat_g = tdef.flatten_up_to(grads)
    flat_m = tdef.flatten_up_to(state["m"])
    flat_v = tdef.flatten_up_to(state["v"])
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = tdef.unflatten([o[0] for o in out])
    new_m = tdef.unflatten([o[1] for o in out])
    new_v = tdef.unflatten([o[2] for o in out])
    return new_p, {"m": new_m, "v": new_v, "step": step}, gnorm
