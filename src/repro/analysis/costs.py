"""Optional per-op bytes/FLOPs estimator: the dormant cost model, wired.

``launch/hloparse.py`` (HLO text -> FLOPs / HBM bytes / collectives) and
``launch/dryrun.py`` (MI300A roofline constants) have been idle since
the seed; the verifier is their first consumer on the road to the
ROADMAP item-5 policy autotuner.  For each captured op we rebuild the
call abstractly — ``jax.ShapeDtypeStruct`` leaves from the trace's
example inputs, ``Lit`` constants, and producer ``out_meta`` — lower
the region's ref function, and hand the compiled HLO to
``hloparse.analyze``; the roofline constants turn the counts into
compute/memory seconds and a bound-side verdict.

``dryrun`` mutates ``XLA_FLAGS`` at import (its forced-host device
fan-out), so it is imported lazily here with the previous value saved
and restored — estimating costs must never reconfigure the session's
backend.
"""
from __future__ import annotations

import os
from typing import Any, Dict, List, Optional

import jax
import numpy as np

from repro.core.program import In, Lit, OpCall, Ref, RegionProgram, _is_array
from repro.launch import hloparse


def _roofline_constants():
    """(PEAK_FLOPS, HBM_BW) from ``launch.dryrun`` without letting its
    import-time ``XLA_FLAGS`` override leak into this process's env."""
    prev = os.environ.get("XLA_FLAGS")
    try:
        from repro.launch import dryrun
    finally:
        if prev is None:
            os.environ.pop("XLA_FLAGS", None)
        else:
            os.environ["XLA_FLAGS"] = prev
    return float(dryrun.PEAK_FLOPS), float(dryrun.HBM_BW)


def _abstract_leaf(prog: RegionProgram, d) -> Any:
    """The leaf as lowering input: ShapeDtypeStruct for arrays (shape and
    dtype from the trace), the literal value otherwise."""
    if isinstance(d, In):
        x = prog._example_in_leaves[d.slot]
        return jax.ShapeDtypeStruct(x.shape, x.dtype) if _is_array(x) else x
    if isinstance(d, Lit):
        v = d.value
        return jax.ShapeDtypeStruct(v.shape, v.dtype) if _is_array(v) else v
    meta = getattr(prog.ops[d.op], "out_meta", None)
    if not meta or d.leaf >= len(meta) or meta[d.leaf] is None:
        raise ValueError(
            f"op{d.op} of {prog.name!r} carries no out_meta for leaf "
            f"{d.leaf}; re-capture the program to record output shapes")
    shape, dtype, _ = meta[d.leaf]
    return jax.ShapeDtypeStruct(tuple(shape), np.dtype(dtype))


def estimate_op_costs(prog: RegionProgram, op_index: int) -> Dict[str, Any]:
    """Static cost estimate for one captured op: lower the region's ref
    function on abstract operands, parse the compiled HLO, price it on
    the MI300A roofline."""
    op: OpCall = prog.ops[op_index]
    leaves = [_abstract_leaf(prog, d) for d in op.leaves]
    args, kwargs = jax.tree.unflatten(op.in_tree, leaves)
    hlo = jax.jit(op.region.fn).lower(*args, **kwargs).compile().as_text()
    costs = hloparse.analyze(hlo)
    peak_flops, hbm_bw = _roofline_constants()
    compute_s = costs.flops / peak_flops
    memory_s = costs.hbm_bytes / hbm_bw
    return {
        "op": op_index,
        "region": op.region.name,
        "flops": costs.flops,
        "hbm_bytes": costs.hbm_bytes,
        "collectives": dict(costs.collectives),
        "roofline_compute_s": compute_s,
        "roofline_memory_s": memory_s,
        "bound": "compute" if compute_s >= memory_s else "memory",
    }


def estimate_program_costs(prog: RegionProgram,
                           strict: bool = False) -> Dict[str, Any]:
    """Per-op estimates plus program totals.  Ops whose regions fail to
    lower abstractly (data-dependent host code) are skipped with their
    error recorded unless ``strict``."""
    ops: List[Dict[str, Any]] = []
    skipped: List[Dict[str, Any]] = []
    for i in range(len(prog.ops)):
        try:
            ops.append(estimate_op_costs(prog, i))
        except Exception as exc:                 # noqa: BLE001 - reported
            if strict:
                raise
            skipped.append({"op": i, "region": prog.ops[i].region.name,
                            "error": str(exc)})
    return {
        "program": prog.name,
        "flops": sum(o["flops"] for o in ops),
        "hbm_bytes": sum(o["hbm_bytes"] for o in ops),
        "roofline_compute_s": sum(o["roofline_compute_s"] for o in ops),
        "roofline_memory_s": sum(o["roofline_memory_s"] for o in ops),
        "ops": ops,
        "skipped": skipped,
    }
