"""CLI: lint every in-repo captured program under every policy.

    PYTHONPATH=src python -m repro.analysis --all \
        --out artifacts/analysis/report.json

Captures the corpus (CFD SIMPLE step, serve prefill/decode, engine
tick, train step) at smoke scale, runs the full rule set under each of
the unified / discrete / adaptive policies, writes one JSON report, and
exits non-zero when any finding is error-severity — the CI gate.
``--costs`` additionally prices each program on the dormant
hloparse/dryrun cost model (per-op FLOPs, HBM bytes, roofline seconds).
"""
from __future__ import annotations

import argparse
import json
import sys
import time
from pathlib import Path

from repro.analysis import verify_program
from repro.analysis.programs import PROGRAM_NAMES, build_programs
from repro.core.ledger import Ledger

POLICY_NAMES = ("unified", "discrete", "adaptive")


def _make_policy(name: str):
    from repro.core.regions import (AdaptivePolicy, DiscretePolicy,
                                    UnifiedPolicy)
    return {"unified": UnifiedPolicy, "discrete": DiscretePolicy,
            "adaptive": AdaptivePolicy}[name]()


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="statically verify the in-repo captured programs")
    ap.add_argument("--all", action="store_true",
                    help="lint the full corpus (default when --programs "
                         "is not given)")
    ap.add_argument("--programs", default=None,
                    help=f"comma-separated subset of {PROGRAM_NAMES}")
    ap.add_argument("--policies", default=",".join(POLICY_NAMES),
                    help="comma-separated policies to lint under "
                         f"(default: {','.join(POLICY_NAMES)})")
    ap.add_argument("--out", default="artifacts/analysis/report.json",
                    help="JSON report path")
    ap.add_argument("--costs", action="store_true",
                    help="include hloparse/dryrun per-op cost estimates")
    args = ap.parse_args(argv)

    names = None if args.all or args.programs is None \
        else [s for s in args.programs.split(",") if s]
    policies = [s for s in args.policies.split(",") if s]
    ledger = Ledger("analysis_cli")

    t0 = time.time()
    programs = build_programs(names)
    entries, n_errors, n_warnings = [], 0, 0
    for name, prog in programs:
        for pol_name in policies:
            rep = verify_program(prog, _make_policy(pol_name),
                                 ledger=ledger)
            rep_d = rep.as_dict()
            rep_d["corpus_name"] = name
            entries.append(rep_d)
            n_errors += len(rep.errors)
            n_warnings += len(rep.warnings)
            print(f"[analysis] {name:>14s} under {pol_name:>8s}: "
                  f"{len(rep.errors)} errors, {len(rep.warnings)} warnings "
                  f"({rep.n_ops} ops)")
            for d in rep.findings:
                print(f"    {d}")
        if args.costs:
            from repro.analysis.costs import estimate_program_costs
            costs = estimate_program_costs(prog)
            entries.append({"corpus_name": name, "costs": costs})
            print(f"[analysis] {name:>14s} costs: "
                  f"{costs['flops']:.3g} flops, "
                  f"{costs['hbm_bytes']:.3g} HBM bytes "
                  f"({len(costs['skipped'])} ops skipped)")

    report = {
        "generated_unix": t0,
        "programs": [n for n, _ in programs],
        "policies": policies,
        "n_errors": n_errors,
        "n_warnings": n_warnings,
        "analysis_counters": dict(ledger.analysis_counters),
        "reports": entries,
    }
    out = Path(args.out)
    out.parent.mkdir(parents=True, exist_ok=True)
    out.write_text(json.dumps(report, indent=2, default=str))
    print(f"[analysis] wrote {out} "
          f"({n_errors} errors, {n_warnings} warnings, "
          f"{time.time() - t0:.1f}s)")
    return 1 if n_errors else 0


if __name__ == "__main__":
    sys.exit(main())
