"""Static verification of captured region programs (docs/ANALYSIS.md).

A captured :class:`~repro.core.program.RegionProgram` is a frozen
dataflow graph carrying every ``Region``'s declarations — which makes
the unified-memory failure modes (donation races, under-declared halos,
placement churn, budget blowups) statically checkable before a single
replay:

>>> prog = capture(step, *example_inputs, verify=UnifiedPolicy())
>>> prog.verify(DiscretePolicy()).summary()
'cavity under discrete: 0 errors, 2 warnings across 9 ops'

Entry points: :func:`verify_program` (full rule set),
:func:`check_halo` (halo rule only — the ``ShardExecutor`` pre-flight),
``RegionProgram.verify`` / ``capture(..., verify=)``, the serve/train
``--verify`` flags, and the ``python -m repro.analysis`` CLI that lints
the whole in-repo corpus into ``artifacts/analysis/report.json``.
"""
from repro.analysis.report import (ERROR, INFO, WARNING, AnalysisReport,
                                   Diagnostic, ProgramVerificationError)
from repro.analysis.rules import RULES, check_halo, verify_program

__all__ = [
    "ERROR", "INFO", "WARNING",
    "AnalysisReport", "Diagnostic", "ProgramVerificationError",
    "RULES", "check_halo", "verify_program",
]
