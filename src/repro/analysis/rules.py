"""The rule set of the static program verifier.

Every rule walks a captured :class:`~repro.core.program.RegionProgram`'s
``OpCall`` graph (``Ref``/``In``/``Lit`` edges) together with each
:class:`~repro.core.regions.Region`'s declarations — ``donate_args``,
``result_space``/``arg_spaces``, ``stencil``/``halo_args``, registered
variants — under one concrete ``ExecutionPolicy``, and yields
:class:`~repro.analysis.report.Diagnostic` findings.  The graph is
frozen and the declarations are data, so this entire bug class (the
PR-4 donation race, under-declared halos, placement ping-pong, budget
blowups) is catchable *before a single replay*.

Severity policy (docs/ANALYSIS.md): ``error`` = replay or sharded
exchange is statically provably wrong (deleted buffers read, halo
operands silently skipped, variants that cannot bind the captured
call); ``warning`` = a hazard or wasted bytes the program survives
(dead results, host<->device churn, pooled donation, composed stencil
reach, watermark over budget).
"""
from __future__ import annotations

import inspect
from typing import Any, Dict, Iterable, Iterator, List, Optional, Set, Tuple

import jax

from repro.analysis.report import (ERROR, INFO, WARNING, AnalysisReport,
                                   Diagnostic)
from repro.core.program import In, Lit, OpCall, Ref, RegionProgram, _is_array, \
    _leaf_space
from repro.core.regions import Region

#: every rule id, in the order the verifier runs them
RULES = (
    "donate-after-use",
    "donate-pooled",
    "dead-result",
    "placement-churn",
    "halo-under-declaration",
    "variant-contract",
    "budget-infeasibility",
)


def _host_kind(space) -> bool:
    return getattr(space, "kind", None) in ("pinned_host", "unpinned_host")


def _device_kind(space) -> bool:
    return getattr(space, "kind", None) == "device"


def _leaf_nbytes(prog: RegionProgram, d) -> int:
    """Static byte size of the value a leaf descriptor stands for."""
    if isinstance(d, In):
        x = prog._example_in_leaves[d.slot]
        return int(getattr(x, "nbytes", 0) or 0)
    if isinstance(d, Lit):
        v = d.value
        return int(getattr(v, "nbytes", 0) or 0) if _is_array(v) else 0
    if isinstance(d, Ref):
        meta = getattr(prog.ops[d.op], "out_meta", None)
        if meta and d.leaf < len(meta) and meta[d.leaf] is not None:
            return int(meta[d.leaf][2])
    return 0


def _out_nbytes(op: OpCall) -> int:
    meta = getattr(op, "out_meta", None)
    if not meta:
        return 0
    return sum(int(m[2]) for m in meta if m is not None)


def _desc_key(d):
    """Hashable identity of a leaf descriptor (Lits by object identity)."""
    if isinstance(d, Ref):
        return ("ref", d.op, d.leaf)
    if isinstance(d, In):
        return ("in", d.slot)
    return ("lit", id(d))


def _halo_leaf_positions(op: OpCall) -> Set[int]:
    """Leaf indices the sharded halo exchange would migrate for this op —
    mirrors ``ShardExecutor._halo_leaf_indices`` (``halo_args=None``
    means every leaf)."""
    spec = op.region.halo_args
    if spec is None:
        return set(range(len(op.leaves)))
    keys: Set[Any] = set()
    for entry in spec:
        keys.add(entry)
        if isinstance(entry, str):
            idx = op.region._param_index.get(entry)
            if idx is not None:
                keys.add(idx)
    return {j for j, k in enumerate(op.arg_keys) if k in keys}


def _out_leaf_spaces(op: OpCall) -> Dict[int, Any]:
    """Per-output-leaf MemSpace implied by the region's ``result_space``
    (whole-result space, or a {tuple index / dict key: space} mapping
    resolved through the captured ``out_tree``)."""
    rs = op.region.result_space
    if rs is None or op.out_tree is None:
        return {}
    if not hasattr(rs, "items"):                      # one space for all
        return {j: rs for j in range(op.n_out)}
    tree = jax.tree.unflatten(op.out_tree, list(range(op.n_out)))
    spaces: Dict[int, Any] = {}
    if isinstance(tree, tuple):
        for key, space in rs.items():
            if isinstance(key, int) and 0 <= key < len(tree):
                for leaf in jax.tree.leaves(tree[key]):
                    spaces[leaf] = space
    elif isinstance(tree, dict):
        for key, space in rs.items():
            if key in tree:
                for leaf in jax.tree.leaves(tree[key]):
                    spaces[leaf] = space
    return spaces


# ---------------------------------------------------------------------------
# Rules.  Each takes (prog, policy) and yields Diagnostics.
# ---------------------------------------------------------------------------

def _rule_donate_after_use(prog: RegionProgram, policy) -> Iterator[Diagnostic]:
    """A leaf donated by op *i* (``Region.donate_args``) must be DEAD
    after op *i*: XLA may alias the output onto its storage, so any later
    ``Ref``, a second use inside the same call, or returning it from the
    program reads a deleted buffer on replay — the PR-4 race class."""
    for i, op in enumerate(prog.ops):
        donated = {k for k in (op.region.donate_args or ())
                   if isinstance(k, int)}
        if not donated:
            continue
        for j, d in enumerate(op.leaves):
            if op.arg_keys[j] not in donated:
                continue
            where = dict(op=i, region=op.region.name, arg=op.arg_keys[j])
            if isinstance(d, Lit):
                if _is_array(d.value):
                    yield Diagnostic(
                        "donate-after-use", ERROR, prog.name,
                        "donates a captured trace constant; the first "
                        "donating replay deletes it and every later replay "
                        "reads a dead buffer",
                        hint="produce the value inside a region (so replays "
                             "recompute it) or drop it from donate_args",
                        **where)
                continue
            if not isinstance(d, (Ref, In)):
                continue
            dup = any(j2 != j and d2 == d
                      for j2, d2 in enumerate(op.leaves))
            later = next(
                ((k, j2) for k in range(i + 1, len(prog.ops))
                 for j2, d2 in enumerate(prog.ops[k].leaves) if d2 == d),
                None)
            returned = any(d2 == d for d2 in prog.out_leaves)
            src = (f"input slot {d.slot}" if isinstance(d, In)
                   else f"op{d.op} output {d.leaf}")
            if later is not None:
                k, j2 = later
                yield Diagnostic(
                    "donate-after-use", ERROR, prog.name,
                    f"donates {src}, but op{k} "
                    f"({prog.ops[k].region.name}) still reads it at leaf "
                    f"{j2} — donation deletes the buffer before that use",
                    hint="donate only the LAST consumer of a value, or "
                         "drop the argument from donate_args",
                    **where)
            elif returned:
                yield Diagnostic(
                    "donate-after-use", ERROR, prog.name,
                    f"donates {src}, which is also a program output — "
                    "replay would return a deleted buffer",
                    hint="return the op's result instead of its donated "
                         "operand, or drop the argument from donate_args",
                    **where)
            elif dup:
                yield Diagnostic(
                    "donate-after-use", ERROR, prog.name,
                    f"donates {src}, which the same call also passes at "
                    "another argument — XLA would alias a live operand",
                    hint="pass a distinct value or drop the argument from "
                         "donate_args",
                    **where)


def _rule_donate_pooled(prog: RegionProgram, policy) -> Iterator[Diagnostic]:
    """Donation under a staging policy: executors fall back to
    ``executable(donate=False)``, but direct ``Region.__call__`` /
    ``as_fn`` paths still donate — and staged operands may alias
    ``DeviceBufferPool`` pages whose lifetime the stager owns."""
    stager = getattr(policy, "stager", None)
    if not getattr(stager, "stages", False):
        return
    for i, op in enumerate(prog.ops):
        r = op.region
        if not r.donate_args:
            continue
        tgt = policy.router.target(r, (), {}, size=op.example_size)
        if r.offloaded and tgt != "host":
            yield Diagnostic(
                "donate-pooled", WARNING, prog.name,
                f"declares donate_args={tuple(r.donate_args)} but stages "
                f"under policy {getattr(policy, 'name', '?')!r}; donation "
                "would hand pool-owned staged pages to XLA on any "
                "non-executor call path",
                hint="mark the region offloaded=False, avoid donate_args "
                     "on staged regions, or replay only through executors "
                     "(which compile donate=False when staging)",
                op=i, region=r.name)


def _rule_dead_result(prog: RegionProgram, policy) -> Iterator[Diagnostic]:
    """An op whose output leaves are never Ref'd by a later op nor
    returned did real device work for nothing on every replay (its value
    was frozen into a ``Lit`` at capture if it steered control flow)."""
    used: Set[Tuple[int, int]] = set()
    for op in prog.ops:
        for d in op.leaves:
            if isinstance(d, Ref):
                used.add((d.op, d.leaf))
    for d in prog.out_leaves:
        if isinstance(d, Ref):
            used.add((d.op, d.leaf))
    for i, op in enumerate(prog.ops):
        if op.n_out and not any((i, j) in used for j in range(op.n_out)):
            yield Diagnostic(
                "dead-result", WARNING, prog.name,
                "no output leaf is consumed by a later op or returned; "
                "the call recomputes a value every replay that only "
                "existed as a frozen capture-time constant (or not at all)",
                hint="drop the call from the captured step, or feed its "
                     "result to a region instead of host-extracting it",
                op=i, region=op.region.name)


def _rule_placement_churn(prog: RegionProgram, policy) -> Iterator[Diagnostic]:
    """A dataflow edge whose producer pins its result host-side while the
    consumer pins the same leaf device-side (or vice versa) migrates the
    bytes twice per replay — the round-trip the MI300A studies price."""
    placer = getattr(policy, "placer", None)
    if placer is not None and not getattr(placer, "honor_hints", True):
        return
    seen: Set[Tuple[str, str, Any]] = set()
    for ci, cop in enumerate(prog.ops):
        for j, d in enumerate(cop.leaves):
            if not isinstance(d, Ref):
                continue
            pop = prog.ops[d.op]
            pspace = _out_leaf_spaces(pop).get(d.leaf)
            cspace = _leaf_space(cop.region, cop.arg_keys[j])
            if pspace is None or cspace is None:
                continue
            churn = (_host_kind(pspace) and _device_kind(cspace)) or \
                (_device_kind(pspace) and _host_kind(cspace))
            key = (pop.region.name, cop.region.name, cop.arg_keys[j])
            if churn and key not in seen:
                seen.add(key)
                yield Diagnostic(
                    "placement-churn", WARNING, prog.name,
                    f"op{d.op} ({pop.region.name}) pins its result to "
                    f"{pspace} but this op's hint moves the same leaf to "
                    f"{cspace} — a host<->device round-trip on every "
                    "replay",
                    hint="align the producer's result_space with the "
                         "consumer's placement hint (or drop one of them)",
                    op=ci, region=cop.region.name, arg=cop.arg_keys[j])


def _rule_halo(prog: RegionProgram, policy) -> Iterator[Diagnostic]:
    """Halo declarations the sharded replay would silently get wrong:
    ``halo_args`` entries that resolve to no captured argument (the
    exchange skips them), halo_args without a stencil (width 0 — nothing
    exchanged), stencils exchanging every leaf for want of ``halo_args``,
    and chained stencil regions whose composed reach
    (``compose_offsets``) exceeds the consumer's declared width — the
    under-provisioning hazard of wide-halo (``halo_multiplier>1``)
    ghost zones."""
    from repro.cfd.dia import compose_offsets
    from repro.core.shard_program import halo_width

    seen_region: Set[int] = set()
    seen_entry: Set[Tuple[int, Any]] = set()
    seen_pair: Set[Tuple[str, str]] = set()

    # per-op set of stencil ops transitively feeding its outputs through
    # pointwise regions only (a stencil op re-syncs: its own halo operands
    # are exchanged before it runs, so it cuts the chain)
    ancestors: List[Set[int]] = []
    for i, op in enumerate(prog.ops):
        if op.region.stencil:
            ancestors.append({i})
        else:
            s: Set[int] = set()
            for d in op.leaves:
                if isinstance(d, Ref):
                    s |= ancestors[d.op]
            ancestors.append(s)

    for i, op in enumerate(prog.ops):
        r = op.region
        rkey = id(r)
        if r.halo_args is not None and not r.stencil and \
                rkey not in seen_region:
            seen_region.add(rkey)
            yield Diagnostic(
                "halo-under-declaration", ERROR, prog.name,
                f"declares halo_args={tuple(r.halo_args)} but no stencil; "
                "inferred halo width is 0 and the sharded replay exchanges "
                "nothing before this region reads its neighbors",
                hint="declare the region's stencil offset table "
                     "(repro.cfd.dia style) or drop halo_args",
                op=i, region=r.name)
        if r.stencil and r.halo_args is None and rkey not in seen_region:
            seen_region.add(rkey)
            yield Diagnostic(
                "halo-under-declaration", WARNING, prog.name,
                "declares a stencil but no halo_args; the sharded replay "
                "exchanges ghost zones for EVERY array operand, including "
                "coefficient stacks that multiply locally",
                hint="declare halo_args=(<names or positions of the "
                     "operands whose neighbors the stencil reads>,)",
                op=i, region=r.name)
        # unresolvable halo_args entries: the exchange silently skips them
        if r.halo_args:
            present = set(op.arg_keys)
            for entry in r.halo_args:
                ekey = (rkey, entry)
                if ekey in seen_entry:
                    continue
                resolved = entry in present or (
                    isinstance(entry, str)
                    and r._param_index.get(entry) in present)
                if not resolved:
                    seen_entry.add(ekey)
                    yield Diagnostic(
                        "halo-under-declaration", ERROR, prog.name,
                        f"halo_args entry {entry!r} matches no captured "
                        "argument of this call; the sharded exchange "
                        "silently skips it and the stencil reads stale "
                        "ghost cells",
                        hint="use the parameter name or positional index "
                             "of an actual argument (see "
                             f"parameters {tuple(r._param_index)} of "
                             f"{r.name!r})",
                        op=i, region=r.name, arg=entry)
        # composed reach across chained stencil regions
        if not r.stencil:
            continue
        for j in _halo_leaf_positions(op):
            d = op.leaves[j]
            if not isinstance(d, Ref):
                continue
            for a in ancestors[d.op]:
                ar = prog.ops[a].region
                if ar is r:
                    continue        # same region chained: wide-halo's k*w
                pair = (ar.name, r.name)
                if pair in seen_pair:
                    continue
                seen_pair.add(pair)
                composed = compose_offsets(ar.stencil, r.stencil)
                axes = sorted({ax for ax, _ in composed})
                worse = [ax for ax in axes
                         if halo_width(composed, ax) > r.stencil_width(ax)]
                if worse:
                    reach = {ax: halo_width(composed, ax) for ax in worse}
                    yield Diagnostic(
                        "halo-under-declaration", WARNING, prog.name,
                        f"halo operand chains through stencil region "
                        f"{ar.name!r} (op{a}); composed neighbor reach "
                        f"{reach} exceeds this region's declared width "
                        f"{ {ax: r.stencil_width(ax) for ax in worse} } — "
                        "wide-halo replay (halo_multiplier>1) would "
                        "under-provision its ghost zones",
                        hint="keep halo_multiplier=1 across this chain or "
                             "declare the composed stencil "
                             "(compose_offsets) on the consumer",
                        op=i, region=r.name, arg=op.arg_keys[j])


def _rule_variant_contract(prog: RegionProgram, policy) -> Iterator[Diagnostic]:
    """Every registered non-ref variant must bind the captured call's
    arity (same top-level args/kwargs as the ref function it can be
    swapped for at any replay, under any selector)."""
    seen: Set[Tuple[int, str]] = set()
    for i, op in enumerate(prog.ops):
        r = op.region
        ints = [k for k in op.arg_keys if isinstance(k, int)]
        n_pos = max(ints) + 1 if ints else 0
        kwnames = {k for k in op.arg_keys if isinstance(k, str)}
        for vname, vfn in r._variants.items():
            if vname == "ref" or (id(r), vname) in seen:
                continue
            seen.add((id(r), vname))
            try:
                sig = inspect.signature(vfn)
            except (TypeError, ValueError):
                continue                     # not introspectable: skip
            try:
                sig.bind(*([None] * n_pos), **{k: None for k in kwnames})
            except TypeError as exc:
                yield Diagnostic(
                    "variant-contract", ERROR, prog.name,
                    f"variant {vname!r} cannot bind the captured call "
                    f"({n_pos} positional"
                    + (f", kwargs {sorted(kwnames)}" if kwnames else "")
                    + f"): {exc}; any selector resolving {vname!r} "
                    "crashes this replay",
                    hint="give the variant the same signature as the ref "
                         "function (declare-variant contract)",
                    op=i, region=r.name)


def _rule_budget(prog: RegionProgram, policy,
                 budget) -> Iterator[Diagnostic]:
    """Static peak-resident-bytes watermark along the trace vs a
    ``MemoryBudget``: liveness intervals per leaf (born at its producer,
    dead after its last consumer — program outputs live to the end),
    byte sizes from the captured example leaves and out metadata."""
    limit = getattr(budget, "limit_bytes", None)
    if limit is None:
        return
    n_ops = len(prog.ops)
    birth: Dict[Any, int] = {}
    death: Dict[Any, int] = {}
    size: Dict[Any, int] = {}

    def note(d, born: int, used_at: int):
        key = _desc_key(d)
        if key not in birth:
            birth[key] = born
            size[key] = _leaf_nbytes(prog, d)
        death[key] = max(death.get(key, born), used_at)

    for slot, x in enumerate(prog._example_in_leaves):
        if _is_array(x):
            note(In(slot), 0, 0)
    for i, op in enumerate(prog.ops):
        for d in op.leaves:
            if isinstance(d, Ref):
                note(d, d.op, i)
            elif isinstance(d, In):
                note(d, 0, i)
            elif isinstance(d, Lit) and _is_array(d.value):
                note(d, 0, n_ops)            # trace-owned constant
        meta = getattr(op, "out_meta", None) or []
        for j, m in enumerate(meta):
            if m is not None:
                note(Ref(i, j), i, i)
    for d in prog.out_leaves:
        if isinstance(d, (Ref, In)):
            key = _desc_key(d)
            if key in death:
                death[key] = n_ops

    peak, peak_op = 0, 0
    for k in range(n_ops):
        live = sum(size[key] for key in birth
                   if birth[key] <= k <= death[key])
        if live > peak:
            peak, peak_op = live, k
    for i, op in enumerate(prog.ops):
        distinct = {_desc_key(d): d for d in op.leaves}
        working = sum(_leaf_nbytes(prog, d) for d in distinct.values()) \
            + _out_nbytes(op)
        if working > limit:
            yield Diagnostic(
                "budget-infeasibility", ERROR, prog.name,
                f"single-call working set {working} B (operands + "
                f"results) exceeds the memory budget "
                f"({getattr(budget, 'name', 'device')}: {limit} B); no "
                "staging schedule fits this op",
                hint="shrink the op (chunk/shard its operands) or raise "
                     "the budget",
                op=i, region=op.region.name)
    if peak > limit:
        yield Diagnostic(
            "budget-infeasibility", WARNING, prog.name,
            f"peak resident watermark {peak} B at op{peak_op} "
            f"({prog.ops[peak_op].region.name}) exceeds the memory "
            f"budget ({getattr(budget, 'name', 'device')}: {limit} B); "
            "replay completes only by spilling/paging (degraded)",
            hint="free dead values earlier (reorder ops), offload "
                 "long-lived leaves host-side, or raise the budget",
            op=peak_op, region=prog.ops[peak_op].region.name)


# ---------------------------------------------------------------------------
# Entry points
# ---------------------------------------------------------------------------

def _find_budget(policy, budget):
    if budget is not None:
        return budget
    b = getattr(policy, "budget", None)
    if b is not None:
        return b
    return getattr(getattr(policy, "stager", None), "budget", None)


def verify_program(prog: RegionProgram, policy=None, *, budget=None,
                   ledger=None, rules: Optional[Iterable[str]] = None
                   ) -> AnalysisReport:
    """Run the rule set over one captured program under one policy.

    ``policy=None`` runs the policy-independent rules only (dataflow,
    halo, variants, declared placement hints).  ``budget`` overrides the
    budget discovered on the policy (``policy.budget`` /
    ``policy.stager.budget``).  ``ledger`` (a
    :class:`~repro.core.ledger.Ledger`) accumulates per-rule finding
    counts into its ``analysis`` coverage-report section.
    """
    wanted = set(rules) if rules is not None else set(RULES)
    findings: List[Diagnostic] = []
    if "donate-after-use" in wanted:
        findings += _rule_donate_after_use(prog, policy)
    if "donate-pooled" in wanted and policy is not None:
        findings += _rule_donate_pooled(prog, policy)
    if "dead-result" in wanted:
        findings += _rule_dead_result(prog, policy)
    if "placement-churn" in wanted:
        findings += _rule_placement_churn(prog, policy)
    if "halo-under-declaration" in wanted:
        findings += _rule_halo(prog, policy)
    if "variant-contract" in wanted:
        findings += _rule_variant_contract(prog, policy)
    if "budget-infeasibility" in wanted:
        b = _find_budget(policy, budget)
        if b is not None:
            findings += _rule_budget(prog, policy, b)
    report = AnalysisReport(
        program=prog.name,
        policy=getattr(policy, "name", None) if policy is not None else None,
        findings=findings, n_ops=len(prog.ops))
    if ledger is not None:
        for d in report.findings:
            ledger.analysis_record(d.rule)
        ledger.analysis_record(f"findings_{ERROR}", len(report.errors))
        ledger.analysis_record(f"findings_{WARNING}", len(report.warnings))
        ledger.analysis_record("programs_verified")
    return report


def check_halo(prog: RegionProgram) -> AnalysisReport:
    """The halo rule alone — what ``ShardExecutor`` consults before
    decomposing a program (error findings veto the replay; composed-reach
    warnings don't, wide-halo parity tests exercise them)."""
    return verify_program(prog, rules=("halo-under-declaration",))
