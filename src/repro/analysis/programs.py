"""The in-repo program corpus the verifier lints.

Every captured program the repo ships — the CFD SIMPLE step, the serve
PREFILL / DECODE_STEP / KV_APPEND programs, the engine's vmapped
DECODE_SLOTS tick, and the train FWD_BWD + ADAMW_UPDATE step — built at
smoke scale, once per process (capture is the expensive part; a static
lint against any policy is free afterwards).  Shared by the
``python -m repro.analysis`` CLI and ``tests/test_analysis.py`` so the
CI gate and the test suite lint the exact same corpus.
"""
from __future__ import annotations

import functools
from typing import Callable, Dict, List, Tuple

from repro.core.ledger import Ledger
from repro.core.program import RegionProgram

#: corpus program names, in build order
PROGRAM_NAMES = ("simple_step", "serve_prefill", "serve_decode",
                 "engine_tick", "train_step")

# serve smoke shape (mirrors tests/test_serve_train_regions.py)
BATCH, PROMPT, GEN = 2, 8, 4
MAX_LEN = PROMPT + GEN


@functools.lru_cache(maxsize=None)
def build_simple_step() -> RegionProgram:
    """The captured CFD SIMPLE step on a smoke grid (stencil-heavy:
    momentum/pressure assembly, DILU chains, grad(p))."""
    from repro.cfd.grid import Grid
    from repro.cfd.simple import SimpleConfig, SimpleFoam, init_state
    cfg = SimpleConfig(grid=Grid((8, 8, 8)), nu=0.1, inner_max=5)
    app = SimpleFoam(cfg)
    st = init_state(cfg)
    return app.capture_step(st)


@functools.lru_cache(maxsize=None)
def _serve_programs() -> Tuple[RegionProgram, RegionProgram]:
    import jax
    import jax.numpy as jnp

    from repro.configs.reduced import reduced as make_reduced
    from repro.configs.registry import get_config
    from repro.core.regions import Executor, UnifiedPolicy
    from repro.launch import serve as SV
    from repro.launch.mesh import make_smoke_mesh
    from repro.models import transformer as T

    cfg = make_reduced(get_config("tinyllama-1.1b"))
    mesh = make_smoke_mesh()
    key = jax.random.PRNGKey(0)
    params = T.init(key, cfg)
    prompts = jax.random.randint(key, (BATCH, PROMPT), 0, cfg.vocab,
                                 jnp.int32)
    batch_in = {"tokens": prompts}
    regions = SV.make_serve_regions(cfg, mesh, params,
                                    ledger=Ledger("analysis_serve"))
    prefill_prog = SV.capture_prefill_program(
        regions, batch_in, T.init_cache(cfg, BATCH, MAX_LEN))
    ex = Executor(UnifiedPolicy(), Ledger("analysis_serve_replay"))
    tok, cache = prefill_prog.replay(ex, batch_in,
                                     T.init_cache(cfg, BATCH, MAX_LEN))
    decode_prog = SV.capture_decode_program(regions, PROMPT, GEN, tok, cache)
    return prefill_prog, decode_prog


def build_serve_prefill() -> RegionProgram:
    """PREFILL + donated KV_APPEND cache commit."""
    return _serve_programs()[0]


def build_serve_decode() -> RegionProgram:
    """(gen-1) x (DECODE_STEP + donated KV_APPEND)."""
    return _serve_programs()[1]


@functools.lru_cache(maxsize=None)
def build_engine_tick() -> RegionProgram:
    """The continuous-batching engine's captured vmapped DECODE_SLOTS
    tick (live position/active-mask inputs, donated slot-cache commit)."""
    import jax

    from repro.configs.reduced import reduced as make_reduced
    from repro.configs.registry import get_config
    from repro.core.regions import Executor, UnifiedPolicy
    from repro.launch.mesh import make_smoke_mesh
    from repro.models import transformer as T
    from repro.serve import PagedKVCache, ServeEngine

    cfg = make_reduced(get_config("tinyllama-1.1b"))
    mesh = make_smoke_mesh()
    params = T.init(jax.random.PRNGKey(0), cfg)
    ex = Executor(UnifiedPolicy(), Ledger("analysis_engine"))
    eng = ServeEngine(cfg, mesh, params, ex, max_len=MAX_LEN, n_slots=2,
                      kv=PagedKVCache(page_tokens=4))
    return eng.tick_prog


@functools.lru_cache(maxsize=None)
def build_train_step() -> RegionProgram:
    """The captured FWD_BWD + ADAMW_UPDATE training step."""
    import jax
    import jax.numpy as jnp

    from repro.configs.reduced import reduced as make_reduced
    from repro.configs.registry import get_config
    from repro.models import transformer as T
    from repro.optim import adamw
    from repro.train import step as S

    cfg = make_reduced(get_config("tinyllama-1.1b"))
    opt_cfg = adamw.AdamWConfig(lr=1e-3)
    key = jax.random.PRNGKey(1)
    params = T.init(key, cfg)
    opt = adamw.init_state(params, opt_cfg)
    batch = {"tokens": jax.random.randint(key, (2, 16), 0, cfg.vocab,
                                          jnp.int32)}
    regions = S.make_train_regions(cfg, opt_cfg,
                                   ledger=Ledger("analysis_train"))
    return S.capture_train_program(regions, (params, opt), batch)


_BUILDERS: Dict[str, Callable[[], RegionProgram]] = {
    "simple_step": build_simple_step,
    "serve_prefill": build_serve_prefill,
    "serve_decode": build_serve_decode,
    "engine_tick": build_engine_tick,
    "train_step": build_train_step,
}


def build_programs(names=None) -> List[Tuple[str, RegionProgram]]:
    """Build (and cache) the named corpus programs; ``None`` = all."""
    picked = PROGRAM_NAMES if names is None else tuple(names)
    out = []
    for name in picked:
        if name not in _BUILDERS:
            raise KeyError(f"unknown corpus program {name!r}; "
                           f"available: {PROGRAM_NAMES}")
        out.append((name, _BUILDERS[name]()))
    return out
