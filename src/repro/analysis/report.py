"""Structured diagnostics for the static program verifier.

A verification pass walks one captured
:class:`~repro.core.program.RegionProgram` and emits
:class:`Diagnostic` findings — each carries the rule id, a severity, the
(program, op, region, argument) location, a human message, and a fix
hint.  :class:`AnalysisReport` is the per-(program, policy) bundle the
callers consume: ``capture(..., verify=)`` and the serve/train
``--verify`` flags raise on ``.errors``, the ``python -m repro.analysis``
CLI serializes ``.as_dict()`` into ``artifacts/analysis/report.json``,
and ``ShardExecutor`` gates decomposition on error-severity halo
findings only (see docs/ANALYSIS.md for the severity policy).
"""
from __future__ import annotations

import dataclasses
from typing import Any, List, Optional

#: severity levels, most severe first.  ``error`` findings are
#: statically provable correctness violations (replay or sharded
#: exchange WILL misbehave); ``warning`` findings are hazards or wasted
#: bytes/bandwidth the program still survives; ``info`` is advisory.
ERROR = "error"
WARNING = "warning"
INFO = "info"

_SEVERITY_ORDER = {ERROR: 0, WARNING: 1, INFO: 2}


@dataclasses.dataclass
class Diagnostic:
    """One finding of one rule at one program location."""
    rule: str                       # rule id, e.g. "donate-after-use"
    severity: str                   # ERROR | WARNING | INFO
    program: str                    # RegionProgram.name
    message: str
    hint: str = ""                  # how to fix it
    op: Optional[int] = None        # op index in the trace, if op-level
    region: Optional[str] = None    # Region.name at that op
    arg: Any = None                 # top-level arg index / kwarg name

    def location(self) -> str:
        loc = self.program
        if self.op is not None:
            loc += f":op{self.op}"
        if self.region is not None:
            loc += f"({self.region})"
        if self.arg is not None:
            loc += f" arg {self.arg!r}"
        return loc

    def __str__(self) -> str:
        s = f"{self.severity}[{self.rule}] {self.location()}: {self.message}"
        if self.hint:
            s += f" (fix: {self.hint})"
        return s

    def as_dict(self) -> dict:
        return dataclasses.asdict(self)


@dataclasses.dataclass
class AnalysisReport:
    """All findings of one verification pass over one program."""
    program: str
    policy: Optional[str] = None            # policy name the pass assumed
    findings: List[Diagnostic] = dataclasses.field(default_factory=list)
    n_ops: int = 0

    def __post_init__(self):
        self.findings.sort(
            key=lambda d: (_SEVERITY_ORDER.get(d.severity, 9),
                           d.op if d.op is not None else -1, d.rule))

    @property
    def errors(self) -> List[Diagnostic]:
        return [d for d in self.findings if d.severity == ERROR]

    @property
    def warnings(self) -> List[Diagnostic]:
        return [d for d in self.findings if d.severity == WARNING]

    @property
    def ok(self) -> bool:
        """Clean at error severity (warnings don't block replay)."""
        return not self.errors

    def by_rule(self) -> dict:
        out: dict = {}
        for d in self.findings:
            out.setdefault(d.rule, []).append(d)
        return out

    def summary(self) -> str:
        pol = f" under {self.policy}" if self.policy else ""
        return (f"{self.program}{pol}: {len(self.errors)} errors, "
                f"{len(self.warnings)} warnings across {self.n_ops} ops")

    def as_dict(self) -> dict:
        return {
            "program": self.program,
            "policy": self.policy,
            "n_ops": self.n_ops,
            "n_errors": len(self.errors),
            "n_warnings": len(self.warnings),
            "findings": [d.as_dict() for d in self.findings],
        }

    def raise_if_errors(self) -> "AnalysisReport":
        if self.errors:
            raise ProgramVerificationError(self)
        return self


class ProgramVerificationError(ValueError):
    """Raised when a verification pass finds error-severity defects."""

    def __init__(self, report: AnalysisReport):
        self.report = report
        lines = [report.summary()] + [f"  {d}" for d in report.errors]
        super().__init__("\n".join(lines))
