"""Incremental-acceleration ledger (paper C2, Figs 2 & 4).

The paper's porting method: walk a production code region by region, add one
directive per parallelizable loop, and track how much of a time-step executes
on the device. This module is that bookkeeping: every ``@offload_region`` is
registered; executors report where each call actually ran and how much
staging it cost; ``coverage_report()`` reproduces the Fig 2 (partial,
PETSc-style) vs Fig 4 (directive, near-total) comparison.
"""
from __future__ import annotations

import contextlib
import dataclasses
import time
from typing import Dict, List, Optional


def variant_cell(target: str, bucket: int) -> str:
    """Key of one autotune calibration cell: routing target x size bucket
    (power-of-two: bucket ``b`` covers sizes in ``[2^(b-1), 2^b)``)."""
    return f"{target}@2^{bucket}"


@dataclasses.dataclass
class RegionRecord:
    name: str
    offloaded: bool = True              # does this region carry a directive?
    calls: int = 0
    device_calls: int = 0
    host_calls: int = 0
    compute_s: float = 0.0
    device_compute_s: float = 0.0       # compute split by routing side — a
    host_compute_s: float = 0.0         # region may mix under AdaptivePolicy
    staging_s: float = 0.0              # discrete-emulation copy time
    staging_bytes: int = 0
    overlap_s: float = 0.0              # staging/exchange hidden behind compute
    #                                     (async + sharded overlapped replay;
    #                                     <= staging_s + exchange_s)
    exchange_s: float = 0.0             # inter-APU halo/boundary traffic time
    exchange_bytes: int = 0             # (sharded replay; Infinity Fabric model)
    host_elems: int = 0                 # routing accounting (was DispatchStats)
    device_elems: int = 0
    cutoff: Optional[int] = None        # calibrated TARGET_CUT_OFF, if any
    #: calls per selected implementation variant ("ref", "pallas", ...) —
    #: the declare-variant dispatch record of paper C3's second half
    impl_counts: Dict[str, int] = dataclasses.field(default_factory=dict)
    #: autotune winners per (target, size-bucket) cell (see variant_cell);
    #: persisted like ``cutoff`` — survives reset_timings()
    calibrated_variants: Dict[str, str] = dataclasses.field(
        default_factory=dict)

    @property
    def impl(self) -> Optional[str]:
        """The dominant implementation this row ran (most calls), or None
        before any variant-resolved call was recorded."""
        if not self.impl_counts:
            return None
        return max(self.impl_counts, key=self.impl_counts.get)

    @property
    def total_s(self) -> float:
        """Wall-clock this row cost the replay.  Overlapped seconds ran
        *concurrently* with some region's compute, so counting them again
        would double-book the node: ``total = compute + staging + exchange
        - overlap`` (the invariant ``Ledger.merged`` reproduces node-wide;
        see docs/SCALING.md)."""
        return (self.compute_s + self.staging_s + self.exchange_s
                - self.overlap_s)

    @property
    def exposed_exchange_s(self) -> float:
        """Exchange seconds NOT hidden behind compute.  Overlap attributes
        to staging first (the async lookahead's claim), the remainder to
        exchange (the sharded overlapped schedule's claim)."""
        return self.exchange_s - max(0.0, self.overlap_s - self.staging_s)

    @property
    def offload_fraction(self) -> float:
        tot = self.host_elems + self.device_elems
        return self.device_elems / tot if tot else 0.0

    @property
    def overlap_fraction(self) -> float:
        """Fraction of this region's hideable time (staging + exchange)
        that actually ran concurrently with another region's compute
        (Fig 6 mitigation: prefetch overlap; docs/SCALING.md: halo
        overlap)."""
        hideable = self.staging_s + self.exchange_s
        return self.overlap_s / hideable if hideable else 0.0


class Ledger:
    def __init__(self, name: str = "default"):
        self.name = name
        self.regions: Dict[str, RegionRecord] = {}
        # serving-engine accounting (repro.serve): scheduler decisions land
        # here so coverage_report() carries the serve story next to the
        # region rows it is made of.  Counters sum on merge; gauges
        # (occupancy, high-water bytes) take the max.
        self.serve_counters: Dict[str, float] = {}
        self.serve_gauges: Dict[str, float] = {}
        # static-verifier accounting (repro.analysis): findings per rule
        # id plus per-severity totals.  Counters sum on merge and — like
        # cutoffs and calibrated variants — persist across reset_timings()
        # (verification happens once at capture, not per replay).
        self.analysis_counters: Dict[str, float] = {}
        # pools attached for byte-level accounting (paper C4): their live
        # PoolStats are snapshotted into every coverage_report()
        self._pools: Dict[str, object] = {}

    def region(self, name: str, offloaded: bool = True) -> RegionRecord:
        if name not in self.regions:
            self.regions[name] = RegionRecord(name=name, offloaded=offloaded)
        return self.regions[name]

    def register(self, name: str, offloaded: bool = True) -> str:
        """Register a NEW region under a guaranteed-unique name.

        Two anonymous regions sharing ``fn.__name__`` used to merge silently
        into one record; registration now auto-uniquifies (``dot``, ``dot#2``,
        ...) so every region owns its own row in the coverage report.
        """
        unique = name
        k = 2
        while unique in self.regions:
            unique = f"{name}#{k}"
            k += 1
        self.regions[unique] = RegionRecord(name=unique, offloaded=offloaded)
        return unique

    def record(self, name: str, *, device: bool, compute_s: float,
               staging_s: float = 0.0, staging_bytes: int = 0,
               offloaded: bool = True, elems: int = 0,
               overlap_s: float = 0.0, exchange_s: float = 0.0,
               exchange_bytes: int = 0,
               impl: Optional[str] = None) -> None:
        r = self.region(name, offloaded)
        r.calls += 1
        if impl is not None:
            r.impl_counts[impl] = r.impl_counts.get(impl, 0) + 1
        r.device_calls += int(device)
        r.host_calls += int(not device)
        r.compute_s += compute_s
        r.staging_s += staging_s
        r.staging_bytes += staging_bytes
        r.overlap_s += min(overlap_s, staging_s + exchange_s)
        r.exchange_s += exchange_s
        r.exchange_bytes += exchange_bytes
        if device:
            r.device_compute_s += compute_s
            r.device_elems += elems
        else:
            r.host_compute_s += compute_s
            r.host_elems += elems

    def set_cutoff(self, name: str, cutoff: int) -> None:
        """Store a calibrated TARGET_CUT_OFF with the region it governs."""
        self.region(name).cutoff = cutoff

    # -- serving-engine accounting (repro.serve) -----------------------
    def serve_record(self, event: str, n: float = 1) -> None:
        """Count one scheduler decision (``admitted``, ``retired``,
        ``evicted``, ...) into the report's ``serve`` section."""
        self.serve_counters[event] = self.serve_counters.get(event, 0) + n

    def serve_gauge(self, key: str, value: float) -> None:
        """Record a level (slot occupancy, KV page high-water bytes).
        Gauges keep the maximum seen — every caller passes its own running
        value, the ledger keeps the peak."""
        self.serve_gauges[key] = max(self.serve_gauges.get(key, value), value)

    # -- static-verifier accounting (repro.analysis) -------------------
    def analysis_record(self, key: str, n: float = 1) -> None:
        """Count one static-verifier event (a finding per rule id, a
        ``findings_error``/``findings_warning`` total, a verified
        program) into the report's ``analysis`` section."""
        self.analysis_counters[key] = self.analysis_counters.get(key, 0) + n

    def attach_pool(self, name: str, pool) -> None:
        """Surface a pool's byte-level PoolStats in coverage_report()
        (``pools`` section).  Re-attaching under the same name replaces."""
        self._pools[name] = pool

    def set_calibrated_variant(self, name: str, target: str, bucket: int,
                               winner: str) -> None:
        """Store an autotuned variant winner for one (target, size-bucket)
        cell with the region it governs — the declare-variant analogue of
        :meth:`set_cutoff`."""
        r = self.region(name)
        r.calibrated_variants[variant_cell(target, bucket)] = winner

    def reset_timings(self) -> None:
        for r in self.regions.values():
            r.calls = r.device_calls = r.host_calls = 0
            r.compute_s = r.staging_s = r.overlap_s = 0.0
            r.exchange_s = 0.0
            r.staging_bytes = r.exchange_bytes = 0
            r.device_compute_s = r.host_compute_s = 0.0
            r.host_elems = r.device_elems = 0
            r.impl_counts = {}          # per-call record; calibrated_variants
            #                             and cutoff persist like settings
        self.serve_counters.clear()     # per-run accounting, like timings;
        self.serve_gauges.clear()       # attached pools persist like settings
        # analysis_counters persist: verification is per capture, not per
        # run — resetting timings must not erase what the verifier found

    def merge_from(self, other: "Ledger") -> None:
        """Accumulate another ledger's rows into this one (rows matched by
        name).  This is the node-level aggregation of the sharded replay:
        per-device ledgers fold into one, and ``coverage_report()`` on the
        result is the node view."""
        for r in other.regions.values():
            m = self.region(r.name, r.offloaded)
            m.calls += r.calls
            m.device_calls += r.device_calls
            m.host_calls += r.host_calls
            m.compute_s += r.compute_s
            m.device_compute_s += r.device_compute_s
            m.host_compute_s += r.host_compute_s
            m.staging_s += r.staging_s
            m.staging_bytes += r.staging_bytes
            m.overlap_s += r.overlap_s
            m.exchange_s += r.exchange_s
            m.exchange_bytes += r.exchange_bytes
            m.host_elems += r.host_elems
            m.device_elems += r.device_elems
            for impl, n in r.impl_counts.items():
                m.impl_counts[impl] = m.impl_counts.get(impl, 0) + n
            for cell, winner in r.calibrated_variants.items():
                m.calibrated_variants.setdefault(cell, winner)
            if m.cutoff is None:
                m.cutoff = r.cutoff
        for k, v in other.serve_counters.items():
            self.serve_counters[k] = self.serve_counters.get(k, 0) + v
        for k, v in other.serve_gauges.items():
            self.serve_gauges[k] = max(self.serve_gauges.get(k, v), v)
        for k, v in other.analysis_counters.items():
            self.analysis_counters[k] = self.analysis_counters.get(k, 0) + v

    @classmethod
    def merged(cls, ledgers, name: str = "node") -> "Ledger":
        """A new ledger holding the row-wise sum of ``ledgers``."""
        out = cls(name)
        for l in ledgers:
            out.merge_from(l)
        return out

    def clear(self) -> None:
        """Drop all region rows. Long-lived processes that rebuild region
        programs against one shared ledger (auto-uniquified names grow it)
        should clear between generations — or give each app its own Ledger."""
        self.regions.clear()
        self.serve_counters.clear()
        self.serve_gauges.clear()
        self.analysis_counters.clear()
        self._pools.clear()

    # ------------------------------------------------------------------
    def coverage_report(self) -> dict:
        # total_s subtracts overlap_s per row: seconds hidden behind compute
        # ran concurrently and must not be double-booked into the node wall
        # (invariant: total == compute + staging + exchange - overlap)
        total = sum(r.total_s for r in self.regions.values())
        # per-side compute, not whole rows: under adaptive routing one region
        # mixes host and device calls, and a single device call must not
        # re-attribute the row's host time (Fig 4 coverage would read ~1.0)
        dev = sum(r.device_compute_s for r in self.regions.values()
                  if r.offloaded)
        compute = sum(r.compute_s for r in self.regions.values())
        staging = sum(r.staging_s for r in self.regions.values())
        overlap = sum(r.overlap_s for r in self.regions.values())
        exchange = sum(r.exchange_s for r in self.regions.values())
        exposed_exchange = sum(r.exposed_exchange_s
                               for r in self.regions.values())
        hideable = staging + exchange
        host_calls = sum(r.host_calls for r in self.regions.values())
        device_calls = sum(r.device_calls for r in self.regions.values())
        host_elems = sum(r.host_elems for r in self.regions.values())
        device_elems = sum(r.device_elems for r in self.regions.values())
        elems = host_elems + device_elems
        impl_counts: Dict[str, int] = {}
        for r in self.regions.values():
            for impl, n in r.impl_counts.items():
                impl_counts[impl] = impl_counts.get(impl, 0) + n
        calibrated = {r.name: dict(r.calibrated_variants)
                      for r in self.regions.values()
                      if r.calibrated_variants}
        variant_wins: Dict[str, int] = {}
        for cells in calibrated.values():
            for winner in cells.values():
                variant_wins[winner] = variant_wins.get(winner, 0) + 1
        extra: Dict[str, dict] = {}
        if self.serve_counters or self.serve_gauges:
            # serving engine (repro.serve): scheduler counters + gauges
            extra["serve"] = {**self.serve_counters, **self.serve_gauges}
        if self.analysis_counters:
            # static verifier (repro.analysis): findings per rule id
            extra["analysis"] = dict(self.analysis_counters)
        if self._pools:
            # byte-level pool accounting (paper C4): live PoolStats snapshot
            pools = {}
            for pname, pool in self._pools.items():
                st = pool.stats.as_dict()
                fb = getattr(pool, "free_bytes", None)
                if fb is not None:
                    st["free_bytes"] = fb
                pools[pname] = st
            extra["pools"] = pools
        return {
            **extra,
            "regions": len(self.regions),
            "offloaded_regions": sum(1 for r in self.regions.values()
                                     if r.offloaded),
            "total_s": total,
            "compute_s": compute,
            "device_compute_s": dev,
            "staging_s": staging,
            "device_fraction": dev / total if total else 0.0,
            "staging_fraction": staging / total if total else 0.0,  # Fig 6
            # inter-APU boundary traffic (sharded replay, repro.core
            # .shard_program): explicit halo-exchange regions land their
            # seconds/bytes here, next to the compute and staging they
            # trade against — the Infinity Fabric split of the node report
            "exchange_s": exchange,
            "exchange_bytes": sum(r.exchange_bytes
                                  for r in self.regions.values()),
            # fraction of node wall that is EXPOSED exchange — overlapped
            # exchange seconds ran behind compute and are excluded (overlap
            # attributes to staging first, remainder to exchange)
            "exchange_fraction": exposed_exchange / total if total else 0.0,
            "exposed_exchange_s": exposed_exchange,
            # overlapped replay (async lookahead staging + sharded halo
            # overlap): how much of the hideable time (staging + exchange)
            # ran behind compute, and the staging seconds saved vs a fully
            # synchronous replay of the same program
            "overlap_s": overlap,
            "overlap_fraction": overlap / hideable if hideable else 0.0,
            "staging_saved_s": sum(min(r.overlap_s, r.staging_s)
                                   for r in self.regions.values()),
            # routing accounting (absorbed from dispatch.DispatchStats):
            # every host/device decision — static or TARGET_CUT_OFF-adaptive —
            # lands here, next to the staging fractions it trades against.
            "host_calls": host_calls,
            "device_calls": device_calls,
            "offload_elem_fraction": device_elems / elems if elems else 0.0,
            "cutoffs": {r.name: r.cutoff for r in self.regions.values()
                        if r.cutoff is not None},
            # declare-variant dispatch (repro.core.regions Selector axis):
            # which implementation each call actually ran, the autotuned
            # winner per (region, target, size-bucket) cell, and how many
            # cells each variant won across the whole calibration
            "impl_counts": impl_counts,
            "calibrated_variants": calibrated,
            "variant_wins": variant_wins,
        }

    def table(self) -> List[dict]:
        return [dataclasses.asdict(r) for r in self.regions.values()]


GLOBAL_LEDGER = Ledger()


@contextlib.contextmanager
def timed_region(ledger: Ledger, name: str, device: bool = True,
                 offloaded: bool = True):
    t0 = time.perf_counter()
    yield
    ledger.record(name, device=device, offloaded=offloaded,
                  compute_s=time.perf_counter() - t0)


def offload_region(name: Optional[str] = None, *, offloaded: bool = True,
                   ledger: Optional[Ledger] = None, **kw):
    """Deprecated alias for :func:`repro.core.regions.region`.

    Mark a function as one OpenMP-directive-sized region; the returned
    :class:`~repro.core.regions.Region` is jitted, self-times into the ledger,
    and can be re-routed (host/device/staged) by any executor without touching
    the function body — the "one line per loop" porting experience of
    listings 4-6. New code should import ``region`` from
    ``repro.core.regions`` directly.
    """
    from repro.core.regions import region
    return region(name, offloaded=offloaded, ledger=ledger, **kw)
