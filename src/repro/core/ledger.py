"""Incremental-acceleration ledger (paper C2, Figs 2 & 4).

The paper's porting method: walk a production code region by region, add one
directive per parallelizable loop, and track how much of a time-step executes
on the device. This module is that bookkeeping: every ``@offload_region`` is
registered; executors report where each call actually ran and how much
staging it cost; ``coverage_report()`` reproduces the Fig 2 (partial,
PETSc-style) vs Fig 4 (directive, near-total) comparison.
"""
from __future__ import annotations

import contextlib
import dataclasses
import time
from typing import Callable, Dict, List, Optional

import jax


@dataclasses.dataclass
class RegionRecord:
    name: str
    offloaded: bool = True              # does this region carry a directive?
    calls: int = 0
    device_calls: int = 0
    host_calls: int = 0
    compute_s: float = 0.0
    staging_s: float = 0.0              # discrete-emulation copy time
    staging_bytes: int = 0

    @property
    def total_s(self) -> float:
        return self.compute_s + self.staging_s


class Ledger:
    def __init__(self, name: str = "default"):
        self.name = name
        self.regions: Dict[str, RegionRecord] = {}

    def region(self, name: str, offloaded: bool = True) -> RegionRecord:
        if name not in self.regions:
            self.regions[name] = RegionRecord(name=name, offloaded=offloaded)
        return self.regions[name]

    def record(self, name: str, *, device: bool, compute_s: float,
               staging_s: float = 0.0, staging_bytes: int = 0,
               offloaded: bool = True) -> None:
        r = self.region(name, offloaded)
        r.calls += 1
        r.device_calls += int(device)
        r.host_calls += int(not device)
        r.compute_s += compute_s
        r.staging_s += staging_s
        r.staging_bytes += staging_bytes

    def reset_timings(self) -> None:
        for r in self.regions.values():
            r.calls = r.device_calls = r.host_calls = 0
            r.compute_s = r.staging_s = 0.0
            r.staging_bytes = 0

    # ------------------------------------------------------------------
    def coverage_report(self) -> dict:
        total = sum(r.total_s for r in self.regions.values())
        dev = sum(r.compute_s for r in self.regions.values()
                  if r.offloaded and r.device_calls)
        staging = sum(r.staging_s for r in self.regions.values())
        return {
            "regions": len(self.regions),
            "offloaded_regions": sum(1 for r in self.regions.values()
                                     if r.offloaded),
            "total_s": total,
            "device_compute_s": dev,
            "staging_s": staging,
            "device_fraction": dev / total if total else 0.0,
            "staging_fraction": staging / total if total else 0.0,  # Fig 6
        }

    def table(self) -> List[dict]:
        return [dataclasses.asdict(r) for r in self.regions.values()]


GLOBAL_LEDGER = Ledger()


@contextlib.contextmanager
def timed_region(ledger: Ledger, name: str, device: bool = True,
                 offloaded: bool = True):
    t0 = time.perf_counter()
    yield
    ledger.record(name, device=device, offloaded=offloaded,
                  compute_s=time.perf_counter() - t0)


def offload_region(name: Optional[str] = None, *, offloaded: bool = True,
                   ledger: Optional[Ledger] = None):
    """Mark a function as one OpenMP-directive-sized region. The returned
    wrapper is jitted and self-times into the ledger; executors can re-route
    it (host/device/staged) without touching the function body — the
    "one line per loop" porting experience of listings 4-6."""
    ldg = ledger or GLOBAL_LEDGER

    def wrap(fn: Callable):
        jfn = jax.jit(fn)
        rname = name or getattr(fn, "__name__", "region")
        ldg.region(rname, offloaded)

        def runner(*args, **kwargs):
            t0 = time.perf_counter()
            out = jfn(*args, **kwargs)
            jax.block_until_ready(out)
            ldg.record(rname, device=offloaded, offloaded=offloaded,
                       compute_s=time.perf_counter() - t0)
            return out

        runner.__name__ = rname
        runner.region_name = rname
        runner.offloaded = offloaded
        runner.jitted = jfn
        return runner

    return wrap
