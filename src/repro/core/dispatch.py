"""Adaptive host/device dispatch — the ``if(target: n > TARGET_CUT_OFF)``
OpenMP clause (paper C3, listings 4-6) as a JAX combinator.

The same function is compiled twice — once pinned to the host CPU backend,
once for the accelerator backend — and each call is routed by problem size.
On an APU (and on our CPU container) switching sides is nearly free because
no data movement is implied; on a discrete system the runtime would charge
staging, which is exactly what the executors in ``repro.core.executors``
measure.

``calibrate()`` reproduces the paper's empirical choice of TARGET_CUT_OFF by
timing both executables over a size ladder and picking the crossover.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Optional, Sequence

import jax
import numpy as np

DEFAULT_CUTOFF = 16384


def _default_size(args, kwargs) -> int:
    for a in jax.tree.leaves((args, kwargs)):
        if hasattr(a, "size"):
            return int(a.size)
    return 0


@dataclasses.dataclass
class DispatchStats:
    host_calls: int = 0
    device_calls: int = 0
    host_elems: int = 0
    device_elems: int = 0

    @property
    def offload_fraction(self) -> float:
        tot = self.host_elems + self.device_elems
        return self.device_elems / tot if tot else 0.0


class TargetDispatch:
    """``TargetDispatch(f, cutoff)(x)`` == OpenMP
    ``target teams distribute parallel for if(target: x.size > cutoff)``."""

    def __init__(self, fn: Callable, cutoff: int = DEFAULT_CUTOFF,
                 size_fn: Callable = None, name: Optional[str] = None):
        self.name = name or getattr(fn, "__name__", "region")
        self.cutoff = cutoff
        self.size_fn = size_fn or _default_size
        self._jitted = jax.jit(fn)
        self._host_dev = jax.devices("cpu")[0]
        accel = [d for d in jax.devices() if d.platform != "cpu"]
        self._accel_dev = accel[0] if accel else jax.devices()[0]
        self.stats = DispatchStats()

    def _run_on(self, device, args, kwargs):
        with jax.default_device(device):
            return self._jitted(*args, **kwargs)

    def __call__(self, *args, **kwargs):
        n = self.size_fn(args, kwargs)
        if n > self.cutoff:
            self.stats.device_calls += 1
            self.stats.device_elems += n
            return self._run_on(self._accel_dev, args, kwargs)
        self.stats.host_calls += 1
        self.stats.host_elems += n
        return self._run_on(self._host_dev, args, kwargs)

    # ------------------------------------------------------------------
    def calibrate(self, make_args: Callable[[int], tuple],
                  sizes: Sequence[int] = (256, 1024, 4096, 16384, 65536),
                  reps: int = 20) -> int:
        """Time host vs device executables per size; set cutoff = crossover."""
        crossover = self.cutoff
        for n in sorted(sizes):
            args = make_args(n)
            ts = {}
            for dev_name, dev in (("host", self._host_dev),
                                  ("dev", self._accel_dev)):
                r = self._run_on(dev, args, {})
                jax.block_until_ready(r)
                t0 = time.perf_counter()
                for _ in range(reps):
                    r = self._run_on(dev, args, {})
                jax.block_until_ready(r)
                ts[dev_name] = (time.perf_counter() - t0) / reps
            if ts["dev"] < ts["host"]:
                crossover = n
                break
        else:
            crossover = max(sizes) + 1
        self.cutoff = crossover
        return crossover


def offload(fn=None, *, cutoff: int = DEFAULT_CUTOFF, size_fn=None, name=None):
    """Decorator form: the one-line directive of listings 4-6."""
    def wrap(f):
        return TargetDispatch(f, cutoff=cutoff, size_fn=size_fn, name=name)
    return wrap(fn) if fn is not None else wrap
