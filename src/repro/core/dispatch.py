"""Adaptive host/device dispatch — the ``if(target: n > TARGET_CUT_OFF)``
OpenMP clause (paper C3, listings 4-6).

The routing logic itself now lives in ``repro.core.regions``
(:class:`SizeRouter` / :class:`AdaptivePolicy`), where it composes with any
executor's placement and staging axes.  :class:`TargetDispatch` survives as
a standalone shim — one Region driven by one AdaptivePolicy executor — and
its per-call accounting lands in a :class:`~repro.core.ledger.Ledger`
instead of a private stats object, so host/device call counts show up in
the same ``coverage_report()`` as staging fractions.  Counts only: like
the pre-regions dispatcher, ``__call__`` stays asynchronous (no
block_until_ready), so it cannot time itself — run the region through an
``Executor(AdaptivePolicy(...))`` when timed coverage is wanted.

``calibrate()`` reproduces the paper's empirical choice of TARGET_CUT_OFF
by timing both executables over a size ladder, picking the crossover, and
recording it with the region's ledger row.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional, Sequence

from repro.core.ledger import Ledger
from repro.core.regions import (DEFAULT_CUTOFF, AdaptivePolicy, Executor,
                                default_size, region as _region)

# legacy alias; sizing now uses the LARGEST leaf, so a scalar first argument
# no longer forces host routing regardless of field size
_default_size = default_size


@dataclasses.dataclass
class DispatchStats:
    """Deprecated read-only view assembled from the ledger's RegionRecord
    (routing accounting was folded into the Ledger)."""
    host_calls: int = 0
    device_calls: int = 0
    host_elems: int = 0
    device_elems: int = 0

    @property
    def offload_fraction(self) -> float:
        tot = self.host_elems + self.device_elems
        return self.device_elems / tot if tot else 0.0


class TargetDispatch:
    """``TargetDispatch(f, cutoff)(x)`` == OpenMP
    ``target teams distribute parallel for if(target: x.size > cutoff)``.

    Shim over ``Executor(AdaptivePolicy(cutoff), ledger)`` running a single
    Region; pass ``ledger=`` to land its routing decisions in a shared
    coverage report."""

    def __init__(self, fn: Callable, cutoff: int = DEFAULT_CUTOFF,
                 size_fn: Callable = None, name: Optional[str] = None,
                 ledger: Optional[Ledger] = None):
        rname = name or getattr(fn, "__name__", "region")
        self.ledger = ledger or Ledger(f"dispatch:{rname}")
        self.region = _region(rname, ledger=self.ledger,
                              size_fn=size_fn)(fn)
        self.policy = AdaptivePolicy(cutoff=cutoff)
        self.executor = Executor(self.policy, self.ledger)
        self.name = self.region.name

    @property
    def cutoff(self) -> int:
        return self.policy.cutoff

    @cutoff.setter
    def cutoff(self, value: int) -> None:
        self.policy.cutoff = value

    @property
    def size_fn(self) -> Callable:
        return self.region.size_fn

    @size_fn.setter
    def size_fn(self, fn: Callable) -> None:
        # forward to the region so post-construction overrides keep routing
        # (the pre-regions implementation read self.size_fn on every call)
        self.region.size_fn = fn or default_size

    @property
    def stats(self) -> DispatchStats:
        """Snapshot of the ledger row (a fresh object per access — hold the
        dispatcher, not a stats reference, to observe updates)."""
        r = self.ledger.regions.get(self.region.name)
        if r is None:                      # pragma: no cover
            return DispatchStats()
        return DispatchStats(host_calls=r.host_calls,
                             device_calls=r.device_calls,
                             host_elems=r.host_elems,
                             device_elems=r.device_elems)

    @stats.setter
    def stats(self, value: DispatchStats) -> None:
        # the old reset idiom `td.stats = DispatchStats()` writes through
        # to the ledger row
        r = self.ledger.region(self.region.name)
        r.host_calls = value.host_calls
        r.device_calls = value.device_calls
        r.host_elems = value.host_elems
        r.device_elems = value.device_elems
        r.calls = value.host_calls + value.device_calls

    def __call__(self, *args, **kwargs):
        # routing + counts only, no block_until_ready: like the pre-regions
        # dispatcher, calls stay asynchronous so back-to-back dispatched ops
        # overlap; use `self.executor.run(self.region, ...)` for timed runs
        r = self.region
        n = r.size_fn(args, kwargs)
        tgt = self.policy.router.target(r, args, kwargs, size=n)
        out = r.executable(tgt)(*args, **kwargs)
        self.ledger.record(r.name, device=(tgt == "device"),
                           offloaded=r.offloaded, compute_s=0.0, elems=n)
        return out

    # ------------------------------------------------------------------
    def calibrate(self, make_args: Callable[[int], tuple],
                  sizes: Sequence[int] = (256, 1024, 4096, 16384, 65536),
                  reps: int = 20) -> int:
        """Time host vs device executables per size; set cutoff = crossover
        and record it into the ledger."""
        return self.policy.calibrate(self.region, make_args, sizes=sizes,
                                     reps=reps, ledger=self.ledger)


def offload(fn=None, *, cutoff: int = DEFAULT_CUTOFF, size_fn=None, name=None,
            ledger=None):
    """Decorator form: the one-line directive of listings 4-6."""
    def wrap(f):
        return TargetDispatch(f, cutoff=cutoff, size_fn=size_fn, name=name,
                              ledger=ledger)
    return wrap(fn) if fn is not None else wrap
