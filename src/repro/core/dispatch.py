"""RETIRED module — deprecation-alias stub only.

The ``TargetDispatch`` / ``offload`` / ``DispatchStats`` shims that lived
here were deleted: the ``if(target: n > TARGET_CUT_OFF)`` clause (paper C3,
listings 4-6) is the :class:`~repro.core.regions.SizeRouter` routing axis,
run *inside* any executor as :class:`~repro.core.regions.AdaptivePolicy`,
and the per-call host/device accounting that ``DispatchStats`` held lives
on :class:`~repro.core.ledger.RegionRecord` rows
(``host_calls``/``device_calls``/``host_elems``/``device_elems``).

Migration (see ARCHITECTURE.md, "Migration notes"):

    td = TargetDispatch(f, cutoff)   ->  r = region("f")(f)
    td(x)                                 ex = Executor(AdaptivePolicy(cutoff))
                                          ex.run(r, x)
    td.calibrate(make_args)          ->  AdaptivePolicy.calibrate(r, make_args)
    td.stats                         ->  ex.ledger.regions[r.name] /
                                         ex.report() (coverage_report schema)

Nothing in this repo imports this module anymore (CI enforces that via
``tools/check_retired_imports.py``); it exists only so external pre-regions
code fails loudly with directions instead of an ImportError.
"""
from __future__ import annotations

import warnings

from repro.core.regions import (DEFAULT_CUTOFF, AdaptivePolicy, Executor,  # noqa: F401
                                SizeRouter, default_size, region)

#: old alias for the old alias — kept because the sizing rule genuinely moved
_default_size = default_size


def offload(fn=None, *, cutoff=None, size_fn=None, name=None, ledger=None):
    """Deprecated decorator spelling of listings 4-6 (both the bare
    ``@offload`` and the ``@offload(cutoff=...)`` forms).  Returns a
    Region, not a self-routing TargetDispatch: ``cutoff`` is accepted for
    signature compatibility but routing now lives on the policy — run the
    region through ``Executor(AdaptivePolicy(cutoff))``."""
    def wrap(f):
        return region(name or getattr(f, "__name__", "region"),
                      size_fn=size_fn, ledger=ledger)(f)
    return wrap(fn) if fn is not None else wrap


warnings.warn(
    "repro.core.dispatch is retired: use repro.core.regions "
    "(Region + Executor(AdaptivePolicy(cutoff)))", DeprecationWarning,
    stacklevel=2)

_RETIRED = {
    "TargetDispatch": "Region + Executor(AdaptivePolicy(cutoff)) "
                      "[repro.core.regions]",
    "DispatchStats": "Ledger rows: RegionRecord.host_calls/device_calls/"
                     "host_elems/device_elems [repro.core.ledger]",
}


def __getattr__(name: str):
    if name in _RETIRED:
        raise AttributeError(
            f"repro.core.dispatch.{name} was removed; use {_RETIRED[name]}")
    raise AttributeError(name)
