"""Umpire-style pooled allocation (paper C4, §5).

The paper pools every buffer larger than 5K elements and reuses allocations
instead of alloc/free churn — on MI300A any allocator returns unified
memory, so one pool serves both host and device code.

Two pools here:

* :class:`HostStagingPool` — mutable numpy staging buffers (checkpoint
  serialization, data pipeline, discrete-executor staging). True in-place
  reuse, size-class binned, hit/miss accounting. This is the direct Umpire
  analogue.
* :class:`DeviceBufferPool` — jax.Array free-lists keyed by
  (shape, dtype, memory_kind) for serve-time KV pages and transient device
  scratch; "reuse" in JAX means handing back an existing buffer whose storage
  is recycled through donation in the consuming jitted function.
"""
from __future__ import annotations

import dataclasses
import threading
from typing import Dict, List, Optional, Tuple

import numpy as np

POOL_MIN_ELEMS = 5120            # the paper's "buffers larger than 5K elements"


def _size_class(nbytes: int) -> int:
    """Round up to the next power-of-two byte class (min 4 KiB)."""
    c = 4096
    while c < nbytes:
        c <<= 1
    return c


class PooledArray(np.ndarray):
    """ndarray subclass so the pool can attach backing-buffer metadata."""
    _pool_raw = None
    _pool_cls = 0


@dataclasses.dataclass
class PoolStats:
    hits: int = 0
    misses: int = 0
    unpooled: int = 0
    bytes_reused: int = 0
    bytes_allocated: int = 0
    high_water_bytes: int = 0           # peak pool footprint: in-use + free
    bytes_in_use: int = 0               # currently acquired, not yet released

    @property
    def hit_rate(self) -> float:
        t = self.hits + self.misses
        return self.hits / t if t else 0.0

    def as_dict(self) -> dict:
        d = dataclasses.asdict(self)
        d["hit_rate"] = self.hit_rate
        return d


class HostStagingPool:
    def __init__(self, min_elems: int = POOL_MIN_ELEMS,
                 max_bytes: Optional[int] = None):
        self.min_elems = min_elems
        self.max_bytes = max_bytes
        self._free: Dict[int, List[bytearray]] = {}
        self._lock = threading.Lock()
        self._outstanding_bytes = 0
        self.stats = PoolStats()

    def acquire(self, shape: Tuple[int, ...], dtype) -> np.ndarray:
        """A numpy view over a pooled backing buffer. Small buffers bypass
        the pool (paper threshold)."""
        dtype = np.dtype(dtype)
        elems = int(np.prod(shape)) if shape else 1
        nbytes = elems * dtype.itemsize
        if elems < self.min_elems:
            self.stats.unpooled += 1
            return np.empty(shape, dtype)
        cls = _size_class(nbytes)
        with self._lock:
            bucket = self._free.get(cls)
            if bucket:
                raw = bucket.pop()
                self.stats.hits += 1
                self.stats.bytes_reused += nbytes
            else:
                raw = bytearray(cls)
                self.stats.misses += 1
                self.stats.bytes_allocated += cls
            self._outstanding_bytes += cls
            self.stats.bytes_in_use = self._outstanding_bytes
            self.stats.high_water_bytes = max(self.stats.high_water_bytes,
                                              self._outstanding_bytes
                                              + self._free_bytes_locked())
        arr = np.frombuffer(raw, dtype=dtype, count=elems).reshape(shape) \
            .view(PooledArray)
        arr._pool_raw = raw                     # keep backing alive + findable
        arr._pool_cls = cls
        return arr

    def release(self, arr: np.ndarray) -> None:
        raw = getattr(arr, "_pool_raw", None)
        if raw is None:
            return
        cls = arr._pool_cls
        with self._lock:
            self._free.setdefault(cls, []).append(raw)
            self._outstanding_bytes -= cls
            self.stats.bytes_in_use = self._outstanding_bytes
            if self.max_bytes is not None:
                self._trim_locked()

    def _free_bytes_locked(self) -> int:
        return sum(cls * len(v) for cls, v in self._free.items())

    def _trim_locked(self) -> None:
        total = self._free_bytes_locked()
        for cls in sorted(self._free, reverse=True):
            while total > self.max_bytes and self._free[cls]:
                self._free[cls].pop()
                total -= cls

    @property
    def free_bytes(self) -> int:
        with self._lock:
            return self._free_bytes_locked()


class DeviceBufferPool:
    """Free-lists of jax.Arrays keyed by (shape, dtype, memory_kind).

    ``budget`` (a :class:`~repro.core.oversub.MemoryBudget`, duck-typed:
    ``charge``/``release``) mirrors the pool's device-kind in-use bytes
    into a logical device-capacity budget, so budget accounting and
    ``PoolStats.bytes_in_use`` agree byte-for-byte for device-resident
    buffers.  Host-kind buckets and sub-threshold (unpooled) buffers are
    never charged — they don't occupy the device partition."""

    def __init__(self, min_elems: int = POOL_MIN_ELEMS, budget=None):
        import jax
        self._jax = jax
        self.min_elems = min_elems
        self.budget = budget
        self._free: Dict[tuple, list] = {}
        # async lookahead staging acquires from a prefetch thread while the
        # main thread releases — free-list mutation must be atomic
        self._lock = threading.Lock()
        self._free_bytes = 0
        self.stats = PoolStats()
        try:
            self._default_kind = jax.devices()[0].default_memory().kind
        except Exception:                   # pragma: no cover
            self._default_kind = "device"

    def _charges_budget(self, key) -> bool:
        """Device-kind buckets count against the budget; explicit host
        placements don't.  Mesh-sharded buckets (key[2] is a sharding
        object) are device-resident by construction."""
        return self.budget is not None and key[2] != "pinned_host" \
            and key[2] != "unpinned_host"

    def _key(self, shape, dtype, memory_kind, sharding=None):
        # a mesh sharding IS the placement key: buffers split the same way
        # over the same mesh recycle together (per-APU shards of the node
        # replay), and never mix with single-device buckets.  Those key on
        # memory kind, with the backend's default kind normalized to
        # "device" so release() (which reads the buffer's actual sharding
        # kind) and acquire(None) agree on platforms whose default kind
        # isn't named "device"
        if sharding is not None:
            return (tuple(shape), str(np.dtype(dtype)), sharding)
        kind = memory_kind or "device"
        if kind == self._default_kind:
            kind = "device"
        return (tuple(shape), str(np.dtype(dtype)), kind)

    def _mesh_sharding(self, buf):
        """The buffer's NamedSharding when it was acquired against one
        (mesh-pooled bucket), else None (single-device bucket)."""
        try:
            s = buf.sharding
            return s if isinstance(s, self._jax.sharding.NamedSharding) \
                else None
        except Exception:
            return None

    def acquire(self, shape, dtype, memory_kind: Optional[str] = None,
                sharding=None):
        """A pooled jax.Array.  ``sharding`` (a hashable multi-device
        sharding, e.g. NamedSharding) pools per-mesh-placement instead of
        per-memory-kind — the sharded-replay path acquires its scattered
        operand buffers here so N-APU staging reuses storage exactly like
        the single-device discrete model (paper C4 at node scale)."""
        import jax.numpy as jnp
        elems = int(np.prod(shape)) if shape else 1
        if elems < self.min_elems:
            with self._lock:
                self.stats.unpooled += 1
            buf = jnp.zeros(shape, dtype)
            return self._jax.device_put(buf, sharding) \
                if sharding is not None else buf
        key = self._key(shape, dtype, memory_kind, sharding)
        nbytes = elems * np.dtype(dtype).itemsize
        with self._lock:
            bucket = self._free.get(key)
            if bucket:
                self.stats.hits += 1
                self.stats.bytes_reused += nbytes
                self._free_bytes -= nbytes
                self._account_acquire_locked(nbytes)
                if self._charges_budget(key):
                    self.budget.charge(nbytes)
                return bucket.pop()
            self.stats.misses += 1
            self.stats.bytes_allocated += nbytes
            self._account_acquire_locked(nbytes)
            if self._charges_budget(key):
                self.budget.charge(nbytes)
        buf = jnp.zeros(shape, dtype)
        if sharding is not None:
            buf = self._jax.device_put(buf, sharding)
        elif memory_kind and memory_kind != "device":
            d = self._jax.devices()[0]
            sh = self._jax.sharding.SingleDeviceSharding(d, memory_kind=memory_kind)
            buf = self._jax.device_put(buf, sh)
        return buf

    def _account_acquire_locked(self, nbytes: int) -> None:
        self.stats.bytes_in_use += nbytes
        self.stats.high_water_bytes = max(self.stats.high_water_bytes,
                                          self.stats.bytes_in_use
                                          + self._free_bytes)

    def release(self, buf) -> None:
        try:
            key = self._key(buf.shape, buf.dtype,
                            getattr(buf.sharding, "memory_kind", None),
                            self._mesh_sharding(buf))
        except Exception:
            return
        if int(np.prod(buf.shape) if buf.shape else 1) < self.min_elems:
            return
        nbytes = int(buf.nbytes)
        with self._lock:
            self._free.setdefault(key, []).append(buf)
            self._free_bytes += nbytes
            # releases may hand back a same-sized buffer that OWNS pooled
            # storage (a donating-copy result) rather than the acquired
            # object itself — byte symmetry holds, so floor at zero only
            # defends against releases the pool never saw acquired
            self.stats.bytes_in_use = max(0, self.stats.bytes_in_use - nbytes)
            if self._charges_budget(key):
                self.budget.release(nbytes)

    @property
    def free_bytes(self) -> int:
        with self._lock:
            return self._free_bytes


class BufferRotation:
    """Double-buffered (depth-N) rotation over a :class:`DeviceBufferPool`.

    Async lookahead staging (``repro.core.program.AsyncExecutor``) migrates
    region *k+1*'s operands while region *k* still computes out of ITS staged
    buffers — the two operand sets must come from disjoint pooled buffers.
    A rotation gives each in-flight region its own *bank*: ``acquire`` lands
    in the active bank, ``advance`` opens a fresh bank for the next region,
    and ``retire`` returns the oldest bank's buffers to the backing pool once
    its region has finished computing.  With ``depth=2`` this is classic
    double buffering; deeper rotations support deeper lookahead.

    Banks are **generation-tagged**: ``drain`` (end of a replay) bumps the
    rotation's generation, and registrations carrying a stale generation —
    a background staging task that outlived the replay that submitted it —
    release their buffer straight back to the pool instead of parking it
    in a bank the next replay would recycle mid-use.  Background tasks get
    their tag through :meth:`handle`.
    """

    def __init__(self, pool: Optional[DeviceBufferPool] = None,
                 depth: int = 2):
        if depth < 2:
            raise ValueError("rotation needs >= 2 banks to double-buffer")
        self.pool = pool or DeviceBufferPool()
        self.depth = depth
        self._banks: List[list] = [[]]
        self._lock = threading.Lock()
        self.rotations = 0
        self.generation = 0

    def register(self, buf, generation: Optional[int] = None) -> None:
        """Track an already-acquired buffer in the active bank.  Stagers that
        route pooled storage through a donating copy must register the copy's
        RESULT (which owns the recycled storage), not the consumed buffer.

        ``generation`` (from :meth:`handle`) defends the banks against
        stale background registrations: a tag minted before the last
        ``drain`` returns the buffer to the pool immediately."""
        with self._lock:
            if generation is not None and generation != self.generation:
                self.pool.release(buf)          # stale task: don't park it
                return
            self._banks[-1].append(buf)

    def acquire(self, shape, dtype, memory_kind: Optional[str] = None):
        buf = self.pool.acquire(shape, dtype, memory_kind)
        self.register(buf)
        return buf

    def handle(self) -> "_RotationHandle":
        """A generation-tagged view for a background staging task.  It
        quacks like the rotation (``pool`` attribute, ``register``) but
        pins the CURRENT generation: if the rotation is drained before the
        task lands its buffers, they go back to the pool instead of into a
        fresh replay's banks."""
        return _RotationHandle(self)

    def advance(self) -> None:
        """Open a new active bank (call when staging for the NEXT region
        begins). If the rotation is full, the oldest bank is retired first."""
        with self._lock:
            while len(self._banks) >= self.depth:
                self._retire_locked()
            self._banks.append([])
            self.rotations += 1

    def retire(self) -> None:
        """Release the oldest bank's buffers back to the pool (call once the
        region computing out of that bank has completed)."""
        with self._lock:
            self._retire_locked()

    def _retire_locked(self) -> None:
        if len(self._banks) > 1 or (self._banks and self._banks[0]):
            for buf in self._banks.pop(0):
                self.pool.release(buf)
            if not self._banks:
                self._banks.append([])

    def drain(self) -> None:
        """Retire every bank (end of a replay) and open a new generation:
        any still-running background task registering after this point
        releases to the pool instead of parking in the next replay's
        banks."""
        with self._lock:
            self.generation += 1
            while self._banks and (len(self._banks) > 1 or self._banks[0]):
                for buf in self._banks.pop(0):
                    self.pool.release(buf)
            if not self._banks:
                self._banks.append([])

    @property
    def in_flight(self) -> int:
        with self._lock:
            return sum(len(b) for b in self._banks)


class _RotationHandle:
    """Generation-tagged proxy handed to background staging tasks (see
    :meth:`BufferRotation.handle`)."""

    __slots__ = ("_rot", "generation", "pool")

    def __init__(self, rot: BufferRotation):
        self._rot = rot
        self.generation = rot.generation
        self.pool = rot.pool

    def register(self, buf) -> None:
        self._rot.register(buf, generation=self.generation)

    def acquire(self, shape, dtype, memory_kind: Optional[str] = None):
        buf = self.pool.acquire(shape, dtype, memory_kind)
        self.register(buf)
        return buf


GLOBAL_STAGING_POOL = HostStagingPool()
