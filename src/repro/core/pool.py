"""Umpire-style pooled allocation (paper C4, §5).

The paper pools every buffer larger than 5K elements and reuses allocations
instead of alloc/free churn — on MI300A any allocator returns unified
memory, so one pool serves both host and device code.

Two pools here:

* :class:`HostStagingPool` — mutable numpy staging buffers (checkpoint
  serialization, data pipeline, discrete-executor staging). True in-place
  reuse, size-class binned, hit/miss accounting. This is the direct Umpire
  analogue.
* :class:`DeviceBufferPool` — jax.Array free-lists keyed by
  (shape, dtype, memory_kind) for serve-time KV pages and transient device
  scratch; "reuse" in JAX means handing back an existing buffer whose storage
  is recycled through donation in the consuming jitted function.
"""
from __future__ import annotations

import dataclasses
import threading
from typing import Dict, List, Optional, Tuple

import numpy as np

POOL_MIN_ELEMS = 5120            # the paper's "buffers larger than 5K elements"


def _size_class(nbytes: int) -> int:
    """Round up to the next power-of-two byte class (min 4 KiB)."""
    c = 4096
    while c < nbytes:
        c <<= 1
    return c


class PooledArray(np.ndarray):
    """ndarray subclass so the pool can attach backing-buffer metadata."""
    _pool_raw = None
    _pool_cls = 0


@dataclasses.dataclass
class PoolStats:
    hits: int = 0
    misses: int = 0
    unpooled: int = 0
    bytes_reused: int = 0
    bytes_allocated: int = 0
    high_water_bytes: int = 0

    @property
    def hit_rate(self) -> float:
        t = self.hits + self.misses
        return self.hits / t if t else 0.0


class HostStagingPool:
    def __init__(self, min_elems: int = POOL_MIN_ELEMS,
                 max_bytes: Optional[int] = None):
        self.min_elems = min_elems
        self.max_bytes = max_bytes
        self._free: Dict[int, List[bytearray]] = {}
        self._lock = threading.Lock()
        self._outstanding_bytes = 0
        self.stats = PoolStats()

    def acquire(self, shape: Tuple[int, ...], dtype) -> np.ndarray:
        """A numpy view over a pooled backing buffer. Small buffers bypass
        the pool (paper threshold)."""
        dtype = np.dtype(dtype)
        elems = int(np.prod(shape)) if shape else 1
        nbytes = elems * dtype.itemsize
        if elems < self.min_elems:
            self.stats.unpooled += 1
            return np.empty(shape, dtype)
        cls = _size_class(nbytes)
        with self._lock:
            bucket = self._free.get(cls)
            if bucket:
                raw = bucket.pop()
                self.stats.hits += 1
                self.stats.bytes_reused += nbytes
            else:
                raw = bytearray(cls)
                self.stats.misses += 1
                self.stats.bytes_allocated += cls
            self._outstanding_bytes += cls
            self.stats.high_water_bytes = max(self.stats.high_water_bytes,
                                              self._outstanding_bytes
                                              + self._free_bytes_locked())
        arr = np.frombuffer(raw, dtype=dtype, count=elems).reshape(shape) \
            .view(PooledArray)
        arr._pool_raw = raw                     # keep backing alive + findable
        arr._pool_cls = cls
        return arr

    def release(self, arr: np.ndarray) -> None:
        raw = getattr(arr, "_pool_raw", None)
        if raw is None:
            return
        cls = arr._pool_cls
        with self._lock:
            self._free.setdefault(cls, []).append(raw)
            self._outstanding_bytes -= cls
            if self.max_bytes is not None:
                self._trim_locked()

    def _free_bytes_locked(self) -> int:
        return sum(cls * len(v) for cls, v in self._free.items())

    def _trim_locked(self) -> None:
        total = self._free_bytes_locked()
        for cls in sorted(self._free, reverse=True):
            while total > self.max_bytes and self._free[cls]:
                self._free[cls].pop()
                total -= cls

    @property
    def free_bytes(self) -> int:
        with self._lock:
            return self._free_bytes_locked()


class DeviceBufferPool:
    """Free-lists of jax.Arrays keyed by (shape, dtype, memory_kind)."""

    def __init__(self, min_elems: int = POOL_MIN_ELEMS):
        import jax
        self._jax = jax
        self.min_elems = min_elems
        self._free: Dict[tuple, list] = {}
        self.stats = PoolStats()
        try:
            self._default_kind = jax.devices()[0].default_memory().kind
        except Exception:                   # pragma: no cover
            self._default_kind = "device"

    def _key(self, shape, dtype, memory_kind):
        # normalize the backend's default kind to "device" so release()
        # (which reads the buffer's actual sharding kind) and acquire(None)
        # agree on platforms whose default kind isn't named "device"
        kind = memory_kind or "device"
        if kind == self._default_kind:
            kind = "device"
        return (tuple(shape), str(np.dtype(dtype)), kind)

    def acquire(self, shape, dtype, memory_kind: Optional[str] = None):
        import jax.numpy as jnp
        elems = int(np.prod(shape)) if shape else 1
        if elems < self.min_elems:
            self.stats.unpooled += 1
            return jnp.zeros(shape, dtype)
        key = self._key(shape, dtype, memory_kind)
        bucket = self._free.get(key)
        if bucket:
            self.stats.hits += 1
            self.stats.bytes_reused += elems * np.dtype(dtype).itemsize
            return bucket.pop()
        self.stats.misses += 1
        self.stats.bytes_allocated += elems * np.dtype(dtype).itemsize
        buf = jnp.zeros(shape, dtype)
        if memory_kind and memory_kind != "device":
            d = self._jax.devices()[0]
            sh = self._jax.sharding.SingleDeviceSharding(d, memory_kind=memory_kind)
            buf = self._jax.device_put(buf, sh)
        return buf

    def release(self, buf) -> None:
        try:
            key = self._key(buf.shape, buf.dtype,
                            getattr(buf.sharding, "memory_kind", None))
        except Exception:
            return
        if int(np.prod(buf.shape) if buf.shape else 1) < self.min_elems:
            return
        self._free.setdefault(key, []).append(buf)


GLOBAL_STAGING_POOL = HostStagingPool()
