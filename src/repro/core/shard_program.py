"""Multi-APU region programs: shard a captured replay across a device mesh.

The paper ports OpenFOAM to ONE MI300A; a production node ships four of
them linked by Infinity Fabric, and the follow-up literature ("Inter-APU
Communication on AMD MI300A Systems via Infinity Fabric", the Grace-Hopper
unified-memory studies) shows that scaling a unified-memory code across a
node hinges on two things the single-device story never surfaces:
topology-aware placement and *communication accounting* — knowing how much
of a step is compute, how much is staging, and how much is inter-APU
boundary traffic.

This module adds that node dimension to captured programs
(:mod:`repro.core.program`):

* :func:`shard_program` / :class:`ShardedProgram` — wrap a captured
  :class:`~repro.core.program.RegionProgram` for a 1-D ``jax.Mesh`` of N
  simulated APUs (CPU containers simulate the node with
  ``XLA_FLAGS=--xla_force_host_platform_device_count=N`` — the
  ``launch.mesh`` trick; :func:`repro.launch.mesh.make_apu_mesh` builds the
  mesh).

* :class:`ShardExecutor` — the executor that replays the trace
  domain-decomposed: every array operand is placed with a ``NamedSharding``
  splitting one dimension (``shard_dim``) over the mesh axis, every region
  executes SPMD across all APUs (XLA partitions the *identical* region
  function — application code is untouched, the paper's C1 claim at node
  scale), and regions that declare a ``stencil`` get an explicit
  **halo-exchange region** inserted before them.

* halo exchange — the width is inferred from the region's declared DIA
  offset table (:data:`repro.cfd.dia.STENCIL_OFFSETS`, see
  :func:`halo_width`).  The exchange itself is a bit-exact value identity,
  ``roll(roll(x, +w), -w)`` along the sharded dimension: XLA partitions
  each roll into exactly the boundary-plane transfers a width-``w`` halo
  swap performs (w planes across every shard boundary, each direction), so
  the measured wall time *is* the inter-APU traffic cost while the value —
  and therefore the replayed numerics — is unchanged.  It appears in every
  per-device ledger as a ``halo(<region>)`` row carrying ``exchange_s`` /
  ``exchange_bytes``.

* per-device ledgers — each simulated APU owns a
  :class:`~repro.core.ledger.Ledger`.  The decomposition is symmetric, so
  each device's rows record its **local share**: ``1/N`` of every measured
  wall interval and of every byte/element count.  Summing the per-device
  ledgers (``Ledger.merged``) therefore reproduces the measured node wall
  split exactly; ``ShardExecutor.report()`` returns that aggregate with a
  ``per_device`` breakdown splitting compute, staging, and exchange time.

Any :class:`~repro.core.regions.ExecutionPolicy` applies:

- ``UnifiedPolicy`` — operands stay resident in the decomposition; only
  halo-exchange regions move bytes between APUs (the paper's APU model,
  scaled out: migration deleted, Fabric traffic remains).
- ``DiscretePolicy`` — every region call stages its operands host->APUs
  (scatter through pooled sharded buffers) and its results APUs->host: the
  managed-memory node where the host bounce multiplies with N.
- ``AdaptivePolicy`` — calls under the calibrated cutoff gather to the
  host and run there (small problems don't amortize a node), the rest run
  decomposed.

Numerics: region math is elementwise/stencil arithmetic partitioned by
XLA, so sharded replay is bit-comparable to the single-device replay of
the same program; only compiler re-fusion across different sharding
signatures can perturb results, within the float32 tolerance documented in
docs/DESIGN.md §2.
"""
from __future__ import annotations

import time
import weakref
from typing import Any, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.ledger import Ledger
from repro.core.pool import DeviceBufferPool
from repro.core.program import Lit, RegionProgram, _is_array, _resolver
from repro.core.regions import (ExecutionPolicy, Executor, Region,
                                UnifiedPolicy, _copy_into, policy_selector)
from repro.core.umem import replicated_sharding, shard_along


def halo_width(offsets, axis: int) -> int:
    """Halo width a 1-D decomposition along grid axis ``axis`` must
    exchange for a stencil with DIA offset table ``offsets`` — the maximum
    reach of any band along that axis.

        halo_width(dia.STENCIL_OFFSETS, axis=2)                  -> 1
        halo_width(dia.compose_offsets(S, S), axis=2)            -> 2
        halo_width(None, axis=2)                                 -> 0
    """
    if not offsets:
        return 0
    return max((abs(d) for ax, d in offsets if ax == axis), default=0)


class ShardExecutor:
    """Replays :class:`RegionProgram`\\ s domain-decomposed over a 1-D mesh
    of simulated APUs, under any :class:`ExecutionPolicy`, with one
    :class:`Ledger` per device.

    ``shard_dim`` selects the array dimension split over the mesh axis
    (default ``-1``: the trailing dimension, which for ``[nx,ny,nz]`` CFD
    fields and ``[6,nx,ny,nz]`` DIA coefficient stacks alike is the grid z
    axis).  Leaves whose ``shard_dim`` extent does not divide by the mesh
    size replicate instead.  ``stencil_axis`` is the *grid* axis that
    ``shard_dim`` decomposes (default ``shard_dim % 3``, i.e. z for 3-D
    fields); halo widths are inferred against it from each region's
    declared ``stencil`` offsets.

    ``prog.replay(shard_executor, *inputs)`` dispatches here through the
    standard ``replay_program`` hook, so a ShardExecutor drops in anywhere
    an :class:`Executor` or ``AsyncExecutor`` does.
    """

    def __init__(self, policy: Optional[ExecutionPolicy], mesh,
                 axis: str = "apu", shard_dim: int = -1,
                 stencil_axis: Optional[int] = None):
        self.policy = policy or UnifiedPolicy()
        self.mesh = mesh
        self.axis = axis
        if axis not in mesh.axis_names:
            raise ValueError(f"mesh has no axis {axis!r}: {mesh.axis_names}")
        self.n_devices = int(mesh.devices.size)
        self.shard_dim = shard_dim
        self.stencil_axis = (stencil_axis if stencil_axis is not None
                             else shard_dim % 3)
        self.mode = f"{self.policy.name}+sharded[{self.n_devices}x{axis}]"
        #: one ledger per simulated APU; each records its 1/N local share
        self.ledgers: List[Ledger] = [
            Ledger(f"{self.policy.name}@{axis}{i}")
            for i in range(self.n_devices)]
        # host-routed calls (adaptive cutoff) run once, undecomposed — they
        # belong to the node, not to any one APU
        self.host_ledger = Ledger(f"{self.policy.name}@host")
        self._inner = Executor(self.policy, self.host_ledger)
        self._replicated = replicated_sharding(mesh)
        self._sharding_cache: dict = {}      # (ndim, extent) -> NamedSharding
        # captured constants scatter across the mesh ONCE per executor, not
        # once per replayed step; keying by the Lit descriptor object keeps
        # it alive, so a recycled address can never alias a stale entry
        self._lit_cache: dict = {}           # Lit descriptor -> placed leaf
        # same-named distinct regions must not merge into one row (the
        # Executor._row_name contract, upheld per executor here — every
        # per-device ledger shares this executor's row names)
        self._row_names = weakref.WeakKeyDictionary()      # Region -> str
        self._taken_rows: set = set()
        self._halo_regions = weakref.WeakKeyDictionary()   # Region -> Region
        self._registry = Ledger(self.mode + "-rows")       # halo-name registry
        stager = self.policy.stager
        self._device_pool = getattr(stager, "device_pool", None) \
            or DeviceBufferPool()

    # -- accounting rows -------------------------------------------------
    def _row_name(self, r: Region) -> str:
        """Ledger row for this region across ALL of this executor's
        per-device ledgers.  Distinct region objects that happen to share
        a name (registered in different app ledgers) get re-uniquified —
        the same contract ``Executor._row_name`` keeps."""
        name = self._row_names.get(r)
        if name is None:
            name = r.name
            k = 2
            while name in self._taken_rows:
                name = f"{r.name}#{k}"
                k += 1
            self._taken_rows.add(name)
            self._row_names[r] = name
        return name

    # -- placement -------------------------------------------------------
    def sharding_for(self, leaf):
        """The NamedSharding this decomposition gives one array leaf:
        ``shard_dim`` split over the mesh axis when divisible, replicated
        otherwise.  Cached per (ndim, extent) — the replay hot loop asks
        for every leaf of every op inside timed intervals."""
        shape = getattr(leaf, "shape", ())
        ndim = len(shape)
        if not (ndim and -ndim <= self.shard_dim < ndim):
            return self._replicated
        ext = shape[self.shard_dim]
        key = (ndim, ext)
        sh = self._sharding_cache.get(key)
        if sh is None:
            sh = self._replicated
            if ext >= self.n_devices and ext % self.n_devices == 0:
                sh = shard_along(self.mesh, self.axis, ndim, self.shard_dim)
            self._sharding_cache[key] = sh
        return sh

    def _place(self, x):
        sh = self.sharding_for(x)
        if isinstance(x, jax.Array) and x.sharding == sh:
            return x
        return jax.device_put(x, sh)

    def _is_sharded(self, x) -> bool:
        sh = self.sharding_for(x)
        return sh is not self._replicated and isinstance(x, jax.Array) \
            and x.sharding == sh

    # -- staging (discrete node model) -----------------------------------
    def _stage_scatter(self, leaves) -> Tuple[list, float, int, list]:
        """Migrate operand leaves host -> N APUs: read each array out of
        host memory and scatter it into a pooled sharded device buffer
        (donation recycles the pool storage, paper C4 at node scale).
        Returns (placed, seconds, bytes, acquired_buffers)."""
        t0 = time.perf_counter()
        placed, nbytes, acquired = [], 0, []
        for x in leaves:
            if not _is_array(x):
                placed.append(x)
                continue
            h = np.asarray(x)                       # host page read / gather
            sh = self.sharding_for(h)
            dst = self._device_pool.acquire(h.shape, h.dtype, sharding=sh)
            y = _copy_into(h, dst)                  # host -> APUs scatter
            if y.sharding != sh:                    # pragma: no cover
                y = jax.device_put(y, sh)
            placed.append(y)
            acquired.append(y)
            nbytes += h.nbytes
        jax.block_until_ready(acquired)
        return placed, time.perf_counter() - t0, nbytes, acquired

    # -- halo exchange ---------------------------------------------------
    def _halo_region(self, r: Region) -> Optional[Region]:
        """The explicit halo-exchange Region inserted before stencil region
        ``r`` (cached per region).  Its fn is the bit-exact roll round-trip
        identity whose partitioned form moves exactly the width-``w``
        boundary planes across every shard boundary, both directions."""
        cached = self._halo_regions.get(r)
        if cached is not None:
            return cached or None
        w = halo_width(r.stencil, self.stencil_axis)
        if w == 0:
            self._halo_regions[r] = False
            return None
        dim = self.shard_dim

        def exchange(x, _w=w, _dim=dim):
            return jnp.roll(jnp.roll(x, _w, _dim), -_w, _dim)

        halo = Region(name=f"halo({self._row_name(r)})", fn=exchange,
                      offloaded=True, ledger=self._registry)
        halo.halo_width = w
        self._halo_regions[r] = halo
        return halo

    def _halo_leaf_indices(self, op) -> List[int]:
        """Which operand leaves the halo exchange covers: the region's
        declared ``halo_args`` (top-level positions/names), else every
        array leaf."""
        r = op.region
        spec = getattr(r, "halo_args", None)
        if spec is None:
            return list(range(len(op.leaves)))
        keys = set(spec)
        for name in [k for k in keys if isinstance(k, str)]:
            idx = r._param_index.get(name)
            if idx is not None:
                keys.add(idx)
        return [i for i, k in enumerate(op.arg_keys) if k in keys]

    def _exchange(self, op, placed) -> Tuple[list, float, int]:
        """Run the halo-exchange region over the stencil-read operands.
        Returns (leaves, wall seconds, per-device bytes sent)."""
        halo = self._halo_region(op.region)
        if halo is None:
            return placed, 0.0, 0
        w = halo.halo_width
        idxs = [i for i in self._halo_leaf_indices(op)
                if self._is_sharded(placed[i])]
        if not idxs:
            return placed, 0.0, 0
        t0 = time.perf_counter()
        out = list(placed)
        bytes_per_dev = 0
        for i in idxs:
            x = placed[i]
            out[i] = halo.jitted(x)
            if self.n_devices > 1:
                # each APU sends w boundary planes in each direction
                plane = x.nbytes // x.shape[self.shard_dim]
                bytes_per_dev += 2 * w * plane
        jax.block_until_ready([out[i] for i in idxs])
        return out, time.perf_counter() - t0, bytes_per_dev

    # -- Executor protocol -----------------------------------------------
    def run(self, target_region, *args, **kwargs):
        """Single calls fall back to the synchronous inner executor (host
        ledger); the decomposition only engages on whole programs."""
        return self._inner.run(target_region, *args, **kwargs)

    # -- program replay --------------------------------------------------
    def replay_program(self, prog: RegionProgram, *inputs):
        pol = self.policy
        stager = pol.stager
        selector = policy_selector(pol)
        staging = getattr(stager, "stages", False)
        nd = self.n_devices
        in_leaves = list(prog._input_leaves(inputs))
        if not staging:
            # unified node model: inputs scatter once and stay decomposed
            in_leaves = [self._place(x) if _is_array(x) else x
                         for x in in_leaves]
        env: List[List[Any]] = []
        resolve = _resolver(env, in_leaves)

        def resolve_placed(d):
            x = resolve(d)
            if staging or not _is_array(x):
                return x
            if isinstance(d, Lit):     # constants: scatter once, ever
                y = self._lit_cache.get(d)
                if y is None:
                    y = self._lit_cache[d] = self._place(x)
                return y
            return self._place(x)      # In/Ref leaves are already placed


        for op in prog.ops:
            r = op.region
            raw = [resolve_placed(d) for d in op.leaves]
            args, kwargs = jax.tree.unflatten(op.in_tree, raw)
            n = r.size_fn(args, kwargs)
            tgt = pol.router.target(r, args, kwargs, size=n)
            if tgt == "host":
                env.append(self._run_host(r, op, raw, n))
                continue
            # variant selection happens here, per replayed call — the
            # captured trace stores Regions, so the same program runs under
            # any Selector at node scale too (XLA partitions whichever
            # variant's executable is chosen; resolve(): unknown -> ref)
            impl = r.resolve(selector.select(r, tgt, args, kwargs, size=n))
            staging_s, staging_b = 0.0, 0
            acquired: list = []
            if staging and r.offloaded:
                raw, staging_s, staging_b, acquired = \
                    self._stage_scatter(raw)
            raw, exchange_s, exchange_bytes_dev = self._exchange(op, raw)
            args, kwargs = jax.tree.unflatten(op.in_tree, raw)
            t0 = time.perf_counter()
            # donate=False: sharded operands may be pool-staged or reused
            # by the exchange bookkeeping — donation is a single-device
            # executor optimization
            out = r.jitted_variant(impl, donate=False)(*args, **kwargs)
            jax.block_until_ready(out)
            compute_s = time.perf_counter() - t0
            if staging and r.offloaded:
                out, s, b = stager.stage_out(r, out, None)
                staging_s += s
                staging_b += b
                for buf in acquired:          # staged operands are dead
                    self._device_pool.release(buf)
            else:
                out = jax.tree.map(
                    lambda x: self._place(x) if _is_array(x) else x, out)
            halo = self._halo_region(r)
            row = self._row_name(r)
            for led in self.ledgers:
                led.record(row, device=True, offloaded=r.offloaded,
                           compute_s=compute_s / nd,
                           staging_s=staging_s / nd,
                           staging_bytes=staging_b // nd,
                           elems=n // nd, impl=impl)
                if halo is not None:
                    led.record(halo.name, device=True, offloaded=True,
                               compute_s=0.0,
                               exchange_s=exchange_s / nd,
                               exchange_bytes=exchange_bytes_dev)
            env.append(jax.tree.leaves(out))
        return jax.tree.unflatten(prog.out_tree,
                                  [resolve(d) for d in prog.out_leaves])

    def _run_host(self, r: Region, op, raw, n) -> list:
        """Adaptive small-problem path: gather operands to the host, run
        the host executable once, account on the node's host ledger."""
        host = [np.asarray(x) if _is_array(x) else x for x in raw]
        args, kwargs = jax.tree.unflatten(op.in_tree, host)
        impl = r.resolve(policy_selector(self.policy).select(
            r, "host", args, kwargs, size=n))
        t0 = time.perf_counter()
        out = r.executable("host", impl, donate=False)(*args, **kwargs)
        jax.block_until_ready(out)
        self.host_ledger.record(self._row_name(r), device=False,
                                offloaded=r.offloaded,
                                compute_s=time.perf_counter() - t0, elems=n,
                                impl=impl)
        return jax.tree.leaves(out)

    # -- accounting ------------------------------------------------------
    def reset_timings(self) -> None:
        for led in (*self.ledgers, self.host_ledger):
            led.reset_timings()

    def _device_summary(self, i: int, led: Ledger) -> dict:
        rows = list(led.regions.values())
        return {
            "device": i,
            "calls": sum(r.calls for r in rows),
            "compute_s": sum(r.compute_s for r in rows),
            "staging_s": sum(r.staging_s for r in rows),
            "exchange_s": sum(r.exchange_s for r in rows),
            "staging_bytes": sum(r.staging_bytes for r in rows),
            "exchange_bytes": sum(r.exchange_bytes for r in rows),
            "elems": sum(r.host_elems + r.device_elems for r in rows),
        }

    def report(self) -> dict:
        """Node-level coverage: the per-device ledgers summed (which, by
        the 1/N-share recording convention, reproduces the measured wall
        split exactly) plus host-routed calls, with a ``per_device``
        compute/staging/exchange breakdown."""
        node = Ledger.merged((*self.ledgers, self.host_ledger),
                             name=self.mode)
        rep = node.coverage_report()
        rep["mode"] = self.mode
        rep["devices"] = self.n_devices
        rep["mesh_axis"] = self.axis
        rep["per_device"] = [self._device_summary(i, led)
                             for i, led in enumerate(self.ledgers)]
        return rep


class ShardedProgram:
    """A captured program bound to its multi-APU executor: ``replay`` runs
    the decomposed trace, ``replay_batch`` scatters N independent instances
    across the APUs (data parallelism over the mesh axis), and
    ``coverage_report`` is the aggregated node view."""

    def __init__(self, prog: RegionProgram, executor: ShardExecutor):
        self.prog = prog
        self.executor = executor

    @property
    def mesh(self):
        return self.executor.mesh

    @property
    def ledgers(self) -> List[Ledger]:
        return self.executor.ledgers

    def replay(self, *inputs):
        return self.prog.replay(self.executor, *inputs)

    # the Executor protocol, so a ShardedProgram itself drops in where an
    # executor is expected (SimpleFoam.replay_steps, benchmarks)
    def replay_program(self, prog: RegionProgram, *inputs):
        return self.executor.replay_program(prog, *inputs)

    def run(self, target_region, *args, **kwargs):
        return self.executor.run(target_region, *args, **kwargs)

    def replay_batch(self, *stacked_inputs, in_axes=0):
        """Replay N stacked independent instances with the batch dimension
        scattered over the mesh axis — each simulated APU decodes its own
        slice of the requests (the ``serve --mesh`` path)."""
        ex = self.executor
        mesh, axis, nd = ex.mesh, ex.axis, ex.n_devices

        def scatter(x):
            if not _is_array(x) or not getattr(x, "ndim", 0):
                return x
            sh = shard_along(mesh, axis, x.ndim, 0) \
                if x.shape[0] % nd == 0 else replicated_sharding(mesh)
            return jax.device_put(x, sh)

        placed = jax.tree.map(scatter, stacked_inputs)
        t0 = time.perf_counter()
        out = self.prog.replay_batch(*placed, in_axes=in_axes)
        jax.block_until_ready(out)
        dt = time.perf_counter() - t0
        sizes = [int(a.size) for a in jax.tree.leaves(stacked_inputs)
                 if hasattr(a, "size")]
        for led in ex.ledgers:
            led.record(f"{self.prog.name}[batch]", device=True,
                       offloaded=True, compute_s=dt / nd,
                       elems=max(sizes, default=0) // nd)
        return out

    def coverage_report(self) -> dict:
        return self.executor.report()

    def report(self) -> dict:
        return self.executor.report()

    def reset_timings(self) -> None:
        self.executor.reset_timings()

    def summary(self) -> str:
        ex = self.executor
        halos = sum(1 for op in self.prog.ops
                    if halo_width(op.region.stencil, ex.stencil_axis))
        return (f"ShardedProgram({self.prog.name!r}: {len(self.prog)} ops, "
                f"{ex.n_devices}x{ex.axis!r} decomposition on dim "
                f"{ex.shard_dim}, {halos} halo-exchanged ops, "
                f"policy={ex.policy.name})")


def shard_program(prog: RegionProgram, mesh,
                  policy: Optional[ExecutionPolicy] = None, *,
                  axis: str = "apu", shard_dim: int = -1,
                  stencil_axis: Optional[int] = None) -> ShardedProgram:
    """Bind a captured program to a 1-D mesh of simulated APUs.

        mesh = make_apu_mesh(4)          # repro.launch.mesh
        sp = shard_program(prog, mesh, DiscretePolicy())
        out = sp.replay(*inputs)
        sp.coverage_report()["per_device"]     # compute/staging/exchange
    """
    return ShardedProgram(prog, ShardExecutor(
        policy, mesh, axis=axis, shard_dim=shard_dim,
        stencil_axis=stencil_axis))
