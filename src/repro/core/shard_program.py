"""Multi-APU region programs: shard a captured replay across a device mesh.

The paper ports OpenFOAM to ONE MI300A; a production node ships four of
them linked by Infinity Fabric, and the follow-up literature ("Inter-APU
Communication on AMD MI300A Systems via Infinity Fabric", the Grace-Hopper
unified-memory studies) shows that scaling a unified-memory code across a
node hinges on two things the single-device story never surfaces:
topology-aware placement and *communication accounting* — knowing how much
of a step is compute, how much is staging, and how much is inter-APU
boundary traffic.

This module adds that node dimension to captured programs
(:mod:`repro.core.program`):

* :func:`shard_program` / :class:`ShardedProgram` — wrap a captured
  :class:`~repro.core.program.RegionProgram` for a 1-D/2-D/3-D ``jax.Mesh``
  of N simulated APUs (CPU containers simulate the node with
  ``XLA_FLAGS=--xla_force_host_platform_device_count=N`` — the
  ``launch.mesh`` trick; :func:`repro.launch.mesh.make_apu_mesh` builds the
  mesh, ``make_apu_mesh((2, 2))`` for a 2-D decomposition that cuts
  surface-to-volume).

* :class:`ShardExecutor` — the executor that replays the trace
  domain-decomposed: every array operand is placed with a ``NamedSharding``
  splitting one array dimension per mesh axis (``shard_dim``), every region
  executes SPMD across all APUs (XLA partitions the *identical* region
  function — application code is untouched, the paper's C1 claim at node
  scale), and regions that declare a ``stencil`` get an explicit
  **halo-exchange region** scheduled around them.

* halo exchange — the width is inferred from the region's declared DIA
  offset table (:data:`repro.cfd.dia.STENCIL_OFFSETS`, see
  :func:`halo_width` / :meth:`Region.stencil_width`).  The exchange itself
  is a bit-exact value identity, ``roll(roll(x, +w), -w)`` along each
  decomposed dimension: XLA partitions each roll into exactly the
  boundary-plane transfers a width-``w`` halo swap performs (w planes
  across every shard boundary, each direction), so the measured wall time
  *is* the inter-APU traffic cost while the value — and therefore the
  replayed numerics — is unchanged.  It appears in every per-device ledger
  as a ``halo(<region>)`` row carrying ``exchange_s`` / ``exchange_bytes``.

* **exchange schedules** (the halo-exchange-tax mitigation, ROADMAP 2):

  - ``overlap=False`` — *sequential*: exchange, then compute consuming the
    exchanged operands (the PR-3 baseline; every exchange is exposed wall
    time).
  - ``overlap=True`` (default) — *overlapped*: the exchange is dispatched
    asynchronously right after the region's interior compute (same
    thread — collectives deadlock if two threads interleave their
    per-device enqueue order, see :meth:`_dispatch_exchange`) and a
    single background worker waits out the transfer while the main loop
    moves on; because the exchange is a value identity, the interior IS
    the whole region and never waits on it.  A bounded lookahead (the
    :class:`~repro.core.program.AsyncExecutor` machinery) additionally
    dispatches the next due exchange whose operands are already
    resolvable before blocking on the *current* op's compute, so step
    N+1's halo hides behind step N.  Hidden seconds land as ``overlap_s``
    on the halo row and are excluded from ledger totals (``total =
    compute + staging + exchange - overlap``).
  - ``split_stencil=True`` — *causal split*: the stencil region runs as
    real ``interior``/``boundary`` sub-regions.  The interior pass computes
    the full field from un-exchanged operands while the exchange runs
    behind it; the ``boundary(<region>)`` pass then recomputes from the
    exchanged operands and blends only the ghost-adjacent band (a
    ``where`` on the shard-local index).  This is the structural form of
    the overlap — boundary values causally consume the exchange — at the
    cost of a second (boundary-masked) pass.

* **wide halos** — ``halo_multiplier=k`` provisions ghost zones ``k`` times
  the stencil width and performs the exchange every ``k``-th application
  of each stencil region: ``1/k`` as many syncs, each moving ``k``-wide
  boundary slabs (same total bytes, amortized latency — the multi-step
  ghost-zone trade of docs/SCALING.md).  The schedule is deterministic
  (per-region application counters), so replays stay reproducible.

* per-device ledgers — each simulated APU owns a
  :class:`~repro.core.ledger.Ledger`.  The decomposition is symmetric, so
  each device's rows record its **local share**: ``1/N`` of every measured
  wall interval and of every byte/element count.  Summing the per-device
  ledgers (``Ledger.merged``) therefore reproduces the measured node wall
  split exactly; ``ShardExecutor.report()`` returns that aggregate with a
  ``per_device`` breakdown splitting compute, staging, exchange, and
  overlap time.

Any :class:`~repro.core.regions.ExecutionPolicy` applies:

- ``UnifiedPolicy`` — operands stay resident in the decomposition; only
  halo-exchange regions move bytes between APUs (the paper's APU model,
  scaled out: migration deleted, Fabric traffic remains).
- ``DiscretePolicy`` — every region call stages its operands host->APUs
  (scatter through pooled sharded buffers) and its results APUs->host: the
  managed-memory node where the host bounce multiplies with N.
- ``AdaptivePolicy`` — calls under the calibrated cutoff gather to the
  host and run there (small problems don't amortize a node), the rest run
  decomposed.

Numerics: region math is elementwise/stencil arithmetic partitioned by
XLA, so sharded replay is bit-comparable to the single-device replay of
the same program under every schedule; only compiler re-fusion across
different sharding signatures (and the split schedule's second compilation
context) can perturb results, within the float32 tolerance documented in
docs/DESIGN.md §2.
"""
from __future__ import annotations

import dataclasses
import time
import weakref
from concurrent.futures import Future, ThreadPoolExecutor
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.ledger import Ledger
from repro.core.pool import DeviceBufferPool
from repro.core.program import (Lit, Ref, RegionProgram, _is_array,
                                _resolver, interval_overlap)
from repro.core.regions import (ExecutionPolicy, Executor, Region,
                                UnifiedPolicy, _chunked_copy_into,
                                _copy_into, policy_selector)
from repro.core.umem import replicated_sharding, shard_along_nd


def halo_width(offsets, axis: int) -> int:
    """Halo width a decomposition along grid axis ``axis`` must exchange
    for a stencil with DIA offset table ``offsets`` — the maximum reach of
    any band along that axis (see :meth:`Region.stencil_width`).

        halo_width(dia.STENCIL_OFFSETS, axis=2)                  -> 1
        halo_width(dia.compose_offsets(S, S), axis=2)            -> 2
        halo_width(None, axis=2)                                 -> 0
    """
    if not offsets:
        return 0
    return max((abs(d) for ax, d in offsets if ax == axis), default=0)


@dataclasses.dataclass
class _Exchange:
    """Result of one (possibly background) halo-exchange execution."""
    outs: Dict[int, Any]        # operand leaf index -> exchanged leaf
    nbytes: int                 # per-device bytes sent over the Fabric
    t0: float
    t1: float

    @property
    def seconds(self) -> float:
        return self.t1 - self.t0


class ShardExecutor:
    """Replays :class:`RegionProgram`\\ s domain-decomposed over a mesh of
    simulated APUs, under any :class:`ExecutionPolicy`, with one
    :class:`Ledger` per device.

    ``shard_dim`` selects the array dimension(s) split over the mesh
    ax(es).  For a 1-D mesh the default is ``-1`` (the trailing dimension,
    which for ``[nx,ny,nz]`` CFD fields and ``[6,nx,ny,nz]`` DIA
    coefficient stacks alike is the grid z axis); an N-axis mesh defaults
    to the N trailing dimensions (2-D: y and z).  Leaves whose extent does
    not divide by a mesh axis replicate along it.  ``stencil_axis`` is the
    *grid* axis each sharded dimension decomposes (default
    ``shard_dim % 3``); halo widths are inferred against it from each
    region's declared ``stencil`` offsets.

    ``halo_multiplier``, ``overlap``, and ``split_stencil`` select the
    exchange schedule (module docstring); ``lookahead_depth`` bounds how
    far ahead the overlap thread may look for the next due exchange.

    ``prog.replay(shard_executor, *inputs)`` dispatches here through the
    standard ``replay_program`` hook, so a ShardExecutor drops in anywhere
    an :class:`Executor` or ``AsyncExecutor`` does.
    """

    def __init__(self, policy: Optional[ExecutionPolicy], mesh,
                 axis=None, shard_dim=None, stencil_axis=None, *,
                 halo_multiplier: int = 1, overlap: bool = True,
                 split_stencil: bool = False, lookahead_depth: int = 2):
        self.policy = policy or UnifiedPolicy()
        self.mesh = mesh
        if axis is None:
            axes = tuple(mesh.axis_names)
        elif isinstance(axis, str):
            axes = (axis,)
        else:
            axes = tuple(axis)
        for a in axes:
            if a not in mesh.axis_names:
                raise ValueError(
                    f"mesh has no axis {a!r}: {mesh.axis_names}")
        self.axes: Tuple[str, ...] = axes
        if shard_dim is None:
            dims: Tuple[int, ...] = tuple(range(-len(axes), 0))
        elif isinstance(shard_dim, int):
            dims = (shard_dim,)
        else:
            dims = tuple(shard_dim)
        if len(dims) != len(axes):
            raise ValueError(f"{len(axes)} mesh axes but {len(dims)} "
                             f"shard dims: {axes} vs {dims}")
        self.shard_dims: Tuple[int, ...] = dims
        if stencil_axis is None:
            st: Tuple[int, ...] = tuple(d % 3 for d in dims)
        elif isinstance(stencil_axis, int):
            st = (stencil_axis,) * len(dims)
        else:
            st = tuple(stencil_axis)
        self.stencil_axes: Tuple[int, ...] = st
        self.axis_sizes: Tuple[int, ...] = tuple(
            int(mesh.shape[a]) for a in axes)
        self.n_devices = int(mesh.devices.size)
        self.halo_multiplier = max(1, int(halo_multiplier))
        self.overlap = bool(overlap)
        self.split_stencil = bool(split_stencil)
        self.lookahead_depth = max(1, int(lookahead_depth))
        # 1-D scalar views of the decomposition (PR-3 API surface)
        self.axis = axes[0]
        self.shard_dim = dims[0]
        self.stencil_axis = st[0]
        shape_str = "x".join(str(s) for s in self.axis_sizes)
        tag = self.axes[0] if len(axes) == 1 else "mesh"
        self.mode = f"{self.policy.name}+sharded[{shape_str}x{tag}]"
        #: one ledger per simulated APU; each records its 1/N local share
        self.ledgers: List[Ledger] = [
            Ledger(f"{self.policy.name}@{tag}{i}")
            for i in range(self.n_devices)]
        # host-routed calls (adaptive cutoff) run once, undecomposed — they
        # belong to the node, not to any one APU
        self.host_ledger = Ledger(f"{self.policy.name}@host")
        self._inner = Executor(self.policy, self.host_ledger)
        self._replicated = replicated_sharding(mesh)
        self._sharding_cache: dict = {}      # (ndim, extents) -> sharding
        # captured constants scatter across the mesh ONCE per executor, not
        # once per replayed step; keying by the Lit descriptor object keeps
        # it alive, so a recycled address can never alias a stale entry
        self._lit_cache: dict = {}           # Lit descriptor -> placed leaf
        # same-named distinct regions must not merge into one row (the
        # Executor._row_name contract, upheld per executor here — every
        # per-device ledger shares this executor's row names)
        self._row_names = weakref.WeakKeyDictionary()      # Region -> str
        self._taken_rows: set = set()
        self._halo_widths = weakref.WeakKeyDictionary()    # Region -> dict
        self._halo_cache: dict = {}     # (row, ((dim, w), ...)) -> Region
        # programs whose halo declarations already passed the static
        # verifier on this executor (repro.analysis; error findings veto
        # decomposition — a silently skipped exchange corrupts values)
        self._halo_verified = weakref.WeakKeyDictionary()  # prog -> True
        self._boundary_regions = weakref.WeakKeyDictionary()
        self._registry = Ledger(self.mode + "-rows")       # halo-name registry
        # wide-halo schedule state: applications seen per stencil row — the
        # exchange runs on every halo_multiplier-th application.  Counters
        # persist across replays so back-to-back replayed steps amortize.
        self._app_counts: Dict[str, int] = {}
        stager = self.policy.stager
        self._device_pool = getattr(stager, "device_pool", None) \
            or DeviceBufferPool()
        # oversubscription: a budget-carrying stager bounds the scatter's
        # transient staging granule (see regions._chunked_copy_into)
        self._staging_budget = getattr(stager, "budget", None)

    @property
    def schedule(self) -> str:
        if self.split_stencil:
            return "split"
        return "overlap" if self.overlap else "sequential"

    # -- accounting rows -------------------------------------------------
    def _row_name(self, r: Region) -> str:
        """Ledger row for this region across ALL of this executor's
        per-device ledgers.  Distinct region objects that happen to share
        a name (registered in different app ledgers) get re-uniquified —
        the same contract ``Executor._row_name`` keeps."""
        name = self._row_names.get(r)
        if name is None:
            name = r.name
            k = 2
            while name in self._taken_rows:
                name = f"{r.name}#{k}"
                k += 1
            self._taken_rows.add(name)
            self._row_names[r] = name
        return name

    # -- placement -------------------------------------------------------
    def _assignments(self, shape) -> Tuple[Tuple[int, str, int], ...]:
        """Which array dimensions of ``shape`` this decomposition splits:
        ``(normalized_dim, mesh_axis, axis_size)`` per mesh axis whose
        assigned ``shard_dim`` exists and divides.  A dimension claimed by
        an earlier mesh axis is not re-split."""
        ndim = len(shape)
        out, used = [], set()
        for ax, dim, size in zip(self.axes, self.shard_dims,
                                 self.axis_sizes):
            if not (ndim and -ndim <= dim < ndim):
                continue
            d = dim % ndim
            if d in used:
                continue
            ext = shape[d]
            if ext >= size and ext % size == 0:
                out.append((d, ax, size))
                used.add(d)
        return tuple(out)

    def sharding_for(self, leaf):
        """The NamedSharding this decomposition gives one array leaf:
        each ``shard_dim`` split over its mesh axis when divisible,
        replicated otherwise.  Cached per (ndim, candidate extents) — the
        replay hot loop asks for every leaf of every op inside timed
        intervals."""
        shape = tuple(getattr(leaf, "shape", ()))
        ndim = len(shape)
        if not ndim:
            return self._replicated
        key = (ndim, tuple(shape[d % ndim] if -ndim <= d < ndim else -1
                           for d in self.shard_dims))
        sh = self._sharding_cache.get(key)
        if sh is None:
            asg = self._assignments(shape)
            sh = shard_along_nd(
                self.mesh, {d: ax for d, ax, _ in asg}, ndim) \
                if asg else self._replicated
            self._sharding_cache[key] = sh
        return sh

    def _place(self, x):
        sh = self.sharding_for(x)
        if isinstance(x, jax.Array) and x.sharding == sh:
            return x
        return jax.device_put(x, sh)

    def _is_sharded(self, x) -> bool:
        sh = self.sharding_for(x)
        return sh is not self._replicated and isinstance(x, jax.Array) \
            and x.sharding == sh

    # -- staging (discrete node model) -----------------------------------
    def _stage_scatter(self, leaves) -> Tuple[list, float, int, list]:
        """Migrate operand leaves host -> N APUs: read each array out of
        host memory and scatter it into a pooled sharded device buffer
        (donation recycles the pool storage, paper C4 at node scale).
        Returns (placed, seconds, bytes, acquired_buffers)."""
        t0 = time.perf_counter()
        placed, nbytes, acquired = [], 0, []
        for x in leaves:
            if not _is_array(x):
                placed.append(x)
                continue
            h = np.asarray(x)                       # host page read / gather
            sh = self.sharding_for(h)
            dst = self._device_pool.acquire(h.shape, h.dtype, sharding=sh)
            chunk = self._staging_budget.staging_chunk_bytes() \
                if self._staging_budget is not None else None
            if chunk is not None and h.nbytes > chunk:
                y, n = _chunked_copy_into(h, dst, chunk)  # budgeted slabs
                self._staging_budget.note_chunks(n)
            else:
                y = _copy_into(h, dst)              # host -> APUs scatter
            if y.sharding != sh:                    # pragma: no cover
                y = jax.device_put(y, sh)
            placed.append(y)
            acquired.append(y)
            nbytes += h.nbytes
        jax.block_until_ready(acquired)
        return placed, time.perf_counter() - t0, nbytes, acquired

    # -- halo exchange ---------------------------------------------------
    def _stencil_widths(self, r: Region) -> Optional[Dict[str, int]]:
        """Base halo width per mesh axis for region ``r`` (cached), from
        its declared stencil against each axis's grid axis; None for
        pointwise regions."""
        w = self._halo_widths.get(r)
        if w is None:
            w = {ax: halo_width(r.stencil, st)
                 for ax, st in zip(self.axes, self.stencil_axes)}
            if not any(w.values()):
                w = False
            self._halo_widths[r] = w
        return w or None

    def _halo_region(self, r: Region, items: Tuple[Tuple[int, int], ...]
                     ) -> Region:
        """The explicit halo-exchange Region for stencil region ``r`` over
        decomposed (dim, exchange_width) pairs ``items`` (cached per
        signature).  Its fn is the bit-exact roll round-trip identity
        whose partitioned form moves exactly the width-``w`` boundary
        slabs across every shard boundary, both directions."""
        row = self._row_name(r)
        key = (row, items)
        halo = self._halo_cache.get(key)
        if halo is None:
            def exchange(x, _items=items):
                for d, w in _items:
                    x = jnp.roll(jnp.roll(x, w, d), -w, d)
                return x

            halo = Region(name=f"halo({row})", fn=exchange,
                          offloaded=True, ledger=self._registry)
            halo.halo_widths = items
            self._halo_cache[key] = halo
        return halo

    def _halo_leaf_indices(self, op) -> List[int]:
        """Which operand leaves the halo exchange covers: the region's
        declared ``halo_args`` (top-level positions/names), else every
        array leaf."""
        r = op.region
        spec = getattr(r, "halo_args", None)
        if spec is None:
            return list(range(len(op.leaves)))
        keys = set(spec)
        for name in [k for k in keys if isinstance(k, str)]:
            idx = r._param_index.get(name)
            if idx is not None:
                keys.add(idx)
        return [i for i, k in enumerate(op.arg_keys) if k in keys]

    def _exchange_leaves(self, op, leaves) -> List[Tuple[int, Any]]:
        """The (index, leaf) pairs a due exchange for ``op`` covers: its
        declared halo operands that are actually decomposed."""
        return [(i, leaves[i]) for i in self._halo_leaf_indices(op)
                if self._is_sharded(leaves[i])]

    def _dispatch_exchange(self, r: Region, leaves: List[Tuple[int, Any]]
                           ) -> _Exchange:
        """Dispatch the halo exchange over ``leaves`` — asynchronously, and
        ALWAYS from the main thread.  Everything this executor runs on the
        mesh contains collectives (the exchange's permutes, and the
        collectives XLA SPMD inserts into partitioned compute), and
        collectives from concurrently-dispatching threads can interleave
        their per-device rendezvous in different orders and deadlock; a
        single dispatch thread gives every device the same enqueue order.
        The overlap schedules therefore dispatch here and hand the
        un-blocked result to the worker only to *wait* on.

        Per-device bytes: each APU sends ``w`` boundary slabs in each
        direction per decomposed dimension; a slab is the leaf's plane
        restricted to the APU's chunk of every *other* decomposed
        dimension — the surface-to-volume term a 2-D mesh shrinks."""
        widths = self._stencil_widths(r) or {}
        k = self.halo_multiplier
        t0 = time.perf_counter()
        outs: Dict[int, Any] = {}
        nbytes = 0
        for i, x in leaves:
            asg = self._assignments(x.shape)
            items = []
            for d, ax, size in asg:
                w = widths.get(ax, 0)
                if w <= 0:
                    continue
                local = x.shape[d] // size
                items.append((d, min(k * w, local)))
                if size > 1:
                    other = 1
                    for d2, _, size2 in asg:
                        if d2 != d:
                            other *= size2
                    plane = x.nbytes // x.shape[d] // other
                    nbytes += 2 * min(k * w, local) * plane
            if not items:
                continue
            outs[i] = self._halo_region(r, tuple(items)).jitted(x)
        return _Exchange(outs, nbytes, t0, t0)

    def _finish_exchange(self, ex: _Exchange) -> _Exchange:
        """Wait for a dispatched exchange's transfers and close its wall
        interval (safe on the overlap worker: a pure wait, no dispatch).
        ``[t0, t1]`` is the in-flight window — the part of it intersecting
        compute spans is recorded as hidden (``overlap_s``)."""
        jax.block_until_ready(list(ex.outs.values()))
        ex.t1 = time.perf_counter()
        return ex

    # -- interior/boundary split (split_stencil schedule) ----------------
    def _boundary_region(self, r: Region) -> Region:
        """The ``boundary(<row>)`` sub-region of stencil region ``r``
        (cached): recompute the region from its *exchanged* operands and
        blend only the ghost-adjacent band (shard-local index within the
        provisioned ghost depth of a shard edge) over the interior pass's
        result — the causal half of the interior/boundary split."""
        b = self._boundary_regions.get(r)
        if b is not None:
            return b
        widths = self._stencil_widths(r) or {}
        kmult = self.halo_multiplier
        assignments = self._assignments

        def boundary(interior, *args, **kwargs):
            full = r.fn(*args, **kwargs)

            def blend(i_leaf, f_leaf):
                shape = tuple(getattr(f_leaf, "shape", ()))
                if not shape:
                    return f_leaf
                mask = None
                for d, ax, size in assignments(shape):
                    w = widths.get(ax, 0)
                    if w <= 0 or size <= 1:
                        continue
                    local = shape[d] // size
                    depth = min(kmult * w, local)
                    idx = jax.lax.broadcasted_iota(
                        jnp.int32, shape, d) % local
                    m = (idx < depth) | (idx >= local - depth)
                    mask = m if mask is None else mask | m
                if mask is None:
                    return f_leaf
                return jnp.where(mask, f_leaf, i_leaf)

            return jax.tree.map(blend, interior, full)

        b = Region(name=f"boundary({self._row_name(r)})", fn=boundary,
                   offloaded=True, ledger=self._registry)
        self._boundary_regions[r] = b
        return b

    # -- Executor protocol -----------------------------------------------
    def run(self, target_region, *args, **kwargs):
        """Single calls fall back to the synchronous inner executor (host
        ledger); the decomposition only engages on whole programs."""
        return self._inner.run(target_region, *args, **kwargs)

    # -- exchange schedule -----------------------------------------------
    def _exchange_plan(self, prog: RegionProgram) -> List[bool]:
        """Which ops of this replay perform their halo exchange: every
        ``halo_multiplier``-th application of each stencil region
        (deterministic counters shared by the issue loop and the
        lookahead, persisted across replays so stepped replays
        amortize)."""
        plan = []
        for op in prog.ops:
            if self._stencil_widths(op.region) is None:
                plan.append(False)
                continue
            row = self._row_name(op.region)
            c = self._app_counts.get(row, 0)
            plan.append(c % self.halo_multiplier == 0)
            self._app_counts[row] = c + 1
        return plan

    def _record_exchange(self, r: Region, ex: _Exchange, spans) -> None:
        """Land one executed exchange on every per-device ledger (1/N
        shares): exchange seconds/bytes on the ``halo(<row>)`` row, plus
        the part of its wall interval that hid behind compute as
        ``overlap_s`` (excluded from totals by the ledger)."""
        ov = min(interval_overlap(ex.t0, ex.t1, spans), ex.seconds)
        row = f"halo({self._row_name(r)})"
        nd = self.n_devices
        for led in self.ledgers:
            led.record(row, device=True, offloaded=True, compute_s=0.0,
                       exchange_s=ex.seconds / nd, exchange_bytes=ex.nbytes,
                       overlap_s=ov / nd)

    def _submit_lookahead(self, tp, prog, plan, k, resolve_placed
                          ) -> Optional[Tuple[int, Future]]:
        """AsyncExecutor's lookahead, composed with the decomposition:
        scan the next ``lookahead_depth`` ops for a due exchange whose
        halo operands are already resolvable (program inputs, constants,
        outputs of ops < k) and submit it on the overlap thread — it runs
        behind op ``k``'s interior compute.  Operands produced by op ``k``
        itself cannot be prefetched; their exchange is submitted at issue
        time instead (hiding behind their own op's compute)."""
        for j in range(k + 1, min(k + 1 + self.lookahead_depth,
                                  len(prog.ops))):
            if not plan[j]:
                continue
            op = prog.ops[j]
            idxs = set(self._halo_leaf_indices(op))
            if any(isinstance(d, Ref) and d.op >= k
                   for i, d in enumerate(op.leaves) if i in idxs):
                continue            # depends on an unfinished op: not ready
            leaves = self._exchange_leaves(
                op, [resolve_placed(d) if i in idxs else None
                     for i, d in enumerate(op.leaves)])
            if leaves:
                # dispatch HERE (main thread — single collective enqueue
                # order); the worker only waits out the transfer
                ex = self._dispatch_exchange(op.region, leaves)
                return (j, tp.submit(self._finish_exchange, ex))
            return None             # due but nothing decomposed: skip
        return None

    # -- program replay --------------------------------------------------
    def _verify_halo(self, prog: RegionProgram) -> None:
        """Pre-flight the program's halo declarations once per executor
        (static, no replay): an unresolvable ``halo_args`` entry or a
        halo_args-without-stencil region would make the exchange silently
        skip operands and corrupt the decomposed values — error-severity
        findings veto the replay.  Composed-reach findings are warnings
        (the wide-halo parity tests exercise those chains deliberately)
        and do not block."""
        if self._halo_verified.get(prog):
            return
        from repro.analysis import check_halo
        errors = check_halo(prog).errors
        if errors:
            raise ValueError(
                f"sharded replay of {prog.name!r} vetoed by halo "
                "verification:\n" + "\n".join(f"  {d}" for d in errors))
        self._halo_verified[prog] = True

    def replay_program(self, prog: RegionProgram, *inputs):
        self._verify_halo(prog)
        if self.overlap:
            with ThreadPoolExecutor(max_workers=1) as tp:
                return self._replay(prog, inputs, tp)
        return self._replay(prog, inputs, None)

    def _replay(self, prog: RegionProgram, inputs: tuple, tp):
        pol = self.policy
        stager = pol.stager
        selector = policy_selector(pol)
        staging = getattr(stager, "stages", False)
        nd = self.n_devices
        in_leaves = list(prog._input_leaves(inputs))
        if not staging:
            # unified node model: inputs scatter once and stay decomposed
            in_leaves = [self._place(x) if _is_array(x) else x
                         for x in in_leaves]
        env: List[List[Any]] = []
        resolve = _resolver(env, in_leaves)

        def resolve_placed(d):
            x = resolve(d)
            if staging or not _is_array(x):
                return x
            if isinstance(d, Lit):     # constants: scatter once, ever
                y = self._lit_cache.get(d)
                if y is None:
                    y = self._lit_cache[d] = self._place(x)
                return y
            return self._place(x)      # In/Ref leaves are already placed

        plan = self._exchange_plan(prog)
        pending: Optional[Tuple[int, Future]] = None
        spans: List[Tuple[float, float]] = []      # recent compute intervals

        def note_span(t0, t1):
            spans.append((t0, t1))
            if len(spans) > 8:
                del spans[0]

        for k, op in enumerate(prog.ops):
            r = op.region
            raw = [resolve_placed(d) for d in op.leaves]
            args, kwargs = jax.tree.unflatten(op.in_tree, raw)
            n = r.size_fn(args, kwargs)
            tgt = pol.router.target(r, args, kwargs, size=n)
            if pending is not None and pending[0] == k:
                ex_fut: Optional[Future] = pending[1]
                pending = None
            else:
                ex_fut = None
            if tgt == "host":
                if ex_fut is not None:
                    # prefetched exchange for a host-routed call: the
                    # transfer happened; account it (with its overlap)
                    self._record_exchange(r, ex_fut.result(), spans)
                env.append(self._run_host(r, op, raw, n))
                continue
            # variant selection happens here, per replayed call — the
            # captured trace stores Regions, so the same program runs under
            # any Selector at node scale too (XLA partitions whichever
            # variant's executable is chosen; resolve(): unknown -> ref)
            impl = r.resolve(selector.select(r, tgt, args, kwargs, size=n))
            staging_s, staging_b = 0.0, 0
            acquired: list = []
            if staging and r.offloaded:
                raw, staging_s, staging_b, acquired = \
                    self._stage_scatter(raw)
                args, kwargs = jax.tree.unflatten(op.in_tree, raw)
            due = plan[k]
            ex: Optional[_Exchange] = None
            ex_leaves = self._exchange_leaves(op, raw) if due else []
            split = self.split_stencil and bool(ex_leaves)
            if due and ex_leaves and ex_fut is None and tp is None:
                # sequential schedule: exchange first, compute consumes
                # the exchanged operands (every exchange is exposed)
                ex = self._finish_exchange(
                    self._dispatch_exchange(r, ex_leaves))
            if ex is not None and not split:
                raw = list(raw)
                for i, y in ex.outs.items():
                    raw[i] = y
                args, kwargs = jax.tree.unflatten(op.in_tree, raw)
            t0 = time.perf_counter()
            # donate=False: sharded operands may be pool-staged or reused
            # by the exchange bookkeeping — donation is a single-device
            # executor optimization.  Under the overlapped schedules this
            # dispatch is the INTERIOR compute: it consumes the
            # un-exchanged (value-identical) operands, so it never waits
            # on the exchange running behind it.
            out = r.jitted_variant(impl, donate=False)(*args, **kwargs)
            if due and ex_leaves and ex is None and ex_fut is None:
                # this op's own exchange hides behind its own compute:
                # dispatched on THIS thread right after the compute
                # dispatch (ordered collectives), waited on by the worker
                ex_fut = tp.submit(self._finish_exchange,
                                   self._dispatch_exchange(r, ex_leaves))
            # submit the NEXT due exchange before blocking on this
            # compute — this ordering is the entire lookahead overlap
            # (operands staged per-call can't be prefetched across ops)
            if tp is not None and pending is None and not staging:
                pending = self._submit_lookahead(tp, prog, plan, k,
                                                 resolve_placed)
            jax.block_until_ready(out)
            t1 = time.perf_counter()
            note_span(t0, t1)
            if ex_fut is not None:
                ex = ex_fut.result()
            if split and ex is not None:
                # causal boundary pass: recompute from exchanged operands,
                # blend the ghost-adjacent band over the interior result
                xraw = list(raw)
                for i, y in ex.outs.items():
                    xraw[i] = y
                xargs, xkwargs = jax.tree.unflatten(op.in_tree, xraw)
                bregion = self._boundary_region(r)
                tb0 = time.perf_counter()
                out = bregion.jitted(out, *xargs, **xkwargs)
                jax.block_until_ready(out)
                tb1 = time.perf_counter()
                note_span(tb0, tb1)
                for led in self.ledgers:
                    led.record(f"boundary({self._row_name(r)})",
                               device=True, offloaded=True,
                               compute_s=(tb1 - tb0) / nd)
            if staging and r.offloaded:
                out, s, b = stager.stage_out(r, out, None)
                staging_s += s
                staging_b += b
                for buf in acquired:          # staged operands are dead
                    self._device_pool.release(buf)
            else:
                out = jax.tree.map(
                    lambda x: self._place(x) if _is_array(x) else x, out)
            row = self._row_name(r)
            for led in self.ledgers:
                led.record(row, device=True, offloaded=r.offloaded,
                           compute_s=(t1 - t0) / nd,
                           staging_s=staging_s / nd,
                           staging_bytes=staging_b // nd,
                           elems=n // nd, impl=impl)
            if ex is not None:
                self._record_exchange(r, ex, spans)
            env.append(jax.tree.leaves(out))
        if pending is not None:       # trailing prefetch past a host turn
            pending[1].result()
        return jax.tree.unflatten(prog.out_tree,
                                  [resolve(d) for d in prog.out_leaves])

    def _run_host(self, r: Region, op, raw, n) -> list:
        """Adaptive small-problem path: gather operands to the host, run
        the host executable once, account on the node's host ledger."""
        host = [np.asarray(x) if _is_array(x) else x for x in raw]
        args, kwargs = jax.tree.unflatten(op.in_tree, host)
        impl = r.resolve(policy_selector(self.policy).select(
            r, "host", args, kwargs, size=n))
        t0 = time.perf_counter()
        out = r.executable("host", impl, donate=False)(*args, **kwargs)
        jax.block_until_ready(out)
        self.host_ledger.record(self._row_name(r), device=False,
                                offloaded=r.offloaded,
                                compute_s=time.perf_counter() - t0, elems=n,
                                impl=impl)
        return jax.tree.leaves(out)

    # -- accounting ------------------------------------------------------
    def reset_timings(self) -> None:
        for led in (*self.ledgers, self.host_ledger):
            led.reset_timings()

    def _device_summary(self, i: int, led: Ledger) -> dict:
        rows = list(led.regions.values())
        return {
            "device": i,
            "calls": sum(r.calls for r in rows),
            "compute_s": sum(r.compute_s for r in rows),
            "staging_s": sum(r.staging_s for r in rows),
            "exchange_s": sum(r.exchange_s for r in rows),
            "overlap_s": sum(r.overlap_s for r in rows),
            "staging_bytes": sum(r.staging_bytes for r in rows),
            "exchange_bytes": sum(r.exchange_bytes for r in rows),
            "elems": sum(r.host_elems + r.device_elems for r in rows),
        }

    def report(self) -> dict:
        """Node-level coverage: the per-device ledgers summed (which, by
        the 1/N-share recording convention, reproduces the measured wall
        split exactly) plus host-routed calls, with a ``per_device``
        compute/staging/exchange/overlap breakdown and the exchange
        schedule that produced it."""
        node = Ledger.merged((*self.ledgers, self.host_ledger),
                             name=self.mode)
        rep = node.coverage_report()
        rep["mode"] = self.mode
        rep["devices"] = self.n_devices
        rep["mesh_axis"] = self.axis
        rep["mesh_shape"] = list(self.axis_sizes)
        rep["schedule"] = self.schedule
        rep["halo_multiplier"] = self.halo_multiplier
        rep["per_device"] = [self._device_summary(i, led)
                             for i, led in enumerate(self.ledgers)]
        return rep


class ShardedProgram:
    """A captured program bound to its multi-APU executor: ``replay`` runs
    the decomposed trace, ``replay_batch`` scatters N independent instances
    across the APUs (data parallelism over the mesh axis), and
    ``coverage_report`` is the aggregated node view."""

    def __init__(self, prog: RegionProgram, executor: ShardExecutor):
        self.prog = prog
        self.executor = executor

    @property
    def mesh(self):
        return self.executor.mesh

    @property
    def ledgers(self) -> List[Ledger]:
        return self.executor.ledgers

    def replay(self, *inputs):
        return self.prog.replay(self.executor, *inputs)

    # the Executor protocol, so a ShardedProgram itself drops in where an
    # executor is expected (SimpleFoam.replay_steps, benchmarks)
    def replay_program(self, prog: RegionProgram, *inputs):
        return self.executor.replay_program(prog, *inputs)

    def run(self, target_region, *args, **kwargs):
        return self.executor.run(target_region, *args, **kwargs)

    def replay_batch(self, *stacked_inputs, in_axes=0):
        """Replay N stacked independent instances with the batch dimension
        scattered over the first mesh axis — each simulated APU decodes its
        own slice of the requests (the ``serve --mesh`` path)."""
        ex = self.executor
        mesh, axis, nd = ex.mesh, ex.axis, ex.n_devices
        n_axis = int(mesh.shape[axis])

        def scatter(x):
            if not _is_array(x) or not getattr(x, "ndim", 0):
                return x
            sh = shard_along_nd(mesh, {0: axis}, x.ndim) \
                if x.shape[0] % n_axis == 0 else replicated_sharding(mesh)
            return jax.device_put(x, sh)

        placed = jax.tree.map(scatter, stacked_inputs)
        t0 = time.perf_counter()
        out = self.prog.replay_batch(*placed, in_axes=in_axes)
        jax.block_until_ready(out)
        dt = time.perf_counter() - t0
        sizes = [int(a.size) for a in jax.tree.leaves(stacked_inputs)
                 if hasattr(a, "size")]
        for led in ex.ledgers:
            led.record(f"{self.prog.name}[batch]", device=True,
                       offloaded=True, compute_s=dt / nd,
                       elems=max(sizes, default=0) // nd)
        return out

    def coverage_report(self) -> dict:
        return self.executor.report()

    def report(self) -> dict:
        return self.executor.report()

    def reset_timings(self) -> None:
        self.executor.reset_timings()

    def summary(self) -> str:
        ex = self.executor
        halos = sum(1 for op in self.prog.ops
                    if ex._stencil_widths(op.region) is not None)
        shape = "x".join(str(s) for s in ex.axis_sizes)
        return (f"ShardedProgram({self.prog.name!r}: {len(self.prog)} ops, "
                f"{shape} decomposition on dims {ex.shard_dims}, "
                f"{halos} halo-exchanged ops, schedule={ex.schedule}, "
                f"halo_multiplier={ex.halo_multiplier}, "
                f"policy={ex.policy.name})")


def shard_program(prog: RegionProgram, mesh,
                  policy: Optional[ExecutionPolicy] = None, *,
                  axis=None, shard_dim=None, stencil_axis=None,
                  halo_multiplier: int = 1, overlap: bool = True,
                  split_stencil: bool = False,
                  lookahead_depth: int = 2) -> ShardedProgram:
    """Bind a captured program to a mesh of simulated APUs.

        mesh = make_apu_mesh(4)          # repro.launch.mesh; (2, 2) for 2-D
        sp = shard_program(prog, mesh, DiscretePolicy(),
                           halo_multiplier=2)      # wide-halo: 1/2 the syncs
        out = sp.replay(*inputs)
        sp.coverage_report()["per_device"]     # compute/staging/exchange

    ``overlap`` (default) hides exchanges behind interior compute;
    ``split_stencil`` runs the causal interior/boundary split;
    ``halo_multiplier=k`` exchanges ``k``-wide ghosts every ``k``-th
    application (docs/SCALING.md)."""
    return ShardedProgram(prog, ShardExecutor(
        policy, mesh, axis=axis, shard_dim=shard_dim,
        stencil_axis=stencil_axis, halo_multiplier=halo_multiplier,
        overlap=overlap, split_stencil=split_stencil,
        lookahead_depth=lookahead_depth))
