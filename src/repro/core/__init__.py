"""The paper's contribution as a first-class runtime:

C1 unified memory  -> repro.core.umem       (MemSpace, UnifiedArena, placement)
C2 incremental     -> repro.core.ledger     (offload_region, coverage)
C3 adaptive switch -> repro.core.dispatch   (TargetDispatch / TARGET_CUT_OFF)
C4 memory pooling  -> repro.core.pool       (HostStagingPool, DeviceBufferPool)
§5 measurement     -> repro.core.executors  (unified / discrete / host)
"""
from repro.core.dispatch import TargetDispatch, offload, DEFAULT_CUTOFF
from repro.core.executors import (DiscreteExecutor, HostExecutor,
                                  UnifiedExecutor, make_executor)
from repro.core.ledger import GLOBAL_LEDGER, Ledger, offload_region
from repro.core.pool import (DeviceBufferPool, HostStagingPool,
                             POOL_MIN_ELEMS, PoolStats)
from repro.core.umem import MemSpace, UnifiedArena, place, tree_place
