"""The paper's contribution as a first-class runtime:

C1 unified memory  -> repro.core.umem       (MemSpace, UnifiedArena, placement)
C2 incremental     -> repro.core.ledger     (Ledger, coverage + routing stats)
C3 adaptive switch -> repro.core.regions    (SizeRouter / AdaptivePolicy)
C4 memory pooling  -> repro.core.pool       (HostStagingPool, DeviceBufferPool)
§5 measurement     -> repro.core.regions    (Unified/Discrete/Host policies)

``repro.core.regions`` is the canonical API — and the ONLY offload path in
the repo: Region (with named implementation variants, OpenMP ``declare
variant``) + ExecutionPolicy (placement x routing x staging x selection)
run by one Executor.  The pre-regions ``executors`` and ``dispatch``
modules are retired deprecation-alias stubs, no longer exported here and
never imported internally (``tools/check_retired_imports.py`` gates it in
CI).  ``repro.core.program`` layers captured region programs on top:
record one step, replay it under any policy with lookahead staging overlap
(AsyncExecutor) or vmapped over N independent instances
(RegionProgram.replay_batch).  ``repro.core.shard_program`` scales a
captured program across a mesh of simulated APUs: domain-decomposed replay
with explicit halo-exchange regions and per-device ledgers aggregated into
one node report.
"""
from repro.core.ledger import GLOBAL_LEDGER, Ledger, RegionRecord, offload_region
from repro.core.pool import (BufferRotation, DeviceBufferPool,
                             HostStagingPool, POOL_MIN_ELEMS, PoolStats)
from repro.core.program import AsyncExecutor, RegionProgram, capture
from repro.core.shard_program import (ShardExecutor, ShardedProgram,
                                      halo_width, shard_program)
from repro.core.regions import (DEFAULT_CUTOFF, DEFAULT_SELECTOR,
                                AdaptivePolicy, AutotuneSelector,
                                ComposedPolicy, DiscretePolicy,
                                ExecutionPolicy, Executor, HostPolicy,
                                MigrationStager, NullStager, Placer, Region,
                                Selector, SizeRouter, StaticRouter,
                                StaticSelector, TargetSelector, UnifiedPolicy,
                                as_region, default_size, make_policy,
                                policy_selector, region, size_bucket)
from repro.core.umem import (MemSpace, UnifiedArena, place, place_like,
                             preferred_host_space, tree_place)
