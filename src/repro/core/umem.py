"""Unified-memory abstraction (paper C1): one logical space, placement by
policy.

On MI300A the hardware gives a single physical memory; any pointer is valid
on CPU cores and GPU CUs. On TPU the analogue is JAX *memory kinds*: every
array lives in ``device`` (HBM) or ``pinned_host``/``unpinned_host`` (DRAM),
addressable by the same program, with XLA streaming data between spaces when
compute needs it. This module gives the rest of the framework a single
placement API so application code never hard-codes a memory space — the
paper's "no programming distinction between host and device memory" (§3).
"""
from __future__ import annotations

import dataclasses
import enum
from typing import Any, Optional

import jax


class MemSpace(enum.Enum):
    DEVICE = "device"            # HBM
    HOST = "pinned_host"         # DMA-able host DRAM
    HOST_UNPINNED = "unpinned_host"

    @property
    def kind(self) -> str:
        return self.value


_SPACES_CACHE: dict = {}


def supported_spaces(device=None) -> set:
    d = device or jax.devices()[0]
    if d not in _SPACES_CACHE:
        try:
            _SPACES_CACHE[d] = {m.kind for m in d.addressable_memories()}
        except Exception:                   # pragma: no cover
            _SPACES_CACHE[d] = {"device"}
    return _SPACES_CACHE[d]


def preferred_host_space(device=None) -> Optional[MemSpace]:
    """Best available host-DRAM space: pinned if the platform has it,
    unpinned otherwise, None when the device exposes no host space at all."""
    sup = supported_spaces(device)
    for space in (MemSpace.HOST, MemSpace.HOST_UNPINNED):
        if space.kind in sup:
            return space
    return None


def place(x, space: MemSpace, device=None):
    """Move one array to a memory space (no-op if already there or if the
    platform does not expose that space).

    A sharded array (NamedSharding etc.) keeps its partitioning — only the
    memory kind is rebound, so placing FSDP-sharded optimizer moments or a
    mesh-scattered KV cache into host space never gathers onto one device.
    Unsharded inputs land on ``device`` (default: the first device)."""
    d = device or jax.devices()[0]
    if space.kind not in supported_spaces(d):
        return x
    sh = None
    cur = getattr(x, "sharding", None)
    if cur is not None and \
            not isinstance(cur, jax.sharding.SingleDeviceSharding):
        try:
            sh = cur.with_memory_kind(space.kind)
        except Exception:               # shardings without memory kinds
            sh = None
    if sh is None:
        sh = jax.sharding.SingleDeviceSharding(d, memory_kind=space.kind)
    return jax.device_put(x, sh)


def tree_place(tree, space: MemSpace, device=None, min_bytes: int = 0):
    """Place every array leaf of a pytree into a memory space.

    ``min_bytes`` is a placement threshold (paper C4's "pool only buffers
    above 5K elements", applied to placement): leaves smaller than it stay
    where they are — moving a scalar across spaces costs more than it saves.
    """
    def maybe(x):
        # leaves without .nbytes (Python scalars) count as size 0: with a
        # threshold set they stay put rather than becoming committed Arrays
        if min_bytes and getattr(x, "nbytes", 0) < min_bytes:
            return x
        return place(x, space, device)
    return jax.tree.map(maybe, tree)


def tree_place_budgeted(tree, budget, device=None, min_bytes: int = 0,
                        device_space: MemSpace = MemSpace.DEVICE,
                        spill_space: Optional[MemSpace] = None,
                        charge: bool = True):
    """Place leaves into ``device_space`` while ``budget`` (a
    :class:`~repro.core.oversub.MemoryBudget`, duck-typed ``admit``/
    ``consult``) has headroom; leaves beyond it land in ``spill_space``
    (the platform's preferred host DRAM space by default) instead of
    failing — the oversubscription model: exceeding device capacity
    degrades placement, never correctness.  ``charge=True`` accounts
    admitted leaves as device-resident (``budget.admit``; the caller
    releases them); ``charge=False`` only consults — the advisory form
    used for per-call placement hints.  Leaf order is deterministic
    (``jax.tree.map`` order), so the same tree under the same budget
    always splits the same way."""
    spill = spill_space or preferred_host_space(device) or device_space

    def maybe(x):
        nbytes = getattr(x, "nbytes", 0)
        if min_bytes and nbytes < min_bytes:
            return x
        ok = budget.admit(nbytes) if charge else budget.consult(nbytes)
        return place(x, device_space if ok else spill, device)
    return jax.tree.map(maybe, tree)


def place_like(tree, shardings):
    """device_put each leaf onto its matching sharding — the placement
    companion to :func:`tree_place` for sharded programs.  ``shardings``
    must mirror ``tree`` leaf-for-leaf (NamedShardings /
    SingleDeviceShardings carrying memory kinds)."""
    return jax.tree.map(lambda x, s: jax.device_put(x, s), tree, shardings)


def shard_along(mesh, axis_name: str, ndim: int, dim: int):
    """NamedSharding splitting array dimension ``dim`` (negative indices
    allowed) of an ``ndim``-rank array over mesh axis ``axis_name``, all
    other dimensions replicated — the one-axis domain decomposition of the
    multi-APU replay (``repro.core.shard_program``)."""
    dim = dim % ndim if ndim else 0
    spec = [None] * ndim
    if ndim:
        spec[dim] = axis_name
    return jax.sharding.NamedSharding(
        mesh, jax.sharding.PartitionSpec(*spec))


def shard_along_nd(mesh, assignments, ndim: int):
    """NamedSharding splitting several array dimensions at once:
    ``assignments`` maps array dimension (normalized, ``0 <= dim < ndim``)
    to mesh axis name — the N-D domain decomposition of the multi-APU
    replay (2-D/3-D meshes cut surface-to-volume, docs/SCALING.md).
    Unassigned dimensions replicate."""
    spec = [None] * ndim
    for dim, axis_name in dict(assignments).items():
        spec[dim % ndim if ndim else 0] = axis_name
    return jax.sharding.NamedSharding(
        mesh, jax.sharding.PartitionSpec(*spec))


def replicated_sharding(mesh):
    """NamedSharding replicating an array across every mesh device."""
    return jax.sharding.NamedSharding(mesh, jax.sharding.PartitionSpec())


def space_of(x) -> Optional[str]:
    try:
        return x.sharding.memory_kind
    except Exception:
        return None


def with_memory_kind(sharding: jax.sharding.Sharding, space: MemSpace):
    """Rebind a NamedSharding to a memory kind (for jit in/out_shardings)."""
    return sharding.with_memory_kind(space.kind)


@dataclasses.dataclass
class UnifiedArena:
    """Two named spaces over the unified address map. The *discrete-memory
    emulation* (benchmarks, Fig 6) stages data between the two with real
    copies; the *unified* executor never calls :meth:`to_device`/:meth:`to_host`
    — that asymmetry is the paper's measured effect."""
    device: Any = None
    host_space: MemSpace = MemSpace.HOST
    device_space: MemSpace = MemSpace.DEVICE

    def __post_init__(self):
        self.device = self.device or jax.devices()[0]
        sup = supported_spaces(self.device)
        if self.host_space.kind not in sup:
            # degrade gracefully: pinned -> unpinned host -> device space
            self.host_space = preferred_host_space(self.device) \
                or self.device_space

    def to_device(self, tree):
        return tree_place(tree, self.device_space, self.device)

    def to_host(self, tree):
        return tree_place(tree, self.host_space, self.device)

    def bytes_of(self, tree) -> int:
        return sum(x.nbytes for x in jax.tree.leaves(tree)
                   if hasattr(x, "nbytes"))
