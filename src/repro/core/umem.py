"""Unified-memory abstraction (paper C1): one logical space, placement by
policy.

On MI300A the hardware gives a single physical memory; any pointer is valid
on CPU cores and GPU CUs. On TPU the analogue is JAX *memory kinds*: every
array lives in ``device`` (HBM) or ``pinned_host``/``unpinned_host`` (DRAM),
addressable by the same program, with XLA streaming data between spaces when
compute needs it. This module gives the rest of the framework a single
placement API so application code never hard-codes a memory space — the
paper's "no programming distinction between host and device memory" (§3).
"""
from __future__ import annotations

import dataclasses
import enum
from typing import Any, Optional

import jax


class MemSpace(enum.Enum):
    DEVICE = "device"            # HBM
    HOST = "pinned_host"         # DMA-able host DRAM
    HOST_UNPINNED = "unpinned_host"

    @property
    def kind(self) -> str:
        return self.value


def supported_spaces(device=None) -> set:
    d = device or jax.devices()[0]
    try:
        return {m.kind for m in d.addressable_memories()}
    except Exception:                       # pragma: no cover
        return {"device"}


def place(x, space: MemSpace, device=None):
    """Move one array to a memory space (no-op if already there)."""
    d = device or jax.devices()[0]
    if space.kind not in supported_spaces(d):
        return x
    sh = jax.sharding.SingleDeviceSharding(d, memory_kind=space.kind)
    return jax.device_put(x, sh)


def tree_place(tree, space: MemSpace, device=None):
    return jax.tree.map(lambda x: place(x, space, device), tree)


def space_of(x) -> Optional[str]:
    try:
        return x.sharding.memory_kind
    except Exception:
        return None


def with_memory_kind(sharding: jax.sharding.Sharding, space: MemSpace):
    """Rebind a NamedSharding to a memory kind (for jit in/out_shardings)."""
    return sharding.with_memory_kind(space.kind)


@dataclasses.dataclass
class UnifiedArena:
    """Two named spaces over the unified address map. The *discrete-memory
    emulation* (benchmarks, Fig 6) stages data between the two with real
    copies; the *unified* executor never calls :meth:`to_device`/:meth:`to_host`
    — that asymmetry is the paper's measured effect."""
    device: Any = None
    host_space: MemSpace = MemSpace.HOST
    device_space: MemSpace = MemSpace.DEVICE

    def __post_init__(self):
        self.device = self.device or jax.devices()[0]
        sup = supported_spaces(self.device)
        if self.host_space.kind not in sup:
            self.host_space = self.device_space   # degrade gracefully

    def to_device(self, tree):
        return tree_place(tree, self.device_space, self.device)

    def to_host(self, tree):
        return tree_place(tree, self.host_space, self.device)

    def bytes_of(self, tree) -> int:
        return sum(x.nbytes for x in jax.tree.leaves(tree)
                   if hasattr(x, "nbytes"))
