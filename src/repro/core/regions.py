"""One region, one policy: the canonical offload API (paper C1+C2+C3).

The paper's central claim is that unified memory lets a *single* abstraction
— "a region with a directive" — be retargeted across host, discrete-managed,
and APU execution without touching application code.  This module is that
abstraction:

* :class:`Region` — one OpenMP-directive-sized unit of work: the function,
  its per-target compiled executables, a problem-size measure (the ``n`` of
  ``if(target: n > TARGET_CUT_OFF)``), the offload hint, and optional
  :class:`~repro.core.umem.MemSpace` placement hints per argument / result.

* :class:`ExecutionPolicy` — four orthogonal, composable axes:

  - **placement** (:class:`Placer`): where operands/results nominally live,
    expressed as ``MemSpace`` hints applied through ``umem`` (paper C1);
  - **routing** (:class:`Router`): which executable runs this call — the
    static host/device choice of the three §5 execution modes, or the
    size-based ``TARGET_CUT_OFF`` clause of the retired dispatch shim
    (paper C3, listings 4-6);
  - **staging** (:class:`Stager`): what crossing the host/device boundary
    costs — nothing on an APU, real out-of-place copies through pooled
    buffers on a managed-memory dGPU (paper §5 Fig 6, C4);
  - **selection** (:class:`Selector`): which *implementation variant* of
    the region runs — OpenMP 5.2's ``declare variant`` / ``metadirective``
    dispatch.  A region registers named variants (``ref`` is always the
    decorated function; custom kernels register as e.g. ``pallas``) and
    the policy picks one per call: :class:`StaticSelector` (one name
    everywhere, base-function fallback), :class:`TargetSelector`
    (``match(device)``-style target-conditioned defaults), or
    :class:`AutotuneSelector` (calibrated winners per region x target x
    size-bucket, persisted in the ledger like ``TARGET_CUT_OFF``).

* :class:`Executor` — runs Regions under a policy and accounts every call
  (where it ran, what it cost, how many elements were routed which way)
  into one :class:`~repro.core.ledger.Ledger`, so routing decisions and
  staging fractions appear in the same ``coverage_report()``.

The old ``UnifiedExecutor`` / ``DiscreteExecutor`` / ``HostExecutor``
classes and ``TargetDispatch`` are RETIRED: the pre-regions ``executors``
and ``dispatch`` modules are deprecation-alias stubs for external callers
only, and nothing inside the repo imports them (CI gates it via
``tools/check_retired_imports.py``).
"""
from __future__ import annotations

import dataclasses
import inspect
import time
import weakref
from typing import (Any, Callable, Dict, Mapping, Optional, Protocol,
                    Sequence, Tuple, runtime_checkable)

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import umem
from repro.core.ledger import GLOBAL_LEDGER, Ledger
from repro.core.pool import DeviceBufferPool, HostStagingPool
from repro.core.umem import MemSpace, UnifiedArena

DEFAULT_CUTOFF = 16384          # the paper's empirical TARGET_CUT_OFF

#: routing targets an executable can be compiled for
TARGETS = ("default", "host", "device")


def host_device():
    return jax.devices("cpu")[0]


def accel_device():
    accel = [d for d in jax.devices() if d.platform != "cpu"]
    return accel[0] if accel else jax.devices()[0]


def _param_indices(fn: Callable) -> Dict[str, int]:
    """Positional index of each named parameter, so placement hints keyed
    by name apply to positionally-passed arguments too."""
    try:
        import inspect
        return {name: i for i, name
                in enumerate(inspect.signature(fn).parameters)}
    except (ValueError, TypeError):         # builtins, odd callables
        return {}


def default_size(args, kwargs) -> int:
    """Problem size of a call = size of the LARGEST array leaf.

    The largest leaf, not the first: a small scalar leading argument (an
    ``alpha``, a tolerance) must not force host routing for a call whose
    field operands are millions of cells."""
    sizes = [int(a.size) for a in jax.tree.leaves((args, kwargs))
             if hasattr(a, "size")]
    return max(sizes, default=0)


# ---------------------------------------------------------------------------
# Region
# ---------------------------------------------------------------------------

@dataclasses.dataclass(eq=False)        # identity semantics: regions are
class Region:                           # hashable, usable as dict/set keys
    """One directive-sized region: fn + compiled executables + hints.

    ``arg_spaces`` maps positional index or keyword name to a
    :class:`MemSpace` placement hint; ``result_space`` hints where results
    should land — either one :class:`MemSpace` for the whole result, or a
    mapping from top-level tuple index / dict key to a space so a region
    returning ``(params, opt_state, gnorm)`` can pin just ``opt_state``
    host-side.  Hints are *advisory*: the executing policy's placement
    axis decides whether (and above what byte threshold) to honor them.

    ``stencil`` declares the region's neighbor-access pattern as a sequence
    of ``(grid_axis, offset)`` pairs (the DIA offset table of
    ``repro.cfd.dia`` is the canonical source).  Pointwise regions leave it
    ``None``.  Sharded replay (``repro.core.shard_program``) reads it to
    infer the halo width a domain decomposition must exchange before the
    region runs; single-device executors ignore it entirely.  ``halo_args``
    optionally narrows the exchange to the top-level arguments (positions
    or parameter names) whose *neighbors* the stencil actually reads —
    coefficient stacks multiply locally and need no halo.

    ``donate_args`` lists positional arguments donated to XLA
    (``jax.jit(donate_argnums=...)``): the output may alias the input's
    storage instead of copying — how a pass-through region (serve's
    ``KV_APPEND`` cache commit) stays O(1) instead of O(bytes).  Donate
    only when the region is the LAST consumer of that argument everywhere
    it appears (capture executes eagerly and deletes donated buffers
    too).  Executors running under a staging policy automatically fall
    back to non-donating executables (``executable(donate=False)``):
    staged operands can alias pooled pages whose lifetime the stager
    manages, and donation must never hand pool-owned storage to XLA.
    """
    name: str
    fn: Callable
    offloaded: bool = True
    size_fn: Callable = default_size
    arg_spaces: Optional[Mapping[Any, MemSpace]] = None
    result_space: Any = None      # MemSpace | {tuple index / dict key: MemSpace}
    stencil: Optional[Sequence[Tuple[int, int]]] = None
    halo_args: Optional[Sequence[Any]] = None
    donate_args: Optional[Sequence[int]] = None
    ledger: Ledger = dataclasses.field(default_factory=lambda: GLOBAL_LEDGER)

    def stencil_width(self, axis: int) -> int:
        """Halo reach of this region's declared ``stencil`` along grid
        ``axis``: the maximum |offset| of any band on that axis, 0 for
        pointwise regions.  A width-``w`` stencil applied ``k`` times
        reaches ``k*w`` (``repro.cfd.dia.compose_offsets`` composes the
        declared tables), which is exactly the ghost-zone depth the
        wide-halo exchange schedule provisions (docs/SCALING.md)."""
        if not self.stencil:
            return 0
        return max((abs(d) for ax, d in self.stencil if ax == axis),
                   default=0)

    def __post_init__(self):
        if self.size_fn is None:
            self.size_fn = default_size
        self.name = self.ledger.register(self.name, self.offloaded)
        # __name__ stays a valid identifier (regions may be named "grad(p)")
        self.__name__ = getattr(self.fn, "__name__", "region")
        self.__qualname__ = self.__name__
        self._jitted = None
        #: named implementations (OpenMP declare variant): "ref" is ALWAYS
        #: the decorated function itself — the base function every selector
        #: can fall back to
        self._variants: Dict[str, Callable] = {"ref": self.fn}
        self._jvar: Dict[str, Callable] = {}
        self._exec: Dict[Tuple[str, str], Callable] = {}
        self._param_index = _param_indices(self.fn)
        self._validate_donate_args()

    def _validate_donate_args(self) -> None:
        """Fail at declaration, not jit time: donate_args must be
        non-negative positional indices inside the signature (when it is
        introspectable and takes no *args), and must not overlap
        halo_args — a donated buffer is deleted by XLA while the sharded
        halo exchange still needs to read its neighbors."""
        if not self.donate_args:
            return
        bad = [d for d in self.donate_args
               if not isinstance(d, int) or d < 0]
        if bad:
            raise ValueError(
                f"region {self.name!r}: donate_args must be non-negative "
                f"positional indices, got {bad!r}")
        try:
            params = list(inspect.signature(self.fn).parameters.values())
        except (TypeError, ValueError):
            params = None                      # not introspectable: skip
        if params is not None and not any(
                p.kind is inspect.Parameter.VAR_POSITIONAL for p in params):
            n_pos = sum(1 for p in params if p.kind in (
                inspect.Parameter.POSITIONAL_ONLY,
                inspect.Parameter.POSITIONAL_OR_KEYWORD))
            out = [d for d in self.donate_args if d >= n_pos]
            if out:
                raise ValueError(
                    f"region {self.name!r}: donate_args {out} out of range "
                    f"for a function with {n_pos} positional parameters "
                    f"({tuple(self._param_index)})")
        if self.halo_args:
            halo_idx = {h for h in self.halo_args if isinstance(h, int)}
            halo_idx |= {self._param_index[h] for h in self.halo_args
                         if isinstance(h, str) and h in self._param_index}
            clash = sorted(halo_idx & set(self.donate_args))
            if clash:
                raise ValueError(
                    f"region {self.name!r}: donate_args {clash} overlap "
                    f"halo_args {tuple(self.halo_args)}; a donated operand "
                    "is deleted by XLA while the sharded halo exchange "
                    "still reads its ghost cells — donate a different "
                    "argument or drop it from halo_args")

    # -- implementation variants (declare variant) -----------------------
    @property
    def variants(self) -> Tuple[str, ...]:
        """Names of the registered implementation variants."""
        return tuple(self._variants)

    def variant(self, name: str, fn: Optional[Callable] = None):
        """Register a named implementation of this region — the
        ``declare variant`` directive.  Decorator form::

            @region("Amul")
            def amul(diag, off, x): ...          # the "ref" variant

            @amul.variant("pallas")
            def _amul_kernel(diag, off, x): ...  # same signature/semantics

        Variants must accept the same arguments and return the same
        structure as the base function; which one runs is decided per call
        by the executing policy's :class:`Selector`.  Re-registering
        ``"ref"`` replaces the base function itself, so every path —
        jitted executables and the fused ``as_fn`` composite alike — sees
        the same implementation."""
        def register(f: Callable) -> Callable:
            self._variants[name] = f
            if name == "ref":                   # ref IS the base function
                self.fn = f
                self._jitted = None
            for key in [k for k in self._jvar if k[0] == name]:
                del self._jvar[key]             # drop stale compilations
            for key in [k for k in self._exec if k[1] == name]:
                del self._exec[key]
            return f
        return register(fn) if fn is not None else register

    def impl_fn(self, name: str = "ref") -> Callable:
        """The raw (unjitted) callable of one registered variant."""
        try:
            return self._variants[name]
        except KeyError:
            raise KeyError(f"region {self.name!r} has no variant {name!r}; "
                           f"registered: {self.variants}") from None

    def resolve(self, name: str) -> str:
        """Variant-name resolution with the declare-variant fallback: an
        unregistered name dispatches to the base function (``ref``)."""
        return name if name in self._variants else "ref"

    # -- per-(target, variant) compiled executables ----------------------
    def _jit(self, fn: Callable) -> Callable:
        return jax.jit(fn, donate_argnums=tuple(self.donate_args or ()))

    @property
    def jitted(self):
        """The target-agnostic jitted ref executable (legacy shim
        attribute; prefer :meth:`jitted_variant`)."""
        if self._jitted is None:
            self._jitted = self._jit(self.fn)
        return self._jitted

    def jitted_variant(self, name: str = "ref",
                       donate: bool = True) -> Callable:
        """The target-agnostic jitted executable of one variant (unknown
        names fall back to ``ref``, like :meth:`executable`).

        ``donate=False`` compiles without buffer donation even when the
        region declares ``donate_args`` — the form staging executors and
        calibration loops (which re-call with the same arguments) use."""
        name = self.resolve(name)
        dflag = bool(donate and self.donate_args)
        key = (name, dflag)
        j = self._jvar.get(key)
        if j is None:
            if name == "ref" and dflag == bool(self.donate_args):
                j = self.jitted          # donating exactly like _jit(fn)
            elif dflag:
                j = self._jit(self.impl_fn(name))
            else:
                j = jax.jit(self.impl_fn(name))
            self._jvar[key] = j
        return j

    @property
    def region_name(self) -> str:
        """Legacy shim attribute; prefer ``.name``."""
        return self.name

    def executable(self, target: str = "default", impl: str = "ref",
                   donate: bool = True) -> Callable:
        """The compiled executable for one (routing target, variant) pair.

        ``default`` runs wherever operands already live (the APU model);
        ``host``/``device`` pin the call to that backend — the two
        executables of the paper's ``if(target: ...)`` clause.  ``impl``
        names a registered variant (unknown names fall back to ``ref``,
        the declare-variant base-function rule).  ``donate=False``
        disables ``donate_args`` for this executable (staging executors,
        calibration loops)."""
        impl = self.resolve(impl)
        key = (target, impl, bool(donate and self.donate_args))
        if key not in self._exec:
            jfn = self.jitted_variant(impl, donate=donate)
            if target == "default":
                call = jfn
            else:
                dev = host_device() if target == "host" else accel_device()

                def call(*args, _jfn=jfn, _dev=dev, **kwargs):
                    with jax.default_device(_dev):
                        return _jfn(*args, **kwargs)

            self._exec[key] = call
        return self._exec[key]

    # -- direct invocation ----------------------------------------------
    def __call__(self, *args, **kwargs):
        """Calling a region directly runs its default executable and
        self-times into the ledger — the pre-executor behavior of
        ``offload_region``'s runner closure."""
        t0 = time.perf_counter()
        out = self.jitted(*args, **kwargs)
        jax.block_until_ready(out)
        self.ledger.record(self.name, device=self.offloaded,
                           offloaded=self.offloaded,
                           compute_s=time.perf_counter() - t0,
                           elems=self.size_fn(args, kwargs), impl="ref")
        return out

    # -- legacy adapter --------------------------------------------------
    @classmethod
    def from_legacy(cls, obj) -> "Region":
        """Adapt a pre-regions closure (``.jitted``/``.offloaded``/
        ``.region_name`` attributes) without re-registering it."""
        r = cls.__new__(cls)
        r.name = getattr(obj, "region_name",
                         getattr(obj, "__name__", "region"))
        r.fn = obj
        r.offloaded = bool(getattr(obj, "offloaded", True))
        r.size_fn = default_size
        r.arg_spaces = None
        r.result_space = None
        r.stencil = None
        r.halo_args = None
        r.donate_args = None
        r.ledger = GLOBAL_LEDGER
        r._jitted = getattr(obj, "jitted", None) or jax.jit(obj)
        r._variants = {"ref": obj}
        r._jvar = {("ref", False): r._jitted}
        r._exec = {}
        r.__name__ = getattr(obj, "__name__", "region")
        r.__qualname__ = r.__name__
        r._param_index = {}
        return r


#: fallback adapter cache for legacy callables that reject attribute
#: assignment (__slots__/frozen) — without it every run() would build a
#: fresh Region and register a new uniquified ledger row
_LEGACY_REGIONS = weakref.WeakKeyDictionary()


def as_region(obj) -> Region:
    """Coerce anything executable into a Region (identity for Regions)."""
    if isinstance(obj, Region):
        return obj
    cached = getattr(obj, "_as_region", None)
    if cached is not None:
        return cached
    try:
        cached = _LEGACY_REGIONS.get(obj)
    except TypeError:                      # unhashable / not weakref-able
        cached = None
    if cached is not None:
        return cached
    r = Region.from_legacy(obj)
    try:
        obj._as_region = r
    except (AttributeError, TypeError):    # frozen objects: weak-cache
        try:
            _LEGACY_REGIONS[obj] = r
        except TypeError:                  # pragma: no cover
            pass
    return r


def region(name: Optional[str] = None, *, offloaded: bool = True,
           ledger: Optional[Ledger] = None, size_fn: Optional[Callable] = None,
           placement: Optional[Mapping[Any, MemSpace]] = None,
           result_space: Any = None,
           stencil: Optional[Sequence[Tuple[int, int]]] = None,
           halo_args: Optional[Sequence[Any]] = None,
           donate_args: Optional[Sequence[int]] = None):
    """Decorator: mark a function as one offloadable region (listings 4-6).

        @region("Amul", placement={0: MemSpace.DEVICE},
                stencil=dia.STENCIL_OFFSETS, halo_args=("x",))
        def amul(diag, off, x): ...
    """
    def wrap(fn: Callable) -> Region:
        return Region(name=name or getattr(fn, "__name__", "region"),
                      fn=fn, offloaded=offloaded,
                      size_fn=size_fn or default_size,
                      arg_spaces=placement, result_space=result_space,
                      stencil=stencil, halo_args=halo_args,
                      donate_args=donate_args,
                      ledger=ledger or GLOBAL_LEDGER)
    return wrap


# ---------------------------------------------------------------------------
# Policy axes: routing, staging, placement
# ---------------------------------------------------------------------------

class Router(Protocol):
    def target(self, region: Region, args, kwargs,
               size: Optional[int] = None) -> str: ...


@dataclasses.dataclass
class StaticRouter:
    """Mode-style routing: offloaded regions go one place, the rest another.

    ``default`` means "run wherever the operands live" — the APU model where
    switching sides implies no data motion."""
    offloaded_target: str = "default"
    fallback_target: str = "default"

    def target(self, region: Region, args, kwargs,
               size: Optional[int] = None) -> str:
        return self.offloaded_target if region.offloaded \
            else self.fallback_target


@dataclasses.dataclass
class SizeRouter:
    """The ``if(target: n > TARGET_CUT_OFF)`` clause (paper C3), absorbed
    from the retired ``TargetDispatch`` shim so it runs *inside* any
    executor."""
    cutoff: int = DEFAULT_CUTOFF

    def target(self, region: Region, args, kwargs,
               size: Optional[int] = None) -> str:
        if not region.offloaded:
            return "host"
        n = region.size_fn(args, kwargs) if size is None else size
        return "device" if n > self.cutoff else "host"


class Stager(Protocol):
    stages: bool
    def stage_in(self, region: Region, args, kwargs) -> Tuple[tuple, float, int]: ...
    def stage_out(self, region: Region, out, staged_in=None) -> Tuple[Any, float, int]: ...


class NullStager:
    """APU / host model: crossing the boundary moves no bytes."""
    stages = False

    def stage_in(self, region, args, kwargs):
        return (args, kwargs), 0.0, 0

    def stage_out(self, region, out, staged_in=None):
        return out, 0.0, 0


# copy-into-donated-buffer: XLA may alias the output onto the pooled
# buffer's storage, which is what "reuse" means for immutable arrays
# (select keeps the dtype exact — src and dst match by construction).
# Module-level so every stager shares one jit cache per shape/dtype.
_copy_into = jax.jit(lambda src, dst: jnp.where(True, src, dst),
                     donate_argnums=(1,))

# slab-into-donated-buffer: the chunked form of _copy_into for
# budget-bounded staging — lands one leading-axis slab of the source in
# the (donated) destination, so a leaf larger than the device budget's
# staging granule streams through it in slabs instead of migrating as
# one transient allocation.
_copy_slab = jax.jit(
    lambda dst, src, start: jax.lax.dynamic_update_slice_in_dim(
        dst, src, start, axis=0),
    donate_argnums=(0,))


def _chunked_copy_into(h, dst, chunk_bytes: int):
    """Stage host array ``h`` into the pooled device buffer ``dst`` in
    leading-axis slabs of at most ``chunk_bytes`` — the managed-memory
    page-migration model with the page size set by a
    :class:`~repro.core.oversub.MemoryBudget`.  Values are identical to a
    single ``_copy_into`` (same bytes, different copy granularity), which
    is what keeps budgeted replay on the §2 parity contract.  Returns
    ``(result, n_chunks)``."""
    rows = int(h.shape[0]) if h.ndim else 0
    row_bytes = h.nbytes // rows if rows else h.nbytes
    slab = max(1, int(chunk_bytes) // max(int(row_bytes), 1))
    if not rows or rows <= slab:
        return _copy_into(h, dst), 1
    y = dst
    n = 0
    for start in range(0, rows, slab):
        y = _copy_slab(y, h[start:start + slab], start)
        n += 1
    return y, n


@dataclasses.dataclass
class MigrationStager:
    """Managed-memory dGPU model: every host<->device crossing is a REAL
    out-of-place copy (paper §5, the >65% migration fraction of Fig 6).

    Inbound, operands are read out of host memory and migrated into device
    buffers recycled through the :class:`DeviceBufferPool` (donation hands
    the pooled storage to XLA — paper C4's "reuse instead of alloc/free
    churn").  Outbound, results are read back and landed in pooled host
    staging pages before being re-wrapped as host-space arrays, so the next
    host consumer sees host memory — and the next offloaded region pays the
    migration again.

    ``budget`` (a :class:`~repro.core.oversub.MemoryBudget`) bounds the
    transient staging granule: leaves larger than the budget's
    ``staging_chunk_bytes()`` migrate in leading-axis slabs through
    ``_chunked_copy_into`` instead of one copy, so grids beyond device
    capacity stream through the budget rather than blowing past it.
    Chunking changes copy granularity, never values."""
    arena: UnifiedArena = dataclasses.field(default_factory=UnifiedArena)
    host_pool: HostStagingPool = dataclasses.field(
        default_factory=HostStagingPool)
    device_pool: DeviceBufferPool = dataclasses.field(
        default_factory=DeviceBufferPool)
    budget: Optional[Any] = None
    stages = True

    def _migrate_in(self, x, rotation=None):
        if not hasattr(x, "nbytes"):
            return x
        h = np.asarray(x)                               # host page read
        pool = rotation.pool if rotation is not None else self.device_pool
        dst = pool.acquire(h.shape, h.dtype)
        chunk = self.budget.staging_chunk_bytes() \
            if self.budget is not None else None
        if chunk is not None and h.nbytes > chunk:
            y, n = _chunked_copy_into(h, dst, chunk)    # budgeted slabs
            self.budget.note_chunks(n)
        else:
            y = _copy_into(h, dst)                      # host -> device copy
        if rotation is not None:
            # the copy DONATES dst; the bank must hold the result (which
            # owns the recycled storage), never the consumed buffer
            rotation.register(y)
        return y

    @staticmethod
    def _aliases(y, buf) -> bool:
        """Does the jax Array share storage with the numpy staging buffer?
        On CPU backends device_put from numpy may be zero-copy."""
        try:
            return y.unsafe_buffer_pointer() == \
                buf.__array_interface__["data"][0]
        except Exception:
            return True                                 # conservative

    def _migrate_out(self, x, pending: Optional[list] = None):
        """Land one result in a pooled host page and re-wrap it host-side.

        The wrap may COPY the page *asynchronously*: the page cannot go
        back to the pool (where the very next result lands a copyto)
        until that read has finished, or a delayed copy reads recycled
        bytes — the PR-2 replay-corruption race.  Ownership is therefore
        decided only after the wrap is ready: standalone calls block here;
        ``stage_out`` passes ``pending`` to collect (wrap, page) pairs,
        block ONCE on the whole staged tree (copies overlap), and settle
        afterwards."""
        if not isinstance(x, jax.Array):
            return x
        h = np.asarray(jax.device_get(x))               # device -> host copy
        buf = self.host_pool.acquire(h.shape, h.dtype)
        np.copyto(buf, h)                               # pooled host pages
        y = umem.place(buf, self.arena.host_space, self.arena.device)
        if not isinstance(y, jax.Array):                # no host space: wrap
            y = jax.device_put(buf, self.arena.device)
        if pending is None:
            jax.block_until_ready(y)
            self._settle_pages([(y, buf)])
        else:
            pending.append((y, buf))
        return y

    def _settle_pages(self, pending) -> None:
        """Decide page ownership for READY wraps: recycle the page when the
        wrap copied; a zero-copy device_put leaves the wrap aliasing the
        pooled bytes (CPU backends), so there the page returns to the pool
        only when the result array dies — the Umpire model: the app
        "frees" host memory by dropping the result."""
        for y, buf in pending:
            if self._aliases(y, buf):
                try:
                    weakref.finalize(y, self.host_pool.release, buf)
                except TypeError:          # pragma: no cover - no weakrefs
                    pass
            else:
                self.host_pool.release(buf)

    def stage_in(self, region, args, kwargs):
        t0 = time.perf_counter()
        nbytes = self.arena.bytes_of((args, kwargs))
        staged = jax.tree.map(self._migrate_in, (args, kwargs))
        jax.block_until_ready(staged)
        return staged, time.perf_counter() - t0, nbytes

    def stage_leaves(self, leaves, rotation=None):
        """Migrate a flat list of leaves host->device, acquiring through a
        :class:`~repro.core.pool.BufferRotation` bank when one is given —
        the double-buffered path of the async lookahead replay
        (``repro.core.program``).  Returns (staged_leaves, seconds, bytes)."""
        t0 = time.perf_counter()
        nbytes = self.arena.bytes_of(leaves)
        staged = [self._migrate_in(x, rotation) for x in leaves]
        jax.block_until_ready(staged)
        return staged, time.perf_counter() - t0, nbytes

    def stage_out(self, region, out, staged_in=None):
        t0 = time.perf_counter()
        nbytes = self.arena.bytes_of(out)
        pending: list = []
        staged = jax.tree.map(lambda x: self._migrate_out(x, pending), out)
        jax.block_until_ready(staged)       # all wrap copies, overlapped
        self._settle_pages(pending)
        if staged_in is not None:                       # recycle dead inputs
            for x in jax.tree.leaves(staged_in):
                if isinstance(x, jax.Array):
                    self.device_pool.release(x)
        return staged, time.perf_counter() - t0, nbytes


@dataclasses.dataclass
class Placer:
    """Placement axis: apply a region's MemSpace hints through umem.

    ``min_bytes`` is the paper-C4-style threshold: leaves smaller than it
    stay where they are (placing a scalar across spaces costs more than it
    saves).

    ``_place_tree`` is the single placement primitive every hint flows
    through — subclasses override it to make placement *conditional*
    (:class:`~repro.core.oversub.BudgetedPlacer` demotes device hints to
    host space when a memory budget lacks headroom)."""
    min_bytes: int = 0
    honor_hints: bool = True

    def _place_tree(self, tree, space: MemSpace):
        return umem.tree_place(tree, space, min_bytes=self.min_bytes)

    def place_args(self, region: Region, args, kwargs):
        if not (self.honor_hints and region.arg_spaces):
            return args, kwargs
        args = list(args)
        kwargs = dict(kwargs)
        for key, space in region.arg_spaces.items():
            if isinstance(key, str):
                if key in kwargs:
                    kwargs[key] = self._place_tree(kwargs[key], space)
                    continue
                # name hint for a positionally-passed argument
                key = region._param_index.get(key, -1)
            if isinstance(key, int) and 0 <= key < len(args):
                args[key] = self._place_tree(args[key], space)
        return tuple(args), kwargs

    def place_result(self, region: Region, out):
        if not (self.honor_hints and region.result_space is not None):
            return out
        rs = region.result_space
        if isinstance(rs, Mapping):
            # keyed form: place only the named top-level result elements
            if isinstance(out, tuple):
                placed = list(out)
                for key, space in rs.items():
                    if isinstance(key, int) and 0 <= key < len(placed):
                        placed[key] = self._place_tree(placed[key], space)
                return tuple(placed)
            if isinstance(out, dict):
                return {k: self._place_tree(v, rs[k])
                        if k in rs else v for k, v in out.items()}
            return out
        return self._place_tree(out, rs)


# ---------------------------------------------------------------------------
# Selection axis: which implementation variant runs (declare variant)
# ---------------------------------------------------------------------------

class Selector(Protocol):
    """The fourth policy axis: resolve one registered variant per call.

    ``target`` is the routing decision already made by the policy's Router
    (``default`` / ``host`` / ``device``), so selection can condition on
    where the call will run — OpenMP's ``match(device={...})`` clause."""

    def select(self, region: Region, target: str, args, kwargs,
               size: Optional[int] = None) -> str: ...


@dataclasses.dataclass
class StaticSelector:
    """One named implementation everywhere.  Regions that never registered
    the name run their base function instead — the declare-variant
    fallback, which is what lets a whole captured program replay under
    ``StaticSelector("pallas")`` when only its hot regions carry kernels."""
    impl: str = "ref"

    def select(self, region: Region, target: str, args, kwargs,
               size: Optional[int] = None) -> str:
        return region.resolve(self.impl)


#: the do-nothing selector: every region runs its decorated function, the
#: exact pre-variants behavior
DEFAULT_SELECTOR = StaticSelector("ref")


@dataclasses.dataclass
class TargetSelector:
    """Target-conditioned defaults — ``declare variant match(construct,
    device)``: device-side calls (including ``default``, the APU's
    resident execution) prefer the custom kernel, host-side calls the
    host-tuned path, with the usual fallback to ``ref``."""
    device_impl: str = "pallas"
    host_impl: str = "host"

    def select(self, region: Region, target: str, args, kwargs,
               size: Optional[int] = None) -> str:
        want = self.host_impl if target == "host" else self.device_impl
        return region.resolve(want)


def size_bucket(n: int) -> int:
    """Power-of-two size bucket: bucket ``b`` covers ``[2^(b-1), 2^b)``.
    The autotune analogue of the paper's single TARGET_CUT_OFF — coarse
    enough that a handful of calibration sizes covers a workload, fine
    enough that the host/kernel crossover lands in its own cell."""
    return int(n).bit_length()


@dataclasses.dataclass
class AutotuneSelector:
    """Calibrated variant selection: winners per (region, target,
    size-bucket), measured by :meth:`calibrate` the way
    ``AdaptivePolicy.calibrate`` measures the routing cutoff, and persisted
    on the region's ledger row (``coverage_report()["calibrated_variants"]``).

    Uncalibrated cells fall back to the nearest calibrated bucket of the
    same (region, target), then to ``fallback`` (default: ``ref``)."""
    fallback: Any = dataclasses.field(
        default_factory=lambda: StaticSelector("ref"))
    winners: Dict[Tuple[str, str, int], str] = dataclasses.field(
        default_factory=dict)

    def select(self, region: Region, target: str, args, kwargs,
               size: Optional[int] = None) -> str:
        n = region.size_fn(args, kwargs) if size is None else size
        b = size_bucket(n)
        win = self.winners.get((region.name, target, b))
        if win is None:
            near = [(abs(bb - b), bb) for (rn, t, bb) in self.winners
                    if rn == region.name and t == target]
            if near:
                win = self.winners[(region.name, target, min(near)[1])]
        if win is None:
            return self.fallback.select(region, target, args, kwargs, size=n)
        return region.resolve(win)

    def calibrate(self, target_region, make_args: Callable[[int], tuple],
                  sizes: Sequence[int] = (256, 4096, 65536),
                  targets: Sequence[str] = ("default",),
                  reps: int = 10, ledger: Optional[Ledger] = None) -> dict:
        """Time every registered variant of ``target_region`` over a size
        ladder per routing target; store the winner per (target, bucket)
        and persist it with the region's ledger row.

        ``make_args(n)`` builds one positional argument tuple of problem
        size ~``n``; the bucket is derived from the region's own
        ``size_fn`` on those arguments, so calibration and selection agree
        on the size measure.  Returns ``{(target, bucket): winner}``."""
        r = as_region(target_region)
        chosen = {}
        for tgt in targets:
            for n in sorted(sizes):
                args = make_args(n)
                best, best_t = "ref", float("inf")
                for name in r.variants:
                    # donate=False: the timing loop re-calls with the same
                    # argument buffers
                    ex = r.executable(tgt, name, donate=False)
                    out = ex(*args)
                    jax.block_until_ready(out)          # compile + warm
                    t0 = time.perf_counter()
                    for _ in range(reps):
                        out = ex(*args)
                    jax.block_until_ready(out)
                    dt = (time.perf_counter() - t0) / reps
                    if dt < best_t:
                        best, best_t = name, dt
                b = size_bucket(r.size_fn(args, {}))
                self.winners[(r.name, tgt, b)] = best
                chosen[(tgt, b)] = best
                r.ledger.set_calibrated_variant(r.name, tgt, b, best)
                if ledger is not None and ledger is not r.ledger:
                    ledger.set_calibrated_variant(r.name, tgt, b, best)
        return chosen


# ---------------------------------------------------------------------------
# ExecutionPolicy = placement x routing x staging x selection
# ---------------------------------------------------------------------------

@runtime_checkable
class ExecutionPolicy(Protocol):
    """What an Executor needs: a name and the composable axes.  ``selector``
    is optional for backward compatibility — executors treat a missing
    attribute as ``DEFAULT_SELECTOR`` (always ``ref``)."""
    name: str
    router: Router
    stager: Stager
    placer: Placer


def policy_selector(policy) -> Selector:
    """The policy's selection axis, defaulting to ref-everywhere for
    pre-variants policy objects."""
    return getattr(policy, "selector", None) or DEFAULT_SELECTOR


@dataclasses.dataclass
class ComposedPolicy:
    """A concrete ExecutionPolicy assembled from the four axes."""
    name: str
    router: Any = dataclasses.field(default_factory=StaticRouter)
    stager: Any = dataclasses.field(default_factory=NullStager)
    placer: Any = dataclasses.field(default_factory=Placer)
    selector: Any = dataclasses.field(
        default_factory=lambda: StaticSelector("ref"))


class UnifiedPolicy(ComposedPolicy):
    """APU model (paper §3): operands stay where they are, regions run
    back-to-back, zero staging by construction."""

    def __init__(self, placer: Optional[Placer] = None,
                 selector: Optional[Selector] = None):
        super().__init__("unified", StaticRouter("default", "default"),
                         NullStager(), placer or Placer(),
                         selector or StaticSelector("ref"))


class HostPolicy(ComposedPolicy):
    """dCPU model: every region — directive or not — runs on the host."""

    def __init__(self, placer: Optional[Placer] = None,
                 selector: Optional[Selector] = None):
        super().__init__("host", StaticRouter("host", "host"),
                         NullStager(), placer or Placer(),
                         selector or StaticSelector("ref"))


class DiscretePolicy(ComposedPolicy):
    """Managed-memory dGPU model: offloaded regions run on the device and
    pay real staging copies both ways (paper Fig 6).

    ``budget`` (a :class:`~repro.core.oversub.MemoryBudget`) makes the
    policy oversubscription-aware: the device pool charges its resident
    bytes against it and the stager migrates in budget-sized slabs, so
    grids beyond the logical device capacity stream through instead of
    blowing past it."""

    def __init__(self, arena: Optional[UnifiedArena] = None,
                 host_pool: Optional[HostStagingPool] = None,
                 device_pool: Optional[DeviceBufferPool] = None,
                 placer: Optional[Placer] = None,
                 selector: Optional[Selector] = None,
                 budget: Optional[Any] = None):
        arena = arena or UnifiedArena()
        if device_pool is None:
            device_pool = DeviceBufferPool(budget=budget)
        super().__init__("discrete", StaticRouter("device", "default"),
                         MigrationStager(arena,
                                         host_pool or HostStagingPool(),
                                         device_pool,
                                         budget=budget),
                         placer or Placer(),
                         selector or StaticSelector("ref"))
        self.arena = arena
        self.budget = budget


class AdaptivePolicy(ComposedPolicy):
    """Calibrated size-based routing *inside* an executor — the
    ``TARGET_CUT_OFF`` clause as a policy axis, which the pre-regions split
    (TargetDispatch vs executors) made structurally impossible."""

    def __init__(self, cutoff: int = DEFAULT_CUTOFF,
                 stager: Optional[Stager] = None,
                 placer: Optional[Placer] = None,
                 selector: Optional[Selector] = None,
                 budget: Optional[Any] = None):
        if stager is None and budget is not None:
            # oversubscription-aware adaptive: device-routed calls pay
            # budget-chunked staging like the discrete model
            stager = MigrationStager(
                device_pool=DeviceBufferPool(budget=budget), budget=budget)
        super().__init__("adaptive", SizeRouter(cutoff),
                         stager or NullStager(), placer or Placer(),
                         selector or StaticSelector("ref"))
        self.budget = budget

    @property
    def cutoff(self) -> int:
        return self.router.cutoff

    @cutoff.setter
    def cutoff(self, value: int) -> None:
        self.router.cutoff = value

    def calibrate(self, target_region, make_args: Callable[[int], tuple],
                  sizes: Sequence[int] = (256, 1024, 4096, 16384, 65536),
                  reps: int = 20, ledger: Optional[Ledger] = None) -> int:
        """Reproduce the paper's empirical TARGET_CUT_OFF choice: time both
        executables over a size ladder, set cutoff to the crossover, and
        record the choice with the region's ledger row.

        ``ledger`` additionally mirrors the cutoff into another ledger's
        row of the same bare name (get-or-create) — note that a foreign
        ledger holding a *different* region under that name would receive
        the mirror on that row."""
        r = as_region(target_region)
        crossover = None
        for n in sorted(sizes):
            args = make_args(n)
            ts = {}
            for tgt in ("host", "device"):
                ex = r.executable(tgt, donate=False)
                out = ex(*args)
                jax.block_until_ready(out)
                t0 = time.perf_counter()
                for _ in range(reps):
                    out = ex(*args)
                jax.block_until_ready(out)
                ts[tgt] = (time.perf_counter() - t0) / reps
            if ts["device"] < ts["host"]:
                crossover = n
                break
        if crossover is None:
            crossover = max(sizes) + 1
        self.cutoff = crossover
        # the region's OWN ledger is authoritative for r.name; an explicit
        # foreign ledger gets a bare-name mirror (see docstring caveat)
        r.ledger.set_cutoff(r.name, crossover)
        if ledger is not None and ledger is not r.ledger:
            ledger.set_cutoff(r.name, crossover)
        return crossover


# ---------------------------------------------------------------------------
# Executor
# ---------------------------------------------------------------------------

class Executor:
    """Replays region programs under one ExecutionPolicy, accounting every
    call into one Ledger.

    Return contract: ``run`` ALWAYS returns jax Arrays (or the region's
    non-array outputs unchanged), regardless of policy.  The discrete policy
    stages results into host-space arrays — it does not leak numpy, which
    the old DiscreteExecutor did, silently changing downstream types per
    mode."""

    def __init__(self, policy: ExecutionPolicy, ledger: Optional[Ledger] = None):
        self.policy = policy
        self.ledger = ledger or Ledger(policy.name)
        self.mode = policy.name
        # staging policies carry pools — attach them so coverage_report()
        # surfaces byte-level pool accounting next to the staging fractions
        stager = getattr(policy, "stager", None)
        for pool_name, attr in (("host_staging", "host_pool"),
                                ("device_buffer", "device_pool")):
            pool = getattr(stager, attr, None)
            if pool is not None:
                self.ledger.attach_pool(pool_name, pool)
        # region -> (ledger -> row name), weak at both levels: entries die
        # with their region/ledger instead of pinning compiled executables
        # for the executor's lifetime, and object identity (not id()) rules
        # out stale hits after a ledger swap recycles an address
        self._row_names = weakref.WeakKeyDictionary()

    def _row_name(self, r: Region) -> str:
        """Ledger row for this region in THIS executor's ledger.  Distinct
        region objects that happen to share a name (registered in different
        ledgers) must not merge into one row — re-uniquify on first record."""
        per_region = self._row_names.get(r)
        if per_region is None:
            per_region = weakref.WeakKeyDictionary()
            self._row_names[r] = per_region
        name = per_region.get(self.ledger)
        if name is None:
            name = r.name if r.ledger is self.ledger \
                else self.ledger.register(r.name, r.offloaded)
            per_region[self.ledger] = name
        return name

    def run(self, target_region, *args, **kwargs):
        r = as_region(target_region)
        pol = self.policy
        n = r.size_fn(args, kwargs)
        tgt = pol.router.target(r, args, kwargs, size=n)
        # resolve() here, not just in executable(): custom selectors may
        # return unregistered names, and the ledger must record what RAN
        impl = r.resolve(policy_selector(pol).select(r, tgt, args, kwargs,
                                                     size=n))
        args, kwargs = pol.placer.place_args(r, args, kwargs)
        staging_s = 0.0
        staging_b = 0
        stage = pol.stager.stages and r.offloaded and tgt != "host"
        staged_in = None
        if stage:
            (args, kwargs), s, b = pol.stager.stage_in(r, args, kwargs)
            staged_in = (args, kwargs)
            staging_s += s
            staging_b += b
        t0 = time.perf_counter()
        # donation is disabled under staging policies: staged operands may
        # alias pooled pages whose lifetime the stager manages
        out = r.executable(tgt, impl,
                           donate=not pol.stager.stages)(*args, **kwargs)
        jax.block_until_ready(out)
        compute_s = time.perf_counter() - t0
        if stage:
            out, s, b = pol.stager.stage_out(r, out, staged_in)
            staging_s += s
            staging_b += b
        out = pol.placer.place_result(r, out)
        device = r.offloaded if tgt == "default" else (tgt == "device")
        self.ledger.record(self._row_name(r), device=device,
                           offloaded=r.offloaded,
                           compute_s=compute_s, staging_s=staging_s,
                           staging_bytes=staging_b, elems=n, impl=impl)
        return out

    def report(self) -> dict:
        rep = self.ledger.coverage_report()
        rep["mode"] = self.mode
        return rep


POLICIES = {
    "unified": UnifiedPolicy,
    "discrete": DiscretePolicy,
    "host": HostPolicy,
    "adaptive": AdaptivePolicy,
}


def make_policy(mode: str, **kw) -> ComposedPolicy:
    return POLICIES[mode](**kw)
