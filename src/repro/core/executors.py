"""RETIRED module — deprecation-alias stub only.

The pre-regions executor *classes* are gone; the three §5 execution modes
are :class:`ExecutionPolicy` instances (``UnifiedPolicy`` /
``DiscretePolicy`` / ``HostPolicy``) run by the one
:class:`~repro.core.regions.Executor`.  The names below are plain alias
functions constructing exactly that, so external pre-regions call sites
keep working one more release; nothing in this repo imports this module
(CI enforces it via ``tools/check_retired_imports.py``).

Migration (see ARCHITECTURE.md, "Migration notes"):

    UnifiedExecutor(ldg)        ->  Executor(UnifiedPolicy(), ldg)
    DiscreteExecutor(ldg, a, p) ->  Executor(DiscretePolicy(arena=a,
                                             device_pool=p), ldg)
    HostExecutor(ldg)           ->  Executor(HostPolicy(), ldg)
    make_executor(mode)         ->  Executor(make_policy(mode))
"""
from __future__ import annotations

import warnings
from typing import Optional

from repro.core.ledger import Ledger
from repro.core.regions import (DiscretePolicy, Executor, HostPolicy,
                                UnifiedPolicy, make_policy)

warnings.warn(
    "repro.core.executors is retired: construct "
    "Executor(<Policy>(), ledger) from repro.core.regions",
    DeprecationWarning, stacklevel=2)

BaseExecutor = Executor


def UnifiedExecutor(ledger: Optional[Ledger] = None) -> Executor:
    return Executor(UnifiedPolicy(), ledger)


def HostExecutor(ledger: Optional[Ledger] = None) -> Executor:
    return Executor(HostPolicy(), ledger)


def DiscreteExecutor(ledger: Optional[Ledger] = None, arena=None,
                     pool=None) -> Executor:
    return Executor(DiscretePolicy(arena=arena, device_pool=pool), ledger)


def make_executor(mode: str, **kw) -> Executor:
    ledger = kw.pop("ledger", None)
    if "pool" in kw:                 # old DiscreteExecutor parameter name
        kw["device_pool"] = kw.pop("pool")
    return Executor(make_policy(mode, **kw), ledger)
