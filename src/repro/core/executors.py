"""Deprecated execution-mode shims (paper §5, Figs 5-6).

The three §5 execution modes — APU / managed-memory dGPU / dCPU — now live
in ``repro.core.regions`` as :class:`ExecutionPolicy` instances
(``UnifiedPolicy`` / ``DiscretePolicy`` / ``HostPolicy``) run by one
:class:`~repro.core.regions.Executor`.  This module keeps the old class
names and ``make_executor`` as thin shims so pre-regions call sites keep
working; new code should construct ``Executor(UnifiedPolicy(), ledger)``
directly.

Return contract (uniform across modes): ``run`` returns jax Arrays.  The
old ``DiscreteExecutor`` returned numpy, silently changing downstream types
per mode; the discrete *policy* instead stages results into host-space jax
Arrays — same host-memory semantics, one type contract.
"""
from __future__ import annotations

from typing import Optional

from repro.core.ledger import Ledger
from repro.core.pool import DeviceBufferPool
from repro.core.regions import (DiscretePolicy, Executor, HostPolicy,
                                UnifiedPolicy, make_policy)
from repro.core.umem import UnifiedArena

BaseExecutor = Executor          # deprecated alias


class UnifiedExecutor(Executor):
    """Deprecated shim: ``Executor(UnifiedPolicy(), ledger)``."""

    def __init__(self, ledger: Optional[Ledger] = None):
        super().__init__(UnifiedPolicy(), ledger)


class HostExecutor(Executor):
    """Deprecated shim: ``Executor(HostPolicy(), ledger)``."""

    def __init__(self, ledger: Optional[Ledger] = None):
        super().__init__(HostPolicy(), ledger)


class DiscreteExecutor(Executor):
    """Deprecated shim: ``Executor(DiscretePolicy(...), ledger)``."""

    def __init__(self, ledger: Optional[Ledger] = None,
                 arena: Optional[UnifiedArena] = None,
                 pool: Optional[DeviceBufferPool] = None):
        policy = DiscretePolicy(arena=arena, device_pool=pool)
        super().__init__(policy, ledger)
        self.arena = policy.arena
        self.pool = policy.stager.device_pool


EXECUTORS = {
    "unified": UnifiedExecutor,
    "discrete": DiscreteExecutor,
    "host": HostExecutor,
}


def make_executor(mode: str, **kw) -> Executor:
    """Deprecated: prefer ``Executor(make_policy(mode), ledger)``."""
    if mode in EXECUTORS:
        return EXECUTORS[mode](**kw)
    ledger = kw.pop("ledger", None)
    return Executor(make_policy(mode, **kw), ledger)
