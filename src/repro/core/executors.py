"""Execution modes over the same region program (paper §5, Figs 5-6).

The paper's measurement: identical OpenFOAM source, three platforms —
dCPU (host only), dGPU + managed memory (every host<->device alternation
pays page migration), APU (unified physical memory, no migration). Here the
three executors run the *same* jitted regions and differ only in data
motion:

* ``UnifiedExecutor``  — APU model. Operands stay where they are; regions
  run back-to-back. Zero staging cost by construction.
* ``DiscreteExecutor`` — managed-memory dGPU model. Every offloaded region
  is bracketed by REAL copies between the host arena (``pinned_host``) and
  the device arena (``device`` memory kind): operands in, results out —
  that is what fine-grained CPU/GPU alternation costs when memory is not
  physically unified. Copy time/bytes land in the ledger as staging (the
  paper's >65% migration fraction, Fig 6).
* ``HostExecutor``     — dCPU model: regions marked offloaded still run,
  but on the host executable; no staging.

The FOM ratio unified/discrete over the CFD case study reproduces the
paper's Fig 5 claim structure.
"""
from __future__ import annotations

import time
from typing import Any

import jax

from repro.core.ledger import Ledger
from repro.core.pool import DeviceBufferPool
from repro.core.umem import UnifiedArena


class BaseExecutor:
    mode = "base"

    def __init__(self, ledger: Ledger = None):
        self.ledger = ledger or Ledger(self.mode)

    def run(self, region, *args, **kwargs):
        raise NotImplementedError

    def report(self) -> dict:
        rep = self.ledger.coverage_report()
        rep["mode"] = self.mode
        return rep


class UnifiedExecutor(BaseExecutor):
    mode = "unified"

    def run(self, region, *args, **kwargs):
        t0 = time.perf_counter()
        out = region.jitted(*args, **kwargs)
        jax.block_until_ready(out)
        self.ledger.record(region.region_name, device=region.offloaded,
                           offloaded=region.offloaded,
                           compute_s=time.perf_counter() - t0)
        return out


class HostExecutor(BaseExecutor):
    mode = "host"

    def __init__(self, ledger: Ledger = None):
        super().__init__(ledger)
        self._host = jax.devices("cpu")[0]

    def run(self, region, *args, **kwargs):
        t0 = time.perf_counter()
        with jax.default_device(self._host):
            out = region.jitted(*args, **kwargs)
        jax.block_until_ready(out)
        self.ledger.record(region.region_name, device=False, offloaded=False,
                           compute_s=time.perf_counter() - t0)
        return out


class DiscreteExecutor(BaseExecutor):
    """Managed-memory dGPU emulation with real inter-space copies."""
    mode = "discrete"

    def __init__(self, ledger: Ledger = None, arena: UnifiedArena = None,
                 pool: DeviceBufferPool = None):
        super().__init__(ledger)
        self.arena = arena or UnifiedArena()
        self.pool = pool or DeviceBufferPool()

    def run(self, region, *args, **kwargs):
        name = region.region_name
        if not region.offloaded:
            t0 = time.perf_counter()
            out = region.jitted(*args, **kwargs)
            jax.block_until_ready(out)
            self.ledger.record(name, device=False, offloaded=False,
                               compute_s=time.perf_counter() - t0)
            return out
        # ---- page-migration emulation: host -> device ----
        t0 = time.perf_counter()
        d_args, d_kwargs = self.arena.to_device((args, kwargs))
        jax.block_until_ready((d_args, d_kwargs))
        t1 = time.perf_counter()
        out = region.jitted(*d_args, **d_kwargs)
        jax.block_until_ready(out)
        t2 = time.perf_counter()
        # ---- results migrate back as HOST (numpy) values: the host code
        # that runs next sees plain host memory, as on a managed-memory dGPU
        out_h = jax.device_get(out)
        t3 = time.perf_counter()
        nbytes = self.arena.bytes_of((args, kwargs)) + self.arena.bytes_of(out)
        self.ledger.record(name, device=True, offloaded=True,
                           compute_s=t2 - t1,
                           staging_s=(t1 - t0) + (t3 - t2),
                           staging_bytes=nbytes)
        return out_h


EXECUTORS = {
    "unified": UnifiedExecutor,
    "discrete": DiscreteExecutor,
    "host": HostExecutor,
}


def make_executor(mode: str, **kw) -> BaseExecutor:
    return EXECUTORS[mode](**kw)
