"""Device-budget enforcement: run workloads that don't fit (paper C1 at
production scale).

The MI300A's headline capability is *transparent oversubscription*: one
HBM3 space means a working set bigger than the GPU partition degrades —
pages migrate — instead of OOMing ("Harnessing Integrated CPU-GPU System
Memory for HPC" in PAPERS.md measures exactly that curve).  On the CPU
container device capacity is emulated the same way the rest of the repo
emulates placement: a :class:`MemoryBudget` is the *logical* device
capacity, every device-resident byte is charged against it, and the
layers that consult it degrade by moving bytes host-side through the
placement axis (``umem.place``) rather than failing:

* :class:`~repro.core.pool.DeviceBufferPool` charges/releases its
  device-kind buffers, so pool accounting (`PoolStats.bytes_in_use`) and
  budget accounting agree byte-for-byte;
* :class:`~repro.serve.paged_kv.PagedKVCache` treats the budget as its
  device page limit — LRU entries spill to host DRAM when parked pages
  exceed it;
* :class:`~repro.models.moe.ExpertPager` keeps a device-resident LRU
  working set of expert weights inside the budget, paging slabs in from
  host-resident stacks per token;
* :class:`~repro.core.regions.MigrationStager` (and the sharded
  ``ShardExecutor`` scatter) bound their transient staging granule to
  :meth:`MemoryBudget.staging_chunk_bytes`, so a grid bigger than the
  budget streams through it in slabs;
* :class:`BudgetedPlacer` demotes ``MemSpace.DEVICE`` placement hints to
  host space while the budget lacks headroom.

Enforcement is *degradation, not denial* — ``charge`` never raises.  A
charge that lands over the limit records a pressure event, and the policy
layer that caused it is responsible for shedding bytes (spill, evict,
chunk).  That asymmetry — budgeted runs complete where a discrete GPU
would OOM — is the claim ``fig_oversub`` and ``tests/test_oversub.py``
lock in, together with the parity contract: placement never changes
values, so a budgeted run is bit-identical to its unbudgeted reference.
"""
from __future__ import annotations

import dataclasses
import threading
from typing import Any, Optional

from repro.core import umem
from repro.core.regions import Placer, Region
from repro.core.umem import MemSpace

#: floor for budget-derived staging slabs — chunking below one page of
#: work costs more dispatches than it saves residency
MIN_CHUNK_BYTES = 4096

#: fraction of the budget one in-flight staging slab may occupy
CHUNK_FRACTION = 4


@dataclasses.dataclass
class BudgetStats:
    charged_bytes: int = 0          # currently device-resident (logical)
    high_water_bytes: int = 0       # peak charged
    charges: int = 0
    releases: int = 0
    admitted: int = 0               # admit()/consult() yeses
    denials: int = 0                # admit()/consult() refusals
    spilled_bytes: int = 0          # bytes a denial redirected host-side
    pressure_events: int = 0        # unconditional charges landing over
    staging_chunks: int = 0         # budget-bounded staging slabs issued

    def as_dict(self) -> dict:
        return dataclasses.asdict(self)


class MemoryBudget:
    """A logical device-capacity budget that policies consult.

    ``limit_bytes=None`` is the unbudgeted reference (everything fits;
    every query says yes).  All methods are thread-safe — the async
    lookahead stager charges from its prefetch thread while the main
    thread releases.
    """

    def __init__(self, limit_bytes: Optional[int] = None, *,
                 name: str = "device"):
        if limit_bytes is not None and limit_bytes < 1:
            raise ValueError("limit_bytes must be >= 1 (None = unlimited)")
        self.limit_bytes = limit_bytes
        self.name = name
        self.stats = BudgetStats()
        self._lock = threading.Lock()

    @classmethod
    def for_ratio(cls, footprint_bytes: int, ratio: float, *,
                  name: str = "device") -> "MemoryBudget":
        """The budget that makes ``footprint_bytes`` an ``ratio``-times
        oversubscribed working set: ``limit = footprint / ratio``.  Ratio
        1.0 is the everything-fits reference point of the degradation
        curve; 4.0 means only a quarter of the workload is device-resident
        at once."""
        if ratio <= 0:
            raise ValueError("oversubscription ratio must be > 0")
        return cls(max(1, int(footprint_bytes / ratio)), name=name)

    def __repr__(self) -> str:
        lim = "unlimited" if self.limit_bytes is None else self.limit_bytes
        return (f"MemoryBudget({self.name}: {lim}, "
                f"charged={self.stats.charged_bytes})")

    # -- queries ---------------------------------------------------------
    def fits(self, nbytes: int) -> bool:
        """Would charging ``nbytes`` stay within the limit?"""
        return self.limit_bytes is None or \
            self.stats.charged_bytes + int(nbytes) <= self.limit_bytes

    def headroom(self) -> Optional[int]:
        """Bytes left under the limit (None = unlimited)."""
        if self.limit_bytes is None:
            return None
        return max(0, self.limit_bytes - self.stats.charged_bytes)

    @property
    def over(self) -> bool:
        return self.limit_bytes is not None and \
            self.stats.charged_bytes > self.limit_bytes

    def utilization(self) -> float:
        if not self.limit_bytes:
            return 0.0
        return self.stats.charged_bytes / self.limit_bytes

    def oversubscription_ratio(self, footprint_bytes: int) -> float:
        """How oversubscribed ``footprint_bytes`` is against this limit
        (1.0 when unlimited: everything fits by definition)."""
        if self.limit_bytes is None:
            return 1.0
        return footprint_bytes / self.limit_bytes

    # -- accounting ------------------------------------------------------
    def admit(self, nbytes: int) -> bool:
        """Charge ``nbytes`` if it fits; otherwise record the denial (and
        the bytes the caller will keep host-side) and charge nothing —
        the resident-set protocol of the KV store and expert pager."""
        nbytes = int(nbytes)
        with self._lock:
            if self.limit_bytes is not None and \
                    self.stats.charged_bytes + nbytes > self.limit_bytes:
                self.stats.denials += 1
                self.stats.spilled_bytes += nbytes
                return False
            self.stats.admitted += 1
            self._charge_locked(nbytes)
            return True

    def consult(self, nbytes: int) -> bool:
        """Would-it-fit without charging — the advisory form placement
        hints use (a placed region argument is per-call transient, not a
        resident-set member).  Denials and redirected bytes are still
        counted."""
        with self._lock:
            ok = self.limit_bytes is None or \
                self.stats.charged_bytes + int(nbytes) <= self.limit_bytes
            if ok:
                self.stats.admitted += 1
            else:
                self.stats.denials += 1
                self.stats.spilled_bytes += int(nbytes)
            return ok

    def charge(self, nbytes: int) -> bool:
        """Unconditionally account ``nbytes`` as device-resident.  Never
        raises — the unified-memory model degrades instead of OOMing; a
        charge landing over the limit records a pressure event and returns
        False so the caller's policy layer can shed bytes."""
        with self._lock:
            self._charge_locked(int(nbytes))
            if self.over:
                self.stats.pressure_events += 1
                return False
            return True

    def _charge_locked(self, nbytes: int) -> None:
        self.stats.charges += 1
        self.stats.charged_bytes += nbytes
        self.stats.high_water_bytes = max(self.stats.high_water_bytes,
                                          self.stats.charged_bytes)

    def release(self, nbytes: int) -> None:
        with self._lock:
            self.stats.releases += 1
            self.stats.charged_bytes = max(
                0, self.stats.charged_bytes - int(nbytes))

    # -- staging granularity --------------------------------------------
    def staging_chunk_bytes(self) -> Optional[int]:
        """Largest transient staging slab this budget tolerates: a quarter
        of the limit (floored at :data:`MIN_CHUNK_BYTES`), None when
        unlimited.  Bounding the in-flight granule is how a grid larger
        than device capacity streams through it — the managed-memory
        page-migration model with the page size set by the budget."""
        if self.limit_bytes is None:
            return None
        return max(MIN_CHUNK_BYTES, self.limit_bytes // CHUNK_FRACTION)

    def note_chunks(self, n: int) -> None:
        with self._lock:
            self.stats.staging_chunks += int(n)

    def as_dict(self) -> dict:
        return {"name": self.name, "limit_bytes": self.limit_bytes,
                "utilization": self.utilization(), **self.stats.as_dict()}


@dataclasses.dataclass
class BudgetedPlacer(Placer):
    """Placement axis that consults a :class:`MemoryBudget`: a
    ``MemSpace.DEVICE`` hint is honored only while the budget has
    headroom; leaves beyond it land in ``spill_space`` (host DRAM by
    default) instead.  Values never change — only residency — so any
    policy carrying this placer keeps the §2 parity contract under
    oversubscription."""
    budget: Optional[MemoryBudget] = None
    spill_space: Optional[MemSpace] = None

    def _place_tree(self, tree, space: MemSpace):
        if self.budget is None or space != MemSpace.DEVICE:
            return super()._place_tree(tree, space)
        return umem.tree_place_budgeted(
            tree, self.budget, min_bytes=self.min_bytes,
            spill_space=self.spill_space, charge=False)


def workload_bytes(tree) -> int:
    """Device footprint of a pytree — the numerator of the
    oversubscription ratio (`MemoryBudget.for_ratio(workload_bytes(x), r)`
    makes ``x`` an r-times-oversubscribed working set).  Plain (non-pytree)
    dataclasses like the CFD ``SimpleState`` are walked field-by-field."""
    import jax
    total = 0
    for x in jax.tree.leaves(tree):
        if dataclasses.is_dataclass(x) and not isinstance(x, type):
            total += workload_bytes(
                [getattr(x, f.name) for f in dataclasses.fields(x)])
        elif hasattr(x, "nbytes"):
            total += int(x.nbytes)
    return total
