"""Captured region programs: record one step, replay it many ways.

The paper's Fig 6 shows the managed-dGPU model paying a *staging storm*
between consecutive regions — every host<->device crossing is a real page
migration.  ``repro.core.regions`` reproduces that storm faithfully but
synchronously: each region stages in, computes, stages out, then the next
region starts.  Real discrete-GPU codes hide part of the storm by
overlapping migration with compute (prefetch/double-buffering) — the
mitigation both MI300A and Grace-Hopper unified-memory studies measure
against.  Expressing it needs one thing the per-call ``Executor`` cannot
have: *knowledge of what runs next*.

This module adds that knowledge as a captured program:

* :func:`capture` — run a step function once under a recording ``run``
  callable and record every region call plus the dataflow between calls
  (which output leaf feeds which later argument leaf).  Capture executes
  regions eagerly, so host-side control flow (solver convergence loops)
  proceeds normally — and, CUDA-graph style, is *frozen* into the trace:
  iteration counts and host-extracted scalars become program constants.

* :class:`RegionProgram` — the trace: ops, input slots, constants, output
  spec.  ``replay(executor, *inputs)`` re-issues the calls through any
  ``Executor`` (synchronous, any policy); ``replay_batch`` vmaps the whole
  program over stacked inputs — N independent cavity solves or decode
  requests through one compiled composite (the "heavy traffic" path).

* :class:`AsyncExecutor` — replays a program under any
  ``ExecutionPolicy`` with ONE-STEP LOOKAHEAD: while region *k* computes,
  a staging thread migrates region *k+1*'s already-available operands
  through a second pooled buffer bank
  (:class:`~repro.core.pool.BufferRotation`).  Staging seconds that run
  concurrently with compute are accounted as ``overlap_s`` on the region's
  ledger row and surface as ``overlap_fraction`` / ``staging_saved_s`` in
  ``Ledger.coverage_report()``.  Results are numerically identical to the
  synchronous ``Executor`` on the same program: the same executables run on
  the same staged copies — only the *schedule* of the copies changes.

Capture semantics (what is and is not recorded):

- array leaves returned by a region and passed to a later region become
  dataflow edges; replay recomputes them,
- array leaves of the example inputs become program input slots; replay
  substitutes fresh values positionally,
- everything else — Python scalars, ``float()``-extracted reductions,
  arrays computed *outside* any region — is captured as a constant.  Keep
  cross-region math inside regions if replays must react to new inputs.

Implementation variants: capture always executes the region's base (ref)
function, and the trace stores the *Region*, never a compiled callable —
so every replay re-resolves each op's variant through the executing
policy's :class:`~repro.core.regions.Selector` (``declare variant``
dispatch).  One captured cavity step replays under ``StaticSelector("ref")``,
``StaticSelector("pallas")``, or a calibrated ``AutotuneSelector`` without
re-capturing (see docs/VARIANTS.md).
"""
from __future__ import annotations

import dataclasses
import time
import weakref
from concurrent.futures import ThreadPoolExecutor
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import numpy as np

from repro.core import umem
from repro.core.ledger import Ledger
from repro.core.pool import BufferRotation
from repro.core.regions import (Executor, ExecutionPolicy, Region, as_region,
                                policy_selector)


def _is_array(x) -> bool:
    return isinstance(x, (jax.Array, np.ndarray))


# ---------------------------------------------------------------------------
# Leaf descriptors: where does each argument leaf of a call come from?
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Ref:
    """Output leaf ``leaf`` of a previous op ``op``."""
    op: int
    leaf: int


@dataclasses.dataclass(frozen=True)
class In:
    """Leaf ``slot`` of the program's flattened inputs."""
    slot: int


class Lit:
    """A captured constant (host scalar, frozen control-flow value, or an
    array computed outside any region)."""
    __slots__ = ("value",)

    def __init__(self, value):
        self.value = value

    def __repr__(self):  # pragma: no cover - debugging aid
        return f"Lit({type(self.value).__name__})"


@dataclasses.dataclass
class OpCall:
    """One recorded region call."""
    region: Region
    in_tree: Any                 # treedef of (args, kwargs)
    leaves: List[Any]            # Ref | In | Lit per argument leaf
    arg_keys: List[Any]          # per-leaf top-level arg index / kwarg name
    example_size: int            # size_fn at capture (routing prediction)
    n_out: int = 0
    out_tree: Any = None
    #: per-output-leaf (shape, dtype, nbytes) recorded at capture — what
    #: the static verifier (repro.analysis) sizes Ref edges with; None
    #: per non-array leaf (or entirely, for pre-analysis pickles)
    out_meta: Any = None


def _resolver(env: List[List[Any]], in_leaves: List[Any]) -> Callable:
    """The one Ref/In/Lit resolution rule, shared by every replay path."""
    def resolve(d):
        if isinstance(d, Ref):
            return env[d.op][d.leaf]
        if isinstance(d, In):
            return in_leaves[d.slot]
        return d.value
    return resolve


def _flatten_call(args, kwargs) -> Tuple[List[Any], List[Any], Any]:
    """Flatten (args, kwargs) keeping, per leaf, the top-level positional
    index or keyword name it belongs to (placement hints are keyed on it).
    Leaf order matches ``jax.tree.flatten((args, kwargs))`` — tuples in
    order, dict keys sorted."""
    leaves, keys = [], []
    for idx, a in enumerate(args):
        ls = jax.tree.leaves(a)
        leaves += ls
        keys += [idx] * len(ls)
    for kname in sorted(kwargs):
        ls = jax.tree.leaves(kwargs[kname])
        leaves += ls
        keys += [kname] * len(ls)
    return leaves, keys, jax.tree.structure((args, kwargs))


# ---------------------------------------------------------------------------
# RegionProgram
# ---------------------------------------------------------------------------

class RegionProgram:
    """A recorded trace of region calls with explicit dataflow."""

    def __init__(self, name: str = "program"):
        self.name = name
        self.ops: List[OpCall] = []
        self.in_tree = None
        self.n_inputs = 0
        self.out_tree = None
        self.out_leaves: List[Any] = []
        self._example_in_leaves: List[Any] = []
        self._batched: Dict[str, Callable] = {}        # in_axes repr -> jit
        self._batch_rows = weakref.WeakKeyDictionary()  # ledger -> row name

    # -- introspection ---------------------------------------------------
    def __len__(self) -> int:
        return len(self.ops)

    @property
    def n_constants(self) -> int:
        return sum(1 for op in self.ops for d in op.leaves
                   if isinstance(d, Lit))

    def summary(self) -> str:
        edges = sum(1 for op in self.ops for d in op.leaves
                    if isinstance(d, Ref))
        return (f"RegionProgram({self.name!r}: {len(self.ops)} ops, "
                f"{self.n_inputs} input leaves, {edges} dataflow edges, "
                f"{self.n_constants} constants)")

    def verify(self, policy=None, *, budget=None, ledger=None):
        """Statically verify this trace (:mod:`repro.analysis`): donation
        liveness, dead results, placement churn, halo declarations,
        variant contracts, and — when ``policy``/``budget`` carries a
        :class:`~repro.core.oversub.MemoryBudget` — the peak-resident
        watermark.  Returns an
        :class:`~repro.analysis.report.AnalysisReport`; callers gate on
        ``.errors`` / ``.raise_if_errors()``."""
        from repro.analysis import verify_program
        return verify_program(self, policy, budget=budget, ledger=ledger)

    # -- replay ----------------------------------------------------------
    def _input_leaves(self, inputs: tuple) -> List[Any]:
        if not inputs:
            return self._example_in_leaves
        leaves, tree = jax.tree.flatten(inputs)
        if tree != self.in_tree:
            raise ValueError(
                f"replay inputs structure {tree} != captured {self.in_tree}")
        return leaves

    def replay(self, executor, *inputs):
        """Re-issue the trace through an executor.  ``executor`` may be a
        synchronous :class:`~repro.core.regions.Executor` (any policy) or an
        :class:`AsyncExecutor` (same results, overlapped staging)."""
        if hasattr(executor, "replay_program"):
            return executor.replay_program(self, *inputs)
        return self._replay_sequential(executor.run, inputs)

    def _replay_sequential(self, run: Callable, inputs: tuple):
        in_leaves = self._input_leaves(inputs)
        env: List[List[Any]] = []
        resolve = _resolver(env, in_leaves)
        for op in self.ops:
            args, kwargs = jax.tree.unflatten(
                op.in_tree, [resolve(d) for d in op.leaves])
            out = run(op.region, *args, **kwargs)
            env.append(jax.tree.leaves(out))
        return jax.tree.unflatten(self.out_tree,
                                  [resolve(d) for d in self.out_leaves])

    # -- batched replay --------------------------------------------------
    def _op_impls(self, selector=None) -> Tuple[str, ...]:
        """Resolve one variant name per op under ``selector`` (None: the
        base ``ref`` everywhere).  Fused replay has no routing step, so
        selection sees the ``default`` target and the captured example
        size — the same prediction the async lookahead uses."""
        if selector is None:
            return tuple("ref" for _ in self.ops)
        return tuple(
            op.region.resolve(selector.select(op.region, "default", (), {},
                                              size=op.example_size))
            for op in self.ops)

    def as_fn(self, selector=None) -> Callable:
        """The program as one pure function of its inputs (region fns
        composed by the recorded dataflow; constants closed over).  This is
        what ``replay_batch`` vmaps — no executor, no staging: the fused
        beyond-paper path.  ``selector`` (a
        :class:`~repro.core.regions.Selector`) swaps each op's
        implementation variant into the composite."""
        impls = self._op_impls(selector)
        fns = [op.region.impl_fn(impl)
               for op, impl in zip(self.ops, impls)]

        def fn(*inputs):
            in_leaves = self._input_leaves(inputs)
            env: List[List[Any]] = []
            resolve = _resolver(env, in_leaves)
            for op, f in zip(self.ops, fns):
                args, kwargs = jax.tree.unflatten(
                    op.in_tree, [resolve(d) for d in op.leaves])
                env.append(jax.tree.leaves(f(*args, **kwargs)))
            return jax.tree.unflatten(self.out_tree,
                                      [resolve(d) for d in self.out_leaves])
        return fn

    def replay_batch(self, *stacked_inputs, executor=None, in_axes=0,
                     selector=None):
        """Replay N independent instances through one vmapped composite.

        ``stacked_inputs`` mirror the captured input structure with a
        leading batch axis on every array leaf (``in_axes`` as in
        ``jax.vmap``).  Captured constants broadcast.  The batch is
        accounted as one ledger row ``<name>[batch]`` on the executor's
        ledger (when given).  ``selector`` picks each op's implementation
        variant (distinct selections compile separately)."""
        impls = self._op_impls(selector)
        key = (repr(in_axes), impls)  # distinct axes/variant mixes compile
        batched = self._batched.get(key)
        if batched is None:
            batched = self._batched[key] = jax.jit(
                jax.vmap(self.as_fn(selector), in_axes=in_axes))
        t0 = time.perf_counter()
        out = batched(*stacked_inputs)
        jax.block_until_ready(out)
        dt = time.perf_counter() - t0
        if executor is not None:
            sizes = [int(a.size) for a in jax.tree.leaves(stacked_inputs)
                     if hasattr(a, "size")]
            executor.ledger.record(
                self._batch_row(executor.ledger), device=True, offloaded=True,
                compute_s=dt, elems=max(sizes, default=0))
        return out

    def _batch_row(self, ledger: Ledger) -> str:
        """Ledger row for this program's batched replays — weak-keyed by
        ledger object (not id()) so a recycled address can never resurrect
        a stale row name."""
        name = self._batch_rows.get(ledger)
        if name is None:
            name = self._batch_rows[ledger] = ledger.register(
                f"{self.name}[batch]", True)
        return name


def capture(fn: Callable, *example_inputs, name: str = "program",
            verify: Any = None) -> RegionProgram:
    """Record ``fn(run, *example_inputs)`` into a :class:`RegionProgram`.

    ``fn`` receives a recording ``run(region, *args, **kwargs)`` callable in
    place of ``Executor.run``; every call is executed eagerly (so Python
    control flow sees concrete values) and recorded with its dataflow.

    ``verify`` runs the static verifier (:mod:`repro.analysis`) on the
    fresh trace before returning it: pass an ``ExecutionPolicy`` to lint
    under it, or ``True`` for the policy-independent rules only.
    Error-severity findings raise
    :class:`~repro.analysis.report.ProgramVerificationError`.
    """
    prog = RegionProgram(name)
    in_leaves, prog.in_tree = jax.tree.flatten(example_inputs)
    prog.n_inputs = len(in_leaves)
    prog._example_in_leaves = in_leaves
    # id -> descriptor for every live array leaf we know the origin of;
    # keepalive pins them so ids stay unique for the capture's duration
    origin: Dict[int, Any] = {}
    keepalive: List[Any] = []
    for i, leaf in enumerate(in_leaves):
        if _is_array(leaf):
            origin[id(leaf)] = In(i)
            keepalive.append(leaf)

    def run(target_region, *args, **kwargs):
        r = as_region(target_region)
        leaves, keys, tree = _flatten_call(args, kwargs)
        desc = [origin.get(id(x), None) if _is_array(x) else Lit(x)
                for x in leaves]
        desc = [d if d is not None else Lit(x)
                for d, x in zip(desc, leaves)]
        op = OpCall(r, tree, desc, keys, r.size_fn(args, kwargs))
        out = r.jitted(*args, **kwargs)         # eager: drives control flow
        out_leaves = jax.tree.leaves(out)
        op.out_tree = jax.tree.structure(out)
        op.n_out = len(out_leaves)
        op.out_meta = [
            (tuple(ol.shape), str(ol.dtype), int(ol.nbytes))
            if _is_array(ol) else None for ol in out_leaves]
        k = len(prog.ops)
        for j, ol in enumerate(out_leaves):
            if _is_array(ol):
                origin[id(ol)] = Ref(k, j)
                keepalive.append(ol)
        prog.ops.append(op)
        return out

    result = fn(run, *example_inputs)
    res_leaves, prog.out_tree = jax.tree.flatten(result)
    prog.out_leaves = [origin.get(id(x), Lit(x)) if _is_array(x) else Lit(x)
                       for x in res_leaves]
    del keepalive
    if verify:
        prog.verify(None if verify is True else verify).raise_if_errors()
    return prog


# ---------------------------------------------------------------------------
# AsyncExecutor: one-step lookahead staging
# ---------------------------------------------------------------------------

def _leaf_space(region: Region, key) -> Optional[umem.MemSpace]:
    """The MemSpace hint (if any) governing the top-level arg/kwarg ``key``
    — per-leaf mirror of ``Placer.place_args``."""
    spaces = region.arg_spaces
    if not spaces:
        return None
    sp = spaces.get(key)
    if sp is None and isinstance(key, int):
        for pname, idx in region._param_index.items():
            if idx == key and pname in spaces:
                return spaces[pname]
    return sp


@dataclasses.dataclass
class _Prefetch:
    """Result of a background staging task for one upcoming op."""
    staged: Dict[int, Any]       # leaf index -> staged device leaf
    seconds: float
    nbytes: int
    t0: float
    t1: float


def interval_overlap(t0: float, t1: float, spans) -> float:
    """Seconds of the wall interval ``[t0, t1]`` covered by the (disjoint)
    compute intervals ``spans`` — the shared overlap accounting of the
    async lookahead replay (staging hidden behind compute) and the sharded
    overlapped replay (halo exchange hidden behind compute,
    :mod:`repro.core.shard_program`)."""
    return sum(max(0.0, min(t1, b1) - max(t0, b0)) for b0, b1 in spans)


class AsyncExecutor:
    """Replays :class:`RegionProgram`\\ s under one policy with one-step
    staging lookahead (double-buffered through a
    :class:`~repro.core.pool.BufferRotation`).

    While op *k* computes, a single staging thread migrates op *k+1*'s
    already-available operand leaves (program inputs, constants, outputs of
    ops < *k*) into the next pooled buffer bank.  Leaves produced by op *k*
    itself cannot be prefetched and are staged synchronously at issue time.
    The overlap between the prefetch interval and op *k*'s compute interval
    is recorded as ``overlap_s`` on op *k+1*'s ledger row.

    ``run`` delegates to a synchronous inner ``Executor`` so an
    AsyncExecutor can stand anywhere an Executor does; the lookahead only
    engages on whole programs via ``replay`` / ``replay_program``.
    """

    def __init__(self, policy: ExecutionPolicy, ledger: Optional[Ledger] = None,
                 lookahead_depth: int = 2):
        self.policy = policy
        self.ledger = ledger or Ledger(policy.name + "+async")
        self.mode = policy.name + "+async"
        self.lookahead_depth = lookahead_depth
        self._inner = Executor(policy, self.ledger)

    # -- Executor protocol ----------------------------------------------
    def run(self, target_region, *args, **kwargs):
        return self._inner.run(target_region, *args, **kwargs)

    def report(self) -> dict:
        rep = self.ledger.coverage_report()
        rep["mode"] = self.mode
        return rep

    # -- program replay --------------------------------------------------
    def replay_program(self, prog: RegionProgram, *inputs):
        pol = self.policy
        stager = pol.stager
        if not getattr(stager, "stages", False) or \
                not hasattr(stager, "stage_leaves"):
            # nothing to overlap (APU/host model): plain sequential replay
            return prog._replay_sequential(self._inner.run, inputs)
        return self._replay_overlapped(prog, inputs)

    def _replay_overlapped(self, prog: RegionProgram, inputs: tuple):
        pol = self.policy
        stager = pol.stager
        selector = policy_selector(pol)
        in_leaves = prog._input_leaves(inputs)
        env: List[List[Any]] = []
        rotation = BufferRotation(pool=stager.device_pool,
                                  depth=self.lookahead_depth)
        resolve = _resolver(env, in_leaves)

        def will_stage(op: OpCall) -> bool:
            """Predict whether op will stage (routing from the captured
            example size; a wrong prediction only wastes one prefetch)."""
            tgt = pol.router.target(op.region, (), {}, size=op.example_size)
            return op.region.offloaded and tgt != "host"

        def placed(op: OpCall, i: int, leaf):
            sp = _leaf_space(op.region, op.arg_keys[i])
            if sp is not None and pol.placer.honor_hints:
                return umem.tree_place(leaf, sp,
                                       min_bytes=pol.placer.min_bytes)
            return leaf

        def prefetch_task(op: OpCall, ready: List[Tuple[int, Any]],
                          bank_handle):
            # the generation-tagged handle keeps a task that outlives this
            # replay from parking buffers in a successor's banks
            t0 = time.perf_counter()
            staged, s, b = stager.stage_leaves(
                [placed(op, i, leaf) for i, leaf in ready], bank_handle)
            return _Prefetch({i: y for (i, _), y in zip(ready, staged)},
                             s, b, t0, time.perf_counter())

        pending: Optional[Tuple[int, Any]] = None      # (op index, future)
        prev_compute: Tuple[float, float] = (0.0, 0.0)
        with ThreadPoolExecutor(max_workers=1) as tp:
            for k, op in enumerate(prog.ops):
                r = op.region
                raw = [resolve(d) for d in op.leaves]
                args, kwargs = jax.tree.unflatten(op.in_tree, raw)
                n = r.size_fn(args, kwargs)
                tgt = pol.router.target(r, args, kwargs, size=n)
                # captured rows carry the REGION, not a compiled callable:
                # every replay re-resolves the variant, so one trace runs
                # under any selector (resolve(): unknown names -> ref)
                impl = r.resolve(
                    selector.select(r, tgt, args, kwargs, size=n))
                stage = stager.stages and r.offloaded and tgt != "host"
                staging_s, staging_b, overlap_s = 0.0, 0, 0.0
                pf: Optional[_Prefetch] = None
                if pending is not None and pending[0] == k:
                    pf = pending[1].result()
                    pending = None
                if stage:
                    staged_map = dict(pf.staged) if pf else {}
                    if pf:
                        staging_s += pf.seconds
                        staging_b += pf.nbytes
                        overlap_s = interval_overlap(pf.t0, pf.t1,
                                                     (prev_compute,))
                    todo = [(i, leaf) for i, leaf in enumerate(raw)
                            if _is_array(leaf) and i not in staged_map]
                    if todo:
                        staged, s, b = stager.stage_leaves(
                            [placed(op, i, leaf) for i, leaf in todo],
                            rotation)
                        staging_s += s
                        staging_b += b
                        staged_map.update(
                            {i: y for (i, _), y in zip(todo, staged)})
                    staged_leaves = [staged_map.get(i, leaf)
                                     for i, leaf in enumerate(raw)]
                    args, kwargs = jax.tree.unflatten(op.in_tree,
                                                      staged_leaves)
                else:
                    # not staging (host target / no directive): mirror the
                    # sync Executor's placement; a mispredicted prefetch is
                    # simply dropped (its copies are value-equal and its
                    # bank drains at the end)
                    args, kwargs = pol.placer.place_args(r, args, kwargs)
                t0 = time.perf_counter()
                # staging policy: non-donating executables only (staged
                # operands may alias pooled pages the stager still owns)
                out = r.executable(tgt, impl, donate=False)(*args, **kwargs)
                # submit the NEXT op's prefetch before blocking on this
                # compute — this ordering is the entire overlap
                if k + 1 < len(prog.ops):
                    nxt = prog.ops[k + 1]
                    if will_stage(nxt):
                        ready = []
                        for i, d in enumerate(nxt.leaves):
                            if isinstance(d, Ref) and d.op >= k:
                                continue        # depends on op k: not ready
                            x = resolve(d)
                            if _is_array(x):
                                ready.append((i, x))
                        if ready:
                            rotation.advance()
                            pending = (k + 1,
                                       tp.submit(prefetch_task, nxt, ready,
                                                 rotation.handle()))
                jax.block_until_ready(out)
                t1 = time.perf_counter()
                prev_compute = (t0, t1)
                if stage:
                    out, s, b = stager.stage_out(r, out, None)
                    staging_s += s
                    staging_b += b
                    rotation.retire()       # this op's staged inputs are dead
                out = pol.placer.place_result(r, out)
                device = r.offloaded if tgt == "default" else (tgt == "device")
                self.ledger.record(self._inner._row_name(r), device=device,
                                   offloaded=r.offloaded, compute_s=t1 - t0,
                                   staging_s=staging_s,
                                   staging_bytes=staging_b, elems=n,
                                   overlap_s=overlap_s, impl=impl)
                env.append(jax.tree.leaves(out))
        rotation.drain()
        return jax.tree.unflatten(prog.out_tree,
                                  [resolve(d) for d in prog.out_leaves])
