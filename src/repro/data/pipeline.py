"""Token data pipeline: deterministic, shardable, restart-exact.

Two sources behind one interface:
* ``SyntheticTokens`` — seeded per (step, host-shard); infinite; used by
  examples and tests.
* ``MemmapTokens``    — flat binary token file (np.memmap), strided across
  hosts; the production path.

Determinism contract (fault tolerance): ``batch_at(step)`` is a pure
function of (seed, step, shard), so restoring a checkpoint at step k
reproduces the exact token stream — restart-equivalence is tested in
``tests/test_fault.py``. Host staging goes through the paper's
``HostStagingPool`` (C4): batch buffers are pooled, not re-allocated.
"""
from __future__ import annotations

import dataclasses
from typing import Iterator, Optional

import numpy as np

from repro.core.pool import HostStagingPool, GLOBAL_STAGING_POOL


@dataclasses.dataclass
class ShardInfo:
    shard: int = 0
    n_shards: int = 1


class TokenSource:
    vocab: int

    def batch_at(self, step: int, batch: int, seq: int) -> np.ndarray:
        raise NotImplementedError

    def stream(self, start_step: int, batch: int, seq: int) -> Iterator:
        step = start_step
        while True:
            yield step, self.batch_at(step, batch, seq)
            step += 1


class SyntheticTokens(TokenSource):
    """Markov-ish synthetic tokens: learnable structure (bigram skeleton) so
    smoke-training shows decreasing loss, fully seeded."""

    def __init__(self, vocab: int, seed: int = 0, shard: ShardInfo = ShardInfo(),
                 pool: Optional[HostStagingPool] = None):
        self.vocab = vocab
        self.seed = seed
        self.shard = shard
        self.pool = pool or GLOBAL_STAGING_POOL
        rng = np.random.RandomState(seed)
        self._succ = rng.randint(0, vocab, size=(min(vocab, 4096),))

    def batch_at(self, step: int, batch: int, seq: int) -> np.ndarray:
        rng = np.random.RandomState(
            (self.seed * 1_000_003 + step) * 97 + self.shard.shard)
        out = self.pool.acquire((batch, seq), np.int32)
        start = rng.randint(0, min(self.vocab, 4096), size=(batch,))
        noise = rng.rand(batch, seq)
        toks = np.empty((batch, seq), np.int64)
        toks[:, 0] = start
        for t in range(1, seq):
            follow = self._succ[toks[:, t - 1] % len(self._succ)]
            rand = rng.randint(0, self.vocab, size=(batch,))
            toks[:, t] = np.where(noise[:, t] < 0.8, follow, rand)
        out[...] = toks.astype(np.int32)
        return out

    def release(self, batch: np.ndarray) -> None:
        self.pool.release(batch)


class MemmapTokens(TokenSource):
    """Flat int32 token file; host h reads blocks h, h+n_shards, ..."""

    def __init__(self, path: str, vocab: int, shard: ShardInfo = ShardInfo(),
                 pool: Optional[HostStagingPool] = None):
        self.tokens = np.memmap(path, dtype=np.int32, mode="r")
        self.vocab = vocab
        self.shard = shard
        self.pool = pool or GLOBAL_STAGING_POOL

    def batch_at(self, step: int, batch: int, seq: int) -> np.ndarray:
        n = len(self.tokens)
        block = batch * seq
        base = (step * self.shard.n_shards + self.shard.shard) * block
        out = self.pool.acquire((batch, seq), np.int32)
        idx = (base + np.arange(block)) % (n - 1)
        out[...] = self.tokens[idx].reshape(batch, seq)
        return out

    def release(self, batch: np.ndarray) -> None:
        self.pool.release(batch)


def make_source(kind: str, vocab: int, *, path: str = "", seed: int = 0,
                shard: ShardInfo = ShardInfo()) -> TokenSource:
    if kind == "synthetic":
        return SyntheticTokens(vocab, seed=seed, shard=shard)
    if kind == "memmap":
        return MemmapTokens(path, vocab, shard=shard)
    raise ValueError(kind)
