"""Tunable workloads: each wraps one shipped captured program.

A :class:`Workload` gives the tuner everything it needs for one search:
the captured :class:`~repro.core.program.RegionProgram` (cost-model
input), a ``run(candidate, steps)`` measurement that replays it under
the candidate's policy and returns parity leaves + a FOM + per-region
measured seconds (residual calibration), the hand-assembled reference
candidate the winner must beat, and the workload-shape ``size`` that
keys the profile bucket.

The four registered workloads mirror the ``fig_tune`` benchmark:

* ``cfd_step`` — the captured SIMPLE step (smoke grid); ref is the
  managed-dGPU ``discrete`` baseline (paper Figs 5/6).
* ``serve_decode`` — the serve DECODE_STEP+KV_APPEND program at the
  analysis-corpus smoke shape; ref ``discrete``.
* ``train_step`` — the FWD_BWD+ADAMW_UPDATE step; ref ``discrete``.
* ``cfd_sharded`` — the SIMPLE step decomposed over simulated APUs via
  a ``repro.launch.scaling`` subprocess (the APU count must be in
  XLA_FLAGS before jax imports); ref is the sequential 1-D slab
  schedule (the PR-3 baseline).

Contexts are built once per process (capture is the expensive part) and
cached, the same trick as ``repro.analysis.programs``.
"""
from __future__ import annotations

import dataclasses
import functools
import json
import os
import subprocess
import sys
import tempfile
import time
from pathlib import Path
from typing import Any, Callable, Dict, List, Optional

import numpy as np

from repro.core.ledger import Ledger
from repro.core.regions import Executor, Placer, UnifiedPolicy
from repro.tune.space import PolicyCandidate, cfd_size, serve_size, train_size

#: serve/train smoke shapes (mirror repro.analysis.programs)
BATCH, PROMPT, GEN = 2, 8, 4
MAX_LEN = PROMPT + GEN

#: CFD smoke shapes
CFD_GRID = (12, 12, 12)
CFD_INNER = 6
SHARD_GRID = (8, 8, 8)
SHARD_INNER = 4

#: simulated APU count the sharded workload decomposes over
SHARD_APUS = int(os.environ.get("REPRO_TUNE_APUS", "4"))

#: placement hints skip leaves below this (mirrors launch.policy)
_PLACER_MIN_BYTES = 4096


@dataclasses.dataclass
class RunResult:
    """One measured replay: parity leaves, FOM, per-region seconds."""
    leaves: List[np.ndarray]
    fom_s: float
    region_s: Dict[str, float]
    replays: int = 1                 # program replays the window covered
    extra: dict = dataclasses.field(default_factory=dict)


@dataclasses.dataclass
class Workload:
    """One tunable workload (see module docstring)."""
    name: str
    kind: str                        # "replay" | "sharded"
    size: int                        # bucket key (see space.*_size)
    memory: Any                      # MemoryPolicy for cutoff defaults
    build_program: Callable[[], Any]
    run: Callable[..., RunResult]    # (candidate, steps, winners=) -> RunResult
    ref: PolicyCandidate
    steps: int = 2                   # default measured replays
    meta: dict = dataclasses.field(default_factory=dict)


def _executor(candidate: PolicyCandidate, memory, winners, name: str):
    """Executor (or AsyncExecutor, for async-staging candidates) running
    the candidate's concrete policy."""
    from repro.core.program import AsyncExecutor
    pol = candidate.build_policy(memory, winners=winners,
                                 placer=Placer(min_bytes=_PLACER_MIN_BYTES))
    cls = AsyncExecutor if candidate.staging == "async" else Executor
    return cls(pol, Ledger(name))


def _region_seconds(ledger: Ledger) -> Dict[str, float]:
    return {name: row.compute_s for name, row in ledger.regions.items()
            if row.compute_s > 0}


# ---------------------------------------------------------------------------
# cfd_step
# ---------------------------------------------------------------------------

@functools.lru_cache(maxsize=None)
def _cfd_ctx():
    from repro.cfd.grid import Grid
    from repro.cfd.simple import SimpleConfig, SimpleFoam, init_state
    cfg = SimpleConfig(grid=Grid(CFD_GRID), nu=0.1, inner_max=CFD_INNER)
    app = SimpleFoam(cfg)
    st = init_state(cfg)
    st, _, _ = app.run_steps(st, 1)          # develop flow + warm caches
    return app, st, app.capture_step(st)


def _run_cfd(candidate: PolicyCandidate, steps: int,
             winners=None) -> RunResult:
    app, st, prog = _cfd_ctx()
    ex = _executor(candidate, None, winners, f"tune_cfd_{candidate.label}")
    app.replay_steps(prog, st, 1, ex)        # warm per-target compiles
    ex.ledger.reset_timings()
    s, fom = app.replay_steps(prog, st, steps, ex)
    leaves = [np.asarray(f) for f in (s.u, s.v, s.w, s.p)]
    return RunResult(leaves, fom, _region_seconds(ex.ledger), replays=steps)


# ---------------------------------------------------------------------------
# serve_decode
# ---------------------------------------------------------------------------

@functools.lru_cache(maxsize=None)
def _serve_ctx():
    import jax
    import jax.numpy as jnp

    from repro.configs.reduced import reduced as make_reduced
    from repro.configs.registry import get_config
    from repro.launch import serve as SV
    from repro.launch.mesh import make_smoke_mesh
    from repro.models import transformer as T

    cfg = make_reduced(get_config("tinyllama-1.1b"))
    mesh = make_smoke_mesh()
    key = jax.random.PRNGKey(0)
    params = T.init(key, cfg)
    prompts = jax.random.randint(key, (BATCH, PROMPT), 0, cfg.vocab,
                                 jnp.int32)
    batch_in = {"tokens": prompts}
    regions = SV.make_serve_regions(cfg, mesh, params,
                                    ledger=Ledger("tune_serve"))
    prefill_prog = SV.capture_prefill_program(
        regions, batch_in, T.init_cache(cfg, BATCH, MAX_LEN))
    warm = Executor(UnifiedPolicy(), Ledger("tune_serve_warm"))
    tok, cache = prefill_prog.replay(warm, batch_in,
                                     T.init_cache(cfg, BATCH, MAX_LEN))
    decode_prog = SV.capture_decode_program(regions, PROMPT, GEN, tok, cache)
    return cfg, batch_in, prefill_prog, decode_prog


def _run_serve(candidate: PolicyCandidate, steps: int,
               winners=None) -> RunResult:
    import jax.numpy as jnp

    from repro.models import transformer as T
    cfg, batch_in, prefill_prog, decode_prog = _serve_ctx()
    warm = Executor(UnifiedPolicy(), Ledger("tune_serve_prefill"))
    tok, cache = prefill_prog.replay(warm, batch_in,
                                     T.init_cache(cfg, BATCH, MAX_LEN))
    ex = _executor(candidate, cfg.memory, winners,
                   f"tune_serve_{candidate.label}")
    decode_prog.replay(ex, tok, cache)       # warm per-target compiles
    ex.ledger.reset_timings()
    t0 = time.perf_counter()
    for _ in range(steps):
        toks = decode_prog.replay(ex, tok, cache)
    fom = (time.perf_counter() - t0) / (steps * max(GEN - 1, 1))
    leaves = [np.asarray(jnp.stack(toks, axis=1))]
    return RunResult(leaves, fom, _region_seconds(ex.ledger), replays=steps)


# ---------------------------------------------------------------------------
# train_step
# ---------------------------------------------------------------------------

TRAIN_BATCH, TRAIN_SEQ = 2, 16


@functools.lru_cache(maxsize=None)
def _train_ctx():
    import jax
    import jax.numpy as jnp

    from repro.configs.reduced import reduced as make_reduced
    from repro.configs.registry import get_config
    from repro.models import transformer as T
    from repro.optim import adamw
    from repro.train import step as S

    cfg = make_reduced(get_config("tinyllama-1.1b"))
    opt_cfg = adamw.AdamWConfig(lr=1e-3)
    key = jax.random.PRNGKey(1)
    params = T.init(key, cfg)
    opt = adamw.init_state(params, opt_cfg)
    batch = {"tokens": jax.random.randint(key, (TRAIN_BATCH, TRAIN_SEQ), 0,
                                          cfg.vocab, jnp.int32)}
    regions = S.make_train_regions(cfg, opt_cfg, ledger=Ledger("tune_train"))
    prog = S.capture_train_program(regions, (params, opt), batch)
    return cfg, (params, opt), batch, prog


def _run_train(candidate: PolicyCandidate, steps: int,
               winners=None) -> RunResult:
    import jax
    cfg, state0, batch, prog = _train_ctx()
    ex = _executor(candidate, cfg.memory, winners,
                   f"tune_train_{candidate.label}")
    prog.replay(ex, state0, batch)           # warm per-target compiles
    ex.ledger.reset_timings()
    state, metrics = state0, {}
    t0 = time.perf_counter()
    for _ in range(steps):
        state, metrics = prog.replay(ex, state, batch)
    fom = (time.perf_counter() - t0) / steps
    leaves = [np.asarray(metrics["loss"]), np.asarray(metrics["grad_norm"])]
    leaves += [np.asarray(x) for x in jax.tree.leaves(state)[:2]]
    return RunResult(leaves, fom, _region_seconds(ex.ledger), replays=steps)


# ---------------------------------------------------------------------------
# cfd_sharded (subprocess — the APU count must precede the jax import)
# ---------------------------------------------------------------------------

@functools.lru_cache(maxsize=None)
def _sharded_prog():
    from repro.cfd.grid import Grid
    from repro.cfd.simple import SimpleConfig, SimpleFoam, init_state
    cfg = SimpleConfig(grid=Grid(SHARD_GRID), nu=0.1, inner_max=SHARD_INNER)
    app = SimpleFoam(cfg)
    st = init_state(cfg)
    st, _, _ = app.run_steps(st, 1)
    return app.capture_step(st)


def _run_sharded(candidate: PolicyCandidate, steps: int,
                 winners=None) -> RunResult:
    mesh = candidate.mesh or (SHARD_APUS,)
    apus = 1
    for s in mesh:
        apus *= s
    with tempfile.TemporaryDirectory() as td:
        out = Path(td) / "run.json"
        cmd = [sys.executable, "-m", "repro.launch.scaling",
               "--apus", str(apus),
               "--mesh", "x".join(str(s) for s in mesh),
               "--steps", str(steps),
               "--grid", ",".join(str(g) for g in SHARD_GRID),
               "--policy", candidate.placement,
               "--schedule", candidate.schedule,
               "--halo-multiplier", str(candidate.halo_multiplier),
               "--inner-max", str(SHARD_INNER), "--out", str(out)]
        r = subprocess.run(cmd, capture_output=True, text=True)
        if r.returncode != 0:
            raise RuntimeError(
                f"sharded measurement failed for {candidate.label}:\n"
                f"{r.stderr[-2000:]}")
        rec = json.loads(out.read_text())
    if not rec["parity_ok"]:                 # DESIGN §2, asserted in-run too
        raise AssertionError(f"{candidate.label}: sharded replay lost "
                             f"parity: {rec['parity_max_abs_err']:.2e}")
    extra = {k: rec[k] for k in ("exchange_fraction", "exchange_s",
                                 "overlap_s", "mesh_shape", "schedule")}
    return RunResult([], rec["fom_sharded_s"], {}, replays=steps,
                     extra=extra)


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------

def _serve_workload() -> Workload:
    # reduced tinyllama d_model = 64 at the corpus smoke shape; build the
    # size without importing jax-heavy context (the driver-side formula)
    from repro.configs.reduced import reduced as make_reduced
    from repro.configs.registry import get_config
    cfg = make_reduced(get_config("tinyllama-1.1b"))
    return Workload(
        name="serve_decode", kind="replay",
        size=serve_size(BATCH, MAX_LEN, cfg.d_model), memory=cfg.memory,
        build_program=lambda: _serve_ctx()[3], run=_run_serve,
        ref=PolicyCandidate(placement="discrete"), steps=2,
        meta={"batch": BATCH, "prompt": PROMPT, "gen": GEN})


def _train_workload() -> Workload:
    from repro.configs.reduced import reduced as make_reduced
    from repro.configs.registry import get_config
    cfg = make_reduced(get_config("tinyllama-1.1b"))
    return Workload(
        name="train_step", kind="replay",
        size=train_size(TRAIN_BATCH, TRAIN_SEQ, cfg.d_model),
        memory=cfg.memory,
        build_program=lambda: _train_ctx()[3], run=_run_train,
        ref=PolicyCandidate(placement="discrete"), steps=2,
        meta={"batch": TRAIN_BATCH, "seq": TRAIN_SEQ})


def _cfd_workload() -> Workload:
    return Workload(
        name="cfd_step", kind="replay", size=cfd_size(CFD_GRID), memory=None,
        build_program=lambda: _cfd_ctx()[2], run=_run_cfd,
        ref=PolicyCandidate(placement="discrete"), steps=2,
        meta={"grid": CFD_GRID})


def _sharded_workload() -> Workload:
    return Workload(
        name="cfd_sharded", kind="sharded", size=cfd_size(SHARD_GRID),
        memory=None, build_program=_sharded_prog, run=_run_sharded,
        ref=PolicyCandidate(placement="unified", schedule="sequential",
                            halo_multiplier=1, mesh=(SHARD_APUS,)),
        steps=1, meta={"grid": SHARD_GRID, "apus": SHARD_APUS})


_REGISTRY: Dict[str, Callable[[], Workload]] = {
    "cfd_step": _cfd_workload,
    "serve_decode": _serve_workload,
    "train_step": _train_workload,
    "cfd_sharded": _sharded_workload,
}

WORKLOAD_NAMES = tuple(_REGISTRY)


def get_workload(name: str) -> Workload:
    if name not in _REGISTRY:
        raise KeyError(f"unknown workload {name!r}; "
                       f"available: {WORKLOAD_NAMES}")
    return _REGISTRY[name]()
