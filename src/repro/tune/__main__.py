"""CLI: search the policy space and persist the warm-start profile.

  PYTHONPATH=src python -m repro.tune \
      --workloads cfd_step,serve_decode --trials 3 \
      --out artifacts/tune/policy_profile.json

``--gate`` arms the tuned-vs-ref regression check (exit non-zero when a
measured winner is worse than its hand-assembled reference beyond
``--tol``) — the CI smoke runs it on the serve decode + CFD programs at
reduced trial counts (docs/AUTOTUNE.md).
"""
from __future__ import annotations

import argparse
import warnings

warnings.filterwarnings("ignore")


def main(argv=None):
    from repro.tune.profile import DEFAULT_PROFILE_PATH
    from repro.tune.tuner import tune_workloads
    from repro.tune.workloads import WORKLOAD_NAMES

    ap = argparse.ArgumentParser(prog="python -m repro.tune")
    ap.add_argument("--workloads", default="cfd_step,serve_decode",
                    help=f"comma list from {','.join(WORKLOAD_NAMES)}")
    ap.add_argument("--trials", type=int, default=3,
                    help="measured finalists per workload (0 = pure "
                         "cost-model ranking, requires a prior profile's "
                         "residuals)")
    ap.add_argument("--steps", type=int, default=0,
                    help="replays per measurement (0 = workload default)")
    ap.add_argument("--out", default=DEFAULT_PROFILE_PATH,
                    help="profile JSON to write")
    ap.add_argument("--winners",
                    default="artifacts/variants/autotune_winners.json",
                    help="AutotuneSelector cells for the 'autotuned' "
                         "selector axis (fig_variants artifact)")
    ap.add_argument("--gate", action="store_true",
                    help="fail when a measured winner is worse than its "
                         "reference beyond --tol")
    ap.add_argument("--tol", type=float, default=0.25,
                    help="gate tolerance (fractional)")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    names = [n for n in args.workloads.split(",") if n]
    profile, results = tune_workloads(
        names, trials=args.trials, steps=args.steps or None, out=args.out,
        winners_path=args.winners,
        gate_tol=args.tol if args.gate else None, seed=args.seed)
    for res in results:
        speed = ""
        if res.fom_s is not None and res.ref_fom_s:
            speed = f" (x{res.ref_fom_s / max(res.fom_s, 1e-12):.2f} vs ref)"
        print(f"[tune] {res.workload}|2^{res.bucket}: {res.winner.label}"
              f"{speed}  score={res.score_s:.3e}s"
              + (f" fom={res.fom_s:.3e}s" if res.fom_s is not None else "")
              + (f" DISQUALIFIED={len(res.disqualified)}"
                 if res.disqualified else ""))
    print(f"[tune] wrote {len(profile.entries)} entr"
          f"{'y' if len(profile.entries) == 1 else 'ies'} to {args.out}")
    return profile


if __name__ == "__main__":
    main()
