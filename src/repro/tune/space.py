"""The policy search space: one candidate = one point on every axis.

A :class:`PolicyCandidate` is the serializable coordinate the tuner
searches over and the profile persists — placement (which
``ComposedPolicy``), routing cutoff (``TARGET_CUT_OFF`` for adaptive),
staging mode (sync Executor vs async double-buffered replay), selector
(ref / pallas / autotuned variant dispatch), and — for sharded
workloads — the exchange schedule, wide-halo depth, and mesh shape.
:meth:`PolicyCandidate.build_policy` turns the coordinate back into the
exact ``ExecutionPolicy`` the regions spine executes, so a profile entry
round-trips to runnable policy with no driver-side interpretation.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

from repro.core.regions import (DEFAULT_CUTOFF, AutotuneSelector,
                                ComposedPolicy, Placer, StaticSelector,
                                make_policy)

#: routing cutoffs the adaptive axis tries (elements) — DEFAULT_CUTOFF is
#: the paper's empirical TARGET_CUT_OFF, bracketed one bucket either side
CUTOFF_LADDER = (4096, DEFAULT_CUTOFF, 65536)

#: variant-selection axis (docs/VARIANTS.md): one implementation
#: everywhere, or the calibrated per-(region, target, bucket) winners
SELECTOR_CHOICES = ("ref", "pallas", "autotuned")


def parse_winner_key(key: str) -> Tuple[str, str, int]:
    """``"region|target|2^b"`` (the fig_variants / profile JSON cell
    format) -> ``(region, target, bucket)``."""
    region, target, cell = key.rsplit("|", 2)
    if not cell.startswith("2^"):
        raise ValueError(f"bad winner cell {key!r}: want region|target|2^b")
    return region, target, int(cell[2:])


@dataclasses.dataclass(frozen=True)
class PolicyCandidate:
    """One point in the policy space (hashable, JSON round-trippable)."""
    placement: str = "unified"        # unified | discrete | host | adaptive
    cutoff: Optional[int] = None      # TARGET_CUT_OFF (adaptive only)
    selector: str = "ref"             # ref | pallas | autotuned
    staging: str = "sync"             # sync | async (AsyncExecutor replay)
    schedule: str = "overlap"         # sharded: overlap|sequential|split
    halo_multiplier: int = 1          # sharded: k-wide ghosts, 1/k syncs
    mesh: Optional[Tuple[int, ...]] = None   # sharded mesh shape

    @property
    def label(self) -> str:
        bits = [self.placement]
        if self.placement == "adaptive" and self.cutoff:
            bits[-1] += f"@{self.cutoff}"
        if self.staging != "sync":
            bits.append(self.staging)
        bits.append(self.selector)
        if self.mesh is not None:
            bits.append("x".join(str(s) for s in self.mesh))
            bits.append(f"{self.schedule}/h{self.halo_multiplier}")
        return "+".join(bits)

    def to_dict(self) -> dict:
        d = dataclasses.asdict(self)
        if self.mesh is not None:
            d["mesh"] = list(self.mesh)
        return d

    @classmethod
    def from_dict(cls, d: dict) -> "PolicyCandidate":
        kw = dict(d)
        if kw.get("mesh") is not None:
            kw["mesh"] = tuple(int(s) for s in kw["mesh"])
        if kw.get("cutoff") is not None:
            kw["cutoff"] = int(kw["cutoff"])
        return cls(**kw)

    def make_selector(self, winners: Optional[Dict[str, str]] = None):
        """The candidate's selection axis.  ``winners`` is the persisted
        ``{"region|target|2^b": impl}`` cell map (the generalization of
        ``artifacts/variants/autotune_winners.json``); an ``autotuned``
        candidate without winners degrades to the ref fallback —
        exactly what an uncalibrated AutotuneSelector does."""
        if self.selector == "autotuned":
            sel = AutotuneSelector()
            for key, win in (winners or {}).items():
                sel.winners[parse_winner_key(key)] = win
            return sel
        return StaticSelector(self.selector)

    def build_policy(self, memory=None, *,
                     winners: Optional[Dict[str, str]] = None,
                     placer: Optional[Placer] = None) -> ComposedPolicy:
        """The concrete ExecutionPolicy this coordinate names.
        ``memory`` (a ``MemoryPolicy``) supplies the adaptive cutoff when
        the candidate doesn't pin one — same precedence as
        ``lm_policy``."""
        kw = {}
        if placer is not None:
            kw["placer"] = placer
        if self.placement == "adaptive":
            cut = self.cutoff
            if cut is None and memory is not None:
                cut = memory.target_cutoff
            if cut is not None:
                kw["cutoff"] = int(cut)
        pol = make_policy(self.placement, **kw)
        pol.selector = self.make_selector(winners)
        return pol


def enumerate_candidates(kind: str = "replay", *, apus: int = 4,
                         cutoffs=CUTOFF_LADDER,
                         selectors=SELECTOR_CHOICES) -> list:
    """The deterministic candidate list the tuner scores, in a fixed
    order (ties in the cost model resolve to the earlier candidate, so
    same inputs always elect the same winner).

    ``replay`` workloads vary placement x cutoff x selector x staging
    (async staging only where it means anything — the discrete stager);
    ``sharded`` workloads vary schedule x halo depth x mesh shape (1-D
    slab vs the shared near-square factorization) under unified
    placement, the regime docs/SCALING.md measures."""
    out = []
    if kind == "replay":
        for placement in ("unified", "adaptive", "discrete", "host"):
            cuts = cutoffs if placement == "adaptive" else (None,)
            stagings = ("sync", "async") if placement == "discrete" \
                else ("sync",)
            for cut in cuts:
                for staging in stagings:
                    for sel in selectors:
                        out.append(PolicyCandidate(
                            placement=placement, cutoff=cut, selector=sel,
                            staging=staging))
    elif kind == "sharded":
        from repro.launch.mesh import near_square_mesh_shape
        meshes = [(apus,)]
        sq = near_square_mesh_shape(apus)
        if sq not in meshes:
            meshes.append(sq)
        for mesh in meshes:
            for schedule in ("sequential", "overlap", "split"):
                for halo in (1, 2):
                    out.append(PolicyCandidate(
                        placement="unified", schedule=schedule,
                        halo_multiplier=halo, mesh=mesh))
    else:
        raise ValueError(f"unknown workload kind {kind!r}")
    return out


# ---------------------------------------------------------------------------
# Workload size measures — the bucket key drivers and tuner must agree on
# ---------------------------------------------------------------------------

def serve_size(batch: int, max_len: int, d_model: int) -> int:
    """Serve-workload size: decode activation elements (batch x max_len
    x d_model) — what the KV working set and per-step matmuls scale
    with."""
    return int(batch) * int(max_len) * int(d_model)


def train_size(batch: int, seq: int, d_model: int) -> int:
    """Train-workload size: step activation elements."""
    return int(batch) * int(seq) * int(d_model)


def cfd_size(grid) -> int:
    """CFD-workload size: cells in the grid."""
    n = 1
    for g in grid:
        n *= int(g)
    return n
