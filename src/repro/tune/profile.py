"""The persisted warm-start profile: tuned winners per (workload, bucket).

``artifacts/tune/policy_profile.json`` generalizes
``artifacts/variants/autotune_winners.json``: where the variants file
held per-(region, target, bucket) *implementation* winners, a profile
entry holds the whole winning :class:`~repro.tune.space.PolicyCandidate`
— placement, cutoff, staging, selector (with its variant-winner cells
carried along), and mesh/schedule for sharded workloads — plus the
measured FOMs and the model-vs-measured residuals the search used.

Entries are keyed ``"{workload}|2^{bucket}"`` on the existing
power-of-2 size-bucket scheme (``repro.core.regions.size_bucket``:
bucket ``b`` covers sizes in ``[2^(b-1), 2^b)``).  :meth:`lookup` falls
back to the nearest calibrated bucket of the same workload — the same
fallback contract ``AutotuneSelector`` uses per region — and returns
``None`` for unknown workloads so callers (``--policy auto``) can fall
back to the hand-assembled ``lm_policy``.
"""
from __future__ import annotations

import dataclasses
import json
from pathlib import Path
from typing import Dict, Optional

from repro.core.regions import size_bucket
from repro.tune.space import PolicyCandidate

#: bump on any schema change; load() refuses mismatched profiles rather
#: than silently building the wrong policy from stale fields
PROFILE_VERSION = 1

#: where the drivers look (override: REPRO_TUNE_PROFILE / --profile)
DEFAULT_PROFILE_PATH = "artifacts/tune/policy_profile.json"


def entry_key(workload: str, bucket: int) -> str:
    return f"{workload}|2^{int(bucket)}"


@dataclasses.dataclass
class ProfileEntry:
    """One tuned cell: the winning candidate for a workload-shape bucket."""
    workload: str
    bucket: int
    candidate: PolicyCandidate
    fom_s: Optional[float] = None        # measured winner FOM (s/unit)
    ref_fom_s: Optional[float] = None    # measured hand-assembled baseline
    score_s: Optional[float] = None      # cost-model prediction for winner
    residuals: Dict[str, float] = dataclasses.field(default_factory=dict)
    variant_winners: Dict[str, str] = dataclasses.field(default_factory=dict)

    @property
    def key(self) -> str:
        return entry_key(self.workload, self.bucket)

    def to_dict(self) -> dict:
        return {
            "workload": self.workload,
            "bucket": self.bucket,
            "candidate": self.candidate.to_dict(),
            "fom_s": self.fom_s,
            "ref_fom_s": self.ref_fom_s,
            "score_s": self.score_s,
            "residuals": dict(self.residuals),
            "variant_winners": dict(self.variant_winners),
        }

    @classmethod
    def from_dict(cls, d: dict) -> "ProfileEntry":
        return cls(workload=d["workload"], bucket=int(d["bucket"]),
                   candidate=PolicyCandidate.from_dict(d["candidate"]),
                   fom_s=d.get("fom_s"), ref_fom_s=d.get("ref_fom_s"),
                   score_s=d.get("score_s"),
                   residuals=dict(d.get("residuals") or {}),
                   variant_winners=dict(d.get("variant_winners") or {}))


class PolicyProfile:
    """A versioned set of :class:`ProfileEntry` cells with nearest-bucket
    lookup and JSON persistence."""

    def __init__(self, entries: Optional[Dict[str, ProfileEntry]] = None):
        self.entries: Dict[str, ProfileEntry] = dict(entries or {})

    def add(self, entry: ProfileEntry) -> None:
        self.entries[entry.key] = entry

    def lookup(self, workload: str, size: int) -> Optional[ProfileEntry]:
        """The entry for ``workload`` at the bucket of ``size``, or the
        nearest calibrated bucket of the same workload (smaller bucket
        wins a distance tie, matching AutotuneSelector), or ``None``."""
        b = size_bucket(size)
        exact = self.entries.get(entry_key(workload, b))
        if exact is not None:
            return exact
        near = [(abs(e.bucket - b), e.bucket, k)
                for k, e in self.entries.items() if e.workload == workload]
        if not near:
            return None
        return self.entries[min(near)[2]]

    def workloads(self) -> list:
        return sorted({e.workload for e in self.entries.values()})

    def to_dict(self) -> dict:
        return {
            "version": PROFILE_VERSION,
            "bucket_model": "b covers sizes in [2^(b-1), 2^b)",
            "entries": {k: e.to_dict()
                        for k, e in sorted(self.entries.items())},
        }

    def save(self, path=DEFAULT_PROFILE_PATH) -> Path:
        out = Path(path)
        out.parent.mkdir(parents=True, exist_ok=True)
        out.write_text(json.dumps(self.to_dict(), indent=1, sort_keys=True))
        return out

    @classmethod
    def load(cls, path=DEFAULT_PROFILE_PATH) -> "PolicyProfile":
        d = json.loads(Path(path).read_text())
        ver = d.get("version")
        if ver != PROFILE_VERSION:
            raise ValueError(
                f"profile {path} is version {ver!r}, this build reads "
                f"{PROFILE_VERSION}; re-run `python -m repro.tune`")
        return cls({k: ProfileEntry.from_dict(e)
                    for k, e in d.get("entries", {}).items()})

    @classmethod
    def load_if_exists(cls, path=DEFAULT_PROFILE_PATH):
        """``load`` that treats a missing file as "no profile" (None) —
        the ``--policy auto`` startup path; schema mismatches still
        raise."""
        p = Path(path)
        if not p.exists():
            return None
        return cls.load(p)
