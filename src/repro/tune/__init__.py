"""Offline global policy autotuner (ROADMAP item 5, docs/AUTOTUNE.md).

The paper's central claim is that unified memory lets the *runtime
policy* — not the programmer — decide where data lives and code runs.
``repro.tune`` closes the loop: profile a captured RegionProgram once
through the PR-9 roofline cost model (``repro.analysis.costs``), correct
the model with a measured calibration replay (per-region residuals),
search the whole policy space — placement x routing-cutoff x staging x
selector x mesh-shape — per workload-shape bucket, and persist the
winners to a versioned warm-start profile that ``serve`` / ``train`` /
``scaling`` load with ``--policy auto``.

  PYTHONPATH=src python -m repro.tune --workloads cfd_step,serve_decode \
      --trials 3 --out artifacts/tune/policy_profile.json
"""
from repro.tune.profile import (DEFAULT_PROFILE_PATH, PROFILE_VERSION,
                                PolicyProfile, ProfileEntry)
from repro.tune.space import (PolicyCandidate, cfd_size,
                              enumerate_candidates, serve_size, train_size)
from repro.tune.tuner import TuneResult, tune, tune_workloads
from repro.tune.workloads import WORKLOAD_NAMES, Workload, get_workload

__all__ = [
    "DEFAULT_PROFILE_PATH", "PROFILE_VERSION", "PolicyProfile",
    "ProfileEntry", "PolicyCandidate", "enumerate_candidates",
    "serve_size", "train_size", "cfd_size", "TuneResult", "tune",
    "tune_workloads", "WORKLOAD_NAMES", "Workload", "get_workload",
]
