"""The search: score the whole space from the cost model, measure finalists.

Three stages per workload (docs/AUTOTUNE.md):

1. **Profile once** — run the PR-9 roofline bridge
   (``repro.analysis.costs``) over the workload's captured program, then
   take ONE measured calibration replay of the reference candidate and
   store the per-region model-vs-measured residual (``measured /
   modeled``).  The residuals correct the model where the container
   diverges from the MI300A roofline; they persist in the profile so a
   later search can warm-start without re-measuring.
2. **Search** — score every :func:`~repro.tune.space.enumerate_candidates`
   point: residual-corrected roofline seconds plus placement priors —
   the discrete staging tax priced at asymmetric host<->device
   bandwidth fractions (seeded from the measured UPM asymmetries in
   "Dissecting CPU-GPU Unified Physical Memory on AMD MI300A APUs",
   PAPERS.md), a host-compute slowdown, an async-overlap discount, and
   for sharded workloads a halo-exchange surface/sync model over the
   mesh-shape x schedule x halo axes.
3. **Measure finalists** — the top-scored candidates (placement/staging
   diversity first) get short measured replays, each parity-asserted
   against the reference leaves (DESIGN §2 tolerance); the winner is
   the best measured FOM among finalists AND the reference, so a tuned
   profile can never elect a candidate that measured worse than the
   hand-assembled baseline it was searched against.

``trials=0`` skips all measurement (given precomputed residuals) and
elects the best-scored candidate — the deterministic pure-model path the
tests pin: same inputs, same winners.
"""
from __future__ import annotations

import dataclasses
import json
from pathlib import Path
from typing import Callable, Dict, List, Optional

import numpy as np

from repro.core.regions import DEFAULT_CUTOFF, size_bucket
from repro.tune.profile import PolicyProfile, ProfileEntry
from repro.tune.space import PolicyCandidate, enumerate_candidates
from repro.tune.workloads import RunResult, Workload, get_workload

# -- placement priors -------------------------------------------------------
# Seeded from the measured MI300A UPM bandwidth asymmetries ("Dissecting
# CPU-GPU Unified Physical Memory on AMD MI300A APUs", PAPERS.md): unified
# fine-grained access runs near HBM rate on-package, while the managed /
# discrete path pays staged copies at a fraction of HBM bandwidth — and
# asymmetrically, with device->host writeback the slower direction.  The
# absolute fractions only have to get the *ranking* right; the measured
# finalist pass owns the final ordering.
H2D_BW_FRACTION = 0.30      # stage-in bandwidth as a fraction of HBM_BW
D2H_BW_FRACTION = 0.20      # stage-out (writeback) — the slow side
HOST_COMPUTE_FACTOR = 8.0   # host-routed region slowdown vs device roofline
ASYNC_OVERLAP_PRIOR = 0.6   # staging fraction the lookahead hides (fig6b)

# sharded exchange model (docs/SCALING.md cost structure)
EXCHANGE_BW_FRACTION = 0.5  # inter-APU fabric vs HBM bandwidth
SYNC_LATENCY_S = 5e-5       # per halo-exchange rendezvous
STENCIL_APPS_PRIOR = 24.0   # stencil applications per step (halo syncs)
FIELDS_PRIOR = 8            # arrays exchanged per stencil application
SCHEDULE_EXPOSURE = {"sequential": 1.0, "split": 0.6, "overlap": 0.35}

#: DESIGN §2 float replay-parity tolerance
PARITY_RTOL = 1e-5


def model_costs(prog) -> dict:
    """Aggregate the per-op roofline estimates into what scoring needs:
    per-region seconds/bytes plus a flat op list (region, roofline_s,
    hbm_bytes) for routing-cutoff modeling."""
    from repro.analysis.costs import estimate_program_costs
    est = estimate_program_costs(prog)
    region_s: Dict[str, float] = {}
    region_bytes: Dict[str, int] = {}
    ops = []
    for o in est["ops"]:
        t = max(o["roofline_compute_s"], o["roofline_memory_s"])
        region_s[o["region"]] = region_s.get(o["region"], 0.0) + t
        region_bytes[o["region"]] = (region_bytes.get(o["region"], 0)
                                     + o["hbm_bytes"])
        ops.append((o["region"], t, o["hbm_bytes"]))
    return {"region_s": region_s, "region_bytes": region_bytes, "ops": ops,
            "total_s": sum(region_s.values()),
            "total_bytes": sum(region_bytes.values()),
            "skipped": est["skipped"]}


def compute_residuals(model: dict, measured_region_s: Dict[str, float],
                      replays: int = 1) -> Dict[str, float]:
    """Per-region ``measured / modeled`` correction factors from one
    calibration replay, plus the ``"*"`` global fallback for regions the
    model skipped or the ledger renamed."""
    res: Dict[str, float] = {}
    matched_meas = matched_model = 0.0
    for name, modeled in model["region_s"].items():
        meas = measured_region_s.get(name)
        if meas is None or modeled <= 0:
            continue
        res[name] = meas / (modeled * max(replays, 1))
        matched_meas += meas
        matched_model += modeled * max(replays, 1)
    res["*"] = (matched_meas / matched_model) if matched_model > 0 else 1.0
    return res


def _roofline_bw() -> float:
    from repro.analysis.costs import _roofline_constants
    return _roofline_constants()[1]


def score_candidate(candidate: PolicyCandidate, model: dict,
                    residuals: Optional[Dict[str, float]] = None,
                    kind: str = "replay", meta: Optional[dict] = None,
                    hbm_bw: Optional[float] = None) -> float:
    """Predicted seconds per program replay for ``candidate`` — the
    pruning score.  Selector choices score identically (the roofline
    cannot see implementation quality); they are separated by the
    measured finalist pass, with ties resolved by candidate order."""
    residuals = residuals or {}
    glob = residuals.get("*", 1.0)
    hbm_bw = hbm_bw or _roofline_bw()
    cutoff = candidate.cutoff or DEFAULT_CUTOFF
    total = 0.0
    for region, t, nbytes in model["ops"]:
        t = t * residuals.get(region, glob)
        if candidate.placement == "host":
            t *= HOST_COMPUTE_FACTOR
        elif candidate.placement == "adaptive":
            # SizeRouter sends small calls to the host; approximate the
            # call's element count from its modeled f32 traffic
            if nbytes // 12 < cutoff:
                t *= HOST_COMPUTE_FACTOR
        total += t
    if candidate.placement == "discrete":
        staging = model["total_bytes"] * (1.0 / (H2D_BW_FRACTION * hbm_bw)
                                          + 1.0 / (D2H_BW_FRACTION * hbm_bw))
        if candidate.staging == "async":
            staging *= 1.0 - ASYNC_OVERLAP_PRIOR
        total += staging
    if kind == "sharded" and candidate.mesh is not None:
        total += _exchange_model(candidate, (meta or {}).get("grid"),
                                 hbm_bw)
    return total


def _exchange_model(candidate: PolicyCandidate, grid, hbm_bw: float) -> float:
    """Exposed halo-exchange seconds per step: surface bytes over the
    fabric plus per-sync latency, discounted by the schedule's exposure
    and the wide-halo sync reduction.  Mesh axes map to trailing grid
    dims (the ShardExecutor convention)."""
    if not grid:
        return 0.0
    mesh = candidate.mesh
    halo = max(candidate.halo_multiplier, 1)
    cells = 1
    for g in grid:
        cells *= int(g)
    surface_cells = 0
    for dim, m in zip(range(-len(mesh), 0), mesh):
        if m <= 1:
            continue
        plane = cells // int(grid[dim])          # cells in one cut plane
        surface_cells += 2 * halo * plane * (m - 1)
    n_syncs = STENCIL_APPS_PRIOR / halo
    xbytes = surface_cells * 4 * FIELDS_PRIOR * n_syncs
    exposure = SCHEDULE_EXPOSURE.get(candidate.schedule, 1.0)
    return (xbytes / (EXCHANGE_BW_FRACTION * hbm_bw)
            + SYNC_LATENCY_S * n_syncs) * exposure


def check_parity(leaves: List[np.ndarray], ref: List[np.ndarray],
                 rtol: float = PARITY_RTOL) -> float:
    """Max abs error of ``leaves`` vs ``ref`` under the DESIGN §2
    contract — integer leaves must match bit-for-bit, float leaves
    within ``rtol`` of the reference scale.  Raises AssertionError."""
    worst = 0.0
    assert len(leaves) == len(ref), (len(leaves), len(ref))
    for a, b in zip(leaves, ref):
        a, b = np.asarray(a), np.asarray(b)
        if np.issubdtype(b.dtype, np.integer) or b.dtype == np.bool_:
            np.testing.assert_array_equal(a, b)
            continue
        scale = max(1.0, float(np.max(np.abs(b))) if b.size else 1.0)
        err = float(np.max(np.abs(a - b))) if b.size else 0.0
        assert err <= rtol * scale, (err, rtol * scale)
        worst = max(worst, err)
    return worst


@dataclasses.dataclass
class TuneResult:
    """One workload's search outcome (feeds a ProfileEntry)."""
    workload: str
    bucket: int
    winner: PolicyCandidate
    fom_s: Optional[float]
    ref_fom_s: Optional[float]
    score_s: float
    residuals: Dict[str, float]
    table: List[dict]                # every candidate: label/score/fom
    disqualified: List[str] = dataclasses.field(default_factory=list)

    def to_entry(self, variant_winners=None) -> ProfileEntry:
        return ProfileEntry(
            workload=self.workload, bucket=self.bucket,
            candidate=self.winner, fom_s=self.fom_s,
            ref_fom_s=self.ref_fom_s, score_s=self.score_s,
            residuals=dict(self.residuals),
            variant_winners=dict(variant_winners or {})
            if self.winner.selector == "autotuned" else {})


def _diverse_finalists(scored: List[tuple], trials: int) -> List[int]:
    """Indices of the measured finalists: best score per new
    (placement, staging) pair first — so the measured pass always sees
    placement diversity — then remaining slots in pure score order."""
    picked: List[int] = []
    seen = set()
    for _, i, cand in scored:
        key = (cand.placement, cand.staging)
        if key not in seen:
            seen.add(key)
            picked.append(i)
        if len(picked) >= trials:
            return picked
    for _, i, _cand in scored:
        if i not in picked:
            picked.append(i)
        if len(picked) >= trials:
            break
    return picked


def tune(workload: Workload, *, trials: int = 3, steps: Optional[int] = None,
         winners: Optional[Dict[str, str]] = None,
         residuals: Optional[Dict[str, float]] = None,
         measure: Optional[Callable] = None, seed: int = 0) -> TuneResult:
    """Search one workload (module docstring).  ``measure(workload,
    candidate, steps) -> RunResult`` is injectable for deterministic
    tests; ``residuals`` warm-starts calibration (required when
    ``trials=0`` wants a fully measurement-free run).  ``seed`` is
    recorded for forward compatibility — the search itself is
    deterministic by construction (fixed enumeration order, score ties
    resolve to the earlier candidate)."""
    del seed  # deterministic search: nothing random to seed (yet)
    steps = steps or workload.steps
    if measure is None:
        def measure(w, c, s):
            return w.run(c, s, winners=winners)
    model = model_costs(workload.build_program())

    ref_res: Optional[RunResult] = None
    if residuals is None:
        ref_res = measure(workload, workload.ref, steps)
        residuals = compute_residuals(model, ref_res.region_s,
                                      ref_res.replays)

    cands = enumerate_candidates(workload.kind,
                                 apus=workload.meta.get("apus", 4))
    if workload.ref not in cands:
        cands.append(workload.ref)
    scores = [score_candidate(c, model, residuals, kind=workload.kind,
                              meta=workload.meta) for c in cands]
    scored = sorted(zip(scores, range(len(cands)), cands))

    table = [{"candidate": c.to_dict(), "label": c.label, "score_s": s,
              "fom_s": None, "parity_max_err": None}
             for s, c in zip(scores, cands)]
    disqualified: List[str] = []

    # the winner pool: (fom, score, order, candidate) — ref always in it
    pool: List[tuple] = []
    if trials > 0:
        if ref_res is None:
            ref_res = measure(workload, workload.ref, steps)
        ref_i = cands.index(workload.ref)
        table[ref_i]["fom_s"] = ref_res.fom_s
        pool.append((ref_res.fom_s, scores[ref_i], ref_i, workload.ref))
        for i in _diverse_finalists(scored, trials):
            cand = cands[i]
            if cand == workload.ref:
                continue
            res = measure(workload, cand, steps)
            try:
                err = check_parity(res.leaves, ref_res.leaves)
            except AssertionError as exc:
                disqualified.append(f"{cand.label}: {exc}")
                table[i]["parity_max_err"] = "FAILED"
                continue
            table[i]["fom_s"] = res.fom_s
            table[i]["parity_max_err"] = err
            pool.append((res.fom_s, scores[i], i, cand))
        fom, score, _, winner = min(pool, key=lambda t: t[:3])
    else:
        score, _, winner = scored[0]
        fom = None

    ref_fom = ref_res.fom_s if ref_res is not None else None
    return TuneResult(workload=workload.name,
                      bucket=size_bucket(workload.size), winner=winner,
                      fom_s=fom, ref_fom_s=ref_fom, score_s=score,
                      residuals=dict(residuals), table=table,
                      disqualified=disqualified)


def load_variant_winners(
        path: str = "artifacts/variants/autotune_winners.json"
) -> Dict[str, str]:
    """The persisted AutotuneSelector cells (fig_variants artifact) the
    ``autotuned`` selector axis reuses; ``{}`` when never calibrated."""
    p = Path(path)
    if not p.exists():
        return {}
    try:
        return dict(json.loads(p.read_text()).get("winners", {}))
    except (ValueError, AttributeError):
        return {}


def tune_workloads(names, *, trials: int = 3, steps: Optional[int] = None,
                   out: Optional[str] = None,
                   winners_path: str = "artifacts/variants/autotune_winners.json",
                   profile: Optional[PolicyProfile] = None,
                   gate_tol: Optional[float] = None, seed: int = 0):
    """Tune each named workload and persist the winners.  Returns
    ``(profile, results)``.  ``gate_tol`` arms the tuned-vs-ref
    regression gate: any measured winner worse than its reference by
    more than the tolerance raises (the winner pool already contains
    the reference, so this only trips on measurement noise — the
    tolerance absorbs it)."""
    winners = load_variant_winners(winners_path)
    profile = profile or PolicyProfile()
    results = []
    failures = []
    for name in names:
        w = get_workload(name)
        res = tune(w, trials=trials, steps=steps, winners=winners, seed=seed)
        results.append(res)
        profile.add(res.to_entry(variant_winners=winners))
        if gate_tol is not None and res.fom_s is not None \
                and res.ref_fom_s is not None \
                and res.fom_s > res.ref_fom_s * (1.0 + gate_tol):
            failures.append(f"{name}: tuned {res.fom_s:.6f}s > ref "
                            f"{res.ref_fom_s:.6f}s * (1+{gate_tol})")
    if out:
        profile.save(out)
    if failures:
        raise SystemExit("[tune] regression gate failed:\n  "
                         + "\n  ".join(failures))
    return profile, results
