"""Core layer ops: norms, RoPE / M-RoPE, SwiGLU MLP, embeddings.

All functions are pure; parameters come in as pytrees built from
``ParamSpec`` trees (see :mod:`repro.models.params`). Activation sharding is
expressed through a ``shd(x, *logical_axes)`` callable threaded through the
model — identity on a single device, ``with_sharding_constraint`` under a
mesh (see :mod:`repro.launch.sharding`).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.params import ParamSpec


def noshard(x, *axes):
    return x


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------

def rmsnorm_spec(d: int) -> ParamSpec:
    return ParamSpec((d,), ("embed",), "float32", "ones")


def rmsnorm(x, scale, eps: float = 1e-6):
    dt = x.dtype
    x = x.astype(jnp.float32)
    x = x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)
    return (x * scale.astype(jnp.float32)).astype(dt)


# ---------------------------------------------------------------------------
# Rotary embeddings
# ---------------------------------------------------------------------------

def _rope_angles(positions, head_dim: int, theta: float):
    """positions [...,] -> cos/sin [..., head_dim//2] in fp32."""
    half = head_dim // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions.astype(jnp.float32)[..., None] * freqs
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x, positions, theta: float):
    """x [B, T, H, hd]; positions [B, T] (ints). Rotate-half convention."""
    cos, sin = _rope_angles(positions, x.shape[-1], theta)   # [B,T,half]
    cos = cos[:, :, None, :]
    sin = sin[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def apply_mrope(x, positions3, sections, theta: float):
    """Qwen2-VL M-RoPE: positions3 [B, T, 3] (t/h/w streams); ``sections``
    partitions the half-dim, each section rotated by its own stream."""
    B, T, H, hd = x.shape
    half = hd // 2
    assert sum(sections) == half, (sections, half)
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    # pick the position stream per frequency index
    sec_id = jnp.repeat(
        jnp.arange(3), jnp.asarray(sections), total_repeat_length=half
    )                                                          # [half]
    pos = jnp.take_along_axis(
        positions3.astype(jnp.float32), sec_id[None, None, :].repeat(T, 1).repeat(B, 0), axis=-1
    )                                                          # [B,T,half]
    ang = pos * freqs
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Dense SwiGLU MLP
# ---------------------------------------------------------------------------

def mlp_specs(cfg: ModelConfig) -> dict:
    d, f = cfg.d_model, cfg.d_ff
    pd = cfg.param_dtype
    return {
        "wi_gate": ParamSpec((d, f), ("embed", "ff"), pd),
        "wi_up": ParamSpec((d, f), ("embed", "ff"), pd),
        "wo": ParamSpec((f, d), ("ff", "embed"), pd),
    }


def mlp(p, x, shd=noshard):
    h = shd(jnp.einsum("btd,df->btf", x, p["wi_gate"]), "batch", None, "ff")
    u = jnp.einsum("btd,df->btf", x, p["wi_up"])
    h = jax.nn.silu(h.astype(jnp.float32)).astype(x.dtype) * u
    return shd(jnp.einsum("btf,fd->btd", h, p["wo"]), "batch", None, None)


# ---------------------------------------------------------------------------
# Embedding / LM head
# ---------------------------------------------------------------------------

def embed_specs(cfg: ModelConfig) -> dict:
    s = {"tok": ParamSpec((cfg.vocab, cfg.d_model), ("vocab", "embed"),
                          cfg.param_dtype, scale=1.0)}
    if not cfg.tie_embeddings:
        s["head"] = ParamSpec((cfg.d_model, cfg.vocab), ("embed", "vocab"),
                              cfg.param_dtype)
    return s


def embed(p, tokens, cfg: ModelConfig, shd=noshard):
    x = jnp.take(p["tok"], tokens, axis=0).astype(cfg.compute_dtype)
    if cfg.tie_embeddings:
        x = x * jnp.asarray(cfg.d_model, x.dtype) ** 0.5   # gemma-style scaling
    return shd(x, "batch", None, None)


def lm_logits(p, x, cfg: ModelConfig, shd=noshard):
    w = p["tok"].T if cfg.tie_embeddings else p["head"]
    logits = jnp.einsum("btd,dv->btv", x, w.astype(x.dtype))
    return shd(logits, "batch", None, "vocab")
