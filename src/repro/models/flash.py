"""Flash-style attention with a custom VJP (beyond-paper optimization).

The baseline query-chunked attention (attention.py) is numerically fine but
its *backward* saves the per-chunk probability tensors stacked over all
chunks — the dry-run roofline shows that traffic dominating every dense
train cell. This path saves only ``(q, k, v, o, lse)`` and recomputes
probabilities chunk-by-chunk in the backward pass: HBM residuals drop from
O(T^2 / chunk * chunk) = O(T^2) to O(T) per head, at the cost of one extra
QK^T recompute (the classic flash trade: ~30% more attention flops for
~10x less attention memory traffic).

Forward is mathematically identical to attention.chunked_attention (row
softmax over the full key range), so it slots in behind the same callers.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def _pick_chunk(T: int, chunk: int) -> int:
    c = min(chunk, T)
    while T % c:
        c -= 1
    return c


def _mask_for(qpos, kpos, causal: bool, window: int):
    mask = None
    if causal:
        mask = kpos[None, :] <= qpos[:, None]
        if window > 0:
            mask &= kpos[None, :] > qpos[:, None] - window
    return mask


def _chunk_fwd(qc, k, v, mask, scale):
    """qc [B,Hkv,G,C,hd]; k/v [B,L,Hkv,hd] -> (o, lse)."""
    logits = jnp.einsum("bkgcd,blkd->bkgcl", qc, k).astype(jnp.float32) * scale
    if mask is not None:
        logits = jnp.where(mask[None, None, None], logits, NEG_INF)
    m = jnp.max(logits, axis=-1, keepdims=True)
    p = jnp.exp(logits - m)
    s = jnp.sum(p, axis=-1, keepdims=True)
    o = jnp.einsum("bkgcl,blkd->bkgcd", p.astype(v.dtype), v)
    o = o / jnp.maximum(s, 1e-30).astype(o.dtype)
    lse = (m + jnp.log(jnp.maximum(s, 1e-30)))[..., 0]      # [B,Hkv,G,C]
    return o, lse


def _chunk_probs(qc, k, lse, mask, scale):
    logits = jnp.einsum("bkgcd,blkd->bkgcl", qc, k).astype(jnp.float32) * scale
    if mask is not None:
        logits = jnp.where(mask[None, None, None], logits, NEG_INF)
    return jnp.exp(logits - lse[..., None])


def make_flash_attention(causal: bool, window: int, chunk: int):
    """Returns flash(q, k, v) for q,k,v [B,T{q,k},H{q,kv},hd], GQA-grouped.
    window>0 => sliding window (mask only; the banded-slice variant of the
    baseline is reused for very long prefill via attention.py routing)."""

    @jax.custom_vjp
    def flash(q, k, v):
        o, _ = _fwd(q, k, v)
        return o

    def _reshape_q(q, Hkv):
        B, Tq, Hq, hd = q.shape
        G = Hq // Hkv
        return q.reshape(B, Tq, Hkv, G, hd).transpose(0, 2, 3, 1, 4)

    def _fwd(q, k, v):
        B, Tq, Hq, hd = q.shape
        Hkv = k.shape[2]
        C = _pick_chunk(Tq, chunk)
        n = Tq // C
        scale = 1.0 / (hd ** 0.5)
        qg = _reshape_q(q, Hkv)                       # [B,Hkv,G,Tq,hd]
        kk = k
        vv = v

        def one(ci):
            c0 = ci * C
            qc = jax.lax.dynamic_slice_in_dim(qg, c0, C, axis=3)
            qpos = c0 + jnp.arange(C)
            mask = _mask_for(qpos, jnp.arange(kk.shape[1]), causal, window)
            return _chunk_fwd(qc, kk, vv, mask, scale)

        o, lse = jax.lax.map(one, jnp.arange(n))      # [n,B,Hkv,G,C,*]
        o = jnp.moveaxis(o, 0, 3).reshape(*qg.shape[:3], n * C, o.shape[-1])
        lse = jnp.moveaxis(lse, 0, 3).reshape(*qg.shape[:3], n * C)
        B, Hkv_, G, Tq_, hd_ = o.shape
        o_out = o.transpose(0, 3, 1, 2, 4).reshape(B, Tq_, Hkv_ * G, hd_)
        return o_out.astype(q.dtype), lse

    def fwd(q, k, v):
        o, lse = _fwd(q, k, v)
        return o, (q, k, v, o, lse)

    def bwd(res, do):
        q, k, v, o, lse = res
        B, Tq, Hq, hd = q.shape
        Hkv = k.shape[2]
        G = Hq // Hkv
        C = _pick_chunk(Tq, chunk)
        n = Tq // C
        scale = 1.0 / (hd ** 0.5)
        qg = _reshape_q(q, Hkv)
        og = _reshape_q(o, Hkv)
        dog = _reshape_q(do.astype(jnp.float32), Hkv)
        lseg = lse.reshape(B, Hkv, G, Tq)
        delta = jnp.sum(dog * og.astype(jnp.float32), axis=-1)  # [B,Hkv,G,Tq]

        def step(carry, ci):
            dk_acc, dv_acc = carry
            c0 = ci * C
            qc = jax.lax.dynamic_slice_in_dim(qg, c0, C, axis=3)
            lc = jax.lax.dynamic_slice_in_dim(lseg, c0, C, axis=3)
            doc = jax.lax.dynamic_slice_in_dim(dog, c0, C, axis=3)
            dc = jax.lax.dynamic_slice_in_dim(delta, c0, C, axis=3)
            qpos = c0 + jnp.arange(C)
            mask = _mask_for(qpos, jnp.arange(k.shape[1]), causal, window)
            p = _chunk_probs(qc, k, lc, mask, scale)             # [B,Hkv,G,C,L]
            dv_c = jnp.einsum("bkgcl,bkgcd->blkd", p, doc)
            dp = jnp.einsum("bkgcd,blkd->bkgcl", doc,
                            v.astype(jnp.float32))
            ds = p * (dp - dc[..., None]) * scale
            dq_c = jnp.einsum("bkgcl,blkd->bkgcd", ds,
                              k.astype(jnp.float32))
            dk_c = jnp.einsum("bkgcl,bkgcd->blkd", ds,
                              qc.astype(jnp.float32))
            return (dk_acc + dk_c, dv_acc + dv_c), dq_c

        zero_kv = jnp.zeros(k.shape, jnp.float32)
        (dk, dv), dq_chunks = jax.lax.scan(step, (zero_kv, zero_kv),
                                           jnp.arange(n))
        dq = jnp.moveaxis(dq_chunks, 0, 3)               # [B,Hkv,G,n,C,hd]
        dq = dq.reshape(B, Hkv, G, Tq, hd).transpose(0, 3, 1, 2, 4)
        dq = dq.reshape(B, Tq, Hq, hd)
        return (dq.astype(q.dtype), dk.astype(k.dtype), dv.astype(v.dtype))

    flash.defvjp(fwd, bwd)
    return flash


@functools.lru_cache(maxsize=64)
def get_flash(causal: bool, window: int, chunk: int):
    return make_flash_attention(causal, window, chunk)
