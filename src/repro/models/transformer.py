"""Composable decoder (+ optional encoder) LM over a *layer program*.

A config's ``layer_cycle`` (e.g. RecurrentGemma's ``(rglru, rglru,
attn_local)`` or Gemma3's ``(local x5, global)``) is tiled to ``n_layers``.
Full cycles are executed under a single ``jax.lax.scan`` over stacked
per-cycle weights — HLO size stays O(cycle), not O(n_layers), which keeps
80-layer configs lowerable/compilable quickly; the non-divisible remainder is
unrolled. ``jax.checkpoint`` (remat) wraps the scanned body.

Three entry points share the layer interpreter: ``forward_train`` (full
sequence, no cache), ``prefill`` (full sequence, fills caches), and
``decode_step`` (one token against caches). Recurrent mixers (rwkv / rglru)
carry constant-size state instead of a KV cache — that is what makes
``long_500k`` runnable for the SSM/hybrid archs.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import attention as A
from repro.models import moe as M
from repro.models import rglru as G
from repro.models import rwkv6 as R
from repro.models.layers import (embed, embed_specs, lm_logits, mlp, mlp_specs,
                                 noshard, rmsnorm, rmsnorm_spec)
from repro.models.params import (ParamSpec, abstract_params, init_params,
                                 stack_specs)


@dataclasses.dataclass
class Ctx:
    mode: str = "train"                  # train | prefill | decode
    shd: Callable = noshard
    q_chunk: int = 512
    rwkv_chunk: int = 32   # perf iteration C (docs/EXPERIMENTS.md SPerf)
    positions3: Optional[jax.Array] = None   # [B,T,3] for M-RoPE
    pos: Optional[jax.Array] = None          # decode position (scalar)
    enc_out: Optional[jax.Array] = None      # whisper encoder output
    remat: bool = True
    remat_policy: Optional[Any] = None
    flash: bool = True                       # flash-VJP attention (see flash.py)


# ---------------------------------------------------------------------------
# Layer program
# ---------------------------------------------------------------------------

def _effective_cycle(cfg: ModelConfig) -> Tuple[Tuple[str, str], ...]:
    """Cycle of (mixer_kind, mlp_kind), extended to lcm with moe periodicity."""
    base = cfg.layer_cycle
    period = math.lcm(len(base), cfg.moe_every if cfg.moe else 1)
    cyc = []
    for i in range(period):
        mixer = base[i % len(base)]
        if cfg.moe is not None and (i % cfg.moe_every) == cfg.moe_offset:
            mlp_kind = "moe"
        elif mixer == "rwkv":
            mlp_kind = "cm"
        else:
            mlp_kind = "dense"
        cyc.append((mixer, mlp_kind))
    return tuple(cyc)


def layer_plan(cfg: ModelConfig):
    """Returns (cycle, n_scanned_cycles, remainder_kinds)."""
    cyc = _effective_cycle(cfg)
    n_full = cfg.n_layers // len(cyc)
    rem = [cyc[i % len(cyc)] for i in range(n_full * len(cyc), cfg.n_layers)]
    return cyc, n_full, tuple(rem)


def _one_layer_specs(cfg: ModelConfig, mixer: str, mlp_kind: str) -> dict:
    d = cfg.d_model
    s: Dict[str, Any] = {"norm1": rmsnorm_spec(d), "norm2": rmsnorm_spec(d)}
    if mixer in ("attn", "attn_local", "attn_enc"):
        s["mixer"] = A.attn_specs(cfg)
    elif mixer == "attn_xdec":
        s["mixer"] = A.attn_specs(cfg)
        s["cross"] = A.xattn_specs(cfg)
        s["norm_x"] = rmsnorm_spec(d)
    elif mixer == "rwkv":
        s["mixer"] = R.rwkv_specs(cfg)
    elif mixer == "rglru":
        s["mixer"] = G.rglru_specs(cfg)
    else:
        raise ValueError(mixer)
    if mlp_kind == "moe":
        s["mlp"] = M.moe_specs(cfg)
    elif mlp_kind == "cm":
        s["mlp"] = R.channelmix_specs(cfg)
    else:
        s["mlp"] = mlp_specs(cfg)
    return s


def param_specs(cfg: ModelConfig) -> dict:
    cyc, n_full, rem = layer_plan(cfg)
    specs: Dict[str, Any] = {"embed": embed_specs(cfg)}
    if n_full:
        specs["cycles"] = {
            f"p{j}": stack_specs(_one_layer_specs(cfg, mk, lk), n_full)
            for j, (mk, lk) in enumerate(cyc)
        }
    for r, (mk, lk) in enumerate(rem):
        specs[f"rest{r}"] = _one_layer_specs(cfg, mk, lk)
    specs["final_norm"] = rmsnorm_spec(cfg.d_model)
    if cfg.n_enc_layers:
        enc_layer = _one_layer_specs(cfg, "attn_enc", "dense")
        specs["encoder"] = {
            "layers": stack_specs(enc_layer, cfg.n_enc_layers),
            "norm": rmsnorm_spec(cfg.d_model),
        }
    return specs


def init(key: jax.Array, cfg: ModelConfig):
    return init_params(key, param_specs(cfg))


def abstract(cfg: ModelConfig):
    return abstract_params(param_specs(cfg))


# ---------------------------------------------------------------------------
# Cache / state layout
# ---------------------------------------------------------------------------

def _one_layer_cache_specs(cfg, mixer, batch, s_max):
    if mixer in ("attn", "attn_local"):
        return A.cache_specs(cfg, mixer, batch, s_max)
    if mixer == "attn_xdec":
        return {**A.cache_specs(cfg, "attn", batch, s_max),
                **A.xcache_specs(cfg, batch)}
    if mixer == "rwkv":
        rs = R.rwkv_state_specs(cfg, batch)
        rs["x_cm"] = ParamSpec((batch, cfg.d_model), ("batch", None),
                               cfg.compute_dtype, "zeros")
        return rs
    if mixer == "rglru":
        return G.rglru_state_specs(cfg, batch)
    raise ValueError(mixer)


def cache_specs(cfg: ModelConfig, batch: int, s_max: int) -> dict:
    cyc, n_full, rem = layer_plan(cfg)
    specs: Dict[str, Any] = {}
    if n_full:
        specs["cycles"] = {
            f"p{j}": stack_specs(_one_layer_cache_specs(cfg, mk, batch, s_max), n_full)
            for j, (mk, _) in enumerate(cyc)
        }
    for r, (mk, _) in enumerate(rem):
        specs[f"rest{r}"] = _one_layer_cache_specs(cfg, mk, batch, s_max)
    return specs


def abstract_cache(cfg: ModelConfig, batch: int, s_max: int):
    return abstract_params(cache_specs(cfg, batch, s_max))


def init_cache(cfg: ModelConfig, batch: int, s_max: int):
    leaves, treedef = jax.tree_util.tree_flatten(
        cache_specs(cfg, batch, s_max), is_leaf=lambda x: isinstance(x, ParamSpec))
    arrs = []
    for s in leaves:
        if s.dtype == "int32":
            arrs.append(jnp.full(s.shape, -1, jnp.int32))   # empty slots
        else:
            arrs.append(jnp.zeros(s.shape, s.dtype))
    return jax.tree_util.tree_unflatten(treedef, arrs)


# ---------------------------------------------------------------------------
# Single-layer forward (all modes)
# ---------------------------------------------------------------------------

def layer_fwd(p, x, cfg: ModelConfig, mixer: str, mlp_kind: str, ctx: Ctx,
              cache=None):
    """Returns (x_out, new_cache, aux_loss)."""
    aux = jnp.zeros((), jnp.float32)
    h = rmsnorm(x, p["norm1"])
    new_cache = cache
    if mixer in ("attn", "attn_local", "attn_enc"):
        if ctx.mode == "train" or mixer == "attn_enc":
            y = A.attn_train(p["mixer"], h, cfg, kind=mixer, ctx=ctx)
        elif ctx.mode == "prefill":
            y, new_cache = A.attn_prefill(p["mixer"], h, cfg, kind=mixer,
                                          ctx=ctx, cache=cache)
        else:
            y, new_cache = A.attn_decode(p["mixer"], h, cfg, kind=mixer,
                                         ctx=ctx, cache=cache)
    elif mixer == "attn_xdec":
        if ctx.mode == "train":
            y = A.attn_train(p["mixer"], h, cfg, kind="attn", ctx=ctx)
        elif ctx.mode == "prefill":
            y, new_cache = A.attn_prefill(p["mixer"], h, cfg, kind="attn",
                                          ctx=ctx, cache=cache)
        else:
            y, new_cache = A.attn_decode(p["mixer"], h, cfg, kind="attn",
                                         ctx=ctx, cache=cache)
        x = x + y
        hx = rmsnorm(x, p["norm_x"])
        if ctx.mode == "train":
            enc_kv = A.encode_cross_kv(p["cross"], ctx.enc_out, cfg, ctx.shd)
        elif ctx.mode == "prefill":
            enc_kv = A.encode_cross_kv(p["cross"], ctx.enc_out, cfg, ctx.shd)
            new_cache = {**new_cache, **enc_kv}
        else:
            enc_kv = {"xk": cache["xk"], "xv": cache["xv"]}
            new_cache = {**new_cache, "xk": cache["xk"], "xv": cache["xv"]}
        y = A.cross_attend(p["cross"], hx, enc_kv, cfg, ctx.shd)
    elif mixer == "rwkv":
        state = None
        if cache is not None:
            state = {"S": cache["S"], "x_prev": cache["x_prev"]}
        if ctx.mode == "decode":
            y, ns = R.rwkv_decode(p["mixer"], h, cfg, ctx=ctx, state=state)
        else:
            y, ns = R.rwkv_train(p["mixer"], h, cfg, ctx=ctx, state=state,
                                 chunk=ctx.rwkv_chunk)
        if cache is not None:
            new_cache = {**cache, **ns}
    elif mixer == "rglru":
        state = None
        if cache is not None:
            state = {"h": cache["h"], "conv": cache["conv"]}
        if ctx.mode == "decode":
            y, ns = G.rglru_decode(p["mixer"], h, cfg, ctx=ctx, state=state)
        else:
            y, ns = G.rglru_train(p["mixer"], h, cfg, ctx=ctx, state=state)
        if cache is not None:
            new_cache = ns
    else:
        raise ValueError(mixer)
    x = x + y

    h2 = rmsnorm(x, p["norm2"])
    if mlp_kind == "moe":
        y2, aux = M.moe_mlp(p["mlp"], h2, cfg, ctx.shd)
    elif mlp_kind == "cm":
        # channel-mix token shift: train shifts in-sequence; decode uses state
        if ctx.mode == "decode" and cache is not None:
            shift = cache["x_cm"][:, None]
        else:
            prev = (cache["x_cm"] if (cache is not None and ctx.mode == "prefill")
                    else jnp.zeros((h2.shape[0], h2.shape[-1]), h2.dtype))
            shift = jnp.concatenate([prev[:, None], h2[:, :-1]], axis=1)
        y2 = R.channelmix(p["mlp"], h2, shift, cfg, ctx.shd)
        if cache is not None and new_cache is not None:
            new_cache = {**new_cache, "x_cm": h2[:, -1]}
    else:
        y2 = mlp(p["mlp"], h2, ctx.shd)
    return x + y2, new_cache, aux


# ---------------------------------------------------------------------------
# Whisper encoder
# ---------------------------------------------------------------------------

def encode(params, enc_embeds, cfg: ModelConfig, ctx: Ctx):
    """enc_embeds [B, enc_len, d] — precomputed frame embeddings (stub)."""
    x = enc_embeds.astype(cfg.compute_dtype)

    def body(x, lp):
        x, _, _ = layer_fwd(lp, x, cfg, "attn_enc", "dense", ctx)
        return x, None

    f = jax.checkpoint(body) if ctx.remat else body
    x, _ = jax.lax.scan(f, x, params["encoder"]["layers"])
    return rmsnorm(x, params["encoder"]["norm"])


# ---------------------------------------------------------------------------
# Backbone drivers
# ---------------------------------------------------------------------------

def _run_layers(params, x, cfg: ModelConfig, ctx: Ctx, caches=None):
    """Interpret the layer program. Returns (x, new_caches, aux_total)."""
    cyc, n_full, rem = layer_plan(cfg)
    aux_total = jnp.zeros((), jnp.float32)
    new_caches: Dict[str, Any] = {}

    if n_full:
        cyc_params = params["cycles"]
        cyc_caches = caches["cycles"] if caches is not None else None

        def cycle_body(carry, xs):
            x, aux = carry
            lp = xs["p"]
            cc = xs.get("c") if caches is not None else None
            new_cc = {}
            for j, (mk, lk) in enumerate(cyc):
                cj = cc[f"p{j}"] if cc is not None else None
                x, ncj, a = layer_fwd(lp[f"p{j}"], x, cfg, mk, lk, ctx, cj)
                if cc is not None:
                    new_cc[f"p{j}"] = ncj
                aux = aux + a
            return (x, aux), (new_cc if cc is not None else None)

        body = cycle_body
        if ctx.remat:
            body = jax.checkpoint(cycle_body, policy=ctx.remat_policy,
                                  prevent_cse=False)
        xs = {"p": cyc_params}
        if caches is not None:
            xs["c"] = cyc_caches
        (x, aux_total), ys = jax.lax.scan(body, (x, aux_total), xs)
        if caches is not None:
            new_caches["cycles"] = ys

    for r, (mk, lk) in enumerate(rem):
        cj = caches.get(f"rest{r}") if caches is not None else None
        x, ncj, a = layer_fwd(params[f"rest{r}"], x, cfg, mk, lk, ctx, cj)
        aux_total = aux_total + a
        if caches is not None:
            new_caches[f"rest{r}"] = ncj
    return x, (new_caches if caches is not None else None), aux_total


def _maybe_merge_embeds(x, batch):
    """VLM early-fusion stub: splice precomputed patch embeddings in."""
    if "embeds" in batch and batch["embeds"] is not None:
        mask = batch["embed_mask"][..., None]
        x = jnp.where(mask, batch["embeds"].astype(x.dtype), x)
    return x


def forward_train(params, batch, cfg: ModelConfig, ctx: Ctx):
    """batch: tokens [B,S] (+ positions3 / embeds / enc_embeds). -> (logits, aux)."""
    if cfg.n_enc_layers:
        ctx.enc_out = encode(params, batch["enc_embeds"], cfg, ctx)
    if cfg.mrope_sections is not None:
        ctx.positions3 = batch["positions3"]
    x = embed(params["embed"], batch["tokens"], cfg, ctx.shd)
    x = _maybe_merge_embeds(x, batch)
    x, _, aux = _run_layers(params, x, cfg, ctx)
    x = rmsnorm(x, params["final_norm"])
    return lm_logits(params["embed"], x, cfg, ctx.shd), aux


def prefill(params, batch, cfg: ModelConfig, ctx: Ctx, caches):
    """Fill caches from a full prompt; returns (last-token logits, caches)."""
    ctx = dataclasses.replace(ctx, mode="prefill")
    if cfg.n_enc_layers:
        ctx.enc_out = encode(params, batch["enc_embeds"], cfg, ctx)
    if cfg.mrope_sections is not None:
        ctx.positions3 = batch["positions3"]
    x = embed(params["embed"], batch["tokens"], cfg, ctx.shd)
    x = _maybe_merge_embeds(x, batch)
    x, caches, _ = _run_layers(params, x, cfg, ctx, caches)
    x = rmsnorm(x[:, -1:], params["final_norm"])
    return lm_logits(params["embed"], x, cfg, ctx.shd), caches


def decode_step(params, token, caches, pos, cfg: ModelConfig, ctx: Ctx):
    """token [B] int32; pos scalar int32. Returns (logits [B,1,V], caches)."""
    ctx = dataclasses.replace(ctx, mode="decode", pos=pos)
    if cfg.mrope_sections is not None:
        B = token.shape[0]
        ctx.positions3 = jnp.full((B, 1, 3), pos, jnp.int32)
    x = embed(params["embed"], token[:, None], cfg, ctx.shd)
    x, caches, _ = _run_layers(params, x, cfg, ctx, caches)
    x = rmsnorm(x, params["final_norm"])
    return lm_logits(params["embed"], x, cfg, ctx.shd), caches
