"""Mixture-of-Experts MLP: top-k routing with sort-based capacity dispatch,
plus host-resident expert paging for oversubscribed decode.

TPU-native formulation (no per-token weight gathers): flatten the (token,
expert-choice) pairs, stable-sort by expert id, rank within expert segment by
a cumsum trick, scatter into a dense ``[E, C, d]`` buffer, run both expert
matmuls as batched einsums (sharded over the ``experts`` -> ``model`` mesh
axis = expert parallelism), gather back and combine with router weights.
Tokens beyond an expert's capacity ``C = ceil(T*k/E * cf)`` are dropped
(standard capacity-factor semantics; cf default 1.25).

``moe_ref`` is the O(T*E) oracle used by tests.

:class:`ExpertPager` + :func:`moe_decode_paged` are the oversubscription
path (ROADMAP item 4 / ``repro.core.oversub``): the stacked expert weights
live in host DRAM and only the experts the router actually selects are
paged into an LRU device-resident working set bounded by a
``MemoryBudget`` — a qwen3-30B-style model whose experts dwarf device
memory decodes by paying per-token expert fetches instead of OOMing.
Compute order is fixed (ascending expert id, f32 accumulate), so the
budgeted run is bit-identical to the everything-resident run — placement
never changes values.

The pager also runs a one-slab staging lookahead mirroring
:class:`~repro.core.program.AsyncExecutor`: while expert ``i`` computes,
a single background thread fetches expert ``i+1``'s slab
(:meth:`ExpertPager.prefetch`), and the fetch-behind-compute overlap is
accounted with the same :func:`~repro.core.program.interval_overlap`
arithmetic the async executor uses (``stats.prefetch_overlap_s``, plus
the ``moe_prefetch_overlap_s`` ledger gauge when a ledger is passed).
Prefetch changes *when* a slab moves, never *what* is computed — the
bit-parity claim above is untouched.
"""
from __future__ import annotations

import dataclasses
import threading
import time
from concurrent.futures import ThreadPoolExecutor
from typing import Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, MoEConfig
from repro.core import umem
from repro.core.program import interval_overlap
from repro.core.umem import MemSpace
from repro.models.layers import ParamSpec, noshard


def moe_specs(cfg: ModelConfig) -> dict:
    m = cfg.moe
    d, pd = cfg.d_model, cfg.param_dtype
    s = {
        "router": ParamSpec((d, m.n_experts), ("embed", "experts"), "float32"),
        "wi_gate": ParamSpec((m.n_experts, d, m.d_ff), ("experts", "embed", "moe_ff"), pd),
        "wi_up": ParamSpec((m.n_experts, d, m.d_ff), ("experts", "embed", "moe_ff"), pd),
        "wo": ParamSpec((m.n_experts, m.d_ff, d), ("experts", "moe_ff", "embed"), pd),
    }
    if m.shared_expert_ff:
        f = m.shared_expert_ff
        s["shared"] = {
            "wi_gate": ParamSpec((d, f), ("embed", "ff"), pd),
            "wi_up": ParamSpec((d, f), ("embed", "ff"), pd),
            "wo": ParamSpec((f, d), ("ff", "embed"), pd),
        }
    return s


def _router(p, x2, m: MoEConfig):
    """x2 [T, d] -> (gate_weights [T,k], expert_ids [T,k], aux_loss)."""
    logits = jnp.einsum("td,de->te", x2.astype(jnp.float32),
                        p["router"].astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)
    gate, idx = jax.lax.top_k(probs, m.top_k)
    gate = gate / jnp.maximum(gate.sum(-1, keepdims=True), 1e-9)
    # Switch-style load-balancing aux loss
    T, E = logits.shape
    density = jnp.mean(jax.nn.one_hot(idx[:, 0], E), axis=0)
    mean_probs = jnp.mean(probs, axis=0)
    aux = E * jnp.sum(density * mean_probs)
    return gate, idx, aux


def _capacity(T: int, m: MoEConfig) -> int:
    c = int(T * m.top_k * m.capacity_factor / m.n_experts)
    return max(8, -(-c // 8) * 8)   # round up to 8 lanes


def _largest_divisor(T: int, G: int) -> int:
    while G > 1 and T % G:
        G -= 1
    return max(G, 1)


def moe_mlp(p, x, cfg: ModelConfig, shd=noshard, n_groups: int = 16):
    """x [B, S, d] -> (y [B, S, d], aux_loss).

    GROUP-LOCAL dispatch (beyond-paper perf iteration, docs/EXPERIMENTS.md SPerf):
    tokens are split into G groups aligned with the data shards; routing,
    ranking and the capacity scatter/gather are all per-group (batched, so
    SPMD partitions them along G with no cross-shard collectives), and the
    only inter-shard movement left is the (G x E) buffer resharding for the
    expert matmuls — a proper all-to-all of token payloads instead of the
    global-argsort path's full-buffer all-reduces.
    """
    m = cfg.moe
    B, S, d = x.shape
    T = B * S
    E, k = m.n_experts, m.top_k
    G = _largest_divisor(T, n_groups)
    Tg = T // G
    C = _capacity(Tg, m)

    xg = shd(x.reshape(G, Tg, d), "expert_group", None, None)
    logits = jnp.einsum("gtd,de->gte", xg.astype(jnp.float32),
                        p["router"].astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)
    gate, idx = jax.lax.top_k(probs, k)              # [G,Tg,k]
    gate = gate / jnp.maximum(gate.sum(-1, keepdims=True), 1e-9)
    density = jnp.mean(jax.nn.one_hot(idx[..., 0], E), axis=(0, 1))
    aux = E * jnp.sum(density * jnp.mean(probs, axis=(0, 1)))

    fe = idx.reshape(G, Tg * k)                      # expert id per pair
    ft = jnp.repeat(jnp.arange(Tg)[None], G, 0).reshape(G, Tg, 1)
    ft = jnp.broadcast_to(jnp.arange(Tg)[None, :, None], (G, Tg, k)) \
        .reshape(G, Tg * k)
    grp = lambda t: shd(t, "expert_group", None)     # keep SPMD on the G axis
    order = grp(jnp.argsort(fe, axis=1, stable=True))
    se = grp(jnp.take_along_axis(fe, order, axis=1))
    st = grp(jnp.take_along_axis(ft, order, axis=1))
    counts = jnp.sum(jax.nn.one_hot(fe, E, dtype=jnp.int32), axis=1)  # [G,E]
    seg_start = jnp.cumsum(counts, axis=1) - counts
    rank = grp(jnp.arange(Tg * k)[None]
               - jnp.take_along_axis(seg_start, se, axis=1))
    keep = rank < C
    dst = grp(jnp.where(keep, se * C + rank, E * C))  # [G, Tg*k]

    def scatter_one(xg_, st_, dst_, keep_):
        upd = jnp.where(keep_[:, None], xg_[st_], 0)
        return jnp.zeros((E * C + 1, d), x.dtype).at[dst_].set(upd)

    buf = jax.vmap(scatter_one)(xg, st, dst, keep)   # [G, E*C+1, d]
    h = buf[:, : E * C].reshape(G, E, C, d)
    h = shd(h, "expert_group", "experts", None, None)
    g_ = jnp.einsum("gecd,edf->gecf", h, p["wi_gate"])
    u = jnp.einsum("gecd,edf->gecf", h, p["wi_up"])
    o = jax.nn.silu(g_.astype(jnp.float32)).astype(x.dtype) * u
    o = jnp.einsum("gecf,efd->gecd", o, p["wo"])
    o = shd(o, "expert_group", "experts", None, None)

    def gather_one(o_, dst_, st_, gate_s):
        o_flat = jnp.concatenate([o_.reshape(E * C, d),
                                  jnp.zeros((1, d), x.dtype)], 0)
        per_pair = o_flat[dst_].astype(jnp.float32) * gate_s[:, None]
        return jnp.zeros((Tg, d), jnp.float32).at[st_].add(per_pair)

    gate_sorted = grp(jnp.take_along_axis(gate.reshape(G, Tg * k), order,
                                          axis=1))
    yg = jax.vmap(gather_one)(o, dst, st, gate_sorted)   # [G,Tg,d] f32
    yg = shd(yg.astype(x.dtype), "expert_group", None, None)
    y = yg.reshape(B, S, d)
    y = shd(y, "batch", None, None)

    if m.shared_expert_ff:
        sp = p["shared"]
        sg = jnp.einsum("btd,df->btf", x, sp["wi_gate"])
        su = jnp.einsum("btd,df->btf", x, sp["wi_up"])
        sh = jax.nn.silu(sg.astype(jnp.float32)).astype(x.dtype) * su
        y = y + jnp.einsum("btf,fd->btd", sh, sp["wo"])
    return y, aux


def moe_ref(p, x, cfg: ModelConfig):
    """O(T*E) dense oracle: every expert on every token, masked combine.
    No capacity drops — tests compare against moe_mlp with cf large enough
    that nothing drops."""
    m = cfg.moe
    B, S, d = x.shape
    x2 = x.reshape(-1, d)
    gate, idx, aux = _router(p, x2, m)
    g = jnp.einsum("td,edf->tef", x2, p["wi_gate"])
    u = jnp.einsum("td,edf->tef", x2, p["wi_up"])
    o = jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype) * u
    o = jnp.einsum("tef,efd->ted", o, p["wo"])       # [T,E,d]
    mask = jax.nn.one_hot(idx, m.n_experts, dtype=jnp.float32)  # [T,k,E]
    w = (mask * gate[..., None]).sum(1)              # [T,E]
    y = jnp.einsum("ted,te->td", o.astype(jnp.float32), w).astype(x.dtype)
    y = y.reshape(B, S, d)
    if m.shared_expert_ff:
        sp = p["shared"]
        sg = jnp.einsum("btd,df->btf", x.reshape(B, S, d), sp["wi_gate"])
        su = jnp.einsum("btd,df->btf", x.reshape(B, S, d), sp["wi_up"])
        sh = jax.nn.silu(sg.astype(jnp.float32)).astype(x.dtype) * su
        y = y + jnp.einsum("btf,fd->btd", sh, sp["wo"])
    return y, aux


# ---------------------------------------------------------------------------
# Host-resident expert paging (oversubscribed decode)
# ---------------------------------------------------------------------------

#: the stacked per-expert weight matrices the pager slices slabs from
EXPERT_KEYS = ("wi_gate", "wi_up", "wo")


@dataclasses.dataclass
class PagingStats:
    fetches: int = 0                # host -> device expert slab moves
    hits: int = 0                   # expert already device-resident
    evictions: int = 0              # LRU slabs dropped to fit the budget
    bytes_fetched: int = 0
    prefetch_hits: int = 0          # fetches satisfied by the lookahead
    prefetch_overlap_s: float = 0.0  # fetch time hidden behind compute

    def as_dict(self) -> dict:
        return dataclasses.asdict(self)


class ExpertPager:
    """LRU device-resident working set of expert weight slabs over
    host-resident stacks, bounded by a
    :class:`~repro.core.oversub.MemoryBudget`.

    The stacked ``wi_gate``/``wi_up``/``wo`` parameters (``[E, ...]``) are
    parked in host DRAM through the placement axis; :meth:`get` pages one
    expert's slab (``wi_gate [d,f]``, ``wi_up [d,f]``, ``wo [f,d]``) to
    the device on demand and evicts least-recently-used slabs until the
    working set fits the budget again.  The tiny router matrix stays
    device-resident — routing must run before the pager knows which
    experts the token needs.  On the CPU container the host/device moves
    are logical (docs/DESIGN.md §2); the claim structure — budget-bounded
    resident high-water, fetch/hit/eviction counts, bit-parity with the
    resident run — is what the tests assert."""

    def __init__(self, p, cfg: ModelConfig, budget=None,
                 host_space: Optional[MemSpace] = None,
                 lookahead: bool = True):
        m = cfg.moe
        self.n_experts = m.n_experts
        self.budget = budget
        host = host_space or umem.preferred_host_space()
        self.router = p["router"]              # device-resident by design
        self.shared = p.get("shared")
        self._host = {k: umem.place(p[k], host) if host is not None else p[k]
                      for k in EXPERT_KEYS}
        self.slab_bytes = sum(int(p[k][0].nbytes) for k in EXPERT_KEYS)
        self._resident: Dict[int, dict] = {}   # expert id -> slab (LRU order)
        self.stats = PagingStats()
        self.lookahead = lookahead
        self._lock = threading.Lock()
        self._pending: Dict[int, object] = {}  # expert id -> Future
        self._pf_pool = None                   # created on first prefetch

    @property
    def footprint_bytes(self) -> int:
        """Device bytes an everything-resident run would pin — the
        numerator of the oversubscription ratio."""
        return self.slab_bytes * self.n_experts

    @property
    def resident_bytes(self) -> int:
        return self.slab_bytes * len(self._resident)

    def _fetch_slab(self, e: int) -> tuple:
        """Page expert ``e`` device-ward; returns (slab, t0, t1) with the
        materialized fetch interval (the span overlap accounting uses)."""
        t0 = time.perf_counter()
        slab = {k: umem.place(self._host[k][e], MemSpace.DEVICE)
                for k in EXPERT_KEYS}
        for v in slab.values():
            jax.block_until_ready(v)
        return slab, t0, time.perf_counter()

    def prefetch(self, e: int) -> None:
        """Hint that expert ``e`` is needed next: start fetching its slab
        on the single staging thread while the caller computes the current
        expert (one-step lookahead — AsyncExecutor's contract applied to
        expert slabs).  No-op when the slab is resident, already in
        flight, or ``lookahead`` is off.  Budget charging and eviction
        happen when :meth:`get` installs the slab, so the one in-flight
        slab is the only budget slack the lookahead adds — the same
        next-bank allowance AsyncExecutor's double buffer carries."""
        e = int(e)
        if not self.lookahead:
            return
        with self._lock:
            if e in self._resident or e in self._pending:
                return
            if self._pf_pool is None:
                self._pf_pool = ThreadPoolExecutor(
                    max_workers=1, thread_name_prefix="expert-prefetch")
            self._pending[e] = self._pf_pool.submit(self._fetch_slab, e)

    def get(self, e: int, compute_spans=None) -> dict:
        """The device-resident slab of expert ``e``, fetching and evicting
        as the budget requires.  A slab arriving via :meth:`prefetch`
        still counts as a fetch (the bytes moved); the time its fetch hid
        behind the caller's ``compute_spans`` intervals accrues to
        ``stats.prefetch_overlap_s``."""
        e = int(e)
        with self._lock:
            slab = self._resident.pop(e, None)
            if slab is not None:
                self._resident[e] = slab       # re-insert = LRU touch
                self.stats.hits += 1
                return slab
            fut = self._pending.pop(e, None)
        if fut is not None:
            slab, t0, t1 = fut.result()
            self.stats.prefetch_hits += 1
            if compute_spans:
                self.stats.prefetch_overlap_s += interval_overlap(
                    t0, t1, compute_spans)
        else:
            slab, _, _ = self._fetch_slab(e)
        with self._lock:
            self._resident[e] = slab
            self.stats.fetches += 1
            self.stats.bytes_fetched += self.slab_bytes
            if self.budget is not None:
                self.budget.charge(self.slab_bytes)
                # shed LRU slabs until we fit again — but never the slab
                # the caller is about to compute with
                while self.budget.over and len(self._resident) > 1:
                    victim = next(iter(self._resident))
                    if victim == e:
                        break
                    self._resident.pop(victim)
                    self.budget.release(self.slab_bytes)
                    self.stats.evictions += 1
        return slab

    def drop(self) -> None:
        """Release the whole resident set (end of a decode stream)."""
        with self._lock:
            pending = list(self._pending.values())
            self._pending.clear()
        for fut in pending:
            fut.cancel()                       # running fetches just expire
        if self.budget is not None:
            self.budget.release(self.resident_bytes)
        self._resident.clear()


def moe_decode_paged(pager: ExpertPager, x, cfg: ModelConfig, ledger=None):
    """x [B, S, d] -> (y [B, S, d], aux_loss), computing only the experts
    the router selects, each through :meth:`ExpertPager.get`.

    Dense per-expert compute over all T tokens (decode-sized T makes that
    cheap) with a FIXED accumulation order — ascending expert id, f32
    accumulate, per-token gate mask — so the output is a pure function of
    the values, not of which slabs happened to be resident: budgeted and
    unbudgeted runs are bit-identical.  Matches ``moe_ref`` to tolerance
    (its lane order differs), which the tests also pin.

    Before computing expert ``i`` the loop prefetches expert ``i+1``
    (ascending order is fixed, so the lookahead is exact, not a guess);
    each expert's compute interval is recorded so the pager can account
    how much of the next fetch hid behind it.  With a ``ledger``, the
    cumulative hidden time lands on the ``moe_prefetch_overlap_s``
    gauge."""
    m = cfg.moe
    B, S, d = x.shape
    x2 = x.reshape(-1, d)
    gate, idx, aux = _router({"router": pager.router}, x2, m)
    gate_np = np.asarray(gate)                 # [T,k] f32
    idx_np = np.asarray(idx)                   # [T,k]
    y = jnp.zeros((B * S, d), jnp.float32)
    experts = sorted({int(v) for v in idx_np.ravel()})
    hits0 = pager.stats.prefetch_hits
    spans = []                       # compute intervals the fetches hide in
    for i, e in enumerate(experts):
        if i + 1 < len(experts):
            pager.prefetch(experts[i + 1])
        w = pager.get(e, compute_spans=spans)
        t0 = time.perf_counter()
        we = jnp.asarray((gate_np * (idx_np == e)).sum(-1), jnp.float32)
        g = jnp.einsum("td,df->tf", x2, w["wi_gate"])
        u = jnp.einsum("td,df->tf", x2, w["wi_up"])
        o = jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype) * u
        o = jnp.einsum("tf,fd->td", o, w["wo"])
        y = jax.block_until_ready(y + o.astype(jnp.float32) * we[:, None])
        spans.append((t0, time.perf_counter()))
    y = y.astype(x.dtype).reshape(B, S, d)
    if ledger is not None:
        ledger.serve_gauge("moe_prefetch_overlap_s",
                           pager.stats.prefetch_overlap_s)
        new_hits = pager.stats.prefetch_hits - hits0
        if new_hits:
            ledger.serve_record("moe_prefetch_hit", new_hits)
    if m.shared_expert_ff and pager.shared is not None:
        sp = pager.shared
        sg = jnp.einsum("btd,df->btf", x, sp["wi_gate"])
        su = jnp.einsum("btd,df->btf", x, sp["wi_up"])
        sh = jax.nn.silu(sg.astype(jnp.float32)).astype(x.dtype) * su
        y = y + jnp.einsum("btf,fd->btd", sh, sp["wo"])
    return y, aux
