"""Parameter-spec machinery.

A model is described as a pytree of :class:`ParamSpec` (shape + logical axis
names + init law). From that single source of truth we derive:

* real parameters        — ``init_params(key, specs)`` (works under
  ``jax.eval_shape`` for the dry-run: no allocation needed there),
* sharding               — ``repro.launch.sharding`` maps logical axis names
  to mesh axes per the parallelism rules,
* abstract inputs        — ``jax.ShapeDtypeStruct`` stand-ins for lowering.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class ParamSpec:
    shape: Tuple[int, ...]
    axes: Tuple[Optional[str], ...]      # logical axis names (len == ndim)
    dtype: str = "bfloat16"
    init: str = "normal"                 # normal | zeros | ones | rwkv_decay
    scale: Optional[float] = None        # default: 1/sqrt(fan_in)

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)

    @property
    def sds(self) -> jax.ShapeDtypeStruct:
        return jax.ShapeDtypeStruct(self.shape, jnp.dtype(self.dtype))


def is_spec(x) -> bool:
    return isinstance(x, ParamSpec)


def tree_specs(tree):
    """Flatten treating ParamSpec as leaves."""
    return jax.tree_util.tree_flatten(tree, is_leaf=is_spec)


def init_one(key: jax.Array, spec: ParamSpec) -> jax.Array:
    if spec.init == "zeros":
        return jnp.zeros(spec.shape, spec.dtype)
    if spec.init == "ones":
        return jnp.ones(spec.shape, spec.dtype)
    if spec.init == "rwkv_decay":
        # w0 init so that exp(-exp(w0)) spans useful decay range per channel
        n = int(np.prod(spec.shape)) if spec.shape else 1
        ramp = jnp.linspace(-6.0, 1.0, n).reshape(spec.shape or ())
        return ramp.astype(spec.dtype)
    fan_in = spec.shape[0] if len(spec.shape) >= 2 else max(spec.shape[-1] if spec.shape else 1, 1)
    scale = spec.scale if spec.scale is not None else 1.0 / np.sqrt(fan_in)
    return (jax.random.normal(key, spec.shape, jnp.float32) * scale).astype(spec.dtype)


def init_params(key: jax.Array, specs):
    leaves, treedef = tree_specs(specs)
    keys = jax.random.split(key, max(len(leaves), 1))
    arrs = [init_one(k, s) for k, s in zip(keys, leaves)]
    return jax.tree_util.tree_unflatten(treedef, arrs)


def abstract_params(specs):
    """ShapeDtypeStruct pytree for .lower() without allocation."""
    leaves, treedef = tree_specs(specs)
    return jax.tree_util.tree_unflatten(treedef, [s.sds for s in leaves])


def stack_specs(specs, n: int, axis_name: str = "layers"):
    """Add a leading stacking dimension (for lax.scan over layers)."""
    leaves, treedef = tree_specs(specs)
    stacked = [
        ParamSpec((n,) + s.shape, (axis_name,) + s.axes, s.dtype, s.init, s.scale)
        for s in leaves
    ]
    return jax.tree_util.tree_unflatten(treedef, stacked)


def param_count(specs) -> int:
    leaves, _ = tree_specs(specs)
    return int(sum(np.prod(s.shape) for s in leaves))


def param_bytes(specs) -> int:
    leaves, _ = tree_specs(specs)
    return int(sum(np.prod(s.shape) * jnp.dtype(s.dtype).itemsize for s in leaves))
