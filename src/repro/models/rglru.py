"""RecurrentGemma / Griffin recurrent block: temporal conv + RG-LRU.

RG-LRU recurrence (Griffin, arXiv:2402.19427):

    r_t = sigmoid(W_a x_t + b_a)          (recurrence gate)
    i_t = sigmoid(W_x x_t + b_x)          (input gate)
    a_t = exp(-c * softplus(L) * r_t)      c = 8, L learned (per channel)
    h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t)

The block is: y = W_out( GeLU(W_gate u) * RGLRU(conv1d(W_in u)) ).
Training uses ``jax.lax.associative_scan`` over the sequence (log-depth —
TPU-friendly; the recurrence is elementwise so the scan is pure VPU work).
Decode is a single fused step carrying (h, conv window) state.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import ParamSpec, noshard

RG_C = 8.0


def rglru_specs(cfg: ModelConfig) -> dict:
    d = cfg.d_model
    w = cfg.rnn_width or d
    pd = cfg.param_dtype
    return {
        "w_in": ParamSpec((d, w), ("embed", "rnn"), pd),
        "w_gate": ParamSpec((d, w), ("embed", "rnn"), pd),
        "conv_w": ParamSpec((cfg.conv_width, w), (None, "rnn"), "float32",
                            "normal", 0.3),
        "conv_b": ParamSpec((w,), ("rnn",), "float32", "zeros"),
        "wa": ParamSpec((w, w), ("rnn", "rnn2"), pd),
        "wx": ParamSpec((w, w), ("rnn", "rnn2"), pd),
        "ba": ParamSpec((w,), ("rnn",), "float32", "zeros"),
        "bx": ParamSpec((w,), ("rnn",), "float32", "zeros"),
        "lam": ParamSpec((w,), ("rnn",), "float32", "normal", 1.0),
        "w_out": ParamSpec((w, d), ("rnn", "embed"), pd),
    }


def rglru_state_specs(cfg: ModelConfig, batch: int) -> dict:
    w = cfg.rnn_width or cfg.d_model
    return {
        "h": ParamSpec((batch, w), ("batch", "rnn"), "float32", "zeros"),
        "conv": ParamSpec((batch, cfg.conv_width - 1, w), ("batch", None, "rnn"),
                          cfg.compute_dtype, "zeros"),
    }


def _gates(p, xc):
    """xc [B,T,w] (post-conv) -> (log_a, beta*ix) in fp32."""
    xf = xc.astype(jnp.float32)
    r = jax.nn.sigmoid(jnp.einsum("btw,wu->btu", xc, p["wa"]).astype(jnp.float32)
                       + p["ba"])
    i = jax.nn.sigmoid(jnp.einsum("btw,wu->btu", xc, p["wx"]).astype(jnp.float32)
                       + p["bx"])
    log_a = -RG_C * jax.nn.softplus(p["lam"]) * r           # <= 0
    a2 = jnp.exp(2.0 * log_a)
    beta = jnp.sqrt(jnp.maximum(1.0 - a2, 1e-12))
    return log_a, beta * (i * xf)


def _conv1d(p, x, conv_state):
    """Causal depthwise temporal conv, width K. x [B,T,w]."""
    K = p["conv_w"].shape[0]
    xpad = jnp.concatenate([conv_state.astype(x.dtype), x], axis=1)  # [B,T+K-1,w]
    out = jnp.zeros_like(x, dtype=jnp.float32)
    for j in range(K):
        out = out + xpad[:, j:j + x.shape[1]].astype(jnp.float32) * p["conv_w"][j]
    out = out + p["conv_b"]
    new_state = xpad[:, -(K - 1):] if K > 1 else conv_state
    return out.astype(x.dtype), new_state


def rglru_train(p, x, cfg: ModelConfig, *, ctx, state=None):
    """x [B,T,d] -> (y [B,T,d], new_state)."""
    shd = ctx.shd
    B, T, d = x.shape
    w = cfg.rnn_width or d
    u = shd(jnp.einsum("btd,dw->btw", x, p["w_in"]), "batch", None, "rnn")
    gate = jnp.einsum("btd,dw->btw", x, p["w_gate"])
    if state is None:
        conv_state = jnp.zeros((B, cfg.conv_width - 1, w), x.dtype)
        h0 = jnp.zeros((B, w), jnp.float32)
    else:
        conv_state, h0 = state["conv"], state["h"]
    xc, new_conv = _conv1d(p, u, conv_state)
    log_a, b = _gates(p, xc)
    # h_t = a_t h_{t-1} + b_t, with h_0 folded in as an extra leading element
    a_seq = jnp.exp(log_a)
    a_all = jnp.concatenate([jnp.ones((B, 1, w)), a_seq], axis=1)
    b_all = jnp.concatenate([h0[:, None], b], axis=1)

    def combine(c1, c2):
        a1, b1 = c1
        a2, b2 = c2
        return a1 * a2, a2 * b1 + b2

    aa, hh = jax.lax.associative_scan(combine, (a_all, b_all), axis=1)
    h = hh[:, 1:]                                            # [B,T,w]
    y = h.astype(x.dtype) * jax.nn.gelu(gate.astype(jnp.float32)).astype(x.dtype)
    y = shd(jnp.einsum("btw,wd->btd", y, p["w_out"]), "batch", None, None)
    return y, {"h": hh[:, -1], "conv": new_conv}


def rglru_decode(p, x1, cfg: ModelConfig, *, ctx, state):
    """Single token step. x1 [B,1,d]."""
    B, _, d = x1.shape
    u = jnp.einsum("btd,dw->btw", x1, p["w_in"])
    gate = jnp.einsum("btd,dw->btw", x1, p["w_gate"])
    xc, new_conv = _conv1d(p, u, state["conv"])
    log_a, b = _gates(p, xc)
    h = jnp.exp(log_a[:, 0]) * state["h"] + b[:, 0]
    y = h[:, None].astype(x1.dtype) * jax.nn.gelu(
        gate.astype(jnp.float32)).astype(x1.dtype)
    y = jnp.einsum("btw,wd->btd", y, p["w_out"])
    return y, {"h": h, "conv": new_conv}


def rglru_ref(p, x, cfg: ModelConfig, state=None):
    """Sequential oracle for tests."""
    B, T, d = x.shape
    w = cfg.rnn_width or d
    u = jnp.einsum("btd,dw->btw", x, p["w_in"])
    gate = jnp.einsum("btd,dw->btw", x, p["w_gate"])
    conv_state = (state["conv"] if state is not None
                  else jnp.zeros((B, cfg.conv_width - 1, w), x.dtype))
    h = state["h"] if state is not None else jnp.zeros((B, w), jnp.float32)
    xc, _ = _conv1d(p, u, conv_state)
    log_a, b = _gates(p, xc)
    outs = []
    for t in range(T):
        h = jnp.exp(log_a[:, t]) * h + b[:, t]
        outs.append(h)
    hs = jnp.stack(outs, axis=1)
    y = hs.astype(x.dtype) * jax.nn.gelu(gate.astype(jnp.float32)).astype(x.dtype)
    return jnp.einsum("btw,wd->btd", y, p["w_out"])
