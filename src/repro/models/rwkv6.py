"""RWKV6 "Finch" time-mix: data-dependent per-channel decay linear attention.

Semantics (the sequential oracle, per head; r,k,w,u in R^dk, v in R^dv):

    out_t[j] = sum_i r_t[i] * (S_{t-1}[i,j] + u[i] k_t[i] v_t[j])
    S_t[i,j] = w_t[i] * S_{t-1}[i,j] + k_t[i] * v_t[j]

Training/prefill uses a *chunked* closed form (log-space-safe: every exponent
is a cumulative-decay difference with t >= i, hence <= 0 — no overflow):

    la_t   = cumsum(log w)                (within chunk, la_0 = 0)
    inter  = (r_t * exp(la_{t-1})) @ S_in
    intra  = sum_{i<t} [sum_d r_t k_i exp(la_{t-1,d} - la_{i,d})] v_i
           + (r_t . (u*k_t)) v_t
    S_out  = diag(exp(la_C)) S_in + sum_i (k_i * exp(la_C - la_i)) v_i^T

``repro.kernels.rwkv6_scan`` implements the same chunked math as a Pallas
kernel; this module is the pure-JAX path and the kernels' semantics anchor.
The scan is declared once as the :data:`RWKV6_SCAN` region with three
variants — ``ref`` (sequential oracle), ``chunked`` (closed form below),
``pallas`` (the kernel) — selected per call by the executing policy
(docs/VARIANTS.md) or explicitly via ``rwkv_train(..., impl=...)``.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core.regions import region
from repro.models.layers import ParamSpec, noshard, rmsnorm

LORA_R = 32  # rank of the ddlerp / decay adapters (RWKV6 uses 32/64)


def rwkv_specs(cfg: ModelConfig) -> dict:
    d, H, hd = cfg.d_model, cfg.n_heads, cfg.hd
    pd = cfg.param_dtype
    adapters = {}
    for nm in ("r", "k", "v", "g", "w"):
        adapters[f"mu_{nm}"] = ParamSpec((d,), ("embed",), "float32", "zeros")
        adapters[f"A_{nm}"] = ParamSpec((d, LORA_R), ("embed", None), pd)
        adapters[f"B_{nm}"] = ParamSpec((LORA_R, d), (None, "embed"), pd, "zeros")
    return {
        **adapters,
        "wr": ParamSpec((d, H, hd), ("embed", "q_heads", "head_dim"), pd),
        "wk": ParamSpec((d, H, hd), ("embed", "q_heads", "head_dim"), pd),
        "wv": ParamSpec((d, H, hd), ("embed", "q_heads", "head_dim"), pd),
        "wg": ParamSpec((d, H, hd), ("embed", "q_heads", "head_dim"), pd),
        "w0": ParamSpec((H, hd), ("q_heads", "head_dim"), "float32", "rwkv_decay"),
        "u": ParamSpec((H, hd), ("q_heads", "head_dim"), "float32", "zeros"),
        "ln_out": ParamSpec((H, hd), ("q_heads", "head_dim"), "float32", "ones"),
        "wo": ParamSpec((H, hd, d), ("q_heads", "head_dim", "embed"), pd),
    }


def rwkv_state_specs(cfg: ModelConfig, batch: int) -> dict:
    H, hd, d = cfg.n_heads, cfg.hd, cfg.d_model
    return {
        "S": ParamSpec((batch, H, hd, hd), ("batch", "q_heads", None, None),
                       "float32", "zeros"),
        "x_prev": ParamSpec((batch, d), ("batch", None), cfg.compute_dtype, "zeros"),
    }


def _ddlerp(p, nm, x, x_prev):
    """Data-dependent token-shift lerp (RWKV6): x + (x_prev - x) * mix."""
    dx = (x_prev - x).astype(jnp.float32)
    base = x.astype(jnp.float32) + dx * p[f"mu_{nm}"]
    lora = jnp.tanh(base.astype(p[f"A_{nm}"].dtype) @ p[f"A_{nm}"]) @ p[f"B_{nm}"]
    mix = p[f"mu_{nm}"] + lora.astype(jnp.float32)
    return (x.astype(jnp.float32) + dx * mix).astype(x.dtype)


def _projections(p, x, x_prev, cfg: ModelConfig):
    """Token-shifted projections. x [B,T,d]; x_prev [B,T,d] (shifted input)."""
    r = jnp.einsum("btd,dhk->bthk", _ddlerp(p, "r", x, x_prev), p["wr"])
    k = jnp.einsum("btd,dhk->bthk", _ddlerp(p, "k", x, x_prev), p["wk"])
    v = jnp.einsum("btd,dhk->bthk", _ddlerp(p, "v", x, x_prev), p["wv"])
    g = jnp.einsum("btd,dhk->bthk", _ddlerp(p, "g", x, x_prev), p["wg"])
    xw = _ddlerp(p, "w", x, x_prev).astype(jnp.float32)
    wlora = jnp.tanh(xw.astype(p["A_w"].dtype) @ p["A_w"]) @ p["B_w"]
    dproj = wlora.astype(jnp.float32).reshape(*x.shape[:2], cfg.n_heads, cfg.hd)
    logw = -jnp.exp(jnp.clip(p["w0"] + dproj, -8.0, 6.0))   # log-decay <= 0
    logw = jnp.maximum(logw, -12.0)                          # floor for stability
    return r, k, v, g, logw


def rwkv_chunk(r, k, v, logw, u, S_in, chunk: int):
    """Chunked linear-attention scan over the T axis.

    r,k,v [B,T,H,hd] (compute dtype); logw [B,T,H,hd] fp32; u [H,hd] fp32;
    S_in [B,H,hd,hd] fp32. Returns (out [B,T,H,hd] fp32, S_out).
    """
    B, T, H, hd = r.shape
    C = min(chunk, T)
    n = T // C
    assert T % C == 0, (T, C)
    rs = r.astype(jnp.float32).reshape(B, n, C, H, hd)
    ks = k.astype(jnp.float32).reshape(B, n, C, H, hd)
    vs = v.astype(jnp.float32).reshape(B, n, C, H, hd)
    lw = logw.reshape(B, n, C, H, hd)

    def body(S, xs):
        rc, kc, vc, lwc = xs                           # [B,C,H,hd]
        la = jnp.cumsum(lwc, axis=1)                   # la_t, t=1..C
        la_prev = la - lwc                             # la_{t-1}
        rA = rc * jnp.exp(la_prev)
        inter = jnp.einsum("bthi,bhij->bthj", rA, S)
        # intra: pairwise decay differences (exponent <= 0 by construction)
        D = la_prev[:, :, None] - la[:, None, :]       # [B,C(t),C(i),H,hd]
        mask = (jnp.arange(C)[:, None] > jnp.arange(C)[None, :])
        D = jnp.where(mask[None, :, :, None, None], D, -jnp.inf)
        att = jnp.einsum("bthd,bihd,btihd->btih", rc, kc, jnp.exp(D))
        diag = jnp.einsum("bthd,bthd,hd->bth", rc, kc, u)
        att = att + diag[:, :, None] * jnp.eye(C)[None, :, :, None]
        intra = jnp.einsum("btih,bihj->bthj", att, vc)
        out_c = inter + intra
        la_C = la[:, -1]                               # [B,H,hd]
        kA = kc * jnp.exp(la_C[:, None] - la)
        S_new = jnp.exp(la_C)[..., None] * S + jnp.einsum(
            "bthi,bthj->bhij", kA, vc)
        return S_new, out_c

    xs = (jnp.moveaxis(rs, 1, 0), jnp.moveaxis(ks, 1, 0),
          jnp.moveaxis(vs, 1, 0), jnp.moveaxis(lw, 1, 0))
    S_out, outs = jax.lax.scan(body, S_in, xs)
    out = jnp.moveaxis(outs, 0, 1).reshape(B, T, H, hd)
    return out, S_out


def rwkv_ref_scan(r, k, v, logw, u, S_in):
    """Sequential oracle (tests / kernels ref)."""
    B, T, H, hd = r.shape

    def step(S, xs):
        rt, kt, vt, lwt = [a.astype(jnp.float32) for a in xs]
        out = jnp.einsum("bhi,bhij->bhj", rt, S) + \
            jnp.einsum("bhi,hi,bhi,bhj->bhj", rt, u, kt, vt)
        S = jnp.exp(lwt)[..., None] * S + kt[..., None] * vt[..., None, :]
        return S, out

    xs = tuple(jnp.moveaxis(a, 1, 0) for a in (r, k, v, logw))
    S_out, outs = jax.lax.scan(step, S_in, xs)
    return jnp.moveaxis(outs, 0, 1), S_out


# ---------------------------------------------------------------------------
# The scan as ONE region with declared implementation variants
# ---------------------------------------------------------------------------

def _chunk_size(T: int, cap: int = 64) -> int:
    """Largest chunk <= cap that divides T (shapes are static under jit)."""
    return max(c for c in range(1, min(cap, T) + 1) if T % c == 0)


@region("rwkv6(scan)")
def RWKV6_SCAN(r, k, v, logw, u, S_in):
    """Time-mix scan from state ``S_in`` — the ``ref`` variant is the
    sequential oracle (exact recurrence, one token at a time)."""
    return rwkv_ref_scan(r, k, v, logw, u, S_in)


@RWKV6_SCAN.variant("chunked")
def _scan_chunked(r, k, v, logw, u, S_in):
    return rwkv_chunk(r, k, v, logw, u, S_in, _chunk_size(r.shape[1]))


@RWKV6_SCAN.variant("pallas")
def _scan_pallas(r, k, v, logw, u, S_in):
    # the kernel runs the zero-state scan; the recurrence is linear in the
    # state, so S_in superposes afterwards: out_t += (r_t * exp(la_{t-1}))
    # @ S_in and S_final += exp(la_T) * S_in (la = running decay sum)
    from repro.kernels.rwkv6_scan import kernel as K
    out, S_out = K.rwkv6_scan(r, k, v, logw, u,
                              chunk=_chunk_size(r.shape[1], K.CHUNK))
    la = jnp.cumsum(logw.astype(jnp.float32), axis=1)
    la_prev = la - logw
    out = out + jnp.einsum("bthi,bhij->bthj",
                           r.astype(jnp.float32) * jnp.exp(la_prev), S_in)
    S_out = S_out + jnp.exp(la[:, -1])[..., None] * S_in
    return out, S_out


def rwkv_train(p, x, cfg: ModelConfig, *, ctx, state=None, chunk: int = 64,
               impl: Optional[str] = None):
    """Full-sequence time-mix. Returns (y, new_state).

    ``impl`` names a registered variant of :data:`RWKV6_SCAN` (``ref`` /
    ``chunked`` / ``pallas``); the default keeps the chunked closed form
    with the caller's ``chunk`` — identical to the pre-variants behavior.
    """
    B, T, d = x.shape
    x_prev_tok = state["x_prev"] if state is not None else jnp.zeros((B, d), x.dtype)
    x_shift = jnp.concatenate([x_prev_tok[:, None], x[:, :-1]], axis=1)
    r, k, v, g, logw = _projections(p, x, x_shift, cfg)
    S_in = (state["S"] if state is not None
            else jnp.zeros((B, cfg.n_heads, cfg.hd, cfg.hd), jnp.float32))
    if impl is None:
        out, S_out = rwkv_chunk(r, k, v, logw, p["u"], S_in, chunk)
    else:
        scan = RWKV6_SCAN.impl_fn(RWKV6_SCAN.resolve(impl))
        out, S_out = scan(r, k, v, logw, p["u"], S_in)
    # per-head groupnorm then output gate
    out = rmsnorm(out.reshape(B, T, cfg.n_heads, cfg.hd),
                  jnp.ones((cfg.hd,), jnp.float32)) * p["ln_out"].astype(out.dtype)
    out = out.astype(x.dtype) * jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype)
    y = jnp.einsum("bthk,hkd->btd", out, p["wo"])
    y = ctx.shd(y, "batch", None, None)
    new_state = {"S": S_out, "x_prev": x[:, -1]}
    return y, new_state


def rwkv_decode(p, x1, cfg: ModelConfig, *, ctx, state):
    """Single-token step: O(1) in sequence length. x1 [B,1,d]."""
    B, _, d = x1.shape
    x_shift = state["x_prev"][:, None]
    r, k, v, g, logw = _projections(p, x1, x_shift, cfg)
    out, S_out = rwkv_ref_scan(r, k, v, logw, p["u"], state["S"])
    out = rmsnorm(out.reshape(B, 1, cfg.n_heads, cfg.hd),
                  jnp.ones((cfg.hd,), jnp.float32)) * p["ln_out"].astype(out.dtype)
    out = out.astype(x1.dtype) * jax.nn.silu(g.astype(jnp.float32)).astype(x1.dtype)
    y = jnp.einsum("bthk,hkd->btd", out, p["wo"])
    return y, {"S": S_out, "x_prev": x1[:, 0]}


# ---------------------------------------------------------------------------
# RWKV channel-mix (the MLP of rwkv layers)
# ---------------------------------------------------------------------------

def channelmix_specs(cfg: ModelConfig) -> dict:
    d, f, pd = cfg.d_model, cfg.d_ff, cfg.param_dtype
    return {
        "mu_k": ParamSpec((d,), ("embed",), "float32", "zeros"),
        "mu_r": ParamSpec((d,), ("embed",), "float32", "zeros"),
        "wk": ParamSpec((d, f), ("embed", "ff"), pd),
        "wv": ParamSpec((f, d), ("ff", "embed"), pd),
        "wr": ParamSpec((d, d), ("embed", "embed2"), pd),
    }


def channelmix(p, x, x_shift, cfg: ModelConfig, shd=noshard):
    xf, sf = x.astype(jnp.float32), x_shift.astype(jnp.float32)
    xk = (xf + (sf - xf) * p["mu_k"]).astype(x.dtype)
    xr = (xf + (sf - xf) * p["mu_r"]).astype(x.dtype)
    k = jnp.einsum("btd,df->btf", xk, p["wk"])
    k = jnp.square(jax.nn.relu(k.astype(jnp.float32))).astype(x.dtype)
    k = shd(k, "batch", None, "ff")
    r = jax.nn.sigmoid(jnp.einsum("btd,de->bte", xr, p["wr"]).astype(jnp.float32))
    y = r.astype(x.dtype) * jnp.einsum("btf,fd->btd", k, p["wv"])
    return shd(y, "batch", None, None)
