"""Attention mixers: global / sliding-window / bidirectional / cross.

Memory-bounded by construction: training & prefill use *query-chunked*
attention (a ``lax.map`` over query chunks — logits never materialize beyond
``[B, H, chunk, Tk]``), and sliding-window layers additionally slice a banded
KV strip so local attention is truly sub-quadratic. Decode attends a
preallocated KV cache (ring buffer for local layers).

This pure-JAX path is the reference; a Pallas flash kernel can be slotted in
per-mixer (see ``repro.kernels``) without touching callers.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import ParamSpec, apply_mrope, apply_rope, noshard

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# Specs
# ---------------------------------------------------------------------------

def attn_specs(cfg: ModelConfig) -> dict:
    d, hq, hkv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd
    pd = cfg.param_dtype
    s = {
        "wq": ParamSpec((d, hq, hd), ("embed", "q_heads", "head_dim"), pd),
        "wk": ParamSpec((d, hkv, hd), ("embed", "kv_heads", "head_dim"), pd),
        "wv": ParamSpec((d, hkv, hd), ("embed", "kv_heads", "head_dim"), pd),
        "wo": ParamSpec((hq, hd, d), ("q_heads", "head_dim", "embed"), pd),
    }
    if cfg.qkv_bias:
        s["bq"] = ParamSpec((hq, hd), ("q_heads", "head_dim"), pd, "zeros")
        s["bk"] = ParamSpec((hkv, hd), ("kv_heads", "head_dim"), pd, "zeros")
        s["bv"] = ParamSpec((hkv, hd), ("kv_heads", "head_dim"), pd, "zeros")
    return s


def qkv(p, x, cfg: ModelConfig, shd=noshard):
    q = jnp.einsum("btd,dhk->bthk", x, p["wq"])
    k = jnp.einsum("btd,dhk->bthk", x, p["wk"])
    v = jnp.einsum("btd,dhk->bthk", x, p["wv"])
    if "bq" in p:
        q = q + p["bq"].astype(q.dtype)
        k = k + p["bk"].astype(k.dtype)
        v = v + p["bv"].astype(v.dtype)
    q = shd(q, "batch", None, "q_heads", None)
    k = shd(k, "batch", None, "kv_heads", None)
    v = shd(v, "batch", None, "kv_heads", None)
    return q, k, v


def out_proj(p, o, shd=noshard):
    return shd(jnp.einsum("bthk,hkd->btd", o, p["wo"]), "batch", None, None)


# ---------------------------------------------------------------------------
# Core chunked attention (train / prefill)
# ---------------------------------------------------------------------------

def _grouped_logits(qc, k):
    """qc [B,C,Hq,hd], k [B,L,Hkv,hd] -> logits [B,Hkv,G,C,L] (GQA grouped)."""
    B, C, Hq, hd = qc.shape
    Hkv = k.shape[2]
    G = Hq // Hkv
    qg = qc.reshape(B, C, Hkv, G, hd)
    return jnp.einsum("bckgd,blkd->bkgcl", qg, k) / jnp.sqrt(hd).astype(qc.dtype)


def _attend(qc, k, v, mask):
    """mask [C, L] boolean (True = keep) or None. Returns [B,C,Hq,hd]."""
    logits = _grouped_logits(qc, k).astype(jnp.float32)
    if mask is not None:
        logits = jnp.where(mask[None, None, None], logits, NEG_INF)
    w = jax.nn.softmax(logits, axis=-1).astype(v.dtype)
    B, Hkv, G, C, L = w.shape
    o = jnp.einsum("bkgcl,blkd->bckgd", w, v)
    return o.reshape(B, C, Hkv * G, o.shape[-1])


def chunked_attention(q, k, v, *, causal: bool, window: int = 0,
                      chunk: int = 512, shd=noshard):
    """q [B,Tq,Hq,hd] vs k/v [B,Tk,Hkv,hd]; q and k share position origin 0.

    window > 0 => sliding-window causal attention over a banded KV strip.
    """
    B, Tq, Hq, hd = q.shape
    Tk = k.shape[1]
    chunk = min(chunk, Tq)
    n = -(-Tq // chunk)
    if Tq % chunk:
        pad = n * chunk - Tq
        q = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0)))
    banded = window > 0 and (window + chunk) < Tk
    L = min(Tk, chunk + window) if banded else Tk

    def one(ci):
        c0 = ci * chunk
        qc = jax.lax.dynamic_slice_in_dim(q, c0, chunk, axis=1)
        qpos = c0 + jnp.arange(chunk)
        if banded:
            start = jnp.clip(c0 + chunk - L, 0, Tk - L)
            kc = jax.lax.dynamic_slice_in_dim(k, start, L, axis=1)
            vc = jax.lax.dynamic_slice_in_dim(v, start, L, axis=1)
            kpos = start + jnp.arange(L)
        else:
            kc, vc, kpos = k, v, jnp.arange(Tk)
        mask = None
        if causal:
            mask = kpos[None, :] <= qpos[:, None]
            if window > 0:
                mask &= kpos[None, :] > qpos[:, None] - window
        return _attend(qc, kc, vc, mask)

    o = jax.lax.map(one, jnp.arange(n))                 # [n,B,chunk,Hq,hd]
    o = jnp.moveaxis(o, 0, 1).reshape(B, n * chunk, Hq, hd)
    return o[:, :Tq]


# ---------------------------------------------------------------------------
# Decode (one new token against a preallocated cache)
# ---------------------------------------------------------------------------

def cache_specs(cfg: ModelConfig, kind: str, batch: int, s_max: int) -> dict:
    """Abstract cache layout for one attention layer."""
    hkv, hd = cfg.n_kv_heads, cfg.hd
    slots = min(s_max, cfg.window) if kind == "attn_local" else s_max
    c = {
        "k": ParamSpec((batch, slots, hkv, hd), ("batch", "kv_seq", "kv_heads", None),
                       cfg.compute_dtype, "zeros"),
        "v": ParamSpec((batch, slots, hkv, hd), ("batch", "kv_seq", "kv_heads", None),
                       cfg.compute_dtype, "zeros"),
        "pos": ParamSpec((slots,), (None,), "int32", "zeros"),
    }
    return c


def decode_attend(q1, ck, cv, cpos, pos, *, window: int = 0, shd=noshard):
    """q1 [B,1,Hq,hd]; cache already contains the current token at its slot.

    cpos [slots] int32 holds the absolute position stored in each slot
    (-1 = empty). Masks: slot valid, <= pos, and within window if local.
    """
    hd = q1.shape[-1]
    valid = (cpos >= 0) & (cpos <= pos)
    if window > 0:
        valid &= cpos > pos - window
    logits = _grouped_logits(q1, ck).astype(jnp.float32)     # [B,Hkv,G,1,slots]
    logits = jnp.where(valid[None, None, None, None, :], logits, NEG_INF)
    w = jax.nn.softmax(logits, axis=-1).astype(cv.dtype)
    B, Hkv, G, _, L = w.shape
    o = jnp.einsum("bkgcl,blkd->bckgd", w, cv)
    return o.reshape(B, 1, Hkv * G, hd)


def cache_insert(cache, k1, v1, pos, *, window: int = 0):
    """Write the current token's k/v at slot ``pos`` (ring slot for local)."""
    slots = cache["k"].shape[1]
    slot = jnp.where(window > 0, pos % slots, pos) if window > 0 else pos
    ck = jax.lax.dynamic_update_slice_in_dim(cache["k"], k1.astype(cache["k"].dtype), slot, axis=1)
    cv = jax.lax.dynamic_update_slice_in_dim(cache["v"], v1.astype(cache["v"].dtype), slot, axis=1)
    cpos = jax.lax.dynamic_update_slice_in_dim(
        cache["pos"], jnp.asarray([pos], jnp.int32).reshape(1), slot, axis=0)
    return {**cache, "k": ck, "v": cv, "pos": cpos}


def cache_fill_prefill(cache, k, v, *, window: int = 0):
    """Bulk-load prefill K/V into the cache (last ``slots`` tokens for ring)."""
    slots = cache["k"].shape[1]
    T = k.shape[1]
    if window > 0 and T > slots:
        # keep the trailing window; slot index = pos % slots keeps ring coherent
        tail_pos = jnp.arange(T - slots, T)
        ring_slot = tail_pos % slots
        ck = cache["k"].at[:, ring_slot].set(k[:, -slots:].astype(cache["k"].dtype))
        cv = cache["v"].at[:, ring_slot].set(v[:, -slots:].astype(cache["v"].dtype))
        cpos = cache["pos"].at[ring_slot].set(tail_pos.astype(jnp.int32))
    else:
        ck = jax.lax.dynamic_update_slice_in_dim(cache["k"], k.astype(cache["k"].dtype), 0, axis=1)
        cv = jax.lax.dynamic_update_slice_in_dim(cache["v"], v.astype(cache["v"].dtype), 0, axis=1)
        cpos = cache["pos"].at[:].set(
            jnp.where(jnp.arange(slots) < T, jnp.arange(slots), -1).astype(jnp.int32))
    return {**cache, "k": ck, "v": cv, "pos": cpos}


# ---------------------------------------------------------------------------
# Full mixer (projection + rope + attend) for the three modes
# ---------------------------------------------------------------------------

def rope_q_k(cfg: ModelConfig, q, k, positions, positions3=None):
    if cfg.mrope_sections is not None and positions3 is not None:
        q = apply_mrope(q, positions3, cfg.mrope_sections, cfg.rope_theta)
        k = apply_mrope(k, positions3, cfg.mrope_sections, cfg.rope_theta)
    else:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    return q, k


def _mix_attend(q, k, v, *, kind: str, cfg: ModelConfig, ctx):
    """Route between the flash-VJP path (global/enc layers: kills the
    backward's stacked-probability HBM traffic) and the banded baseline
    (local layers, where the band keeps compute sub-quadratic)."""
    causal = kind != "attn_enc"
    window = cfg.window if kind == "attn_local" else 0
    T = q.shape[1]
    chunk = min(ctx.q_chunk, T)
    banded_useful = window > 0 and (window + chunk) < k.shape[1]
    if getattr(ctx, "flash", False) and not banded_useful:
        from repro.models.flash import get_flash
        return get_flash(causal, window, chunk)(q, k, v)
    return chunked_attention(q, k, v, causal=causal, window=window,
                             chunk=chunk, shd=ctx.shd)


def attn_train(p, x, cfg: ModelConfig, *, kind: str, ctx) -> jax.Array:
    """Training / prefill forward (no cache output here; see attn_prefill)."""
    shd = ctx.shd
    q, k, v = qkv(p, x, cfg, shd)
    B, T = x.shape[:2]
    if kind != "attn_enc":  # encoder: no rope (whisper uses learned pos; stub adds none)
        pos = jnp.arange(T)[None, :].repeat(B, 0)
        q, k = rope_q_k(cfg, q, k, pos, ctx.positions3)
    o = _mix_attend(q, k, v, kind=kind, cfg=cfg, ctx=ctx)
    return out_proj(p, o, shd)


def attn_prefill(p, x, cfg: ModelConfig, *, kind: str, ctx, cache):
    """Prefill: same as train but also fills the KV cache."""
    shd = ctx.shd
    q, k, v = qkv(p, x, cfg, shd)
    B, T = x.shape[:2]
    pos = jnp.arange(T)[None, :].repeat(B, 0)
    q, k = rope_q_k(cfg, q, k, pos, ctx.positions3)
    window = cfg.window if kind == "attn_local" else 0
    o = _mix_attend(q, k, v, kind=kind, cfg=cfg, ctx=ctx)
    cache = cache_fill_prefill(cache, k, v, window=window)
    return out_proj(p, o, shd), cache


def attn_decode(p, x1, cfg: ModelConfig, *, kind: str, ctx, cache):
    """x1 [B,1,d]; ctx.pos = scalar absolute position of this token."""
    shd = ctx.shd
    q, k, v = qkv(p, x1, cfg, shd)
    B = x1.shape[0]
    pos_arr = jnp.full((B, 1), ctx.pos, jnp.int32)
    p3 = None
    if ctx.positions3 is not None:
        p3 = ctx.positions3
    q, k = rope_q_k(cfg, q, k, pos_arr, p3)
    window = cfg.window if kind == "attn_local" else 0
    cache = cache_insert(cache, k, v, ctx.pos, window=window)
    o = decode_attend(q, cache["k"], cache["v"], cache["pos"], ctx.pos,
                      window=window, shd=shd)
    return out_proj(p, o, shd), cache


# ---------------------------------------------------------------------------
# Cross-attention (whisper decoder)
# ---------------------------------------------------------------------------

def xattn_specs(cfg: ModelConfig) -> dict:
    d, hq, hkv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd
    pd = cfg.param_dtype
    return {
        "wq": ParamSpec((d, hq, hd), ("embed", "q_heads", "head_dim"), pd),
        "wk": ParamSpec((d, hkv, hd), ("embed", "kv_heads", "head_dim"), pd),
        "wv": ParamSpec((d, hkv, hd), ("embed", "kv_heads", "head_dim"), pd),
        "wo": ParamSpec((hq, hd, d), ("q_heads", "head_dim", "embed"), pd),
    }


def xcache_specs(cfg: ModelConfig, batch: int) -> dict:
    return {
        "xk": ParamSpec((batch, cfg.enc_len, cfg.n_kv_heads, cfg.hd),
                        ("batch", None, "kv_heads", None), cfg.compute_dtype, "zeros"),
        "xv": ParamSpec((batch, cfg.enc_len, cfg.n_kv_heads, cfg.hd),
                        ("batch", None, "kv_heads", None), cfg.compute_dtype, "zeros"),
    }


def cross_attend(p, x, enc_kv, cfg: ModelConfig, shd=noshard):
    """x [B,T,d] queries vs precomputed encoder K/V (no mask)."""
    q = jnp.einsum("btd,dhk->bthk", x, p["wq"])
    q = shd(q, "batch", None, "q_heads", None)
    o = chunked_attention(q, enc_kv["xk"], enc_kv["xv"], causal=False,
                          chunk=512, shd=shd)
    return out_proj(p, o, shd)


def encode_cross_kv(p, enc_out, cfg: ModelConfig, shd=noshard):
    k = jnp.einsum("btd,dhk->bthk", enc_out, p["wk"])
    v = jnp.einsum("btd,dhk->bthk", enc_out, p["wv"])
    return {"xk": shd(k, "batch", None, "kv_heads", None),
            "xv": shd(v, "batch", None, "kv_heads", None)}
