"""Fault tolerance: restart equivalence, stragglers, elastic restore,
gradient compression."""
import tempfile

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.ckpt import Checkpointer
from repro.runtime.compression import (compression_error, compress,
                                       decompress, init_state)
from repro.runtime.fault import (FaultInjector, StragglerMonitor,
                                 TrainSupervisor)


def _step(state, batch):
    return {"x": state["x"] * 0.99 + batch.mean()}, {"x": state["x"]}


def _batch(step):
    return jnp.ones((4,)) * (step % 7)


def test_restart_equivalence():
    with tempfile.TemporaryDirectory() as d:
        ck = Checkpointer(d, async_save=False)
        sup = TrainSupervisor(_step, _batch, ck, ckpt_every=4,
                              fault=FaultInjector({3, 9, 10}))
        st, rep = sup.run({"x": jnp.ones(())}, 0, 16)
        ref = {"x": jnp.ones(())}
        for s in range(16):
            ref, _ = _step(ref, _batch(s))
        assert abs(float(st["x"]) - float(ref["x"])) < 1e-6
        assert rep.restarts == 3


def test_straggler_monitor():
    m = StragglerMonitor(threshold=2.0)
    flags = [m.observe(i, 0.1) for i in range(10)]
    assert not any(flags)
    assert m.observe(10, 0.5)          # 5x EWMA -> flagged
    assert m.flagged == 1
    # EWMA not poisoned by the outlier
    assert m.ewma < 0.12


def test_compression_error_feedback_reduces_bias():
    rng = np.random.RandomState(0)
    g = {"w": jnp.asarray(rng.randn(128, 64).astype(np.float32))}
    state = init_state(g)
    err = compression_error(g, state)
    assert err < 0.02
    # error feedback: accumulated mean of dequantized grads approaches true
    acc = np.zeros((128, 64), np.float32)
    for _ in range(32):
        q, s, state = compress(g, state)
        acc += np.asarray(decompress(q, s)["w"])
    acc /= 32
    rel = np.linalg.norm(acc - np.asarray(g["w"])) / np.linalg.norm(np.asarray(g["w"]))
    assert rel < 5e-3, rel


def test_elastic_restore_roundtrip():
    """Save an arbitrary param tree, restore via the elastic path onto the
    (1-device) smoke mesh with derived shardings."""
    import tempfile

    from repro.configs.base import ModelConfig
    from repro.launch.mesh import make_smoke_mesh
    from repro.models import transformer as T
    from repro.runtime.elastic import reshard_restore

    cfg = ModelConfig(name="t", family="dense", n_layers=2, d_model=32,
                      n_heads=2, n_kv_heads=1, head_dim=16, d_ff=64, vocab=128)
    params = T.init(jax.random.PRNGKey(0), cfg)
    with tempfile.TemporaryDirectory() as d:
        ck = Checkpointer(d, async_save=False)
        ck.save(7, params)
        mesh = make_smoke_mesh()
        out, man = reshard_restore(ck, T.param_specs(cfg), mesh)
        for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(out)):
            np.testing.assert_array_equal(np.asarray(a, np.float32),
                                          np.asarray(b, np.float32))
