"""Continuous-batching engine: paged KV store, slot scheduler, parity.

The contract under test (docs/SERVING.md): the engine may page, spill,
evict, re-prefill, and batch requests across slots however its budgets
dictate — but every request's token sequence stays bit-identical to a
solo jit decode of the same prompt, under every policy.  Alongside: the
pool byte accounting (`bytes_in_use` / `high_water_bytes`), the ledger's
``serve`` / ``pools`` report sections, and the pinned-down
``decode_stream`` sync semantics (``sync_every <= 0`` = one final sync).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.reduced import reduced as make_reduced
from repro.configs.registry import get_config
from repro.core.ledger import Ledger
from repro.core.pool import DeviceBufferPool, HostStagingPool
from repro.core.regions import Executor, UnifiedPolicy
from repro.launch import serve as SV
from repro.launch.mesh import make_smoke_mesh
from repro.launch.policy import lm_policy
from repro.models import transformer as T
from repro.serve import (PagedKVCache, Request, ServeEngine, make_traffic,
                         run_traffic, solo_reference)
from repro.serve.scheduler import DECODE, DONE, QUEUED
from repro.serve.traffic import assert_parity

MAX_LEN = 16


@pytest.fixture(scope="module")
def setup(traffic_seed):
    cfg = make_reduced(get_config("tinyllama-1.1b"))
    mesh = make_smoke_mesh()
    params = T.init(jax.random.PRNGKey(0), cfg)
    reqs = _traffic(cfg, traffic_seed)
    oracle, _ = solo_reference(cfg, mesh, params, reqs, MAX_LEN)
    return {"cfg": cfg, "mesh": mesh, "params": params, "oracle": oracle,
            "seed": traffic_seed}


def _traffic(cfg, seed):
    # the seed comes from the session `traffic_seed` fixture (conftest.py)
    # so every engine run and its parity oracle share one request stream
    return make_traffic(seed=seed, n_requests=4, vocab=cfg.vocab,
                        arrival_rate=2.0, prompt_lens=(6, 10),
                        gen_lens=(1, 5))


def _engine(s, policy=None, ledger_name="engine", **kv_kwargs):
    ex = Executor(policy or UnifiedPolicy(), Ledger(ledger_name))
    kv = PagedKVCache(page_tokens=4, **kv_kwargs)
    eng = ServeEngine(s["cfg"], s["mesh"], s["params"], ex,
                      max_len=MAX_LEN, n_slots=2, kv=kv)
    return eng, ex, kv


def _filled_cache(cfg, max_len=MAX_LEN, true_len=10):
    """A batch-1 cache with random values in [0, true_len) and the exact
    init_cache tail beyond — the shape a prefill leaves behind."""
    cache = T.init_cache(cfg, 1, max_len)
    flat, treedef = jax.tree_util.tree_flatten_with_path(cache)
    out = []
    for i, (path, leaf) in enumerate(flat):
        if not jnp.issubdtype(leaf.dtype, jnp.floating):
            out.append(leaf)
            continue
        v = jax.random.normal(jax.random.fold_in(jax.random.PRNGKey(1), i),
                              leaf.shape, leaf.dtype)
        ax = 2 if any(getattr(p, "key", None) == "cycles"
                      for p in path) else 1
        shape = [1] * leaf.ndim
        shape[ax] = leaf.shape[ax]
        mask = (jnp.arange(leaf.shape[ax]) < true_len).reshape(shape)
        out.append(jnp.where(mask, v, 0))
    return jax.tree_util.tree_unflatten(
        jax.tree_util.tree_structure(cache), out)


# ---------------------------------------------------------------------------
# paged KV store
# ---------------------------------------------------------------------------

def test_paged_kv_round_trip_bitwise(setup):
    cfg = setup["cfg"]
    cache = _filled_cache(cfg)
    kv = PagedKVCache(page_tokens=4)
    kv.commit(0, cache, true_len=10)
    # ceil(10/4) = 3 pages per k/v role per stacked leaf group
    assert kv.stats.role_pages == {"k": 3, "v": 3}
    back = kv.gather(0)
    for a, b in zip(jax.tree.leaves(cache), jax.tree.leaves(back)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert len(kv) == 0
    assert kv.stats.device_bytes == 0 and kv.stats.host_bytes == 0


def test_paged_kv_pages_recycle_through_pool(setup):
    cfg = setup["cfg"]
    cache = _filled_cache(cfg)
    kv = PagedKVCache(page_tokens=4)
    kv.commit(0, cache, true_len=10)
    kv.gather(0)                       # pages go back to the free-list
    assert kv.pool.stats.misses > 0 and kv.pool.stats.hits == 0
    kv.commit(1, cache, true_len=10)   # same shapes: all hits
    assert kv.pool.stats.hits == kv.pool.stats.misses
    assert kv.pool.stats.bytes_reused > 0


def test_paged_kv_spill_keeps_bits(setup):
    cfg = setup["cfg"]
    cache = _filled_cache(cfg)
    kv = PagedKVCache(page_tokens=4, device_budget_bytes=1)
    kv.commit(0, cache, true_len=10)
    assert kv.stats.pages_spilled == 6          # whole entry went to host
    assert kv.stats.device_bytes == 0 and kv.stats.host_bytes > 0
    back = kv.gather(0)
    assert kv.stats.pages_fetched == 6
    for a, b in zip(jax.tree.leaves(cache), jax.tree.leaves(back)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_paged_kv_total_budget_evicts_lru(setup):
    cfg = setup["cfg"]
    cache = _filled_cache(cfg)
    probe = PagedKVCache(page_tokens=4)
    probe.commit(0, cache, true_len=10)
    one_entry = probe.total_bytes
    kv = PagedKVCache(page_tokens=4, total_budget_bytes=one_entry)
    kv.commit(0, cache, true_len=10)
    evicted = kv.commit(1, cache, true_len=10)
    assert evicted == [0]                       # LRU out, newest stays
    assert 0 not in kv and 1 in kv
    assert kv.stats.evictions == 1


def test_paged_kv_rejects_duplicate_commit(setup):
    cache = _filled_cache(setup["cfg"])
    kv = PagedKVCache(page_tokens=4)
    kv.commit(0, cache, true_len=10)
    with pytest.raises(ValueError, match="already committed"):
        kv.commit(0, cache, true_len=10)


# ---------------------------------------------------------------------------
# engine parity: the one invariant everything else may not bend
# ---------------------------------------------------------------------------

def test_engine_parity_unified(setup):
    reqs = _traffic(setup["cfg"], setup["seed"])
    eng, ex, kv = _engine(setup)
    metrics = run_traffic(eng, reqs)
    assert_parity(reqs, setup["oracle"])
    assert metrics["tokens"] == sum(len(r.tokens) for r in reqs)
    assert all(r.done for r in reqs)


def test_engine_parity_across_host_spill(setup):
    """Device page budget of 1 byte: every parked prefill crosses to host
    DRAM and back — oversubscription must not bend a single bit."""
    reqs = _traffic(setup["cfg"], setup["seed"])
    eng, ex, kv = _engine(setup, ledger_name="spill",
                          device_budget_bytes=1)
    run_traffic(eng, reqs)
    assert kv.stats.pages_spilled > 0 and kv.stats.pages_fetched > 0
    assert kv.stats.device_high_water_bytes <= max(
        1, kv.stats.total_high_water_bytes)
    assert_parity(reqs, setup["oracle"])


def test_engine_parity_across_eviction_requeue(setup):
    """Total budget fits ~one parked entry: the store evicts, the
    scheduler re-queues for a fresh prefill, tokens still match."""
    cfg = setup["cfg"]
    probe = PagedKVCache(page_tokens=4)
    probe.commit(0, _filled_cache(cfg), true_len=10)
    reqs = _traffic(cfg, setup["seed"])
    eng, ex, kv = _engine(setup, ledger_name="evict",
                          total_budget_bytes=probe.total_bytes)
    run_traffic(eng, reqs)
    assert_parity(reqs, setup["oracle"])
    assert ex.ledger.serve_counters.get("evicted", 0) == \
        sum(r.evictions for r in reqs)


def test_engine_parity_discrete_policy(setup):
    """The engine is policy-agnostic: under the discrete emulation every
    region stages through the pools, tokens still match solo jit."""
    reqs = _traffic(setup["cfg"], setup["seed"])
    pol = lm_policy("discrete", setup["cfg"].memory)
    eng, ex, kv = _engine(setup, policy=pol, ledger_name="discrete")
    run_traffic(eng, reqs)
    assert_parity(reqs, setup["oracle"])
    pools = ex.ledger.coverage_report()["pools"]
    assert {"kv_pages", "host_staging", "device_buffer"} <= set(pools)


def test_engine_parity_offload_kv_placer(setup):
    """--offload-kv composes: the KVCachePlacer re-homes appended pages at
    region boundaries while the paged store parks prefills — same bits."""
    reqs = _traffic(setup["cfg"], setup["seed"])
    pol = lm_policy("unified", setup["cfg"].memory,
                    placer=SV.offload_kv_cache(min_bytes=0))
    eng, ex, kv = _engine(setup, policy=pol, ledger_name="offkv")
    run_traffic(eng, reqs)
    assert_parity(reqs, setup["oracle"])


# ---------------------------------------------------------------------------
# scheduler bookkeeping
# ---------------------------------------------------------------------------

def test_engine_serve_section_accounts_lifecycle(setup):
    reqs = _traffic(setup["cfg"], setup["seed"])
    eng, ex, kv = _engine(setup, ledger_name="acct")
    run_traffic(eng, reqs)
    rep = ex.ledger.coverage_report()
    serve = rep["serve"]
    n_decode = sum(1 for r in reqs if r.gen > 1)
    assert serve["submitted"] == len(reqs)
    assert serve["prefills"] == len(reqs)       # warm-up counters reset
    assert serve["admitted"] == n_decode        # gen==1 never takes a slot
    assert serve["retired"] == len(reqs)
    assert serve["decode_tokens"] == sum(r.gen - 1 for r in reqs)
    assert 0 < serve["slot_occupancy"] <= 1
    assert rep["pools"]["kv_pages"]["high_water_bytes"] > 0
    for r in reqs:
        assert r.history[0] == QUEUED and r.history[-1] == DONE


def test_engine_gen_one_finishes_at_prefill(setup):
    eng, ex, kv = _engine(setup, ledger_name="gen1")
    prompt = np.arange(6, dtype=np.int32)
    req = eng.submit(Request(req_id=0, prompt=prompt, gen=1))
    eng.drain()
    assert req.done and len(req.tokens) == 1
    assert req.history == [QUEUED, DONE]        # never PREFILL/DECODE
    assert len(kv) == 0                         # nothing parked


def test_engine_rejects_oversized_and_duplicate(setup):
    eng, ex, kv = _engine(setup, ledger_name="reject")
    with pytest.raises(ValueError, match="exceeds engine max_len"):
        eng.submit(Request(req_id=0, gen=MAX_LEN,
                           prompt=np.zeros(MAX_LEN, np.int32)))
    eng.submit(Request(req_id=1, prompt=np.zeros(4, np.int32), gen=2))
    with pytest.raises(ValueError, match="duplicate req_id"):
        eng.submit(Request(req_id=1, prompt=np.zeros(4, np.int32), gen=2))
    eng.drain()


def test_engine_state_machine_rejects_illegal_transition(setup):
    eng, ex, kv = _engine(setup, ledger_name="fsm")
    req = Request(req_id=0, prompt=np.zeros(4, np.int32), gen=2)
    with pytest.raises(RuntimeError, match="illegal transition"):
        eng._set_state(req, DECODE)             # QUEUED cannot jump slots


# ---------------------------------------------------------------------------
# pool byte accounting (satellite of this PR, used by the report above)
# ---------------------------------------------------------------------------

def test_device_pool_bytes_in_use_and_high_water():
    pool = DeviceBufferPool(min_elems=0)
    a = pool.acquire((8,), jnp.float32)         # 32 B live
    b = pool.acquire((8,), jnp.float32)         # 64 B live
    assert pool.stats.bytes_in_use == 64
    assert pool.stats.high_water_bytes == 64
    pool.release(a)
    assert pool.stats.bytes_in_use == 32 and pool.free_bytes == 32
    c = pool.acquire((8,), jnp.float32)         # free-list hit
    assert pool.stats.hits == 1
    assert pool.stats.bytes_in_use == 64 and pool.free_bytes == 0
    # in_use + free never exceeded the recorded high water
    assert pool.stats.high_water_bytes == 64
    pool.release(b), pool.release(c)
    assert pool.stats.bytes_in_use == 0 and pool.free_bytes == 64


def test_host_pool_bytes_in_use_tracks_outstanding():
    pool = HostStagingPool(min_elems=0)
    a = pool.acquire((100,), np.float32)
    assert pool.stats.bytes_in_use == pool.stats.high_water_bytes > 0
    before = pool.stats.bytes_in_use
    b = pool.acquire((100,), np.float32)
    assert pool.stats.bytes_in_use == 2 * before
    pool.release(a)
    pool.release(b)
    assert pool.stats.bytes_in_use == 0
    assert pool.stats.high_water_bytes == 2 * before
    assert pool.stats.as_dict()["bytes_in_use"] == 0


# ---------------------------------------------------------------------------
# decode_stream sync semantics (pinned down by this PR)
# ---------------------------------------------------------------------------

def _stream_with_sync(setup, sync_every, syncs):
    cfg, mesh, params = setup["cfg"], setup["mesh"], setup["params"]
    prefill, decode, make_cache = SV.build_server(cfg, mesh, 1, 12)
    prompt = np.arange(8, dtype=np.int32)
    batch = {"tokens": jnp.asarray(prompt)[None]}
    logits, cache = prefill(params, batch, make_cache())
    tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
    real = jax.block_until_ready

    def counting(x):
        syncs.append(1)
        return real(x)

    jax.block_until_ready = counting
    try:
        toks, _ = SV.decode_stream(decode, params, tok, cache, 8, 4,
                                   sync_every=sync_every)
    finally:
        jax.block_until_ready = real
    return [int(np.asarray(t)[0]) for t in toks]


@pytest.mark.parametrize("sync_every,expected_syncs", [
    (0, 1),     # never mid-stream: exactly the one final sync
    (-3, 1),    # negative = same contract (used to alias per-token sync)
    (1, 4),     # retired per-token sync: 3 mid-stream + 1 final
])
def test_decode_stream_sync_every_contract(setup, sync_every,
                                           expected_syncs):
    syncs = []
    toks = _stream_with_sync(setup, sync_every, syncs)
    assert len(syncs) == expected_syncs
    # sync cadence is scheduling, not math
    ref = _stream_with_sync(setup, 0, [])
    assert toks == ref
