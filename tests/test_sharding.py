"""Sharding resolver properties over the production mesh shapes."""
import types

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st
from jax.sharding import PartitionSpec as P

from repro.launch.sharding import ShardingRules, resolve


def fake_mesh(shape, axes):
    return types.SimpleNamespace(axis_names=axes, devices=np.zeros(shape))


SP = fake_mesh((16, 16), ("data", "model"))
MP = fake_mesh((2, 16, 16), ("pod", "data", "model"))
TRAIN = ShardingRules("train")
SERVE = ShardingRules("serve")


def flat_axes(spec):
    out = []
    for e in spec:
        if e is None:
            continue
        out.extend(e if isinstance(e, tuple) else (e,))
    return out


def test_param_fsdp_tp():
    # wq [d_model, heads, hd]: embed->fsdp(data/pod), heads->model
    spec = resolve((4096, 64, 128), ("embed", "q_heads", "head_dim"), MP,
                   TRAIN, "param")
    assert spec[1] == "model"
    assert set(flat_axes(spec)) == {"pod", "data", "model"}


def test_kv_heads_indivisible_replicates():
    spec = resolve((2, 128, 1, 256), ("batch", "kv_seq", "kv_heads", None),
                   SP, SERVE, "act")
    assert spec[2] is None                     # kv=1 can't shard over 16


def test_long_context_kv_seq_soaks_axes():
    spec = resolve((1, 524288, 1, 256), ("batch", "kv_seq", "kv_heads", None),
                   MP, SERVE, "act")
    assert spec[0] is None                     # batch 1
    assert set(flat_axes(spec)) == {"pod", "data", "model"}


def test_serve_mode_keeps_params_replicated_over_data():
    spec = resolve((4096, 14336), ("embed", "ff"), SP, SERVE, "param")
    assert spec[1] == "model" and spec[0] is None


@given(st.lists(st.sampled_from(
    ["batch", "embed", "ff", "vocab", "q_heads", "kv_heads", "kv_seq",
     "experts", None]), min_size=1, max_size=4, unique=True),
    st.data())
@settings(max_examples=200, deadline=None)
def test_no_mesh_axis_used_twice(axes, data):
    shape = tuple(data.draw(st.sampled_from([1, 2, 3, 16, 128, 256, 4096]))
                  for _ in axes)
    for mesh in (SP, MP):
        for rules in (TRAIN, SERVE):
            for kind in ("param", "act"):
                spec = resolve(shape, tuple(axes), mesh, rules, kind)
                used = flat_axes(spec)
                assert len(used) == len(set(used)), (axes, shape, spec)
                # divisibility always respected
                sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
                for dim, e in zip(shape, spec):
                    if e is None:
                        continue
                    prod = int(np.prod([sizes[a] for a in
                                        (e if isinstance(e, tuple) else (e,))]))
                    assert dim % prod == 0, (dim, e)
