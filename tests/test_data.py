"""Data pipeline: determinism, shard disjointness, memmap source."""
import tempfile

import numpy as np

from repro.data.pipeline import (MemmapTokens, ShardInfo, SyntheticTokens)


def test_synthetic_deterministic():
    s = SyntheticTokens(vocab=1000, seed=42)
    a = s.batch_at(13, 4, 32).copy()
    b = SyntheticTokens(vocab=1000, seed=42).batch_at(13, 4, 32)
    np.testing.assert_array_equal(a, b)
    c = s.batch_at(14, 4, 32)
    assert not np.array_equal(a, c)


def test_shards_differ():
    a = SyntheticTokens(1000, seed=1, shard=ShardInfo(0, 4)).batch_at(5, 2, 16)
    b = SyntheticTokens(1000, seed=1, shard=ShardInfo(1, 4)).batch_at(5, 2, 16)
    assert not np.array_equal(a, b)


def test_synthetic_learnable_structure():
    s = SyntheticTokens(vocab=1000, seed=0)
    b = s.batch_at(0, 8, 128)
    # 80% of transitions follow the fixed bigram table
    succ = s._succ[b[:, :-1] % len(s._succ)]
    frac = (b[:, 1:] == succ).mean()
    assert frac > 0.6, frac


def test_memmap_source():
    with tempfile.NamedTemporaryFile(suffix=".bin") as f:
        toks = np.arange(10000, dtype=np.int32) % 777
        toks.tofile(f.name)
        src = MemmapTokens(f.name, vocab=777)
        b0 = src.batch_at(0, 2, 16)
        b1 = src.batch_at(1, 2, 16)
        assert b0.shape == (2, 16)
        assert not np.array_equal(b0, b1)
        assert b0.max() < 777
