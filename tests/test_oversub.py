"""Memory oversubscription: run models and grids that don't fit (ROADMAP 4).

The invariant suite behind ``repro.core.oversub``: a MemoryBudget below
the working set degrades every workload through spill / paging / chunked
staging instead of OOMing, and NEVER changes values — each budgeted run
is bit-identical to its unbudgeted reference (the §2 parity contract).
Covers the three budgeted workloads of fig_oversub (KV serving, MoE
expert paging, CFD staged replay), the Hypothesis property suite over
random PagedKVCache interleavings, the engine drain/pool-accounting
regression, and the same-seed traffic determinism contract.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:                      # conftest stubs this, but be safe
    HAVE_HYPOTHESIS = False

from repro.configs.reduced import reduced as make_reduced
from repro.configs.registry import get_config
from repro.core import umem
from repro.core.ledger import Ledger
from repro.core.oversub import (MIN_CHUNK_BYTES, BudgetedPlacer,
                                MemoryBudget, workload_bytes)
from repro.core.pool import DeviceBufferPool
from repro.core.regions import (DiscretePolicy, Executor, UnifiedPolicy,
                                region)
from repro.core.umem import MemSpace
from repro.models import moe as M
from repro.models import transformer as T
from repro.models.params import init_params
from repro.serve import (PagedKVCache, ServeEngine, make_traffic,
                         run_traffic, solo_reference)
from repro.serve.traffic import assert_parity

MAX_LEN = 16


# ---------------------------------------------------------------------------
# MemoryBudget unit contract
# ---------------------------------------------------------------------------

def test_budget_charge_release_high_water():
    b = MemoryBudget(100)
    assert b.charge(60) and b.stats.charged_bytes == 60
    assert not b.charge(60)              # lands over: pressure, no raise
    assert b.over and b.stats.pressure_events == 1
    assert b.stats.high_water_bytes == 120
    b.release(60)
    assert not b.over and b.stats.charged_bytes == 60
    b.release(1000)                      # floors at zero, never negative
    assert b.stats.charged_bytes == 0
    assert b.stats.high_water_bytes == 120


def test_budget_for_ratio_headroom_and_utilization():
    b = MemoryBudget.for_ratio(1000, 4.0)
    assert b.limit_bytes == 250
    assert b.oversubscription_ratio(1000) == 4.0
    assert b.headroom() == 250
    b.charge(200)
    assert b.headroom() == 50 and b.utilization() == 0.8
    # ratio 1 = the everything-fits reference point
    assert MemoryBudget.for_ratio(1000, 1.0).limit_bytes == 1000
    # unlimited budget: everything fits by definition
    u = MemoryBudget()
    assert u.fits(10**12) and u.headroom() is None
    assert u.oversubscription_ratio(10**12) == 1.0
    with pytest.raises(ValueError):
        MemoryBudget.for_ratio(1000, 0)
    with pytest.raises(ValueError):
        MemoryBudget(0)


def test_budget_admit_denies_and_counts_spill():
    b = MemoryBudget(100)
    assert b.admit(80)
    assert not b.admit(80)               # would exceed: denied, not charged
    assert b.stats.charged_bytes == 80
    assert b.stats.denials == 1 and b.stats.spilled_bytes == 80
    # consult: advisory, never charges
    assert not b.consult(80) and b.consult(10)
    assert b.stats.charged_bytes == 80


def test_budget_staging_chunk_bytes():
    assert MemoryBudget().staging_chunk_bytes() is None
    assert MemoryBudget(1 << 20).staging_chunk_bytes() == (1 << 20) // 4
    # tiny budgets floor at MIN_CHUNK_BYTES: chunking below a page of
    # work costs more dispatches than it saves
    assert MemoryBudget(16).staging_chunk_bytes() == MIN_CHUNK_BYTES


# ---------------------------------------------------------------------------
# DeviceBufferPool x budget: accounting agrees byte-for-byte
# ---------------------------------------------------------------------------

def test_device_pool_charges_and_releases_budget():
    b = MemoryBudget(64)
    pool = DeviceBufferPool(min_elems=0, budget=b)
    x = pool.acquire((8,), jnp.float32)          # 32 B
    assert b.stats.charged_bytes == pool.stats.bytes_in_use == 32
    y = pool.acquire((16,), jnp.float32)         # 96 B: over, pressure
    assert b.stats.charged_bytes == pool.stats.bytes_in_use == 96
    assert b.stats.pressure_events == 1
    pool.release(x)
    pool.release(y)
    assert b.stats.charged_bytes == pool.stats.bytes_in_use == 0
    assert b.stats.high_water_bytes == 96
    # free-list hits charge too: a reacquired buffer is device-resident
    z = pool.acquire((8,), jnp.float32)
    assert pool.stats.hits == 1 and b.stats.charged_bytes == 32
    pool.release(z)


def test_device_pool_skips_budget_below_threshold():
    b = MemoryBudget(1024)
    pool = DeviceBufferPool(min_elems=100, budget=b)
    x = pool.acquire((8,), jnp.float32)          # unpooled: not charged
    assert pool.stats.unpooled == 1 and b.stats.charged_bytes == 0
    pool.release(x)
    assert b.stats.charged_bytes == 0


# ---------------------------------------------------------------------------
# Placement axis under a budget
# ---------------------------------------------------------------------------

def test_tree_place_budgeted_splits_and_preserves_values():
    b = MemoryBudget(40)
    tree = {"a": jnp.arange(8, dtype=jnp.float32),    # 32 B: admitted
            "b": jnp.arange(8, dtype=jnp.float32)}    # 32 B: spilled
    placed = umem.tree_place_budgeted(tree, b)
    assert b.stats.charged_bytes == 32
    assert b.stats.denials == 1 and b.stats.spilled_bytes == 32
    for k in tree:                                    # placement, not math
        np.testing.assert_array_equal(np.asarray(tree[k]),
                                      np.asarray(placed[k]))


def test_budgeted_placer_demotes_hints_bitwise():
    ldg = Ledger("bp")

    @region("bp_scale", ledger=ldg,
            placement={0: MemSpace.DEVICE, 1: MemSpace.DEVICE})
    def bp_scale(a, x):
        return a * x

    a = jnp.linspace(0.0, 1.0, 8)                     # 32 B: within budget
    x = jnp.linspace(1.0, 2.0, 8 * 64).reshape(64, 8)  # 2 KiB: demoted
    ref = Executor(UnifiedPolicy(), Ledger("bp_ref")).run(bp_scale, a, x)
    budget = MemoryBudget(256)
    pol = UnifiedPolicy(placer=BudgetedPlacer(budget=budget))
    out = Executor(pol, Ledger("bp_out")).run(bp_scale, a, x)
    np.testing.assert_array_equal(np.asarray(ref), np.asarray(out))
    # consult-only: hints are per-call transients, nothing stays charged
    assert budget.stats.charged_bytes == 0
    assert budget.stats.admitted >= 1 and budget.stats.denials >= 1


# ---------------------------------------------------------------------------
# Workload (a): MoE decode with host-resident experts paged per token
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def moe_setup():
    # qwen3-moe-30b-a3b structure at test scale, but with a sparse router
    # (16 experts, top-2) so paging is meaningful — the reduced() cap
    # (8 experts, top-8) selects every expert every token
    cfg = make_reduced(get_config("qwen3-moe-30b-a3b"))
    cfg = dataclasses.replace(
        cfg, moe=dataclasses.replace(cfg.moe, n_experts=16, top_k=2,
                                     d_ff=32))
    p = init_params(jax.random.PRNGKey(0), M.moe_specs(cfg))
    xs = [jax.random.normal(jax.random.PRNGKey(10 + t),
                            (1, 1, cfg.d_model), cfg.compute_dtype)
          for t in range(6)]             # a 6-token decode stream
    return {"cfg": cfg, "p": p, "xs": xs}


def _paged_stream(s, budget):
    pager = M.ExpertPager(s["p"], s["cfg"], budget=budget)
    ys = []
    for x in s["xs"]:
        y, _ = M.moe_decode_paged(pager, x, s["cfg"])
        if budget is not None:           # the invariant the LRU maintains
            assert pager.resident_bytes <= budget.limit_bytes
        ys.append(np.asarray(y))
    return pager, ys


def test_moe_paged_budgeted_bitwise_vs_resident(moe_setup):
    """The tentpole parity bar: a 4x-oversubscribed expert working set
    produces bit-identical outputs — paging changes residency, not math."""
    pager_ref, ref = _paged_stream(moe_setup, None)
    fp = pager_ref.footprint_bytes
    for ratio in (2.0, 4.0):
        budget = MemoryBudget.for_ratio(fp, ratio)
        pager, ys = _paged_stream(moe_setup, budget)
        for a, b in zip(ref, ys):
            np.testing.assert_array_equal(a, b)
        assert pager.stats.fetches > 0
        assert budget.stats.high_water_bytes <= budget.limit_bytes \
            + pager.slab_bytes           # transient: one slab mid-evict


def test_moe_paged_matches_dense_oracle(moe_setup):
    s = moe_setup
    pager = M.ExpertPager(s["p"], s["cfg"])
    for x in s["xs"][:2]:
        y, aux = M.moe_decode_paged(pager, x, s["cfg"])
        yr, auxr = M.moe_ref(s["p"], x, s["cfg"])
        np.testing.assert_allclose(np.asarray(y), np.asarray(yr),
                                   rtol=2e-2, atol=2e-2)
        np.testing.assert_allclose(float(aux), float(auxr), rtol=1e-5)


def test_expert_pager_lru_and_accounting(moe_setup):
    s = moe_setup
    pager = M.ExpertPager(
        s["p"], s["cfg"],
        budget=MemoryBudget(2 * _slab_bytes(s)))     # room for 2 slabs
    pager.get(0), pager.get(1)
    assert pager.stats.fetches == 2 and pager.stats.evictions == 0
    pager.get(0)                                     # touch: 0 is now MRU
    assert pager.stats.hits == 1
    pager.get(2)                                     # evicts LRU = 1
    assert pager.stats.evictions == 1
    assert set(pager._resident) == {0, 2}
    assert pager.budget.stats.charged_bytes == pager.resident_bytes
    pager.drop()
    assert pager.budget.stats.charged_bytes == 0 and not pager._resident


def _slab_bytes(s):
    return sum(int(s["p"][k][0].nbytes) for k in M.EXPERT_KEYS)


def test_moe_prefetch_parity_and_overlap(moe_setup):
    """The one-step slab lookahead (AsyncExecutor's contract applied to
    expert paging): identical fetch/hit/eviction accounting and
    bit-identical outputs vs the lookahead-off pager, with the hidden
    fetch time surfaced on the pager stats and the ledger gauge."""
    s = moe_setup
    led = Ledger("serve")
    pon = M.ExpertPager(s["p"], s["cfg"])            # lookahead default on
    poff = M.ExpertPager(s["p"], s["cfg"], lookahead=False)
    for x in s["xs"]:
        y1, _ = M.moe_decode_paged(pon, x, s["cfg"], ledger=led)
        y0, _ = M.moe_decode_paged(poff, x, s["cfg"])
        np.testing.assert_array_equal(np.asarray(y1), np.asarray(y0))
    on, off = pon.stats, poff.stats
    # prefetch moves the same bytes at a different time — the paging
    # ledger cannot tell the difference
    assert (on.fetches, on.hits, on.evictions, on.bytes_fetched) == \
        (off.fetches, off.hits, off.evictions, off.bytes_fetched)
    assert on.prefetch_hits > 0 and off.prefetch_hits == 0
    assert on.prefetch_overlap_s >= 0.0
    assert "moe_prefetch_overlap_s" in led.serve_gauges
    assert led.serve_counters.get("moe_prefetch_hit") == on.prefetch_hits
    pon.drop()
    assert not pon._pending and not pon._resident


def test_moe_prefetch_budgeted_charges_on_install(moe_setup):
    """A prefetched slab only hits the MemoryBudget when get() installs
    it, so the budget invariants (and evictions) are unchanged by the
    lookahead."""
    s = moe_setup
    budget = MemoryBudget(2 * _slab_bytes(s))
    pager = M.ExpertPager(s["p"], s["cfg"], budget=budget)
    ys = []
    for x in s["xs"]:
        y, _ = M.moe_decode_paged(pager, x, s["cfg"])
        assert pager.resident_bytes <= budget.limit_bytes
        ys.append(np.asarray(y))
    ref, refs = _paged_stream(s, None)
    for a, b in zip(refs, ys):
        np.testing.assert_array_equal(a, b)
    assert pager.stats.evictions > 0             # the budget really bound
    pager.drop()
    assert budget.stats.charged_bytes == 0


# ---------------------------------------------------------------------------
# Workload (c): CFD grids beyond device capacity via budgeted staged replay
# ---------------------------------------------------------------------------

def test_cfd_budgeted_chunked_staging_bitwise():
    """A captured SIMPLE step replayed under a discrete policy whose
    budget is 1/4 the state footprint: staging happens in budget-sized
    slabs (chunks counted), fields stay bit-identical to the unbudgeted
    discrete replay."""
    from repro.cfd.grid import Grid
    from repro.cfd.simple import SimpleConfig, SimpleFoam, init_state
    cfg = SimpleConfig(grid=Grid((12, 12, 12)), nu=0.1, inner_max=6)
    app = SimpleFoam(cfg)
    st = init_state(cfg)
    st, _, _ = app.run_steps(st, 1)
    prog = app.capture_step(st)
    s_ref, _ = app.replay_steps(prog, st, 2, Executor(DiscretePolicy()))
    fp = workload_bytes(st)
    assert fp > 0
    budget = MemoryBudget.for_ratio(fp, 4.0)
    assert budget.staging_chunk_bytes() < 12 * 12 * 12 * 4  # < one field
    s_b, _ = app.replay_steps(prog, st, 2,
                              Executor(DiscretePolicy(budget=budget)))
    for name in ("u", "v", "w", "p"):
        np.testing.assert_array_equal(np.asarray(getattr(s_ref, name)),
                                      np.asarray(getattr(s_b, name)))
    assert budget.stats.staging_chunks > 0
    assert budget.stats.pressure_events > 0          # it really didn't fit


def test_sharded_scatter_respects_staging_budget():
    """The sharded+staged replay path: ShardExecutor's host->APUs scatter
    chunks through the policy budget on a degenerate 1-APU mesh, matching
    the unbudgeted sharded replay bit-for-bit."""
    from repro.core.program import capture
    from repro.core.shard_program import shard_program
    ldg = Ledger("oversub_shard")
    grid = (16, 16, 16)                  # 16 KiB fields: > min chunk

    @region("ov_scale", ledger=ldg)
    def ov_scale(d, x):
        return d * x

    def step(run, d, x):
        return run(ov_scale, d, run(ov_scale, d, x))

    d = jnp.linspace(1.0, 2.0, int(np.prod(grid))).reshape(grid)
    x = jnp.full(grid, 0.3, jnp.float32)
    prog = capture(step, d, x, name="ov3d")
    mesh = jax.make_mesh((1,), ("apu",), devices=jax.devices()[:1])
    ref = shard_program(prog, mesh, DiscretePolicy()).replay(d, x)
    budget = MemoryBudget(16384)         # chunk = 4 KiB < one 16 KiB field
    out = shard_program(prog, mesh,
                        DiscretePolicy(budget=budget)).replay(d, x)
    np.testing.assert_array_equal(np.asarray(ref), np.asarray(out))
    assert budget.stats.staging_chunks > 1


# ---------------------------------------------------------------------------
# Workload (b): KV caches beyond the device budget (store-level contract;
# the full-traffic engine runs live in the engine section below)
# ---------------------------------------------------------------------------

def _toy_cache(rng, S, true_len):
    """A synthetic k/v cache tree (the role keying PagedKVCache pages on)
    with the init_cache-style zero tail beyond true_len."""
    def leaf():
        a = rng.random((1, S, 4)).astype(np.float32)
        a[:, true_len:] = 0
        return a
    return {"k": jnp.asarray(leaf()), "v": jnp.asarray(leaf()),
            "pos": jnp.full((1,), true_len, jnp.int32)}


def test_paged_kv_memory_budget_drives_spill_bitwise():
    rng = np.random.default_rng(3)
    cache = _toy_cache(rng, 12, 10)
    budget = MemoryBudget(1)             # nothing device-resident fits
    kv = PagedKVCache(page_tokens=4, budget=budget)
    kv.commit(0, cache, true_len=10)
    assert kv.stats.pages_spilled == 6 and kv.stats.device_bytes == 0
    assert budget.stats.charged_bytes == 0           # spill released it
    assert budget.stats.pressure_events >= 1
    back = kv.gather(0)
    for key in ("k", "v", "pos"):
        np.testing.assert_array_equal(np.asarray(cache[key]),
                                      np.asarray(back[key]))
    assert budget.stats.charged_bytes == 0


def test_paged_kv_tightest_of_two_budgets_wins():
    rng = np.random.default_rng(4)
    cache = _toy_cache(rng, 12, 10)
    # explicit device_budget_bytes is looser than the MemoryBudget: the
    # budget's limit governs
    kv = PagedKVCache(page_tokens=4, device_budget_bytes=1 << 30,
                      budget=MemoryBudget(1))
    kv.commit(0, cache, true_len=10)
    assert kv.stats.pages_spilled == 6
    # and the other way around
    kv2 = PagedKVCache(page_tokens=4, device_budget_bytes=1,
                       budget=MemoryBudget(1 << 30))
    kv2.commit(0, cache, true_len=10)
    assert kv2.stats.pages_spilled == 6


# ---------------------------------------------------------------------------
# Satellite: Hypothesis property suite — random interleavings of
# commit/spill/evict/requeue vs an unpaged reference cache
# ---------------------------------------------------------------------------

def _run_interleaving(page_tokens, dev_budget, tot_entries, seed, ops):
    """The satellite-1 property: under ANY interleaving of commit /
    budget-spill / budget-evict / requeue with random page sizes and
    budgets, gather stays bitwise equal to the kept-original reference
    tree, and page/byte accounting never goes negative."""
    rng = np.random.default_rng(seed)
    trees = {}                           # rid -> (numpy tree, true_len)
    for rid in range(6):
        S = int(rng.integers(4, 17))
        tl = int(rng.integers(1, S + 1))
        trees[rid] = (_toy_cache(rng, S, tl), tl)
    one_entry = None
    if tot_entries is not None:
        probe = PagedKVCache(page_tokens=page_tokens)
        probe.commit(0, trees[0][0], true_len=trees[0][1])
        one_entry = probe.total_bytes * tot_entries
        probe.free(0)
    kv = PagedKVCache(page_tokens=page_tokens,
                      device_budget_bytes=dev_budget,
                      total_budget_bytes=one_entry)
    parked = set()

    def check_invariants():
        s = kv.stats
        assert s.device_bytes >= 0 and s.host_bytes >= 0
        assert kv.pool.stats.bytes_in_use >= 0
        assert s.pages_released <= s.pages_committed
        if dev_budget is not None:       # spill always possible on CPU
            assert s.device_bytes <= dev_budget

    def check_bits(rid, back):
        ref = trees[rid][0]
        for key in ref:
            np.testing.assert_array_equal(np.asarray(ref[key]),
                                          np.asarray(back[key]))

    for op, rid in ops:
        if op == "commit" and rid not in parked:
            evicted = kv.commit(rid, trees[rid][0],
                                true_len=trees[rid][1])
            parked.add(rid)
            for ev in evicted:           # evict = requeue: commit later ok
                parked.discard(ev)
        elif op == "gather" and rid in parked:
            check_bits(rid, kv.gather(rid))
            parked.discard(rid)
        elif op == "free" and rid in parked:
            kv.free(rid)
            parked.discard(rid)
        elif op == "touch":
            kv.touch(rid)
        check_invariants()

    for rid in sorted(parked):           # drain: every survivor bit-exact
        check_bits(rid, kv.gather(rid))
    assert kv.stats.device_bytes == 0 and kv.stats.host_bytes == 0
    assert kv.pool.stats.bytes_in_use == 0


@settings(max_examples=20, deadline=None)
@given(
    page_tokens=st.integers(min_value=1, max_value=6),
    dev_budget=st.sampled_from([None, 1, 256, 4096]),
    tot_entries=st.sampled_from([None, 1, 3]),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
    ops=st.lists(
        st.tuples(st.sampled_from(["commit", "gather", "free", "touch"]),
                  st.integers(min_value=0, max_value=5)),
        min_size=1, max_size=24),
)
def test_paged_kv_random_interleavings_property(page_tokens, dev_budget,
                                                tot_entries, seed, ops):
    _run_interleaving(page_tokens, dev_budget, tot_entries, seed, ops)


def test_paged_kv_random_interleavings_seeded():
    """Deterministic fallback for the property above: the same invariant
    over 15 seeded random draws, so the interleaving contract is exercised
    even where hypothesis is unavailable (the conftest stub turns the
    @given test into a SKIP there)."""
    rng = np.random.default_rng(7)
    for trial in range(15):
        page_tokens = int(rng.integers(1, 7))
        dev_budget = [None, 1, 256, 4096][trial % 4]
        tot_entries = [None, 1, 3][trial % 3]
        n_ops = int(rng.integers(4, 25))
        ops = [(["commit", "gather", "free", "touch"][int(rng.integers(4))],
                int(rng.integers(6))) for _ in range(n_ops)]
        _run_interleaving(page_tokens, dev_budget, tot_entries,
                          int(rng.integers(2**31)), ops)


# ---------------------------------------------------------------------------
# Engine-level: budgeted traffic, drain accounting, seed determinism
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def eng_setup(traffic_seed):
    cfg = make_reduced(get_config("tinyllama-1.1b"))
    from repro.launch.mesh import make_smoke_mesh
    mesh = make_smoke_mesh()
    params = T.init(jax.random.PRNGKey(0), cfg)
    reqs = _traffic(cfg, traffic_seed)
    oracle, _ = solo_reference(cfg, mesh, params, reqs, MAX_LEN)
    return {"cfg": cfg, "mesh": mesh, "params": params, "oracle": oracle,
            "seed": traffic_seed}


def _traffic(cfg, seed, id_base=0):
    reqs = make_traffic(seed=seed, n_requests=4, vocab=cfg.vocab,
                        arrival_rate=2.0, prompt_lens=(6, 10),
                        gen_lens=(1, 5))
    for r in reqs:
        r.req_id += id_base
    return reqs


def _engine(s, budget=None, ledger_name="oversub", **kv_kwargs):
    ex = Executor(UnifiedPolicy(), Ledger(ledger_name))
    kv = PagedKVCache(page_tokens=4, budget=budget, **kv_kwargs)
    eng = ServeEngine(s["cfg"], s["mesh"], s["params"], ex,
                      max_len=MAX_LEN, n_slots=2, kv=kv)
    return eng, ex, kv


def _kv_footprint(s, n_slots=2):
    probe = PagedKVCache(page_tokens=4)
    probe.commit(0, T.init_cache(s["cfg"], 1, MAX_LEN), true_len=MAX_LEN)
    fp = probe.total_bytes * n_slots
    probe.free(0)
    return fp


def test_engine_parity_under_oversubscription(eng_setup):
    """Tentpole workload (b) end-to-end: real traffic against a KV budget
    a quarter of the working set (ratio 2 exactly equals the parked-page
    peak for this traffic, so 4x is the first ratio that forces spill) —
    spill traffic flows, the budget gauges land in the ledger, and every
    token matches the solo oracle bit-for-bit."""
    s = eng_setup
    budget = MemoryBudget.for_ratio(_kv_footprint(s), 4.0, name="kv")
    reqs = _traffic(s["cfg"], s["seed"])
    eng, ex, kv = _engine(s, budget=budget)
    run_traffic(eng, reqs)
    assert_parity(reqs, s["oracle"])
    assert kv.stats.pages_spilled > 0
    gauges = ex.ledger.coverage_report()["serve"]
    assert gauges["kv_budget_limit_bytes"] == budget.limit_bytes
    assert gauges["kv_budget_high_water_bytes"] > 0


def test_engine_drain_restores_pool_baseline(eng_setup):
    """Satellite regression: after a run fully drains, the KV pool's
    bytes_in_use returns to its pre-run baseline and high_water_bytes is
    monotone — the double-release/leak tripwire for the spill path."""
    s = eng_setup
    eng, ex, kv = _engine(s, ledger_name="drain",
                          device_budget_bytes=1)     # force spill traffic
    baseline = kv.pool.stats.bytes_in_use
    run_traffic(eng, _traffic(s["cfg"], s["seed"]))
    assert kv.stats.pages_spilled > 0
    assert len(kv) == 0
    assert kv.pool.stats.bytes_in_use == baseline
    hw1 = kv.pool.stats.high_water_bytes
    assert hw1 > 0
    # second wave on the SAME engine (fresh ids): baseline again, high
    # water never decreases
    run_traffic(eng, _traffic(s["cfg"], s["seed"], id_base=100),
                warmup=False)
    assert kv.pool.stats.bytes_in_use == baseline
    assert kv.pool.stats.high_water_bytes >= hw1


def test_same_seed_traffic_is_reproducible(eng_setup):
    """Satellite: the threaded seed fixture makes traffic runs
    deterministic — two same-seed engine runs produce identical token
    streams, and make_traffic itself is a pure function of the seed."""
    s = eng_setup
    a = make_traffic(seed=s["seed"], n_requests=4, vocab=s["cfg"].vocab)
    b = make_traffic(seed=s["seed"], n_requests=4, vocab=s["cfg"].vocab)
    for ra, rb in zip(a, b):
        np.testing.assert_array_equal(ra.prompt, rb.prompt)
        assert (ra.gen, ra.arrival_tick) == (rb.gen, rb.arrival_tick)
    streams = []
    for _ in range(2):
        reqs = _traffic(s["cfg"], s["seed"])
        eng, ex, kv = _engine(s, ledger_name="det")
        run_traffic(eng, reqs)
        streams.append([list(map(int, r.tokens)) for r in reqs])
    assert streams[0] == streams[1]
