"""Region implementation variants (the declare-variant Selector axis,
repro.core.regions): registration mechanics, selector resolution with the
base-function fallback, parity of every registered variant of every region
against its ref under all four policies (docs/DESIGN.md §2 tolerances),
AutotuneSelector calibration determinism + ledger persistence, variant
re-resolution on captured-program replay (sync, async, batched), the
kernel-package ref contract, and the 2-APU sharded acceptance scenario
(subprocess)."""
import json
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.cfd import fvm
from repro.cfd.fields import make_field_ops
from repro.cfd.grid import Grid
from repro.cfd.precond import rb_dilu_factor
from repro.cfd.solvers import make_solver_regions
from repro.core.ledger import Ledger
from repro.core.program import AsyncExecutor, capture
from repro.core.regions import (AdaptivePolicy, AutotuneSelector,
                                DiscretePolicy, Executor, HostPolicy,
                                StaticSelector, TargetSelector,
                                UnifiedPolicy, region, size_bucket)

GRID = (8, 6, 10)

#: docs/DESIGN.md §2 variant tolerance: variant-vs-ref agreement bound for
#: one region application (the Pallas kernel parity sweeps' bound)
VTOL = dict(rtol=3e-4, atol=1e-4)

ALL_POLICIES = [UnifiedPolicy, HostPolicy, DiscretePolicy,
                lambda **kw: AdaptivePolicy(cutoff=64, **kw)]


def solver_fixture():
    """Every variant-carrying region of the CFD stack with example args:
    [(region, make_args())] over a real assembled system."""
    g = Grid(GRID)
    A, _ = fvm.laplacian(g, 1.0)
    red, _ = g.red_black_masks()
    P = rb_dilu_factor(A, red)
    rng = np.random.RandomState(7)
    x = jnp.asarray(rng.rand(*GRID).astype(np.float32))
    y = jnp.asarray(rng.rand(*GRID).astype(np.float32))
    z = jnp.asarray(rng.rand(*GRID).astype(np.float32))
    R = make_solver_regions(Ledger("vfix"))
    ops = make_field_ops(Ledger("vfix_ops"))
    return [
        (R.amul, (A.diag, A.off, x)),
        (R.precond, (P.rdiag, P.red, A.off, x)),
        (R.saxpy, (0.7, x, y)),
        (R.update_x, (x, 0.3, y, -0.2, z)),
        (ops.axpy, (1.5, x, y)),
        (ops.xpay, (-0.5, x, y)),
        (ops.axpbypz, (0.25, x, -1.5, y, z)),
        (ops.fmul, (x, y)),
    ]


# ---------------------------------------------------------------------------
# Region.variant mechanics
# ---------------------------------------------------------------------------

def test_every_region_has_ref_and_fallback_resolution():
    for r, _ in solver_fixture():
        assert "ref" in r.variants
        assert r.resolve("no-such-impl") == "ref"
        assert r.impl_fn("ref") is r.fn


def test_variant_registration_and_executable_cache():
    ldg = Ledger("t")

    @region("f", ledger=ldg)
    def f(x):
        return x + 1.0

    assert f.variants == ("ref",)

    @f.variant("double")
    def _g(x):
        return x + 2.0

    assert f.variants == ("ref", "double")
    np.testing.assert_allclose(
        np.asarray(f.executable("default", "double")(jnp.zeros(8))), 2.0)
    np.testing.assert_allclose(
        np.asarray(f.executable("default")(jnp.zeros(8))), 1.0)
    with pytest.raises(KeyError, match="no variant"):
        f.impl_fn("nope")
    # re-registration drops the stale compilation
    f.variant("double", lambda x: x + 3.0)
    np.testing.assert_allclose(
        np.asarray(f.executable("default", "double")(jnp.zeros(8))), 3.0)
    # re-registering "ref" replaces the BASE function everywhere: jitted
    # executables and the raw fn (the fused as_fn path) must agree
    f.variant("ref", lambda x: x + 10.0)
    np.testing.assert_allclose(
        np.asarray(f.executable("default")(jnp.zeros(8))), 10.0)
    np.testing.assert_allclose(
        np.asarray(f.jitted_variant("ref")(jnp.zeros(8))), 10.0)
    np.testing.assert_allclose(np.asarray(f.fn(jnp.zeros(8))), 10.0)


def test_unknown_selector_name_falls_back_on_every_path():
    """A custom Selector may return an unregistered name: every executor
    path (incl. jitted_variant and the fused composite) must fall back to
    ref, and the ledger must record what actually ran."""
    ldg = Ledger("t")

    @region("f", ledger=ldg)
    def f(x):
        return x * 2.0

    ex = Executor(UnifiedPolicy(selector=StaticSelector("cuda")), ldg)
    np.testing.assert_allclose(np.asarray(ex.run(f, jnp.ones(8))), 2.0)
    assert ldg.coverage_report()["impl_counts"] == {"ref": 1}
    np.testing.assert_allclose(
        np.asarray(f.jitted_variant("cuda")(jnp.ones(8))), 2.0)

    def step(run, x):
        return run(f, x)

    prog = capture(step, jnp.ones(8), name="fb")
    out = prog.replay_batch(jnp.ones((2, 8)),
                            selector=StaticSelector("cuda"))
    np.testing.assert_allclose(np.asarray(out), 2.0)


def test_target_selector_prefers_device_kernel_and_host_path():
    ldg = Ledger("t")

    @region("f", ledger=ldg)
    def f(x):
        return x * 1.0

    f.variant("pallas", lambda x: x * 2.0)
    f.variant("host", lambda x: x * 3.0)
    sel = TargetSelector()
    assert sel.select(f, "default", (), {}) == "pallas"
    assert sel.select(f, "device", (), {}) == "pallas"
    assert sel.select(f, "host", (), {}) == "host"

    @region("g", ledger=ldg)         # no variants: everything falls back
    def g(x):
        return x

    assert sel.select(g, "device", (), {}) == "ref"
    assert sel.select(g, "host", (), {}) == "ref"


# ---------------------------------------------------------------------------
# Parity: every registered variant == ref under every policy (§2 tolerance)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("make_policy", ALL_POLICIES)
def test_every_variant_matches_ref_under_every_policy(make_policy):
    for r, args in solver_fixture():
        ref_out = np.asarray(
            Executor(make_policy(selector=StaticSelector("ref")),
                     Ledger("ref")).run(r, *args))
        for name in r.variants:
            if name == "ref":
                continue
            ex = Executor(make_policy(selector=StaticSelector(name)),
                          Ledger(name))
            out = np.asarray(ex.run(r, *args))
            np.testing.assert_allclose(
                out, ref_out, **VTOL,
                err_msg=f"{r.name}:{name} vs ref under "
                        f"{ex.policy.name}")
            rep = ex.report()
            want = name if name in r.variants else "ref"
            assert rep["impl_counts"] == {want: 1}


def test_rwkv6_scan_variants_match_ref_with_nonzero_state():
    from repro.models.rwkv6 import RWKV6_SCAN
    B, T, H, hd = 2, 32, 2, 8
    rng = np.random.RandomState(3)
    r, k, v = [jnp.asarray(rng.randn(B, T, H, hd).astype(np.float32)) * 0.5
               for _ in range(3)]
    logw = -jnp.asarray(rng.rand(B, T, H, hd).astype(np.float32)) - 0.01
    u = jnp.asarray(rng.randn(H, hd).astype(np.float32)) * 0.3
    S0 = jnp.asarray(rng.randn(B, H, hd, hd).astype(np.float32)) * 0.2
    assert set(RWKV6_SCAN.variants) >= {"ref", "chunked", "pallas"}
    ro, rs = RWKV6_SCAN.impl_fn("ref")(r, k, v, logw, u, S0)
    for name in ("chunked", "pallas"):
        o, s = RWKV6_SCAN.jitted_variant(name)(r, k, v, logw, u, S0)
        np.testing.assert_allclose(np.asarray(o), np.asarray(ro),
                                   rtol=3e-4, atol=3e-4, err_msg=name)
        np.testing.assert_allclose(np.asarray(s), np.asarray(rs),
                                   rtol=3e-4, atol=3e-4, err_msg=name)


def test_rwkv_train_impl_dispatch_matches_default():
    from repro.configs.base import ModelConfig
    from repro.models import rwkv6 as R
    from repro.models.params import init_params

    cfg = ModelConfig(name="t", family="ssm", n_layers=1, d_model=16,
                      n_heads=2, n_kv_heads=2, d_ff=32, vocab=64,
                      layer_cycle=("rwkv",))

    class Ctx:
        rwkv_chunk = 8

        @staticmethod
        def shd(x, *_):
            return x

    rng = np.random.RandomState(0)
    p = init_params(jax.random.PRNGKey(0), R.rwkv_specs(cfg))
    x = jnp.asarray(rng.randn(2, 16, 16).astype(np.float32))
    y0, s0 = R.rwkv_train(p, x, cfg, ctx=Ctx, chunk=8)
    for impl in ("ref", "chunked", "pallas"):
        y, s = R.rwkv_train(p, x, cfg, ctx=Ctx, chunk=8, impl=impl)
        np.testing.assert_allclose(np.asarray(y), np.asarray(y0),
                                   rtol=5e-4, atol=5e-4, err_msg=impl)
        np.testing.assert_allclose(np.asarray(s["S"]), np.asarray(s0["S"]),
                                   rtol=5e-4, atol=5e-4, err_msg=impl)


# ---------------------------------------------------------------------------
# AutotuneSelector
# ---------------------------------------------------------------------------

def test_autotune_calibration_is_deterministic_and_persisted():
    ldg = Ledger("t")

    @region("tuned", ledger=ldg)
    def tuned(x):
        return x * 2.0 + 1.0

    @tuned.variant("slow")
    def _slow(x):
        y = x
        for _ in range(50):              # deterministically slower
            y = jnp.sin(y) * 1.0001
        return y * 0.0 + x * 2.0 + 1.0

    sizes = (256, 4096)
    winners = {}
    for trial in range(2):
        sel = AutotuneSelector()
        winners[trial] = sel.calibrate(
            tuned, lambda n: (jnp.ones(n),), sizes=sizes, reps=3)
    # calibration on fixed sizes picks a stable winner
    assert winners[0] == winners[1]
    assert set(winners[0].values()) == {"ref"}
    rep = ldg.coverage_report()
    cells = rep["calibrated_variants"]["tuned"]
    assert cells == {f"default@2^{size_bucket(n)}": "ref" for n in sizes}
    assert rep["variant_wins"] == {"ref": len(sizes)}
    # selection honors the calibrated cell (and nearest-bucket fallback)
    sel = AutotuneSelector()
    sel.calibrate(tuned, lambda n: (jnp.ones(n),), sizes=sizes, reps=2)
    assert sel.select(tuned, "default", (jnp.ones(4096),), {}) == "ref"
    assert sel.select(tuned, "default", (jnp.ones(1 << 20),), {}) == "ref"


def test_autotune_mirrors_winner_into_foreign_ledger():
    ldg = Ledger("own")

    @region("m", ledger=ldg)
    def m(x):
        return x + 1.0

    foreign = Ledger("foreign")
    sel = AutotuneSelector()
    sel.calibrate(m, lambda n: (jnp.ones(n),), sizes=(256,), reps=1,
                  ledger=foreign)
    assert "m" in foreign.coverage_report()["calibrated_variants"]


def test_size_bucket_model():
    assert size_bucket(1) == 1
    assert size_bucket(255) == 8
    assert size_bucket(256) == 9          # [2^8, 2^9)
    assert size_bucket(511) == 9
    assert size_bucket(512) == 10


# ---------------------------------------------------------------------------
# Captured programs re-resolve variants at replay
# ---------------------------------------------------------------------------

def replay_fixture():
    ldg = Ledger("prog")

    @region("work", ledger=ldg)
    def work(x):
        return x * 2.0 + 1.0

    @work.variant("pallas")
    def _w(x):
        return (x + 0.0) * 2.0 + 1.0

    @region("tail", ledger=ldg)          # no variants: fallback territory
    def tail(x):
        return x - 0.5

    def step(run, x):
        return run(tail, run(work, x))

    x = jnp.linspace(0.0, 1.0, 1 << 14)
    return capture(step, x, name="vprog"), x


def test_one_trace_replays_under_any_selector_sync_async():
    prog, x = replay_fixture()
    outs = {}
    for sel in ("ref", "pallas"):
        for make_ex in (lambda s: Executor(UnifiedPolicy(
                            selector=StaticSelector(s))),
                        lambda s: AsyncExecutor(DiscretePolicy(
                            selector=StaticSelector(s)))):
            ex = make_ex(sel)
            out = np.asarray(prog.replay(ex, x))
            outs.setdefault(sel, out)
            np.testing.assert_allclose(out, outs[sel], rtol=1e-6, atol=1e-7)
            counts = ex.report()["impl_counts"]
            # the variant-carrying op follows the selector; the plain op
            # falls back to ref — proof the trace re-resolves per replay
            if sel == "pallas":
                assert counts == {"pallas": 1, "ref": 1}, counts
            else:
                assert counts == {"ref": 2}, counts
    np.testing.assert_allclose(outs["pallas"], outs["ref"],
                               rtol=1e-6, atol=1e-7)


def test_replay_batch_accepts_selector():
    prog, x = replay_fixture()
    xs = jnp.stack([x, x + 0.25])
    base = prog.replay_batch(xs)
    for sel in (StaticSelector("pallas"), None):
        out = prog.replay_batch(xs, selector=sel)
        np.testing.assert_allclose(np.asarray(out), np.asarray(base),
                                   rtol=1e-6, atol=1e-7)


# ---------------------------------------------------------------------------
# Kernel-package contract
# ---------------------------------------------------------------------------

def test_kernel_packages_all_register_ref():
    from repro.kernels import (PACKAGES, REQUIRED_VARIANT,
                               check_ref_variants, variant_tables)
    tables = variant_tables()
    assert set(tables) == set(PACKAGES)
    for pkg, ops in tables.items():
        for op, table in ops.items():
            assert REQUIRED_VARIANT in table, f"{pkg}.{op}"
            assert "pallas" in table, f"{pkg}.{op}"
    assert check_ref_variants() == {pkg: len(ops)
                                    for pkg, ops in tables.items()}


# ---------------------------------------------------------------------------
# Acceptance scenario: one captured SIMPLE step under every selector,
# sync + async (the 2-APU sharded leg runs in a subprocess below)
# ---------------------------------------------------------------------------

def cavity_fixture():
    from repro.cfd.simple import SimpleConfig, SimpleFoam, init_state
    cfg = SimpleConfig(grid=Grid((8, 8, 8)), nu=0.1, inner_max=5)
    app = SimpleFoam(cfg)
    st = init_state(cfg)
    st, _, _ = app.run_steps(st, 1)
    return app, st, app.capture_step(st)


def _fields(s):
    return [np.asarray(f) for f in (s.u, s.v, s.w, s.p)]


def test_cavity_step_replays_under_every_selector():
    app, st, prog = cavity_fixture()
    # a calibrated AutotuneSelector over the two solver hot-spot regions
    auto = AutotuneSelector()
    g = app.cfg.grid
    from repro.cfd import fvm
    A, _ = fvm.laplacian(g, 1.0)
    x = jnp.ones(g.shape, jnp.float32)
    auto.calibrate(app.solver_regions.amul,
                   lambda n: (A.diag, A.off, x), sizes=(g.n,), reps=2)
    selectors = {"ref": StaticSelector("ref"),
                 "pallas": StaticSelector("pallas"),
                 "autotuned": auto}
    outs, counts = {}, {}
    for name, sel in selectors.items():
        sync = Executor(UnifiedPolicy(selector=sel))
        s_sync, _ = app.replay_steps(prog, st, 1, sync)
        asyn = AsyncExecutor(DiscretePolicy(selector=sel))
        s_asyn, _ = app.replay_steps(prog, st, 1, asyn)
        outs[name] = _fields(s_sync)
        counts[name] = sync.report()["impl_counts"]
        scale = max(np.max(np.abs(f)) for f in outs[name])
        tol = 1e-5 * max(1.0, scale)              # DESIGN §2 bound
        for a, b in zip(outs[name], _fields(s_asyn)):
            if name == "autotuned":
                # sync routes "default", async discrete routes "device":
                # calibrated cells differ per target, so the two replays
                # may legitimately run different (parity-bounded) variants
                np.testing.assert_allclose(a, b, atol=tol, rtol=0,
                                           err_msg=name)
            else:
                # a static selector resolves identically on both
                # executors: same executables, bit-for-bit agreement
                np.testing.assert_array_equal(a, b, err_msg=name)
    # DESIGN §2 tolerance across selectors on the whole replayed step
    scale = max(np.max(np.abs(f)) for f in outs["ref"])
    tol = 1e-5 * max(1.0, scale)
    for name in ("pallas", "autotuned"):
        for a, b in zip(outs["ref"], outs[name]):
            np.testing.assert_allclose(a, b, atol=tol, rtol=0,
                                       err_msg=name)
    # impl_counts prove which variant ran where
    assert set(counts["ref"]) == {"ref"}
    assert counts["pallas"]["pallas"] > 0      # kernels engaged ...
    assert counts["pallas"]["ref"] > 0         # ... with ref fallback
    assert counts["autotuned"]["ref"] > 0      # uncalibrated regions: ref
    total = sum(counts["ref"].values())
    assert all(sum(c.values()) == total for c in counts.values())


def test_two_apu_sharded_replay_under_pallas_variant(tmp_path):
    """The sharded leg of the acceptance criterion: the SAME captured step
    replayed on 2 simulated APUs under StaticSelector('pallas') keeps §2
    parity with its single-device replay, and the aggregated node report's
    impl_counts prove the kernels ran decomposed."""
    out = tmp_path / "apu2_pallas.json"
    cmd = [sys.executable, "-m", "repro.launch.scaling", "--apus", "2",
           "--steps", "1", "--grid", "8,8,8", "--inner-max", "3",
           "--variant", "pallas", "--out", str(out)]
    r = subprocess.run(cmd, capture_output=True, text=True, timeout=600,
                       env={**os.environ, "XLA_FLAGS": ""})
    assert r.returncode == 0, r.stderr[-2000:]
    rec = json.loads(out.read_text())
    assert rec["parity_ok"], rec
    assert rec["variant"] == "pallas"
    assert rec["impl_counts"].get("pallas", 0) > 0
    assert rec["impl_counts"].get("ref", 0) > 0     # fallback regions
    assert rec["report"]["devices"] == 2
