"""HLO parser validation: trip-count-scaled flops must equal the unrolled
program's flops; collectives found and scaled."""
import subprocess
import sys

CODE = r'''
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.launch import hloparse

x = jax.ShapeDtypeStruct((256, 256), jnp.float32)

def scanned(x, w):
    def body(c, _):
        return c @ w, None
    y, _ = jax.lax.scan(body, x, None, length=10)
    return y

def unrolled(x, w):
    for _ in range(10):
        x = x @ w
    return x

fs = hloparse.analyze(jax.jit(scanned).lower(x, x).compile().as_text()).flops
fu = hloparse.analyze(jax.jit(unrolled).lower(x, x).compile().as_text()).flops
assert abs(fs - fu) / fu < 0.01, (fs, fu)
assert abs(fu - 10 * 2 * 256**3) / (10 * 2 * 256**3) < 0.01

mesh = jax.make_mesh((8,), ("model",))
def sharded(x, w):
    def body(c, _):
        y = jax.lax.with_sharding_constraint(
            c @ w, NamedSharding(mesh, P(None, "model")))
        y = jax.lax.with_sharding_constraint(y, NamedSharding(mesh, P()))
        return y, None
    y, _ = jax.lax.scan(body, x, None, length=5)
    return y
c = jax.jit(sharded, in_shardings=(NamedSharding(mesh, P()),
                                   NamedSharding(mesh, P(None, "model"))))
r = hloparse.analyze(c.lower(x, x).compile().as_text())
ag = r.collectives.get("all-gather", {})
assert ag.get("count") == 5.0, r.collectives       # scaled by trip count
assert abs(r.flops - 5 * 2 * 256**3 / 8) / (5 * 2 * 256**3 / 8) < 0.01
print("HLOPARSE_OK")
'''


def test_hloparse_subprocess():
    r = subprocess.run([sys.executable, "-c", CODE], capture_output=True,
                       text=True, timeout=300)
    assert "HLOPARSE_OK" in r.stdout, r.stderr[-2000:]


def test_shape_bytes():
    from repro.launch.hloparse import shape_elems_bytes
    assert shape_elems_bytes("f32[128,4]{1,0}") == (512, 2048)
    assert shape_elems_bytes("bf16[10]") == (10, 20)
    assert shape_elems_bytes("(f32[4], s32[2])") == (6, 24)
    assert shape_elems_bytes("pred[]") == (1, 1)
