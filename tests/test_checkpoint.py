"""Checkpoint: roundtrip (hypothesis), atomicity, GC, async."""
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.checkpoint.ckpt import Checkpointer


@given(st.lists(st.integers(1, 50), min_size=1, max_size=5),
       st.sampled_from(["float32", "int32", "bfloat16"]))
@settings(max_examples=20, deadline=None)
def test_roundtrip_random_trees(dims, dtype):
    rng = np.random.RandomState(sum(dims))
    tree = {"w": {}, "step": jnp.asarray(3)}
    for i, d in enumerate(dims):
        arr = rng.randn(d, 4).astype(np.float32)
        tree["w"][f"l{i}"] = jnp.asarray(arr).astype(dtype)
    with tempfile.TemporaryDirectory() as d:
        ck = Checkpointer(d, async_save=False)
        ck.save(1, tree)
        out, man = ck.restore(tree)
        for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(out)):
            np.testing.assert_array_equal(np.asarray(a, np.float32),
                                          np.asarray(b, np.float32))


def test_gc_keeps_k():
    with tempfile.TemporaryDirectory() as d:
        ck = Checkpointer(d, keep=2, async_save=False)
        t = {"a": jnp.ones(4)}
        for s in (1, 2, 3, 4):
            ck.save(s, t)
        assert ck.all_steps() == [3, 4]


def test_tmp_dirs_invisible():
    with tempfile.TemporaryDirectory() as d:
        ck = Checkpointer(d, async_save=False)
        ck.save(1, {"a": jnp.ones(2)})
        (ck.dir / "step_0000000009.tmp").mkdir()
        assert ck.latest_step() == 1


def test_async_save_blocks_correctly():
    with tempfile.TemporaryDirectory() as d:
        ck = Checkpointer(d, async_save=True)
        ck.save(1, {"a": jnp.arange(100000.)})
        ck.wait()
        out, man = ck.restore({"a": jnp.zeros(100000)})
        np.testing.assert_array_equal(np.asarray(out["a"]), np.arange(100000.))
