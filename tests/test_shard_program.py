"""Sharded region programs (repro.core.shard_program): halo-width
inference from DIA offsets, degenerate 1-device decomposition == plain
replay, per-device ledger aggregation arithmetic, sharded pooling, and the
real multi-device parity check (subprocess — the APU count must be in
XLA_FLAGS before jax imports, and this process already sees one device)."""
import json
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.cfd.dia import STENCIL_OFFSETS, compose_offsets
from repro.core.ledger import Ledger
from repro.core.pool import DeviceBufferPool
from repro.core.program import capture
from repro.core.regions import (DiscretePolicy, Executor, UnifiedPolicy,
                                region)
from repro.core.shard_program import (ShardExecutor, ShardedProgram,
                                      halo_width, shard_program)

GRID = (8, 8, 8)


def apu_mesh_1():
    return jax.make_mesh((1,), ("apu",), devices=jax.devices()[:1])


def make_field_program(ledger=None):
    """A small cavity-shaped program over 3-D fields: a pointwise region,
    a stencil region (declared DIA offsets + halo_args), and a reduction
    frozen as a constant."""
    kw = dict(ledger=ledger or Ledger("shard_test"))

    @region("scale", **kw)
    def scale(d, x):
        return d * x

    @region("stencil", stencil=STENCIL_OFFSETS, halo_args=("x",), **kw)
    def stencil(c, x):
        nz = x.shape[2]
        zlo = jnp.pad(x, ((0, 0), (0, 0), (1, 0)))[:, :, :nz]
        return c * x + zlo

    @region("dot", **kw)
    def dot(x, y):
        return jnp.sum(x * y)

    def step(run, d, x):
        a = run(scale, d, x)
        b = run(stencil, d, a)
        s = float(run(dot, b, b))              # frozen control-flow scalar
        return run(scale, s / (abs(s) + 1.0), b)

    d = jnp.linspace(1.0, 2.0, int(np.prod(GRID))).reshape(GRID)
    x = jnp.full(GRID, 0.3, jnp.float32)
    return capture(step, d, x, name="mini3d"), (d, x)


# ---------------------------------------------------------------------------
# Halo-width inference
# ---------------------------------------------------------------------------

def test_halo_width_from_dia_offsets():
    # one band per face direction: width 1 along every grid axis
    for axis in range(3):
        assert halo_width(STENCIL_OFFSETS, axis) == 1
    # composed 7-point stencils (e.g. the two DILU half-sweeps) reach 2
    composed = compose_offsets(STENCIL_OFFSETS, STENCIL_OFFSETS)
    assert halo_width(composed, 2) == 2
    # pointwise regions exchange nothing
    assert halo_width(None, 2) == 0
    assert halo_width((), 2) == 0
    # offsets on other axes don't bleed into the decomposed one
    assert halo_width(((0, -1), (0, 1)), 2) == 0


def test_solver_regions_declare_stencils():
    from repro.cfd.solvers import make_solver_regions
    R = make_solver_regions(Ledger("decl"))
    assert halo_width(R.amul.stencil, 2) == 1
    assert halo_width(R.precond.stencil, 2) == 2    # two half-sweeps
    assert R.dot.stencil is None                    # reductions: pointwise


# ---------------------------------------------------------------------------
# Degenerate 1-device mesh == plain replay
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("make_policy", [UnifiedPolicy, DiscretePolicy])
def test_one_device_mesh_equals_plain_replay(make_policy):
    prog, (d, x) = make_field_program()
    ref = prog.replay(Executor(make_policy()), d, x)
    sp = shard_program(prog, apu_mesh_1(), make_policy())
    out = sp.replay(d, x)
    np.testing.assert_array_equal(np.asarray(ref), np.asarray(out))
    rep = sp.coverage_report()
    assert rep["devices"] == 1
    # a single shard has no neighbor to exchange with: the halo region
    # still runs (and is accounted) but moves zero inter-APU bytes
    assert rep["exchange_bytes"] == 0
    assert "halo(stencil)" in sp.ledgers[0].regions


def test_sharded_program_quacks_like_an_executor():
    """prog.replay(sharded, ...) dispatches through the replay_program
    hook, so SimpleFoam.replay_steps & co. take a ShardedProgram as-is."""
    prog, (d, x) = make_field_program()
    sp = shard_program(prog, apu_mesh_1(), UnifiedPolicy())
    out_via_prog = prog.replay(sp, d, x)
    np.testing.assert_array_equal(np.asarray(out_via_prog),
                                  np.asarray(sp.replay(d, x)))


def test_sharding_rule():
    sp = shard_program(make_field_program()[0], apu_mesh_1(),
                       UnifiedPolicy())
    ex = sp.executor
    field = jnp.zeros(GRID)
    off = jnp.zeros((6,) + GRID)
    scalar = jnp.float32(1.0)
    assert ex.sharding_for(field).spec == jax.sharding.PartitionSpec(
        None, None, "apu")
    assert ex.sharding_for(off).spec == jax.sharding.PartitionSpec(
        None, None, None, "apu")
    assert ex.sharding_for(scalar).spec == jax.sharding.PartitionSpec()


# ---------------------------------------------------------------------------
# Ledger aggregation arithmetic
# ---------------------------------------------------------------------------

def make_device_ledgers(n=4):
    """N per-device ledgers recording the 1/N-share convention for one
    stencil region + its halo row, with known numbers."""
    ledgers = [Ledger(f"apu{i}") for i in range(n)]
    for led in ledgers:
        led.record("Amul", device=True, offloaded=True,
                   compute_s=0.4 / n, staging_s=0.2 / n,
                   staging_bytes=4096 // n, elems=512 // n)
        led.record("halo(Amul)", device=True, offloaded=True,
                   compute_s=0.0, exchange_s=0.1 / n, exchange_bytes=256)
    return ledgers


def test_merged_ledger_reproduces_node_totals():
    ledgers = make_device_ledgers(4)
    node = Ledger.merged(ledgers)
    rep = node.coverage_report()
    assert rep["compute_s"] == pytest.approx(0.4)
    assert rep["staging_s"] == pytest.approx(0.2)
    assert rep["exchange_s"] == pytest.approx(0.1)
    assert rep["exchange_bytes"] == 4 * 256
    assert rep["total_s"] == pytest.approx(0.7)     # compute+staging+exchange
    assert rep["exchange_fraction"] == pytest.approx(0.1 / 0.7)
    assert rep["staging_fraction"] == pytest.approx(0.2 / 0.7)
    # per-row: exchange lands on the halo row, not the stencil row
    assert node.regions["Amul"].exchange_s == 0.0
    assert node.regions["halo(Amul)"].exchange_s == pytest.approx(0.1)
    assert node.regions["halo(Amul)"].total_s == pytest.approx(0.1)


def test_record_accepts_exchange_and_resets_it():
    led = Ledger("x")
    led.record("r", device=True, compute_s=1.0, exchange_s=0.5,
               exchange_bytes=100)
    assert led.regions["r"].total_s == pytest.approx(1.5)
    led.reset_timings()
    assert led.regions["r"].exchange_s == 0.0
    assert led.regions["r"].exchange_bytes == 0


def test_same_named_regions_keep_distinct_rows():
    """Two distinct Region objects sharing a display name (registered in
    different app ledgers) must not merge into one per-device row — the
    Executor._row_name contract, upheld by ShardExecutor."""
    @region("Amul", ledger=Ledger("a"))
    def amul1(x):
        return x * 2.0

    @region("Amul", ledger=Ledger("b"))
    def amul2(x):
        return x + 1.0

    def step(run, x):
        return run(amul2, run(amul1, x))

    prog = capture(step, jnp.ones(GRID), name="dup")
    sp = shard_program(prog, apu_mesh_1(), UnifiedPolicy())
    sp.replay(jnp.ones(GRID))
    rows = sp.ledgers[0].regions
    assert "Amul" in rows and "Amul#2" in rows
    assert rows["Amul"].calls == 1 and rows["Amul#2"].calls == 1


def test_report_per_device_breakdown_sums_to_aggregate():
    prog, (d, x) = make_field_program()
    sp = shard_program(prog, apu_mesh_1(), UnifiedPolicy())
    sp.replay(d, x)
    rep = sp.coverage_report()
    assert len(rep["per_device"]) == rep["devices"] == 1
    per = rep["per_device"][0]
    for key in ("compute_s", "staging_s", "exchange_s"):
        assert per[key] == pytest.approx(rep[key], abs=1e-9), key
    assert per["exchange_s"] >= 0.0
    assert rep["mode"].startswith("unified+sharded")


# ---------------------------------------------------------------------------
# Batched replay over the mesh + sharded pooling
# ---------------------------------------------------------------------------

def test_replay_steps_mesh_kwarg_matches_plain_replay():
    """SimpleFoam.replay_steps(mesh=...) rebinds a plain Executor into the
    decomposition (convenience path; reports need an explicit
    ShardExecutor) and rejects executors it cannot rebind."""
    from repro.cfd.grid import Grid
    from repro.cfd.simple import SimpleConfig, SimpleFoam, init_state
    from repro.core.program import AsyncExecutor
    cfg = SimpleConfig(grid=Grid((6, 6, 6)), nu=0.1, inner_max=3)
    app = SimpleFoam(cfg)
    st = init_state(cfg)
    st, _, _ = app.run_steps(st, 1)
    prog = app.capture_step(st)
    s_plain, _ = app.replay_steps(prog, st, 1, Executor(UnifiedPolicy()))
    mesh = apu_mesh_1()
    s_mesh, _ = app.replay_steps(prog, st, 1, Executor(UnifiedPolicy()),
                                 mesh=mesh)
    for a, b in zip((s_plain.u, s_plain.v, s_plain.w, s_plain.p),
                    (s_mesh.u, s_mesh.v, s_mesh.w, s_mesh.p)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    with pytest.raises(ValueError, match="cannot rebind"):
        app.replay_steps(prog, st, 1, AsyncExecutor(UnifiedPolicy()),
                         mesh=mesh)


def test_sharded_replay_batch_matches_sequential():
    prog, (d, x) = make_field_program()
    sp = shard_program(prog, apu_mesh_1(), UnifiedPolicy(), shard_dim=0)
    B = 2
    ds = jnp.stack([d] * B)
    xs = jnp.stack([x + 0.01 * i for i in range(B)])
    batched = sp.replay_batch(ds, xs)
    ex = Executor(UnifiedPolicy())
    seq = jnp.stack([prog.replay(ex, ds[i], xs[i]) for i in range(B)])
    np.testing.assert_allclose(np.asarray(batched), np.asarray(seq),
                               rtol=1e-6, atol=1e-6)
    assert "mini3d[batch]" in sp.ledgers[0].regions


def test_device_pool_recycles_sharded_buffers():
    mesh = apu_mesh_1()
    sh = jax.sharding.NamedSharding(
        mesh, jax.sharding.PartitionSpec(None, None, "apu"))
    pool = DeviceBufferPool(min_elems=1)
    a = pool.acquire(GRID, jnp.float32, sharding=sh)
    assert a.sharding == sh
    pool.release(a)
    b = pool.acquire(GRID, jnp.float32, sharding=sh)
    assert pool.stats.hits == 1
    # plain acquires don't steal from the sharded bucket
    pool.release(b)
    c = pool.acquire(GRID, jnp.float32)
    assert pool.stats.hits == 1 and pool.stats.misses == 2
    assert c is not b


# ---------------------------------------------------------------------------
# Real multi-device parity (subprocess: needs its own XLA_FLAGS)
# ---------------------------------------------------------------------------

def test_two_apu_cavity_parity_subprocess(tmp_path):
    """The acceptance-criterion scenario at test scale: the captured
    SIMPLE step replayed on 1 vs 2 simulated APUs agrees within the
    docs/DESIGN.md §2 tolerance, and the aggregated report splits
    compute / staging / exchange per device."""
    out = tmp_path / "apu2.json"
    cmd = [sys.executable, "-m", "repro.launch.scaling", "--apus", "2",
           "--steps", "1", "--grid", "8,8,8", "--inner-max", "4",
           "--out", str(out)]
    r = subprocess.run(cmd, capture_output=True, text=True, timeout=600,
                       env={**os.environ, "XLA_FLAGS": ""})
    assert r.returncode == 0, r.stderr[-2000:]
    rec = json.loads(out.read_text())
    assert rec["parity_ok"], rec
    assert rec["parity_max_abs_err"] <= rec["parity_tol"]
    rep = rec["report"]
    assert rep["devices"] == 2
    assert len(rep["per_device"]) == 2
    assert rep["exchange_s"] > 0.0
    assert rep["exchange_bytes"] > 0
    # 1/N recording convention: each APU ledger carries half of the node
    # aggregate (both sides derive from the same measured wall intervals,
    # so this checks the share arithmetic, not runtime load balance)
    a, b = rep["per_device"]
    assert a["compute_s"] + b["compute_s"] == pytest.approx(
        rep["compute_s"])
    assert a["compute_s"] == pytest.approx(rep["compute_s"] / 2)
    assert a["exchange_bytes"] + b["exchange_bytes"] == \
        rep["exchange_bytes"]
    # halo-exchange rows for the stencil regions are explicit
    assert any(n.startswith("halo(Amul)") for n in rec["halo_rows"])
    assert any("precondition" in n for n in rec["halo_rows"])
