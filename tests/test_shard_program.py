"""Sharded region programs (repro.core.shard_program): halo-width
inference from DIA offsets (plus hypothesis property tests), degenerate
1-device decomposition == plain replay, wide-halo ghost-zone value
identity, overlap-aware per-device ledger aggregation arithmetic, sharded
pooling, and the real multi-device parity checks (subprocess — the APU
count must be in XLA_FLAGS before jax imports, and this process already
sees one device): the 2-APU cavity acceptance run, the remainder-row
padding case, and the schedule x halo-width x mesh x policy parity
matrix (``python tests/test_shard_program.py --matrix`` under 4 forced
devices)."""
import json
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ImportError:  # direct `python tests/... --matrix` run: no conftest
    # stub installed and the property tests aren't reached — inert deco's
    class _InertStrategy:
        def __call__(self, *a, **k):
            return self

        def __getattr__(self, name):
            return self

    st = _InertStrategy()

    def given(*_a, **_k):
        return lambda fn: fn

    def settings(*_a, **_k):
        return lambda fn: fn

from repro.cfd.dia import STENCIL_OFFSETS, compose_offsets
from repro.core.ledger import Ledger
from repro.core.pool import DeviceBufferPool
from repro.core.program import capture
from repro.core.regions import (DiscretePolicy, Executor, UnifiedPolicy,
                                region)
from repro.core.shard_program import (ShardExecutor, ShardedProgram,
                                      halo_width, shard_program)
from repro.launch.mesh import make_apu_mesh, parse_mesh_shape

GRID = (8, 8, 8)


def apu_mesh_1():
    return jax.make_mesh((1,), ("apu",), devices=jax.devices()[:1])


def make_field_program(ledger=None):
    """A small cavity-shaped program over 3-D fields: a pointwise region,
    a stencil region (declared DIA offsets + halo_args), and a reduction
    frozen as a constant."""
    kw = dict(ledger=ledger or Ledger("shard_test"))

    @region("scale", **kw)
    def scale(d, x):
        return d * x

    @region("stencil", stencil=STENCIL_OFFSETS, halo_args=("x",), **kw)
    def stencil(c, x):
        nz = x.shape[2]
        zlo = jnp.pad(x, ((0, 0), (0, 0), (1, 0)))[:, :, :nz]
        return c * x + zlo

    @region("dot", **kw)
    def dot(x, y):
        return jnp.sum(x * y)

    def step(run, d, x):
        a = run(scale, d, x)
        b = run(stencil, d, a)
        s = float(run(dot, b, b))              # frozen control-flow scalar
        return run(scale, s / (abs(s) + 1.0), b)

    d = jnp.linspace(1.0, 2.0, int(np.prod(GRID))).reshape(GRID)
    x = jnp.full(GRID, 0.3, jnp.float32)
    return capture(step, d, x, name="mini3d"), (d, x)


# ---------------------------------------------------------------------------
# Halo-width inference
# ---------------------------------------------------------------------------

def test_halo_width_from_dia_offsets():
    # one band per face direction: width 1 along every grid axis
    for axis in range(3):
        assert halo_width(STENCIL_OFFSETS, axis) == 1
    # composed 7-point stencils (e.g. the two DILU half-sweeps) reach 2
    composed = compose_offsets(STENCIL_OFFSETS, STENCIL_OFFSETS)
    assert halo_width(composed, 2) == 2
    # pointwise regions exchange nothing
    assert halo_width(None, 2) == 0
    assert halo_width((), 2) == 0
    # offsets on other axes don't bleed into the decomposed one
    assert halo_width(((0, -1), (0, 1)), 2) == 0


def test_solver_regions_declare_stencils():
    from repro.cfd.solvers import make_solver_regions
    R = make_solver_regions(Ledger("decl"))
    assert halo_width(R.amul.stencil, 2) == 1
    assert halo_width(R.precond.stencil, 2) == 2    # two half-sweeps
    assert R.dot.stencil is None                    # reductions: pointwise


# ---------------------------------------------------------------------------
# Property tests (hypothesis; skip when it isn't installed — conftest stub)
# ---------------------------------------------------------------------------

offsets_st = st.lists(st.tuples(st.integers(0, 2), st.integers(-3, 3)),
                      max_size=12).map(tuple)


@given(offsets_st)
@settings(deadline=None, max_examples=100)
def test_prop_halo_width_covers_every_declared_offset(offsets):
    """The inferred halo width is never narrower than any declared band:
    a decomposition exchanging ``halo_width`` ghost layers always covers
    the stencil's reach on that axis (and is exactly the max reach)."""
    for ax, d in offsets:
        assert halo_width(offsets, ax) >= abs(d)
    for ax in range(3):
        assert halo_width(offsets, ax) == max(
            (abs(d) for a, d in offsets if a == ax), default=0)


@given(offsets_st, offsets_st)
@settings(deadline=None, max_examples=100)
def test_prop_compose_offsets_monotone_under_composition(a, b):
    """compose_offsets is inflationary and subadditive: chaining two
    stencils never shrinks the reach of either (monotone), and never
    reaches further than the sum of the two (Minkowski bound)."""
    comp = compose_offsets(a, b)
    assert set(a) <= set(comp) and set(b) <= set(comp)
    for ax in range(3):
        wa, wb, wc = (halo_width(a, ax), halo_width(b, ax),
                      halo_width(comp, ax))
        assert wc >= max(wa, wb)       # monotone
        assert wc <= wa + wb           # subadditive


def _stencil1d(x):
    """width-1 reference stencil with the zero-Dirichlet global boundary:
    y[i] = x[i-1] + 2 x[i] + x[i+1]."""
    p = np.pad(x, 1)
    return p[:-2] + 2.0 * x + p[2:]


def _exchanged_steps(chunks, n_steps, ghost):
    """The chunked ghost-zone model of the sharded replay: ONE exchange of
    ``ghost``-wide halos, then ``n_steps`` stencil applications on the
    extended chunks, keeping the interior.  Valid while n_steps <= ghost
    (one layer of ghost validity is consumed per application)."""
    assert n_steps <= ghost
    n = len(chunks)
    ext = []
    for i, c in enumerate(chunks):
        left = chunks[i - 1][-ghost:] if i > 0 else np.zeros(
            ghost, c.dtype)
        right = chunks[i + 1][:ghost] if i < n - 1 else np.zeros(
            ghost, c.dtype)
        ext.append(np.concatenate([left, c, right]))
    for _ in range(n_steps):
        ext = [_stencil1d(e) for e in ext]
    return [e[ghost:len(e) - ghost] for e in ext]


@given(st.lists(st.floats(-4.0, 4.0, allow_nan=False, width=32),
                min_size=8, max_size=48),
       st.integers(1, 3), st.integers(2, 4))
@settings(deadline=None, max_examples=50)
def test_prop_wide_halo_replay_value_identical(vals, k, nchunks):
    """The wide-halo schedule's contract: one width-k exchange followed by
    k stencil applications is VALUE-IDENTICAL (bit-exact) to k separate
    width-1 exchanged steps — and both equal the undecomposed replay."""
    m = len(vals) // nchunks
    if m < k:                          # chunks must hold >= k ghost cells
        m = k
        nchunks = max(2, len(vals) // m)
        if len(vals) < 2 * m:
            return                     # domain too small for this k
    x = np.asarray(vals[:m * nchunks], np.float32)
    chunks = [x[i * m:(i + 1) * m] for i in range(nchunks)]

    wide = np.concatenate(_exchanged_steps(chunks, k, ghost=k))
    narrow = chunks
    for _ in range(k):                 # k width-1 exchanged steps
        narrow = _exchanged_steps(narrow, 1, ghost=1)
    narrow = np.concatenate(narrow)
    ref = x
    for _ in range(k):
        ref = _stencil1d(ref)

    np.testing.assert_array_equal(wide, narrow)
    np.testing.assert_array_equal(wide, ref)


# ---------------------------------------------------------------------------
# Degenerate 1-device mesh == plain replay
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("make_policy", [UnifiedPolicy, DiscretePolicy])
def test_one_device_mesh_equals_plain_replay(make_policy):
    prog, (d, x) = make_field_program()
    ref = prog.replay(Executor(make_policy()), d, x)
    sp = shard_program(prog, apu_mesh_1(), make_policy())
    out = sp.replay(d, x)
    np.testing.assert_array_equal(np.asarray(ref), np.asarray(out))
    rep = sp.coverage_report()
    assert rep["devices"] == 1
    # a single shard has no neighbor to exchange with: the halo region
    # still runs (and is accounted) but moves zero inter-APU bytes
    assert rep["exchange_bytes"] == 0
    assert "halo(stencil)" in sp.ledgers[0].regions


def test_sharded_program_quacks_like_an_executor():
    """prog.replay(sharded, ...) dispatches through the replay_program
    hook, so SimpleFoam.replay_steps & co. take a ShardedProgram as-is."""
    prog, (d, x) = make_field_program()
    sp = shard_program(prog, apu_mesh_1(), UnifiedPolicy())
    out_via_prog = prog.replay(sp, d, x)
    np.testing.assert_array_equal(np.asarray(out_via_prog),
                                  np.asarray(sp.replay(d, x)))


def test_sharding_rule():
    sp = shard_program(make_field_program()[0], apu_mesh_1(),
                       UnifiedPolicy())
    ex = sp.executor
    field = jnp.zeros(GRID)
    off = jnp.zeros((6,) + GRID)
    scalar = jnp.float32(1.0)
    assert ex.sharding_for(field).spec == jax.sharding.PartitionSpec(
        None, None, "apu")
    assert ex.sharding_for(off).spec == jax.sharding.PartitionSpec(
        None, None, None, "apu")
    assert ex.sharding_for(scalar).spec == jax.sharding.PartitionSpec()


def test_parse_mesh_shape_and_pad_grid():
    from repro.launch.scaling import pad_grid
    assert parse_mesh_shape("4") == (4,)
    assert parse_mesh_shape(4) == (4,)
    assert parse_mesh_shape("2x2") == (2, 2)
    assert parse_mesh_shape("2x2x2") == (2, 2, 2)
    # remainder-row padding: odd extents grow to the next mesh multiple
    assert pad_grid((8, 8, 9), (2,)) == (8, 8, 10)
    assert pad_grid((8, 9, 9), (2, 2)) == (8, 10, 10)
    assert pad_grid((8, 8, 8), (2, 4)) == (8, 8, 8)


def test_2d_mesh_sharding_rule_and_report():
    """Degenerate (1,1) 2-D mesh in-process: fields decompose over BOTH
    trailing dims, the replay matches the plain one, and the report
    carries the new schedule keys."""
    prog, (d, x) = make_field_program()
    ref = prog.replay(Executor(UnifiedPolicy()), d, x)
    mesh = make_apu_mesh((1, 1))
    sp = shard_program(prog, mesh, UnifiedPolicy())
    ex = sp.executor
    assert ex.sharding_for(jnp.zeros(GRID)).spec == \
        jax.sharding.PartitionSpec(None, "apu0", "apu1")
    assert ex.sharding_for(jnp.zeros((6,) + GRID)).spec == \
        jax.sharding.PartitionSpec(None, None, "apu0", "apu1")
    out = sp.replay(d, x)
    np.testing.assert_array_equal(np.asarray(ref), np.asarray(out))
    rep = sp.coverage_report()
    assert rep["mesh_shape"] == [1, 1]
    assert rep["schedule"] == "overlap"
    assert rep["halo_multiplier"] == 1
    assert "overlap_s" in rep and "overlap_s" in rep["per_device"][0]


@pytest.mark.parametrize("schedule,k", [("overlap", 2), ("sequential", 3),
                                        ("split", 1)])
def test_schedules_match_plain_replay_one_device(schedule, k):
    """Every exchange schedule x wide-halo combination reproduces the
    plain replay on a degenerate mesh, across chained steps (the wide-halo
    plan cycles through due and skipped exchanges)."""
    prog, (d, x) = make_field_program()
    ex = Executor(UnifiedPolicy())
    sp = shard_program(prog, apu_mesh_1(), UnifiedPolicy(),
                       halo_multiplier=k,
                       overlap=schedule != "sequential",
                       split_stencil=schedule == "split")
    ref, cur = x, x
    for _ in range(2 * k):             # full halo-plan cycle, twice
        ref = prog.replay(ex, d, ref)
        cur = sp.replay(d, cur)
    if schedule == "split":            # blend pass recompiles the region:
        scale = max(float(np.max(np.abs(np.asarray(ref)))), 1.0)
        np.testing.assert_allclose(np.asarray(cur), np.asarray(ref),
                                   atol=1e-5 * scale, rtol=0)
    else:
        np.testing.assert_array_equal(np.asarray(cur), np.asarray(ref))
    assert sp.coverage_report()["schedule"] == schedule


# ---------------------------------------------------------------------------
# Ledger aggregation arithmetic
# ---------------------------------------------------------------------------

def make_device_ledgers(n=4):
    """N per-device ledgers recording the 1/N-share convention for one
    stencil region + its halo row, with known numbers."""
    ledgers = [Ledger(f"apu{i}") for i in range(n)]
    for led in ledgers:
        led.record("Amul", device=True, offloaded=True,
                   compute_s=0.4 / n, staging_s=0.2 / n,
                   staging_bytes=4096 // n, elems=512 // n)
        led.record("halo(Amul)", device=True, offloaded=True,
                   compute_s=0.0, exchange_s=0.1 / n, exchange_bytes=256)
    return ledgers


def test_merged_ledger_reproduces_node_totals():
    ledgers = make_device_ledgers(4)
    node = Ledger.merged(ledgers)
    rep = node.coverage_report()
    assert rep["compute_s"] == pytest.approx(0.4)
    assert rep["staging_s"] == pytest.approx(0.2)
    assert rep["exchange_s"] == pytest.approx(0.1)
    assert rep["exchange_bytes"] == 4 * 256
    assert rep["total_s"] == pytest.approx(0.7)     # compute+staging+exchange
    assert rep["exchange_fraction"] == pytest.approx(0.1 / 0.7)
    assert rep["staging_fraction"] == pytest.approx(0.2 / 0.7)
    # per-row: exchange lands on the halo row, not the stencil row
    assert node.regions["Amul"].exchange_s == 0.0
    assert node.regions["halo(Amul)"].exchange_s == pytest.approx(0.1)
    assert node.regions["halo(Amul)"].total_s == pytest.approx(0.1)


def test_merged_ledger_excludes_overlapped_exchange_from_totals():
    """Overlap accounting invariant on fabricated per-device ledgers:
    total ~= compute + staging + exchange - overlap, and the exchange
    fraction is computed from the EXPOSED (un-hidden) exchange time."""
    n = 2
    ledgers = [Ledger(f"apu{i}") for i in range(n)]
    for led in ledgers:
        led.record("Amul", device=True, offloaded=True,
                   compute_s=0.4 / n, staging_s=0.1 / n)
        led.record("halo(Amul)", device=True, offloaded=True,
                   compute_s=0.0, exchange_s=0.2 / n, exchange_bytes=128,
                   overlap_s=0.15 / n)
    node = Ledger.merged(ledgers)
    rep = node.coverage_report()
    assert rep["compute_s"] == pytest.approx(0.4)
    assert rep["staging_s"] == pytest.approx(0.1)
    assert rep["exchange_s"] == pytest.approx(0.2)
    assert rep["overlap_s"] == pytest.approx(0.15)
    # the invariant this PR fixes: overlapped exchange is NOT double-counted
    assert rep["total_s"] == pytest.approx(0.4 + 0.1 + 0.2 - 0.15)
    # exposed exchange = exchange - overlap (halo rows have no staging)
    assert rep["exposed_exchange_s"] == pytest.approx(0.05)
    assert rep["exchange_fraction"] == pytest.approx(0.05 / rep["total_s"])
    # per-row: the halo row's own wall-clock contribution is its exposure
    assert node.regions["halo(Amul)"].total_s == pytest.approx(0.05)
    assert node.regions["halo(Amul)"].exposed_exchange_s == \
        pytest.approx(0.05)


def test_record_accepts_overlap_and_clamps_it():
    led = Ledger("x")
    # overlap can never exceed the hideable time (staging + exchange)
    led.record("h", device=True, compute_s=0.0, exchange_s=0.2,
               staging_s=0.1, overlap_s=9.0)
    assert led.regions["h"].overlap_s == pytest.approx(0.3)
    assert led.regions["h"].total_s == pytest.approx(0.0)
    led.reset_timings()
    assert led.regions["h"].overlap_s == 0.0


def test_record_accepts_exchange_and_resets_it():
    led = Ledger("x")
    led.record("r", device=True, compute_s=1.0, exchange_s=0.5,
               exchange_bytes=100)
    assert led.regions["r"].total_s == pytest.approx(1.5)
    led.reset_timings()
    assert led.regions["r"].exchange_s == 0.0
    assert led.regions["r"].exchange_bytes == 0


def test_same_named_regions_keep_distinct_rows():
    """Two distinct Region objects sharing a display name (registered in
    different app ledgers) must not merge into one per-device row — the
    Executor._row_name contract, upheld by ShardExecutor."""
    @region("Amul", ledger=Ledger("a"))
    def amul1(x):
        return x * 2.0

    @region("Amul", ledger=Ledger("b"))
    def amul2(x):
        return x + 1.0

    def step(run, x):
        return run(amul2, run(amul1, x))

    prog = capture(step, jnp.ones(GRID), name="dup")
    sp = shard_program(prog, apu_mesh_1(), UnifiedPolicy())
    sp.replay(jnp.ones(GRID))
    rows = sp.ledgers[0].regions
    assert "Amul" in rows and "Amul#2" in rows
    assert rows["Amul"].calls == 1 and rows["Amul#2"].calls == 1


def test_report_per_device_breakdown_sums_to_aggregate():
    prog, (d, x) = make_field_program()
    sp = shard_program(prog, apu_mesh_1(), UnifiedPolicy())
    sp.replay(d, x)
    rep = sp.coverage_report()
    assert len(rep["per_device"]) == rep["devices"] == 1
    per = rep["per_device"][0]
    for key in ("compute_s", "staging_s", "exchange_s"):
        assert per[key] == pytest.approx(rep[key], abs=1e-9), key
    assert per["exchange_s"] >= 0.0
    assert rep["mode"].startswith("unified+sharded")


# ---------------------------------------------------------------------------
# Batched replay over the mesh + sharded pooling
# ---------------------------------------------------------------------------

def test_replay_steps_mesh_kwarg_matches_plain_replay():
    """SimpleFoam.replay_steps(mesh=...) rebinds a plain Executor into the
    decomposition (convenience path; reports need an explicit
    ShardExecutor) and rejects executors it cannot rebind."""
    from repro.cfd.grid import Grid
    from repro.cfd.simple import SimpleConfig, SimpleFoam, init_state
    from repro.core.program import AsyncExecutor
    cfg = SimpleConfig(grid=Grid((6, 6, 6)), nu=0.1, inner_max=3)
    app = SimpleFoam(cfg)
    st = init_state(cfg)
    st, _, _ = app.run_steps(st, 1)
    prog = app.capture_step(st)
    s_plain, _ = app.replay_steps(prog, st, 1, Executor(UnifiedPolicy()))
    mesh = apu_mesh_1()
    s_mesh, _ = app.replay_steps(prog, st, 1, Executor(UnifiedPolicy()),
                                 mesh=mesh)
    for a, b in zip((s_plain.u, s_plain.v, s_plain.w, s_plain.p),
                    (s_mesh.u, s_mesh.v, s_mesh.w, s_mesh.p)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    with pytest.raises(ValueError, match="cannot rebind"):
        app.replay_steps(prog, st, 1, AsyncExecutor(UnifiedPolicy()),
                         mesh=mesh)


def test_sharded_replay_batch_matches_sequential():
    prog, (d, x) = make_field_program()
    sp = shard_program(prog, apu_mesh_1(), UnifiedPolicy(), shard_dim=0)
    B = 2
    ds = jnp.stack([d] * B)
    xs = jnp.stack([x + 0.01 * i for i in range(B)])
    batched = sp.replay_batch(ds, xs)
    ex = Executor(UnifiedPolicy())
    seq = jnp.stack([prog.replay(ex, ds[i], xs[i]) for i in range(B)])
    np.testing.assert_allclose(np.asarray(batched), np.asarray(seq),
                               rtol=1e-6, atol=1e-6)
    assert "mini3d[batch]" in sp.ledgers[0].regions


def test_device_pool_recycles_sharded_buffers():
    mesh = apu_mesh_1()
    sh = jax.sharding.NamedSharding(
        mesh, jax.sharding.PartitionSpec(None, None, "apu"))
    pool = DeviceBufferPool(min_elems=1)
    a = pool.acquire(GRID, jnp.float32, sharding=sh)
    assert a.sharding == sh
    pool.release(a)
    b = pool.acquire(GRID, jnp.float32, sharding=sh)
    assert pool.stats.hits == 1
    # plain acquires don't steal from the sharded bucket
    pool.release(b)
    c = pool.acquire(GRID, jnp.float32)
    assert pool.stats.hits == 1 and pool.stats.misses == 2
    assert c is not b


# ---------------------------------------------------------------------------
# Real multi-device parity (subprocess: needs its own XLA_FLAGS)
# ---------------------------------------------------------------------------

def test_two_apu_cavity_parity_subprocess(tmp_path):
    """The acceptance-criterion scenario at test scale: the captured
    SIMPLE step replayed on 1 vs 2 simulated APUs agrees within the
    docs/DESIGN.md §2 tolerance, and the aggregated report splits
    compute / staging / exchange per device."""
    out = tmp_path / "apu2.json"
    cmd = [sys.executable, "-m", "repro.launch.scaling", "--apus", "2",
           "--steps", "1", "--grid", "8,8,8", "--inner-max", "4",
           "--out", str(out)]
    r = subprocess.run(cmd, capture_output=True, text=True, timeout=600,
                       env={**os.environ, "XLA_FLAGS": ""})
    assert r.returncode == 0, r.stderr[-2000:]
    rec = json.loads(out.read_text())
    assert rec["parity_ok"], rec
    assert rec["parity_max_abs_err"] <= rec["parity_tol"]
    rep = rec["report"]
    assert rep["devices"] == 2
    assert len(rep["per_device"]) == 2
    assert rep["exchange_s"] > 0.0
    assert rep["exchange_bytes"] > 0
    # 1/N recording convention: each APU ledger carries half of the node
    # aggregate (both sides derive from the same measured wall intervals,
    # so this checks the share arithmetic, not runtime load balance)
    a, b = rep["per_device"]
    assert a["compute_s"] + b["compute_s"] == pytest.approx(
        rep["compute_s"])
    assert a["compute_s"] == pytest.approx(rep["compute_s"] / 2)
    assert a["exchange_bytes"] + b["exchange_bytes"] == \
        rep["exchange_bytes"]
    # halo-exchange rows for the stencil regions are explicit
    assert any(n.startswith("halo(Amul)") for n in rec["halo_rows"])
    assert any("precondition" in n for n in rec["halo_rows"])


def test_odd_grid_remainder_padding_subprocess(tmp_path):
    """Production grids rarely divide evenly: an odd z-extent is padded up
    to the next mesh multiple (both replays run the padded grid, so parity
    stays meaningful) instead of silently replicating or refusing."""
    out = tmp_path / "odd.json"
    cmd = [sys.executable, "-m", "repro.launch.scaling", "--apus", "2",
           "--steps", "1", "--grid", "8,8,9", "--inner-max", "3",
           "--out", str(out)]
    r = subprocess.run(cmd, capture_output=True, text=True, timeout=600,
                       env={**os.environ, "XLA_FLAGS": ""})
    assert r.returncode == 0, r.stderr[-2000:]
    rec = json.loads(out.read_text())
    assert rec["grid_requested"] == [8, 8, 9]
    assert rec["grid"] == [8, 8, 10]
    assert rec["grid_padded"] is True
    assert rec["parity_ok"], rec
    assert rec["report"]["exchange_bytes"] > 0


# ---------------------------------------------------------------------------
# Parity matrix: schedule x halo-width x mesh x policy, vs unsharded replay
# (one subprocess under 4 forced devices runs _matrix_main below)
# ---------------------------------------------------------------------------

#: covering design over the matrix axes — every schedule, both halo
#: widths, and both mesh ranks appear, each cell under all four policies
MATRIX_COMBOS = (
    ("overlap", 1, (4,)),
    ("sequential", 1, (4,)),
    ("overlap", 2, (4,)),
    ("sequential", 2, (2, 2)),
    ("overlap", 1, (2, 2)),
    ("split", 1, (4,)),
    ("split", 2, (2, 2)),
)
MATRIX_POLICIES = ("unified", "discrete", "adaptive", "host")


def _matrix_main() -> None:
    """Runs inside the subprocess (4 forced host devices): every
    MATRIX_COMBOS cell under every placement policy, two chained steps,
    compared against the same policy's unsharded replay — bit-exact for
    the exchange schedules (the roll-roundtrip is a value identity and
    partitioned elementwise compute is bitwise deterministic), DESIGN §2
    tolerance for the split schedule (the boundary blend is a separate
    compilation)."""
    from repro.core.regions import make_policy
    assert jax.device_count() >= 4, jax.devices()
    steps = 2
    prog, (d, x) = make_field_program()
    failures = []
    for policy_name in MATRIX_POLICIES:
        refs, cur = [], x
        ref_ex = Executor(make_policy(policy_name))
        for _ in range(steps):
            cur = prog.replay(ref_ex, d, cur)
            refs.append(np.asarray(cur))
        for schedule, k, mesh_shape in MATRIX_COMBOS:
            mesh = make_apu_mesh(mesh_shape)
            sp = shard_program(prog, mesh, make_policy(policy_name),
                               halo_multiplier=k,
                               overlap=schedule != "sequential",
                               split_stencil=schedule == "split")
            cur = x
            for s in range(steps):
                cur = sp.replay(d, cur)
                got = np.asarray(cur)
                tag = (f"{policy_name}/{schedule}/k={k}/"
                       f"mesh={'x'.join(map(str, mesh_shape))}/step{s}")
                err = float(np.max(np.abs(got - refs[s])))
                if schedule == "split":
                    tol = 1e-5 * max(float(np.max(np.abs(refs[s]))), 1.0)
                    ok = err <= tol
                else:
                    ok = np.array_equal(got, refs[s])
                if not ok:
                    failures.append(f"{tag} max_err={err:.3e}")
                else:
                    print(f"ok {tag} max_err={err:.3e}")
            rep = sp.coverage_report()
            if rep["mesh_shape"] != list(mesh_shape):
                failures.append(f"{tag} bad mesh_shape {rep['mesh_shape']}")
            # adaptive gathers small problems to the host and the offload
            # policy keeps assembly there — no decomposed compute, so no
            # exchange is CORRECT for them at this size; the guarantee
            # holds where device-sharded compute is guaranteed
            if policy_name in ("unified", "discrete"):
                if rep["exchange_bytes"] <= 0:
                    failures.append(f"{tag} no exchange bytes")
                if schedule == "overlap" and rep["overlap_s"] <= 0.0:
                    failures.append(f"{tag} no overlap recorded")
    if failures:
        print("MATRIX FAILURES:\n" + "\n".join(failures))
        raise SystemExit(1)
    print("MATRIX OK")


def test_parity_matrix_subprocess():
    """The satellite parity matrix: overlapped vs sequential vs split,
    width-1 vs wide-halo, 1-D vs 2-D mesh, under all four placement
    policies, against the unsharded replay (subprocess — needs 4 forced
    devices in XLA_FLAGS before jax imports)."""
    src = os.path.join(os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))), "src")
    env = {**os.environ,
           "XLA_FLAGS": "--xla_force_host_platform_device_count=4",
           "PYTHONPATH": os.pathsep.join(
               p for p in (src, os.environ.get("PYTHONPATH")) if p)}
    r = subprocess.run([sys.executable, os.path.abspath(__file__),
                        "--matrix"],
                       capture_output=True, text=True, timeout=900, env=env)
    assert r.returncode == 0, (r.stdout[-3000:], r.stderr[-2000:])
    assert "MATRIX OK" in r.stdout


if __name__ == "__main__":
    if "--matrix" in sys.argv:
        _matrix_main()
