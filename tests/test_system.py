"""End-to-end behaviour tests: training learns, resume is exact, serving
decodes, the dry-run lowers+compiles a production cell, and the paper's
executor claims hold on the CFD case study."""
import json
import subprocess
import sys
import tempfile
from pathlib import Path

import numpy as np
import pytest


def test_train_loss_decreases():
    from repro.launch.train import main
    losses = main(["--arch", "tinyllama-1.1b", "--reduced", "--steps", "30",
                   "--batch", "8", "--seq", "32", "--lr", "2e-3"])
    first = np.mean(losses[:5])
    last = np.mean(losses[-5:])
    assert last < first - 0.05, (first, last)


def test_train_resume_exact():
    from repro.launch.train import main
    with tempfile.TemporaryDirectory() as d:
        l1 = main(["--arch", "tinyllama-1.1b", "--reduced", "--steps", "10",
                   "--batch", "4", "--seq", "16", "--ckpt-dir", d,
                   "--ckpt-every", "5"])
        l2 = main(["--arch", "tinyllama-1.1b", "--reduced", "--steps", "5",
                   "--batch", "4", "--seq", "16", "--ckpt-dir", d,
                   "--resume", "--ckpt-every", "5"])
        # ran and produced finite losses from the restored state
        assert np.isfinite(l2).all()


def test_serve_decodes():
    from repro.launch.serve import main
    seq = main(["--arch", "gemma3-1b", "--reduced", "--batch", "2",
                "--prompt-len", "12", "--gen", "6"])
    assert seq.shape == (2, 6)


def test_serve_offload_kv_matches_device_kv():
    from repro.launch.serve import main
    a = main(["--arch", "tinyllama-1.1b", "--reduced", "--batch", "2",
              "--prompt-len", "8", "--gen", "5", "--seed", "3"])
    b = main(["--arch", "tinyllama-1.1b", "--reduced", "--batch", "2",
              "--prompt-len", "8", "--gen", "5", "--seed", "3",
              "--offload-kv"])
    np.testing.assert_array_equal(a, b)   # placement must not change math


def test_dryrun_cell_compiles():
    with tempfile.TemporaryDirectory() as d:
        out = Path(d) / "cell.json"
        r = subprocess.run(
            [sys.executable, "-m", "repro.launch.dryrun", "--arch",
             "tinyllama-1.1b", "--shape", "train_4k", "--out", str(out)],
            capture_output=True, text=True, timeout=560)
        rec = json.loads(out.read_text())
        assert rec["status"] == "ok", rec.get("error", r.stderr[-500:])
        assert rec["chips"] == 256
        assert rec["roofline"]["hlo_flops_per_dev"] > 0
        assert rec["collectives"]


def test_unified_beats_discrete_on_cfd():
    """The paper's Fig 5/6 claim structure on the region program."""
    import jax.numpy as jnp

    from repro.cfd.grid import Grid
    from repro.cfd.simple import SimpleConfig, SimpleFoam, init_state
    from repro.core.regions import (DiscretePolicy, Executor, UnifiedPolicy)

    cfg = SimpleConfig(grid=Grid((16, 16, 16)), nu=0.1, inner_max=15)
    fom = {}
    for name, make_pol in (("unified", UnifiedPolicy),
                           ("discrete", DiscretePolicy)):
        app = SimpleFoam(cfg, executor=Executor(make_pol()))
        st = init_state(cfg)
        st, _, _ = app.run_steps(st, 1)          # warm compile caches
        app.ledger.reset_timings()
        st, f, _ = app.run_steps(st, 2)
        fom[name] = f
        rep = app.ex.report()
        if name == "discrete":
            assert rep["staging_fraction"] > 0.1
        else:
            assert rep["staging_fraction"] == 0.0
    assert fom["unified"] < fom["discrete"]
