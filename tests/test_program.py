"""Captured region programs (repro.core.program): capture fidelity, replay
parity across policies (sync Executor == AsyncExecutor), overlap/staging
accounting, batched replay, and pooled buffer rotation."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.ledger import Ledger
from repro.core.pool import BufferRotation, DeviceBufferPool
from repro.core.program import (AsyncExecutor, In, Lit, Ref, RegionProgram,
                                capture)
from repro.core.regions import (AdaptivePolicy, DiscretePolicy, Executor,
                                HostPolicy, UnifiedPolicy, region)

N = 1 << 15           # big enough to exceed pool/placement thresholds


def make_program(ledger=None):
    """A small solver-shaped program: dataflow edges, a host-extracted
    scalar (frozen as a constant), and multi-output regions."""
    kw = dict(ledger=ledger or Ledger("prog_test"))

    @region("scale", **kw)
    def scale(d, x):
        return d * x

    @region("saxpy", **kw)
    def saxpy(a, x, y):
        return y - a * x

    @region("split", **kw)
    def split(x):
        return x * 0.5, x * 2.0

    @region("dot", **kw)
    def dot(x, y):
        return jnp.sum(x * y)

    def step(run, d, x, b):
        r = run(saxpy, 1.0, run(scale, d, x), b)
        lo, hi = run(split, r)
        s = float(run(dot, lo, hi))            # frozen control-flow scalar
        return run(saxpy, s / (abs(s) + 1.0), lo, hi)

    d = jnp.linspace(1.0, 2.0, N)
    x = jnp.full((N,), 0.3, jnp.float32)
    b = jnp.linspace(0.0, 1.0, N)
    return capture(step, d, x, b, name="mini"), (d, x, b), step


def test_capture_records_dataflow_and_constants():
    prog, (d, x, b), _ = make_program()
    assert len(prog) == 5
    assert prog.n_inputs == 3
    kinds = [type(l) for op in prog.ops for l in op.leaves]
    assert Ref in kinds and In in kinds and Lit in kinds
    # output of the program is the last op's output, not a constant
    assert isinstance(prog.out_leaves[0], Ref)
    assert "5 ops" in prog.summary()


@pytest.mark.parametrize("make_policy", [
    UnifiedPolicy, HostPolicy, DiscretePolicy,
    lambda: AdaptivePolicy(cutoff=1024)])
def test_async_matches_sync_under_every_policy(make_policy):
    prog, (d, x, b), _ = make_program()
    sync = Executor(make_policy())
    asyn = AsyncExecutor(make_policy())
    out_s = prog.replay(sync, d, x, b)
    out_a = prog.replay(asyn, d, x, b)
    np.testing.assert_array_equal(np.asarray(out_s), np.asarray(out_a))


def test_replay_with_fresh_inputs_recomputes_dataflow():
    prog, (d, x, b), step = make_program()
    ex = Executor(UnifiedPolicy())
    x2 = jnp.full((N,), 0.9, jnp.float32)
    out = prog.replay(ex, d, x2, b)
    # the array dataflow reacts to the new input (the frozen dot-scalar is
    # capture's documented constant; all Ref-edges recompute)
    base = prog.replay(ex, d, x, b)
    assert not np.allclose(np.asarray(out), np.asarray(base))


def test_replay_rejects_mismatched_structure():
    prog, (d, x, b), _ = make_program()
    with pytest.raises(ValueError, match="structure"):
        prog.replay(Executor(UnifiedPolicy()), d, x)


def test_async_discrete_overlaps_and_accounts():
    prog, (d, x, b), _ = make_program()
    asyn = AsyncExecutor(DiscretePolicy())
    sync = Executor(DiscretePolicy())
    out_a = prog.replay(asyn, d, x, b)
    out_s = prog.replay(sync, d, x, b)
    np.testing.assert_array_equal(np.asarray(out_s), np.asarray(out_a))
    rep_a, rep_s = asyn.report(), sync.report()
    # same staged bytes whether or not staging was overlapped
    assert rep_a["staging_s"] > 0
    rows_a = {r["name"]: r for r in asyn.ledger.table()}
    rows_s = {r["name"]: r for r in sync.ledger.table()}
    for name, r in rows_a.items():
        assert r["staging_bytes"] == rows_s[name]["staging_bytes"], name
        # overlap can never exceed the staging it hides
        assert 0.0 <= r["overlap_s"] <= r["staging_s"] + 1e-9, name
    assert rep_a["overlap_s"] <= rep_a["staging_s"]
    assert rep_a["overlap_fraction"] == pytest.approx(
        rep_a["overlap_s"] / rep_a["staging_s"])
    assert rep_a["staging_saved_s"] == rep_a["overlap_s"]
    # sync replay reports no overlap at all
    assert rep_s["overlap_s"] == 0.0 and rep_s["overlap_fraction"] == 0.0


def test_null_stager_policies_report_zero_overlap():
    prog, (d, x, b), _ = make_program()
    asyn = AsyncExecutor(UnifiedPolicy())
    prog.replay(asyn, d, x, b)
    rep = asyn.report()
    assert rep["staging_s"] == 0.0 and rep["overlap_fraction"] == 0.0


def test_replay_batch_matches_sequential_replays():
    prog, (d, x, b), _ = make_program()
    ex = Executor(UnifiedPolicy())
    B = 3
    ds = jnp.stack([d] * B)
    xs = jnp.stack([x + 0.01 * i for i in range(B)])
    bs = jnp.stack([b] * B)
    batched = prog.replay_batch(ds, xs, bs, executor=ex)
    seq = jnp.stack([prog.replay(ex, ds[i], xs[i], bs[i]) for i in range(B)])
    np.testing.assert_allclose(np.asarray(batched), np.asarray(seq),
                               rtol=1e-6, atol=1e-6)
    # accounted as one ledger row
    assert any(name.startswith("mini[batch]") for name in ex.ledger.regions)


def test_async_executor_run_falls_back_to_sync():
    ldg = Ledger("fallback")

    @region("twice", ledger=ldg)
    def twice(x):
        return x * 2.0

    asyn = AsyncExecutor(UnifiedPolicy(), ldg)
    out = asyn.run(twice, jnp.ones((8,)))
    np.testing.assert_array_equal(np.asarray(out), 2.0 * np.ones((8,)))
    assert "+async" in asyn.report()["mode"]


def test_cavity_step_capture_parity():
    """The acceptance-criterion scenario at test scale: one captured SIMPLE
    step, sync vs async DiscretePolicy replay, identical fields, positive
    overlap fraction in coverage_report()."""
    from repro.cfd.grid import Grid
    from repro.cfd.simple import SimpleConfig, SimpleFoam, init_state
    cfg = SimpleConfig(grid=Grid((8, 8, 8)), nu=0.1, inner_max=6)
    app = SimpleFoam(cfg)
    st = init_state(cfg)
    st, _, _ = app.run_steps(st, 1)
    prog = app.capture_step(st)
    assert len(prog) > 20
    sync = Executor(DiscretePolicy())
    asyn = AsyncExecutor(DiscretePolicy())
    s_sync, _ = app.replay_steps(prog, st, 2, sync)
    s_asyn, _ = app.replay_steps(prog, st, 2, asyn)
    for a, b in zip((s_sync.u, s_sync.v, s_sync.w, s_sync.p),
                    (s_asyn.u, s_asyn.v, s_asyn.w, s_asyn.p)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    rep = asyn.report()
    assert rep["staging_s"] > 0
    assert rep["overlap_fraction"] > 0, rep
    assert rep["staging_saved_s"] > 0


# ---------------------------------------------------------------------------
# PR-2 flake regression: buffer-aliasing/recycling race
# ---------------------------------------------------------------------------

def test_replay_results_survive_forced_recycling_20_of_20():
    """PR-2 flake regression (deterministic, no load dependence): the race
    was a staged-out result page returning to the host pool while its
    host-wrap copy could still be in flight — a later replay's ``copyto``
    into the recycled page then corrupted the FIRST replay's outputs.

    This harness forces the reuse instead of relying on CPU load: sync and
    async executors share ONE DiscretePolicy, so every replay recycles the
    same host pages, device buffers, and rotation banks as the previous
    one (pool hit counters prove it).  Both PR-2 parity assertions must
    hold 20/20, and earlier outputs must stay bit-identical to the
    snapshots taken before the pools were churned again."""
    prog, (d, x, b), _ = make_program()
    pol = DiscretePolicy()
    sync = Executor(pol)
    asyn = AsyncExecutor(pol)
    for i in range(20):
        out_a = prog.replay(asyn, d, x, b)
        snap_a = np.array(out_a)              # snapshot BEFORE pool churn
        out_s = prog.replay(sync, d, x, b)
        snap_s = np.array(out_s)
        # the two PR-2 parity assertions
        np.testing.assert_array_equal(snap_s, snap_a,
                                      err_msg=f"round {i}: sync != async")
        # replay N's outputs survive replay N+1's recycling of the pools
        out_a2 = prog.replay(asyn, d, x, b)
        np.testing.assert_array_equal(
            np.asarray(out_a), snap_a,
            err_msg=f"round {i}: first replay's outputs corrupted")
        np.testing.assert_array_equal(
            np.asarray(out_s), snap_s,
            err_msg=f"round {i}: sync replay's outputs corrupted")
        np.testing.assert_array_equal(np.asarray(out_a2), snap_a)
    stager = pol.stager
    # the harness really did recycle: pooled pages and device buffers hit
    assert stager.host_pool.stats.hits > 0
    assert stager.device_pool.stats.hits > 0


def test_migrate_out_pages_not_recycled_while_copy_in_flight():
    """Unit form of the same race: consecutive same-size stage-outs must
    never overwrite an earlier result, whether the host wrap aliased the
    pooled page (finalize-owned) or copied it (released only after the
    copy completed)."""
    from repro.core.regions import MigrationStager
    stager = MigrationStager()
    outs = [stager._migrate_out(jnp.full((N,), float(i))) for i in range(8)]
    for i, o in enumerate(outs):
        np.testing.assert_array_equal(np.asarray(o), float(i))


# ---------------------------------------------------------------------------
# BufferRotation
# ---------------------------------------------------------------------------

def test_rotation_banks_are_disjoint_and_retire_releases():
    pool = DeviceBufferPool(min_elems=1)
    rot = BufferRotation(pool, depth=2)
    a = rot.acquire((N,), jnp.float32)
    rot.advance()
    b = rot.acquire((N,), jnp.float32)
    # double-buffering: the second bank must not recycle the first bank's
    # live buffer
    assert a.unsafe_buffer_pointer() != b.unsafe_buffer_pointer()
    assert rot.in_flight == 2
    rot.retire()                       # oldest bank (a) returns to the pool
    assert rot.in_flight == 1
    c = pool.acquire((N,), jnp.float32)
    assert c.unsafe_buffer_pointer() == a.unsafe_buffer_pointer()


def test_rotation_advance_auto_retires_when_full():
    pool = DeviceBufferPool(min_elems=1)
    rot = BufferRotation(pool, depth=2)
    rot.acquire((64,), jnp.float32)
    rot.advance()
    rot.acquire((64,), jnp.float32)
    assert rot.in_flight == 2
    rot.advance()          # rotation full: oldest bank retires automatically
    assert rot.in_flight == 1


def test_rotation_drain_releases_everything():
    pool = DeviceBufferPool(min_elems=1)
    rot = BufferRotation(pool, depth=3)
    rot.acquire((64,), jnp.float32)
    rot.advance()
    rot.acquire((64,), jnp.float32)
    rot.acquire((64,), jnp.float32)       # same active bank
    assert rot.in_flight == 3
    rot.drain()
    assert rot.in_flight == 0
    # all three buffers are reusable again
    hits_before = pool.stats.hits
    for _ in range(3):
        pool.acquire((64,), jnp.float32)
    assert pool.stats.hits == hits_before + 3


def test_rotation_depth_validation():
    with pytest.raises(ValueError):
        BufferRotation(DeviceBufferPool(), depth=1)


def test_rotation_generation_tag_rejects_stale_registrations():
    """A background staging task that outlives its replay (drain bumps the
    generation) must hand its buffer back to the pool, not park it in the
    next replay's banks."""
    pool = DeviceBufferPool(min_elems=1)
    rot = BufferRotation(pool, depth=2)
    handle = rot.handle()                  # minted in generation 0
    live = rot.acquire((64,), jnp.float32)
    rot.drain()                            # replay ends: generation 1
    stale = pool.acquire((64,), jnp.float32)
    handle.register(stale)                 # stale task lands late
    assert rot.in_flight == 0              # NOT parked in a bank
    again = pool.acquire((64,), jnp.float32)
    assert again.unsafe_buffer_pointer() == stale.unsafe_buffer_pointer()
    # a fresh handle follows the current generation and parks normally
    rot.handle().register(again)
    assert rot.in_flight == 1
