"""Per-architecture smoke tests (assignment requirement): a REDUCED config
of the same family runs one forward/train step on CPU with correct output
shapes and no NaNs; decode-vs-prefill consistency is checked for
representative families."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import SHAPE_BY_NAME, shape_applicable
from repro.configs.reduced import reduced
from repro.configs.registry import ARCH_IDS, get_config
from repro.models import transformer as T
from repro.optim import adamw
from repro.train import step as S

BATCH, SEQ = 2, 24


def _fp32(cfg):
    return dataclasses.replace(cfg, param_dtype="float32",
                               compute_dtype="float32")


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_train_step_smoke(arch):
    cfg = reduced(get_config(arch))
    key = jax.random.PRNGKey(0)
    params = T.init(key, cfg)
    batch = S.demo_batch(key, cfg, BATCH, SEQ)
    ts = S.make_train_step(cfg, adamw.AdamWConfig(lr=1e-3))
    opt = adamw.init_state(params, adamw.AdamWConfig())
    p2, o2, m = jax.jit(ts)(params, opt, batch)
    for k, v in m.items():
        assert np.isfinite(float(v)), (arch, k, v)
    # optimizer actually moved the params (some leaf must change; bf16
    # leaves can be below update resolution when the grad clip is active)
    changed = any(
        not np.array_equal(np.asarray(a, np.float32), np.asarray(b, np.float32))
        for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(p2)))
    assert changed


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_forward_shapes_and_decode(arch):
    cfg = reduced(get_config(arch))
    key = jax.random.PRNGKey(1)
    params = T.init(key, cfg)
    batch = S.demo_batch(key, cfg, BATCH, SEQ)
    logits, aux = T.forward_train(params, batch, cfg, T.Ctx(mode="train"))
    assert logits.shape == (BATCH, SEQ, cfg.vocab)
    assert np.isfinite(np.asarray(logits, np.float32)).all()

    caches = T.init_cache(cfg, BATCH, SEQ + 4)
    lg, caches = jax.jit(S.make_prefill_step(cfg))(params, batch, caches)
    assert lg.shape == (BATCH, 1, cfg.vocab)
    tok = jnp.zeros((BATCH,), jnp.int32)
    lg2, caches = jax.jit(S.make_decode_step(cfg))(params, tok, caches,
                                                   jnp.int32(SEQ))
    assert lg2.shape == (BATCH, 1, cfg.vocab)
    assert np.isfinite(np.asarray(lg2, np.float32)).all()


@pytest.mark.parametrize("arch", ["tinyllama-1.1b", "gemma3-1b", "rwkv6-7b",
                                  "recurrentgemma-9b", "qwen3-moe-30b-a3b"])
def test_decode_matches_forward(arch):
    """prefill(x[:P]) + decode(x[P]) must equal forward(x[:P+1])[-1]."""
    cfg = _fp32(reduced(get_config(arch)))
    P = 12
    key = jax.random.PRNGKey(2)
    params = T.init(key, cfg)
    full = S.demo_batch(key, cfg, BATCH, P + 1)
    logits_full, _ = T.forward_train(params, full, cfg, T.Ctx(mode="train"))

    pre = {k: (v[:, :P] if v.ndim >= 2 and v.shape[1] == P + 1 else v)
           for k, v in full.items()}
    caches = T.init_cache(cfg, BATCH, P + 1)
    _, caches = T.prefill(params, pre, cfg, T.Ctx(mode="prefill"), caches)
    lg, _ = T.decode_step(params, full["tokens"][:, P], caches,
                          jnp.int32(P), cfg, T.Ctx(mode="decode"))
    a = np.asarray(logits_full[:, P], np.float32)
    b = np.asarray(lg[:, 0], np.float32)
    np.testing.assert_allclose(a, b, rtol=2e-3, atol=2e-3)


def test_shape_applicability_rules():
    skips = []
    for arch in ARCH_IDS:
        cfg = get_config(arch)
        ok, why = shape_applicable(cfg, SHAPE_BY_NAME["long_500k"])
        if not ok:
            skips.append(arch)
    assert "rwkv6_7b" not in skips
    assert "recurrentgemma_9b" not in skips
    assert "gemma3_1b" not in skips
    assert len(skips) == 7, skips


def test_param_counts_match_scale():
    """Analytic n_params sanity: within 2x of the advertised scale."""
    expect = {"tinyllama_1_1b": 1.1e9, "llama3_2_3b": 3.2e9,
              "qwen2_5_32b": 32e9, "rwkv6_7b": 7e9,
              "qwen3_moe_30b_a3b": 30e9}
    for arch, n in expect.items():
        got = get_config(arch).n_params
        assert 0.5 * n < got < 2.0 * n, (arch, got, n)
