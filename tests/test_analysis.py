"""The static program verifier (repro.analysis, docs/ANALYSIS.md):
seven planted-defect programs each firing exactly one rule, the in-repo
program corpus linting error-clean under all three policies, the
capture/driver/sharded-gate wiring, `donate_args` declaration
validation, `interval_overlap` edges, the ledger's analysis counters
across merge/reset, and the HLO cost bridge."""
import os

import jax.numpy as jnp
import pytest

from repro.analysis import (ERROR, WARNING, AnalysisReport, Diagnostic,
                            ProgramVerificationError, RULES, check_halo,
                            verify_program)
from repro.core.ledger import Ledger
from repro.core.oversub import MemoryBudget
from repro.core.program import capture, interval_overlap
from repro.core.regions import (AdaptivePolicy, DiscretePolicy,
                                UnifiedPolicy, region)
from repro.core.umem import MemSpace

def X2():
    """Fresh per call: donating captures delete their example inputs."""
    return jnp.ones((4, 4))


def X3():
    return jnp.ones((4, 4, 4))


def only_rule(report, rule):
    """Assert the report fired exactly one rule, and return its findings."""
    assert {d.rule for d in report.findings} == {rule}, report.findings
    return report.findings


# ---------------------------------------------------------------------------
# Planted defects: one program per rule, each firing exactly that rule
# ---------------------------------------------------------------------------

class TestPlantedDefects:

    def test_donate_after_use(self):
        led = Ledger("t_donate")

        @region("A", ledger=led)
        def a(x):
            return x + 1.0

        @region("B", ledger=led)
        def b(x, y):
            return x * y

        def fn(run, x):
            h = run(a, x)
            return run(b, h, x)     # x is read again AFTER a consumed it

        prog = capture(fn, X2(), name="donate_test")
        # plant post-capture: a donating capture of this program would
        # already crash replaying eagerly — the verifier must catch the
        # hazard from declarations alone
        a.donate_args = (0,)
        rep = verify_program(prog, UnifiedPolicy())
        finds = only_rule(rep, "donate-after-use")
        assert rep.errors and not rep.ok
        assert finds[0].op == 0 and finds[0].region == "A"
        with pytest.raises(ProgramVerificationError):
            rep.raise_if_errors()

    def test_donate_pooled_fires_only_under_staging_policy(self):
        led = Ledger("t_pool")

        @region("C", ledger=led, donate_args=(0,))
        def cc(x):
            return x * 2.0

        prog = capture(lambda run, x: run(cc, x), X2(), name="pool_test")
        rep = verify_program(prog, DiscretePolicy())
        finds = only_rule(rep, "donate-pooled")
        assert finds[0].severity == WARNING and rep.ok
        # unified never stages: the same declaration is clean there
        assert not verify_program(prog, UnifiedPolicy()).findings

    def test_dead_result(self):
        led = Ledger("t_dead")

        @region("D", ledger=led)
        def dd(x):
            return x + 1.0

        @region("E", ledger=led)
        def ee(x):
            return x * 3.0

        def fn(run, x):
            _ = run(dd, x)          # result dropped on the floor
            return run(ee, x)

        rep = verify_program(capture(fn, X2(), name="dead_test"),
                             UnifiedPolicy())
        finds = only_rule(rep, "dead-result")
        assert finds[0].severity == WARNING and finds[0].region == "D"

    def test_placement_churn(self):
        led = Ledger("t_churn")

        @region("P", ledger=led, result_space=MemSpace.HOST)
        def pp(x):
            return x + 1.0

        @region("Q", ledger=led, placement={0: MemSpace.DEVICE})
        def qq(x):
            return x * 2.0

        def fn(run, x):
            return run(qq, run(pp, x))   # host-pinned edge into device hint

        rep = verify_program(capture(fn, X3(), name="churn_test"),
                             UnifiedPolicy())
        finds = only_rule(rep, "placement-churn")
        assert finds[0].severity == WARNING and finds[0].arg == 0

    def test_halo_unresolvable_entry(self):
        led = Ledger("t_halo")

        @region("H", ledger=led, stencil=((2, 1), (2, -1)),
                halo_args=("bogus",))
        def hh(x):
            return x * 1.0

        prog = capture(lambda run, x: run(hh, x), X3(), name="halo_test")
        rep = verify_program(prog, UnifiedPolicy())
        finds = only_rule(rep, "halo-under-declaration")
        assert rep.errors and finds[0].arg == "bogus"
        # the single-rule gate ShardExecutor consults sees the same error
        assert check_halo(prog).errors

    def test_variant_contract(self):
        led = Ledger("t_var")

        @region("V", ledger=led)
        def vv(x, y):
            return x + y

        vv.variant("pallas", lambda x: x)   # wrong arity: cannot bind
        rep = verify_program(
            capture(lambda run, a, b: run(vv, a, b), X3(), X3(),
                    name="variant_test"),
            UnifiedPolicy())
        finds = only_rule(rep, "variant-contract")
        assert rep.errors and "pallas" in finds[0].message

    def test_budget_infeasibility(self):
        led = Ledger("t_budget")

        @region("W", ledger=led)
        def ww(x):
            return x * 2.0

        prog = capture(lambda run, x: run(ww, x), X3(), name="budget_test")
        # 4x4x4 f32 in + out = 512 B against a 64 B budget: the single
        # call can never fit (error) and the watermark is over (warning)
        rep = verify_program(prog, UnifiedPolicy(), budget=MemoryBudget(64))
        only_rule(rep, "budget-infeasibility")
        assert rep.errors and rep.warnings
        # no budget anywhere on the policy -> the rule stays silent
        assert not verify_program(prog, UnifiedPolicy()).findings

    def test_every_rule_has_a_planted_trigger(self):
        """The seven cases above cover the whole registered rule set."""
        planted = {"donate-after-use", "donate-pooled", "dead-result",
                   "placement-churn", "halo-under-declaration",
                   "variant-contract", "budget-infeasibility"}
        assert planted == set(RULES)


# ---------------------------------------------------------------------------
# Wiring: capture(verify=), sharded halo gate, report plumbing
# ---------------------------------------------------------------------------

class TestWiring:

    def test_capture_verify_raises_on_planted_error(self):
        led = Ledger("t_cap")

        @region("HB", ledger=led, stencil=((2, 1), (2, -1)),
                halo_args=("nope",))
        def hb(x):
            return x + 1.0

        with pytest.raises(ProgramVerificationError) as ei:
            capture(lambda run, x: run(hb, x), X3(), name="cap_bad",
                    verify=UnifiedPolicy())
        assert ei.value.report.errors

    def test_capture_verify_passes_clean_program(self):
        led = Ledger("t_cap_ok")

        @region("OK", ledger=led)
        def ok(x):
            return x + 1.0

        prog = capture(lambda run, x: run(ok, x), X2(), name="cap_ok",
                       verify=True)
        assert verify_program(prog, UnifiedPolicy()).ok

    def test_shard_executor_vetoes_bad_halo_program(self):
        from repro.core.shard_program import ShardExecutor
        from repro.launch.mesh import make_smoke_mesh

        led = Ledger("t_gate")

        @region("HG", ledger=led, stencil=((2, 1), (2, -1)),
                halo_args=("missing",))
        def hg(x):
            return x * 1.0

        prog = capture(lambda run, x: run(hg, x), X3(), name="gate_bad")
        sx = ShardExecutor(UnifiedPolicy(), make_smoke_mesh())
        with pytest.raises(ValueError, match="halo verification"):
            sx.replay_program(prog, X3())

    def test_shard_executor_gate_caches_good_programs(self):
        from repro.core.shard_program import ShardExecutor
        from repro.launch.mesh import make_smoke_mesh

        led = Ledger("t_gate_ok")

        @region("G", ledger=led, stencil=((0, 1), (0, -1)),
                halo_args=("x",))
        def gg(x):
            return x * 1.0

        prog = capture(lambda run, x: run(gg, x), X3(), name="gate_ok")
        sx = ShardExecutor(UnifiedPolicy(), make_smoke_mesh())
        sx._verify_halo(prog)
        assert prog in sx._halo_verified      # second replay skips the pass
        sx._verify_halo(prog)

    def test_report_ordering_and_serialization(self):
        finds = [Diagnostic("dead-result", WARNING, "p", "w", op=3),
                 Diagnostic("donate-after-use", ERROR, "p", "e", op=7)]
        rep = AnalysisReport(program="p", policy="unified",
                             findings=finds, n_ops=9)
        assert [d.severity for d in rep.findings] == [ERROR, WARNING]
        d = rep.as_dict()
        assert (d["n_errors"], d["n_warnings"]) == (1, 1)
        assert d["findings"][0]["rule"] == "donate-after-use"
        assert "7" in str(rep.findings[0])
        assert set(rep.by_rule()) == {"donate-after-use", "dead-result"}


# ---------------------------------------------------------------------------
# The in-repo corpus lints error-clean under all three policies
# ---------------------------------------------------------------------------

POLICIES = {"unified": UnifiedPolicy, "discrete": DiscretePolicy,
            "adaptive": AdaptivePolicy}


@pytest.mark.parametrize("name", ["simple_step", "serve_prefill",
                                  "serve_decode", "engine_tick",
                                  "train_step"])
@pytest.mark.parametrize("policy", sorted(POLICIES))
def test_corpus_error_clean(name, policy):
    """Every captured program the repo ships must verify with zero
    error-severity findings under every built-in policy — the same
    invariant the CI `python -m repro.analysis --all` gate enforces."""
    from repro.analysis import programs as corpus
    ((_, prog),) = corpus.build_programs([name])
    rep = prog.verify(POLICIES[policy]())
    assert rep.ok, f"{rep.summary()}:\n" + "\n".join(
        f"  {d}" for d in rep.errors)


# ---------------------------------------------------------------------------
# Satellite: donate_args declaration validation
# ---------------------------------------------------------------------------

class TestDonateArgsValidation:

    def test_negative_and_non_int_rejected(self):
        with pytest.raises(ValueError, match="non-negative"):
            region("bad_neg", ledger=Ledger("v1"),
                   donate_args=(-1,))(lambda x: x)
        with pytest.raises(ValueError, match="non-negative"):
            region("bad_str", ledger=Ledger("v2"),
                   donate_args=("x",))(lambda x: x)

    def test_out_of_range_rejected(self):
        with pytest.raises(ValueError, match="out of range"):
            region("bad_range", ledger=Ledger("v3"),
                   donate_args=(2,))(lambda x, *, k=None: x)

    def test_var_positional_skips_range_check(self):
        r = region("varargs", ledger=Ledger("v4"),
                   donate_args=(5,))(lambda *xs: xs[0])
        assert r.donate_args == (5,)

    def test_halo_overlap_rejected_by_name_and_index(self):
        with pytest.raises(ValueError, match="overlap halo_args"):
            region("clash_name", ledger=Ledger("v5"), donate_args=(1,),
                   stencil=((0, 1), (0, -1)),
                   halo_args=("x",))(lambda c, x: c * x)
        with pytest.raises(ValueError, match="overlap halo_args"):
            region("clash_idx", ledger=Ledger("v6"), donate_args=(0,),
                   stencil=((0, 1), (0, -1)),
                   halo_args=(0,))(lambda x: x)

    def test_valid_declaration_passes(self):
        r = region("fine", ledger=Ledger("v7"), donate_args=(0,),
                   stencil=((0, 1), (0, -1)),
                   halo_args=("x",))(lambda c, x: c * x)
        assert r.donate_args == (0,)


# ---------------------------------------------------------------------------
# Satellite: interval_overlap edges
# ---------------------------------------------------------------------------

class TestIntervalOverlap:

    def test_empty_spans(self):
        assert interval_overlap(0.0, 1.0, []) == 0.0

    def test_zero_length_interval(self):
        assert interval_overlap(0.5, 0.5, [(0.0, 1.0)]) == 0.0

    def test_zero_length_span(self):
        assert interval_overlap(0.0, 1.0, [(0.5, 0.5)]) == 0.0

    def test_fully_contained_span(self):
        assert interval_overlap(0.0, 1.0, [(0.25, 0.75)]) == 0.5

    def test_interval_inside_span(self):
        assert interval_overlap(0.25, 0.75, [(0.0, 1.0)]) == 0.5

    def test_adjacent_spans_no_double_count(self):
        assert interval_overlap(0.0, 1.0, [(0.0, 0.5), (0.5, 1.0)]) == 1.0

    def test_disjoint_span_clamps_to_zero(self):
        assert interval_overlap(0.0, 1.0, [(2.0, 3.0)]) == 0.0
        assert interval_overlap(2.0, 3.0, [(0.0, 1.0)]) == 0.0

    def test_partial_overlap_both_ends(self):
        assert interval_overlap(0.4, 1.6, [(0.0, 0.5), (1.5, 2.0)]) == \
            pytest.approx(0.2)


# ---------------------------------------------------------------------------
# Satellite: ledger analysis counters across record/merge/reset/clear
# ---------------------------------------------------------------------------

class TestLedgerAnalysisCounters:

    def make_report_into(self, ledger):
        led = Ledger("t_lc")

        @region("LC", ledger=led)
        def lc(x):
            return x + 1.0

        def fn(run, x):
            _ = run(lc, x)          # planted dead-result warning
            return run(lc, x)

        prog = capture(fn, X2(), name="lc_test")
        return verify_program(prog, UnifiedPolicy(), ledger=ledger)

    def test_verify_records_counters(self):
        ldg = Ledger("rec")
        rep = self.make_report_into(ldg)
        assert rep.warnings and not rep.errors
        assert ldg.analysis_counters["programs_verified"] == 1
        assert ldg.analysis_counters["findings_warning"] == 1
        assert ldg.analysis_counters["findings_error"] == 0
        assert ldg.analysis_counters["dead-result"] == 1

    def test_merge_sums_and_merged_aggregates(self):
        a, b = Ledger("a"), Ledger("b")
        self.make_report_into(a)
        self.make_report_into(b)
        self.make_report_into(b)
        a.merge_from(b)
        assert a.analysis_counters["programs_verified"] == 3
        assert a.analysis_counters["dead-result"] == 3
        c, d = Ledger("c"), Ledger("d")
        self.make_report_into(c)
        self.make_report_into(d)
        agg = Ledger.merged([c, d])
        assert agg.analysis_counters["programs_verified"] == 2

    def test_reset_timings_preserves_clear_clears(self):
        ldg = Ledger("rst")
        self.make_report_into(ldg)
        ldg.reset_timings()
        # settings-like: verification is per capture, not per replay epoch
        assert ldg.analysis_counters["programs_verified"] == 1
        ldg.clear()
        assert ldg.analysis_counters == {}

    def test_coverage_report_section(self):
        ldg = Ledger("cov")
        assert "analysis" not in ldg.coverage_report()
        self.make_report_into(ldg)
        sec = ldg.coverage_report()["analysis"]
        assert sec["programs_verified"] == 1 and sec["dead-result"] == 1


# ---------------------------------------------------------------------------
# Satellite: the dryrun/hloparse cost bridge
# ---------------------------------------------------------------------------

class TestCostBridge:

    def test_estimates_and_xla_flags_hygiene(self):
        from repro.analysis.costs import (estimate_op_costs,
                                          estimate_program_costs)
        led = Ledger("t_cost")

        @region("MM", ledger=led)
        def mm(x, y):
            return x @ y

        x = jnp.ones((16, 16))
        prog = capture(lambda run, a, b: run(mm, a, b), x, x,
                       name="cost_test")
        before = os.environ.get("XLA_FLAGS")
        c = estimate_op_costs(prog, 0)
        assert os.environ.get("XLA_FLAGS") == before  # dryrun import leak
        assert c["flops"] > 0 and c["hbm_bytes"] > 0
        assert c["bound"] in ("compute", "memory")
        assert c["roofline_compute_s"] > 0 and c["roofline_memory_s"] > 0
        total = estimate_program_costs(prog)
        assert total["flops"] >= c["flops"]
        assert total["skipped"] == []
