"""CFD validation: operators vs dense algebra, two-color DILU vs sequential
DILU (iteration parity), SIMPLE convergence, executor equivalence."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.cfd import fvm
from repro.cfd.dia import DiaMatrix, amul_ref, to_dense
from repro.cfd.grid import Grid
from repro.cfd.precond import (dilu_seq_ref, jacobi_apply, rb_dilu_apply,
                               rb_dilu_factor)
from repro.cfd.simple import SimpleConfig, SimpleFoam, init_state
from repro.cfd.solvers import make_solver_regions, pbicgstab_regions, solve
from repro.core.ledger import Ledger
from repro.core.regions import (DiscretePolicy, Executor, HostPolicy,
                                UnifiedPolicy)


def test_amul_matches_dense(rng):
    g = Grid((4, 3, 5))
    A, _ = fvm.laplacian(g, 2.0)
    x = jnp.asarray(rng.rand(*g.shape).astype(np.float32))
    y = amul_ref(A, x)
    yd = (to_dense(A) @ np.asarray(x, np.float64).ravel()).reshape(g.shape)
    np.testing.assert_allclose(np.asarray(y), yd, rtol=1e-4, atol=1e-4)


def test_laplacian_spd(rng):
    g = Grid((4, 4, 4))
    A, _ = fvm.laplacian(g, 1.0)
    M = to_dense(A)
    np.testing.assert_allclose(M, M.T, atol=1e-12)   # symmetric
    w = np.linalg.eigvalsh(M)
    assert w.min() > 0                                # positive definite


def test_transpose_matches_dense(rng):
    g = Grid((3, 4, 2))
    phi = jnp.asarray(rng.randn(6, *g.shape).astype(np.float32))
    A = fvm.div_upwind(g, phi)     # non-symmetric
    At = A.transpose()
    np.testing.assert_allclose(to_dense(At), to_dense(A).T, atol=1e-5)


def test_rb_dilu_iteration_parity(rng):
    """Two-color DILU must precondition comparably to sequential DILU:
    same solve within +-50% iterations, and much better than none."""
    g = Grid((8, 8, 8))
    A, _ = fvm.laplacian(g, 1.0)
    b = jnp.asarray(rng.rand(*g.shape).astype(np.float32))
    red, _ = g.red_black_masks()
    r_dilu = solve(A, b, jnp.zeros_like(b), red, tol=1e-6, max_iter=300)
    r_jac = solve(A, b, jnp.zeros_like(b), red, tol=1e-6, max_iter=300,
                  use_dilu=False)
    assert r_dilu.converged
    assert r_dilu.iters <= r_jac.iters            # DILU no worse than Jacobi
    assert r_dilu.iters <= 0.8 * r_jac.iters + 2  # and materially better


def test_rb_dilu_is_exact_inverse_of_its_M(rng):
    """M^-1 applied via sweeps must invert M = (L+D*)D*^-1(D*+U) exactly."""
    g = Grid((4, 4, 2))
    A, _ = fvm.laplacian(g, 1.0)
    red, _ = g.red_black_masks()
    P = rb_dilu_factor(A, red)
    r = jnp.asarray(rng.rand(*g.shape).astype(np.float32))
    w = rb_dilu_apply(P, A, r)
    # rebuild M densely in the SAME (natural) index space
    N = g.n
    M = to_dense(A).copy()
    redv = np.asarray(red).ravel()
    dstar = np.where(redv, np.asarray(A.diag).ravel(),
                     1.0 / np.asarray(P.rdiag).ravel())
    Lm = np.zeros((N, N)); Um = np.zeros((N, N))
    for i in range(N):
        for j in range(N):
            if i == j or M[i, j] == 0:
                continue
            # ordering: red before black
            before = (redv[j] and not redv[i])
            if before:
                Lm[i, j] = M[i, j]
            elif redv[i] and not redv[j]:
                Um[i, j] = M[i, j]
    Mfull = (Lm + np.diag(dstar)) @ np.diag(1.0 / dstar) @ (np.diag(dstar) + Um)
    w2 = np.linalg.solve(Mfull, np.asarray(r, np.float64).ravel())
    np.testing.assert_allclose(np.asarray(w).ravel(), w2, rtol=2e-3, atol=2e-4)


def test_pbicgstab_regions_matches_fused(rng):
    g = Grid((8, 8, 8))
    A, _ = fvm.laplacian(g, 1.0)
    b = jnp.asarray(rng.rand(*g.shape).astype(np.float32))
    red, _ = g.red_black_masks()
    P = rb_dilu_factor(A, red)
    ldg = Ledger("t")
    regions = make_solver_regions(ldg)
    ex = Executor(UnifiedPolicy(), ldg)
    r1 = pbicgstab_regions(ex, regions, A, b, jnp.zeros_like(b), P, tol=1e-6)
    r2 = solve(A, b, jnp.zeros_like(b), red, tol=1e-6)
    assert r1.converged and r2.converged
    np.testing.assert_allclose(np.asarray(r1.x), np.asarray(r2.x),
                               rtol=5e-3, atol=5e-4)


def test_executors_same_result(rng):
    """unified / discrete / host must be numerically identical paths."""
    g = Grid((6, 6, 6))
    A, _ = fvm.laplacian(g, 1.0)
    b = jnp.asarray(rng.rand(*g.shape).astype(np.float32))
    red, _ = g.red_black_masks()
    P = rb_dilu_factor(A, red)
    outs = []
    for make in (UnifiedPolicy, DiscretePolicy, HostPolicy):
        ldg = Ledger("t")
        regions = make_solver_regions(ldg)
        r = pbicgstab_regions(Executor(make(), ldg), regions, A, b,
                              jnp.zeros_like(b), P, tol=1e-6)
        outs.append(np.asarray(r.x))
    np.testing.assert_allclose(outs[0], outs[1], rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(outs[0], outs[2], rtol=1e-5, atol=1e-6)


def test_discrete_executor_pays_staging(rng):
    g = Grid((12, 12, 12))
    A, _ = fvm.laplacian(g, 1.0)
    b = jnp.asarray(rng.rand(*g.shape).astype(np.float32))
    red, _ = g.red_black_masks()
    P = rb_dilu_factor(A, red)
    ldg = Ledger("t")
    regions = make_solver_regions(ldg)
    ex = Executor(DiscretePolicy(), ldg)
    r = pbicgstab_regions(ex, regions, A, b, jnp.zeros_like(b), P, tol=1e-6)
    rep = ex.report()
    assert rep["staging_fraction"] > 0.05
    assert rep["staging_s"] > 0
    # uniform return contract: staged results are host-space jax Arrays,
    # not numpy (the old DiscreteExecutor changed types per mode)
    assert isinstance(r.x, jax.Array)


def test_simple_foam_converges():
    from repro.cfd import fvc
    cfg = SimpleConfig(grid=Grid((10, 10, 10)), nu=0.1, inner_max=40)
    app = SimpleFoam(cfg)
    st = init_state(cfg)

    def div_inf(s):
        return float(jnp.abs(fvc.div_flux(
            cfg.grid, fvm.face_fluxes(cfg.grid, s.u, s.v, s.w))).max())

    st, _, _ = app.run_steps(st, 3)
    d1 = div_inf(st)
    st, _, _ = app.run_steps(st, 7)
    d2 = div_inf(st)
    assert np.isfinite(np.asarray(st.u)).all()
    assert np.isfinite(np.asarray(st.p)).all()
    # velocities bounded by the lid scale (stability) and flow develops
    assert float(jnp.abs(st.u).max()) < 2.0 * cfg.lid_velocity
    assert float(jnp.abs(st.u).max()) > 0.05
    # SIMPLE drives the field toward divergence-free
    assert d2 < d1
