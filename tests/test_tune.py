"""Global policy autotuner (repro.tune): deterministic search, profile
persistence round-tripping into ``--policy auto``, nearest-bucket
fallback, and the cost-model-vs-measured rank-correlation smoke.

The search itself is pinned with an injected ``measure`` function — the
tuner's determinism contract is "same seed + same profile -> identical
winners", which only holds if nothing inside the search consults the
wall clock."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.ledger import Ledger
from repro.core.program import capture
from repro.core.regions import (AdaptivePolicy, AutotuneSelector,
                                DiscretePolicy, StaticSelector,
                                UnifiedPolicy, region, size_bucket)
from repro.launch.mesh import near_square_mesh_shape
from repro.launch.policy import auto_policy
from repro.tune import tuner as TU
from repro.tune.profile import (PROFILE_VERSION, PolicyProfile, ProfileEntry,
                                entry_key)
from repro.tune.space import (PolicyCandidate, cfd_size, enumerate_candidates,
                              parse_winner_key, serve_size, train_size)
from repro.tune.workloads import RunResult, Workload, get_workload

N = 1 << 14


def _mini_program():
    """A two-region captured program the cost model can price."""
    ldg = Ledger("tune_prog")
    scale = region("TSCALE", ledger=ldg)(lambda d, x: d * x)
    saxpy = region("TSAXPY", ledger=ldg)(lambda a, x, y: y - a * x)

    def step(run, d, x, b):
        return run(saxpy, 1.0, run(scale, d, x), b)

    d = jnp.linspace(1.0, 2.0, N)
    x = jnp.full((N,), 0.3, jnp.float32)
    b = jnp.linspace(0.0, 1.0, N)
    return capture(step, d, x, b, name="tune_mini")


def _fake_workload(fom_by_placement, bad_leaves_for=()):
    """A workload whose 'measurements' are a deterministic lookup table:
    FOM per placement, reference leaves everywhere except the labels in
    ``bad_leaves_for`` (which fail the parity check)."""
    prog = _mini_program()

    def run(candidate, steps, winners=None):
        fom = fom_by_placement.get(candidate.label,
                                   fom_by_placement.get(candidate.placement,
                                                        1.0))
        leaves = [np.arange(8, dtype=np.float32)]
        if candidate.label in bad_leaves_for:
            leaves = [np.arange(8, dtype=np.float32) + 1.0]
        return RunResult(leaves=leaves, fom_s=fom,
                         region_s={"TSCALE": fom / 2, "TSAXPY": fom / 2},
                         replays=steps)

    return Workload(name="fake", kind="replay", size=1536, memory=None,
                    build_program=lambda: prog, run=run,
                    ref=PolicyCandidate(placement="discrete"), steps=2)


def _measure(w, c, s):
    return w.run(c, s)


# ---------------------------------------------------------------------------
# search space
# ---------------------------------------------------------------------------

def test_enumeration_deterministic_and_covers_placements():
    a = enumerate_candidates("replay")
    b = enumerate_candidates("replay")
    assert a == b                               # fixed order, fixed set
    assert {c.placement for c in a} == {"unified", "adaptive", "discrete",
                                        "host"}
    assert any(c.staging == "async" for c in a)          # discrete only
    assert all(c.placement == "discrete" for c in a if c.staging == "async")
    sh = enumerate_candidates("sharded", apus=4)
    assert {c.mesh for c in sh} == {(4,), (2, 2)}
    assert {c.schedule for c in sh} == {"sequential", "overlap", "split"}


def test_candidate_roundtrip_and_selector():
    c = PolicyCandidate(placement="adaptive", cutoff=4096,
                        selector="autotuned", mesh=(2, 2))
    assert PolicyCandidate.from_dict(c.to_dict()) == c
    sel = c.make_selector({"TSCALE|device|2^11": "pallas"})
    assert isinstance(sel, AutotuneSelector)
    assert sel.winners[("TSCALE", "device", 11)] == "pallas"
    assert isinstance(PolicyCandidate().make_selector(), StaticSelector)
    with pytest.raises(ValueError):
        parse_winner_key("no-bucket-suffix")


def test_build_policy_reconstructs_each_placement():
    assert isinstance(PolicyCandidate().build_policy(), UnifiedPolicy)
    assert isinstance(PolicyCandidate(placement="discrete").build_policy(),
                      DiscretePolicy)
    pol = PolicyCandidate(placement="adaptive", cutoff=4096).build_policy()
    assert isinstance(pol, AdaptivePolicy) and pol.cutoff == 4096


def test_near_square_mesh_shape():
    assert near_square_mesh_shape(1) == (1,)
    assert near_square_mesh_shape(4) == (2, 2)
    assert near_square_mesh_shape(6) == (2, 3)
    assert near_square_mesh_shape(8) == (2, 4)
    assert near_square_mesh_shape(12) == (3, 4)
    assert near_square_mesh_shape(7) == (7,)     # primes stay 1-D
    with pytest.raises(ValueError):
        near_square_mesh_shape(0)


# ---------------------------------------------------------------------------
# the search
# ---------------------------------------------------------------------------

def test_tuner_determinism_same_inputs_same_winner():
    w = _fake_workload({"unified": 0.1, "adaptive": 0.3, "discrete": 1.0,
                        "host": 2.0})
    r1 = TU.tune(w, trials=4, measure=_measure, seed=0)
    r2 = TU.tune(w, trials=4, measure=_measure, seed=0)
    assert r1.winner == r2.winner
    assert [t["label"] for t in r1.table] == [t["label"] for t in r2.table]
    assert [t["score_s"] for t in r1.table] == [t["score_s"] for t in r2.table]
    assert r1.winner.placement == "unified"      # the fastest fake FOM
    assert r1.fom_s == 0.1 and r1.ref_fom_s == 1.0


def test_winner_never_measured_worse_than_ref():
    # every searched candidate measures 10x slower than the reference;
    # the winner pool always contains the ref, so the ref must win
    w = _fake_workload({"unified": 10.0, "adaptive": 10.0, "host": 10.0,
                        "discrete": 10.0, "discrete+ref": 1.0})
    res = TU.tune(w, trials=3, measure=_measure)
    assert res.winner == w.ref and res.fom_s == 1.0


def test_parity_failure_disqualifies_candidate():
    w = _fake_workload({"unified": 0.01, "discrete": 1.0},
                       bad_leaves_for=("unified+ref",))
    res = TU.tune(w, trials=1, measure=_measure)
    assert any("unified+ref" in d for d in res.disqualified)
    assert res.winner != PolicyCandidate()       # the cheater did not win


def test_trials_zero_is_pure_cost_model():
    w = _fake_workload({})
    calls = []
    res = TU.tune(w, trials=0, residuals={"*": 1.0},
                  measure=lambda *a: calls.append(a))
    assert not calls                             # measurement-free
    assert res.fom_s is None and res.ref_fom_s is None
    # the UPM-seeded priors rank unified ahead of staged/host placements
    assert res.winner.placement == "unified"
    res2 = TU.tune(w, trials=0, residuals={"*": 1.0},
                   measure=lambda *a: calls.append(a))
    assert res.winner == res2.winner and res.score_s == res2.score_s


def test_residuals_correct_the_model():
    prog = _mini_program()
    model = TU.model_costs(prog)
    assert model["total_s"] > 0 and model["ops"]
    meas = {r: 10.0 * t for r, t in model["region_s"].items()}
    res = TU.compute_residuals(model, meas)
    assert res["*"] == pytest.approx(10.0)
    for r in model["region_s"]:
        assert res[r] == pytest.approx(10.0)
    base = TU.score_candidate(PolicyCandidate(), model)
    corrected = TU.score_candidate(PolicyCandidate(), model, res)
    assert corrected == pytest.approx(10.0 * base)


def test_scores_rank_placements_by_prior():
    model = TU.model_costs(_mini_program())
    s = {p: TU.score_candidate(PolicyCandidate(placement=p), model)
         for p in ("unified", "discrete", "host")}
    assert s["unified"] < s["discrete"]          # staging tax
    assert s["unified"] < s["host"]              # host-compute factor


# ---------------------------------------------------------------------------
# profile persistence + --policy auto
# ---------------------------------------------------------------------------

def test_profile_roundtrip_constructs_exact_winning_policy(tmp_path):
    w = _fake_workload({"adaptive": 0.1, "unified": 0.5, "discrete": 1.0})
    res = TU.tune(w, trials=4, measure=_measure)
    assert res.winner.placement == "adaptive"
    path = tmp_path / "profile.json"
    prof = PolicyProfile()
    prof.add(res.to_entry())
    prof.save(path)

    loaded = PolicyProfile.load(path)
    entry = loaded.lookup("fake", w.size)
    assert entry is not None and entry.candidate == res.winner
    assert entry.fom_s == res.fom_s and entry.ref_fom_s == res.ref_fom_s

    pol = auto_policy("fake", w.size, profile_path=str(path), quiet=True)
    assert isinstance(pol, AdaptivePolicy)
    assert pol.cutoff == (res.winner.cutoff or pol.cutoff)
    assert pol.tuned_entry.key == entry_key("fake", size_bucket(w.size))


def test_profile_version_gate(tmp_path):
    path = tmp_path / "profile.json"
    prof = PolicyProfile()
    prof.add(ProfileEntry("fake", 11, PolicyCandidate()))
    prof.save(path)
    d = path.read_text().replace(f'"version": {PROFILE_VERSION}',
                                 '"version": 999')
    path.write_text(d)
    with pytest.raises(ValueError):
        PolicyProfile.load(path)
    # but a MISSING profile is "no profile", not an error
    assert PolicyProfile.load_if_exists(tmp_path / "nope.json") is None


def test_nearest_bucket_fallback(tmp_path):
    prof = PolicyProfile()
    e8 = ProfileEntry("fake", 8, PolicyCandidate(placement="host"))
    e12 = ProfileEntry("fake", 12, PolicyCandidate(placement="discrete"))
    prof.add(e8)
    prof.add(e12)
    assert prof.lookup("fake", 2 ** 11).bucket == 12       # exact bucket
    assert prof.lookup("fake", 2 ** 20).bucket == 12       # nearest above
    assert prof.lookup("fake", 4).bucket == 8              # nearest below
    # distance tie resolves to the smaller bucket (AutotuneSelector rule)
    assert prof.lookup("fake", 2 ** 9 + 1).bucket == 8
    assert prof.lookup("unknown", 2 ** 11) is None

    path = tmp_path / "profile.json"
    prof.save(path)
    # an unknown workload falls back to the hand-assembled lm_policy
    pol = auto_policy("unknown", 1024, profile_path=str(path), quiet=True)
    assert isinstance(pol, UnifiedPolicy) and pol.tuned_entry is None


def test_size_helpers_match_bucket_scheme():
    assert serve_size(2, 12, 64) == 1536
    assert train_size(2, 16, 64) == 2048
    assert cfd_size((12, 12, 12)) == 1728
    assert size_bucket(serve_size(2, 12, 64)) == 11


# ---------------------------------------------------------------------------
# cost model vs measured (the calibration smoke)
# ---------------------------------------------------------------------------

def test_cost_model_rank_correlation_on_cfd_corpus():
    """The roofline bridge must get the per-region *ranking* right on a
    real shipped program — that is all the pruning stage needs from it
    (the measured finalist pass owns absolute ordering)."""
    w = get_workload("cfd_step")
    model = TU.model_costs(w.build_program())
    assert not model["skipped"], model["skipped"]
    res = w.run(PolicyCandidate(), 2)
    common = [r for r in res.region_s if r in model["region_s"]]
    assert len(common) >= 8, common
    m = np.array([model["region_s"][r] for r in common])
    s = np.array([res.region_s[r] for r in common])
    rank = lambda v: np.argsort(np.argsort(v))
    corr = float(np.corrcoef(rank(m), rank(s))[0, 1])
    assert corr > 0.5, (corr, dict(zip(common, zip(m, s))))
