import dataclasses
import os
import sys
import types

import jax
import numpy as np
import pytest

# Smoke tests and benches must see ONE device — the dry-run and multi-APU
# scaling subprocesses (repro.launch.{dryrun,scaling}) set their own
# XLA_FLAGS before their jax import; never set device-count flags here.

# ---------------------------------------------------------------------------
# hypothesis skip-guard: when hypothesis is not installed, property tests
# must degrade to SKIP, not break collection of their whole module.
# ---------------------------------------------------------------------------
try:
    import hypothesis  # noqa: F401
except ImportError:
    def _given_stub(*_a, **_k):
        def deco(fn):
            import inspect

            def skipper(*args, **kwargs):
                pytest.skip("hypothesis not installed")

            skipper.__name__ = fn.__name__
            skipper.__doc__ = fn.__doc__
            # expose only `self` so pytest doesn't mistake strategy
            # parameters for fixtures
            params = [p for p in inspect.signature(fn).parameters.values()
                      if p.name == "self"]
            skipper.__signature__ = inspect.Signature(params)
            return skipper
        return deco

    def _settings_stub(*_a, **_k):
        if _a and callable(_a[0]) and not _k:      # bare @settings use
            return _a[0]
        return lambda fn: fn

    class _StrategyStub:
        """Accepts any chained strategy construction (st.lists(...).map(...))."""
        def __call__(self, *a, **k):
            return self

        def __getattr__(self, name):
            return self

    _hyp = types.ModuleType("hypothesis")
    _st = types.ModuleType("hypothesis.strategies")
    _strategy = _StrategyStub()
    # any attribute resolves to the inert strategy stub, so future
    # `from hypothesis import <anything>` degrades to skip too
    _st.__getattr__ = lambda name: _strategy
    _hyp.given = _given_stub
    _hyp.settings = _settings_stub
    _hyp.strategies = _st
    _hyp.assume = lambda *a, **k: True
    _hyp.__getattr__ = lambda name: _strategy
    sys.modules["hypothesis"] = _hyp
    sys.modules["hypothesis.strategies"] = _st


@pytest.fixture(scope="session")
def rng():
    return np.random.RandomState(0)


@pytest.fixture(scope="session")
def traffic_seed():
    """ONE seed for every traffic-driven test (fig_traffic-style engine
    runs): threading a single session fixture through makes the Poisson
    request streams reproducible run-to-run instead of each module picking
    its own ad-hoc constant.  Override with REPRO_TRAFFIC_SEED to sweep —
    the parity oracles are derived from the same fixture, so any seed must
    pass."""
    return int(os.environ.get("REPRO_TRAFFIC_SEED", "11"))


def fp32(cfg):
    """Reduced configs in fp32 for tight numeric comparisons."""
    return dataclasses.replace(cfg, param_dtype="float32",
                               compute_dtype="float32")
