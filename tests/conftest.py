import dataclasses

import jax
import numpy as np
import pytest

# Smoke tests and benches must see ONE device (the dry-run subprocesses set
# their own XLA_FLAGS) — assert that contract instead of setting flags here.


@pytest.fixture(scope="session")
def rng():
    return np.random.RandomState(0)


def fp32(cfg):
    """Reduced configs in fp32 for tight numeric comparisons."""
    return dataclasses.replace(cfg, param_dtype="float32",
                               compute_dtype="float32")
