"""Per-kernel shape/dtype sweeps vs the pure-jnp oracles (assignment
requirement for every Pallas kernel)."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.cfd import fvm
from repro.cfd.grid import Grid
from repro.cfd.precond import rb_dilu_factor


class TestFusedField:
    @pytest.mark.parametrize("shape", [(33,), (128, 128), (17, 5, 9),
                                       (64 * 128 + 3,)])
    @pytest.mark.parametrize("dt", ["float32", "bfloat16"])
    def test_axpy_xpay_mul(self, shape, dt, rng):
        from repro.kernels.fused_field import ops as K, ref as R
        x = jnp.asarray(rng.rand(*shape), dt)
        y = jnp.asarray(rng.rand(*shape), dt)
        z = jnp.asarray(rng.rand(*shape), dt)
        tol = dict(rtol=2e-2 if dt == "bfloat16" else 1e-5, atol=1e-2 if dt == "bfloat16" else 1e-6)
        for kf, rf, args in [(K.fused_axpy, R.fused_axpy, (2.5, x, y)),
                             (K.fused_xpay, R.fused_xpay, (-1.25, x, y)),
                             (K.fused_mul, R.fused_mul, (x, y)),
                             (K.fused_axpbypz, R.fused_axpbypz,
                              (2.0, x, -0.5, y, z))]:
            np.testing.assert_allclose(np.asarray(kf(*args), np.float32),
                                       np.asarray(rf(*args), np.float32),
                                       **tol)


class TestStencilSpmv:
    @pytest.mark.parametrize("shape", [(8, 6, 10), (16, 16, 16), (5, 7, 3),
                                       (32, 16, 8), (3, 3, 3)])
    def test_amul_vs_ref(self, shape, rng):
        from repro.kernels.stencil_spmv import ops as K, ref as R
        g = Grid(shape)
        A, _ = fvm.laplacian(g, 1.0)
        x = jnp.asarray(rng.rand(*shape).astype(np.float32))
        np.testing.assert_allclose(
            np.asarray(K.stencil_spmv(A.diag, A.off, x)),
            np.asarray(R.stencil_spmv(A.diag, A.off, x)),
            rtol=3e-4, atol=1e-4)

    @pytest.mark.parametrize("shape", [(8, 6, 10), (16, 16, 16), (6, 4, 12)])
    def test_rb_dilu_vs_ref(self, shape, rng):
        from repro.kernels.stencil_spmv import ops as K, ref as R
        g = Grid(shape)
        A, _ = fvm.laplacian(g, 1.0)
        red, _ = g.red_black_masks()
        P = rb_dilu_factor(A, red)
        r = jnp.asarray(rng.rand(*shape).astype(np.float32))
        np.testing.assert_allclose(
            np.asarray(K.rb_dilu_apply(P.rdiag, red, A.off, r)),
            np.asarray(R.rb_dilu(P.rdiag, red, A.off, r)),
            rtol=3e-4, atol=1e-4)


class TestRwkv6Scan:
    @pytest.mark.parametrize("dims", [(2, 128, 2, 16, 32), (1, 64, 3, 8, 64),
                                      (2, 96, 1, 32, 16), (1, 32, 2, 8, 8)])
    def test_vs_sequential(self, dims, rng):
        from repro.kernels.rwkv6_scan import ops as K, ref as R
        B, T, H, hd, C = dims
        r, k, v = [jnp.asarray(rng.randn(B, T, H, hd).astype(np.float32)) * 0.5
                   for _ in range(3)]
        logw = -jnp.asarray(rng.rand(B, T, H, hd).astype(np.float32)) * 2 - 0.01
        u = jnp.asarray(rng.randn(H, hd).astype(np.float32)) * 0.3
        ko, ks = K.rwkv6_scan(r, k, v, logw, u, chunk=C)
        ro, rs = R.rwkv6_scan(r, k, v, logw, u)
        np.testing.assert_allclose(np.asarray(ko), np.asarray(ro),
                                   rtol=2e-4, atol=2e-4)
        np.testing.assert_allclose(np.asarray(ks), np.asarray(rs),
                                   rtol=2e-4, atol=2e-4)

    def test_chunked_jax_path_matches_too(self, rng):
        from repro.kernels.rwkv6_scan import ref as R
        B, T, H, hd = 2, 128, 2, 16
        r, k, v = [jnp.asarray(rng.randn(B, T, H, hd).astype(np.float32)) * 0.5
                   for _ in range(3)]
        logw = -jnp.asarray(rng.rand(B, T, H, hd).astype(np.float32)) - 0.01
        u = jnp.asarray(rng.randn(H, hd).astype(np.float32)) * 0.3
        co, cs = R.rwkv6_chunked(r, k, v, logw, u, chunk=32)
        ro, rs = R.rwkv6_scan(r, k, v, logw, u)
        np.testing.assert_allclose(np.asarray(co), np.asarray(ro),
                                   rtol=2e-4, atol=2e-4)
