"""MoE dispatch: sort-based capacity path vs dense oracle."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ModelConfig, MoEConfig
from repro.models import moe as M
from repro.models import transformer as T


def make_cfg(E=8, k=2, shared=0, cf=8.0):
    return ModelConfig(
        name="t", family="moe", n_layers=2, d_model=32, n_heads=4,
        n_kv_heads=2, head_dim=8, d_ff=64, vocab=64,
        moe=MoEConfig(n_experts=E, top_k=k, d_ff=32, shared_expert_ff=shared,
                      capacity_factor=cf))


@pytest.mark.parametrize("E,k,shared", [(8, 2, 0), (16, 1, 32), (4, 4, 0)])
def test_moe_matches_dense_oracle(E, k, shared, rng):
    cfg = make_cfg(E, k, shared, cf=float(E))   # capacity ~= no drops
    key = jax.random.PRNGKey(0)
    from repro.models.params import init_params
    p = init_params(key, M.moe_specs(cfg))
    x = jnp.asarray(rng.randn(2, 16, 32).astype(np.float32) * 0.5)
    y, aux = M.moe_mlp(p, x, cfg)
    yr, auxr = M.moe_ref(p, x, cfg)
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(yr, np.float32), rtol=2e-2, atol=2e-2)
    np.testing.assert_allclose(float(aux), float(auxr), rtol=1e-4)


def test_capacity_drops_are_bounded(rng):
    cfg = make_cfg(8, 2, 0, cf=1.0)
    key = jax.random.PRNGKey(0)
    from repro.models.params import init_params
    p = init_params(key, M.moe_specs(cfg))
    x = jnp.asarray(rng.randn(4, 64, 32).astype(np.float32))
    y, _ = M.moe_mlp(p, x, cfg)
    # even with drops output must be finite and mostly nonzero
    ya = np.asarray(y, np.float32)
    assert np.isfinite(ya).all()
    assert (np.abs(ya).sum(-1) > 0).mean() > 0.5


def test_router_normalizes_gates(rng):
    cfg = make_cfg(8, 4)
    key = jax.random.PRNGKey(0)
    from repro.models.params import init_params
    p = init_params(key, M.moe_specs(cfg))
    x2 = jnp.asarray(rng.randn(32, 32).astype(np.float32))
    gate, idx, aux = M._router(p, x2, cfg.moe)
    np.testing.assert_allclose(np.asarray(gate.sum(-1)), 1.0, rtol=1e-5)
    assert int(idx.max()) < 8 and float(aux) > 0
