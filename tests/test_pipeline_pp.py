"""GPipe pipeline parallelism == sequential execution (4-stage subprocess)."""
import subprocess
import sys

CODE = r'''
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import numpy as np, jax, jax.numpy as jnp
from repro.runtime.pipeline import gpipe_apply, split_microbatches

mesh = jax.make_mesh((4,), ("pod",))
S, d = 4, 8
ws = jnp.asarray(np.random.RandomState(1).randn(S, d, d) * 0.3, jnp.float32)
def stage(w, x): return jnp.tanh(x @ w)
x = jnp.asarray(np.random.RandomState(2).randn(16, d), jnp.float32)
y = gpipe_apply(stage, ws, split_microbatches(x, 8), mesh, axis="pod")
ref = x
for s in range(S):
    ref = stage(ws[s], ref)
np.testing.assert_allclose(np.asarray(y).reshape(16, d), np.asarray(ref),
                           rtol=2e-5, atol=2e-5)

# differentiability (PP backward schedule via AD)
def loss(ws, x):
    y = gpipe_apply(stage, ws, split_microbatches(x, 4), mesh, axis="pod")
    return jnp.sum(y ** 2)
g = jax.grad(loss)(ws, x)
def loss_ref(ws, x):
    r = x
    for s in range(S): r = stage(ws[s], r)
    return jnp.sum(r ** 2)
g_ref = jax.grad(loss_ref)(ws, x)
np.testing.assert_allclose(np.asarray(g), np.asarray(g_ref), rtol=1e-4, atol=1e-4)
print("GPIPE_OK")
'''


def test_gpipe_subprocess():
    r = subprocess.run([sys.executable, "-c", CODE], capture_output=True,
                       text=True, timeout=300)
    assert "GPIPE_OK" in r.stdout, r.stderr[-2000:]
