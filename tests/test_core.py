"""Paper-core unit + property tests: pool invariants (hypothesis), adaptive
routing, ledger coverage, memory placement."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.ledger import Ledger, offload_region
from repro.core.pool import (HostStagingPool, POOL_MIN_ELEMS, _size_class)
from repro.core.regions import AdaptivePolicy, Executor, Region, region
from repro.core.umem import MemSpace, place, space_of, supported_spaces


class TestPoolProperties:
    @given(st.lists(st.tuples(st.integers(1, 200_000), st.booleans()),
                    min_size=1, max_size=40))
    @settings(max_examples=50, deadline=None)
    def test_acquire_release_invariants(self, ops_list):
        pool = HostStagingPool()
        held = []
        for n, do_release in ops_list:
            a = pool.acquire((n,), np.float32)
            assert a.shape == (n,) and a.dtype == np.float32
            held.append(a)
            if do_release and held:
                pool.release(held.pop())
        s = pool.stats
        # pooled buffers only above the paper's 5K threshold
        assert s.unpooled == sum(1 for n, _ in ops_list if n < POOL_MIN_ELEMS)
        assert s.hits + s.misses == sum(1 for n, _ in ops_list
                                        if n >= POOL_MIN_ELEMS)
        # a released class must be reusable: free bytes consistent
        assert pool.free_bytes >= 0

    @given(st.integers(1, 1 << 30))
    @settings(max_examples=200, deadline=None)
    def test_size_class_sane(self, n):
        c = _size_class(n)
        assert c >= max(n, 4096) and c < 2 * max(n, 4096)

    def test_reuse_is_real(self):
        pool = HostStagingPool()
        a = pool.acquire((8192,), np.float32)
        raw = a._pool_raw
        pool.release(a)
        b = pool.acquire((8192,), np.float32)
        assert b._pool_raw is raw            # same backing memory
        assert pool.stats.hit_rate == 0.5


class TestAdaptiveRouting:
    """The ``if(target: n > TARGET_CUT_OFF)`` clause on the regions API —
    the behaviors the retired TargetDispatch shim used to cover."""

    def test_cutoff_routes(self):
        ldg = Ledger("t")

        @region("inc", ledger=ldg)
        def inc(x):
            return x + 1

        ex = Executor(AdaptivePolicy(cutoff=100), ldg)
        ex.run(inc, jnp.ones(10))
        ex.run(inc, jnp.ones(1000))
        r = ldg.regions["inc"]
        assert r.host_calls == 1 and r.device_calls == 1
        assert 0 < r.offload_fraction < 1

    def test_results_identical_both_paths(self):
        ldg = Ledger("t")

        @region("sin2", ledger=ldg)
        def sin2(x):
            return jnp.sin(x) * 2

        ex = Executor(AdaptivePolicy(cutoff=50), ldg)
        np.testing.assert_allclose(
            np.asarray(ex.run(sin2, jnp.linspace(0, 1, 10))),
            np.sin(np.linspace(0, 1, 10)) * 2, rtol=1e-6)
        np.testing.assert_allclose(
            np.asarray(ex.run(sin2, jnp.linspace(0, 1, 1000))),
            np.sin(np.linspace(0, 1, 1000)) * 2, rtol=1e-6)
        r = ldg.regions["sin2"]
        assert r.host_calls == 1 and r.device_calls == 1

    def test_decorator(self):
        @region("triple", ledger=Ledger("t"))
        def f(x):
            return x * 3

        assert isinstance(f, Region)
        out = Executor(AdaptivePolicy(cutoff=10), Ledger("t")).run(
            f, jnp.ones(5))
        np.testing.assert_allclose(np.asarray(out), 3.0)


class TestLedger:
    def test_coverage(self):
        ldg = Ledger("t")

        @offload_region("hot", ledger=ldg)
        def hot(x):
            return x * 2

        @offload_region("cold", offloaded=False, ledger=ldg)
        def cold(x):
            return x + 1

        hot(jnp.ones(100))
        cold(jnp.ones(100))
        rep = ldg.coverage_report()
        assert rep["regions"] == 2 and rep["offloaded_regions"] == 1
        assert 0 < rep["device_fraction"] < 1


class TestUmem:
    def test_placement(self):
        if "pinned_host" not in supported_spaces():
            pytest.skip("no host memory space")
        x = place(jnp.ones(100), MemSpace.HOST)
        assert space_of(x) == "pinned_host"
        y = place(x, MemSpace.DEVICE)
        assert space_of(y) == "device"
        np.testing.assert_array_equal(np.asarray(y), 1.0)
