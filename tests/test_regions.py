"""repro.core.regions: Region + ExecutionPolicy API.

Covers the unified/discrete/host policy parity on a cavity time-step, the
adaptive (TARGET_CUT_OFF-inside-an-executor) policy's ledger accounting,
the uniform return contract, region-name uniquification, sizing, placement
hints, calibration recording, and the retired-shim import gate."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.cfd.grid import Grid
from repro.cfd.simple import SimpleConfig, SimpleFoam, init_state
from repro.core.ledger import Ledger
from repro.core.regions import (AdaptivePolicy, DiscretePolicy, Executor,
                                HostPolicy, MigrationStager, Region,
                                UnifiedPolicy, as_region, default_size,
                                make_policy, region)
from repro.core.umem import MemSpace, preferred_host_space, space_of


# ---------------------------------------------------------------------------
# policy parity (the paper's "same source, three platforms" claim)
# ---------------------------------------------------------------------------

def test_policy_parity_cavity_time_step():
    """unified / discrete / host policies must produce numerically identical
    cavity time_step results on an 8^3 grid."""
    cfg = SimpleConfig(grid=Grid((8, 8, 8)), nu=0.1, inner_max=20)
    outs = {}
    for name, policy in (("unified", UnifiedPolicy()),
                         ("discrete", DiscretePolicy()),
                         ("host", HostPolicy())):
        app = SimpleFoam(cfg, executor=Executor(policy))
        st, _ = app.time_step(init_state(cfg))
        outs[name] = st
    for name in ("discrete", "host"):
        for f in ("u", "v", "w", "p"):
            np.testing.assert_allclose(
                np.asarray(getattr(outs["unified"], f)),
                np.asarray(getattr(outs[name], f)),
                rtol=1e-5, atol=1e-6,
                err_msg=f"{name} diverges from unified on {f}")


def test_return_contract_is_jax_arrays():
    """One return contract across ALL policies: jax Arrays, never numpy
    (the old DiscreteExecutor leaked numpy, silently changing types)."""
    ldg = Ledger("t")

    @region("work", ledger=ldg)
    def work(x):
        return x * 2.0

    x = jnp.ones(8192)
    for mode in ("unified", "discrete", "host", "adaptive"):
        ex = Executor(make_policy(mode), Ledger(mode))
        out = ex.run(work, x)
        assert isinstance(out, jax.Array), f"{mode} broke the return contract"
        np.testing.assert_allclose(np.asarray(out), 2.0)


def test_discrete_staged_results_survive_pool_reuse():
    """A staged-out result must not alias a pooled host page: the next
    region's stage_out would overwrite it (zero-copy device_put on CPU)."""
    ldg = Ledger("t")

    @region("plus", ledger=ldg)
    def plus(x):
        return x + 1.0

    @region("zero", ledger=ldg)
    def zero(x):
        return x * 0.0

    ex = Executor(DiscretePolicy(), ldg)
    x = jnp.ones(6000)                   # above POOL_MIN_ELEMS=5120
    a = ex.run(plus, x)
    b = ex.run(zero, x)                  # same size class: pool would reuse
    np.testing.assert_allclose(np.asarray(a), 2.0)
    np.testing.assert_allclose(np.asarray(b), 0.0)


def test_discrete_device_pool_actually_reuses():
    """Staged-in device buffers must recycle through the DeviceBufferPool:
    release and acquire have to agree on the key even on backends whose
    default memory kind isn't named 'device' (CPU: unpinned_host)."""
    ldg = Ledger("t")

    @region("work", ledger=ldg)
    def work(x):
        return x + 1.0

    ex = Executor(DiscretePolicy(), ldg)
    pool = ex.policy.stager.device_pool
    for _ in range(4):
        ex.run(work, jnp.ones(8192))
    assert pool.stats.hits > 0                       # real reuse
    assert all(len(v) <= 2 for v in pool._free.values())  # no leak


def test_discrete_policy_stages_and_accounts():
    ldg = Ledger("t")

    @region("big", ledger=ldg)
    def big(x):
        return x + 1.0

    ex = Executor(DiscretePolicy(), ldg)
    x = jnp.ones(1 << 16)
    ex.run(big, x)
    rep = ex.report()
    assert rep["staging_s"] > 0
    r = ldg.regions["big"]
    assert r.staging_bytes >= 2 * x.nbytes          # operands in + results out
    # pooled staging actually engaged
    stager = ex.policy.stager
    assert isinstance(stager, MigrationStager)
    assert stager.host_pool.stats.hits + stager.host_pool.stats.misses > 0


def test_host_pool_recycles_when_results_die():
    """Pooled host staging pages must return to the pool once the staged
    result array is dropped (Umpire model), giving real reuse even on
    backends where the host wrap is zero-copy."""
    import gc
    ldg = Ledger("t")

    @region("work", ledger=ldg)
    def work(x):
        return x + 1.0

    ex = Executor(DiscretePolicy(), ldg)
    pool = ex.policy.stager.host_pool
    for _ in range(4):
        out = ex.run(work, jnp.ones(1 << 16))
        del out                          # app frees its host memory
        gc.collect()
    assert pool.stats.hits > 0           # later calls reuse released pages


# ---------------------------------------------------------------------------
# adaptive routing inside an executor
# ---------------------------------------------------------------------------

def test_adaptive_routing_lands_in_coverage_report():
    ldg = Ledger("t")

    @region("saxpy", ledger=ldg)
    def saxpy(x):
        return x * 3.0

    ex = Executor(AdaptivePolicy(cutoff=100), ldg)
    ex.run(saxpy, jnp.ones(10))          # below cutoff -> host
    ex.run(saxpy, jnp.ones(1000))        # above cutoff -> device
    rep = ex.report()
    assert rep["host_calls"] == 1 and rep["device_calls"] == 1
    assert 0 < rep["offload_elem_fraction"] < 1
    r = ldg.regions["saxpy"]
    assert r.host_elems == 10 and r.device_elems == 1000


def test_adaptive_policy_drives_region_program():
    """AdaptivePolicy must be drivable by the same executor machinery as
    the static modes — the composition the old TargetDispatch split made
    impossible."""
    cfg = SimpleConfig(grid=Grid((6, 6, 6)), nu=0.1, inner_max=15)
    app_ref = SimpleFoam(cfg, executor=Executor(UnifiedPolicy()))
    app_ad = SimpleFoam(cfg, executor=Executor(AdaptivePolicy(cutoff=64)))
    st_ref, _ = app_ref.time_step(init_state(cfg))
    st_ad, _ = app_ad.time_step(init_state(cfg))
    np.testing.assert_allclose(np.asarray(st_ref.u), np.asarray(st_ad.u),
                               rtol=1e-5, atol=1e-6)
    rep = app_ad.ex.report()
    assert rep["host_calls"] + rep["device_calls"] > 0
    # 6^3=216 cells > 64 cutoff: field regions route to device, scalar-ish
    # reductions still count somewhere — decisions are all in one report
    assert rep["device_calls"] > 0


def test_mixed_routing_splits_device_fraction():
    """One region routed both ways must attribute compute per side: a single
    device call must not claim the row's host time as device coverage."""
    ldg = Ledger("t")
    ldg.record("r", device=False, compute_s=9.0, elems=10)
    ldg.record("r", device=True, compute_s=1.0, elems=1000)
    rep = ldg.coverage_report()
    assert rep["device_compute_s"] == pytest.approx(1.0)
    assert rep["device_fraction"] == pytest.approx(0.1)
    r = ldg.regions["r"]
    assert r.host_compute_s == pytest.approx(9.0)
    assert r.device_compute_s == pytest.approx(1.0)


def test_tree_place_min_bytes_keeps_python_scalars():
    from repro.core.umem import tree_place
    host = preferred_host_space()
    if host is None:
        pytest.skip("no host memory space on this platform")
    tree = {"len": 7, "kv": jnp.ones(8192)}
    out = tree_place(tree, host, min_bytes=1024)
    assert out["len"] == 7 and not isinstance(out["len"], jax.Array)
    assert space_of(out["kv"]) == host.kind


def test_calibrate_records_cutoff_in_ledger():
    ldg = Ledger("t")

    @region("kern", ledger=ldg)
    def kern(x):
        return x * 2.0 + 1.0

    pol = AdaptivePolicy()
    cut = pol.calibrate(kern, lambda n: (jnp.ones(n),),
                        sizes=(256, 4096), reps=2, ledger=ldg)
    assert pol.cutoff == cut
    assert ldg.regions["kern"].cutoff == cut
    assert ldg.coverage_report()["cutoffs"] == {"kern": cut}


# ---------------------------------------------------------------------------
# Region mechanics
# ---------------------------------------------------------------------------

def test_duplicate_region_names_uniquify():
    ldg = Ledger("t")

    @region("dot", ledger=ldg)
    def dot_a(x):
        return x.sum()

    @region("dot", ledger=ldg)
    def dot_b(x):
        return x.sum()

    assert dot_a.name == "dot" and dot_b.name == "dot#2"
    dot_a(jnp.ones(4))
    dot_b(jnp.ones(4))
    assert ldg.regions["dot"].calls == 1
    assert ldg.regions["dot#2"].calls == 1


def test_same_named_regions_from_different_ledgers_dont_merge():
    """An executor recording regions registered in OTHER ledgers must keep
    one row per region object, not merge by bare name."""
    @region("dot", ledger=Ledger("a"))
    def dot_a(x):
        return x.sum()

    @region("dot", ledger=Ledger("b"))
    def dot_b(x):
        return (x * x).sum()

    ex = Executor(UnifiedPolicy(), Ledger("shared"))
    ex.run(dot_a, jnp.ones(8))
    ex.run(dot_b, jnp.ones(8))
    ex.run(dot_a, jnp.ones(8))
    rows = {n: r.calls for n, r in ex.ledger.regions.items()}
    assert rows == {"dot": 2, "dot#2": 1}


def test_regions_are_hashable():
    @region("h", ledger=Ledger("t"))
    def h(x):
        return x

    assert h in {h}                     # usable as set/dict key
    assert len({h, h}) == 1


def test_region_dunder_name_is_identifier():
    @region("grad(p)", ledger=Ledger("t"))
    def grad_p(p):
        return p

    assert grad_p.__name__.isidentifier()
    assert grad_p.name == "grad(p)"


def test_default_size_uses_max_leaf():
    """A small scalar first arg must not mask the field size."""
    n = default_size((jnp.float32(0.5), jnp.ones(50000)), {})
    assert n == 50000
    assert default_size((), {}) == 0


def test_placement_hints_applied():
    host = preferred_host_space()
    if host is None:
        pytest.skip("no host memory space on this platform")
    ldg = Ledger("t")

    @region("hinted", ledger=ldg, placement={0: host}, result_space=host)
    def hinted(x):
        return x + 1.0

    ex = Executor(UnifiedPolicy(), ldg)
    out = ex.run(hinted, jnp.ones(8192))
    assert space_of(out) == host.kind


def test_placement_hint_by_name_applies_to_positional_arg():
    host = preferred_host_space()
    if host is None:
        pytest.skip("no host memory space on this platform")
    @region("named-hint", ledger=Ledger("t"), placement={"x": host})
    def f(x):
        return x + 1.0

    ex = Executor(UnifiedPolicy(), Ledger("t"))
    # drive place_args directly: positional call must still hit the hint
    args, kwargs = ex.policy.placer.place_args(f, (jnp.ones(8192),), {})
    assert space_of(args[0]) == host.kind


def test_legacy_closure_adapts_to_region():
    calls = []

    def f(x):
        calls.append(1)
        return x * 2

    legacy = jax.jit(f)
    runner = lambda x: f(x)
    runner.jitted = legacy
    runner.offloaded = True
    runner.region_name = "legacy"
    r = as_region(runner)
    assert isinstance(r, Region)
    assert r.name == "legacy" and r.offloaded
    out = Executor(UnifiedPolicy()).run(runner, jnp.ones(4))
    np.testing.assert_allclose(np.asarray(out), 2.0)


# ---------------------------------------------------------------------------
# retired shims: the regions API is the only offload path
# ---------------------------------------------------------------------------

def test_no_internal_imports_of_retired_shims():
    """core/dispatch and core/executors are deprecation-alias stubs for
    external callers only; nothing in-repo may reference them (the same
    gate CI runs)."""
    import importlib.util
    import pathlib
    tool = pathlib.Path(__file__).resolve().parent.parent / "tools" / \
        "check_retired_imports.py"
    spec = importlib.util.spec_from_file_location("check_retired_imports",
                                                  tool)
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    assert mod.check() == 0


def test_retired_shims_not_exported_from_core():
    import repro.core as core
    for retired in ("TargetDispatch", "DispatchStats", "offload",
                    "UnifiedExecutor", "DiscreteExecutor", "HostExecutor",
                    "make_executor", "BaseExecutor"):
        assert not hasattr(core, retired), \
            f"repro.core still exports retired shim {retired}"


def test_size_fn_override_respected():
    """Post-construction size_fn overrides must keep steering routing (the
    pre-regions dispatcher read size_fn on every call)."""
    ldg = Ledger("t")

    @region("f", ledger=ldg)
    def f(x):
        return x + 1

    ex = Executor(AdaptivePolicy(cutoff=100), ldg)
    f.size_fn = lambda args, kwargs: 0       # route everything to host
    ex.run(f, jnp.ones(1000))
    r = ldg.regions["f"]
    assert r.host_calls == 1 and r.device_calls == 0


def test_adaptive_executor_shares_ledger_with_staging_metrics():
    ldg = Ledger("shared")

    @region("f", ledger=ldg)
    def f(x):
        return x + 1

    ex = Executor(AdaptivePolicy(cutoff=100), ldg)
    ex.run(f, jnp.ones(10))
    ex.run(f, jnp.ones(1000))
    rep = ldg.coverage_report()
    assert rep["host_calls"] == 1 and rep["device_calls"] == 1
    assert "staging_fraction" in rep      # same report as staging metrics
