"""The ML stack on the region-program spine (serve + train).

Covers: role-keyed KV placement (``offload_kv_cache`` as a Placer), decode
bit-parity with and without KV offload, ``replay_batch`` decode parity vs
N sequential replays, the region-decomposed train step (``FWD_BWD`` /
``ADAMW_UPDATE``) vs the raw jit step, the AdamW ``host`` variant,
supervisor restarts that re-capture while keeping the same Ledger, and the
coverage_report() snapshot saved beside checkpoint weights."""
import json
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.reduced import reduced as make_reduced
from repro.configs.registry import get_config
from repro.core.ledger import Ledger
from repro.core.program import capture
from repro.core.regions import (Executor, HostPolicy, Placer, TargetSelector,
                                UnifiedPolicy, region)
from repro.core.umem import preferred_host_space
from repro.launch import serve as SV
from repro.launch.mesh import make_smoke_mesh
from repro.launch.policy import lm_policy
from repro.models import transformer as T
from repro.optim import adamw
from repro.train import step as S


# ---------------------------------------------------------------------------
# role-keyed KV placement
# ---------------------------------------------------------------------------

def _recording_tree_place(monkeypatch):
    """Swap serve's tree_place for a recorder (placement itself is a no-op
    assertion target on CPU, where every space is unpinned_host)."""
    calls = []

    def rec(tree, space, device=None, min_bytes=0):
        calls.append((tuple(np.asarray(x).shape
                            for x in jax.tree.leaves(tree)), min_bytes))
        return tree

    monkeypatch.setattr(SV, "tree_place", rec)
    return calls


def test_place_kv_leaves_moves_only_kv_roles(monkeypatch):
    calls = _recording_tree_place(monkeypatch)
    cache = {"cycles": {"p0": {"k": jnp.ones((2, 8, 1, 16)),
                               "v": jnp.ones((2, 8, 1, 16)),
                               "pos": jnp.ones((8,), jnp.int32)}},
             "x_cm": jnp.ones((2, 64))}
    host = preferred_host_space()
    if host is None:
        pytest.skip("no host memory space on this platform")
    out = SV.place_kv_leaves(cache, host, min_bytes=123)
    # only the two k/v leaves were offered to tree_place, with min_bytes
    # threaded through (the size gate itself is tree_place's, covered in
    # test_regions); pos and x_cm never cross
    assert len(calls) == 2
    assert all(mb == 123 for _, mb in calls)
    assert all(shapes == ((2, 8, 1, 16),) for shapes, _ in calls)
    for a, b in zip(jax.tree.leaves(out), jax.tree.leaves(cache)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_offload_kv_cache_is_a_placer(monkeypatch):
    host = preferred_host_space()
    if host is None:
        pytest.skip("no host memory space on this platform")
    placer = SV.offload_kv_cache(min_bytes=7)
    assert isinstance(placer, Placer)          # a policy placement axis
    assert placer.kv_space == host and placer.kv_min_bytes == 7
    calls = _recording_tree_place(monkeypatch)

    @region("kv-dummy", ledger=Ledger("t"))
    def f(tok, cache):
        return cache

    cache = {"k": jnp.ones((4, 16)), "v": jnp.ones((4, 16)),
             "pos": jnp.ones((16,), jnp.int32)}
    args, kwargs = placer.place_args(f, (jnp.ones(2), cache), {})
    assert len(calls) == 2                     # k and v of the args tree
    out = placer.place_result(f, cache)
    assert len(calls) == 4                     # + k and v of the result
    for a, b in zip(jax.tree.leaves(out), jax.tree.leaves(cache)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_place_preserves_named_sharding():
    """Placing a mesh-sharded array into host space must rebind the memory
    kind, not gather onto one device — FSDP moments / scattered KV caches
    keep their partitioning under the placement axis."""
    from repro.core.umem import place
    host = preferred_host_space()
    if host is None:
        pytest.skip("no host memory space on this platform")
    mesh = make_smoke_mesh()
    sh = jax.sharding.NamedSharding(mesh, jax.sharding.PartitionSpec())
    x = jax.device_put(jnp.ones(128), sh)
    y = place(x, host)
    assert isinstance(y.sharding, jax.sharding.NamedSharding)
    assert y.sharding.memory_kind == host.kind
    np.testing.assert_array_equal(np.asarray(y), np.asarray(x))


# ---------------------------------------------------------------------------
# serve programs (model-backed; one shared reduced setup)
# ---------------------------------------------------------------------------

BATCH, PROMPT, GEN = 2, 8, 4


@pytest.fixture(scope="module")
def serve_setup():
    cfg = make_reduced(get_config("tinyllama-1.1b"))
    mesh = make_smoke_mesh()
    key = jax.random.PRNGKey(0)
    params = T.init(key, cfg)
    prompts = jax.random.randint(key, (BATCH, PROMPT), 0, cfg.vocab,
                                 jnp.int32)
    batch_in = {"tokens": prompts}
    regions = SV.make_serve_regions(cfg, mesh, params,
                                    ledger=Ledger("serve_tests"))
    make_cache = lambda: T.init_cache(cfg, BATCH, PROMPT + GEN)
    prefill_prog = SV.capture_prefill_program(regions, batch_in,
                                              make_cache())
    ex = Executor(UnifiedPolicy(), Ledger("setup"))
    tok, cache = prefill_prog.replay(ex, batch_in, make_cache())
    decode_prog = SV.capture_decode_program(regions, PROMPT, GEN, tok, cache)
    return {"cfg": cfg, "params": params, "batch_in": batch_in,
            "regions": regions, "make_cache": make_cache,
            "prefill_prog": prefill_prog, "decode_prog": decode_prog}


def _decode_tokens(s, ex):
    tok, cache = s["prefill_prog"].replay(ex, s["batch_in"],
                                          s["make_cache"]())
    toks = s["decode_prog"].replay(ex, tok, cache)
    return np.asarray(jnp.stack(toks, axis=1))


def test_decode_bit_identical_with_and_without_kv_offload(serve_setup):
    s = serve_setup
    host = preferred_host_space()
    if host is None:
        pytest.skip("no host memory space on this platform")
    plain = Executor(UnifiedPolicy(), Ledger("plain"))
    # min_bytes=0 forces even smoke-scale k/v pages across the boundary
    offl = Executor(lm_policy("unified", s["cfg"].memory,
                              placer=SV.offload_kv_cache(min_bytes=0)),
                    Ledger("offl"))
    seq_plain = _decode_tokens(s, plain)
    seq_offl = _decode_tokens(s, offl)
    assert seq_plain.shape == (BATCH, GEN)
    np.testing.assert_array_equal(seq_plain, seq_offl)


def test_replay_batch_decode_parity_vs_sequential(serve_setup):
    s = serve_setup
    ex = Executor(UnifiedPolicy(), Ledger("batch"))
    toks, caches = [], []
    for r in range(2):
        key = jax.random.fold_in(jax.random.PRNGKey(7), r)
        prompts = jax.random.randint(key, (BATCH, PROMPT), 0,
                                     s["cfg"].vocab, jnp.int32)
        tok, cache = s["prefill_prog"].replay(ex, {"tokens": prompts},
                                              s["make_cache"]())
        toks.append(tok)
        caches.append(cache)
    stacked_tok = jnp.stack(toks)
    stacked_cache = jax.tree.map(lambda *xs: jnp.stack(xs), *caches)
    out = s["decode_prog"].replay_batch(stacked_tok, stacked_cache,
                                        executor=ex)
    batched = np.asarray(jnp.stack(out, axis=-1))          # (N, B, gen)
    solo = np.stack([
        np.asarray(jnp.stack(s["decode_prog"].replay(ex, toks[i], caches[i]),
                             axis=-1))
        for i in range(2)])
    np.testing.assert_array_equal(batched, solo)
    # accounted as one ledger row on the executor's ledger
    assert any(name.startswith("decode_program[batch]")
               for name in ex.ledger.regions)


def test_serve_regions_account_on_one_ledger(serve_setup):
    s = serve_setup
    ex = Executor(UnifiedPolicy(), Ledger("acct"))
    _decode_tokens(s, ex)
    rep = ex.report()
    rows = set(ex.ledger.regions)
    assert {"PREFILL", "DECODE_STEP", "KV_APPEND"} <= rows
    assert rep["impl_counts"].get("ref", 0) >= 1 + 2 * (GEN - 1)
    assert 0 < rep["device_fraction"] <= 1    # KV_APPEND commits host-side


# ---------------------------------------------------------------------------
# train regions
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def train_setup():
    cfg = make_reduced(get_config("tinyllama-1.1b"))
    opt_cfg = adamw.AdamWConfig(lr=1e-3)
    key = jax.random.PRNGKey(1)
    params = T.init(key, cfg)
    opt = adamw.init_state(params, opt_cfg)
    batch = {"tokens": jax.random.randint(key, (2, 16), 0, cfg.vocab,
                                          jnp.int32)}
    return {"cfg": cfg, "opt_cfg": opt_cfg, "state": (params, opt),
            "batch": batch}


def test_train_regions_match_raw_step(train_setup):
    t = train_setup
    ldg = Ledger("train_regions")
    regions = S.make_train_regions(t["cfg"], t["opt_cfg"], ledger=ldg)
    prog = S.capture_train_program(regions, t["state"], t["batch"])
    ex = Executor(UnifiedPolicy(), ldg)
    (params_r, opt_r), metrics_r = prog.replay(ex, t["state"], t["batch"])

    raw = jax.jit(S.make_train_step(t["cfg"], t["opt_cfg"]))
    params_j, opt_j, metrics_j = raw(t["state"][0], t["state"][1],
                                     t["batch"])
    np.testing.assert_allclose(float(metrics_r["loss"]),
                               float(metrics_j["loss"]), rtol=1e-5)
    for a, b in zip(jax.tree.leaves(params_r), jax.tree.leaves(params_j)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   rtol=1e-4, atol=1e-5)
    rows = set(ldg.regions)
    assert {"FWD_BWD", "ADAMW_UPDATE"} <= rows
    assert ex.report()["impl_counts"] == {"ref": 2}


def test_adamw_host_variant_bitwise_parity():
    key = jax.random.PRNGKey(3)
    cfg = adamw.AdamWConfig(lr=1e-2)
    params = {"a": jax.random.normal(key, (17, 5)),
              "b": {"w": jax.random.normal(jax.random.fold_in(key, 1),
                                           (8,))}}
    grads = jax.tree.map(lambda p: p * 0.3 + 0.01, params)
    state = adamw.init_state(params, cfg)
    ref = adamw.apply_updates(params, grads, state, cfg)
    host = adamw.apply_updates_leafwise(params, grads, state, cfg)
    for a, b in zip(jax.tree.leaves(ref), jax.tree.leaves(host)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_host_policy_selects_adamw_host_variant(train_setup):
    t = train_setup
    ldg = Ledger("host_variant")
    regions = S.make_train_regions(t["cfg"], t["opt_cfg"], ledger=ldg)
    assert "host" in regions.adamw_update.variants
    ex = Executor(HostPolicy(selector=TargetSelector()), ldg)
    prog = S.capture_train_program(regions, t["state"], t["batch"])
    prog.replay(ex, t["state"], t["batch"])
    counts = ex.report()["impl_counts"]
    # FWD_BWD has no host variant -> declare-variant fallback to ref;
    # ADAMW_UPDATE runs its registered host implementation
    assert counts == {"ref": 1, "host": 1}
    assert ldg.regions["ADAMW_UPDATE"].impl == "host"


def test_optimizer_offload_is_a_placement_hint(train_setup):
    t = train_setup
    host = preferred_host_space()
    if host is None:
        pytest.skip("no host memory space on this platform")
    regions = S.make_train_regions(t["cfg"], t["opt_cfg"],
                                   ledger=Ledger("hint"),
                                   offload_optimizer=True)
    assert regions.adamw_update.arg_spaces == {"opt_state": host}
    # keyed result hint: only opt_state (element 1) re-homes host-side, so
    # moments stay host-resident BETWEEN steps without dragging params along
    assert regions.adamw_update.result_space == {1: host}
    out = Placer().place_result(
        regions.adamw_update,
        (jnp.ones(3), {"m": jnp.ones(4)}, jnp.float32(0.5)))
    assert isinstance(out, tuple) and len(out) == 3
    np.testing.assert_array_equal(np.asarray(out[1]["m"]), 1.0)
    plain = S.make_train_regions(t["cfg"], t["opt_cfg"],
                                 ledger=Ledger("nohint"))
    assert plain.adamw_update.arg_spaces is None
    assert plain.adamw_update.result_space is None


# ---------------------------------------------------------------------------
# supervisor re-capture + checkpoint coverage snapshot
# ---------------------------------------------------------------------------

def test_supervisor_recapture_keeps_ledger_rows(tmp_path):
    from repro.checkpoint.ckpt import Checkpointer
    from repro.runtime.fault import FaultInjector, TrainSupervisor

    ldg = Ledger("sup")

    @region("STEP", ledger=ldg)
    def step_region(x):
        return x * 0.9

    ex = Executor(UnifiedPolicy(), ldg)
    captures = []

    def make_step(state):
        prog = capture(lambda run, s: run(step_region, s), state)
        captures.append(prog)
        return lambda s, batch: (prog.replay(ex, s),
                                 {"loss": jnp.sum(jnp.abs(s))})

    state0 = jnp.ones(32)
    ckpt = Checkpointer(str(tmp_path), keep=3, async_save=False)
    sup = TrainSupervisor(make_step(state0), lambda step: None, ckpt,
                          ckpt_every=2, fault=FaultInjector({3}),
                          rebuild_step=lambda st, step: make_step(st),
                          report_fn=ex.report)
    state, rep = sup.run(state0, 0, 6)
    assert rep.restarts == 1
    assert len(captures) == 2                 # initial + post-restore
    # the re-capture reused the SAME region: one ledger row, no STEP#2
    assert set(ldg.regions) == {"STEP"}
    assert ldg.regions["STEP"].calls >= 6
    # every committed checkpoint carries the coverage snapshot
    steps = ckpt.all_steps()
    assert steps
    for s in steps:
        cov = tmp_path / f"step_{s:010d}" / "coverage.json"
        assert cov.exists()
    snap = json.loads(cov.read_text())
    assert snap["regions"] == 1 and snap["mode"] == "unified"
    np.testing.assert_allclose(np.asarray(state),
                               np.asarray(state0) * 0.9 ** 6, rtol=1e-6)


def test_checkpoint_save_without_report_has_no_coverage_file(tmp_path):
    from repro.checkpoint.ckpt import Checkpointer
    ck = Checkpointer(str(tmp_path), async_save=False)
    ck.save(1, {"w": jnp.ones(4)}, extra={"step": 1})
    d = tmp_path / "step_0000000001"
    assert (d / "manifest.json").exists()
    assert not (d / "coverage.json").exists()


# ---------------------------------------------------------------------------
# driver acceptance: --policy/--report emit the canonical report
# ---------------------------------------------------------------------------

def _json_tail(out: str) -> dict:
    return json.loads(out[out.index("\n{") + 1:])


def test_serve_main_report_emits_coverage(capsys):
    from repro.launch.serve import main
    seq = main(["--arch", "tinyllama-1.1b", "--reduced", "--batch", "2",
                "--prompt-len", "8", "--gen", "4", "--report"])
    assert seq.shape == (2, 4)
    rep = _json_tail(capsys.readouterr().out)
    assert rep["mode"] == "unified"
    assert sum(rep["impl_counts"].values()) > 0
    assert 0 < rep["device_fraction"] <= 1


def test_train_main_report_emits_coverage(capsys):
    from repro.launch.train import main
    losses = main(["--arch", "tinyllama-1.1b", "--reduced", "--steps", "2",
                   "--batch", "2", "--seq", "16", "--report"])
    assert np.isfinite(losses).all()
    rep = _json_tail(capsys.readouterr().out)
    assert rep["mode"] == "unified"
    assert rep["impl_counts"].get("ref", 0) == 4      # 2 regions x 2 steps
    assert rep["device_fraction"] > 0


# ---------------------------------------------------------------------------
# KVCachePlacer edge cases: the min_bytes boundary, role misses, idempotence
# ---------------------------------------------------------------------------

def _recording_place(monkeypatch):
    """Record which leaves tree_place actually offers to umem.place — the
    size gate lives inside tree_place, so this sees its decisions."""
    import repro.core.umem as U
    offered = []

    def rec(x, space, device=None):
        offered.append(x)
        return x

    monkeypatch.setattr(U, "place", rec)
    return offered


def test_kv_placer_leaf_exactly_at_min_bytes_moves(monkeypatch):
    """The threshold is `nbytes < min_bytes stays`: a leaf exactly AT the
    boundary crosses (the paper's 'pool above 5K elements' cut applied to
    placement is inclusive on the budget side)."""
    host = preferred_host_space()
    if host is None:
        pytest.skip("no host memory space on this platform")
    offered = _recording_place(monkeypatch)
    at = jnp.ones((8,), jnp.float32)            # 32 bytes == min_bytes
    below = jnp.ones((7,), jnp.float32)         # 28 bytes  < min_bytes
    cache = {"k": at, "v": below, "pos": jnp.ones((64,), jnp.int32)}
    out = SV.place_kv_leaves(cache, host, min_bytes=32)
    assert len(offered) == 1 and offered[0] is at
    assert out["v"] is below                    # skipped leaf: same object
    assert out["pos"] is cache["pos"]           # non-kv role: never offered


def test_kv_placer_no_kv_leaves_is_identity(monkeypatch):
    """A tree with no k/v-keyed leaves comes back leaf-identical — the
    role keying never touches (or copies) bystander state."""
    host = preferred_host_space()
    if host is None:
        pytest.skip("no host memory space on this platform")
    offered = _recording_place(monkeypatch)
    tree = {"x_cm": jnp.ones((4, 64)), "pos": jnp.ones((16,), jnp.int32),
            "nested": {"state": jnp.zeros((2, 8))}}
    out = SV.place_kv_leaves(tree, host, min_bytes=0)
    assert not offered
    for a, b in zip(jax.tree.leaves(out), jax.tree.leaves(tree)):
        assert a is b


def test_kv_placer_idempotent_when_already_in_host_space():
    """Placing twice is placing once: the second pass is a memory-kind
    no-op and values never change (place never rewrites data)."""
    from repro.core.umem import space_of
    host = preferred_host_space()
    if host is None:
        pytest.skip("no host memory space on this platform")
    cache = {"k": jnp.arange(64, dtype=jnp.float32).reshape(4, 16),
             "v": jnp.ones((4, 16)), "pos": jnp.ones((16,), jnp.int32)}
    once = SV.place_kv_leaves(cache, host, min_bytes=0)
    twice = SV.place_kv_leaves(once, host, min_bytes=0)
    assert space_of(twice["k"]) == host.kind
    assert space_of(twice["v"]) == host.kind
    for a, b in zip(jax.tree.leaves(twice), jax.tree.leaves(cache)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
