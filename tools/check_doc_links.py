#!/usr/bin/env python
"""Doc-link check: fail on references to documentation files that don't
exist in-repo.

Scans Python sources (docstrings/comments) and the curated documentation
set for ``*.md`` references and verifies each target exists, resolved
against the repo root or the referencing file's directory.  Historical /
externally-generated files (CHANGES.md, ISSUE.md, PAPER*.md, SNIPPETS.md,
ROADMAP.md) are exempt — they quote other repos and past states.

  python tools/check_doc_links.py        # exit 1 on any dangling reference
"""
from __future__ import annotations

import re
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parent.parent

# files whose .md mentions are not promises about THIS repo's tree
EXEMPT = {"CHANGES.md", "ISSUE.md", "PAPER.md", "PAPERS.md", "SNIPPETS.md",
          "ROADMAP.md"}
SKIP_DIRS = {".git", ".github", "artifacts", "__pycache__", ".pytest_cache"}

MD_REF = re.compile(r"[A-Za-z0-9_][A-Za-z0-9_./-]*\.md\b")


def scanned_files():
    for path in sorted(ROOT.rglob("*")):
        if any(part in SKIP_DIRS for part in path.parts):
            continue
        if path.suffix == ".py" or (path.suffix == ".md"
                                    and path.name not in EXEMPT):
            yield path


def check() -> int:
    dangling = []
    for path in scanned_files():
        text = path.read_text(errors="replace")
        for lineno, line in enumerate(text.splitlines(), 1):
            for ref in MD_REF.findall(line):
                if "http://" in line or "https://" in line:
                    continue
                if (ROOT / ref).exists() or (path.parent / ref).exists():
                    continue
                dangling.append((path.relative_to(ROOT), lineno, ref))
    for rel, lineno, ref in dangling:
        print(f"{rel}:{lineno}: dangling doc reference: {ref}")
    if dangling:
        print(f"\n{len(dangling)} dangling doc reference(s).")
        return 1
    print("doc links ok")
    return 0


if __name__ == "__main__":
    sys.exit(check())
