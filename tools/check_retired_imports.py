#!/usr/bin/env python
"""Retired-shim import gate.

``repro.core.dispatch`` and ``repro.core.executors`` are retired
deprecation-alias stubs for *external* pre-regions callers only: nothing
inside this repo may import or reference them.  This gate greps every
Python source (src, tests, benchmarks, examples, tools) for the retired
module paths and fails if any file other than the two stubs themselves
mentions them — the regions API is the only offload path in the repo.

  python tools/check_retired_imports.py      # exit 1 on any violation
"""
from __future__ import annotations

import re
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parent.parent

#: the retired module paths — dotted/slashed spellings ("repro.core.dispatch",
#: "repro/core/executors") AND the from-import spelling
#: ("from repro.core import dispatch, executors as e")
RETIRED = re.compile(
    r"repro[./]core[./](dispatch|executors)\b"
    r"|from\s+repro\.core\s+import\s[^#\n]*\b(dispatch|executors)\b")

#: the alias stubs themselves, plus this gate
ALLOWED = {
    Path("src/repro/core/dispatch.py"),
    Path("src/repro/core/executors.py"),
    Path("tools/check_retired_imports.py"),
}

SCAN_DIRS = ("src", "tests", "benchmarks", "examples", "tools")


def check() -> int:
    violations = []
    for top in SCAN_DIRS:
        for path in sorted((ROOT / top).rglob("*.py")):
            rel = path.relative_to(ROOT)
            if rel in ALLOWED or "__pycache__" in path.parts:
                continue
            for lineno, line in enumerate(
                    path.read_text(errors="replace").splitlines(), 1):
                if RETIRED.search(line):
                    violations.append((rel, lineno, line.strip()))
    for rel, lineno, line in violations:
        print(f"{rel}:{lineno}: retired module reference: {line}")
    if violations:
        print(f"\n{len(violations)} reference(s) to retired shim modules; "
              "use repro.core.regions (see ARCHITECTURE.md migration notes).")
        return 1
    print("retired-shim imports ok")
    return 0


if __name__ == "__main__":
    sys.exit(check())
