#!/usr/bin/env python
"""Retired-shim / CLI-only import gate.

Two classes of names nothing in this repo may import:

* ``repro.core.dispatch`` and ``repro.core.executors`` — retired
  deprecation-alias stubs for *external* pre-regions callers only; the
  regions API is the only offload path in the repo.
* ``replay_batch_demo`` — the heavy-traffic CLI demo inside
  ``repro.launch.serve``.  It is a driver endpoint, not a library:
  library code wanting batched decode uses ``RegionProgram.replay_batch``
  directly, and the continuous-batching path is ``repro.serve``
  (docs/SERVING.md).

This gate greps every Python source (src, tests, benchmarks, examples,
tools) and fails on any reference outside each rule's allow-list.

  python tools/check_retired_imports.py      # exit 1 on any violation
"""
from __future__ import annotations

import re
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parent.parent

#: (pattern, allowed files, label, remedy) — allowed covers the
#: definitions themselves plus this gate
RULES = (
    (
        # dotted/slashed spellings ("repro.core.dispatch",
        # "repro/core/executors") AND the from-import spelling
        # ("from repro.core import dispatch, executors as e")
        re.compile(
            r"repro[./]core[./](dispatch|executors)\b"
            r"|from\s+repro\.core\s+import\s[^#\n]*\b(dispatch|executors)\b"),
        {
            Path("src/repro/core/dispatch.py"),
            Path("src/repro/core/executors.py"),
            Path("tools/check_retired_imports.py"),
        },
        "retired module reference",
        "use repro.core.regions (see ARCHITECTURE.md migration notes).",
    ),
    (
        re.compile(r"\breplay_batch_demo\b"),
        {
            Path("src/repro/launch/serve.py"),
            Path("tools/check_retired_imports.py"),
        },
        "CLI-only demo reference",
        "replay_batch_demo is a launch/serve.py driver endpoint; use "
        "RegionProgram.replay_batch or repro.serve (docs/SERVING.md).",
    ),
    (
        # the v0 per-function decorator; frozen for its existing callers
        # (ledger.py defines it, core/__init__ re-exports it, test_core.py
        # pins its behavior) but closed to NEW importers — new offload
        # surfaces are Regions, which the static verifier can lint
        re.compile(r"\boffload_region\b"),
        {
            Path("src/repro/core/ledger.py"),
            Path("src/repro/core/__init__.py"),
            Path("src/repro/core/regions.py"),
            Path("tests/test_core.py"),
            Path("tools/check_retired_imports.py"),
        },
        "legacy offload_region reference",
        "offload_region is frozen; declare a repro.core.regions.Region "
        "(capturable + verifiable by repro.analysis, docs/ANALYSIS.md).",
    ),
)

SCAN_DIRS = ("src", "tests", "benchmarks", "examples", "tools")


def check() -> int:
    failed = False
    for pattern, allowed, label, remedy in RULES:
        violations = []
        for top in SCAN_DIRS:
            for path in sorted((ROOT / top).rglob("*.py")):
                rel = path.relative_to(ROOT)
                if rel in allowed or "__pycache__" in path.parts:
                    continue
                for lineno, line in enumerate(
                        path.read_text(errors="replace").splitlines(), 1):
                    if pattern.search(line):
                        violations.append((rel, lineno, line.strip()))
        for rel, lineno, line in violations:
            print(f"{rel}:{lineno}: {label}: {line}")
        if violations:
            print(f"\n{len(violations)} {label}(s); {remedy}")
            failed = True
    if failed:
        return 1
    print("retired-shim / CLI-only imports ok")
    return 0


if __name__ == "__main__":
    sys.exit(check())
