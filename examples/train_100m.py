"""End-to-end driver: train a ~100M-param llama-family model for a few
hundred steps with checkpointing + fault-tolerant supervision.

    PYTHONPATH=src python examples/train_100m.py --steps 300
"""
import argparse
import dataclasses

from repro.configs.base import ModelConfig

CFG_100M = ModelConfig(
    name="llama-100m", family="dense",
    n_layers=12, d_model=768, n_heads=12, n_kv_heads=4, head_dim=64,
    d_ff=2048, vocab=32000, tie_embeddings=True,
)

if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_100m_ckpt")
    args = ap.parse_args()
    print(f"[100m] params ~= {CFG_100M.n_params/1e6:.0f}M")

    # route through the standard trainer by registering the config inline
    import repro.configs.registry as REG
    REG._cache["llama_100m"] = CFG_100M
    REG.ARCH_IDS = tuple(REG.ARCH_IDS) + ("llama_100m",)
    from repro.launch.train import main
    main(["--arch", "llama_100m", "--steps", str(args.steps),
          "--batch", str(args.batch), "--seq", str(args.seq),
          "--ckpt-dir", args.ckpt_dir, "--ckpt-every", "50", "--resume",
          "--lr", "3e-4"])
