"""Batched serving example: prefill + greedy decode on the gemma3 family,
on the region-program spine — the second run offloads the KV cache to host
memory by policy (a role-keyed Placer, unified address space) and prints
the canonical coverage_report() (--report).

    PYTHONPATH=src python examples/serve_decode.py
"""
from repro.launch.serve import main

if __name__ == "__main__":
    main(["--arch", "gemma3-1b", "--reduced", "--batch", "4",
          "--prompt-len", "32", "--gen", "32"])
    main(["--arch", "recurrentgemma-9b", "--reduced", "--batch", "4",
          "--prompt-len", "32", "--gen", "32", "--offload-kv", "--report"])
