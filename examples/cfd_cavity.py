"""The paper's case study: simpleFoam on a lid-driven cavity, executed by
all three memory models (host / discrete-managed / unified) plus the
beyond-paper adaptive policy, with the coverage + migration report
(paper Figs 4-6).

    PYTHONPATH=src python examples/cfd_cavity.py [--grid 20]
"""
import argparse

from repro.cfd.grid import Grid
from repro.cfd.simple import SimpleConfig, SimpleFoam, init_state
from repro.core.regions import (AdaptivePolicy, DiscretePolicy, Executor,
                                HostPolicy, UnifiedPolicy)

if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--grid", type=int, default=16)
    ap.add_argument("--steps", type=int, default=4)
    args = ap.parse_args()
    cfg = SimpleConfig(grid=Grid((args.grid,) * 3), nu=0.1, inner_max=25)
    foms = {}
    policies = (("host", HostPolicy()), ("discrete", DiscretePolicy()),
                ("unified", UnifiedPolicy()),
                ("adaptive", AdaptivePolicy(cutoff=1024)))
    for name, policy in policies:
        app = SimpleFoam(cfg, executor=Executor(policy))
        st = init_state(cfg)
        st, _, _ = app.run_steps(st, 1)          # warm compile caches
        app.ledger.reset_timings()
        st, fom, m = app.run_steps(st, args.steps)
        foms[name] = fom
        rep = app.ex.report()
        print(f"[{name:8s}] FOM {fom:.4f} s/step  "
              f"staging {rep['staging_fraction']*100:5.1f}%  "
              f"offloaded regions {rep['offloaded_regions']}/{rep['regions']}  "
              f"routing {rep['device_calls']}dev/{rep['host_calls']}host  "
              f"res_u {m['res_u']:.2e}")
    print(f"\nunified speedup vs discrete-managed: "
          f"x{foms['discrete']/foms['unified']:.2f}  (paper Fig 5: 4-5x)")
