"""Quickstart: train a small LM with the unified-memory policy in ~a minute.

    PYTHONPATH=src python examples/quickstart.py
"""
from repro.launch.train import main

if __name__ == "__main__":
    # reduced tinyllama, AdamW moments placed in pinned_host (paper C1)
    main(["--arch", "tinyllama-1.1b", "--reduced", "--steps", "30",
          "--batch", "8", "--seq", "64", "--lr", "1e-3",
          "--offload-optimizer"])
