"""Benchmark harness — one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows (and mirrors them to a CSV
file, ``--out``). The CPU container cannot reproduce the paper's absolute
hardware numbers (4x vs H100 etc.); each benchmark reproduces the *claim
structure* on real measured work (see docs/DESIGN.md §8) — unified vs
discrete-managed vs host on identical region programs, migration fractions
and their async-overlap mitigation, offload coverage, pooling and cutoff
calibration — plus the roofline report over the dry-run artifacts.

  python benchmarks/run.py                      # everything
  python benchmarks/run.py --only fig6b_overlap,pool --out artifacts/bench.csv
"""
from __future__ import annotations

import argparse
import json
import os
import warnings

warnings.filterwarnings("ignore")
import time
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

ROWS = []


def row(name: str, us_per_call: float, derived: str = ""):
    ROWS.append((name, us_per_call, derived))
    print(f"{name},{us_per_call:.1f},{derived}", flush=True)


# ---------------------------------------------------------------------------
def fig5_speedup(steps: int = 3, grid=(16, 16, 16)):
    """Paper Fig 5: FOM (s/time-step) per execution policy, normalized.

    ``adaptive`` is the beyond-paper mode the regions API enables: the
    TARGET_CUT_OFF clause running *inside* an executor, with its host/device
    routing counts in the same coverage report as the staging fractions."""
    from repro.cfd.grid import Grid
    from repro.cfd.simple import SimpleConfig, SimpleFoam, init_state
    from repro.core.regions import (AdaptivePolicy, DiscretePolicy, Executor,
                                    HostPolicy, UnifiedPolicy)
    cfg = SimpleConfig(grid=Grid(grid), nu=0.1, inner_max=15)
    fom = {}
    policies = (("host", HostPolicy), ("discrete", DiscretePolicy),
                ("unified", UnifiedPolicy),
                ("adaptive", lambda: AdaptivePolicy(cutoff=1024)))
    for name, make in policies:
        app = SimpleFoam(cfg, executor=Executor(make()))
        st = init_state(cfg)
        st, _, _ = app.run_steps(st, 1)      # warm caches
        app.ledger.reset_timings()
        _, f, _ = app.run_steps(st, steps)
        fom[name] = f
        rep = app.ex.report()
        row(f"fig5/{name}_fom", f * 1e6,
            f"s_per_step={f:.4f};host_calls={rep['host_calls']}"
            f";device_calls={rep['device_calls']}")
    for name in ("host", "discrete"):
        row(f"fig5/speedup_unified_vs_{name}", 0.0,
            f"x{fom[name] / fom['unified']:.2f}")
    return fom


def fig6_migration(steps: int = 2, grid=(16, 16, 16)):
    """Paper Fig 6: fraction of step time in staging (page migration)."""
    from repro.cfd.grid import Grid
    from repro.cfd.simple import SimpleConfig, SimpleFoam, init_state
    from repro.core.regions import DiscretePolicy, Executor, UnifiedPolicy
    cfg = SimpleConfig(grid=Grid(grid), nu=0.1, inner_max=15)
    for name, cls in (("discrete", DiscretePolicy),
                      ("unified", UnifiedPolicy)):
        app = SimpleFoam(cfg, executor=Executor(cls()))
        st = init_state(cfg)
        st, _, _ = app.run_steps(st, 1)
        app.ledger.reset_timings()
        app.run_steps(st, steps)
        rep = app.ex.report()
        row(f"fig6/{name}_staging", rep["staging_s"] * 1e6 / max(steps, 1),
            f"fraction={rep['staging_fraction']:.3f}")


def fig6b_overlap(steps: int = 2, grid=(16, 16, 16)):
    """Beyond-paper Fig 6b: the discrete staging storm with one-step
    lookahead (repro.core.program).  One SIMPLE step is captured as a
    RegionProgram and replayed under DiscretePolicy twice — synchronously
    (Executor) and with double-buffered prefetch (AsyncExecutor).  The two
    replays must agree bit-for-bit; the async one reports how much of the
    migration storm was hidden behind compute.  On a CPU-only container the
    prefetch thread and "device" compute share the same cores, so the FOM
    here is overlap_fraction / staging_saved_s, not wall-clock — the
    wall-clock win needs a real copy engine."""
    from repro.cfd.grid import Grid
    from repro.cfd.simple import SimpleConfig, SimpleFoam, init_state
    from repro.core.program import AsyncExecutor
    from repro.core.regions import DiscretePolicy, Executor
    cfg = SimpleConfig(grid=Grid(grid), nu=0.1, inner_max=15)
    app = SimpleFoam(cfg)
    st = init_state(cfg)
    st, _, _ = app.run_steps(st, 1)              # develop flow + warm caches
    prog = app.capture_step(st)
    sync = Executor(DiscretePolicy())
    asyn = AsyncExecutor(DiscretePolicy())
    app.replay_steps(prog, st, 1, sync)          # warm per-target caches
    app.replay_steps(prog, st, 1, asyn)
    sync.ledger.reset_timings()
    asyn.ledger.reset_timings()
    s_sync, f_sync = app.replay_steps(prog, st, steps, sync)
    s_asyn, f_asyn = app.replay_steps(prog, st, steps, asyn)
    for a, b in zip((s_sync.u, s_sync.v, s_sync.w, s_sync.p),
                    (s_asyn.u, s_asyn.v, s_asyn.w, s_asyn.p)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    rep = asyn.report()
    row("fig6b/sync_replay_fom", f_sync * 1e6,
        f"staging_fraction={sync.report()['staging_fraction']:.3f}")
    row("fig6b/async_replay_fom", f_asyn * 1e6,
        f"overlap_fraction={rep['overlap_fraction']:.3f}"
        f";staging_saved_s={rep['staging_saved_s']:.4f}"
        f";speedup=x{f_sync / max(f_asyn, 1e-12):.2f}")
    assert rep["overlap_fraction"] > 0, rep      # acceptance criterion
    return rep


def _scaling_mesh_shape(n: int) -> tuple:
    """Mesh shape for an n-APU node: the shared near-square 2-D
    factorization (``repro.launch.mesh.near_square_mesh_shape`` — also
    the autotuner's mesh-shape axis) to cut surface-to-volume.
    FIG_SCALING_MESH=1d forces the 1-D baseline."""
    import os

    from repro.launch.mesh import near_square_mesh_shape
    if os.environ.get("FIG_SCALING_MESH", "auto") == "1d":
        return (n,)
    return near_square_mesh_shape(n)


def fig_scaling(steps: int = 2, grid="8,8,8", policy="unified"):
    """Beyond-paper scaling figure: the captured SIMPLE step replayed
    domain-decomposed over 1/2/4/8 simulated APUs
    (repro.core.shard_program + repro.launch.scaling), strong- AND
    weak-scaling, under the overlapped wide-halo exchange schedule.

    Each node size runs in a fresh subprocess — the APU count must be in
    XLA_FLAGS before the first jax import, and this process has already
    imported jax with one device.  Every run asserts single- vs
    multi-device numerical parity (docs/DESIGN.md §2 tolerance) and the
    derived column carries the node-level compute/staging/exchange/overlap
    split from the aggregated per-device ledgers.  On a CPU container all
    "APUs" share the same cores, so the FOM here is the exchange
    accounting and the parity guarantee, not wall-clock speedup (see
    docs/SCALING.md).

    Regression gate (CI): every multi-APU run must keep its EXPOSED
    exchange fraction under the pinned budget and, under the overlapped
    schedule, must actually hide exchange time (``overlap_s > 0``) — the
    halo-exchange-tax fix is locked in here.  Knobs: FIG_SCALING_APUS=1,2
    FIG_SCALING_GRID=16,16,16 FIG_SCALING_SCHEDULE=overlap|sequential|split
    FIG_SCALING_HALO=2 FIG_SCALING_MESH=auto|1d FIG_SCALING_BUDGET=0.15."""
    import os
    import subprocess
    import sys
    apus = [int(x) for x in
            os.environ.get("FIG_SCALING_APUS", "1,2,4,8").split(",") if x]
    grid = os.environ.get("FIG_SCALING_GRID", grid)
    schedule = os.environ.get("FIG_SCALING_SCHEDULE", "overlap")
    halo_mult = os.environ.get("FIG_SCALING_HALO", "2")
    budget = float(os.environ.get("FIG_SCALING_BUDGET", "0.15"))
    base_grid = tuple(int(g) for g in grid.split(","))

    def run_one(n, grid_t, out_name, row_name, base):
        mesh_shape = _scaling_mesh_shape(n)
        out = Path(f"artifacts/scaling/{out_name}.json")
        out.parent.mkdir(parents=True, exist_ok=True)
        cmd = [sys.executable, "-m", "repro.launch.scaling",
               "--apus", str(n), "--mesh",
               "x".join(str(s) for s in mesh_shape),
               "--steps", str(steps),
               "--grid", ",".join(str(g) for g in grid_t),
               "--policy", policy, "--schedule", schedule,
               "--halo-multiplier", halo_mult,
               "--inner-max", "6", "--out", str(out)]
        r = subprocess.run(cmd, capture_output=True, text=True)
        if r.returncode != 0:
            row(row_name, 0.0,
                f"FAILED rc={r.returncode}:{r.stderr.strip()[-160:]}")
            raise RuntimeError(f"fig_scaling subprocess failed for "
                               f"{n} APUs:\n{r.stderr[-2000:]}")
        rec = json.loads(out.read_text())
        assert rec["parity_ok"], rec          # acceptance criterion
        rep = rec["report"]
        dev0 = rep["per_device"][0]
        row(row_name, rec["fom_sharded_s"] * 1e6,
            f"parity_max_err={rec['parity_max_abs_err']:.2e}"
            f";mesh={'x'.join(str(s) for s in rec['mesh_shape'])}"
            f";compute_s={rep['compute_s']:.4f}"
            f";staging_s={rep['staging_s']:.4f}"
            f";exchange_s={rep['exchange_s']:.4f}"
            f";overlap_s={rep['overlap_s']:.4f}"
            f";exchange_fraction={rep['exchange_fraction']:.3f}"
            f";exchange_bytes={rep['exchange_bytes']}"
            f";dev0_compute_s={dev0['compute_s']:.4f}"
            f";dev0_exchange_s={dev0['exchange_s']:.4f}"
            f";vs_base=x{rec['fom_sharded_s'] / max(base or rec['fom_sharded_s'], 1e-12):.2f}")
        if n > 1:
            # the regression gate: exposed exchange stays under the pinned
            # budget, and the overlapped schedule actually hides time
            assert rep["exchange_fraction"] <= budget, (
                f"exchange_fraction {rep['exchange_fraction']:.3f} over "
                f"budget {budget} for {n} APUs ({row_name})")
            if schedule != "sequential":
                assert rep["overlap_s"] > 0.0, (
                    f"no exchange overlap recorded for {n} APUs "
                    f"({row_name}): {rep['overlap_s']}")
        return rec

    # strong scaling: fixed grid, growing node
    base = None
    for n in apus:
        rec = run_one(n, base_grid, f"apu{n}", f"fig_scaling/apus{n}", base)
        if base is None:
            base = rec["fom_sharded_s"]

    # weak scaling: constant cells/APU — the decomposed dims grow with
    # their mesh axes, so exchange surface per APU stays fixed while node
    # volume grows (the JSONs land next to the strong-scaling artifacts)
    wbase = None
    for n in apus:
        mesh_shape = _scaling_mesh_shape(n)
        wgrid = list(base_grid)
        for dim, s in zip(range(-len(mesh_shape), 0), mesh_shape):
            wgrid[dim] *= s
        rec = run_one(n, tuple(wgrid), f"weak_apu{n}",
                      f"fig_scaling/weak_apus{n}", wbase)
        if wbase is None:
            wbase = rec["fom_sharded_s"]
    return apus


def fig_variants(steps: int = 2, grid=(12, 12, 12),
                 out_json="artifacts/variants/autotune_winners.json"):
    """Beyond-paper variants figure: the captured SIMPLE step replayed
    under StaticSelector('ref'), StaticSelector('pallas'), and a
    calibrated AutotuneSelector, per policy (repro.core.regions Selector
    axis — the 'which implementation' half of the paper's one-directive
    claim).  Asserts DESIGN §2 parity across selectors, prints the
    impl_counts proving which variant ran where, and writes the autotune
    winners JSON next to the CSV.  On a CPU container the Pallas kernels
    run in interpret mode, so the FOM here is the dispatch/accounting
    structure and the measured per-cell winners, not kernel wall-clock.
    Calibration grid edges override via FIG_VARIANTS_SIZES=8,12."""
    import os
    from repro.cfd import fvm
    from repro.cfd.grid import Grid
    from repro.cfd.simple import SimpleConfig, SimpleFoam, init_state
    from repro.core.regions import (AutotuneSelector, Executor,
                                    StaticSelector, make_policy)
    edges = [int(x) for x in
             os.environ.get("FIG_VARIANTS_SIZES", "8,12,16").split(",") if x]
    cfg = SimpleConfig(grid=Grid(grid), nu=0.1, inner_max=10)
    app = SimpleFoam(cfg)
    st = init_state(cfg)
    st, _, _ = app.run_steps(st, 1)
    prog = app.capture_step(st)

    # calibrate the solver hot-spot regions over a grid-edge ladder
    auto = AutotuneSelector()
    sizes_cells = []
    for m in edges:
        g = Grid((m, m, m))
        A, _ = fvm.laplacian(g, 1.0)
        x = jnp.ones(g.shape, jnp.float32)
        red, _ = g.red_black_masks()
        from repro.cfd.precond import rb_dilu_factor
        P = rb_dilu_factor(A, red)
        # both routing targets: UnifiedPolicy routes offloaded regions to
        # "default", DiscretePolicy to "device" — winners are per-target
        # cells, so calibrating only one would leave the other on ref
        auto.calibrate(app.solver_regions.amul,
                       lambda n, A=A, x=x: (A.diag, A.off, x),
                       sizes=(g.n,), targets=("default", "device"), reps=3)
        auto.calibrate(app.solver_regions.precond,
                       lambda n, P=P, A=A, x=x: (P.rdiag, P.red, A.off, x),
                       sizes=(g.n,), targets=("default", "device"), reps=3)
        sizes_cells.append(g.n)
    winners = {f"{rn}|{tgt}|2^{b}": win
               for (rn, tgt, b), win in sorted(auto.winners.items())}

    selectors = (("ref", StaticSelector("ref")),
                 ("pallas", StaticSelector("pallas")),
                 ("autotuned", auto))
    base = {}
    for pol_name in ("unified", "discrete"):
        for sel_name, sel in selectors:
            pol = make_policy(pol_name)
            pol.selector = sel
            ex = Executor(pol)
            app.replay_steps(prog, st, 1, ex)          # warm compiles
            ex.ledger.reset_timings()
            s, fom = app.replay_steps(prog, st, steps, ex)
            fields = [np.asarray(f) for f in (s.u, s.v, s.w, s.p)]
            ref_fields = base.setdefault(pol_name, fields)
            scale = max(np.max(np.abs(f)) for f in ref_fields)
            err = max(np.max(np.abs(a - b))
                      for a, b in zip(fields, ref_fields))
            assert err <= 1e-5 * max(1.0, scale), \
                (pol_name, sel_name, err)              # DESIGN §2 parity
            counts = ex.report()["impl_counts"]
            # calibration persisted on the app ledger's region rows
            wins = app.ledger.coverage_report()["variant_wins"]
            row(f"fig_variants/{pol_name}_{sel_name}", fom * 1e6,
                f"impl_counts={'+'.join(f'{k}:{v}' for k, v in sorted(counts.items()))}"
                f";parity_max_err={err:.2e}"
                f";variant_wins={'+'.join(f'{k}:{v}' for k, v in sorted(wins.items()))}")
    out = Path(out_json)
    out.parent.mkdir(parents=True, exist_ok=True)
    out.write_text(json.dumps(
        {"calibration_grid_edges": edges, "calibration_sizes": sizes_cells,
         "winners": winners,
         "bucket_model": "b covers sizes in [2^(b-1), 2^b)"}, indent=1))
    print(f"[bench] wrote autotune winners to {out}", flush=True)
    return winners


def fig4_coverage(grid=(12, 12, 12)):
    """Paper Figs 2 vs 4: offload coverage, PETSc-interface mode (assembly
    on host, solver offloaded) vs full directive mode."""
    from repro.cfd.grid import Grid
    from repro.cfd.simple import SimpleConfig, SimpleFoam, init_state
    cfg = SimpleConfig(grid=Grid(grid), nu=0.1, inner_max=15)
    for name, host_asm in (("petsc_mode", True), ("directive_mode", False)):
        app = SimpleFoam(cfg, assemble_on_host=host_asm)
        st = init_state(cfg)
        st, _, _ = app.run_steps(st, 1)
        app.ledger.reset_timings()
        app.run_steps(st, 2)
        rep = app.ledger.coverage_report()
        row(f"fig4/{name}", rep["total_s"] * 1e6,
            f"device_fraction={rep['device_fraction']:.3f}"
            f";regions={rep['offloaded_regions']}/{rep['regions']}")


def fig_serve(batch: int = 2, prompt_len: int = 12, gen: int = 8,
              out_json: str = "artifacts/serve/fig_serve.json"):
    """Beyond-paper serving figure: the LM request path on the region
    spine (PREFILL / DECODE_STEP / KV_APPEND captured as RegionPrograms,
    repro.launch.serve) replayed under unified vs discrete vs
    offloaded-KV policies — ONE captured trace, three policies — with the
    per-policy coverage_report() in the derived column and every token
    sequence parity-asserted against the pre-capture jit path.  Also
    measures the decode stream with a per-token block_until_ready vs one
    sync per interval (the retired per-token sync serialized the stream)
    and records the reclaimed latency.  On a CPU-only container XLA's
    dispatch is effectively synchronous, so ``reclaimed_ms`` ~ 0 there —
    the row records the claim structure; the win needs a real async
    device stream (same caveat as fig6b's wall-clock)."""
    from types import SimpleNamespace

    from repro.configs.reduced import reduced as make_reduced
    from repro.configs.registry import get_config
    from repro.core.ledger import Ledger
    from repro.core.regions import Executor
    from repro.launch import serve as SV
    from repro.launch.mesh import make_smoke_mesh
    from repro.launch.policy import lm_policy
    from repro.models import transformer as T

    cfg = make_reduced(get_config("tinyllama-1.1b"))
    mesh = make_smoke_mesh()
    max_len = prompt_len + gen
    key = jax.random.PRNGKey(0)
    params = T.init(key, cfg)
    prompts = jax.random.randint(key, (batch, prompt_len), 0, cfg.vocab,
                                 jnp.int32)
    ns = SimpleNamespace(batch=batch, prompt_len=prompt_len, gen=gen)
    batch_in = SV._prefill_inputs(cfg, ns, prompts)

    # -- pre-capture jit path: parity reference + stream-sync measurement
    prefill_j, decode_j, make_cache = SV.build_server(cfg, mesh, batch,
                                                      max_len)
    logits, cache_w = prefill_j(params, batch_in, make_cache())
    tok0 = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
    jax.block_until_ready(
        decode_j(params, tok0, cache_w, jnp.int32(prompt_len)))  # warm
    stream_ms = {}
    seq_ref = None
    for sync_name, sync_every in (("per_token", 1), ("interval", 0)):
        best = float("inf")
        for _ in range(3):
            _, cache_s = prefill_j(params, batch_in, make_cache())
            t0 = time.perf_counter()
            toks_s, _ = SV.decode_stream(decode_j, params, tok0, cache_s,
                                         prompt_len, gen,
                                         sync_every=sync_every)
            best = min(best, time.perf_counter() - t0)
        stream_ms[sync_name] = best * 1e3
        seq_ref = np.asarray(jnp.stack(toks_s, axis=1))
    reclaimed = stream_ms["per_token"] - stream_ms["interval"]
    row("fig_serve/stream_sync", stream_ms["interval"] * 1e3 / gen,
        f"per_token_ms={stream_ms['per_token']:.2f}"
        f";interval_ms={stream_ms['interval']:.2f}"
        f";reclaimed_ms={reclaimed:.2f}")

    # -- the serving spine: capture ONCE, replay under every policy ------
    regions = SV.make_serve_regions(cfg, mesh, params,
                                    ledger=Ledger("serve_bench"))
    prefill_prog = SV.capture_prefill_program(
        regions, batch_in, T.init_cache(cfg, batch, max_len))
    tok_ex, cache_ex = prefill_prog.replay(
        Executor(lm_policy("unified", cfg.memory), Ledger("warm")),
        batch_in, T.init_cache(cfg, batch, max_len))
    decode_prog = SV.capture_decode_program(regions, prompt_len, gen,
                                            tok_ex, cache_ex)
    reports = {}
    policies = (
        ("unified", lambda: lm_policy("unified", cfg.memory)),
        ("discrete", lambda: lm_policy("discrete", cfg.memory)),
        ("offload_kv", lambda: lm_policy("unified", cfg.memory,
                                         placer=SV.offload_kv_cache())),
    )
    for name, make_pol in policies:
        ex = Executor(make_pol(), Ledger(f"serve_{name}"))
        tok, cache = prefill_prog.replay(ex, batch_in,
                                         T.init_cache(cfg, batch, max_len))
        decode_prog.replay(ex, tok, cache)          # warm per-target caches
        ex.ledger.reset_timings()
        t0 = time.perf_counter()
        toks = decode_prog.replay(ex, tok, cache)
        t_decode = time.perf_counter() - t0
        seq = np.asarray(jnp.stack(toks, axis=1))
        # capture changes the schedule, never the tokens: every policy's
        # sequence must match the pre-capture jit path bit-for-bit
        np.testing.assert_array_equal(seq, seq_ref, err_msg=name)
        rep = ex.report()
        reports[name] = rep
        row(f"fig_serve/{name}", t_decode * 1e6 / gen,
            f"device_fraction={rep['device_fraction']:.3f}"
            f";staging_fraction={rep['staging_fraction']:.3f}"
            f";impl_counts={'+'.join(f'{k}:{v}' for k, v in sorted(rep['impl_counts'].items()))}"
            f";parity=exact")
    out = Path(out_json)
    out.parent.mkdir(parents=True, exist_ok=True)
    out.write_text(json.dumps(
        {"batch": batch, "prompt_len": prompt_len, "gen": gen,
         "stream_ms": stream_ms, "reclaimed_ms": reclaimed,
         "reports": reports}, indent=1, default=str))
    print(f"[bench] wrote serve reports to {out}", flush=True)
    return reports


def fig_traffic(requests: int = 8, slots: int = 4, rate: float = 2.0,
                out_json: str = "artifacts/traffic/fig_traffic.json"):
    """Continuous-batching traffic figure (docs/SERVING.md).

    Seeded Poisson arrivals with ragged prompt/gen lengths pushed through
    the ``ServeEngine`` (slot scheduler + paged KV over the region spine)
    under unified / discrete / offloaded-KV policies, against two
    references on the SAME traffic:

    * ``sequential``: the engine with one slot — solo decodes in arrival
      order through the identical spine; the continuous-batching win is
      engine tokens/s strictly above this (asserted);
    * the solo jit path (``build_server`` + ``decode_stream``): the
      bit-parity oracle — every engine token sequence must match it
      exactly, under every policy (asserted).

    A final run caps the device page budget below one parked prefill so
    the paged store spills to host DRAM mid-traffic: the artifact records
    pages spilled/fetched and the device high-water, and parity must
    survive the crossing (the paper's oversubscription story applied to
    serving)."""
    from repro.configs.reduced import reduced as make_reduced
    from repro.configs.registry import get_config
    from repro.core.ledger import Ledger
    from repro.core.regions import Executor
    from repro.launch import serve as SV
    from repro.launch.mesh import make_smoke_mesh
    from repro.launch.policy import lm_policy
    from repro.models import transformer as T
    from repro.serve import (PagedKVCache, ServeEngine, make_traffic,
                             run_traffic, solo_reference)
    from repro.serve.traffic import assert_parity

    cfg = make_reduced(get_config("tinyllama-1.1b"))
    mesh = make_smoke_mesh()
    params = T.init(jax.random.PRNGKey(0), cfg)
    max_len = 18                                 # fits 10-prompt + 8-gen

    def traffic():
        return make_traffic(seed=3, n_requests=requests, vocab=cfg.vocab,
                            arrival_rate=rate, prompt_lens=(6, 10),
                            gen_lens=(1, 5, 8))

    reqs0 = traffic()
    oracle, solo_wall = solo_reference(cfg, mesh, params, reqs0, max_len)
    n_tokens = sum(len(v) for v in oracle.values())
    solo_tps = n_tokens / max(solo_wall, 1e-9)

    def run(name, policy, n_slots, **kv_kwargs):
        ex = Executor(policy, Ledger(f"traffic_{name}"))
        kv = PagedKVCache(page_tokens=4, **kv_kwargs)
        eng = ServeEngine(cfg, mesh, params, ex, max_len=max_len,
                          n_slots=n_slots, kv=kv)
        reqs = traffic()
        metrics = run_traffic(eng, reqs)
        assert_parity(reqs, oracle)              # the invariant, per policy
        rep = ex.ledger.coverage_report()
        rec = {**metrics, "n_slots": n_slots, "kv": kv.stats.as_dict(),
               "serve": rep.get("serve", {}),
               "pools": {k: v for k, v in rep.get("pools", {}).items()}}
        row(f"fig_traffic/{name}",
            metrics["wall_s"] * 1e6 / max(metrics["tokens"], 1),
            f"tokens_per_s={metrics['tokens_per_s']:.0f}"
            f";occupancy={rep['serve'].get('slot_occupancy', 0):.2f}"
            f";evictions={metrics['evictions']}"
            f";spilled={kv.stats.pages_spilled};parity=exact")
        return rec

    results = {"sequential": run("sequential",
                                 lm_policy("unified", cfg.memory), 1)}
    for name, pol in (
            ("unified", lm_policy("unified", cfg.memory)),
            ("discrete", lm_policy("discrete", cfg.memory)),
            ("offload_kv", lm_policy("unified", cfg.memory,
                                     placer=SV.offload_kv_cache(
                                         min_bytes=0)))):
        results[name] = run(name, pol, slots)

    # the continuous-batching claim: batched slots beat sequential solo
    # decodes through the identical spine on the identical traffic
    assert results["unified"]["tokens_per_s"] > \
        results["sequential"]["tokens_per_s"], \
        (results["unified"]["tokens_per_s"],
         results["sequential"]["tokens_per_s"])

    # oversubscription: device page budget below one parked prefill
    results["spill"] = run("spill", lm_policy("unified", cfg.memory),
                           slots, device_budget_bytes=512)
    assert results["spill"]["kv"]["pages_spilled"] > 0

    out = Path(out_json)
    out.parent.mkdir(parents=True, exist_ok=True)
    out.write_text(json.dumps(
        {"requests": requests, "slots": slots, "rate": rate,
         "solo_jit_tokens_per_s": solo_tps, "runs": results},
        indent=1, default=str))
    print(f"[bench] wrote traffic figure to {out}", flush=True)
    return results


def fig_oversub(out_json: str = "artifacts/oversub/fig_oversub.json"):
    """Throughput vs oversubscription ratio — run what doesn't fit.

    Three workloads whose working sets exceed a logical device budget
    (``MemoryBudget.for_ratio(footprint, r)``, ratios from the
    ``FIG_OVERSUB_RATIOS`` env, default ``1,2,4``; ratio 1 is the
    everything-fits reference point):

    * **serve** — KV caches beyond the device budget under real seeded
      traffic: the paged store spills/evicts mid-stream, under unified /
      discrete / adaptive execution policies;
    * **moe** — host-resident expert weights (qwen3-moe structure with a
      sparse 16-expert/top-2 router) paged per token through a budgeted
      LRU working set;
    * **cfd** — a SIMPLE grid replayed under discrete and adaptive
      policies whose staging streams in budget-sized slabs.

    Gates (the paper's oversubscription claim on the logical budget):
    every budgeted run COMPLETES — degradation, never OOM — and parity
    holds against the unbudgeted reference at every ratio: serve tokens
    bitwise vs the solo jit oracle, moe outputs and cfd fields bitwise vs
    their ratio-independent references.  At ratios >= 4 the serve curve
    must actually spill (ratio 2 equals the parked-page peak for this
    traffic, so 4x is the first ratio past it).  ``REPRO_TRAFFIC_SEED``
    and ``FIG_OVERSUB_REQUESTS`` shape the traffic."""
    import dataclasses as _dc

    from repro.configs.reduced import reduced as make_reduced
    from repro.configs.registry import get_config
    from repro.core.ledger import Ledger
    from repro.core.oversub import MemoryBudget, workload_bytes
    from repro.core.regions import AdaptivePolicy, DiscretePolicy, Executor
    from repro.launch.mesh import make_smoke_mesh
    from repro.launch.policy import lm_policy
    from repro.models import moe as M
    from repro.models import transformer as T
    from repro.models.params import init_params
    from repro.serve import (PagedKVCache, ServeEngine, make_traffic,
                             run_traffic, solo_reference)
    from repro.serve.traffic import assert_parity

    ratios = [float(r) for r in os.environ.get(
        "FIG_OVERSUB_RATIOS", "1,2,4").split(",") if r]
    n_requests = int(os.environ.get("FIG_OVERSUB_REQUESTS", "6"))
    seed = int(os.environ.get("REPRO_TRAFFIC_SEED", "11"))
    results = {"ratios": ratios, "seed": seed,
               "serve": {}, "moe": [], "cfd": {}}

    # ---- (b) serving: KV caches larger than the device budget ----------
    cfg = make_reduced(get_config("tinyllama-1.1b"))
    mesh = make_smoke_mesh()
    params = T.init(jax.random.PRNGKey(0), cfg)
    max_len, slots = 16, 2

    def traffic():
        return make_traffic(seed=seed, n_requests=n_requests,
                            vocab=cfg.vocab, arrival_rate=2.0,
                            prompt_lens=(6, 10), gen_lens=(1, 5))

    oracle, _ = solo_reference(cfg, mesh, params, traffic(), max_len)
    probe = PagedKVCache(page_tokens=4)
    probe.commit(0, T.init_cache(cfg, 1, max_len), true_len=max_len)
    kv_fp = probe.total_bytes * slots
    probe.free(0)

    for mode in ("unified", "discrete", "adaptive"):
        curve = []
        for r in ratios:
            budget = MemoryBudget.for_ratio(kv_fp, r, name="kv")
            ex = Executor(lm_policy(mode, cfg.memory),
                          Ledger(f"oversub_{mode}_{r:g}"))
            kv = PagedKVCache(page_tokens=4, budget=budget)
            eng = ServeEngine(cfg, mesh, params, ex, max_len=max_len,
                              n_slots=slots, kv=kv)
            reqs = traffic()
            m = run_traffic(eng, reqs)
            assert_parity(reqs, oracle)          # completed AND bit-exact
            if r >= 4:
                assert kv.stats.pages_spilled > 0, \
                    f"ratio {r:g} should exceed the parked-page peak"
            curve.append({"ratio": r, "tokens_per_s": m["tokens_per_s"],
                          "evictions": m["evictions"],
                          "kv": kv.stats.as_dict(),
                          "budget": budget.as_dict()})
            row(f"fig_oversub/serve_{mode}_r{r:g}",
                m["wall_s"] * 1e6 / max(m["tokens"], 1),
                f"tokens_per_s={m['tokens_per_s']:.0f}"
                f";spilled={kv.stats.pages_spilled}"
                f";pressure={budget.stats.pressure_events};parity=exact")
        results["serve"][mode] = curve

    # ---- (a) MoE decode: experts paged per token through the budget ----
    mcfg = make_reduced(get_config("qwen3-moe-30b-a3b"))
    # reduced() caps MoE at 8 experts / top-8 (dense); restore a sparse
    # router so paging a partial working set is meaningful
    mcfg = _dc.replace(mcfg, moe=_dc.replace(mcfg.moe, n_experts=16,
                                             top_k=2, d_ff=32))
    p = init_params(jax.random.PRNGKey(0), M.moe_specs(mcfg))
    xs = [jax.random.normal(jax.random.PRNGKey(100 + t),
                            (1, 1, mcfg.d_model), mcfg.compute_dtype)
          for t in range(8)]

    def moe_stream(budget):
        pager = M.ExpertPager(p, mcfg, budget=budget)
        t0 = time.perf_counter()
        ys = [np.asarray(M.moe_decode_paged(pager, x, mcfg)[0])
              for x in xs]
        return pager, ys, time.perf_counter() - t0

    pager_ref, ref_ys, _ = moe_stream(None)      # warm + reference
    moe_fp = pager_ref.footprint_bytes
    for r in ratios:
        budget = MemoryBudget.for_ratio(moe_fp, r, name="moe")
        pager, ys, wall = moe_stream(budget)
        for a, b in zip(ref_ys, ys):             # paging moves bytes, not math
            np.testing.assert_array_equal(a, b)
        results["moe"].append({
            "ratio": r, "tokens_per_s": len(xs) / max(wall, 1e-9),
            "paging": pager.stats.as_dict(), "budget": budget.as_dict()})
        row(f"fig_oversub/moe_r{r:g}", wall * 1e6 / len(xs),
            f"fetches={pager.stats.fetches}"
            f";evictions={pager.stats.evictions};parity=exact")

    # ---- (c) CFD: grids beyond device capacity via budgeted staging ----
    from repro.cfd.grid import Grid
    from repro.cfd.simple import SimpleConfig, SimpleFoam, init_state
    ccfg = SimpleConfig(grid=Grid((12, 12, 12)), nu=0.1, inner_max=6)
    app = SimpleFoam(ccfg)
    st = init_state(ccfg)
    st, _, _ = app.run_steps(st, 1)
    prog = app.capture_step(st)
    cfd_fp = workload_bytes(st)
    for mode, make in (("discrete", DiscretePolicy),
                       ("adaptive", AdaptivePolicy)):
        s_ref, _ = app.replay_steps(prog, st, 2, Executor(make()))
        curve = []
        for r in ratios:
            budget = MemoryBudget.for_ratio(cfd_fp, r, name="cfd")
            s_b, fom = app.replay_steps(prog, st, 2,
                                        Executor(make(budget=budget)))
            for nm in ("u", "v", "w", "p"):
                np.testing.assert_array_equal(
                    np.asarray(getattr(s_ref, nm)),
                    np.asarray(getattr(s_b, nm)))
            curve.append({"ratio": r, "fom_s_per_step": fom,
                          "budget": budget.as_dict()})
            row(f"fig_oversub/cfd_{mode}_r{r:g}", fom * 1e6,
                f"chunks={budget.stats.staging_chunks}"
                f";pressure={budget.stats.pressure_events};parity=exact")
        results["cfd"][mode] = curve

    out = Path(out_json)
    out.parent.mkdir(parents=True, exist_ok=True)
    out.write_text(json.dumps(results, indent=1, default=str))
    print(f"[bench] wrote oversubscription figure to {out}", flush=True)
    return results


def fig_tune(out_json: str = "", bench_json: str = "BENCH_pr10.json"):
    """Global policy autotuner figure + the perf-trajectory gate.

    Runs the ``repro.tune`` search per workload (serve decode traffic,
    train step, CFD replay, sharded CFD), persists the warm-start
    profile, and reports the tuned winner's measured FOM against the
    hand-assembled reference policy each workload names (the paper's
    managed-dGPU baseline for the replay workloads, the PR-3
    sequential 1-D slab decomposition for the sharded one).  The gate
    locks the trajectory in: any tuned winner measurably worse than its
    reference beyond ``FIG_TUNE_TOL`` (or fewer than 2 strict wins
    across the suite) exits non-zero, so CI catches a cost model or
    search regression before it ships.  The canonical machine-readable
    record lands in ``BENCH_pr10.json`` at the repo root.

    Env knobs: FIG_TUNE_WORKLOADS (csv), FIG_TUNE_TRIALS,
    FIG_TUNE_STEPS, FIG_TUNE_TOL, FIG_TUNE_PROFILE, FIG_TUNE_MIN_WINS.
    """
    from repro.tune.profile import DEFAULT_PROFILE_PATH
    from repro.tune.tuner import tune_workloads
    names = [n for n in os.environ.get(
        "FIG_TUNE_WORKLOADS",
        "cfd_step,serve_decode,train_step,cfd_sharded").split(",") if n]
    trials = int(os.environ.get("FIG_TUNE_TRIALS", "2"))
    steps = int(os.environ.get("FIG_TUNE_STEPS", "0")) or None
    tol = float(os.environ.get("FIG_TUNE_TOL", "0.25"))
    min_wins = int(os.environ.get("FIG_TUNE_MIN_WINS",
                                  str(min(2, len(names)))))
    prof_path = os.environ.get("FIG_TUNE_PROFILE", DEFAULT_PROFILE_PATH)

    profile, results = tune_workloads(names, trials=trials, steps=steps,
                                      out=prof_path, gate_tol=None)
    cells, failures, wins = [], [], 0
    for res in results:
        fom, ref = res.fom_s, res.ref_fom_s
        speedup = (ref / max(fom, 1e-12)) if fom and ref else None
        strict_win = bool(fom and ref and fom < ref)
        wins += strict_win
        if fom and ref and fom > ref * (1.0 + tol):
            failures.append(f"{res.workload}: tuned {fom:.3e}s vs ref "
                            f"{ref:.3e}s exceeds tol {tol:g}")
        cells.append({
            "workload": res.workload, "bucket": res.bucket,
            "winner": res.winner.label, "candidate": res.winner.to_dict(),
            "fom_s": fom, "ref_fom_s": ref, "score_s": res.score_s,
            "speedup_vs_ref": speedup, "strict_win": strict_win,
            "disqualified": res.disqualified,
            "candidates_scored": len(res.table),
        })
        row(f"fig_tune/{res.workload}", (fom or 0.0) * 1e6,
            f"winner={res.winner.label}"
            + (f";x{speedup:.2f}_vs_ref" if speedup else "")
            + (";WIN" if strict_win else ""))
    if wins < min_wins:
        failures.append(f"only {wins} strict tuned-vs-ref wins, "
                        f"gate requires >= {min_wins}")
    gate = {"tol": tol, "min_wins": min_wins, "strict_wins": wins,
            "ok": not failures, "failures": failures}
    rec = {
        "bench": "fig_tune",
        "generated_utc": time.strftime("%Y-%m-%dT%H:%M:%SZ", time.gmtime()),
        "profile": prof_path,
        "trials": trials,
        "workloads": cells,
        "gate": gate,
    }
    for path in (bench_json, out_json):
        if path:
            p = Path(path)
            if p.parent != Path("."):
                p.parent.mkdir(parents=True, exist_ok=True)
            p.write_text(json.dumps(rec, indent=1, default=str) + "\n")
    print(f"[bench] wrote tuned-vs-ref figure to {bench_json}"
          f" (profile: {prof_path})", flush=True)
    row("fig_tune/gate", 0.0,
        f"wins={wins}/{len(names)};tol={tol:g};"
        f"{'ok' if gate['ok'] else 'FAIL'}")
    if failures:
        raise SystemExit("[fig_tune] perf-trajectory gate failed: "
                         + "; ".join(failures))
    return rec


def pool_bench(n: int = 200, shape=(1 << 20,)):
    """Umpire pooling (paper §5): alloc+touch latency, pooled vs malloc."""
    from repro.core.pool import HostStagingPool
    pool = HostStagingPool()
    a = pool.acquire(shape, np.float32)
    pool.release(a)
    t0 = time.perf_counter()
    for _ in range(n):
        b = pool.acquire(shape, np.float32)
        b[0] = 1.0
        pool.release(b)
    t_pool = (time.perf_counter() - t0) / n
    t0 = time.perf_counter()
    for _ in range(n):
        b = np.empty(shape, np.float32)
        b[0] = 1.0
        del b
    t_malloc = (time.perf_counter() - t0) / n
    row("pool/pooled_acquire", t_pool * 1e6,
        f"hit_rate={pool.stats.hit_rate:.2f}")
    row("pool/malloc_acquire", t_malloc * 1e6,
        f"speedup=x{t_malloc / max(t_pool, 1e-12):.2f}")


def dispatch_bench():
    """TARGET_CUT_OFF calibration (listings 4-6) on the regions API — a
    Region driven by AdaptivePolicy; the chosen cutoff is recorded with
    the region's ledger row and the routing decisions land in the same
    coverage report as staging fractions."""
    from repro.core.ledger import Ledger
    from repro.core.regions import AdaptivePolicy, Executor, region
    ldg = Ledger("dispatch")
    saxpy = region("saxpy", ledger=ldg)(lambda x: x * 2.0 + 1.0)
    pol = AdaptivePolicy()
    cut = pol.calibrate(saxpy, lambda n: (jnp.ones(n),),
                        sizes=(256, 1024, 4096, 16384, 65536, 262144),
                        ledger=ldg)
    ex = Executor(pol, ldg)
    ex.run(saxpy, jnp.ones(max(cut // 2, 1)))     # below cutoff -> host
    ex.run(saxpy, jnp.ones(2 * cut))              # above cutoff -> device
    rep = ldg.coverage_report()
    row("dispatch/target_cutoff", 0.0,
        f"cutoff={cut};ledger={rep['cutoffs']}"
        f";host_calls={rep['host_calls']};device_calls={rep['device_calls']}")


def kernel_bench(grid=(64, 64, 64), reps: int = 20):
    """Solver hot-spot micro-bench: jnp reference timings + the fused
    kernel's analytic HBM-traffic ratio (the kernel itself runs in
    interpret mode on CPU, so its wall-time is not meaningful here)."""
    from repro.cfd import fvm
    from repro.cfd.dia import DiaMatrix, amul_ref
    from repro.cfd.grid import Grid
    from repro.cfd.precond import RBDilu, rb_dilu_apply, rb_dilu_factor
    g = Grid(grid)
    A, _ = fvm.laplacian(g, 1.0)
    x = jnp.ones(g.shape, jnp.float32)
    f = jax.jit(lambda d, o, x: amul_ref(DiaMatrix(d, o), x))
    f(A.diag, A.off, x).block_until_ready()
    t0 = time.perf_counter()
    for _ in range(reps):
        y = f(A.diag, A.off, x)
    y.block_until_ready()
    row("kernel/amul_jnp", (time.perf_counter() - t0) / reps * 1e6,
        f"cells={g.n}")
    # per-cell float traffic: unfused = 7 shifted passes (read+write each)
    # + 7 coeff reads + 1 write; fused = x(+halo) + 7 coeffs + 1 write
    row("kernel/amul_traffic_ratio", 0.0, f"x{(7 * 2 + 7 + 1) / 10:.2f}")
    red, _ = g.red_black_masks()
    P = rb_dilu_factor(A, red)
    h = jax.jit(lambda rd, r: rb_dilu_apply(RBDilu(rd, red), A, r))
    h(P.rdiag, x).block_until_ready()
    t0 = time.perf_counter()
    for _ in range(reps):
        y = h(P.rdiag, x)
    y.block_until_ready()
    row("kernel/rb_dilu_jnp", (time.perf_counter() - t0) / reps * 1e6,
        f"cells={g.n}")


def solver_bench(grid=(32, 32, 32)):
    """PBiCGStab end-to-end: region-granular (paper) vs fused while_loop
    (beyond-paper) on identical systems."""
    from repro.cfd import fvm
    from repro.cfd.grid import Grid
    from repro.cfd.precond import rb_dilu_factor
    from repro.cfd.solvers import (make_solver_regions, pbicgstab_fused,
                                   pbicgstab_regions)
    from repro.core.ledger import Ledger
    from repro.core.regions import Executor, UnifiedPolicy
    g = Grid(grid)
    A, _ = fvm.laplacian(g, 1.0)
    b = jnp.ones(g.shape, jnp.float32)
    red, _ = g.red_black_masks()
    P = rb_dilu_factor(A, red)
    ldg = Ledger("bench")
    regions = make_solver_regions(ldg)
    ex = Executor(UnifiedPolicy(), ldg)
    pbicgstab_regions(ex, regions, A, b, jnp.zeros_like(b), P, tol=1e-6)
    t0 = time.perf_counter()
    r = pbicgstab_regions(ex, regions, A, b, jnp.zeros_like(b), P, tol=1e-6)
    t_reg = time.perf_counter() - t0
    pbicgstab_fused(A, b, jnp.zeros_like(b), P.rdiag, P.red, tol=1e-6)
    t0 = time.perf_counter()
    x, it, _, res = pbicgstab_fused(A, b, jnp.zeros_like(b), P.rdiag, P.red,
                                    tol=1e-6)
    jax.block_until_ready(x)
    t_fused = time.perf_counter() - t0
    row("solver/pbicgstab_regions", t_reg * 1e6, f"iters={r.iters}")
    row("solver/pbicgstab_fused", t_fused * 1e6,
        f"iters={int(it)};speedup=x{t_reg / max(t_fused, 1e-12):.2f}")


def lm_train_bench(steps: int = 3):
    """LM substrate throughput at smoke scale (tok/s, reduced tinyllama)."""
    from repro.launch.train import main
    t0 = time.perf_counter()
    losses = main(["--arch", "tinyllama-1.1b", "--reduced",
                   "--steps", str(steps), "--batch", "4", "--seq", "64"])
    dt = (time.perf_counter() - t0) / steps
    row("lm/train_step_reduced", dt * 1e6, f"loss={losses[-1]:.3f}")


def roofline_report(art_dir: str = "artifacts/dryrun"):
    """Summarize the dry-run roofline artifacts (docs/EXPERIMENTS.md source)."""
    d = Path(art_dir)
    if not d.exists():
        row("roofline/missing", 0.0, "run launch.dryrun --sweep first")
        return
    cells = []
    for f in sorted(d.glob("*__sp.json")):
        rec = json.loads(f.read_text())
        if rec.get("status") != "ok":
            continue
        r = rec["roofline"]
        cells.append((rec["arch"], rec["shape"], r["bottleneck"],
                      r["roofline_fraction"]))
        row(f"roofline/{rec['arch']}/{rec['shape']}",
            max(r["compute_s"], r["memory_s"], r["collective_s"]) * 1e6,
            f"bottleneck={r['bottleneck'].replace('_s', '')}"
            f";fraction={r['roofline_fraction']:.4f}")
    if cells:
        worst = min(cells, key=lambda c: c[3])
        row("roofline/worst_cell", 0.0,
            f"{worst[0]}/{worst[1]};fraction={worst[3]:.5f}")


BENCHES = {
    "fig5_speedup": fig5_speedup,
    "fig6_migration": fig6_migration,
    "fig6b_overlap": fig6b_overlap,
    "fig_scaling": fig_scaling,
    "fig_variants": fig_variants,
    "fig4_coverage": fig4_coverage,
    "fig_serve": fig_serve,
    "fig_traffic": fig_traffic,
    "fig_oversub": fig_oversub,
    "fig_tune": fig_tune,
    "pool": pool_bench,
    "dispatch": dispatch_bench,
    "kernel": kernel_bench,
    "solver": solver_bench,
    "lm_train": lm_train_bench,
    "roofline": roofline_report,
}


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default="",
                    help=f"comma list of benchmarks ({','.join(BENCHES)})")
    ap.add_argument("--out", default="",
                    help="also write the CSV rows to this file")
    args = ap.parse_args(argv)
    names = [n for n in args.only.split(",") if n] or list(BENCHES)
    unknown = [n for n in names if n not in BENCHES]
    if unknown:
        raise SystemExit(f"unknown benchmark(s): {unknown}")
    print("name,us_per_call,derived")
    for n in names:
        BENCHES[n]()
    if args.out:
        out = Path(args.out)
        out.parent.mkdir(parents=True, exist_ok=True)
        out.write_text("name,us_per_call,derived\n" + "".join(
            f"{n},{us:.1f},{d}\n" for n, us, d in ROWS))
        print(f"[bench] wrote {len(ROWS)} rows to {out}", flush=True)


if __name__ == "__main__":
    main()
